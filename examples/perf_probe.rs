//! Perf probe: sweep solver knobs (inner tolerance ratio, Anderson M,
//! ws growth) on the dense Figure-1 workload and an rcv1-like sparse one.
//! Used for the EXPERIMENTS.md §Perf iteration log.
//!
//! ```bash
//! cargo run --release --offline --example perf_probe
//! ```

use skglm::data::{correlated, sparse, CorrelatedSpec, Dataset, SparseSpec};
use skglm::datafit::Quadratic;
use skglm::estimators::linear::quadratic_lambda_max;
use skglm::penalty::L1;
use skglm::solver::{solve, SolverOpts};

fn bench(ds: &Dataset, lam_div: f64, label: &str, opts_fn: impl Fn(&mut SolverOpts)) {
    let lam = quadratic_lambda_max(&ds.design, &ds.y) / lam_div;
    let pen = L1::new(lam);
    let mut opts = SolverOpts::default().with_tol(1e-10);
    opts_fn(&mut opts);
    // median of 3
    let mut times = Vec::new();
    let mut last = None;
    for _ in 0..3 {
        let mut f = Quadratic::new();
        let t0 = std::time::Instant::now();
        let r = solve(&ds.design, &ds.y, &mut f, &pen, &opts, None, None);
        times.push(t0.elapsed().as_secs_f64());
        last = Some(r);
    }
    times.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let r = last.unwrap();
    println!(
        "{label:<36} λ/{lam_div:<5} {:>8.3}s  outer {:>3}  epochs {:>6}  acc/rej {}/{}  kkt {:.1e}",
        times[1], r.n_outer, r.n_epochs, r.accepted_extrapolations, r.rejected_extrapolations, r.kkt
    );
}

fn main() {
    let dense = correlated(CorrelatedSpec { n: 1000, p: 2000, rho: 0.6, nnz: 200, snr: 5.0 }, 42);
    let sp = sparse(
        "sparse_probe",
        SparseSpec { n: 3000, p: 60_000, density: 1e-3, support_frac: 5e-4, snr: 5.0, binary: false },
        42,
    );
    for (name, ds, divs) in [("dense 1000x2000", &dense, [10.0, 100.0]), ("sparse 3000x60000", &sp, [10.0, 50.0])] {
        println!("=== {name} ===");
        for div in divs {
            bench(ds, div, "default (ratio 0.3, M=5)", |_| {});
            bench(ds, div, "inner ratio 0.1", |o| o.inner_tol_ratio = 0.1);
            bench(ds, div, "inner ratio 0.05", |o| o.inner_tol_ratio = 0.05);
            bench(ds, div, "inner ratio 0.5", |o| o.inner_tol_ratio = 0.5);
            bench(ds, div, "M=3", |o| o.anderson_m = 3);
            bench(ds, div, "M=8", |o| o.anderson_m = 8);
            bench(ds, div, "no accel", |o| o.anderson_m = 0);
            bench(ds, div, "no ws", |o| o.use_ws = false);
            println!();
        }
    }
}
