//! END-TO-END DRIVER: exercises the full three-layer system on a real
//! small workload and reports the paper's headline metric.
//!
//! The pipeline proves all layers compose:
//!   1. L1/L2 — AOT artifacts (Pallas `Xᵀr` kernel inside a JAX graph,
//!      lowered to HLO text by `make artifacts`) are loaded through PJRT
//!      and serve the solver's scoring pass on the dense workload;
//!   2. L3 — the Rust skglm solver (working sets + Anderson) runs against
//!      four baselines through the benchopt-style harness on the Figure-1
//!      dense problem (n=1000, p=2000) and an rcv1-like sparse problem;
//!   3. the headline metric — time to reach a 1e-6 normalized duality
//!      gap, skglm vs each baseline — is printed and appended to
//!      EXPERIMENTS.md-ready CSV under results/end_to_end/.
//!
//! ```bash
//! make artifacts && cargo run --release --offline --example end_to_end
//! ```

use skglm::bench::harness::{black_box_curve, budget_schedule, SolverCurve};
use skglm::bench::report::{summary_table, write_curves};
use skglm::data::{correlated, sparse, CorrelatedSpec, Dataset, SparseSpec};
use skglm::datafit::Quadratic;
use skglm::estimators::linear::quadratic_lambda_max;
use skglm::penalty::L1;
use skglm::solver::baselines::{celer::solve_celer, fireworks::solve_fireworks, pgd::solve_pgd};
use skglm::solver::{solve, GradEngine, SolverOpts};

fn norm_gap(ds: &Dataset, beta: &[f64], lam: f64) -> f64 {
    let mut xb = vec![0.0; ds.n()];
    ds.design.matvec(beta, &mut xb);
    let r: Vec<f64> = ds.y.iter().zip(xb.iter()).map(|(a, b)| a - b).collect();
    let p0 = skglm::linalg::sq_nrm2(&ds.y) / (2.0 * ds.n() as f64);
    skglm::metrics::lasso_gap(&ds.design, &ds.y, beta, &r, lam) / p0
}

fn run_workload(name: &str, ds: &Dataset, lam_div: f64, use_pjrt: bool) -> Vec<SolverCurve> {
    let lam = quadratic_lambda_max(&ds.design, &ds.y) / lam_div;
    let pen = L1::new(lam);
    let budgets = budget_schedule(40, 1.6);
    println!("\n--- workload {name}: n={}, p={}, λ=λmax/{lam_div} ---", ds.n(), ds.p());

    let mut curves = vec![
        black_box_curve("full_cd", &budgets, |b| {
            let mut f = Quadratic::new();
            let mut opts = SolverOpts::default().with_tol(1e-14).without_ws().without_acceleration();
            opts.max_outer = 1;
            opts.max_epochs = b * 10;
            opts.inner_tol_ratio = 0.0;
            let r = solve(&ds.design, &ds.y, &mut f, &pen, &opts, None, None);
            (r.objective, norm_gap(ds, &r.beta, lam))
        }),
        black_box_curve("fista", &budgets, |b| {
            let mut f = Quadratic::new();
            let r = solve_pgd(&ds.design, &ds.y, &mut f, &pen, b * 10, 1e-14, true);
            (r.objective, norm_gap(ds, &r.beta, lam))
        }),
        black_box_curve("celer_like", &budgets, |b| {
            let mut opts = SolverOpts::default().with_tol(1e-14);
            opts.max_outer = b;
            let r = solve_celer(&ds.design, &ds.y, lam, &opts);
            (r.objective, norm_gap(ds, &r.beta, lam))
        }),
        black_box_curve("fireworks_like", &budgets, |b| {
            let mut f = Quadratic::new();
            let mut opts = SolverOpts::default().with_tol(1e-14);
            opts.max_outer = b;
            let r = solve_fireworks(&ds.design, &ds.y, &mut f, &pen, &opts);
            (r.objective, norm_gap(ds, &r.beta, lam))
        }),
        black_box_curve("skglm", &budgets, |b| {
            let mut f = Quadratic::new();
            let mut opts = SolverOpts::default().with_tol(1e-14);
            opts.max_outer = b;
            let r = solve(&ds.design, &ds.y, &mut f, &pen, &opts, None, None);
            (r.objective, norm_gap(ds, &r.beta, lam))
        }),
    ];

    // the three-layer path: PJRT-served scoring (dense shapes with AOT
    // artifacts only)
    if use_pjrt {
        let (n, p) = (ds.n(), ds.p());
        if skglm::runtime::PjrtRuntime::available("xt_r", n, p) {
            let rt = skglm::runtime::PjrtRuntime::cpu().expect("PJRT client");
            let mut engine = skglm::runtime::PjrtGradEngine::for_design(&rt, &ds.design)
                .expect("engine for dense design");
            println!("    [pjrt] artifact xt_r_n{n}_p{p} loaded on {}", rt.platform());
            curves.push(black_box_curve("skglm_pjrt_scoring", &budgets, |b| {
                let mut f = Quadratic::new();
                let mut opts = SolverOpts::default()
                    .with_tol(skglm::runtime::PjrtGradEngine::MIN_TOL);
                opts.max_outer = b;
                let r = solve(
                    &ds.design,
                    &ds.y,
                    &mut f,
                    &pen,
                    &opts,
                    Some(&mut engine as &mut dyn GradEngine),
                    None,
                );
                (r.objective, norm_gap(ds, &r.beta, lam))
            }));
            println!("    [pjrt] scoring passes served: {}", engine.calls);
        } else {
            println!("    [pjrt] artifacts missing — run `make artifacts` (falling back to native only)");
        }
    }
    curves
}

fn main() {
    println!("=== skglm-rs end-to-end driver ===");
    println!("layers: L1 Pallas kernel -> L2 JAX graph -> HLO text -> PJRT -> L3 Rust solver");

    // workload 1: the Figure-1 dense problem (AOT artifact shape) at
    // λmax/10 — the WS-favourable regime the paper's Figure 2 sweeps
    let dense = correlated(CorrelatedSpec { n: 1000, p: 2000, rho: 0.6, nnz: 200, snr: 5.0 }, 42);
    let dense_curves = run_workload("dense_fig1", &dense, 10.0, true);

    // workload 2: a news20-scale sparse stand-in (native CSC path; large
    // enough for wall-clock times to mean something)
    let sparse_ds = sparse(
        "news20_scale",
        SparseSpec { n: 5_000, p: 100_000, density: 1e-3, support_frac: 5e-4, snr: 5.0, binary: false },
        42,
    );
    let sparse_curves = run_workload("news20_scale", &sparse_ds, 50.0, false);

    // headline: time to reach each gap decade; the speedup is quoted at
    // the deepest target every solver pair reached
    let targets = [1e-3, 1e-6, 1e-9];
    for (name, curves) in [("dense_fig1", &dense_curves), ("news20_scale", &sparse_curves)] {
        println!("\n=== {name}: time to reach normalized-gap targets ===");
        println!("{}", summary_table(curves, &targets).text());
        let skglm = curves.iter().find(|c| c.solver == "skglm").unwrap();
        let cd = curves.iter().find(|c| c.solver == "full_cd").unwrap();
        for &tgt in targets.iter().rev() {
            if let (Some(a), Some(b)) = (skglm.time_to(tgt), cd.time_to(tgt)) {
                println!(
                    "HEADLINE {name}: skglm reaches gap {tgt:.0e} {:.1}x faster than full CD ({:.3}s vs {:.3}s)",
                    b / a.max(1e-9),
                    a,
                    b
                );
                break;
            }
        }
        write_curves("end_to_end", name, "headline", curves).expect("write results");
    }
    println!("\nresults written under results/end_to_end/ — see EXPERIMENTS.md");
}
