//! §E.4 reproduction: hinge-loss SVM solved in the dual as Problem (1)
//! with the box-indicator penalty. Shows the generalized-support concept
//! (Definition 4) on a non-sparsity problem: the working set tracks the
//! *free* dual variables (margin support vectors).
//!
//! ```bash
//! cargo run --release --offline --example svm_dual
//! ```

use skglm::data::{paper_dataset_small, Dataset};
use skglm::estimators::LinearSvc;
use skglm::linalg::Design;

fn main() {
    let ds: Dataset = paper_dataset_small("real-sim", 42).expect("real-sim stand-in");
    let x = match &ds.design {
        Design::Sparse(s) => s.clone(),
        _ => unreachable!(),
    };
    println!(
        "real-sim stand-in: n={} samples, d={} features, density {:.1e}",
        ds.n(),
        ds.p(),
        x.density()
    );

    println!(
        "\n{:>6} {:>10} {:>10} {:>10} {:>10} {:>9} {:>10}",
        "C", "dual obj", "kkt", "free α", "bound α", "epochs", "train acc"
    );
    for &c in &[0.1, 1.0, 10.0] {
        let t0 = std::time::Instant::now();
        let fit = LinearSvc::new(c).with_tol(1e-7).fit_sparse(&x, &ds.y);
        let pen = skglm::penalty::BoxIndicator::new(c);
        use skglm::penalty::Penalty;
        let free = fit.alpha.beta.iter().filter(|&&a| pen.in_gsupp(a)).count();
        let at_bounds = fit.alpha.beta.len() - free;
        // training accuracy from the recovered primal coefficients
        let mut scores = vec![0.0; ds.n()];
        ds.design.matvec(&fit.primal_coef, &mut scores);
        // wait: primal scores are X β; our design is X itself
        let acc = scores
            .iter()
            .zip(ds.y.iter())
            .filter(|(s, y)| s.signum() == y.signum())
            .count() as f64
            / ds.n() as f64;
        println!(
            "{:>6} {:>10.3} {:>10.1e} {:>10} {:>10} {:>9} {:>9.1}%  ({:.2}s)",
            c,
            fit.alpha.objective,
            fit.alpha.kkt,
            free,
            at_bounds,
            fit.alpha.n_epochs,
            acc * 100.0,
            t0.elapsed().as_secs_f64()
        );
    }

    println!("\nDefinition-4 check: the generalized support of the dual problem is");
    println!("the set of FREE variables 0 < α_i < C — the working-set solver only");
    println!("sweeps those once identified, which is why harder problems (larger C,");
    println!("more margin violations) still solve quickly.");
}
