//! Figure 4 reproduction: M/EEG source localisation on the simulated
//! right-auditory-stimulation dataset. The convex ℓ2,1 penalty biases
//! amplitudes and splits/mislocalises sources; block-MCP and block-SCAD
//! recover exactly one source per hemisphere.
//!
//! ```bash
//! cargo run --release --offline --example meeg_source_localization
//! ```

use skglm::data::meeg::{localize, simulate, MeegSpec};
use skglm::estimators::multitask::{
    block_lambda_max, flatten_tasks, unflatten_coef, BlockMcpRegressor, BlockScadRegressor,
    MultiTaskLasso,
};
use skglm::linalg::Design;

fn main() {
    let spec = MeegSpec::default();
    let pb = simulate(spec, 42);
    println!(
        "simulated M/EEG: {} sensors, {} sources, {} time points, 2 planted sources at positions {:+.2} / {:+.2}",
        pb.gain.nrows(),
        pb.gain.ncols(),
        pb.measurements.ncols(),
        pb.positions[pb.active[0]],
        pb.positions[pb.active[1]]
    );

    let design = Design::Dense(pb.gain.clone());
    let y = flatten_tasks(&pb.measurements);
    let t = pb.measurements.ncols();
    let lam_max = block_lambda_max(&design, &y, t);
    let lam = 0.3 * lam_max;
    // γ > 1/L_j = n_sensors for the unit-norm leadfield (semi-convexity)
    let gamma = 2.5 * pb.gain.nrows() as f64;

    let runs: Vec<(&str, skglm::solver::MultiTaskFit)> = vec![
        ("l2,1 (convex)", MultiTaskLasso::new(lam).with_tol(1e-6).fit(&design, &y, t)),
        ("block-MCP", BlockMcpRegressor::new(lam, gamma).with_tol(1e-6).fit(&design, &y, t)),
        ("block-SCAD", BlockScadRegressor::new(lam, gamma).fit(&design, &y, t)),
    ];

    println!(
        "\n{:<14} {:>6} {:>12} {:>12} {:>18} {:>10}",
        "penalty", "rows", "hemispheres", "pos-error", "epochs", "converged"
    );
    for (name, fit) in &runs {
        let w = unflatten_coef(&fit.w, t);
        let loc = localize(&pb, &w, 1e-6);
        println!(
            "{:<14} {:>6} {:>12} {:>12} {:>18} {:>10}",
            name,
            loc.recovered.len(),
            format!("{}/2", loc.hemispheres_hit),
            if loc.max_position_error.is_finite() {
                format!("{:.4}", loc.max_position_error)
            } else {
                "missed".into()
            },
            fit.n_epochs,
            fit.converged
        );
    }

    // amplitude bias: compare recovered row norms at the true sources
    println!("\nrecovered amplitude at the true sources (truth row-norms shown first):");
    let truth_norm = |j: usize| {
        (0..t).map(|tt| pb.sources_true.get(j, tt).powi(2)).sum::<f64>().sqrt()
    };
    print!("{:<14}", "truth");
    for &j in &pb.active {
        print!(" src@{:+.2}: {:>7.3}", pb.positions[j], truth_norm(j));
    }
    println!();
    for (name, fit) in &runs {
        let w = unflatten_coef(&fit.w, t);
        print!("{name:<14}");
        for &j in &pb.active {
            let norm = (0..t).map(|tt| w.get(j, tt).powi(2)).sum::<f64>().sqrt();
            print!(" src@{:+.2}: {:>7.3}", pb.positions[j], norm);
        }
        println!();
    }
    println!("\n(expected: ℓ2,1 under-estimates amplitudes / may split sources;");
    println!(" block-MCP and block-SCAD hit both hemispheres with tight positions)");
}
