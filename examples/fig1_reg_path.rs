//! Figure 1 reproduction: regularization paths of L1 / MCP / SCAD / ℓ0.5
//! on the correlated design — non-convex penalties achieve exact support
//! recovery, lower estimation error, and their best-estimation and
//! best-prediction λ coincide (the paper's headline qualitative claim).
//!
//! ```bash
//! cargo run --release --offline --example fig1_reg_path [-- --full]
//! ```

use skglm::data::{correlated, CorrelatedSpec};
use skglm::estimators::path::{geometric_grid, lasso_path, lq_path, mcp_path, scad_path};
use skglm::solver::SolverOpts;

fn main() {
    let full = std::env::args().any(|a| a == "--full");
    let scale = if full { 1.0 } else { 0.15 };
    let ds = correlated(CorrelatedSpec::figure1(scale), 42);
    let mut design = ds.design.clone();
    design.normalize_cols((ds.n() as f64).sqrt());
    println!(
        "Figure-1 data: n={}, p={}, |supp(β*)|={}, SNR=5, ρ=0.6",
        ds.n(),
        ds.p(),
        ds.beta_true.iter().filter(|&&b| b != 0.0).count()
    );

    let ratios = geometric_grid(1e-3, if full { 30 } else { 15 });
    let opts = SolverOpts::default().with_tol(1e-7);

    let paths = vec![
        lasso_path(&design, &ds.y, Some(&ds.beta_true), &ratios, &opts),
        mcp_path(&design, &ds.y, Some(&ds.beta_true), &ratios, 3.0, &opts),
        scad_path(&design, &ds.y, Some(&ds.beta_true), &ratios, 3.7, &opts),
        lq_path(&design, &ds.y, Some(&ds.beta_true), &ratios, 0.5, &opts),
    ];

    for path in &paths {
        println!("\n=== {} (path computed in {:.2}s) ===", path.penalty_name, path.total_time);
        println!("{:<12} {:>8} {:>5} {:>5} {:>11} {:>11}", "λ/λmax", "supp", "tp", "fp", "est_err", "pred_mse");
        for pt in &path.points {
            let rec = pt.recovery.as_ref().unwrap();
            println!(
                "{:<12.4e} {:>8} {:>5} {:>5} {:>11.4e} {:>11.4e}",
                pt.lambda_ratio,
                pt.support_size,
                rec.true_positives,
                rec.false_positives,
                pt.estimation_error.unwrap(),
                pt.prediction_mse.unwrap()
            );
        }
        let be = path.best_estimation().unwrap();
        let bp = path.best_prediction().unwrap();
        println!(
            "-> exact recovery anywhere: {} | best-estimation λ/λmax {:.3e} | best-prediction λ/λmax {:.3e}{}",
            path.any_exact_recovery(),
            be.lambda_ratio,
            bp.lambda_ratio,
            if (be.lambda_ratio - bp.lambda_ratio).abs() < 1e-12 {
                "  (they coincide — the paper's top/bottom-panel agreement)"
            } else {
                ""
            }
        );
    }

    println!("\nPaper's Figure-1 claims to check above:");
    println!(" 1. non-convex paths (mcp/scad/lq) reach exact support recovery; l1 does not");
    println!(" 2. non-convex best estimation error < lasso best estimation error");
    println!(" 3. for non-convex penalties the optimal λ in estimation and prediction agree");
}
