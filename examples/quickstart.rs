//! Quickstart: fit a Lasso, an elastic net and an MCP regressor on a
//! synthetic correlated design and inspect the results.
//!
//! ```bash
//! cargo run --release --offline --example quickstart
//! ```

use skglm::metrics::support_recovery;
use skglm::prelude::*;

fn main() {
    // Figure-1-style data: n=1000, p=2000, AR(1) correlation 0.6, 200
    // nonzero coefficients, SNR 5 (scaled to 20% for a fast demo).
    let ds = skglm::data::correlated(CorrelatedSpec::figure1(0.2), 42);
    println!("dataset: n={}, p={}, true support={}", ds.n(), ds.p(),
             ds.beta_true.iter().filter(|&&b| b != 0.0).count());

    let lam_max = Lasso::lambda_max(&ds.design, &ds.y);
    let lam = lam_max / 10.0;

    // --- Lasso ---
    let t0 = std::time::Instant::now();
    let lasso = Lasso::new(lam).with_tol(1e-8).fit(&ds.design, &ds.y);
    let rec = support_recovery(&lasso.beta, &ds.beta_true, 1e-8);
    println!(
        "\nLasso      λ=λmax/10: {} epochs, {:.3}s, support {} (tp {}, fp {}), kkt {:.1e}",
        lasso.n_epochs,
        t0.elapsed().as_secs_f64(),
        lasso.support().len(),
        rec.true_positives,
        rec.false_positives,
        lasso.kkt
    );

    // --- Elastic net ---
    let t0 = std::time::Instant::now();
    let enet = ElasticNet::new(lam, 0.5).with_tol(1e-8).fit(&ds.design, &ds.y);
    println!(
        "ElasticNet ρ=0.5     : {} epochs, {:.3}s, support {}",
        enet.n_epochs,
        t0.elapsed().as_secs_f64(),
        enet.support().len()
    );

    // --- MCP: sparser + less biased (the paper's Figure-1 point) ---
    let t0 = std::time::Instant::now();
    let (mcp, scales) = McpRegressor::new(lam, 3.0).with_tol(1e-8).fit(&ds.design, &ds.y);
    let beta_orig: Vec<f64> = mcp.beta.iter().zip(scales.iter()).map(|(b, s)| b * s).collect();
    let rec_mcp = support_recovery(&beta_orig, &ds.beta_true, 1e-8);
    println!(
        "MCP γ=3              : {} epochs, {:.3}s, support {} (tp {}, fp {}), kkt {:.1e}",
        mcp.n_epochs,
        t0.elapsed().as_secs_f64(),
        mcp.support().len(),
        rec_mcp.true_positives,
        rec_mcp.false_positives,
        mcp.kkt
    );

    // --- generic API: any (datafit, penalty) pair ---
    let mut datafit = Quadratic::new();
    let fit = solve(
        &ds.design,
        &ds.y,
        &mut datafit,
        &Lq::half(lam / 2.0),
        &SolverOpts::default().with_tol(1e-7),
        None,
        None,
    );
    println!(
        "ℓ0.5 (score^cd rule) : {} epochs, support {}",
        fit.n_epochs,
        fit.support().len()
    );

    println!("\nMCP mean |coef| on true support vs Lasso (bias check):");
    let true_sup: Vec<usize> =
        ds.beta_true.iter().enumerate().filter(|(_, &b)| b != 0.0).map(|(j, _)| j).collect();
    let mean = |b: &[f64]| {
        true_sup.iter().map(|&j| b[j].abs()).sum::<f64>() / true_sup.len() as f64
    };
    println!(
        "  lasso {:.3}   mcp {:.3}   (truth 1.000 — MCP shrinks less)",
        mean(&lasso.beta),
        mean(&beta_orig)
    );
}
