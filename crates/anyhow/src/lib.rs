//! Vendored, dependency-free subset of the `anyhow` error-handling API.
//!
//! This environment builds fully offline (no crates.io registry), so the
//! workspace ships the small slice of `anyhow` the codebase actually uses
//! as a path dependency under the same crate name: [`Error`], [`Result`],
//! the [`Context`] extension trait, and the [`anyhow!`] / [`bail!`] /
//! [`ensure!`] macros. Call sites are source-compatible with the real
//! crate; swapping back to crates.io `anyhow` is a one-line change in
//! `rust/Cargo.toml`.
//!
//! Differences from upstream (acceptable for this repo's usage):
//! - the error stores its context chain as strings (no live `source()`
//!   chain, no downcasting, no backtraces);
//! - `{:#}` formatting joins the chain with `": "` exactly like upstream;
//!   `{:?}` prints the upstream-style "Caused by:" block.

use std::error::Error as StdError;
use std::fmt;

/// A string-chained error value. Outermost context first.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Build an error from a printable message.
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Error { chain: vec![message.to_string()] }
    }

    /// Attach higher-level context (becomes the new outermost message).
    pub fn context<C: fmt::Display>(mut self, context: C) -> Self {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The context chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }

    /// The innermost (root-cause) message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().expect("error chain is never empty")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            f.write_str(&self.chain.join(": "))
        } else {
            f.write_str(&self.chain[0])
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.chain[0])?;
        if self.chain.len() > 1 {
            f.write_str("\n\nCaused by:")?;
            for (i, cause) in self.chain[1..].iter().enumerate() {
                write!(f, "\n    {i}: {cause}")?;
            }
        }
        Ok(())
    }
}

// Like upstream anyhow, `Error` deliberately does NOT implement
// `std::error::Error`, which is what makes this blanket conversion
// coexist with the reflexive `From<Error> for Error`.
impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(err: E) -> Self {
        let mut chain = vec![err.to_string()];
        let mut source = err.source();
        while let Some(s) = source {
            chain.push(s.to_string());
            source = s.source();
        }
        Error { chain }
    }
}

/// `anyhow`-style result alias with a defaulted error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(...)` / `.with_context(...)`.
pub trait Context<T> {
    /// Wrap the error with a higher-level message.
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static;

    /// Like [`Context::context`], evaluated lazily.
    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E: Into<Error>> Context<T> for Result<T, E> {
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
    {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
    {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string (or any `Display` value).
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(::std::format!($msg))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(::std::format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Return early with an [`anyhow!`] error.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing file")
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        let e = inner().unwrap_err();
        assert_eq!(e.to_string(), "missing file");
    }

    #[test]
    fn context_chains_and_alternate_format() {
        let e: Result<()> = Err(io_err());
        let e = e.context("parsing HLO text").unwrap_err();
        assert_eq!(format!("{e}"), "parsing HLO text");
        assert_eq!(format!("{e:#}"), "parsing HLO text: missing file");
        assert_eq!(e.root_cause(), "missing file");
    }

    #[test]
    fn with_context_on_option() {
        let v: Option<u32> = None;
        let e = v.with_context(|| format!("no value for {}", "k")).unwrap_err();
        assert_eq!(e.to_string(), "no value for k");
        assert_eq!(Some(7).context("x").unwrap(), 7);
    }

    #[test]
    fn macros_build_errors() {
        fn f(x: i32) -> Result<i32> {
            ensure!(x >= 0, "negative: {x}");
            if x == 0 {
                bail!("zero not allowed");
            }
            Ok(x)
        }
        assert_eq!(f(3).unwrap(), 3);
        assert_eq!(f(0).unwrap_err().to_string(), "zero not allowed");
        assert_eq!(f(-2).unwrap_err().to_string(), "negative: -2");
        let e = anyhow!("plain {}", 1);
        assert_eq!(e.to_string(), "plain 1");
    }

    #[test]
    fn debug_prints_cause_block() {
        let e = Error::from(io_err()).context("outer");
        let dbg = format!("{e:?}");
        assert!(dbg.starts_with("outer"));
        assert!(dbg.contains("Caused by:"));
    }
}
