#!/usr/bin/env bash
# CI gate: formatting, lints, tests, docs. Everything runs offline
# (path-only dependencies; see ARCHITECTURE.md §Dependencies).
set -euo pipefail
cd "$(dirname "$0")/.."

echo "==> cargo fmt --check"
cargo fmt --all --check

echo "==> cargo clippy -D warnings"
cargo clippy --workspace --all-targets -- -D warnings

echo "==> cargo test -q"
cargo test -q --workspace

echo "==> cargo bench --no-run (benches are compile-gated)"
cargo bench --no-run --workspace

echo "==> kernel bench smoke (writes BENCH_kernels.json)"
cargo run --release -p skglm --bin skglm -- exp kernels

echo "==> glm bench smoke (writes BENCH_glms.json)"
cargo run --release -p skglm --bin skglm -- exp glms

echo "==> group bench smoke (writes BENCH_groups.json)"
cargo run --release -p skglm --bin skglm -- exp groups

echo "==> gram inner-engine bench smoke (writes BENCH_gram.json)"
cargo run --release -p skglm --bin skglm -- exp gram

echo "==> batched-fit bench smoke (writes BENCH_batch.json)"
cargo run --release -p skglm --bin skglm -- exp batch

echo "==> simd/precision kernel bench smoke (writes BENCH_simd.json)"
cargo run --release -p skglm --bin skglm -- exp simd

echo "==> scenario conformance smoke gate (writes BENCH_scenarios.json; non-zero exit on any failing scenario)"
cargo run --release -p skglm --bin skglm -- conform --smoke

echo "==> scenario conformance smoke gate under the pinned scalar ISA (bit-identity leg of ARCHITECTURE.md §Kernel ISA & precision)"
SKGLM_ISA=scalar cargo run --release -p skglm --bin skglm -- conform --smoke

echo "==> serve smoke gate (loopback fit service under a fault plan; writes BENCH_serve_smoke.json; non-zero exit on any unhandled degradation)"
cargo run --release -p skglm --bin skglm -- client --script smoke --transcript BENCH_serve_smoke.json

echo "==> static-analysis gate (writes BENCH_analysis.json; non-zero exit on any finding)"
cargo run --release -p skglm --bin skglm -- analyze

echo "==> roll up BENCH_*.json -> BENCH_SUMMARY.json"
cargo run --release -p skglm --bin skglm -- exp summary

echo "==> cargo doc --no-deps"
RUSTDOCFLAGS="-D warnings" cargo doc --no-deps --workspace

echo "CI green."
