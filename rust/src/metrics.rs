//! Optimality metrics: duality gaps for the convex problems (the y-axis of
//! Figures 2, 3, 6, 7, 8) and the generic stationarity measure
//! `max_j dist(−∇_j f, ∂g_j)` used for the non-convex ones (Figure 5).
//!
//! Duality-gap conventions follow Massias et al. (2018): for the Lasso
//! `P(β) = ‖y−Xβ‖²/2n + λ‖β‖₁`, the dual point is the rescaled residual
//! `θ = r / max(nλ, ‖Xᵀr‖_∞)` and
//! `D(θ) = ‖y‖²/2n − nλ²/2 · ‖θ − y/(nλ)‖²`. The elastic net reduces to a
//! Lasso gap on the augmented design `[X; √(nλ(1−ρ))·I]` computed without
//! materialising the augmentation.

use crate::linalg::Design;

/// Lasso duality gap at `beta` (residual `r = y − Xβ` supplied to avoid a
/// matvec when the caller maintains it; note the *sign*: `y − Xβ`).
pub fn lasso_gap(design: &Design, y: &[f64], beta: &[f64], r: &[f64], lambda: f64) -> f64 {
    let n = design.nrows() as f64;
    let primal =
        crate::linalg::sq_nrm2(r) / (2.0 * n) + lambda * crate::linalg::norm1(beta);
    // dual feasible point: θ = r / max(nλ, ‖Xᵀr‖∞)
    let mut xtr = vec![0.0; design.ncols()];
    design.matvec_t(r, &mut xtr);
    let scale = (n * lambda).max(crate::linalg::norm_inf(&xtr));
    if scale == 0.0 {
        return primal; // degenerate: y == Xβ and λ may be 0
    }
    // D(θ) = ‖y‖²/(2n) − nλ²/2 ‖θ − y/(nλ)‖²
    let nl = n * lambda;
    let mut dev = 0.0;
    for (&ri, &yi) in r.iter().zip(y.iter()) {
        let d = ri / scale - yi / nl;
        dev += d * d;
    }
    let dual = crate::linalg::sq_nrm2(y) / (2.0 * n) - nl * lambda / 2.0 * dev;
    (primal - dual).max(0.0)
}

/// Elastic-net duality gap via the augmented-Lasso reduction:
/// `P(β) = ‖y−Xβ‖²/2n + λρ‖β‖₁ + λ(1−ρ)‖β‖²/2` equals the Lasso primal
/// with design `[X; √(nλ(1−ρ))·I]`, target `[y; 0]` and penalty `λρ‖·‖₁`.
pub fn enet_gap(
    design: &Design,
    y: &[f64],
    beta: &[f64],
    r: &[f64],
    lambda: f64,
    rho: f64,
) -> f64 {
    if rho >= 1.0 {
        return lasso_gap(design, y, beta, r, lambda);
    }
    let n = design.nrows() as f64;
    let l1 = lambda * rho;
    let aug = (n * lambda * (1.0 - rho)).sqrt(); // √(nλ(1−ρ))
    // augmented residual r_aug = [r; −aug·β]
    let r_aug_sq = crate::linalg::sq_nrm2(r) + aug * aug * crate::linalg::sq_nrm2(beta);
    let primal = r_aug_sq / (2.0 * n) + l1 * crate::linalg::norm1(beta);
    // Xᵀ_aug r_aug = Xᵀ r − aug²·β
    let mut xtr = vec![0.0; design.ncols()];
    design.matvec_t(r, &mut xtr);
    for (g, &b) in xtr.iter_mut().zip(beta.iter()) {
        *g -= aug * aug * b;
    }
    let scale = (n * l1).max(crate::linalg::norm_inf(&xtr));
    if scale == 0.0 {
        return primal;
    }
    let nl = n * l1;
    // ‖θ − y_aug/(nλρ)‖² with θ = r_aug/scale, y_aug = [y; 0]
    let mut dev = 0.0;
    for (&ri, &yi) in r.iter().zip(y.iter()) {
        let d = ri / scale - yi / nl;
        dev += d * d;
    }
    for &b in beta.iter() {
        let d = -aug * b / scale;
        dev += d * d;
    }
    let dual = crate::linalg::sq_nrm2(y) / (2.0 * n) - nl * l1 / 2.0 * dev;
    (primal - dual).max(0.0)
}

/// Sparse-logistic duality gap:
/// `P(β) = (1/n)Σ log(1+e^{−y_i x_iᵀβ}) + λ‖β‖₁`;
/// dual `D(θ) = −(1/n)Σ [θ_i n log(θ_i n) + (1−θ_i n)log(1−θ_i n)]` over
/// feasible `‖Xᵀ(θ⊙y)‖∞ ≤ λ` — we rescale the natural residual point.
pub fn logistic_gap(design: &Design, y: &[f64], beta: &[f64], xw: &[f64], lambda: f64) -> f64 {
    let n = design.nrows() as f64;
    let mut primal = 0.0;
    for (&s, &yi) in xw.iter().zip(y.iter()) {
        let v = -yi * s;
        primal += if v > 33.0 { v } else { v.exp().ln_1p() };
    }
    primal = primal / n + lambda * crate::linalg::norm1(beta);
    // natural dual point: w_i = σ(−y_i xw_i)/n, dual var θ_i = y_i w_i
    let mut theta: Vec<f64> = xw
        .iter()
        .zip(y.iter())
        .map(|(&s, &yi)| {
            let sig = 1.0 / (1.0 + (yi * s).exp());
            yi * sig / n
        })
        .collect();
    let mut xt = vec![0.0; design.ncols()];
    design.matvec_t(&theta, &mut xt);
    let scale = (crate::linalg::norm_inf(&xt) / lambda).max(1.0);
    for t in theta.iter_mut() {
        *t /= scale;
    }
    // D(θ) = −(1/n) Σ h(n y_i θ_i), h(u) = u ln u + (1−u) ln(1−u)
    let mut dual = 0.0;
    for (&t, &yi) in theta.iter().zip(y.iter()) {
        let u = (n * yi * t).clamp(1e-12, 1.0 - 1e-12);
        dual -= u * u.ln() + (1.0 - u) * (1.0 - u).ln();
    }
    dual /= n;
    (primal - dual).max(0.0)
}

/// Generic stationarity: `max_j dist(−∇_j f(β), ∂g_j(β_j))` — the paper's
/// Figure-5 metric and the solver's stopping criterion.
pub fn stationarity<D: crate::datafit::Datafit, P: crate::penalty::Penalty>(
    design: &Design,
    y: &[f64],
    datafit: &D,
    penalty: &P,
    beta: &[f64],
    state: &[f64],
) -> f64 {
    let mut grad = vec![0.0; design.ncols()];
    datafit.grad_full(design, y, state, beta, &mut grad);
    let lipschitz = datafit.lipschitz();
    grad.iter()
        .enumerate()
        .map(|(j, &g)| {
            if lipschitz[j] == 0.0 {
                0.0
            } else {
                penalty.subdiff_distance(beta[j], g, j)
            }
        })
        .fold(0.0, f64::max)
}

/// Support-recovery statistics against a ground truth (Figure 1).
#[derive(Clone, Debug, PartialEq)]
pub struct SupportRecovery {
    pub true_positives: usize,
    pub false_positives: usize,
    pub false_negatives: usize,
    pub f1: f64,
    /// exact support recovery
    pub exact: bool,
}

pub fn support_recovery(beta: &[f64], beta_true: &[f64], tol: f64) -> SupportRecovery {
    assert_eq!(beta.len(), beta_true.len());
    let (mut tp, mut fp, mut fne) = (0usize, 0usize, 0usize);
    for (&b, &bt) in beta.iter().zip(beta_true.iter()) {
        let est = b.abs() > tol;
        let tru = bt != 0.0;
        match (est, tru) {
            (true, true) => tp += 1,
            (true, false) => fp += 1,
            (false, true) => fne += 1,
            _ => {}
        }
    }
    let f1 = if 2 * tp + fp + fne == 0 {
        1.0
    } else {
        2.0 * tp as f64 / (2 * tp + fp + fne) as f64
    };
    SupportRecovery { true_positives: tp, false_positives: fp, false_negatives: fne, f1, exact: fp == 0 && fne == 0 }
}

/// Prediction mean-squared error ‖Xβ − Xβ*‖²/n (Figure 1's bottom panel).
pub fn prediction_mse(design: &Design, beta: &[f64], beta_true: &[f64]) -> f64 {
    let n = design.nrows();
    let mut a = vec![0.0; n];
    let mut b = vec![0.0; n];
    design.matvec(beta, &mut a);
    design.matvec(beta_true, &mut b);
    a.iter().zip(b.iter()).map(|(x, y)| (x - y) * (x - y)).sum::<f64>() / n as f64
}

/// Estimation error ‖β − β*‖₂ (Figure 1's top panel).
pub fn estimation_error(beta: &[f64], beta_true: &[f64]) -> f64 {
    beta.iter()
        .zip(beta_true.iter())
        .map(|(a, b)| (a - b) * (a - b))
        .sum::<f64>()
        .sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{correlated, CorrelatedSpec};
    use crate::datafit::{Datafit, Quadratic};
    use crate::penalty::L1;
    use crate::solver::{solve, SolverOpts};

    fn lambda_max(design: &Design, y: &[f64]) -> f64 {
        let n = design.nrows() as f64;
        let mut xty = vec![0.0; design.ncols()];
        design.matvec_t(y, &mut xty);
        crate::linalg::norm_inf(&xty) / n
    }

    fn residual(design: &Design, y: &[f64], beta: &[f64]) -> Vec<f64> {
        let mut xb = vec![0.0; design.nrows()];
        design.matvec(beta, &mut xb);
        y.iter().zip(xb.iter()).map(|(a, b)| a - b).collect()
    }

    #[test]
    fn lasso_gap_positive_and_zero_at_optimum() {
        let ds = correlated(CorrelatedSpec { n: 60, p: 100, rho: 0.5, nnz: 6, snr: 10.0 }, 0);
        let lam = lambda_max(&ds.design, &ds.y) / 10.0;
        // random point: gap > 0
        let beta0 = vec![0.01; 100];
        let r0 = residual(&ds.design, &ds.y, &beta0);
        assert!(lasso_gap(&ds.design, &ds.y, &beta0, &r0, lam) > 0.0);
        // optimum: gap ~ 0
        let mut f = Quadratic::new();
        let res = solve(&ds.design, &ds.y, &mut f, &L1::new(lam), &SolverOpts::default().with_tol(1e-12), None, None);
        let r = residual(&ds.design, &ds.y, &res.beta);
        let gap = lasso_gap(&ds.design, &ds.y, &res.beta, &r, lam);
        assert!(gap < 1e-10, "gap {gap}");
    }

    #[test]
    fn gap_bounds_suboptimality() {
        // P(β) − P* <= gap for any β
        let ds = correlated(CorrelatedSpec { n: 50, p: 60, rho: 0.4, nnz: 5, snr: 10.0 }, 1);
        let lam = lambda_max(&ds.design, &ds.y) / 5.0;
        let mut f = Quadratic::new();
        let res = solve(&ds.design, &ds.y, &mut f, &L1::new(lam), &SolverOpts::default().with_tol(1e-13), None, None);
        let p_star = res.objective;
        let beta = vec![0.05; 60];
        let r = residual(&ds.design, &ds.y, &beta);
        let n = 50.0;
        let primal = crate::linalg::sq_nrm2(&r) / (2.0 * n) + lam * crate::linalg::norm1(&beta);
        let gap = lasso_gap(&ds.design, &ds.y, &beta, &r, lam);
        assert!(gap + 1e-12 >= primal - p_star, "gap {gap} < subopt {}", primal - p_star);
    }

    #[test]
    fn enet_gap_zero_at_optimum_and_matches_lasso_at_rho_1() {
        let ds = correlated(CorrelatedSpec { n: 50, p: 80, rho: 0.5, nnz: 6, snr: 10.0 }, 2);
        let lam = lambda_max(&ds.design, &ds.y) / 10.0;
        let beta = vec![0.02; 80];
        let r = residual(&ds.design, &ds.y, &beta);
        let g1 = enet_gap(&ds.design, &ds.y, &beta, &r, lam, 1.0);
        let g2 = lasso_gap(&ds.design, &ds.y, &beta, &r, lam);
        assert!((g1 - g2).abs() < 1e-12);
        // enet optimum via solver
        let rho = 0.5;
        let mut f = Quadratic::new();
        let res = solve(
            &ds.design,
            &ds.y,
            &mut f,
            &crate::penalty::L1L2::new(lam, rho),
            &SolverOpts::default().with_tol(1e-12),
            None,
            None,
        );
        let r = residual(&ds.design, &ds.y, &res.beta);
        let gap = enet_gap(&ds.design, &ds.y, &res.beta, &r, lam, rho);
        assert!(gap < 1e-10, "gap {gap}");
    }

    #[test]
    fn logistic_gap_zero_at_optimum() {
        let ds = correlated(CorrelatedSpec { n: 80, p: 40, rho: 0.3, nnz: 4, snr: 10.0 }, 3);
        let yb: Vec<f64> = ds.y.iter().map(|&v| if v >= 0.0 { 1.0 } else { -1.0 }).collect();
        // lambda_max for logistic: ||X^T y||_inf / (2n)
        let mut xty = vec![0.0; 40];
        ds.design.matvec_t(&yb, &mut xty);
        let lam = crate::linalg::norm_inf(&xty) / (2.0 * 80.0) / 5.0;
        let mut f = crate::datafit::Logistic::new();
        let res = solve(&ds.design, &yb, &mut f, &L1::new(lam), &SolverOpts::default().with_tol(1e-12), None, None);
        let mut xw = vec![0.0; 80];
        ds.design.matvec(&res.beta, &mut xw);
        let gap = logistic_gap(&ds.design, &yb, &res.beta, &xw, lam);
        assert!(gap.abs() < 1e-8, "gap {gap}");
    }

    #[test]
    fn stationarity_zero_at_optimum_positive_elsewhere() {
        let ds = correlated(CorrelatedSpec { n: 60, p: 90, rho: 0.5, nnz: 6, snr: 8.0 }, 4);
        let lam = lambda_max(&ds.design, &ds.y) / 10.0;
        let pen = L1::new(lam);
        let mut f = Quadratic::new();
        f.init(&ds.design, &ds.y);
        let beta0 = vec![0.5; 90];
        let s0 = f.init_state(&ds.design, &ds.y, &beta0);
        assert!(stationarity(&ds.design, &ds.y, &f, &pen, &beta0, &s0) > 0.0);
        let mut f2 = Quadratic::new();
        let res = solve(&ds.design, &ds.y, &mut f2, &pen, &SolverOpts::default().with_tol(1e-12), None, None);
        let s = f.init_state(&ds.design, &ds.y, &res.beta);
        assert!(stationarity(&ds.design, &ds.y, &f, &pen, &res.beta, &s) < 1e-10);
    }

    #[test]
    fn support_recovery_metrics() {
        let bt = vec![1.0, 0.0, -1.0, 0.0];
        let exact = support_recovery(&[0.9, 0.0, -1.2, 0.0], &bt, 1e-9);
        assert!(exact.exact);
        assert_eq!(exact.f1, 1.0);
        let missed = support_recovery(&[0.9, 0.0, 0.0, 0.0], &bt, 1e-9);
        assert_eq!(missed.false_negatives, 1);
        assert!(!missed.exact);
        let extra = support_recovery(&[0.9, 0.5, -1.0, 0.0], &bt, 1e-9);
        assert_eq!(extra.false_positives, 1);
    }

    #[test]
    fn estimation_and_prediction_errors_zero_at_truth() {
        let ds = correlated(CorrelatedSpec { n: 30, p: 20, rho: 0.2, nnz: 3, snr: 5.0 }, 5);
        assert_eq!(estimation_error(&ds.beta_true, &ds.beta_true), 0.0);
        assert_eq!(prediction_mse(&ds.design, &ds.beta_true, &ds.beta_true), 0.0);
        let other = vec![0.0; 20];
        assert!(estimation_error(&other, &ds.beta_true) > 0.0);
        assert!(prediction_mse(&ds.design, &other, &ds.beta_true) > 0.0);
    }
}
