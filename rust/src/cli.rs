//! Dependency-free CLI argument parsing (clap is unavailable offline).
//!
//! Grammar: `skglm <subcommand> [positional...] [--flag value] [--switch]`.
//! Flags may be `--key value` or `--key=value`; unknown flags are
//! collected and reported by [`Args::finish`] so typos fail loudly.

use std::collections::{HashMap, HashSet};

#[derive(Clone, Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    flags: HashMap<String, String>,
    switches: HashSet<String>,
    consumed: HashSet<String>,
}

impl Args {
    /// Parse from raw arguments (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(args: I) -> Self {
        let mut out = Args::default();
        let mut it = args.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(stripped) = a.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    let v = it.next().unwrap();
                    out.flags.insert(stripped.to_string(), v);
                } else {
                    out.switches.insert(stripped.to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    pub fn from_env() -> Self {
        Self::parse(std::env::args().skip(1))
    }

    /// First positional (the subcommand).
    pub fn subcommand(&self) -> Option<&str> {
        self.positional.first().map(|s| s.as_str())
    }

    pub fn get(&mut self, key: &str) -> Option<String> {
        self.consumed.insert(key.to_string());
        self.flags.get(key).cloned()
    }

    pub fn get_or(&mut self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or_else(|| default.to_string())
    }

    pub fn get_f64(&mut self, key: &str, default: f64) -> anyhow::Result<f64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{key} expects a number, got {v:?}")),
        }
    }

    pub fn get_usize(&mut self, key: &str, default: usize) -> anyhow::Result<usize> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{key} expects an integer, got {v:?}")),
        }
    }

    pub fn has(&mut self, key: &str) -> bool {
        self.consumed.insert(key.to_string());
        self.switches.contains(key)
    }

    /// Consume the global `--threads N` knob (the kernel-engine + worker
    /// thread budget; overrides `SKGLM_THREADS`). Returns the override if
    /// present; errors on zero or non-integer values.
    pub fn take_threads(&mut self) -> anyhow::Result<Option<usize>> {
        if self.has("threads") {
            // parsed as a value-less switch: the count is missing
            anyhow::bail!("--threads needs a value (e.g. --threads 4)");
        }
        match self.get("threads") {
            None => Ok(None),
            Some(v) => {
                let n: usize = v
                    .parse()
                    .map_err(|_| anyhow::anyhow!("--threads expects a positive integer, got {v:?}"))?;
                if n == 0 {
                    anyhow::bail!("--threads must be >= 1");
                }
                Ok(Some(n))
            }
        }
    }

    /// Consume the global `--batch [on|off]` knob (many-fit batching;
    /// overrides `SKGLM_BATCH`, which defaults to on). A bare `--batch`
    /// switch means on. Returns the override if present.
    pub fn take_batch(&mut self) -> anyhow::Result<Option<bool>> {
        if self.has("batch") {
            return Ok(Some(true));
        }
        match self.get("batch") {
            None => Ok(None),
            Some(v) => match v.trim().to_ascii_lowercase().as_str() {
                "1" | "on" | "true" => Ok(Some(true)),
                "0" | "off" | "false" => Ok(Some(false)),
                other => anyhow::bail!("--batch expects on|off, got {other:?}"),
            },
        }
    }

    /// Consume the global `--isa NAME` knob (kernel ISA override;
    /// overrides `SKGLM_ISA`). Accepted names: `scalar`, `avx2`,
    /// `avx2fma`, `neon`, `neonfma`, `auto`. Returns the name if present.
    pub fn take_isa(&mut self) -> anyhow::Result<Option<String>> {
        if self.has("isa") {
            anyhow::bail!("--isa needs a value (e.g. --isa scalar)");
        }
        match self.get("isa") {
            None => Ok(None),
            Some(v) => {
                let name = v.trim().to_ascii_lowercase();
                if name == "auto" || crate::linalg::KernelIsa::parse(&name).is_some() {
                    Ok(Some(name))
                } else {
                    anyhow::bail!(
                        "--isa expects scalar|avx2|avx2fma|neon|neonfma|auto, got {v:?}"
                    )
                }
            }
        }
    }

    /// Consume the global `--precision MODE` knob (full-design pass
    /// precision; overrides `SKGLM_PRECISION`). Returns the parsed mode
    /// if present.
    pub fn take_precision(&mut self) -> anyhow::Result<Option<crate::linalg::Precision>> {
        if self.has("precision") {
            anyhow::bail!("--precision needs a value (e.g. --precision mixed)");
        }
        match self.get("precision") {
            None => Ok(None),
            Some(v) => match crate::linalg::Precision::parse(v.trim()) {
                Some(p) => Ok(Some(p)),
                None => anyhow::bail!("--precision expects f64|f32|mixed, got {v:?}"),
            },
        }
    }

    /// Error on unconsumed flags (call after all gets).
    pub fn finish(&self) -> anyhow::Result<()> {
        let unknown: Vec<&String> = self
            .flags
            .keys()
            .chain(self.switches.iter())
            .filter(|k| !self.consumed.contains(*k))
            .collect();
        if unknown.is_empty() {
            Ok(())
        } else {
            anyhow::bail!("unknown flags: {unknown:?}")
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|x| x.to_string()))
    }

    #[test]
    fn parses_positional_flags_switches() {
        let mut a = parse("exp fig2 --lambda 0.1 --verbose --tol=1e-8");
        assert_eq!(a.subcommand(), Some("exp"));
        assert_eq!(a.positional[1], "fig2");
        assert_eq!(a.get_f64("lambda", 0.0).unwrap(), 0.1);
        assert_eq!(a.get_f64("tol", 0.0).unwrap(), 1e-8);
        assert!(a.has("verbose"));
        assert!(a.finish().is_ok());
    }

    #[test]
    fn negative_number_flag_values() {
        let mut a = parse("solve --shift -3.5");
        // "-3.5" doesn't start with --, so it's the value
        assert_eq!(a.get_f64("shift", 0.0).unwrap(), -3.5);
    }

    #[test]
    fn unknown_flags_rejected() {
        let mut a = parse("solve --typo 1");
        let _ = a.get("lambda");
        assert!(a.finish().is_err());
    }

    #[test]
    fn bad_number_reports_error() {
        let mut a = parse("solve --lambda abc");
        assert!(a.get_f64("lambda", 0.0).is_err());
    }

    #[test]
    fn key_equals_value_keeps_later_equals_signs() {
        // regression: `--out=a=b.svm` must split on the FIRST '='
        let mut a = parse("synth --out=a=b.svm");
        assert_eq!(a.get("out").as_deref(), Some("a=b.svm"));
        assert!(a.finish().is_ok());
    }

    #[test]
    fn key_equals_value_mixes_with_space_form() {
        let mut a = parse("solve --tol=1e-6 --lambda 0.5 --seed=7");
        assert_eq!(a.get_f64("tol", 0.0).unwrap(), 1e-6);
        assert_eq!(a.get_f64("lambda", 0.0).unwrap(), 0.5);
        assert_eq!(a.get_usize("seed", 0).unwrap(), 7);
        assert!(a.finish().is_ok());
    }

    #[test]
    fn repeated_flag_last_wins() {
        let mut a = parse("solve --tol 1e-4 --tol=1e-8");
        assert_eq!(a.get_f64("tol", 0.0).unwrap(), 1e-8);
    }

    #[test]
    fn unknown_switch_rejected_even_with_known_flags_consumed() {
        // regression: switches (no value) must also be caught by finish()
        let mut a = parse("path --points 5 --vrebose");
        assert_eq!(a.get_usize("points", 0).unwrap(), 5);
        let err = a.finish().unwrap_err();
        assert!(format!("{err}").contains("vrebose"), "typo named in: {err}");
    }

    #[test]
    fn unknown_key_equals_value_rejected() {
        let mut a = parse("path --poinst=5");
        let _ = a.get_usize("points", 20);
        assert!(a.finish().is_err());
    }

    #[test]
    fn switch_before_flag_is_not_eaten_as_value() {
        let mut a = parse("solve --verbose --tol 1e-3");
        assert!(a.has("verbose"));
        assert_eq!(a.get_f64("tol", 0.0).unwrap(), 1e-3);
        assert!(a.finish().is_ok());
    }

    #[test]
    fn threads_flag_parses_and_validates() {
        let mut a = parse("solve --threads 4");
        assert_eq!(a.take_threads().unwrap(), Some(4));
        assert!(a.finish().is_ok());
        let mut b = parse("solve");
        assert_eq!(b.take_threads().unwrap(), None);
        let mut c = parse("solve --threads 0");
        assert!(c.take_threads().is_err());
        let mut d = parse("solve --threads lots");
        assert!(d.take_threads().is_err());
        // value forgotten: --threads parses as a switch and must error,
        // not silently fall back to full parallelism
        let mut e = parse("cv --threads --small");
        assert!(e.take_threads().is_err());
        let mut f = parse("solve --small --threads");
        assert!(f.take_threads().is_err());
    }

    #[test]
    fn batch_flag_parses_and_validates() {
        let mut a = parse("cv --batch off");
        assert_eq!(a.take_batch().unwrap(), Some(false));
        assert!(a.finish().is_ok());
        let mut b = parse("cv --batch on");
        assert_eq!(b.take_batch().unwrap(), Some(true));
        // bare switch means on
        let mut c = parse("cv --batch --small");
        assert_eq!(c.take_batch().unwrap(), Some(true));
        let mut d = parse("cv");
        assert_eq!(d.take_batch().unwrap(), None);
        let mut e = parse("cv --batch sideways");
        assert!(e.take_batch().is_err());
    }

    #[test]
    fn isa_flag_parses_and_validates() {
        let mut a = parse("solve --isa scalar");
        assert_eq!(a.take_isa().unwrap().as_deref(), Some("scalar"));
        assert!(a.finish().is_ok());
        let mut b = parse("solve --isa AVX2");
        assert_eq!(b.take_isa().unwrap().as_deref(), Some("avx2"));
        let mut c = parse("solve --isa auto");
        assert_eq!(c.take_isa().unwrap().as_deref(), Some("auto"));
        let mut d = parse("solve");
        assert_eq!(d.take_isa().unwrap(), None);
        let mut e = parse("solve --isa warp9");
        assert!(e.take_isa().is_err());
        // value forgotten: --isa parses as a switch and must error
        let mut f = parse("solve --isa --small");
        assert!(f.take_isa().is_err());
    }

    #[test]
    fn precision_flag_parses_and_validates() {
        use crate::linalg::Precision;
        let mut a = parse("solve --precision mixed");
        assert_eq!(a.take_precision().unwrap(), Some(Precision::Mixed));
        assert!(a.finish().is_ok());
        let mut b = parse("solve --precision f32");
        assert_eq!(b.take_precision().unwrap(), Some(Precision::F32));
        let mut c = parse("solve --precision f64");
        assert_eq!(c.take_precision().unwrap(), Some(Precision::F64));
        let mut d = parse("solve");
        assert_eq!(d.take_precision().unwrap(), None);
        let mut e = parse("solve --precision f16");
        assert!(e.take_precision().is_err());
        let mut f = parse("solve --precision --small");
        assert!(f.take_precision().is_err());
    }

    #[test]
    fn defaults_apply() {
        let mut a = parse("solve");
        assert_eq!(a.get_or("dataset", "rcv1"), "rcv1");
        assert_eq!(a.get_usize("seed", 42).unwrap(), 42);
        assert!(!a.has("verbose"));
    }
}
