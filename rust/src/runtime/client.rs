//! PJRT CPU client + artifact loading.
//!
//! Pattern from the `xla` crate's HLO-loading example: `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//! `client.compile` → `execute`. One compiled executable per artifact.

use anyhow::{Context, Result};
use std::path::{Path, PathBuf};

/// Directory holding `*.hlo.txt` artifacts (override with
/// `SKGLM_ARTIFACTS`).
pub fn artifacts_dir() -> PathBuf {
    std::env::var_os("SKGLM_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts"))
}

/// Path of a named artifact at a given (n, p) shape — the naming
/// convention `aot.py` writes: `<op>_n{n}_p{p}.hlo.txt`.
pub fn artifact_path(op: &str, n: usize, p: usize) -> PathBuf {
    artifacts_dir().join(format!("{op}_n{n}_p{p}.hlo.txt"))
}

/// A compiled executable with its declared shape.
pub struct Artifact {
    pub op: String,
    pub n: usize,
    pub p: usize,
    exe: xla::PjRtLoadedExecutable,
}

impl Artifact {
    /// Execute on f32 input buffers; returns the flat f32 outputs of the
    /// (1-tuple) result.
    pub fn run(&self, inputs: &[xla::Literal]) -> Result<Vec<f32>> {
        let result = self
            .exe
            .execute::<xla::Literal>(inputs)
            .context("PJRT execution failed")?[0][0]
            .to_literal_sync()?;
        // aot.py lowers with return_tuple=True → unwrap the 1-tuple
        let out = result.to_tuple1()?;
        Ok(out.to_vec::<f32>()?)
    }

    /// Execute an artifact whose result is an N-tuple (e.g. the fused
    /// score kernels return `(grad, score)`); returns one f32 vector per
    /// tuple element.
    pub fn run_tuple(&self, inputs: &[xla::Literal]) -> Result<Vec<Vec<f32>>> {
        let result = self
            .exe
            .execute::<xla::Literal>(inputs)
            .context("PJRT execution failed")?[0][0]
            .to_literal_sync()?;
        let parts = result.to_tuple()?;
        parts.into_iter().map(|l| Ok(l.to_vec::<f32>()?)).collect()
    }

    /// Execute on device-resident buffers (no host→device copy for inputs
    /// already uploaded — the scoring engine keeps the design on device).
    pub fn run_buffers(&self, inputs: &[&xla::PjRtBuffer]) -> Result<Vec<f32>> {
        let result = self
            .exe
            .execute_b(inputs)
            .context("PJRT execution failed")?[0][0]
            .to_literal_sync()?;
        let out = result.to_tuple1()?;
        Ok(out.to_vec::<f32>()?)
    }
}

/// Wraps the PJRT CPU client; compiles artifacts on demand.
pub struct PjrtRuntime {
    client: xla::PjRtClient,
}

impl PjrtRuntime {
    pub fn cpu() -> Result<Self> {
        Ok(Self { client: xla::PjRtClient::cpu().context("creating PJRT CPU client")? })
    }

    /// Cheap handle clone (the underlying client is reference-counted).
    pub fn clone_handle(&self) -> Self {
        Self { client: self.client.clone() }
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Upload f32 host data to the default device (used by the scoring
    /// engine to keep the design matrix resident across calls).
    pub fn upload_f32(&self, data: &[f32], dims: &[usize]) -> Result<xla::PjRtBuffer> {
        Ok(self.client.buffer_from_host_buffer::<f32>(data, dims, None)?)
    }

    /// Load + compile `<op>_n{n}_p{p}.hlo.txt`.
    pub fn load(&self, op: &str, n: usize, p: usize) -> Result<Artifact> {
        let path = artifact_path(op, n, p);
        self.load_path(&path, op, n, p)
    }

    /// Load + compile an explicit path.
    pub fn load_path(&self, path: &Path, op: &str, n: usize, p: usize) -> Result<Artifact> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 artifact path")?,
        )
        .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {}", path.display()))?;
        Ok(Artifact { op: op.to_string(), n, p, exe })
    }

    /// Does the artifact file exist (cheap pre-check before compiling)?
    pub fn available(op: &str, n: usize, p: usize) -> bool {
        artifact_path(op, n, p).exists()
    }
}

/// Build an f32 literal of the given shape from f64 data (row-major).
pub fn literal_from_f64(data: &[f64], shape: &[usize]) -> Result<xla::Literal> {
    let f32s: Vec<f32> = data.iter().map(|&v| v as f32).collect();
    let lit = xla::Literal::vec1(&f32s);
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    Ok(lit.reshape(&dims)?)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn artifact_path_convention() {
        std::env::remove_var("SKGLM_ARTIFACTS");
        assert_eq!(
            artifact_path("xt_r", 100, 200),
            PathBuf::from("artifacts/xt_r_n100_p200.hlo.txt")
        );
    }

    #[test]
    fn cpu_client_boots() {
        let rt = PjrtRuntime::cpu().expect("PJRT CPU client");
        assert!(!rt.platform().is_empty());
    }

    #[test]
    fn literal_round_trip() {
        let lit = literal_from_f64(&[1.0, 2.0, 3.0, 4.0], &[2, 2]).unwrap();
        assert_eq!(lit.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
    }
}
