//! API-compatible stand-in for the PJRT runtime, compiled when the `pjrt`
//! cargo feature is off (the default in offline builds — the real client
//! in `client.rs`/`engine.rs` links against the `xla` crate, which cannot
//! be fetched without a registry).
//!
//! Every entry point either reports the engine as unavailable
//! ([`PjrtRuntime::cpu`] errors, [`PjrtRuntime::available`] is `false`)
//! or declines the request ([`PjrtGradEngine::grad_full`] returns
//! `false`), so callers — `skglm solve --engine pjrt`, the micro-kernel
//! bench, the end-to-end example — take their native fallback branches
//! without any `cfg` churn at the call sites.

use crate::linalg::Design;
use crate::solver::GradEngine;
use anyhow::{bail, Result};
use std::path::PathBuf;

/// Directory holding `*.hlo.txt` artifacts (override with
/// `SKGLM_ARTIFACTS`). Kept in the stub so `skglm info` can report it.
pub fn artifacts_dir() -> PathBuf {
    std::env::var_os("SKGLM_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts"))
}

/// Path of a named artifact at a given (n, p) shape — the naming
/// convention `aot.py` writes: `<op>_n{n}_p{p}.hlo.txt`.
pub fn artifact_path(op: &str, n: usize, p: usize) -> PathBuf {
    artifacts_dir().join(format!("{op}_n{n}_p{p}.hlo.txt"))
}

/// Placeholder for a compiled executable; never constructible without the
/// `pjrt` feature.
pub struct Artifact {
    pub op: String,
    pub n: usize,
    pub p: usize,
}

/// Stub PJRT client handle.
pub struct PjrtRuntime {}

impl PjrtRuntime {
    /// Always fails: the binary was built without the `pjrt` feature.
    pub fn cpu() -> Result<Self> {
        bail!("built without the `pjrt` cargo feature (see README.md §PJRT)")
    }

    /// Mirrors the real handle-clone API.
    pub fn clone_handle(&self) -> Self {
        PjrtRuntime {}
    }

    pub fn platform(&self) -> String {
        "unavailable".to_string()
    }

    /// Artifacts can never be served without the engine.
    pub fn available(_op: &str, _n: usize, _p: usize) -> bool {
        false
    }
}

/// Stub scoring engine; [`GradEngine::grad_full`] always declines so the
/// solver recomputes natively.
pub struct PjrtGradEngine {
    /// number of gradient calls served (always 0 in the stub)
    pub calls: usize,
}

impl PjrtGradEngine {
    /// Tolerances tighter than this should not rely on f32 scoring
    /// (kept for API parity with the real engine).
    pub const MIN_TOL: f64 = 1e-6;

    /// Always fails: no runtime exists to build an engine from.
    pub fn for_design(_runtime: &PjrtRuntime, _design: &Design) -> Result<Self> {
        bail!("built without the `pjrt` cargo feature (see README.md §PJRT)")
    }
}

impl GradEngine for PjrtGradEngine {
    fn grad_full(
        &mut self,
        _design: &Design,
        _y: &[f64],
        _state: &[f64],
        _beta: &[f64],
        _out: &mut [f64],
    ) -> bool {
        false
    }

    fn name(&self) -> &'static str {
        "pjrt-stub"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_reports_unavailable() {
        assert!(PjrtRuntime::cpu().is_err());
        assert!(!PjrtRuntime::available("xt_r", 100, 200));
        let e = PjrtRuntime::cpu().unwrap_err();
        assert!(format!("{e}").contains("pjrt"));
    }

    #[test]
    fn artifact_path_convention() {
        std::env::remove_var("SKGLM_ARTIFACTS");
        assert_eq!(
            artifact_path("xt_r", 100, 200),
            PathBuf::from("artifacts/xt_r_n100_p200.hlo.txt")
        );
    }
}
