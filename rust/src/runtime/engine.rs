//! [`GradEngine`] backed by PJRT: serves the solver's full-gradient
//! scoring pass (`∇f = Xᵀr/n` for the quadratic datafit) from the AOT
//! artifact `xt_r_n{n}_p{p}.hlo.txt`, whose compute body is the L1 Pallas
//! kernel (lowered with interpret=True, CPU-sized blocks — see
//! `python/compile/model.py::SCHEDULES` and EXPERIMENTS.md §Perf).
//!
//! Zero-copy + device residency: our dense design is stored
//! **column-major** [n, p], which is exactly a row-major [p, n] buffer —
//! the artifact takes `Xᵀ` as a [p, n] input, converted to f32 once and
//! **uploaded to the device once** at engine construction (`execute_b`
//! then reuses the resident buffer; only the n-length residual crosses
//! the FFI boundary per call — measured 11.2 ms → ~2 ms on the 1000×2000
//! scoring pass, §Perf).
//!
//! Precision note: artifacts run in f32; gradients come back with ~1e-7
//! relative error. That is plenty for working-set *selection*, but a
//! stopping tolerance tighter than ~1e-6 would chase noise — the engine
//! therefore serves scoring only above [`PjrtGradEngine::MIN_TOL`] and the
//! solver always recomputes final KKT metrics natively in f64.

use super::client::{Artifact, PjrtRuntime};
use crate::linalg::Design;
use crate::solver::GradEngine;

pub struct PjrtGradEngine {
    artifact: Artifact,
    /// design converted to f32 [p, n] and uploaded once
    xt_buffer: xla::PjRtBuffer,
    /// runtime handle for per-call residual uploads
    runtime: PjrtRuntime,
    /// reused f32 staging buffer for the residual
    r_staging: Vec<f32>,
    n: usize,
    p: usize,
    /// number of gradient calls served (perf accounting)
    pub calls: usize,
}

impl PjrtGradEngine {
    /// Tolerances tighter than this should not rely on f32 scoring.
    pub const MIN_TOL: f64 = 1e-6;

    /// Build for a dense design; fails if no artifact matches the shape.
    pub fn for_design(runtime: &PjrtRuntime, design: &Design) -> anyhow::Result<Self> {
        let (n, p) = (design.nrows(), design.ncols());
        let dense = match design {
            Design::Dense(m) => m,
            Design::Sparse(_) => {
                anyhow::bail!("PJRT scoring engine supports dense designs only")
            }
        };
        let artifact = runtime.load("xt_r", n, p)?;
        // column-major [n,p] == row-major [p,n]; upload once
        let xt_f32: Vec<f32> = dense.raw().iter().map(|&v| v as f32).collect();
        let xt_buffer = runtime.upload_f32(&xt_f32, &[p, n])?;
        Ok(Self {
            artifact,
            xt_buffer,
            runtime: runtime.clone_handle(),
            r_staging: vec![0.0; n],
            n,
            p,
            calls: 0,
        })
    }
}

impl GradEngine for PjrtGradEngine {
    fn grad_full(
        &mut self,
        design: &Design,
        _y: &[f64],
        state: &[f64],
        _beta: &[f64],
        out: &mut [f64],
    ) -> bool {
        if design.nrows() != self.n || design.ncols() != self.p || out.len() != self.p {
            return false;
        }
        for (s, &v) in self.r_staging.iter_mut().zip(state.iter()) {
            *s = v as f32;
        }
        let r_buf = match self.runtime.upload_f32(&self.r_staging, &[self.n]) {
            Ok(b) => b,
            Err(_) => return false,
        };
        match self.artifact.run_buffers(&[&self.xt_buffer, &r_buf]) {
            Ok(g) => {
                debug_assert_eq!(g.len(), self.p);
                for (o, &v) in out.iter_mut().zip(g.iter()) {
                    *o = v as f64;
                }
                self.calls += 1;
                true
            }
            Err(_) => false,
        }
    }

    fn name(&self) -> &'static str {
        "pjrt"
    }
}
