//! PJRT runtime: loads the AOT artifacts produced by `python/compile/aot.py`
//! (HLO **text** — see DESIGN.md and /opt/xla-example/README.md for why
//! text, not serialized protos) and serves them to the solver as a
//! [`crate::solver::GradEngine`].
//!
//! Python runs once at build time (`make artifacts`); this module is the
//! only place the solve path touches XLA, and it is entirely optional —
//! every solver falls back to the native Rust path when no artifact
//! matches the problem shape.

pub mod client;
pub mod engine;

pub use client::{artifact_path, Artifact, PjrtRuntime};
pub use engine::PjrtGradEngine;
