//! PJRT runtime: loads the AOT artifacts produced by `python/compile/aot.py`
//! (HLO **text** — see ARCHITECTURE.md §PJRT for why text, not serialized
//! protos) and serves them to the solver as a
//! [`crate::solver::GradEngine`].
//!
//! Python runs once at build time (`make artifacts`); this module is the
//! only place the solve path touches XLA, and it is entirely optional —
//! every solver falls back to the native Rust path when no artifact
//! matches the problem shape.
//!
//! The real engine links against the `xla` crate, which cannot be fetched
//! in this offline environment, so it is gated behind the `pjrt` cargo
//! feature (see README.md §PJRT). Without the feature an API-compatible
//! stub is compiled instead: [`PjrtRuntime::cpu`] reports the engine as
//! unavailable and every caller takes its native fallback branch.

#[cfg(feature = "pjrt")]
pub mod client;
#[cfg(feature = "pjrt")]
pub mod engine;

#[cfg(not(feature = "pjrt"))]
pub mod stub;
#[cfg(not(feature = "pjrt"))]
pub use self::stub as client;
#[cfg(not(feature = "pjrt"))]
pub use self::stub as engine;

#[cfg(feature = "pjrt")]
pub use client::{artifact_path, Artifact, PjrtRuntime};
#[cfg(feature = "pjrt")]
pub use engine::PjrtGradEngine;

#[cfg(not(feature = "pjrt"))]
pub use stub::{artifact_path, Artifact, PjrtGradEngine, PjrtRuntime};
