//! # skglm-rs
//!
//! Rust + JAX + Pallas reproduction of **"Beyond L1: Faster and Better
//! Sparse Models with skglm"** (Bertrand et al., NeurIPS 2022): a generic,
//! Anderson-accelerated working-set coordinate-descent solver for sparse
//! generalized linear models with convex *and* non-convex separable
//! penalties.
//!
//! Architecture (see ARCHITECTURE.md):
//! - **L3 (this crate)** — the full solver framework: datafits, penalties,
//!   Algorithms 1–4, baselines, datasets, the benchopt-like harness, the
//!   PJRT runtime and the CLI. Python never runs on the solve path.
//! - **L2/L1 (python/compile)** — the dense scoring hot spot (`Xᵀr`) as a
//!   JAX function wrapping a Pallas kernel, AOT-lowered to HLO text and
//!   executed from Rust through the `xla` crate (PJRT CPU).
//!
//! ## Quickstart
//!
//! ```no_run
//! use skglm::prelude::*;
//!
//! let ds = skglm::data::correlated(CorrelatedSpec::figure1(0.1), 42);
//! let lam = Lasso::lambda_max(&ds.design, &ds.y) / 10.0;
//! let fit = Lasso::new(lam).fit(&ds.design, &ds.y);
//! println!("support size: {}", fit.support().len());
//! ```

pub mod analysis;
pub mod bench;
pub mod cli;
pub mod coordinator;
pub mod data;
pub mod datafit;
pub mod estimators;
pub mod linalg;
pub mod metrics;
pub mod penalty;
pub mod runtime;
pub mod solver;
pub mod util;

/// Convenient glob-import surface.
pub mod prelude {
    pub use crate::data::{CorrelatedSpec, Dataset, GroupedSpec, SparseSpec};
    pub use crate::datafit::{
        Datafit, GroupedQuadratic, Logistic, Poisson, Probit, Quadratic, QuadraticSvc,
    };
    pub use crate::estimators::{ElasticNet, Lasso, LinearSvc, McpRegressor, ScadRegressor};
    pub use crate::linalg::{CscMatrix, DenseMatrix, Design};
    pub use crate::penalty::{
        BlockL21, BlockMcp, BlockPenalty, BlockScad, BoxIndicator, GroupLasso, GroupMcp,
        GroupScad, WeightedGroupLasso, L1L2, Lq, Mcp, Penalty, Scad, WeightedL1, L1,
    };
    pub use crate::solver::{solve, solve_blocks, BlockPartition, FitResult, SolverOpts};
}
