//! K-fold cross-validation for λ selection — the model-selection layer a
//! practitioner uses on top of the solver (LassoCV-style), built on the
//! coordinator's thread pool so folds × λ run concurrently.

use crate::coordinator::run_parallel;
use crate::data::Dataset;
use crate::linalg::{CscMatrix, DenseMatrix, Design};
use crate::solver::SolverOpts;
use crate::util::rng::Rng;

/// CV outcome: per-λ mean validation MSE and the winner.
#[derive(Clone, Debug)]
pub struct CvResult {
    pub lambda_ratios: Vec<f64>,
    /// mean validation MSE per λ-ratio (folds averaged)
    pub cv_mse: Vec<f64>,
    pub best_index: usize,
    pub best_lambda: f64,
    /// full-data λ_max (anchors `best_lambda` and the refit)
    pub lambda_max: f64,
    /// per-fold λ_max computed on that fold's TRAINING rows only — the
    /// anchor each fold's grid actually used (leakage guard; exposed so
    /// reports/tests can see the training-only anchoring)
    pub fold_lambda_max: Vec<f64>,
    /// coefficients refit on the full data at the winning λ
    pub beta: Vec<f64>,
}

/// Row-subset of a design (fold extraction).
fn take_rows(design: &Design, rows: &[usize]) -> Design {
    match design {
        Design::Dense(m) => {
            let mut out = DenseMatrix::zeros(rows.len(), m.ncols());
            for (ri, &i) in rows.iter().enumerate() {
                for j in 0..m.ncols() {
                    out.set(ri, j, m.get(i, j));
                }
            }
            out.into()
        }
        Design::Sparse(s) => {
            // invert the row map once, then filter triplets
            let mut map = vec![usize::MAX; s.nrows()];
            for (ri, &i) in rows.iter().enumerate() {
                map[i] = ri;
            }
            let mut trips = Vec::new();
            for j in 0..s.ncols() {
                let (ridx, vals) = s.col(j);
                for (&i, &v) in ridx.iter().zip(vals.iter()) {
                    let m = map[i as usize];
                    if m != usize::MAX {
                        trips.push((m, j, v));
                    }
                }
            }
            CscMatrix::from_triplets(rows.len(), s.ncols(), &trips).into()
        }
    }
}

/// Shuffled k-fold assignment shared by every CV entry point (the
/// batched and sequential paths must hold out identical rows).
fn fold_assignment(n: usize, k_folds: usize, seed: u64) -> Vec<Vec<usize>> {
    let mut order: Vec<usize> = (0..n).collect();
    Rng::seed_from_u64(seed).shuffle(&mut order);
    (0..k_folds).map(|k| order.iter().skip(k).step_by(k_folds).cloned().collect()).collect()
}

/// 0/1 **training** masks for each fold: 1.0 on training rows, 0.0 on the
/// fold's held-out validation rows. A masked batch member on the full
/// design follows the fold-restricted loss exactly (masked rows stay
/// identically zero in the residual panel).
fn fold_masks(n: usize, folds: &[Vec<usize>]) -> Vec<std::sync::Arc<Vec<f64>>> {
    folds
        .iter()
        .map(|val_rows| {
            let mut w = vec![1.0; n];
            for &i in val_rows {
                w[i] = 0.0;
            }
            std::sync::Arc::new(w)
        })
        .collect()
}

/// K-fold CV over a geometric λ grid for the Lasso. `threads` bounds the
/// worker pool (folds run concurrently; λ is warm-started within a fold).
///
/// The grid lives in **ratio space**: each fold anchors
/// `λ = ratio · λ_max(train fold)` at its *own training rows'* λ_max —
/// anchoring at the full-data λ_max would leak the fold's validation rows
/// into its model-selection grid and bias the chosen λ. The winning ratio
/// is then rescaled by the full-data λ_max for the final refit.
///
/// When many-fit batching is on ([`crate::solver::batching_enabled`],
/// `SKGLM_BATCH`/`--batch`) the k folds run as **one batched job**: every
/// λ point is a single [`crate::solver::solve_batch`] call over all k
/// fold members (0/1 row masks on the shared full design, per-member warm
/// continuation along the grid), and the per-fold anchors come from one
/// multi-RHS panel pass — the same training-rows-only leakage guard,
/// computed without materialising k row-subset designs.
pub fn lasso_cv(
    dataset: &Dataset,
    lambda_ratios: &[f64],
    k_folds: usize,
    opts: &SolverOpts,
    seed: u64,
    threads: usize,
) -> CvResult {
    assert!(k_folds >= 2);
    assert!(dataset.n() >= 2 * k_folds, "need at least 2 samples per fold");
    if crate::solver::batching_enabled() {
        lasso_cv_batched(dataset, lambda_ratios, k_folds, opts, seed)
    } else {
        lasso_cv_sequential(dataset, lambda_ratios, k_folds, opts, seed, threads)
    }
}

/// The batched CV engine behind [`lasso_cv`]: folds × λ as one fused
/// many-fit job (λ-outer, folds-inner).
fn lasso_cv_batched(
    dataset: &Dataset,
    lambda_ratios: &[f64],
    k_folds: usize,
    opts: &SolverOpts,
    seed: u64,
) -> CvResult {
    use crate::penalty::{BatchPenalty, L1};
    use crate::solver::{batch_lambda_max, solve_batch, BatchFit};
    use std::sync::Arc;

    let n = dataset.n();
    let lam_max = super::linear::quadratic_lambda_max(&dataset.design, &dataset.y);
    let folds = fold_assignment(n, k_folds, seed);
    let masks = fold_masks(n, &folds);

    // leakage guard: per-fold anchors from the masked targets — one
    // multi-RHS panel pass instead of k row-subset λ_max passes. Masked
    // rows contribute exact zeros, so each anchor equals the λ_max of the
    // fold's training rows.
    let mask_opts: Vec<Option<Arc<Vec<f64>>>> =
        masks.iter().map(|w| Some(Arc::clone(w))).collect();
    let fold_lambda_max = batch_lambda_max(&dataset.design, &dataset.y, &mask_opts);

    let mut warm: Vec<Option<(Vec<f64>, Option<usize>)>> = vec![None; k_folds];
    let mut cv_mse = vec![0.0; lambda_ratios.len()];
    let mut pred = vec![0.0; n];
    for (li, &ratio) in lambda_ratios.iter().enumerate() {
        let mut fits = Vec::with_capacity(k_folds);
        for f in 0..k_folds {
            let pen = BatchPenalty::L1(L1::new(fold_lambda_max[f] * ratio));
            let mut fit = BatchFit::new(pen).with_row_weights(Arc::clone(&masks[f]));
            if let Some((beta, ws)) = &warm[f] {
                fit = fit.warm(beta.clone(), *ws);
            }
            fits.push(fit);
        }
        let out = solve_batch(&dataset.design, &dataset.y, fits, opts, None, None);
        for (f, m) in out.members.into_iter().enumerate() {
            let beta = m.result.beta;
            // validation MSE on the held-out rows: one full-design
            // matvec restricted to the fold's validation rows (row i of
            // X·β is the same arithmetic as on a row-subset design)
            dataset.design.matvec(&beta, &mut pred);
            let val = &folds[f];
            let mse = val
                .iter()
                .map(|&i| (pred[i] - dataset.y[i]) * (pred[i] - dataset.y[i]))
                .sum::<f64>()
                / val.len() as f64;
            cv_mse[li] += mse / k_folds as f64;
            let ws = m.result.history.last().map(|h| h.ws_size);
            warm[f] = Some((beta, ws));
        }
    }

    let best_index = cv_mse
        .iter()
        .enumerate()
        .min_by(|a, b| crate::util::order::nan_last(*a.1, *b.1))
        .map(|(i, _)| i)
        .unwrap_or(0);
    let best_lambda = lam_max * lambda_ratios[best_index];
    let beta = super::linear::Lasso::new(best_lambda)
        .with_solver(opts.clone())
        .fit(&dataset.design, &dataset.y)
        .beta;
    CvResult {
        lambda_ratios: lambda_ratios.to_vec(),
        cv_mse,
        best_index,
        best_lambda,
        lambda_max: lam_max,
        fold_lambda_max,
        beta,
    }
}

/// The sequential CV engine behind [`lasso_cv`]: one row-subset path
/// sweep per fold on the coordinator's thread pool.
fn lasso_cv_sequential(
    dataset: &Dataset,
    lambda_ratios: &[f64],
    k_folds: usize,
    opts: &SolverOpts,
    seed: u64,
    threads: usize,
) -> CvResult {
    let n = dataset.n();
    let lam_max = super::linear::quadratic_lambda_max(&dataset.design, &dataset.y);
    let folds = fold_assignment(n, k_folds, seed);

    // one job per fold: warm-started path over the grid, validation MSE
    let jobs: Vec<_> = folds
        .iter()
        .map(|val_rows| {
            let val_rows = val_rows.clone();
            let ratios = lambda_ratios.to_vec();
            let opts = opts.clone();
            move || -> (f64, Vec<f64>) {
                let mut in_val = vec![false; n];
                for &i in &val_rows {
                    in_val[i] = true;
                }
                let train_rows: Vec<usize> = (0..n).filter(|&i| !in_val[i]).collect();
                let x_train = take_rows(&dataset.design, &train_rows);
                let y_train: Vec<f64> = train_rows.iter().map(|&i| dataset.y[i]).collect();
                let x_val = take_rows(&dataset.design, &val_rows);
                let y_val: Vec<f64> = val_rows.iter().map(|&i| dataset.y[i]).collect();

                // leakage guard: the fold's grid is anchored at the λ_max
                // of its TRAINING rows, never the full data's
                let fold_lam_max =
                    super::linear::quadratic_lambda_max(&x_train, &y_train);
                let mut warm: Option<Vec<f64>> = None;
                let mut mses = Vec::with_capacity(ratios.len());
                for &ratio in &ratios {
                    let mut est = super::linear::Lasso::new(fold_lam_max * ratio)
                        .with_solver(opts.clone());
                    if let Some(w) = &warm {
                        est = est.warm_start(w.clone());
                    }
                    let fit = est.fit(&x_train, &y_train);
                    warm = Some(fit.beta.clone());
                    let mut pred = vec![0.0; y_val.len()];
                    x_val.matvec(&fit.beta, &mut pred);
                    let mse = pred
                        .iter()
                        .zip(y_val.iter())
                        .map(|(a, b)| (a - b) * (a - b))
                        .sum::<f64>()
                        / y_val.len() as f64;
                    mses.push(mse);
                }
                (fold_lam_max, mses)
            }
        })
        .collect();

    let per_fold = run_parallel(jobs, threads);
    let fold_lambda_max: Vec<f64> = per_fold.iter().map(|(lm, _)| *lm).collect();
    let mut cv_mse = vec![0.0; lambda_ratios.len()];
    for (_, fold) in &per_fold {
        for (acc, &m) in cv_mse.iter_mut().zip(fold.iter()) {
            *acc += m / k_folds as f64;
        }
    }
    // NaN-last selection: a divergent fold must not panic the report
    let best_index = cv_mse
        .iter()
        .enumerate()
        .min_by(|a, b| crate::util::order::nan_last(*a.1, *b.1))
        .map(|(i, _)| i)
        .unwrap_or(0);
    let best_lambda = lam_max * lambda_ratios[best_index];
    let beta = super::linear::Lasso::new(best_lambda)
        .with_solver(opts.clone())
        .fit(&dataset.design, &dataset.y)
        .beta;
    CvResult {
        lambda_ratios: lambda_ratios.to_vec(),
        cv_mse,
        best_index,
        best_lambda,
        lambda_max: lam_max,
        fold_lambda_max,
        beta,
    }
}

/// Per-fold **group** λ_max anchors from one multi-RHS panel pass:
/// column f of the n×k panel holds `(w_f ⊙ y) / n_eff_f`, so column f of
/// `XᵀR` is the fold's gradient at 0 and the anchor is the largest block
/// ℓ2-norm (`max_b ‖X_bᵀ(w_f ⊙ y)‖₂ / n_eff_f`, unit block weights —
/// matching [`crate::solver::block_lambda_max_for`] on the fold's
/// training rows, since masked rows contribute exact zeros).
fn group_cv_fold_anchors(
    design: &Design,
    y: &[f64],
    part: &crate::solver::BlockPartition,
    masks: &[std::sync::Arc<Vec<f64>>],
) -> Vec<f64> {
    let n = design.nrows();
    let p = design.ncols();
    let k = masks.len();
    let mut panel = vec![0.0; n * k];
    for (f, w) in masks.iter().enumerate() {
        let n_eff: f64 = w.iter().sum();
        let col = &mut panel[f * n..(f + 1) * n];
        for i in 0..n {
            col[i] = w[i] * y[i] / n_eff;
        }
    }
    let mut grads = vec![0.0; p * k];
    design.matmul_t(&panel, k, &mut grads);
    (0..k)
        .map(|f| {
            let g = &grads[f * p..(f + 1) * p];
            let mut best = 0.0f64;
            for b in 0..part.n_blocks() {
                let sq: f64 = part.coords(b).iter().map(|&j| g[j] * g[j]).sum();
                best = best.max(sq.sqrt());
            }
            best
        })
        .collect()
}

/// K-fold CV for the **group Lasso** over a geometric λ grid — the same
/// leakage-guarded protocol as [`lasso_cv`] (per-fold training-rows-only
/// λ_max anchors, warm-started within-fold sweeps, NaN-last winner
/// selection), with solves running on the block-coordinate engine.
///
/// Block penalties are outside the batched engine's scalar penalty
/// universe, so the fold sweeps stay on block CD; with batching enabled
/// the per-fold anchors still come from one shared multi-RHS panel pass
/// ([`group_cv_fold_anchors`]) instead of k row-subset gradient passes.
pub fn group_lasso_cv(
    dataset: &Dataset,
    part: &std::sync::Arc<crate::solver::BlockPartition>,
    lambda_ratios: &[f64],
    k_folds: usize,
    opts: &SolverOpts,
    seed: u64,
    threads: usize,
) -> CvResult {
    use crate::penalty::GroupLasso;
    use crate::solver::{solve_blocks_continued, ContinuationState};
    assert!(k_folds >= 2);
    let n = dataset.n();
    assert!(n >= 2 * k_folds, "need at least 2 samples per fold");
    let lam_max = super::group::group_lambda_max(&dataset.design, &dataset.y, part, None);

    let folds = fold_assignment(n, k_folds, seed);

    // batched anchor pass: one XᵀR panel over all folds' masked targets
    let panel_anchors: Option<Vec<f64>> = if crate::solver::batching_enabled() {
        let masks = fold_masks(n, &folds);
        Some(group_cv_fold_anchors(&dataset.design, &dataset.y, part, &masks))
    } else {
        None
    };

    let jobs: Vec<_> = folds
        .iter()
        .enumerate()
        .map(|(f, val_rows)| {
            let val_rows = val_rows.clone();
            let ratios = lambda_ratios.to_vec();
            let opts = opts.clone();
            let part = std::sync::Arc::clone(part);
            let anchor = panel_anchors.as_ref().map(|a| a[f]);
            move || -> (f64, Vec<f64>) {
                let mut in_val = vec![false; n];
                for &i in &val_rows {
                    in_val[i] = true;
                }
                let train_rows: Vec<usize> = (0..n).filter(|&i| !in_val[i]).collect();
                let x_train = take_rows(&dataset.design, &train_rows);
                let y_train: Vec<f64> = train_rows.iter().map(|&i| dataset.y[i]).collect();
                let x_val = take_rows(&dataset.design, &val_rows);
                let y_val: Vec<f64> = val_rows.iter().map(|&i| dataset.y[i]).collect();

                let fold_lam_max = anchor.unwrap_or_else(|| {
                    super::group::group_lambda_max(&x_train, &y_train, &part, None)
                });
                // warm-started within-fold sweep through the block engine
                let mut state = ContinuationState::default();
                let mut datafit =
                    crate::datafit::GroupedQuadratic::new(std::sync::Arc::clone(&part));
                let mut mses = Vec::with_capacity(ratios.len());
                for &ratio in &ratios {
                    let pen = GroupLasso::new(fold_lam_max * ratio);
                    let fit = solve_blocks_continued(
                        &x_train, &y_train, &part, &mut datafit, &pen, &opts, &mut state,
                        None, None,
                    );
                    let mut pred = vec![0.0; y_val.len()];
                    x_val.matvec(&fit.v, &mut pred);
                    let mse = pred
                        .iter()
                        .zip(y_val.iter())
                        .map(|(a, b)| (a - b) * (a - b))
                        .sum::<f64>()
                        / y_val.len() as f64;
                    mses.push(mse);
                }
                (fold_lam_max, mses)
            }
        })
        .collect();

    let per_fold = run_parallel(jobs, threads);
    let fold_lambda_max: Vec<f64> = per_fold.iter().map(|(lm, _)| *lm).collect();
    let mut cv_mse = vec![0.0; lambda_ratios.len()];
    for (_, fold) in &per_fold {
        for (acc, &m) in cv_mse.iter_mut().zip(fold.iter()) {
            *acc += m / k_folds as f64;
        }
    }
    let best_index = cv_mse
        .iter()
        .enumerate()
        .min_by(|a, b| crate::util::order::nan_last(*a.1, *b.1))
        .map(|(i, _)| i)
        .unwrap_or(0);
    let best_lambda = lam_max * lambda_ratios[best_index];
    // refit with the SAME solver configuration the folds used — not just
    // the tolerance — so the reported coefficients come from the solver
    // that actually selected λ
    let beta = super::group::group_lasso(best_lambda, std::sync::Arc::clone(part))
        .with_opts(opts.clone())
        .fit(&dataset.design, &dataset.y)
        .result
        .v;
    CvResult {
        lambda_ratios: lambda_ratios.to_vec(),
        cv_mse,
        best_index,
        best_lambda,
        lambda_max: lam_max,
        fold_lambda_max,
        beta,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{correlated, paper_dataset_small, CorrelatedSpec};
    use crate::estimators::path::geometric_grid;

    #[test]
    fn cv_picks_an_interior_lambda_and_recovers_signal() {
        let ds = correlated(CorrelatedSpec { n: 120, p: 60, rho: 0.3, nnz: 6, snr: 10.0 }, 5);
        let ratios = geometric_grid(1e-3, 10);
        let cv = lasso_cv(&ds, &ratios, 4, &SolverOpts::default().with_tol(1e-8), 0, 2);
        assert_eq!(cv.cv_mse.len(), 10);
        // the best lambda should not be the most extreme grid point at
        // lambda_max (that predicts with beta=0)
        assert!(cv.best_index > 0, "cv chose the null model");
        // refit beta recovers true support reasonably
        let rec = crate::metrics::support_recovery(&cv.beta, &ds.beta_true, 1e-8);
        assert_eq!(rec.false_negatives, 0, "cv-selected model misses true features");
        // cv error at best < cv error at lambda_max (null model)
        assert!(cv.cv_mse[cv.best_index] < cv.cv_mse[0]);
    }

    #[test]
    fn cv_works_on_sparse_designs() {
        let ds = paper_dataset_small("rcv1", 7).unwrap();
        let ratios = geometric_grid(1e-2, 5);
        let cv = lasso_cv(&ds, &ratios, 3, &SolverOpts::default().with_tol(1e-6), 1, 2);
        assert!(cv.cv_mse.iter().all(|m| m.is_finite()));
        assert!(cv.best_lambda > 0.0);
    }

    #[test]
    fn per_fold_lambda_max_differs_from_full_data_on_a_skewed_split() {
        // plant one huge-leverage row: whichever fold holds it out for
        // validation must see a training λ_max well below the full-data
        // λ_max — under the old (leaky) grid that fold's λs were anchored
        // too high
        let mut ds = correlated(CorrelatedSpec { n: 40, p: 10, rho: 0.2, nnz: 3, snr: 8.0 }, 3);
        ds.y[0] *= 50.0;
        let ratios = geometric_grid(1e-2, 6);
        let cv = lasso_cv(&ds, &ratios, 4, &SolverOpts::default().with_tol(1e-8), 0, 1);
        assert_eq!(cv.fold_lambda_max.len(), 4);
        assert!(
            cv.fold_lambda_max.iter().any(|&lm| (lm - cv.lambda_max).abs() > 1e-8 * cv.lambda_max),
            "per-fold λ_max {:?} all equal full-data λ_max {} — grid still leaks validation rows",
            cv.fold_lambda_max,
            cv.lambda_max
        );
        // the fold holding the leverage row out for validation anchors
        // far below the folds training on it
        let lo = cv.fold_lambda_max.iter().cloned().fold(f64::INFINITY, f64::min);
        let hi = cv.fold_lambda_max.iter().cloned().fold(0.0f64, f64::max);
        assert!(hi / lo > 1.2, "skewed split should spread fold anchors: {:?}", cv.fold_lambda_max);
    }

    #[test]
    fn nan_fold_mse_does_not_panic_best_index() {
        // regression for the partial_cmp().unwrap() panic: feed the
        // selector a NaN-contaminated mse vector directly
        let mse = [f64::NAN, 0.5, 0.2, f64::NAN];
        let best = mse
            .iter()
            .enumerate()
            .min_by(|a, b| crate::util::order::nan_last(*a.1, *b.1))
            .map(|(i, _)| i)
            .unwrap();
        assert_eq!(best, 2);
    }

    #[test]
    fn group_cv_picks_an_interior_lambda_and_recovers_groups() {
        let (ds, part) = crate::data::grouped_correlated(
            crate::data::GroupedSpec {
                n: 120,
                p: 48,
                group_size: 4,
                active_groups: 3,
                rho: 0.3,
                snr: 10.0,
            },
            5,
        );
        let ratios = geometric_grid(1e-3, 8);
        let cv = group_lasso_cv(
            &ds,
            &part,
            &ratios,
            4,
            &SolverOpts::default().with_tol(1e-8),
            0,
            2,
        );
        assert_eq!(cv.cv_mse.len(), 8);
        assert!(cv.best_index > 0, "cv chose the null model");
        assert!(cv.cv_mse[cv.best_index] < cv.cv_mse[0]);
        // refit recovers the planted groups
        let rec = crate::metrics::support_recovery(&cv.beta, &ds.beta_true, 1e-8);
        assert_eq!(rec.false_negatives, 0, "cv-selected model misses true features");
        // per-fold anchors are training-only (leakage guard inherited)
        assert_eq!(cv.fold_lambda_max.len(), 4);
    }

    #[test]
    fn batched_and_sequential_cv_agree() {
        let ds = correlated(CorrelatedSpec { n: 90, p: 40, rho: 0.3, nnz: 5, snr: 10.0 }, 11);
        let ratios = geometric_grid(1e-2, 8);
        let opts = SolverOpts::default().with_tol(1e-10);
        let b = lasso_cv_batched(&ds, &ratios, 3, &opts, 0);
        let s = lasso_cv_sequential(&ds, &ratios, 3, &opts, 0, 2);
        assert_eq!(b.best_index, s.best_index, "batched CV must pick the same λ");
        // per-fold anchors: masked panel pass vs row-subset λ_max
        for (ba, sa) in b.fold_lambda_max.iter().zip(&s.fold_lambda_max) {
            assert!((ba - sa).abs() <= 1e-10 * sa.abs(), "fold anchor drifted: {ba} vs {sa}");
        }
        // fold optima agree to solver tolerance, so the CV curves do too
        for (bm, sm) in b.cv_mse.iter().zip(&s.cv_mse) {
            assert!((bm - sm).abs() <= 2e-6 * (1.0 + sm.abs()), "cv mse drifted: {bm} vs {sm}");
        }
        assert!((b.best_lambda - s.best_lambda).abs() <= 1e-12 * s.best_lambda);
    }

    #[test]
    fn batched_cv_works_on_sparse_designs() {
        let ds = paper_dataset_small("rcv1", 7).unwrap();
        let ratios = geometric_grid(1e-2, 5);
        let cv = lasso_cv_batched(&ds, &ratios, 3, &SolverOpts::default().with_tol(1e-6), 1);
        assert!(cv.cv_mse.iter().all(|m| m.is_finite()));
        assert!(cv.best_lambda > 0.0);
    }

    #[test]
    fn group_panel_anchors_match_subset_anchors() {
        let (ds, part) = crate::data::grouped_correlated(
            crate::data::GroupedSpec {
                n: 80,
                p: 24,
                group_size: 4,
                active_groups: 2,
                rho: 0.3,
                snr: 8.0,
            },
            7,
        );
        let folds = fold_assignment(ds.n(), 4, 3);
        let masks = fold_masks(ds.n(), &folds);
        let anchors = group_cv_fold_anchors(&ds.design, &ds.y, &part, &masks);
        for (f, val_rows) in folds.iter().enumerate() {
            let mut in_val = vec![false; ds.n()];
            for &i in val_rows {
                in_val[i] = true;
            }
            let train_rows: Vec<usize> = (0..ds.n()).filter(|&i| !in_val[i]).collect();
            let x_train = take_rows(&ds.design, &train_rows);
            let y_train: Vec<f64> = train_rows.iter().map(|&i| ds.y[i]).collect();
            let subset = crate::estimators::group::group_lambda_max(&x_train, &y_train, &part, None);
            assert!(
                (anchors[f] - subset).abs() <= 1e-10 * subset,
                "panel anchor {} drifted from subset anchor {} on fold {f}",
                anchors[f],
                subset
            );
        }
    }

    #[test]
    fn fold_extraction_preserves_rows() {
        let ds = correlated(CorrelatedSpec { n: 20, p: 4, rho: 0.2, nnz: 2, snr: 5.0 }, 9);
        let rows = [3usize, 7, 11];
        let sub = take_rows(&ds.design, &rows);
        assert_eq!(sub.nrows(), 3);
        let mut full = vec![0.0; 20];
        let mut part = vec![0.0; 3];
        let beta = vec![1.0, -0.5, 0.25, 2.0];
        ds.design.matvec(&beta, &mut full);
        sub.matvec(&beta, &mut part);
        for (k, &i) in rows.iter().enumerate() {
            assert!((full[i] - part[k]).abs() < 1e-14);
        }
    }
}
