//! Multitask estimators for the M/EEG inverse problem (Figure 4):
//! multitask Lasso (ℓ2,1) and block-MCP / block-SCAD regressors.

use crate::linalg::{DenseMatrix, Design};
use crate::penalty::{BlockL21, BlockMcp, BlockScad};
use crate::solver::{solve_multitask, MultiTaskFit, SolverOpts};

/// `λ_max` for block penalties: `max_j ‖X_jᵀY‖₂ / n`.
pub fn block_lambda_max(design: &Design, y: &[f64], n_tasks: usize) -> f64 {
    let n = design.nrows();
    assert_eq!(y.len() % n, 0);
    let mut best = 0.0f64;
    for j in 0..design.ncols() {
        let mut s = 0.0;
        for t in 0..n_tasks {
            let d = design.col_dot(j, &y[t * n..(t + 1) * n]);
            s += d * d;
        }
        best = best.max(s.sqrt() / n as f64);
    }
    best
}

/// Flatten a sensors×tasks measurement matrix to the task-major target
/// vector the multitask solver consumes.
pub fn flatten_tasks(m: &DenseMatrix) -> Vec<f64> {
    let (n, t) = (m.nrows(), m.ncols());
    let mut y = vec![0.0; n * t];
    for tt in 0..t {
        for i in 0..n {
            y[tt * n + i] = m.get(i, tt);
        }
    }
    y
}

/// Reshape a row-major multitask coefficient vector into a p×T matrix.
pub fn unflatten_coef(w: &[f64], n_tasks: usize) -> DenseMatrix {
    let p = w.len() / n_tasks;
    let mut m = DenseMatrix::zeros(p, n_tasks);
    for j in 0..p {
        for t in 0..n_tasks {
            m.set(j, t, w[j * n_tasks + t]);
        }
    }
    m
}

/// Pick the fit with the smallest objective, ordering NaNs (divergent
/// non-convex fits) last — the multitask analogue of `PathResult`'s
/// NaN-safe best-point selectors. Returns `None` only when every
/// objective is NaN.
pub fn best_fit(fits: &[MultiTaskFit]) -> Option<&MultiTaskFit> {
    fits.iter()
        .filter(|f| !f.objective.is_nan())
        .min_by(|a, b| crate::util::order::nan_last(a.objective, b.objective))
}

/// Multitask Lasso: `min ‖Y−XW‖²_F/2n + λ Σ_j ‖W_{j,:}‖₂`.
#[derive(Clone, Debug)]
pub struct MultiTaskLasso {
    pub lambda: f64,
    pub opts: SolverOpts,
}

impl MultiTaskLasso {
    pub fn new(lambda: f64) -> Self {
        Self { lambda, opts: SolverOpts::default() }
    }

    pub fn with_tol(mut self, tol: f64) -> Self {
        self.opts.tol = tol;
        self
    }

    pub fn fit(&self, design: &Design, y: &[f64], n_tasks: usize) -> MultiTaskFit {
        solve_multitask(design, y, n_tasks, &BlockL21::new(self.lambda), &self.opts)
    }
}

/// Block-MCP multitask regressor.
#[derive(Clone, Debug)]
pub struct BlockMcpRegressor {
    pub lambda: f64,
    pub gamma: f64,
    pub opts: SolverOpts,
}

impl BlockMcpRegressor {
    pub fn new(lambda: f64, gamma: f64) -> Self {
        Self { lambda, gamma, opts: SolverOpts::default() }
    }

    pub fn with_tol(mut self, tol: f64) -> Self {
        self.opts.tol = tol;
        self
    }

    pub fn fit(&self, design: &Design, y: &[f64], n_tasks: usize) -> MultiTaskFit {
        solve_multitask(design, y, n_tasks, &BlockMcp::new(self.lambda, self.gamma), &self.opts)
    }
}

/// Block-SCAD multitask regressor.
#[derive(Clone, Debug)]
pub struct BlockScadRegressor {
    pub lambda: f64,
    pub gamma: f64,
    pub opts: SolverOpts,
}

impl BlockScadRegressor {
    pub fn new(lambda: f64, gamma: f64) -> Self {
        Self { lambda, gamma, opts: SolverOpts::default() }
    }

    pub fn fit(&self, design: &Design, y: &[f64], n_tasks: usize) -> MultiTaskFit {
        solve_multitask(design, y, n_tasks, &BlockScad::new(self.lambda, self.gamma), &self.opts)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::meeg::{localize, simulate, MeegSpec};

    #[test]
    fn lambda_max_gives_all_zero_rows() {
        let pb = simulate(MeegSpec { n_sensors: 30, n_sources: 60, n_times: 5, ..Default::default() }, 0);
        let design = Design::Dense(pb.gain.clone());
        let y = flatten_tasks(&pb.measurements);
        let lam = block_lambda_max(&design, &y, 5);
        let fit = MultiTaskLasso::new(lam * 1.001).fit(&design, &y, 5);
        assert!(fit.row_support().is_empty());
        // just below lambda_max: at least one active row
        let fit2 = MultiTaskLasso::new(lam * 0.9).fit(&design, &y, 5);
        assert!(!fit2.row_support().is_empty());
    }

    #[test]
    fn best_fit_orders_nan_objectives_last() {
        let mk = |obj: f64| MultiTaskFit {
            w: vec![0.0],
            n_tasks: 1,
            objective: obj,
            kkt: 0.0,
            converged: obj.is_finite(),
            n_outer: 1,
            n_epochs: 1,
            history: Vec::new(),
        };
        // a divergent (NaN) block-MCP fit must not panic or win selection
        let fits = [mk(f64::NAN), mk(3.0), mk(1.0), mk(f64::NAN)];
        let best = best_fit(&fits).expect("finite fit exists");
        assert_eq!(best.objective, 1.0);
        let all_nan = [mk(f64::NAN)];
        assert!(best_fit(&all_nan).is_none());
    }

    #[test]
    fn unflatten_round_trip() {
        let w = vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0];
        let m = unflatten_coef(&w, 2);
        assert_eq!(m.nrows(), 3);
        assert_eq!(m.get(0, 1), 2.0);
        assert_eq!(m.get(2, 0), 5.0);
    }

    /// The Figure-4 headline, as a test: block-MCP recovers one source per
    /// hemisphere; ℓ2,1 at the same λ splits activity across extra rows.
    #[test]
    fn block_mcp_localizes_better_than_l21() {
        let pb = simulate(MeegSpec::default(), 42);
        let design = Design::Dense(pb.gain.clone());
        let y = flatten_tasks(&pb.measurements);
        let t = pb.measurements.ncols();
        let lam = block_lambda_max(&design, &y, t);

        // MCP semi-convexity needs γ > 1/L_j = n/‖G_j‖² = n (unit-norm
        // leadfield columns), so γ scales with the sensor count here.
        let gamma = 2.5 * pb.gain.nrows() as f64;
        let l21 = MultiTaskLasso::new(lam * 0.3).with_tol(1e-7).fit(&design, &y, t);
        let mcp = BlockMcpRegressor::new(lam * 0.3, gamma).with_tol(1e-7).fit(&design, &y, t);

        let loc_l21 = localize(&pb, &unflatten_coef(&l21.w, t), 1e-6);
        let loc_mcp = localize(&pb, &unflatten_coef(&mcp.w, t), 1e-6);
        // MCP recovers both hemispheres with no worse support size
        assert_eq!(loc_mcp.hemispheres_hit, 2, "MCP must find both sources");
        assert!(
            loc_mcp.recovered.len() <= loc_l21.recovered.len(),
            "MCP support {} should not exceed L21 {}",
            loc_mcp.recovered.len(),
            loc_l21.recovered.len()
        );
    }
}
