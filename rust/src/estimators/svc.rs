//! Linear SVM via the dual formulation (paper §E.4): hinge-loss SVC solved
//! as Problem (1) with the [`QuadraticSvc`] datafit and the box-indicator
//! penalty; the primal coefficients are recovered as `β = Gᵀα` (Eq. 35).

use crate::datafit::QuadraticSvc;
use crate::linalg::{CscMatrix, DenseMatrix, Design};
use crate::penalty::BoxIndicator;
use crate::solver::{solve, FitResult, SolverOpts};

#[derive(Clone, Debug)]
pub struct LinearSvc {
    pub c: f64,
    pub opts: SolverOpts,
}

/// Fit output: dual solution + recovered primal coefficients.
#[derive(Clone, Debug)]
pub struct SvcFit {
    pub alpha: FitResult,
    pub primal_coef: Vec<f64>,
    /// number of support vectors (α_i > 0)
    pub n_support: usize,
}

impl LinearSvc {
    pub fn new(c: f64) -> Self {
        Self { c, opts: SolverOpts::default() }
    }

    pub fn with_tol(mut self, tol: f64) -> Self {
        self.opts.tol = tol;
        self
    }

    pub fn with_solver(mut self, opts: SolverOpts) -> Self {
        self.opts = opts;
        self
    }

    /// Fit from a dense primal design (n × d) and ±1 labels.
    pub fn fit_dense(&self, x: &DenseMatrix, y: &[f64]) -> SvcFit {
        let dual = QuadraticSvc::dual_design_dense(x, y);
        self.fit_dual(&dual, y)
    }

    /// Fit from a sparse primal design.
    pub fn fit_sparse(&self, x: &CscMatrix, y: &[f64]) -> SvcFit {
        let dual = QuadraticSvc::dual_design_sparse(x, y);
        self.fit_dual(&dual, y)
    }

    /// Fit on a prebuilt dual design `Gᵀ` (d × n).
    pub fn fit_dual(&self, dual_design: &Design, y: &[f64]) -> SvcFit {
        let n = dual_design.ncols();
        assert_eq!(y.len(), n);
        let mut datafit = QuadraticSvc::new();
        let pen = BoxIndicator::new(self.c);
        let alpha = solve(dual_design, y, &mut datafit, &pen, &self.opts, None, None);
        // primal coef = Gᵀ α (the datafit state, recomputed here from α)
        let mut primal = vec![0.0; dual_design.nrows()];
        dual_design.matvec(&alpha.beta, &mut primal);
        let n_support = alpha.beta.iter().filter(|&&a| a > 0.0).count();
        SvcFit { alpha, primal_coef: primal, n_support }
    }

    /// Decision function `x ↦ xᵀβ` on a dense design.
    pub fn decision_function(x: &DenseMatrix, primal_coef: &[f64]) -> Vec<f64> {
        let mut out = vec![0.0; x.nrows()];
        Design::Dense(x.clone()).matvec(primal_coef, &mut out);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{correlated, CorrelatedSpec};
    use crate::util::rng::Rng;

    fn classification_data(n: usize, d: usize, seed: u64) -> (DenseMatrix, Vec<f64>) {
        let ds = correlated(
            CorrelatedSpec { n, p: d, rho: 0.3, nnz: d.min(5), snr: 10.0 },
            seed,
        );
        let y: Vec<f64> = ds.y.iter().map(|&v| if v >= 0.0 { 1.0 } else { -1.0 }).collect();
        match ds.design {
            Design::Dense(m) => (m, y),
            _ => unreachable!(),
        }
    }

    #[test]
    fn dual_solution_is_feasible_and_accurate() {
        let (x, y) = classification_data(100, 10, 0);
        let fit = LinearSvc::new(1.0).with_tol(1e-8).fit_dense(&x, &y);
        assert!(fit.alpha.converged, "kkt {}", fit.alpha.kkt);
        for &a in &fit.alpha.beta {
            assert!((-1e-12..=1.0 + 1e-12).contains(&a), "alpha {a} out of box");
        }
        let scores = LinearSvc::decision_function(&x, &fit.primal_coef);
        let acc = scores
            .iter()
            .zip(y.iter())
            .filter(|(s, yi)| (s.signum() - **yi).abs() < 1e-12)
            .count() as f64
            / y.len() as f64;
        assert!(acc > 0.85, "accuracy {acc}");
    }

    #[test]
    fn support_vectors_are_a_strict_subset() {
        let (x, y) = classification_data(150, 8, 1);
        let fit = LinearSvc::new(1.0).with_tol(1e-8).fit_dense(&x, &y);
        assert!(fit.n_support > 0);
        assert!(fit.n_support < 150, "not every point should be a support vector");
    }

    #[test]
    fn larger_c_fits_harder() {
        let (x, mut y) = classification_data(100, 6, 2);
        // flip a few labels to create margin violations
        let mut rng = Rng::seed_from_u64(3);
        for _ in 0..8 {
            let i = rng.below(100);
            y[i] = -y[i];
        }
        let loose = LinearSvc::new(0.01).with_tol(1e-8).fit_dense(&x, &y);
        let tight = LinearSvc::new(10.0).with_tol(1e-8).fit_dense(&x, &y);
        // higher C → larger dual objective magnitude (more support weight)
        let sum_loose: f64 = loose.alpha.beta.iter().sum();
        let sum_tight: f64 = tight.alpha.beta.iter().sum();
        assert!(sum_tight > sum_loose);
    }

    #[test]
    fn sparse_and_dense_fits_agree() {
        let (x, y) = classification_data(60, 5, 4);
        let mut trips = Vec::new();
        for i in 0..60 {
            for j in 0..5 {
                trips.push((i, j, x.get(i, j)));
            }
        }
        let xs = crate::linalg::CscMatrix::from_triplets(60, 5, &trips);
        let a = LinearSvc::new(1.0).with_tol(1e-10).fit_dense(&x, &y);
        let b = LinearSvc::new(1.0).with_tol(1e-10).fit_sparse(&xs, &y);
        assert!((a.alpha.objective - b.alpha.objective).abs() < 1e-8);
    }
}
