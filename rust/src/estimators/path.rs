//! Regularization paths (Figure 1): solve a geometric λ grid with warm
//! starts and report support / error metrics per point.

use crate::linalg::Design;
use crate::metrics::{estimation_error, prediction_mse, support_recovery, SupportRecovery};
use crate::solver::SolverOpts;

/// One solved point of a path.
#[derive(Clone, Debug)]
pub struct PathPoint {
    pub lambda: f64,
    /// λ / λ_max
    pub lambda_ratio: f64,
    pub beta: Vec<f64>,
    pub objective: f64,
    pub support_size: usize,
    /// vs. ground truth (when available)
    pub recovery: Option<SupportRecovery>,
    pub estimation_error: Option<f64>,
    pub prediction_mse: Option<f64>,
}

/// A full path.
#[derive(Clone, Debug)]
pub struct PathResult {
    pub penalty_name: String,
    pub points: Vec<PathPoint>,
    pub total_time: f64,
}

impl PathResult {
    /// λ-ratio of the point with the best estimation error. NaN metrics
    /// (divergent non-convex fits) sort last instead of panicking; a
    /// point is returned only when at least one finite metric exists.
    pub fn best_estimation(&self) -> Option<&PathPoint> {
        self.points
            .iter()
            .filter(|p| p.estimation_error.map(|e| !e.is_nan()).unwrap_or(false))
            .min_by(|a, b| {
                crate::util::order::nan_last_opt(a.estimation_error, b.estimation_error)
            })
    }

    pub fn best_prediction(&self) -> Option<&PathPoint> {
        self.points
            .iter()
            .filter(|p| p.prediction_mse.map(|e| !e.is_nan()).unwrap_or(false))
            .min_by(|a, b| crate::util::order::nan_last_opt(a.prediction_mse, b.prediction_mse))
    }

    /// Does any point on the path recover the support exactly?
    pub fn any_exact_recovery(&self) -> bool {
        self.points
            .iter()
            .any(|p| p.recovery.as_ref().map(|r| r.exact).unwrap_or(false))
    }
}

/// Generic warm-started path driver.
fn run_path<F>(
    design: &Design,
    beta_true: Option<&[f64]>,
    lambda_max: f64,
    ratios: &[f64],
    name: &str,
    mut solve_at: F,
) -> PathResult
where
    F: FnMut(f64, Option<&[f64]>) -> crate::solver::FitResult,
{
    let start = std::time::Instant::now();
    let mut points = Vec::with_capacity(ratios.len());
    let mut warm: Option<Vec<f64>> = None;
    for &ratio in ratios {
        let lam = lambda_max * ratio;
        let fit = solve_at(lam, warm.as_deref());
        warm = Some(fit.beta.clone());
        let recovery = beta_true.map(|bt| support_recovery(&fit.beta, bt, 1e-8));
        let est = beta_true.map(|bt| estimation_error(&fit.beta, bt));
        let pred = beta_true.map(|bt| prediction_mse(design, &fit.beta, bt));
        points.push(PathPoint {
            lambda: lam,
            lambda_ratio: ratio,
            support_size: fit.support().len(),
            objective: fit.objective,
            beta: fit.beta,
            recovery,
            estimation_error: est,
            prediction_mse: pred,
        });
    }
    PathResult {
        penalty_name: name.to_string(),
        points,
        total_time: start.elapsed().as_secs_f64(),
    }
}

/// Geometric grid of `count` ratios from 1 down to `min_ratio`.
///
/// # Examples
///
/// ```
/// let grid = skglm::estimators::path::geometric_grid(0.01, 5);
/// assert_eq!(grid.len(), 5);
/// assert!((grid[0] - 1.0).abs() < 1e-12);
/// assert!((grid[4] - 0.01).abs() < 1e-12);
/// assert!(grid.windows(2).all(|w| w[1] < w[0]), "descending");
/// ```
pub fn geometric_grid(min_ratio: f64, count: usize) -> Vec<f64> {
    assert!(count >= 2);
    assert!(min_ratio > 0.0 && min_ratio < 1.0);
    let step = min_ratio.powf(1.0 / (count - 1) as f64);
    (0..count).map(|k| step.powi(k as i32)).collect()
}

/// Lasso path.
pub fn lasso_path(
    design: &Design,
    y: &[f64],
    beta_true: Option<&[f64]>,
    ratios: &[f64],
    opts: &SolverOpts,
) -> PathResult {
    let lam_max = super::linear::quadratic_lambda_max(design, y);
    run_path(design, beta_true, lam_max, ratios, "l1", |lam, warm| {
        let mut est = super::linear::Lasso::new(lam).with_solver(opts.clone());
        if let Some(w) = warm {
            est = est.warm_start(w.to_vec());
        }
        est.fit(design, y)
    })
}

/// MCP path (on the √n-normalised design — caller should pre-normalise so
/// that errors refer to consistent coefficients; see `examples/fig1`).
pub fn mcp_path(
    design: &Design,
    y: &[f64],
    beta_true: Option<&[f64]>,
    ratios: &[f64],
    gamma: f64,
    opts: &SolverOpts,
) -> PathResult {
    let lam_max = super::linear::quadratic_lambda_max(design, y);
    run_path(design, beta_true, lam_max, ratios, "mcp", |lam, warm| {
        let mut est = super::linear::McpRegressor::new(lam, gamma)
            .without_normalize()
            .with_solver(opts.clone());
        if let Some(w) = warm {
            est = est.warm_start(w.to_vec());
        }
        est.fit(design, y).0
    })
}

/// SCAD path (same conventions as [`mcp_path`]).
pub fn scad_path(
    design: &Design,
    y: &[f64],
    beta_true: Option<&[f64]>,
    ratios: &[f64],
    gamma: f64,
    opts: &SolverOpts,
) -> PathResult {
    let lam_max = super::linear::quadratic_lambda_max(design, y);
    run_path(design, beta_true, lam_max, ratios, "scad", |lam, warm| {
        let mut datafit = crate::datafit::Quadratic::new();
        let pen = crate::penalty::Scad::new(lam, gamma);
        crate::solver::solve(design, y, &mut datafit, &pen, opts, None, warm)
    })
}

/// ℓ_{0.5} path (uses the `score^cd` rule internally).
pub fn lq_path(
    design: &Design,
    y: &[f64],
    beta_true: Option<&[f64]>,
    ratios: &[f64],
    q: f64,
    opts: &SolverOpts,
) -> PathResult {
    let lam_max = super::linear::quadratic_lambda_max(design, y);
    run_path(design, beta_true, lam_max, ratios, "lq", |lam, warm| {
        let mut datafit = crate::datafit::Quadratic::new();
        let pen = crate::penalty::Lq::new(lam, q);
        crate::solver::solve(design, y, &mut datafit, &pen, opts, None, warm)
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{correlated, CorrelatedSpec};

    #[test]
    fn geometric_grid_shape() {
        let g = geometric_grid(0.01, 5);
        assert_eq!(g.len(), 5);
        assert!((g[0] - 1.0).abs() < 1e-12);
        assert!((g[4] - 0.01).abs() < 1e-12);
        for w in g.windows(2) {
            assert!(w[1] < w[0]);
        }
    }

    #[test]
    fn lasso_path_support_grows_as_lambda_shrinks() {
        let ds = correlated(CorrelatedSpec { n: 100, p: 150, rho: 0.5, nnz: 8, snr: 10.0 }, 0);
        let ratios = geometric_grid(0.01, 10);
        let path = lasso_path(&ds.design, &ds.y, Some(&ds.beta_true), &ratios, &SolverOpts::default());
        assert_eq!(path.points.len(), 10);
        assert_eq!(path.points[0].support_size, 0, "support empty at lambda_max");
        assert!(
            path.points.last().unwrap().support_size >= path.points[1].support_size,
            "support grows along the path"
        );
    }

    #[test]
    fn best_point_selectors_survive_nan_objectives() {
        // regression: a single divergent (NaN-metric) point used to panic
        // best_estimation/best_prediction via partial_cmp().unwrap()
        let mk = |est: f64, pred: f64, ratio: f64| PathPoint {
            lambda: ratio,
            lambda_ratio: ratio,
            beta: vec![0.0],
            objective: est,
            support_size: 0,
            recovery: None,
            estimation_error: Some(est),
            prediction_mse: Some(pred),
        };
        let path = PathResult {
            penalty_name: "mcp".into(),
            points: vec![
                mk(3.0, 5.0, 1.0),
                mk(f64::NAN, f64::NAN, 0.5), // divergent fit
                mk(1.0, 2.0, 0.25),
            ],
            total_time: 0.0,
        };
        let be = path.best_estimation().expect("finite point exists");
        assert_eq!(be.lambda_ratio, 0.25);
        let bp = path.best_prediction().expect("finite point exists");
        assert_eq!(bp.lambda_ratio, 0.25);
        // all-NaN path: no best point, still no panic
        let all_nan = PathResult {
            penalty_name: "mcp".into(),
            points: vec![mk(f64::NAN, f64::NAN, 1.0)],
            total_time: 0.0,
        };
        assert!(all_nan.best_estimation().is_none());
        assert!(all_nan.best_prediction().is_none());
    }

    #[test]
    fn mcp_path_recovers_support_where_lasso_cannot_exactly() {
        // Figure-1 narrative: MCP achieves exact support recovery on the
        // correlated design; the Lasso path overselects at its best
        // prediction point.
        let ds = correlated(CorrelatedSpec { n: 200, p: 400, rho: 0.6, nnz: 20, snr: 5.0 }, 1);
        let mut design = ds.design.clone();
        design.normalize_cols((200.0f64).sqrt());
        let ratios = geometric_grid(0.05, 12);
        let opts = SolverOpts::default().with_tol(1e-7);
        let mcp = mcp_path(&design, &ds.y, Some(&ds.beta_true), &ratios, 3.0, &opts);
        assert!(
            mcp.any_exact_recovery(),
            "MCP path should contain an exact-recovery point"
        );
    }
}
