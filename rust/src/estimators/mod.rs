//! scikit-learn-style estimator API on top of the generic solver — the
//! library surface a practitioner uses (the paper's Table-1 "modular"
//! column: a new model is one datafit + one penalty).

pub mod cv;
pub mod group;
pub mod linear;
pub mod multitask;
pub mod path;
pub mod svc;

pub use cv::{group_lasso_cv, lasso_cv, CvResult};
pub use group::{group_lambda_max, GroupEstimator, GroupFit};
pub use linear::{ElasticNet, Lasso, McpRegressor, ScadRegressor, SparseLogisticRegression};
pub use multitask::{BlockMcpRegressor, MultiTaskLasso};
pub use path::{lasso_path, mcp_path, scad_path, PathPoint, PathResult};
pub use svc::LinearSvc;
