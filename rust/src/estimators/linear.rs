//! Single-task linear estimators: Lasso, ElasticNet, MCP / SCAD
//! regressors, sparse logistic regression.

use crate::datafit::{Logistic, Quadratic};
use crate::linalg::Design;
use crate::penalty::{L1L2, Mcp, Scad, L1};
use crate::solver::{solve, FitResult, GradEngine, SolverOpts};

/// Shared implementation detail: `λ_max = ‖Xᵀy‖∞ / n` — the smallest λ for
/// which the all-zero vector is optimal (quadratic datafit).
pub fn quadratic_lambda_max(design: &Design, y: &[f64]) -> f64 {
    let n = design.nrows() as f64;
    let mut xty = vec![0.0; design.ncols()];
    design.matvec_t(y, &mut xty);
    crate::linalg::norm_inf(&xty) / n
}

macro_rules! common_builder {
    () => {
        /// Replace the solver options.
        pub fn with_solver(mut self, opts: SolverOpts) -> Self {
            self.opts = opts;
            self
        }

        /// Set the stopping tolerance.
        pub fn with_tol(mut self, tol: f64) -> Self {
            self.opts.tol = tol;
            self
        }

        /// Warm-start from a previous solution.
        pub fn warm_start(mut self, beta0: Vec<f64>) -> Self {
            self.beta0 = Some(beta0);
            self
        }
    };
}

/// Lasso: `min ‖y−Xβ‖²/2n + λ‖β‖₁`.
///
/// # Examples
///
/// ```
/// use skglm::data::{correlated, CorrelatedSpec};
/// use skglm::estimators::Lasso;
///
/// let ds = correlated(CorrelatedSpec { n: 60, p: 80, rho: 0.4, nnz: 5, snr: 10.0 }, 0);
/// let lam = Lasso::lambda_max(&ds.design, &ds.y) / 10.0;
/// let fit = Lasso::new(lam).with_tol(1e-8).fit(&ds.design, &ds.y);
/// assert!(fit.converged);
/// assert!(!fit.support().is_empty());
/// assert!(fit.support().len() < 80, "solution is sparse");
/// ```
#[derive(Clone, Debug)]
pub struct Lasso {
    pub lambda: f64,
    pub opts: SolverOpts,
    beta0: Option<Vec<f64>>,
}

impl Lasso {
    pub fn new(lambda: f64) -> Self {
        Self { lambda, opts: SolverOpts::default(), beta0: None }
    }

    /// Smallest λ with all-zero solution.
    pub fn lambda_max(design: &Design, y: &[f64]) -> f64 {
        quadratic_lambda_max(design, y)
    }

    common_builder!();

    pub fn fit(&self, design: &Design, y: &[f64]) -> FitResult {
        let mut datafit = Quadratic::new();
        solve(design, y, &mut datafit, &L1::new(self.lambda), &self.opts, None, self.beta0.as_deref())
    }

    /// Fit with a pluggable scoring engine (PJRT path).
    pub fn fit_with_engine(
        &self,
        design: &Design,
        y: &[f64],
        engine: &mut dyn GradEngine,
    ) -> FitResult {
        let mut datafit = Quadratic::new();
        solve(
            design,
            y,
            &mut datafit,
            &L1::new(self.lambda),
            &self.opts,
            Some(engine),
            self.beta0.as_deref(),
        )
    }
}

/// Elastic net: `min ‖y−Xβ‖²/2n + λ(ρ‖β‖₁ + (1−ρ)‖β‖²/2)`.
///
/// # Examples
///
/// ```
/// use skglm::data::{correlated, CorrelatedSpec};
/// use skglm::estimators::ElasticNet;
///
/// let ds = correlated(CorrelatedSpec { n: 60, p: 80, rho: 0.4, nnz: 5, snr: 10.0 }, 1);
/// let lam = ElasticNet::lambda_max(&ds.design, &ds.y, 0.5) / 10.0;
/// let fit = ElasticNet::new(lam, 0.5).with_tol(1e-8).fit(&ds.design, &ds.y);
/// assert!(fit.converged);
/// ```
#[derive(Clone, Debug)]
pub struct ElasticNet {
    pub lambda: f64,
    pub l1_ratio: f64,
    pub opts: SolverOpts,
    beta0: Option<Vec<f64>>,
}

impl ElasticNet {
    pub fn new(lambda: f64, l1_ratio: f64) -> Self {
        Self { lambda, l1_ratio, opts: SolverOpts::default(), beta0: None }
    }

    pub fn lambda_max(design: &Design, y: &[f64], l1_ratio: f64) -> f64 {
        quadratic_lambda_max(design, y) / l1_ratio.max(1e-12)
    }

    common_builder!();

    pub fn fit(&self, design: &Design, y: &[f64]) -> FitResult {
        let mut datafit = Quadratic::new();
        solve(
            design,
            y,
            &mut datafit,
            &L1L2::new(self.lambda, self.l1_ratio),
            &self.opts,
            None,
            self.beta0.as_deref(),
        )
    }
}

/// MCP regression (paper §3.2): columns are normalised to ‖X_j‖ = √n when
/// `normalize = true` (the paper's convention, which also guarantees the
/// α-semi-convex regime γL_j = γ > 1).
///
/// # Examples
///
/// ```
/// use skglm::data::{correlated, CorrelatedSpec};
/// use skglm::estimators::{Lasso, McpRegressor};
///
/// let ds = correlated(CorrelatedSpec { n: 80, p: 100, rho: 0.4, nnz: 6, snr: 10.0 }, 2);
/// let lam = Lasso::lambda_max(&ds.design, &ds.y) / 10.0;
/// // fit returns the result plus the column scales applied by the √n
/// // normalization: β on the original design is scale ⊙ β
/// let (fit, scales) = McpRegressor::new(lam, 3.0).with_tol(1e-8).fit(&ds.design, &ds.y);
/// assert!(fit.converged);
/// assert_eq!(scales.len(), 100);
/// ```
#[derive(Clone, Debug)]
pub struct McpRegressor {
    pub lambda: f64,
    pub gamma: f64,
    pub normalize: bool,
    pub opts: SolverOpts,
    beta0: Option<Vec<f64>>,
}

impl McpRegressor {
    pub fn new(lambda: f64, gamma: f64) -> Self {
        Self { lambda, gamma, normalize: true, opts: SolverOpts::default(), beta0: None }
    }

    pub fn without_normalize(mut self) -> Self {
        self.normalize = false;
        self
    }

    common_builder!();

    /// Returns the fit and, when normalising, the column scales applied
    /// (coefficients refer to the scaled design: β_orig = scale ⊙ β).
    pub fn fit(&self, design: &Design, y: &[f64]) -> (FitResult, Vec<f64>) {
        let mut datafit = Quadratic::new();
        let pen = Mcp::new(self.lambda, self.gamma);
        if self.normalize {
            let mut d = design.clone();
            let scales = d.normalize_cols((design.nrows() as f64).sqrt());
            let fit = solve(&d, y, &mut datafit, &pen, &self.opts, None, self.beta0.as_deref());
            (fit, scales)
        } else {
            let fit =
                solve(design, y, &mut datafit, &pen, &self.opts, None, self.beta0.as_deref());
            (fit, vec![1.0; design.ncols()])
        }
    }
}

/// SCAD regression (same conventions as [`McpRegressor`]).
#[derive(Clone, Debug)]
pub struct ScadRegressor {
    pub lambda: f64,
    pub gamma: f64,
    pub normalize: bool,
    pub opts: SolverOpts,
    beta0: Option<Vec<f64>>,
}

impl ScadRegressor {
    pub fn new(lambda: f64, gamma: f64) -> Self {
        Self { lambda, gamma, normalize: true, opts: SolverOpts::default(), beta0: None }
    }

    common_builder!();

    pub fn fit(&self, design: &Design, y: &[f64]) -> (FitResult, Vec<f64>) {
        let mut datafit = Quadratic::new();
        let pen = Scad::new(self.lambda, self.gamma);
        if self.normalize {
            let mut d = design.clone();
            let scales = d.normalize_cols((design.nrows() as f64).sqrt());
            let fit = solve(&d, y, &mut datafit, &pen, &self.opts, None, self.beta0.as_deref());
            (fit, scales)
        } else {
            let fit =
                solve(design, y, &mut datafit, &pen, &self.opts, None, self.beta0.as_deref());
            (fit, vec![1.0; design.ncols()])
        }
    }
}

/// ℓ1-regularised logistic regression, labels ±1.
///
/// # Examples
///
/// ```
/// use skglm::data::{correlated, CorrelatedSpec};
/// use skglm::estimators::SparseLogisticRegression;
///
/// let ds = correlated(CorrelatedSpec { n: 60, p: 40, rho: 0.3, nnz: 4, snr: 10.0 }, 3);
/// let labels: Vec<f64> = ds.y.iter().map(|&v| if v >= 0.0 { 1.0 } else { -1.0 }).collect();
/// let lam = SparseLogisticRegression::lambda_max(&ds.design, &labels) / 10.0;
/// let fit = SparseLogisticRegression::new(lam).with_tol(1e-6).fit(&ds.design, &labels);
/// assert!(fit.converged);
/// let proba = SparseLogisticRegression::predict_proba(&ds.design, &fit.beta);
/// assert!(proba.iter().all(|p| (0.0..=1.0).contains(p)));
/// ```
#[derive(Clone, Debug)]
pub struct SparseLogisticRegression {
    pub lambda: f64,
    pub opts: SolverOpts,
    beta0: Option<Vec<f64>>,
}

impl SparseLogisticRegression {
    pub fn new(lambda: f64) -> Self {
        Self { lambda, opts: SolverOpts::default(), beta0: None }
    }

    /// `λ_max = ‖Xᵀy‖∞ / 2n` for the logistic loss.
    pub fn lambda_max(design: &Design, y: &[f64]) -> f64 {
        let n = design.nrows() as f64;
        let mut xty = vec![0.0; design.ncols()];
        design.matvec_t(y, &mut xty);
        crate::linalg::norm_inf(&xty) / (2.0 * n)
    }

    common_builder!();

    pub fn fit(&self, design: &Design, y: &[f64]) -> FitResult {
        let mut datafit = Logistic::new();
        solve(design, y, &mut datafit, &L1::new(self.lambda), &self.opts, None, self.beta0.as_deref())
    }

    /// Predicted probabilities P(y=1|x).
    pub fn predict_proba(design: &Design, beta: &[f64]) -> Vec<f64> {
        let mut xb = vec![0.0; design.nrows()];
        design.matvec(beta, &mut xb);
        xb.iter().map(|&s| 1.0 / (1.0 + (-s).exp())).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{correlated, CorrelatedSpec};
    use crate::metrics::support_recovery;

    fn ds() -> crate::data::Dataset {
        correlated(CorrelatedSpec { n: 150, p: 300, rho: 0.5, nnz: 10, snr: 10.0 }, 7)
    }

    #[test]
    fn lasso_estimator_converges_and_recovers_support() {
        let d = ds();
        let lam = Lasso::lambda_max(&d.design, &d.y) / 20.0;
        let fit = Lasso::new(lam).with_tol(1e-10).fit(&d.design, &d.y);
        assert!(fit.converged);
        let rec = support_recovery(&fit.beta, &d.beta_true, 1e-8);
        assert_eq!(rec.false_negatives, 0, "all true features found");
    }

    #[test]
    fn lambda_max_yields_null_model() {
        let d = ds();
        let lam = Lasso::lambda_max(&d.design, &d.y);
        let fit = Lasso::new(lam * 1.0001).fit(&d.design, &d.y);
        assert!(fit.support().is_empty());
    }

    #[test]
    fn enet_support_superset_of_lasso_like_behaviour() {
        let d = ds();
        let lam = Lasso::lambda_max(&d.design, &d.y) / 10.0;
        let fit = ElasticNet::new(lam, 0.5).with_tol(1e-10).fit(&d.design, &d.y);
        assert!(fit.converged);
        assert!(!fit.support().is_empty());
    }

    #[test]
    fn mcp_larger_coefficients_than_lasso() {
        // MCP is unbiased: on the true support its estimates exceed the
        // shrunk Lasso ones (Figure 1's story)
        let d = ds();
        let lam = Lasso::lambda_max(&d.design, &d.y) / 10.0;
        let lasso = Lasso::new(lam).with_tol(1e-9).fit(&d.design, &d.y);
        let (mcp, scales) = McpRegressor::new(lam, 3.0).with_tol(1e-9).fit(&d.design, &d.y);
        let true_sup: Vec<usize> =
            d.beta_true.iter().enumerate().filter(|(_, &b)| b != 0.0).map(|(j, _)| j).collect();
        let avg = |beta: &[f64], sc: &[f64]| {
            true_sup.iter().map(|&j| (beta[j] * sc[j]).abs()).sum::<f64>() / true_sup.len() as f64
        };
        let ones = vec![1.0; 300];
        assert!(
            avg(&mcp.beta, &scales) > avg(&lasso.beta, &ones),
            "MCP {} should exceed (less-biased) Lasso {}",
            avg(&mcp.beta, &scales),
            avg(&lasso.beta, &ones)
        );
    }

    #[test]
    fn scad_converges() {
        let d = ds();
        let lam = Lasso::lambda_max(&d.design, &d.y) / 10.0;
        let (fit, _) = ScadRegressor::new(lam, 3.7).with_tol(1e-9).fit(&d.design, &d.y);
        assert!(fit.converged, "kkt {}", fit.kkt);
    }

    #[test]
    fn logistic_estimator_classifies() {
        let d = ds();
        let yb: Vec<f64> = d.y.iter().map(|&v| if v >= 0.0 { 1.0 } else { -1.0 }).collect();
        let lam = SparseLogisticRegression::lambda_max(&d.design, &yb) / 10.0;
        let fit = SparseLogisticRegression::new(lam).with_tol(1e-8).fit(&d.design, &yb);
        assert!(fit.converged);
        let proba = SparseLogisticRegression::predict_proba(&d.design, &fit.beta);
        let acc = proba
            .iter()
            .zip(yb.iter())
            .filter(|(p, y)| (**p >= 0.5) == (**y > 0.0))
            .count() as f64
            / yb.len() as f64;
        assert!(acc > 0.8, "accuracy {acc}");
    }
}
