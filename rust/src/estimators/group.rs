//! Group-penalty estimators (structured sparsity over feature groups):
//! group Lasso (unweighted and √|b|-weighted), group MCP and group SCAD,
//! all running through the shared block-coordinate engine.

use crate::datafit::GroupedQuadratic;
use crate::linalg::Design;
use crate::penalty::{BlockPenalty, GroupLasso, GroupMcp, GroupScad, WeightedGroupLasso};
use crate::solver::{block_lambda_max_for, BlockFitResult, BlockPartition, SolverOpts};
use std::sync::Arc;

/// `λ_max` for group penalties: `max_b ‖X_bᵀy‖₂ / (n·w_b)` — the smallest
/// λ with an all-zero solution. `weights = None` is the unweighted group
/// Lasso / group MCP convention.
pub fn group_lambda_max(
    design: &Design,
    y: &[f64],
    part: &Arc<BlockPartition>,
    weights: Option<&[f64]>,
) -> f64 {
    let mut datafit = GroupedQuadratic::new(Arc::clone(part));
    block_lambda_max_for(design, y, &mut datafit, part, weights)
}

/// A fitted group model.
#[derive(Clone, Debug)]
pub struct GroupFit {
    pub result: BlockFitResult,
    part: Arc<BlockPartition>,
}

impl GroupFit {
    /// Active groups (any finite nonzero coefficient).
    pub fn group_support(&self) -> Vec<usize> {
        self.result.block_support(&self.part)
    }

    pub fn beta(&self) -> &[f64] {
        &self.result.v
    }
}

/// Group-penalty regressor: `min ‖y−Xβ‖²/2n + Σ_b φ_b(‖β_b‖)`.
#[derive(Clone, Debug)]
pub struct GroupEstimator<B: BlockPenalty> {
    penalty: B,
    part: Arc<BlockPartition>,
    pub opts: SolverOpts,
    /// gap-safe block screening: `(λ, per-block weights)` — only set by
    /// the convex ℓ2,1 constructors, where the sphere test is sound
    screen: Option<(f64, Option<Vec<f64>>)>,
}

impl<B: BlockPenalty> GroupEstimator<B> {
    /// Assemble from an explicit penalty, partition and solver options
    /// (the CLI path for the non-convex penalties; the named constructors
    /// below cover the common cases). No screening — use the
    /// [`group_lasso`]/[`weighted_group_lasso`] constructors for the
    /// convex screened solves.
    pub fn from_parts(penalty: B, part: Arc<BlockPartition>, opts: SolverOpts) -> Self {
        Self { penalty, part, opts, screen: None }
    }

    pub fn with_tol(mut self, tol: f64) -> Self {
        self.opts.tol = tol;
        self
    }

    pub fn with_opts(mut self, opts: SolverOpts) -> Self {
        self.opts = opts;
        self
    }

    pub fn fit(&self, design: &Design, y: &[f64]) -> GroupFit {
        let mut datafit = GroupedQuadratic::new(Arc::clone(&self.part));
        let screen = self.screen.as_ref().map(|(lambda, weights)| {
            let grouped_sq =
                design.group_sq_norms(self.part.flat_indices(), self.part.offsets());
            crate::solver::GroupScreenCfg {
                lambda: *lambda,
                weights: weights
                    .clone()
                    .unwrap_or_else(|| vec![1.0; self.part.n_blocks()]),
                block_frob: grouped_sq.iter().map(|s| s.sqrt()).collect(),
            }
        });
        let mut state = crate::solver::ContinuationState::default();
        let result = crate::solver::solve_blocks_continued(
            design,
            y,
            &self.part,
            &mut datafit,
            &self.penalty,
            &self.opts,
            &mut state,
            None,
            screen,
        );
        GroupFit { result, part: Arc::clone(&self.part) }
    }
}

/// Unweighted group Lasso (gap-safe block screening on).
pub fn group_lasso(lambda: f64, part: Arc<BlockPartition>) -> GroupEstimator<GroupLasso> {
    GroupEstimator {
        penalty: GroupLasso::new(lambda),
        part,
        opts: SolverOpts::default(),
        screen: Some((lambda, None)),
    }
}

/// √|b|-weighted group Lasso (the standard size-corrected convention;
/// gap-safe block screening on).
pub fn weighted_group_lasso(
    lambda: f64,
    part: Arc<BlockPartition>,
) -> GroupEstimator<WeightedGroupLasso> {
    let penalty = WeightedGroupLasso::sqrt_sizes(lambda, &part);
    let weights = penalty.weights().to_vec();
    GroupEstimator {
        penalty,
        part,
        opts: SolverOpts::default(),
        screen: Some((lambda, Some(weights))),
    }
}

/// Group MCP (non-convex; γ must satisfy the semi-convexity regime
/// `γ > 1/min_b L_b`, asserted at solve time).
pub fn group_mcp(lambda: f64, gamma: f64, part: Arc<BlockPartition>) -> GroupEstimator<GroupMcp> {
    GroupEstimator::from_parts(GroupMcp::new(lambda, gamma), part, SolverOpts::default())
}

/// Group SCAD (same regime caveat as [`group_mcp`]).
pub fn group_scad(
    lambda: f64,
    gamma: f64,
    part: Arc<BlockPartition>,
) -> GroupEstimator<GroupScad> {
    GroupEstimator::from_parts(GroupScad::new(lambda, gamma), part, SolverOpts::default())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{grouped_correlated, GroupedSpec};

    #[test]
    fn lambda_max_gives_all_zero_groups() {
        let (ds, part) = grouped_correlated(
            GroupedSpec { n: 60, p: 40, group_size: 5, active_groups: 2, rho: 0.4, snr: 8.0 },
            0,
        );
        let lam = group_lambda_max(&ds.design, &ds.y, &part, None);
        let fit = group_lasso(lam * 1.001, Arc::clone(&part)).fit(&ds.design, &ds.y);
        assert!(fit.group_support().is_empty(), "beta must be 0 at lambda_max");
        let fit2 = group_lasso(lam * 0.5, Arc::clone(&part)).fit(&ds.design, &ds.y);
        assert!(!fit2.group_support().is_empty());
    }

    #[test]
    fn group_lasso_recovers_planted_groups() {
        let (ds, part) = grouped_correlated(
            GroupedSpec { n: 120, p: 60, group_size: 5, active_groups: 3, rho: 0.3, snr: 10.0 },
            1,
        );
        let lam = group_lambda_max(&ds.design, &ds.y, &part, None) / 8.0;
        let fit = group_lasso(lam, Arc::clone(&part)).with_tol(1e-9).fit(&ds.design, &ds.y);
        assert!(fit.result.converged, "kkt {}", fit.result.kkt);
        let sup = fit.group_support();
        // planted groups are evenly spread; all must be found
        let planted: Vec<usize> = (0..part.n_blocks())
            .filter(|&b| part.coords(b).iter().any(|&j| ds.beta_true[j] != 0.0))
            .collect();
        for g in &planted {
            assert!(sup.contains(g), "planted group {g} missed (support {sup:?})");
        }
        assert!(sup.len() < part.n_blocks(), "solution should be group-sparse");
    }

    #[test]
    fn weighted_group_lasso_runs_and_penalises_large_groups() {
        let (ds, part) = grouped_correlated(
            GroupedSpec { n: 80, p: 48, group_size: 6, active_groups: 2, rho: 0.4, snr: 8.0 },
            2,
        );
        let lam = group_lambda_max(
            &ds.design,
            &ds.y,
            &part,
            Some(&(0..part.n_blocks())
                .map(|b| (part.block_len(b) as f64).sqrt())
                .collect::<Vec<_>>()),
        ) / 5.0;
        let fit =
            weighted_group_lasso(lam, Arc::clone(&part)).with_tol(1e-8).fit(&ds.design, &ds.y);
        assert!(fit.result.converged);
        assert!(!fit.group_support().is_empty());
    }
}
