//! Inner solver (paper Algorithm 2): Anderson-accelerated coordinate
//! descent restricted to the working set.
//!
//! Every epoch runs cyclic CD over `ws` (alternating sweep direction, per
//! Proposition 13's symmetric-sweep hypothesis); every M-th epoch an
//! Anderson extrapolation of the last M+1 ws-iterates is proposed and
//! **accepted only if it decreases the objective** — the guard that makes
//! acceleration safe on non-convex problems.
//!
//! Perf notes (EXPERIMENTS.md §Perf):
//! - the extrapolated *state* is obtained by combining stored state
//!   snapshots with the Anderson weights (valid because the weights sum
//!   to 1 and every built-in datafit's state is affine in β) — O(n·M)
//!   instead of replaying O(|ws|·n) column updates per proposal;
//! - the O(|ws|·n) working-set stationarity check only runs once the
//!   cheap per-epoch move bound `max_j L_j|Δβ_j|` drops to the tolerance
//!   (with a periodic safety check to bound staleness).

use super::anderson::Anderson;
use super::cd::{cd_epoch, cd_epoch_rev};
use crate::datafit::Datafit;
use crate::linalg::Design;
use crate::penalty::Penalty;
use std::time::Instant;

/// Forced stationarity evaluation at least every this many epochs, even
/// while the cheap move bound stays large. Shared with the batched
/// many-fit engine (`solver::batch`) so its gating matches bitwise.
pub(crate) const FORCE_CHECK_EVERY: usize = 50;

/// Per-stage wall-time and (modelled) flop attribution of the inner
/// solvers, accumulated up through [`super::outer::OuterOutcome`] and
/// [`super::skglm::FitResult`] and surfaced by `exp gram` — so perf PRs
/// can attribute time instead of guessing. Flops are stored-entry
/// touches: a residual epoch is `2·nnz(ws)` (one dot + one axpy per
/// coordinate), a Gram epoch is `|ws|²`, Gram assembly is the entries the
/// store actually computed.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct InnerProfile {
    /// seconds inside CD epochs (either engine)
    pub epoch_secs: f64,
    /// seconds scoring stationarity (inner checks + outer scoring passes)
    pub score_secs: f64,
    /// seconds proposing/guarding Anderson extrapolations
    pub extrapolation_secs: f64,
    /// seconds assembling working-set Gram blocks
    pub gram_assembly_secs: f64,
    /// modelled epoch flops (stored-entry touches)
    pub epoch_flops: f64,
    /// Gram-block entries computed (stored-entry touches)
    pub gram_assembly_flops: f64,
    /// stored-entry touches spent in multi-RHS panel passes (`XᵀR`,
    /// `stored_entries·B` per batched scoring pass) — the batched
    /// engine's share of the work; 0 for scalar fits
    pub panel_flops: f64,
    /// epochs run by the residual engine
    pub residual_epochs: usize,
    /// epochs run by the Gram engine
    pub gram_epochs: usize,
    /// effective kernel ISA the counted flops ran on (scalar-f64 flops
    /// and avx2-f32 flops are not comparable across hosts; the label
    /// travels with the numbers)
    pub kernel_isa: crate::linalg::KernelIsa,
    /// precision of the full-design passes behind the counters
    pub precision: crate::linalg::Precision,
}

impl InnerProfile {
    /// Accumulate another profile (outer loop / path sweeps).
    pub fn merge(&mut self, o: &InnerProfile) {
        self.epoch_secs += o.epoch_secs;
        self.score_secs += o.score_secs;
        self.extrapolation_secs += o.extrapolation_secs;
        self.gram_assembly_secs += o.gram_assembly_secs;
        self.epoch_flops += o.epoch_flops;
        self.gram_assembly_flops += o.gram_assembly_flops;
        self.panel_flops += o.panel_flops;
        self.residual_epochs += o.residual_epochs;
        self.gram_epochs += o.gram_epochs;
        // labels: adopt the other side's when it carries a non-default
        // one (merging across ISAs/precisions cannot happen in-process —
        // the ISA is probed once and pinned)
        if o.kernel_isa != crate::linalg::KernelIsa::default() {
            self.kernel_isa = o.kernel_isa;
        }
        if o.precision != crate::linalg::Precision::default() {
            self.precision = o.precision;
        }
    }

    /// Total modelled flops (epochs + Gram assembly + batched panel
    /// passes) — the engine comparison metric `exp gram` records even
    /// where wall time is too noisy to measure.
    pub fn total_flops(&self) -> f64 {
        self.epoch_flops + self.gram_assembly_flops + self.panel_flops
    }

    /// Fraction of modelled work done by multi-RHS panel kernels — the
    /// batching diagnostic surfaced by `exp batch` and the service stats
    /// verb. 0 when nothing ran batched.
    pub fn panel_flop_ratio(&self) -> f64 {
        let total = self.total_flops();
        if total > 0.0 {
            self.panel_flops / total
        } else {
            0.0
        }
    }
}

/// Result of one inner solve.
#[derive(Clone, Debug, Default)]
pub struct InnerStats {
    pub epochs: usize,
    pub accepted_extrapolations: usize,
    pub rejected_extrapolations: usize,
    /// final max working-set score
    pub ws_score: f64,
    /// number of full ws stationarity evaluations performed
    pub score_checks: usize,
    /// per-stage wall-time / flop attribution
    pub profile: InnerProfile,
}

/// Working-set score of coordinate `j` (Eq. 2, or Eq. 24 for `score^cd`
/// penalties).
#[inline]
pub fn coordinate_score<D: Datafit, P: Penalty>(
    design: &Design,
    y: &[f64],
    datafit: &D,
    penalty: &P,
    beta: &[f64],
    state: &[f64],
    j: usize,
) -> f64 {
    let lj = datafit.lipschitz()[j];
    if lj == 0.0 {
        return 0.0;
    }
    let grad = datafit.grad_j(design, y, state, beta, j);
    if penalty.use_cd_score() {
        // score^cd (Eq. 24): violation of the prox fixed-point equation
        (beta[j] - penalty.prox(beta[j] - grad / lj, 1.0 / lj, j)).abs()
    } else {
        penalty.subdiff_distance(beta[j], grad, j)
    }
}

/// Fill `scores[k]` with the working-set score of `ws[k]`. The O(|ws|·n)
/// stationarity pass, parallelised over the kernel engine (each score is
/// an independent column dot).
#[allow(clippy::too_many_arguments)]
pub fn coordinate_scores_into<D: Datafit, P: Penalty>(
    design: &Design,
    y: &[f64],
    datafit: &D,
    penalty: &P,
    beta: &[f64],
    state: &[f64],
    ws: &[usize],
    scores: &mut [f64],
) {
    use crate::linalg::parallel::{self, KernelPolicy};
    assert_eq!(ws.len(), scores.len());
    // work ≈ average column cost × |ws|
    let p = design.ncols().max(1);
    let work = design.stored_entries() / p * ws.len();
    let threads = KernelPolicy::global().threads_for(work);
    let ranges = parallel::even_chunks(ws.len(), parallel::chunk_count(threads));
    parallel::par_slices(scores, &ranges, threads, |_, rng, sub| {
        for (o, &j) in sub.iter_mut().zip(ws[rng].iter()) {
            *o = coordinate_score(design, y, datafit, penalty, beta, state, j);
        }
    });
}

/// Max score over the working set (allocates a scratch score buffer; only
/// runs on the move-bound-gated checks, never every epoch). Shared with
/// the batched engine's per-member gated checks.
pub(crate) fn ws_score_max<D: Datafit, P: Penalty>(
    design: &Design,
    y: &[f64],
    datafit: &D,
    penalty: &P,
    beta: &[f64],
    state: &[f64],
    ws: &[usize],
) -> f64 {
    let mut scores = vec![0.0; ws.len()];
    coordinate_scores_into(design, y, datafit, penalty, beta, state, ws, &mut scores);
    scores.iter().fold(0.0f64, |m, &s| m.max(s))
}

/// Algorithm 2. Mutates `beta`/`state` in place; `anderson_m = 0` disables
/// acceleration (ablation Figure 6).
#[allow(clippy::too_many_arguments)]
pub fn inner_solver<D: Datafit, P: Penalty>(
    design: &Design,
    y: &[f64],
    datafit: &D,
    penalty: &P,
    beta: &mut [f64],
    state: &mut [f64],
    ws: &[usize],
    max_epochs: usize,
    tol: f64,
    anderson_m: usize,
) -> InnerStats {
    let mut stats = InnerStats::default();
    // modelled per-epoch work: one column dot + one column axpy per coord
    let epoch_flops = 2.0 * design.subset_stored_entries(ws) as f64;
    let affine = datafit.state_is_affine();
    let mut accel = if anderson_m >= 2 { Some(Anderson::new(anderson_m)) } else { None };
    let mut ws_beta = vec![0.0; ws.len()];
    // state snapshots aligned with the Anderson buffer (affine path)
    let mut state_snaps: Vec<Vec<f64>> = Vec::new();
    let snap_cap = anderson_m + 1;

    let push_snap = |snaps: &mut Vec<Vec<f64>>, state: &[f64]| {
        if snaps.len() == snap_cap {
            snaps.remove(0);
        }
        snaps.push(state.to_vec());
    };

    // seed the buffer with the entry point
    if let Some(acc) = accel.as_mut() {
        gather(beta, ws, &mut ws_beta);
        acc.push(&ws_beta);
        if affine {
            push_snap(&mut state_snaps, state);
        }
    }

    let mut epochs_since_check = 0usize;
    for epoch in 1..=max_epochs {
        stats.epochs = epoch;
        // alternate sweep direction (Proposition 13 hypothesis 3)
        let t_epoch = Instant::now();
        let max_move = if epoch % 2 == 1 {
            cd_epoch(design, y, datafit, penalty, beta, state, ws)
        } else {
            cd_epoch_rev(design, y, datafit, penalty, beta, state, ws)
        };
        stats.profile.epoch_secs += t_epoch.elapsed().as_secs_f64();
        stats.profile.epoch_flops += epoch_flops;
        stats.profile.residual_epochs += 1;

        if let Some(acc) = accel.as_mut() {
            let t_extr = Instant::now();
            gather(beta, ws, &mut ws_beta);
            let full = acc.push(&ws_beta);
            if affine {
                push_snap(&mut state_snaps, state);
            }
            if full && epoch % acc.m() == 0 {
                if let Some(c) = acc.coefficients() {
                    let extr = acc.combine(&c);
                    let trial_state = if affine {
                        acc.combine_series(&c, &state_snaps)
                    } else {
                        replay_state(design, datafit, beta, state, ws, &extr)
                    };
                    if try_accept(
                        datafit, penalty, y, beta, state, ws, &extr, &trial_state,
                    ) {
                        stats.accepted_extrapolations += 1;
                        acc.clear();
                        state_snaps.clear();
                        gather(beta, ws, &mut ws_beta);
                        acc.push(&ws_beta);
                        if affine {
                            push_snap(&mut state_snaps, state);
                        }
                    } else {
                        stats.rejected_extrapolations += 1;
                    }
                }
            }
            stats.profile.extrapolation_secs += t_extr.elapsed().as_secs_f64();
        }

        // cheap move bound gates the O(|ws|·n) stationarity evaluation
        epochs_since_check += 1;
        let due = max_move <= tol
            || epochs_since_check >= FORCE_CHECK_EVERY
            || epoch == max_epochs;
        if due {
            epochs_since_check = 0;
            stats.score_checks += 1;
            let t_score = Instant::now();
            let score = ws_score_max(design, y, datafit, penalty, beta, state, ws);
            stats.profile.score_secs += t_score.elapsed().as_secs_f64();
            stats.profile.epoch_flops += epoch_flops / 2.0; // one dot per coord
            stats.ws_score = score;
            if score <= tol {
                return stats;
            }
        }
    }
    stats.score_checks += 1;
    let t_score = Instant::now();
    stats.ws_score = ws_score_max(design, y, datafit, penalty, beta, state, ws);
    stats.profile.score_secs += t_score.elapsed().as_secs_f64();
    stats.profile.epoch_flops += epoch_flops / 2.0;
    stats
}

#[inline]
pub(crate) fn gather(beta: &[f64], ws: &[usize], out: &mut [f64]) {
    for (o, &j) in out.iter_mut().zip(ws.iter()) {
        *o = beta[j];
    }
}

/// Non-affine fallback: build the trial state by replaying column updates.
fn replay_state<D: Datafit>(
    design: &Design,
    datafit: &D,
    beta: &[f64],
    state: &[f64],
    ws: &[usize],
    extr: &[f64],
) -> Vec<f64> {
    let mut trial = state.to_vec();
    for (k, &j) in ws.iter().enumerate() {
        let delta = extr[k] - beta[j];
        if delta != 0.0 {
            datafit.update_state(design, j, delta, &mut trial);
        }
    }
    trial
}

/// Objective guard: commit the extrapolated point iff it strictly
/// decreases the (working-set-restricted) objective. Shared with the
/// batched engine's per-member Anderson proposals (identical arithmetic
/// keeps batch == scalar trajectories).
#[allow(clippy::too_many_arguments)]
pub(crate) fn try_accept<D: Datafit, P: Penalty>(
    datafit: &D,
    penalty: &P,
    y: &[f64],
    beta: &mut [f64],
    state: &mut [f64],
    ws: &[usize],
    extr: &[f64],
    trial_state: &[f64],
) -> bool {
    let g_ext: f64 = ws.iter().enumerate().map(|(k, &j)| penalty.value(extr[k], j)).sum();
    if !g_ext.is_finite() {
        return false; // left the penalty's domain (e.g. box indicator)
    }
    let f_cur = datafit.value(y, beta, state);
    let g_cur: f64 = ws.iter().map(|&j| penalty.value(beta[j], j)).sum();
    let f_ext = datafit.value(y, beta, trial_state);
    if f_ext + g_ext < f_cur + g_cur {
        for (k, &j) in ws.iter().enumerate() {
            beta[j] = extr[k];
        }
        state.copy_from_slice(trial_state);
        true
    } else {
        false
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{correlated, CorrelatedSpec};
    use crate::datafit::Quadratic;
    use crate::penalty::{Mcp, L1};

    fn lasso_problem() -> (Design, Vec<f64>, Quadratic, L1) {
        let ds = correlated(CorrelatedSpec { n: 60, p: 40, rho: 0.5, nnz: 5, snr: 10.0 }, 42);
        let mut f = Quadratic::new();
        f.init(&ds.design, &ds.y);
        // lambda = lambda_max / 10
        let mut grad0 = vec![0.0; ds.p()];
        let state0 = f.init_state(&ds.design, &ds.y, &vec![0.0; ds.p()]);
        f.grad_full(&ds.design, &ds.y, &state0, &vec![0.0; ds.p()], &mut grad0);
        let lam = grad0.iter().fold(0.0f64, |m, g| m.max(g.abs())) / 10.0;
        let (design, y) = (ds.design, ds.y);
        (design, y, f, L1::new(lam))
    }

    #[test]
    fn reaches_tolerance() {
        let (d, y, f, pen) = lasso_problem();
        let p = d.ncols();
        let mut beta = vec![0.0; p];
        let mut state = f.init_state(&d, &y, &beta);
        let ws: Vec<usize> = (0..p).collect();
        let stats =
            inner_solver(&d, &y, &f, &pen, &mut beta, &mut state, &ws, 2000, 1e-10, 5);
        assert!(stats.ws_score <= 1e-10, "score {}", stats.ws_score);
        assert!(stats.score_checks >= 1);
    }

    #[test]
    fn acceleration_reduces_epochs() {
        let (d, y, f, pen) = lasso_problem();
        let p = d.ncols();
        let ws: Vec<usize> = (0..p).collect();
        let run = |m: usize| {
            let mut beta = vec![0.0; p];
            let mut state = f.init_state(&d, &y, &beta);
            inner_solver(&d, &y, &f, &pen, &mut beta, &mut state, &ws, 100_000, 1e-12, m)
                .epochs
        };
        let plain = run(0);
        let accel = run(5);
        assert!(
            accel < plain,
            "Anderson ({accel} epochs) should beat plain CD ({plain} epochs)"
        );
    }

    #[test]
    fn affine_snapshot_and_replay_paths_agree() {
        // force the replay path through a wrapper datafit that claims a
        // non-affine state; the two paths must produce identical iterates
        #[derive(Clone)]
        struct NonAffine(Quadratic);
        impl Datafit for NonAffine {
            fn init(&mut self, d: &Design, y: &[f64]) {
                self.0.init(d, y)
            }
            fn lipschitz(&self) -> &[f64] {
                self.0.lipschitz()
            }
            fn init_state(&self, d: &Design, y: &[f64], b: &[f64]) -> Vec<f64> {
                self.0.init_state(d, y, b)
            }
            fn update_state(&self, d: &Design, j: usize, dl: f64, s: &mut [f64]) {
                self.0.update_state(d, j, dl, s)
            }
            fn value(&self, y: &[f64], b: &[f64], s: &[f64]) -> f64 {
                self.0.value(y, b, s)
            }
            fn grad_j(&self, d: &Design, y: &[f64], s: &[f64], b: &[f64], j: usize) -> f64 {
                self.0.grad_j(d, y, s, b, j)
            }
            fn name(&self) -> &'static str {
                "nonaffine-test"
            }
            fn state_is_affine(&self) -> bool {
                false
            }
        }

        let (d, y, f, pen) = lasso_problem();
        let p = d.ncols();
        let ws: Vec<usize> = (0..p).collect();

        let mut beta_a = vec![0.0; p];
        let mut state_a = f.init_state(&d, &y, &beta_a);
        inner_solver(&d, &y, &f, &pen, &mut beta_a, &mut state_a, &ws, 100, 1e-12, 5);

        let nf = NonAffine(f.clone());
        let mut beta_b = vec![0.0; p];
        let mut state_b = nf.init_state(&d, &y, &beta_b);
        inner_solver(&d, &y, &nf, &pen, &mut beta_b, &mut state_b, &ws, 100, 1e-12, 5);

        for (a, b) in beta_a.iter().zip(beta_b.iter()) {
            assert!((a - b).abs() < 1e-10, "paths diverged: {a} vs {b}");
        }
    }

    #[test]
    fn extrapolation_never_increases_objective() {
        let (d, y, f, _) = lasso_problem();
        let p = d.ncols();
        let pen = Mcp::new(0.05, 3.0);
        let min_l = f.lipschitz().iter().cloned().fold(f64::INFINITY, f64::min);
        assert!(3.0 * min_l > 1.0, "test setup: semi-convexity violated");
        let mut beta = vec![0.0; p];
        let mut state = f.init_state(&d, &y, &beta);
        let ws: Vec<usize> = (0..p).collect();
        let mut prev = super::super::cd::objective(&f, &pen, &y, &beta, &state);
        for _ in 0..30 {
            inner_solver(&d, &y, &f, &pen, &mut beta, &mut state, &ws, 5, f64::MIN_POSITIVE, 5);
            let cur = super::super::cd::objective(&f, &pen, &y, &beta, &state);
            assert!(cur <= prev + 1e-10, "objective increased {prev} -> {cur}");
            prev = cur;
        }
    }

    #[test]
    fn state_stays_consistent_after_extrapolations() {
        let (d, y, f, pen) = lasso_problem();
        let p = d.ncols();
        let mut beta = vec![0.0; p];
        let mut state = f.init_state(&d, &y, &beta);
        let ws: Vec<usize> = (0..p).collect();
        inner_solver(&d, &y, &f, &pen, &mut beta, &mut state, &ws, 200, 1e-8, 5);
        let fresh = f.init_state(&d, &y, &beta);
        for (a, b) in state.iter().zip(fresh.iter()) {
            assert!((a - b).abs() < 1e-9, "state drifted: {a} vs {b}");
        }
    }

    #[test]
    fn gated_score_checks_are_sparse_but_sound() {
        let (d, y, f, pen) = lasso_problem();
        let p = d.ncols();
        let mut beta = vec![0.0; p];
        let mut state = f.init_state(&d, &y, &beta);
        let ws: Vec<usize> = (0..p).collect();
        let stats =
            inner_solver(&d, &y, &f, &pen, &mut beta, &mut state, &ws, 5000, 1e-10, 5);
        // far fewer checks than epochs, and the final one certifies tol
        assert!(stats.score_checks * 2 <= stats.epochs.max(4), "checks {} epochs {}", stats.score_checks, stats.epochs);
        assert!(stats.ws_score <= 1e-10);
    }
}
