//! Anderson extrapolation (paper Algorithm 4, Bertrand & Massias 2021).
//!
//! Keeps the last M+1 working-set iterates β^{(k−M)}, …, β^{(k)}; forms the
//! difference matrix `U = (β^{(1)}−β^{(0)}, …, β^{(M)}−β^{(M−1)})`, solves
//! the M×M normal system `(UᵀU) z = 1` (Tikhonov-regularised — UᵀU is
//! singular at convergence), normalises `c = z / 1ᵀz`, and proposes
//! `β_extr = Σ_k c_k β^{(k)}`. Cost O(M²·|ws| + M³) per proposal, as the
//! paper annotates. The *inner solver* owns the objective guard that makes
//! this safe for non-convex problems.

/// Fixed-capacity iterate buffer + extrapolation solve.
#[derive(Clone, Debug)]
pub struct Anderson {
    m: usize,
    /// stored iterates, oldest first; at most m+1
    iterates: Vec<Vec<f64>>,
}

impl Anderson {
    pub fn new(m: usize) -> Self {
        assert!(m >= 2, "Anderson needs M >= 2");
        Self { m, iterates: Vec::with_capacity(m + 1) }
    }

    pub fn m(&self) -> usize {
        self.m
    }

    /// Reset the buffer (on working-set change or rejected proposal).
    pub fn clear(&mut self) {
        self.iterates.clear();
    }

    /// Record an iterate. Returns true when the buffer holds M+1 iterates
    /// and an extrapolation can be attempted.
    pub fn push(&mut self, x: &[f64]) -> bool {
        if self.iterates.len() == self.m + 1 {
            self.iterates.remove(0);
        }
        self.iterates.push(x.to_vec());
        self.iterates.len() == self.m + 1
    }

    /// Solve for the extrapolated point. Returns None if the buffer is not
    /// full or the normal system is too ill-conditioned to trust.
    pub fn extrapolate(&self) -> Option<Vec<f64>> {
        let c = self.coefficients()?;
        Some(self.combine(&c))
    }

    /// The extrapolation weights `c` (length M, summing to 1) over the
    /// last M stored iterates — exposed so callers can combine *other*
    /// affine-in-β quantities (e.g. the residual state) at O(n·M) instead
    /// of replaying O(|ws|·n) column updates.
    pub fn coefficients(&self) -> Option<Vec<f64>> {
        if self.iterates.len() != self.m + 1 {
            return None;
        }
        let m = self.m;
        let dim = self.iterates[0].len();
        // Gram matrix G = UᵀU where U[:,k] = x_{k+1} − x_k
        let mut g = vec![0.0; m * m];
        for a in 0..m {
            for b in a..m {
                let mut s = 0.0;
                for i in 0..dim {
                    let ua = self.iterates[a + 1][i] - self.iterates[a][i];
                    let ub = self.iterates[b + 1][i] - self.iterates[b][i];
                    s += ua * ub;
                }
                g[a * m + b] = s;
                g[b * m + a] = s;
            }
        }
        // Tikhonov: G += 1e-12 · trace(G) · I (Scieur et al. 2016 style)
        let trace: f64 = (0..m).map(|k| g[k * m + k]).sum();
        if trace == 0.0 {
            return None; // iterates identical: already converged
        }
        let reg = 1e-12 * trace;
        for k in 0..m {
            g[k * m + k] += reg;
        }
        // solve G z = 1 by Gaussian elimination with partial pivoting
        let mut z = vec![1.0; m];
        if !solve_in_place(&mut g, &mut z, m) {
            return None;
        }
        let sum: f64 = z.iter().sum();
        if sum.abs() < 1e-300 || !sum.is_finite() {
            return None;
        }
        for zk in z.iter_mut() {
            *zk /= sum;
        }
        if z.iter().any(|v| !v.is_finite()) {
            return None;
        }
        Some(z)
    }

    /// `Σ c_k x_{k+1}` over the stored iterates.
    pub fn combine(&self, c: &[f64]) -> Vec<f64> {
        assert_eq!(c.len(), self.m);
        let dim = self.iterates[0].len();
        let mut out = vec![0.0; dim];
        for (k, &ck) in c.iter().enumerate() {
            for (o, &xi) in out.iter_mut().zip(self.iterates[k + 1].iter()) {
                *o += ck * xi;
            }
        }
        out
    }

    /// Combine an external per-iterate series (e.g. state snapshots) with
    /// the same weights: `Σ c_k series[k+1]`. `series` must have M+1
    /// entries aligned with the pushes.
    pub fn combine_series(&self, c: &[f64], series: &[Vec<f64>]) -> Vec<f64> {
        assert_eq!(c.len(), self.m);
        assert_eq!(series.len(), self.m + 1);
        let dim = series[0].len();
        let mut out = vec![0.0; dim];
        for (k, &ck) in c.iter().enumerate() {
            for (o, &xi) in out.iter_mut().zip(series[k + 1].iter()) {
                *o += ck * xi;
            }
        }
        out
    }
}

/// In-place dense solve of `A x = b` (row-major m×m), partial pivoting.
/// Returns false if A is numerically singular.
fn solve_in_place(a: &mut [f64], b: &mut [f64], m: usize) -> bool {
    for col in 0..m {
        // pivot
        let mut piv = col;
        let mut best = a[col * m + col].abs();
        for r in col + 1..m {
            let v = a[r * m + col].abs();
            if v > best {
                best = v;
                piv = r;
            }
        }
        if best < 1e-300 {
            return false;
        }
        if piv != col {
            for k in 0..m {
                a.swap(col * m + k, piv * m + k);
            }
            b.swap(col, piv);
        }
        let d = a[col * m + col];
        for r in col + 1..m {
            let factor = a[r * m + col] / d;
            if factor != 0.0 {
                for k in col..m {
                    a[r * m + k] -= factor * a[col * m + k];
                }
                b[r] -= factor * b[col];
            }
        }
    }
    for col in (0..m).rev() {
        let mut s = b[col];
        for k in col + 1..m {
            s -= a[col * m + k] * b[k];
        }
        b[col] = s / a[col * m + col];
    }
    true
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn needs_full_buffer() {
        let mut an = Anderson::new(3);
        assert!(!an.push(&[1.0, 2.0]));
        assert!(an.extrapolate().is_none());
        assert!(!an.push(&[1.5, 2.5]));
        assert!(!an.push(&[1.75, 2.75]));
        assert!(an.push(&[1.875, 2.875]));
        assert!(an.extrapolate().is_some());
    }

    #[test]
    fn exact_for_linear_fixed_point_iteration() {
        // x_{k+1} = T x_k + b with spectral radius < 1: Anderson with
        // M >= dim recovers the fixed point exactly from M+1 iterates.
        let t = [[0.6, 0.2], [0.1, 0.5]];
        let b = [1.0, -0.5];
        let step = |x: [f64; 2]| {
            [
                t[0][0] * x[0] + t[0][1] * x[1] + b[0],
                t[1][0] * x[0] + t[1][1] * x[1] + b[1],
            ]
        };
        // true fixed point: (I−T) x* = b
        let det = (1.0 - t[0][0]) * (1.0 - t[1][1]) - t[0][1] * t[1][0];
        let xs = [
            ((1.0 - t[1][1]) * b[0] + t[0][1] * b[1]) / det,
            (t[1][0] * b[0] + (1.0 - t[0][0]) * b[1]) / det,
        ];
        let mut an = Anderson::new(3);
        let mut x = [0.0, 0.0];
        an.push(&x);
        for _ in 0..3 {
            x = step(x);
            an.push(&x);
        }
        let extr = an.extrapolate().unwrap();
        assert!((extr[0] - xs[0]).abs() < 1e-8, "{extr:?} vs {xs:?}");
        assert!((extr[1] - xs[1]).abs() < 1e-8);
    }

    #[test]
    fn beats_plain_iteration_on_ill_conditioned_system() {
        // slow scalar contraction: x_{k+1} = 0.999 x_k, fixed point 0
        let mut an = Anderson::new(5);
        let mut x = vec![1.0, -2.0, 0.5];
        an.push(&x);
        for _ in 0..5 {
            for v in x.iter_mut() {
                *v *= 0.999;
            }
            an.push(&x);
        }
        let extr = an.extrapolate().unwrap();
        let plain_err: f64 = x.iter().map(|v| v.abs()).fold(0.0, f64::max);
        let extr_err: f64 = extr.iter().map(|v| v.abs()).fold(0.0, f64::max);
        assert!(
            extr_err < plain_err * 1e-3,
            "extrapolation ({extr_err}) should crush plain iteration ({plain_err})"
        );
    }

    #[test]
    fn converged_buffer_returns_none() {
        let mut an = Anderson::new(2);
        for _ in 0..3 {
            an.push(&[1.0, 1.0]);
        }
        assert!(an.extrapolate().is_none());
    }

    #[test]
    fn clear_resets() {
        let mut an = Anderson::new(2);
        for i in 0..3 {
            an.push(&[i as f64]);
        }
        an.clear();
        assert!(an.extrapolate().is_none());
    }

    #[test]
    fn solver_handles_permuted_systems() {
        // A requiring pivoting: [[0, 1], [1, 0]]
        let mut a = vec![0.0, 1.0, 1.0, 0.0];
        let mut b = vec![2.0, 3.0];
        assert!(solve_in_place(&mut a, &mut b, 2));
        assert!((b[0] - 3.0).abs() < 1e-14);
        assert!((b[1] - 2.0).abs() < 1e-14);
    }
}
