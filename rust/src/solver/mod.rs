//! Solvers: the paper's Algorithm 1 (working sets) / Algorithm 2
//! (Anderson-accelerated inner CD) / Algorithm 3 (CD epoch) / Algorithm 4
//! (Anderson extrapolation), all hosted on **one** generic
//! block-coordinate outer loop ([`outer`]) instantiated by the scalar
//! solver, the screened-Lasso fast path, and the grouped/multitask block
//! engine ([`block_cd`]); plus the prox-Newton outer solver for datafits
//! without precomputable Lipschitz bounds (Poisson/probit) and every
//! baseline the evaluation figures compare against.
//!
//! Quadratic datafits have **two** interchangeable inner engines behind
//! one cost-model dispatcher ([`gram`]): the residual engine (O(n) per
//! coordinate) and the Gram-domain engine (O(|ws|) per coordinate on
//! incrementally assembled, cache-persistent working-set Grams).

pub mod anderson;
pub mod baselines;
pub mod batch;
pub mod block_cd;
pub mod cd;
pub mod gram;
pub mod inner;
pub mod multitask;
pub mod outer;
pub mod partition;
pub mod prox_newton;
pub mod screening;
pub mod skglm;

pub use batch::{
    batch_lambda_max, batching_enabled, solve_batch, BatchFit, BatchMemberResult, BatchOutcome,
    MaskedQuadratic,
};
pub use gram::{gram_inner_solver, EngineDispatch, InnerEngine};
pub use inner::InnerProfile;
pub use skglm::{
    solve, solve_continued, solve_prepared, Certificate, ContinuationState, FitResult,
    GradEngine, HistoryPoint, SolveBudget, SolverOpts, StopReason,
};
pub use block_cd::{
    block_lambda_max_for, solve_blocks, solve_blocks_continued, BlockDatafit, BlockFitResult,
    GroupScreenCfg,
};
pub use multitask::{solve_multitask, MultiTaskFit};
pub use outer::{solve_outer, BlockCoords, OuterOutcome};
pub use partition::BlockPartition;
pub use prox_newton::{
    glm_lambda_max, solve_prox_newton, solve_prox_newton_continued, solve_prox_newton_prepared,
};
