//! Solvers: the paper's Algorithm 1 (working sets) / Algorithm 2
//! (Anderson-accelerated inner CD) / Algorithm 3 (CD epoch) / Algorithm 4
//! (Anderson extrapolation), the prox-Newton outer solver for datafits
//! without precomputable Lipschitz bounds (Poisson/probit), the multitask
//! block variant, and every baseline the evaluation figures compare
//! against.

pub mod anderson;
pub mod baselines;
pub mod cd;
pub mod inner;
pub mod multitask;
pub mod prox_newton;
pub mod screening;
pub mod skglm;

pub use skglm::{
    solve, solve_continued, solve_prepared, ContinuationState, FitResult, GradEngine,
    HistoryPoint, SolverOpts,
};
pub use multitask::{solve_multitask, MultiTaskFit};
pub use prox_newton::{
    glm_lambda_max, solve_prox_newton, solve_prox_newton_continued, solve_prox_newton_prepared,
};
