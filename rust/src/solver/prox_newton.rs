//! Prox-Newton outer solver — the second solver topology next to the
//! direct working-set CD of [`super::skglm`].
//!
//! The direct path needs a *precomputable* per-coordinate Lipschitz bound
//! (Assumption 1), which rules out GLMs with unbounded curvature such as
//! Poisson regression. This solver removes that requirement: at every
//! outer iteration it rebuilds a local quadratic model of the datafit
//! from the per-sample derivatives ([`crate::datafit::Datafit::raw_grad`]
//! / [`crate::datafit::Datafit::raw_hessian`]) and lets the *existing*
//! working-set machinery loose on the model:
//!
//! 1. score all features on the true gradient `∇f(β) = Xᵀ F'(Xβ)`, stop
//!    on the KKT tolerance, grow the working set exactly like
//!    Algorithm 1 (same `select_working_set`);
//! 2. assemble the working-set quadratic subproblem
//!    `q(v) = ∇f(β)ᵀ(v−β) + ½ (v−β)ᵀ Xᵀ diag(F'') X (v−β) + Σ g_j(v_j)`
//!    whose per-coordinate Lipschitz constants are the Hessian-weighted
//!    column norms `Σ_i F_i'' X_ij²` ([`Design::col_weighted_sq_norm`]);
//! 3. solve it with the Anderson-accelerated inner CD solver
//!    (Algorithm 2) — the subproblem state `X(v−β)` is affine in `v`, so
//!    the snapshot-combining acceleration path applies unchanged;
//! 4. globalise with a backtracking line search on the **true** composite
//!    objective (Armijo condition with the standard prox-Newton decrease
//!    measure `∇fᵀd + g(β+d) − g(β)`), which near the optimum accepts the
//!    full step and the iteration converges quadratically.
//!
//! The cost profile differs from direct CD: each inner epoch is the same
//! O(|ws|·n̄) sweep, but the gradient/Hessian refresh adds two O(n) passes
//! and one weighted column-norm pass per outer iteration — the price of
//! curvature adaptivity.

use super::inner::inner_solver;
use super::outer::select_working_set;
use super::{ContinuationState, FitResult, HistoryPoint, SolverOpts};
use crate::datafit::Datafit;
use crate::linalg::Design;
use crate::penalty::Penalty;
use std::time::Instant;

/// Armijo sufficient-decrease constant.
const ARMIJO_SIGMA: f64 = 1e-4;
/// Maximum backtracking halvings before the step is declared stalled.
const MAX_BACKTRACKS: usize = 30;

/// The working-set quadratic model, packaged as a [`Datafit`] so the
/// inner solver (Algorithm 2) runs on it verbatim. The subproblem
/// variable is the *absolute* coefficient vector `v` (not the increment),
/// and its state is `X(v − β)` — affine in `v`, starting at zero.
#[derive(Clone)]
struct NewtonSubproblem {
    /// per-sample curvature `F_i''` at the expansion point (incl. 1/n)
    h: Vec<f64>,
    /// full gradient `∇f(β)` at the expansion point
    grad0: Vec<f64>,
    /// expansion point β
    beta_ref: Vec<f64>,
    /// `Σ_i h_i X_ij²` for working-set columns (0 elsewhere)
    lipschitz: Vec<f64>,
}

impl Datafit for NewtonSubproblem {
    fn init(&mut self, _design: &Design, _y: &[f64]) {
        // assembled by the outer loop; nothing to precompute
    }

    fn lipschitz(&self) -> &[f64] {
        &self.lipschitz
    }

    /// State = `X(v − β)`.
    fn init_state(&self, design: &Design, _y: &[f64], beta: &[f64]) -> Vec<f64> {
        let diff: Vec<f64> =
            beta.iter().zip(self.beta_ref.iter()).map(|(v, b)| v - b).collect();
        let mut out = vec![0.0; design.nrows()];
        design.matvec(&diff, &mut out);
        out
    }

    #[inline]
    fn update_state(&self, design: &Design, j: usize, delta: f64, state: &mut [f64]) {
        design.col_axpy(j, delta, state);
    }

    /// `q(v) − f(β) = ∇f(β)ᵀ(v−β) + ½ Σ_i h_i d_i²` (the constant `f(β)`
    /// drops out of every comparison the inner solver makes).
    fn value(&self, _y: &[f64], beta: &[f64], state: &[f64]) -> f64 {
        let mut lin = 0.0;
        for ((&v, &b), &g) in beta.iter().zip(self.beta_ref.iter()).zip(self.grad0.iter()) {
            if v != b {
                lin += g * (v - b);
            }
        }
        let mut quad = 0.0;
        for (&hi, &di) in self.h.iter().zip(state.iter()) {
            quad += hi * di * di;
        }
        lin + 0.5 * quad
    }

    #[inline]
    fn grad_j(&self, design: &Design, _y: &[f64], state: &[f64], _beta: &[f64], j: usize) -> f64 {
        self.grad0[j] + design.col_dot_map(j, state, |i, d| self.h[i] * d)
    }

    fn name(&self) -> &'static str {
        "prox-newton-subproblem"
    }
}

/// Solve `min f(β) + Σ g_j(β_j)` by prox-Newton. `beta0` warm-starts.
pub fn solve_prox_newton<D: Datafit, P: Penalty>(
    design: &Design,
    y: &[f64],
    datafit: &mut D,
    penalty: &P,
    opts: &SolverOpts,
    beta0: Option<&[f64]>,
) -> FitResult {
    datafit.init(design, y);
    solve_prox_newton_prepared(design, y, datafit, penalty, opts, beta0, None)
}

/// [`solve_prox_newton`] threading a [`ContinuationState`] through (path
/// sweeps): warm-starts from `state`, then updates it with the outcome.
/// `col_sq_norms` is the coordinator's cached Gram diagonal.
pub fn solve_prox_newton_continued<D: Datafit, P: Penalty>(
    design: &Design,
    y: &[f64],
    datafit: &mut D,
    penalty: &P,
    opts: &SolverOpts,
    state: &mut ContinuationState,
    col_sq_norms: Option<&[f64]>,
) -> FitResult {
    datafit.init_cached(design, y, col_sq_norms);
    let result = solve_prox_newton_prepared(
        design,
        y,
        datafit,
        penalty,
        opts,
        state.beta.as_deref(),
        state.ws_size,
    );
    state.update_from(&result);
    result
}

/// Prox-Newton on an already-initialized datafit. `ws0` seeds the
/// working-set size (path continuation).
pub fn solve_prox_newton_prepared<D: Datafit, P: Penalty>(
    design: &Design,
    y: &[f64],
    datafit: &D,
    penalty: &P,
    opts: &SolverOpts,
    beta0: Option<&[f64]>,
    ws0: Option<usize>,
) -> FitResult {
    assert!(
        datafit.supports_prox_newton(),
        "datafit {} does not expose raw curvature (supports_prox_newton = false)",
        datafit.name()
    );
    let start = Instant::now();
    let n = design.nrows();
    let p = design.ncols();

    let mut beta = match beta0 {
        Some(b) => {
            assert_eq!(b.len(), p);
            b.to_vec()
        }
        None => vec![0.0; p],
    };
    let mut state = datafit.init_state(design, y, &beta);
    let mut grad = vec![0.0; p];
    let mut scores = vec![0.0; p];
    let mut h = vec![0.0; n];
    let mut trial_state = vec![0.0; n];

    let mut result = FitResult {
        beta: Vec::new(),
        objective: f64::NAN,
        kkt: f64::NAN,
        certificate: super::skglm::Certificate::Stationarity,
        n_outer: 0,
        n_epochs: 0,
        converged: false,
        history: Vec::new(),
        accepted_extrapolations: 0,
        rejected_extrapolations: 0,
        profile: Default::default(),
    };

    let mut ws_size = ws0.unwrap_or(opts.ws_start).min(p).max(1);

    for outer in 1..=opts.max_outer {
        if let Some(budget) = &opts.budget {
            if budget.check(result.n_epochs).is_some() {
                break; // partial iterate; final metrics computed below
            }
        }
        result.n_outer = outer;

        // ---- scoring pass on the true gradient ----
        datafit.grad_full(design, y, &state, &beta, &mut grad);
        let mut kkt_max = 0.0f64;
        for j in 0..p {
            let s = penalty.subdiff_distance(beta[j], grad[j], j);
            scores[j] = s;
            kkt_max = kkt_max.max(s);
        }

        let objective = super::cd::objective(datafit, penalty, y, &beta, &state);
        result.history.push(HistoryPoint {
            t: start.elapsed().as_secs_f64(),
            objective,
            kkt: kkt_max,
            ws_size: if opts.use_ws { ws_size.min(p) } else { p },
        });
        if opts.verbose {
            eprintln!(
                "[prox-newton] outer {outer:3}  obj {objective:.6e}  kkt {kkt_max:.3e}  ws {}",
                if opts.use_ws { ws_size.min(p) } else { p }
            );
        }
        if kkt_max <= opts.tol {
            result.converged = true;
            break;
        }

        // ---- working-set selection (same rule as Algorithm 1) ----
        let gsupp_count = beta.iter().filter(|&&b| penalty.in_gsupp(b)).count();
        let ws: Vec<usize> = if opts.use_ws {
            ws_size = ws_size.max(2 * gsupp_count).min(p);
            select_working_set(&mut scores, ws_size, |j| penalty.in_gsupp(beta[j]))
        } else {
            (0..p).collect()
        };
        if ws.is_empty() {
            result.converged = true;
            break;
        }

        // ---- assemble + solve the quadratic subproblem ----
        datafit.raw_hessian(y, &state, &mut h);
        let mut lip = vec![0.0; p];
        for &j in &ws {
            lip[j] = design.col_weighted_sq_norm(j, &h);
        }
        let sub = NewtonSubproblem {
            h: h.clone(),
            grad0: grad.clone(),
            beta_ref: beta.clone(),
            lipschitz: lip,
        };
        let mut v = beta.clone();
        let mut sub_state = vec![0.0; n]; // X(v − β), starts at 0
        let inner_tol = (opts.inner_tol_ratio * kkt_max).max(0.1 * opts.tol);
        let stats = inner_solver(
            design,
            y,
            &sub,
            penalty,
            &mut v,
            &mut sub_state,
            &ws,
            opts.max_epochs,
            inner_tol,
            opts.anderson_m,
        );
        result.n_epochs += stats.epochs;
        result.accepted_extrapolations += stats.accepted_extrapolations;
        result.rejected_extrapolations += stats.rejected_extrapolations;

        // ---- direction + decrease measure Δ = ∇fᵀd + g(β+d) − g(β) ----
        let mut delta_lin = 0.0;
        let mut moved = false;
        for &j in &ws {
            let d = v[j] - beta[j];
            if d != 0.0 {
                moved = true;
            }
            delta_lin += grad[j] * d + penalty.value(v[j], j) - penalty.value(beta[j], j);
        }
        if !moved {
            // subproblem fixed point below the KKT tolerance resolution:
            // nothing further to gain from this model
            break;
        }

        // ---- backtracking line search on the true objective ----
        // (sub_state holds Xd exactly — no extra matvec needed)
        let pen_ws0: f64 = ws.iter().map(|&j| penalty.value(beta[j], j)).sum();
        // objective = f(β) + pen_ws0 + pen_off_ws; only the first two move
        let pen_off_ws = objective - datafit.value(y, &beta, &state) - pen_ws0;
        let mut trial_beta = beta.clone();
        let mut t = 1.0f64;
        let mut accepted = false;
        for _ in 0..MAX_BACKTRACKS {
            for &j in &ws {
                trial_beta[j] =
                    if t == 1.0 { v[j] } else { beta[j] + t * (v[j] - beta[j]) };
            }
            for i in 0..n {
                trial_state[i] = state[i] + t * sub_state[i];
            }
            let f_t = datafit.value(y, &trial_beta, &trial_state);
            let pen_ws_t: f64 = ws.iter().map(|&j| penalty.value(trial_beta[j], j)).sum();
            let obj_t = f_t + pen_off_ws + pen_ws_t;
            // the noise allowance keeps the final Newton steps acceptable
            // at deep tolerances, where the true decrease (~kkt²) sits
            // below the f64 resolution of the objective itself
            let noise = 10.0 * f64::EPSILON * objective.abs().max(1.0);
            if obj_t <= objective + ARMIJO_SIGMA * t * delta_lin + noise {
                accepted = true;
                break;
            }
            t *= 0.5;
        }
        if !accepted {
            // the model step yields no decrease at any step size (numeric
            // floor); report the best point found so far
            break;
        }
        beta.copy_from_slice(&trial_beta);
        state.copy_from_slice(&trial_state);
    }

    // final metrics on the true problem
    datafit.grad_full(design, y, &state, &beta, &mut grad);
    result.kkt = (0..p)
        .map(|j| penalty.subdiff_distance(beta[j], grad[j], j))
        .fold(0.0f64, f64::max);
    result.converged = result.converged || result.kkt <= opts.tol;
    result.objective = super::cd::objective(datafit, penalty, y, &beta, &state);
    result.beta = beta;
    result
}

/// Smallest λ whose ℓ1 solution is all-zero for a prox-Newton datafit:
/// `λ_max = ‖∇f(0)‖∞ = ‖Xᵀ F'(0)‖∞` (anchors path grids; coincides with
/// `quadratic_lambda_max` for the quadratic datafit).
pub fn glm_lambda_max<D: Datafit>(prototype: &D, design: &Design, y: &[f64]) -> f64 {
    let mut f = prototype.clone();
    f.init(design, y);
    let beta0 = vec![0.0; design.ncols()];
    let state = f.init_state(design, y, &beta0);
    let mut w = vec![0.0; design.nrows()];
    f.raw_grad(y, &state, &mut w);
    let mut g = vec![0.0; design.ncols()];
    design.matvec_t(&w, &mut g);
    crate::linalg::norm_inf(&g)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{correlated, poisson_correlated, probit_correlated, CorrelatedSpec};
    use crate::datafit::{Logistic, Poisson, Probit, Quadratic};
    use crate::estimators::linear::quadratic_lambda_max;
    use crate::penalty::L1;
    use crate::solver::solve;

    #[test]
    fn quadratic_lasso_matches_direct_cd() {
        let ds = correlated(CorrelatedSpec { n: 80, p: 120, rho: 0.5, nnz: 8, snr: 10.0 }, 2);
        let lam = quadratic_lambda_max(&ds.design, &ds.y) / 10.0;
        let opts = SolverOpts::default().with_tol(1e-10);
        let mut f1 = Quadratic::new();
        let direct = solve(&ds.design, &ds.y, &mut f1, &L1::new(lam), &opts, None, None);
        let mut f2 = Quadratic::new();
        let pn = solve_prox_newton(&ds.design, &ds.y, &mut f2, &L1::new(lam), &opts, None);
        assert!(pn.converged, "kkt = {}", pn.kkt);
        assert!(
            (pn.objective - direct.objective).abs() < 1e-9,
            "{} vs {}",
            pn.objective,
            direct.objective
        );
        // constant curvature + full working set + tight inner solve ⇒ the
        // first subproblem IS the problem: one solving outer + one
        // converged-check outer
        let mut full_opts = opts.clone().without_ws();
        full_opts.inner_tol_ratio = 0.0; // inner solves straight to 0.1·tol
        let mut f3 = Quadratic::new();
        let pn_full =
            solve_prox_newton(&ds.design, &ds.y, &mut f3, &L1::new(lam), &full_opts, None);
        assert!(pn_full.converged);
        assert!(pn_full.n_outer <= 2, "took {} outer iters", pn_full.n_outer);
        assert!((pn_full.objective - direct.objective).abs() < 1e-9);
    }

    #[test]
    fn logistic_lasso_matches_direct_cd() {
        let ds = correlated(CorrelatedSpec { n: 100, p: 60, rho: 0.4, nnz: 6, snr: 10.0 }, 5);
        let y: Vec<f64> = ds.y.iter().map(|&v| if v >= 0.0 { 1.0 } else { -1.0 }).collect();
        let mut f = Logistic::new();
        f.init(&ds.design, &y);
        let state0 = f.init_state(&ds.design, &y, &vec![0.0; ds.p()]);
        let mut g0 = vec![0.0; ds.p()];
        f.grad_full(&ds.design, &y, &state0, &vec![0.0; ds.p()], &mut g0);
        let lam = crate::linalg::norm_inf(&g0) / 20.0;
        let opts = SolverOpts::default().with_tol(1e-9);
        let mut f1 = Logistic::new();
        let direct = solve(&ds.design, &y, &mut f1, &L1::new(lam), &opts, None, None);
        let mut f2 = Logistic::new();
        let pn = solve_prox_newton(&ds.design, &y, &mut f2, &L1::new(lam), &opts, None);
        assert!(pn.converged, "kkt = {}", pn.kkt);
        assert!(
            (pn.objective - direct.objective).abs() < 1e-8,
            "{} vs {}",
            pn.objective,
            direct.objective
        );
    }

    #[test]
    fn poisson_lasso_converges_and_is_sparse() {
        let ds = poisson_correlated(
            CorrelatedSpec { n: 150, p: 300, rho: 0.4, nnz: 8, snr: 0.0 },
            7,
        );
        let lam = glm_lambda_max(&Poisson::new(), &ds.design, &ds.y) / 20.0;
        let mut f = Poisson::new();
        let pn = solve_prox_newton(
            &ds.design,
            &ds.y,
            &mut f,
            &L1::new(lam),
            &SolverOpts::default().with_tol(1e-9),
            None,
        );
        assert!(pn.converged, "kkt = {}", pn.kkt);
        assert!(!pn.support().is_empty());
        assert!(pn.support().len() < 150, "solution should be sparse");
        // line-searched outer objective never increases
        for w in pn.history.windows(2) {
            assert!(w[1].objective <= w[0].objective + 1e-12);
        }
    }

    #[test]
    fn probit_lasso_matches_direct_cd() {
        // probit curvature is globally < 1, so direct CD is also valid:
        // the two topologies must land on the same optimum
        let ds = probit_correlated(
            CorrelatedSpec { n: 120, p: 80, rho: 0.4, nnz: 6, snr: 0.0 },
            11,
        );
        let lam = glm_lambda_max(&Probit::new(), &ds.design, &ds.y) / 10.0;
        let opts = SolverOpts::default().with_tol(1e-9);
        let mut f1 = Probit::new();
        let direct = solve(&ds.design, &ds.y, &mut f1, &L1::new(lam), &opts, None, None);
        let mut f2 = Probit::new();
        let pn = solve_prox_newton(&ds.design, &ds.y, &mut f2, &L1::new(lam), &opts, None);
        assert!(pn.converged && direct.converged);
        assert!(
            (pn.objective - direct.objective).abs() < 1e-8,
            "{} vs {}",
            pn.objective,
            direct.objective
        );
    }

    #[test]
    fn poisson_lambda_max_gives_zero_solution() {
        let ds = poisson_correlated(
            CorrelatedSpec { n: 80, p: 60, rho: 0.3, nnz: 5, snr: 0.0 },
            3,
        );
        let lam = glm_lambda_max(&Poisson::new(), &ds.design, &ds.y) * 1.001;
        let mut f = Poisson::new();
        let pn = solve_prox_newton(
            &ds.design,
            &ds.y,
            &mut f,
            &L1::new(lam),
            &SolverOpts::default(),
            None,
        );
        assert!(pn.support().is_empty(), "beta must be 0 at lambda_max");
        assert_eq!(pn.n_outer, 1, "should stop immediately");
    }

    #[test]
    fn continuation_state_threads_through_a_poisson_path() {
        let ds = poisson_correlated(
            CorrelatedSpec { n: 100, p: 80, rho: 0.4, nnz: 6, snr: 0.0 },
            13,
        );
        let lam_max = glm_lambda_max(&Poisson::new(), &ds.design, &ds.y);
        let mut state = ContinuationState::default();
        let opts = SolverOpts::default().with_tol(1e-9);
        let mut f = Poisson::new();
        let first = solve_prox_newton_continued(
            &ds.design, &ds.y, &mut f, &L1::new(lam_max / 5.0), &opts, &mut state, None,
        );
        assert!(first.converged);
        assert!(state.beta.is_some() && state.ws_size.is_some());
        let mut f2 = Poisson::new();
        let warm = solve_prox_newton_continued(
            &ds.design, &ds.y, &mut f2, &L1::new(lam_max / 10.0), &opts, &mut state, None,
        );
        let mut f3 = Poisson::new();
        let cold = solve_prox_newton(
            &ds.design, &ds.y, &mut f3, &L1::new(lam_max / 10.0), &opts, None,
        );
        assert!(warm.converged);
        assert!(
            (warm.objective - cold.objective).abs() < 1e-8,
            "{} vs {}",
            warm.objective,
            cold.objective
        );
        assert!(warm.n_epochs <= cold.n_epochs, "warm {} vs cold {}", warm.n_epochs, cold.n_epochs);
    }
}
