//! Coordinate-descent epoch (paper Algorithm 3).
//!
//! One pass of cyclic proximal CD over the working set:
//!
//! ```text
//! for j in ws:
//!     β_j ← prox_{g_j/L_j}( β_j − ∇_j f(β)/L_j )
//!     state-update (e.g. residual += (β_j − β_old)·X[:,j])
//! ```
//!
//! This is the innermost hot loop of the whole system; it allocates
//! nothing and touches only the working-set columns.

use crate::datafit::Datafit;
use crate::linalg::Design;
use crate::penalty::Penalty;

/// How a CD epoch obtains per-coordinate gradients and propagates
/// committed moves. The epoch loop itself ([`cd_epoch_core`]) is written
/// once; the **residual** backend ([`ResidualEpoch`]) recomputes the
/// gradient from the datafit state with two O(n) column passes per
/// update, the **Gram** backend (`solver::gram`) maintains the packed
/// working-set gradient at O(|ws|) per update.
pub trait EpochState {
    /// `∇f` at working-set position `pos` (design column `j`).
    fn grad(&mut self, pos: usize, j: usize, beta: &[f64]) -> f64;

    /// Propagate the committed move `beta[j] += delta`.
    fn commit(&mut self, pos: usize, j: usize, delta: f64);
}

/// The residual-domain backend: gradients via [`Datafit::grad_j`]
/// (one column dot), propagation via [`Datafit::update_state`] (one
/// column axpy).
pub struct ResidualEpoch<'a, D: Datafit> {
    pub design: &'a Design,
    pub y: &'a [f64],
    pub datafit: &'a D,
    pub state: &'a mut [f64],
}

impl<D: Datafit> EpochState for ResidualEpoch<'_, D> {
    #[inline]
    fn grad(&mut self, _pos: usize, j: usize, beta: &[f64]) -> f64 {
        self.datafit.grad_j(self.design, self.y, self.state, beta, j)
    }

    #[inline]
    fn commit(&mut self, _pos: usize, j: usize, delta: f64) {
        self.datafit.update_state(self.design, j, delta, self.state);
    }
}

/// The one cyclic-CD epoch (paper Algorithm 3), direction-generic and
/// backend-generic — used by both the residual and Gram inner engines.
/// `reverse = true` sweeps p→1 (Proposition 13's Anderson rate needs
/// symmetric sweeps, so the inner solvers alternate directions). Returns
/// the largest coordinate move `max_j L_j·|Δβ_j|` (the cheap stationarity
/// surrogate used between full score evaluations).
pub fn cd_epoch_core<P: Penalty, S: EpochState>(
    penalty: &P,
    lipschitz: &[f64],
    beta: &mut [f64],
    ws: &[usize],
    reverse: bool,
    st: &mut S,
) -> f64 {
    let m = ws.len();
    let mut max_move = 0.0f64;
    for step in 0..m {
        let pos = if reverse { m - 1 - step } else { step };
        let j = ws[pos];
        let lj = lipschitz[j];
        if lj == 0.0 {
            continue; // empty column: g_j alone keeps β_j at its prox-fixed point
        }
        let old = beta[j];
        let grad = st.grad(pos, j, beta);
        let new = penalty.prox(old - grad / lj, 1.0 / lj, j);
        if new != old {
            beta[j] = new;
            st.commit(pos, j, new - old);
            max_move = max_move.max(lj * (new - old).abs());
        }
    }
    max_move
}

/// Run one forward (1→p) residual-domain CD epoch over `ws`.
pub fn cd_epoch<D: Datafit, P: Penalty>(
    design: &Design,
    y: &[f64],
    datafit: &D,
    penalty: &P,
    beta: &mut [f64],
    state: &mut [f64],
    ws: &[usize],
) -> f64 {
    let mut st = ResidualEpoch { design, y, datafit, state };
    cd_epoch_core(penalty, datafit.lipschitz(), beta, ws, false, &mut st)
}

/// Reverse-order (p→1) residual-domain epoch.
pub fn cd_epoch_rev<D: Datafit, P: Penalty>(
    design: &Design,
    y: &[f64],
    datafit: &D,
    penalty: &P,
    beta: &mut [f64],
    state: &mut [f64],
    ws: &[usize],
) -> f64 {
    let mut st = ResidualEpoch { design, y, datafit, state };
    cd_epoch_core(penalty, datafit.lipschitz(), beta, ws, true, &mut st)
}

/// Objective Φ(β) = f(β) + Σ g_j(β_j).
pub fn objective<D: Datafit, P: Penalty>(
    datafit: &D,
    penalty: &P,
    y: &[f64],
    beta: &[f64],
    state: &[f64],
) -> f64 {
    datafit.value(y, beta, state) + penalty.value_sum(beta)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datafit::Quadratic;
    use crate::linalg::DenseMatrix;
    use crate::penalty::L1;

    fn problem() -> (Design, Vec<f64>) {
        let x = DenseMatrix::from_rows(&[
            vec![1.0, 0.3, -0.5],
            vec![-0.2, 1.1, 0.4],
            vec![0.7, -0.6, 1.2],
            vec![0.1, 0.8, -0.9],
        ]);
        let y = vec![1.0, -0.5, 0.8, 0.2];
        (x.into(), y)
    }

    #[test]
    fn epoch_decreases_objective() {
        let (d, y) = problem();
        let mut f = Quadratic::new();
        f.init(&d, &y);
        let pen = L1::new(0.05);
        let mut beta = vec![0.0; 3];
        let mut state = f.init_state(&d, &y, &beta);
        let ws: Vec<usize> = (0..3).collect();
        let mut prev = objective(&f, &pen, &y, &beta, &state);
        for _ in 0..10 {
            cd_epoch(&d, &y, &f, &pen, &mut beta, &mut state, &ws);
            let cur = objective(&f, &pen, &y, &beta, &state);
            assert!(cur <= prev + 1e-12, "objective increased: {prev} -> {cur}");
            prev = cur;
        }
    }

    #[test]
    fn epoch_converges_to_kkt_point() {
        let (d, y) = problem();
        let mut f = Quadratic::new();
        f.init(&d, &y);
        let pen = L1::new(0.05);
        let mut beta = vec![0.0; 3];
        let mut state = f.init_state(&d, &y, &beta);
        let ws: Vec<usize> = (0..3).collect();
        for _ in 0..500 {
            cd_epoch(&d, &y, &f, &pen, &mut beta, &mut state, &ws);
        }
        for j in 0..3 {
            let g = f.grad_j(&d, &y, &state, &beta, j);
            assert!(
                pen.subdiff_distance(beta[j], g, j) < 1e-10,
                "KKT violated at {j}"
            );
        }
    }

    #[test]
    fn restricted_epoch_leaves_other_coords_untouched() {
        let (d, y) = problem();
        let mut f = Quadratic::new();
        f.init(&d, &y);
        let pen = L1::new(0.01);
        let mut beta = vec![0.0; 3];
        let mut state = f.init_state(&d, &y, &beta);
        cd_epoch(&d, &y, &f, &pen, &mut beta, &mut state, &[1]);
        assert_eq!(beta[0], 0.0);
        assert_eq!(beta[2], 0.0);
        assert!(beta[1] != 0.0);
    }

    #[test]
    fn forward_and_reverse_agree_at_fixed_point() {
        let (d, y) = problem();
        let mut f = Quadratic::new();
        f.init(&d, &y);
        let pen = L1::new(0.05);
        let mut beta = vec![0.0; 3];
        let mut state = f.init_state(&d, &y, &beta);
        let ws: Vec<usize> = (0..3).collect();
        for _ in 0..500 {
            cd_epoch(&d, &y, &f, &pen, &mut beta, &mut state, &ws);
        }
        let before = beta.clone();
        let mv = cd_epoch_rev(&d, &y, &f, &pen, &mut beta, &mut state, &ws);
        assert!(mv < 1e-10);
        for (a, b) in before.iter().zip(beta.iter()) {
            assert!((a - b).abs() < 1e-10);
        }
    }
}
