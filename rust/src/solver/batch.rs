//! Batched many-fit engine (FaSTGLZ): solve `B` sibling quadratic fits on
//! **one** design simultaneously, so every read of `X` is amortized over
//! all `B` fits.
//!
//! The members of a batch share the design and target but differ in
//! penalty (λ, family), row weights (CV folds as 0/1 masks) and warm
//! start. Their residuals live side by side in a column-major `n × B`
//! **panel**; the outer scoring pass — the O(n·p) hot spot — becomes one
//! multi-RHS `XᵀR` panel kernel ([`Design::matmul_t`]) instead of `B`
//! separate `Xᵀr` passes, and the inner CD epochs interleave the members
//! column-by-column so each working-set column is loaded once per sweep
//! for all members ([`Design::col_axpy_panel`] commits the deltas).
//!
//! Parity contract (tested): every member follows **exactly** the scalar
//! solver's trajectory — same summation orders in the panel kernels, same
//! CD update arithmetic, same Anderson proposals and guards, same gated
//! stationarity checks — so an unmasked member's `beta` is bit-identical
//! to a standalone [`super::skglm::solve`] at the same options, and the
//! whole batch is bit-identical across kernel thread counts.
//!
//! Retirement: members leave the batch independently — when their KKT
//! certificate passes, their `JobCtl` cancel flag is raised, or their
//! deadline expires (deadline partials). A retiring member's panel column
//! is swap-removed, shrinking every subsequent panel pass; the rest of
//! the batch is never aborted.

use super::anderson::Anderson;
use super::cd;
use super::gram::{gram_inner_solver, EngineDispatch, InnerEngine};
use super::inner::{
    coordinate_scores_into, gather, try_accept, ws_score_max, InnerProfile, InnerStats,
    FORCE_CHECK_EVERY,
};
use super::outer::{select_working_set, solve_outer, BlockCoords};
use super::skglm::{Certificate, FitResult, HistoryPoint, SolverOpts, StopReason};
use crate::datafit::Datafit;
use crate::linalg::gram::GramCache;
use crate::linalg::simd::{self, Precision, ShadowF32};
use crate::linalg::Design;
use crate::penalty::{BatchPenalty, Penalty};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Instant;

/// Quadratic datafit with optional per-row weights — the member datafit
/// of the batched engine. With `weights = None` it reproduces
/// [`crate::datafit::Quadratic`] **bitwise** (same Lipschitz pass, same
/// state arithmetic). With 0/1 weights it is the fold-restricted loss
/// `‖w ⊙ (Xβ − y)‖² / (2·Σw)`: masked rows stay exactly zero in the
/// state, so a masked fit on the full design follows the same iterates as
/// a scalar fit on the row-subset design (up to column-norm summation
/// order).
#[derive(Clone, Debug)]
pub struct MaskedQuadratic {
    lipschitz: Vec<f64>,
    inv_n: f64,
    weights: Option<Arc<Vec<f64>>>,
}

impl MaskedQuadratic {
    pub fn new(weights: Option<Arc<Vec<f64>>>) -> Self {
        Self { lipschitz: Vec::new(), inv_n: 0.0, weights }
    }

    /// `1/n_eff` — the gradient scale the batched scoring pass applies to
    /// the raw panel dot products.
    #[inline]
    pub fn inv_n(&self) -> f64 {
        self.inv_n
    }

    #[inline]
    pub fn is_masked(&self) -> bool {
        self.weights.is_some()
    }
}

impl Datafit for MaskedQuadratic {
    fn init(&mut self, design: &Design, y: &[f64]) {
        assert_eq!(design.nrows(), y.len());
        match &self.weights {
            None => {
                // exact Quadratic::init arithmetic
                let n = design.nrows() as f64;
                self.inv_n = 1.0 / n;
                self.lipschitz = design.col_sq_norms().iter().map(|s| s / n).collect();
            }
            Some(w) => {
                assert_eq!(w.len(), design.nrows());
                let n_eff: f64 = w.iter().sum();
                assert!(n_eff > 0.0, "row weights must keep at least one row");
                self.inv_n = 1.0 / n_eff;
                self.lipschitz = (0..design.ncols())
                    .map(|j| design.col_weighted_sq_norm(j, w) / n_eff)
                    .collect();
            }
        }
    }

    fn init_cached(&mut self, design: &Design, y: &[f64], col_sq_norms: Option<&[f64]>) {
        match (&self.weights, col_sq_norms) {
            (None, Some(norms)) => {
                // exact Quadratic::init_cached arithmetic
                assert_eq!(design.nrows(), y.len());
                assert_eq!(norms.len(), design.ncols());
                let n = design.nrows() as f64;
                self.inv_n = 1.0 / n;
                self.lipschitz = norms.iter().map(|s| s / n).collect();
            }
            // masked members can't reuse unweighted norms
            _ => self.init(design, y),
        }
    }

    fn lipschitz(&self) -> &[f64] {
        &self.lipschitz
    }

    /// State = `w ⊙ (Xβ − y)` (plain residual when unmasked).
    fn init_state(&self, design: &Design, y: &[f64], beta: &[f64]) -> Vec<f64> {
        let mut s = vec![0.0; design.nrows()];
        design.matvec(beta, &mut s);
        for (r, &yi) in s.iter_mut().zip(y.iter()) {
            *r -= yi;
        }
        if let Some(w) = &self.weights {
            for (r, &wi) in s.iter_mut().zip(w.iter()) {
                *r *= wi;
            }
        }
        s
    }

    #[inline]
    fn update_state(&self, design: &Design, j: usize, delta: f64, state: &mut [f64]) {
        match &self.weights {
            None => design.col_axpy(j, delta, state),
            Some(w) => design.col_axpy_weighted(j, delta, w, state),
        }
    }

    fn value(&self, _y: &[f64], _beta: &[f64], state: &[f64]) -> f64 {
        0.5 * self.inv_n * crate::linalg::sq_nrm2(state)
    }

    #[inline]
    fn grad_j(&self, design: &Design, _y: &[f64], state: &[f64], _beta: &[f64], j: usize) -> f64 {
        // masked rows are zero in the state, so no mask is needed here
        self.inv_n * design.col_dot(j, state)
    }

    fn grad_full(
        &self,
        design: &Design,
        _y: &[f64],
        state: &[f64],
        _beta: &[f64],
        out: &mut [f64],
    ) {
        design.matvec_t(state, out);
        for g in out.iter_mut() {
            *g *= self.inv_n;
        }
    }

    fn name(&self) -> &'static str {
        "quadratic"
    }

    /// The Gram engine's recursion maintains `g += δ·c·(XᵀX)_row`, which
    /// is only exact for the **unweighted** residual — masked members must
    /// stay on the residual engine (documented fusion rule).
    fn residual_quadratic_scale(&self) -> Option<f64> {
        match &self.weights {
            None => Some(self.inv_n),
            Some(_) => None,
        }
    }
}

/// One member of a batch: its penalty (λ included), optional 0/1 row
/// weights (CV folds), warm start, and per-member controls (a scheduler
/// `JobCtl`'s cancel flag / deadline — retirement granularity).
#[derive(Clone, Debug, Default)]
pub struct BatchFit {
    pub penalty: Option<BatchPenalty>,
    pub row_weights: Option<Arc<Vec<f64>>>,
    pub beta0: Option<Vec<f64>>,
    pub ws0: Option<usize>,
    pub cancel: Option<Arc<AtomicBool>>,
    pub deadline: Option<Instant>,
}

impl BatchFit {
    pub fn new(penalty: BatchPenalty) -> Self {
        Self { penalty: Some(penalty), ..Default::default() }
    }

    pub fn with_row_weights(mut self, w: Arc<Vec<f64>>) -> Self {
        self.row_weights = Some(w);
        self
    }

    /// Warm start (λ-grid continuation): previous β and working-set size.
    pub fn warm(mut self, beta0: Vec<f64>, ws0: Option<usize>) -> Self {
        self.beta0 = Some(beta0);
        self.ws0 = ws0;
        self
    }

    pub fn with_cancel(mut self, flag: Arc<AtomicBool>) -> Self {
        self.cancel = Some(flag);
        self
    }

    pub fn with_deadline(mut self, deadline: Instant) -> Self {
        self.deadline = Some(deadline);
        self
    }
}

/// Per-member outcome: the scalar-equivalent [`FitResult`] plus why the
/// member stopped early, if it did (`None` = ran to its own certificate
/// or to the shared outer-iteration limit).
#[derive(Clone, Debug)]
pub struct BatchMemberResult {
    pub result: FitResult,
    pub stopped: Option<StopReason>,
}

/// Outcome of a batched solve: per-member results in input order plus
/// batch-level attribution (the panel-kernel share lives in
/// `profile.panel_flops`).
#[derive(Clone, Debug)]
pub struct BatchOutcome {
    pub members: Vec<BatchMemberResult>,
    /// outer iterations of the shared batch loop
    pub n_outer: usize,
    /// whole-batch profile: merged member inner profiles + outer panel
    /// passes
    pub profile: InnerProfile,
}

/// Per-member λ_max via **one** multi-RHS panel pass: column `c` of the
/// panel is `w_c ⊙ y` and the anchor is `max_j |X_jᵀ(w_c ⊙ y)| / Σw_c`
/// (`w = 1` when unmasked — the usual `max|Xᵀy|/n`). This is the batched
/// CV path's per-fold leakage-safe λ_max computation.
/// Is many-fit batching enabled for this process? Reads `SKGLM_BATCH`
/// (also set by the `--batch` CLI flag): unset or anything but
/// `0`/`off`/`false` means **on**. Each batch member is bit-identical to
/// the scalar solver, so the switch exists for A/B benchmarking and
/// incident bisection, not correctness.
pub fn batching_enabled() -> bool {
    match std::env::var("SKGLM_BATCH") {
        Ok(v) => {
            let v = v.trim().to_ascii_lowercase();
            !(v == "0" || v == "off" || v == "false")
        }
        Err(_) => true,
    }
}

pub fn batch_lambda_max(
    design: &Design,
    y: &[f64],
    weights: &[Option<Arc<Vec<f64>>>],
) -> Vec<f64> {
    let n = design.nrows();
    let p = design.ncols();
    let b = weights.len();
    if b == 0 {
        return Vec::new();
    }
    let mut panel = vec![0.0; n * b];
    let mut n_eff = vec![0.0f64; b];
    for (c, w) in weights.iter().enumerate() {
        let col = &mut panel[c * n..(c + 1) * n];
        match w {
            None => {
                col.copy_from_slice(y);
                n_eff[c] = n as f64;
            }
            Some(w) => {
                assert_eq!(w.len(), n);
                for (ci, (&wi, &yi)) in col.iter_mut().zip(w.iter().zip(y.iter())) {
                    *ci = wi * yi;
                }
                n_eff[c] = w.iter().sum();
                assert!(n_eff[c] > 0.0, "row weights must keep at least one row");
            }
        }
    }
    let mut xty = vec![0.0; p * b];
    design.matmul_t(&panel, b, &mut xty);
    (0..b)
        .map(|c| {
            let mut m = 0.0f64;
            for j in 0..p {
                m = m.max(xty[j * b + c].abs());
            }
            m / n_eff[c]
        })
        .collect()
}

/// Internal per-member solver state.
struct Member {
    penalty: BatchPenalty,
    datafit: MaskedQuadratic,
    beta: Vec<f64>,
    /// working set selected by this member's last scoring pass
    ws: Vec<usize>,
    ws_size: usize,
    inner_tol: f64,
    dispatch: EngineDispatch,
    cancel: Option<Arc<AtomicBool>>,
    deadline: Option<Instant>,
    history: Vec<HistoryPoint>,
    n_outer: usize,
    n_epochs: usize,
    accepted: usize,
    rejected: usize,
    profile: InnerProfile,
    /// per-feature score scratch (clobbered by selection)
    scores: Vec<f64>,
    done: Option<BatchMemberResult>,
}

/// The batched [`BlockCoords`] instantiation driven by the shared
/// [`solve_outer`] loop. `live` maps panel slots to member indices; a
/// member's residual/state is the panel column of its slot.
struct BatchedCoords<'a> {
    design: &'a Design,
    y: &'a [f64],
    tol: f64,
    inner_tol_ratio: f64,
    use_ws: bool,
    members: Vec<Member>,
    /// slot → member index (panel column order); retirement swap-removes
    live: Vec<usize>,
    /// column-major n × live.len() residual panel
    panel: Vec<f64>,
    /// feature-major p × live.len() panel-gradient scratch
    grads: Vec<f64>,
    /// union-membership mask over features (the outer working set)
    in_union: Vec<bool>,
    all_features: Vec<usize>,
    gram: Option<Arc<GramCache>>,
    start: Instant,
    /// batch-level extras not attributable to one member (panel passes)
    profile: InnerProfile,
    /// panel-pass precision; reduced modes route the multi-RHS scan
    /// through `shadow` (dense designs only)
    precision: Precision,
    /// f32 design mirror for reduced-precision panel passes
    shadow: Option<ShadowF32>,
    /// f32 residual-panel scratch for reduced-precision passes
    panel32: Vec<f32>,
}

/// Per-member context for one interleaved residual inner solve.
struct ResCtx {
    slot: usize,
    member: usize,
    ws: Vec<usize>,
    /// membership of `union[pos]` in this member's ws
    ws_mask: Vec<bool>,
    accel: Option<Anderson>,
    ws_beta: Vec<f64>,
    state_snaps: Vec<Vec<f64>>,
    epochs_since_check: usize,
    epoch_flops: f64,
    max_move: f64,
    stats: InnerStats,
}

fn push_snap(snaps: &mut Vec<Vec<f64>>, state: &[f64], cap: usize) {
    if snaps.len() == cap {
        snaps.remove(0);
    }
    snaps.push(state.to_vec());
}

impl BatchedCoords<'_> {
    /// Retire members whose cancel flag is raised or deadline has passed
    /// — the per-fit-retirement granularity of `JobCtl` honoring.
    /// Descending slot order keeps swap-remove indices valid.
    fn retire_stopped(&mut self) {
        let mut slot = self.live.len();
        while slot > 0 {
            slot -= 1;
            let m = &self.members[self.live[slot]];
            let reason = if m
                .cancel
                .as_ref()
                .map(|c| c.load(Ordering::Relaxed))
                .unwrap_or(false)
            {
                Some(StopReason::Cancelled)
            } else if m.deadline.map(|d| Instant::now() >= d).unwrap_or(false) {
                Some(StopReason::Deadline)
            } else {
                None
            };
            if reason.is_some() {
                self.retire_slot(slot, reason, false);
            }
        }
    }

    /// Finalize a member: compute the scalar-identical final certificate
    /// (full [`coordinate_scores_into`] pass — exactly the scalar
    /// `final_kkt`), record its [`FitResult`], and free its panel column
    /// by swap-removing the slot. `score_converged` mirrors the scalar
    /// loop's converged-by-scoring-pass break: the final certificate may
    /// land a hair above tol (different summation order) and the fit
    /// still counts as converged.
    fn retire_slot(&mut self, slot: usize, stopped: Option<StopReason>, score_converged: bool) {
        let n = self.design.nrows();
        let mi = self.live[slot];
        {
            let m = &mut self.members[mi];
            let state = &self.panel[slot * n..(slot + 1) * n];
            let t_score = Instant::now();
            let mut fs = vec![0.0; self.all_features.len()];
            coordinate_scores_into(
                self.design,
                self.y,
                &m.datafit,
                &m.penalty,
                &m.beta,
                state,
                &self.all_features,
                &mut fs,
            );
            let kkt = fs.iter().fold(0.0f64, |a, &s| a.max(s));
            m.profile.score_secs += t_score.elapsed().as_secs_f64();
            let objective = cd::objective(&m.datafit, &m.penalty, self.y, &m.beta, state);
            m.done = Some(BatchMemberResult {
                result: FitResult {
                    beta: std::mem::take(&mut m.beta),
                    objective,
                    kkt,
                    certificate: Certificate::Stationarity,
                    n_outer: m.n_outer,
                    n_epochs: m.n_epochs,
                    converged: score_converged || kkt <= self.tol,
                    history: std::mem::take(&mut m.history),
                    accepted_extrapolations: m.accepted,
                    rejected_extrapolations: m.rejected,
                    profile: m.profile,
                },
                stopped,
            });
        }
        // free the member's panel column: move the last column into the
        // vacated slot (mirrors Vec::swap_remove on `live`)
        let b = self.live.len();
        if slot != b - 1 {
            let (head, tail) = self.panel.split_at_mut((b - 1) * n);
            head[slot * n..(slot + 1) * n].copy_from_slice(&tail[..n]);
        }
        self.live.swap_remove(slot);
        self.panel.truncate((b - 1) * n);
    }

    /// Retire every remaining live member (budget stop / outer-limit
    /// exhaustion) so each gets a well-formed partial result.
    fn finalize(&mut self, stopped: Option<StopReason>) {
        while !self.live.is_empty() {
            let slot = self.live.len() - 1;
            self.retire_slot(slot, stopped, false);
        }
    }

    /// Interleaved residual inner solve: one CD epoch sweeps the
    /// working-set **union** column by column, applying every active
    /// member's update for that column before moving on — each design
    /// column is read once per sweep for the whole batch, and unmasked
    /// members' deltas are committed with one panel axpy. Per member the
    /// update order, Anderson schedule and gated checks are exactly
    /// [`super::inner::inner_solver`]'s.
    fn residual_inner(
        &mut self,
        union: &[usize],
        res_slots: &[usize],
        opts: &SolverOpts,
    ) -> Vec<InnerStats> {
        let design = self.design;
        let y = self.y;
        let n = design.nrows();
        let snap_cap = opts.anderson_m + 1;

        // per-member contexts
        let mut ctxs: Vec<ResCtx> = Vec::with_capacity(res_slots.len());
        for &slot in res_slots {
            let mi = self.live[slot];
            let m = &self.members[mi];
            let ws = m.ws.clone();
            // ws ⊆ union (both sorted): mark membership per union position
            let mut ws_mask = vec![false; union.len()];
            let mut k = 0usize;
            for (pos, &j) in union.iter().enumerate() {
                if k < ws.len() && ws[k] == j {
                    ws_mask[pos] = true;
                    k += 1;
                }
            }
            debug_assert_eq!(k, ws.len(), "member ws must be a subset of the union");
            let mut ctx = ResCtx {
                slot,
                member: mi,
                epoch_flops: 2.0 * design.subset_stored_entries(&ws) as f64,
                ws_beta: vec![0.0; ws.len()],
                ws,
                ws_mask,
                accel: if opts.anderson_m >= 2 { Some(Anderson::new(opts.anderson_m)) } else { None },
                state_snaps: Vec::new(),
                epochs_since_check: 0,
                max_move: 0.0,
                stats: InnerStats::default(),
            };
            // seed the Anderson buffer with the entry point
            if let Some(acc) = ctx.accel.as_mut() {
                gather(&self.members[mi].beta, &ctx.ws, &mut ctx.ws_beta);
                acc.push(&ctx.ws_beta);
                push_snap(&mut ctx.state_snaps, &self.panel[slot * n..(slot + 1) * n], snap_cap);
            }
            ctxs.push(ctx);
        }

        let mut active: Vec<usize> = (0..ctxs.len()).collect();
        // per-slot delta scratch for the panel axpy commit
        let mut deltas = vec![0.0f64; self.live.len()];

        for epoch in 1..=opts.max_epochs {
            if active.is_empty() {
                break;
            }
            let t_epoch = Instant::now();
            let reverse = epoch % 2 == 0;
            for ci in &active {
                ctxs[*ci].max_move = 0.0;
            }
            // ---- one interleaved CD sweep over the union ----
            for pos in 0..union.len() {
                let upos = if reverse { union.len() - 1 - pos } else { pos };
                let j = union[upos];
                let mut touched = false;
                for &ci in &active {
                    let ctx = &mut ctxs[ci];
                    if !ctx.ws_mask[upos] {
                        continue;
                    }
                    let s = ctx.slot;
                    let m = &mut self.members[ctx.member];
                    let lj = m.datafit.lipschitz()[j];
                    if lj == 0.0 {
                        continue;
                    }
                    let old = m.beta[j];
                    let grad = {
                        let state = &self.panel[s * n..(s + 1) * n];
                        m.datafit.grad_j(design, y, state, &m.beta, j)
                    };
                    let new = m.penalty.prox(old - grad / lj, 1.0 / lj, j);
                    if new != old {
                        m.beta[j] = new;
                        let delta = new - old;
                        if m.datafit.is_masked() {
                            // masked commits need the row weights
                            let state = &mut self.panel[s * n..(s + 1) * n];
                            m.datafit.update_state(design, j, delta, state);
                        } else {
                            deltas[s] = delta;
                            touched = true;
                        }
                        ctx.max_move = ctx.max_move.max(lj * delta.abs());
                    }
                }
                if touched {
                    // one column read commits every unmasked member's move
                    design.col_axpy_panel(j, &deltas, &mut self.panel);
                    for d in deltas.iter_mut() {
                        *d = 0.0;
                    }
                }
            }
            let epoch_share = t_epoch.elapsed().as_secs_f64() / active.len() as f64;

            // ---- per-member epoch end: Anderson + gated checks ----
            let mut idx = active.len();
            while idx > 0 {
                idx -= 1;
                let ci = active[idx];
                let ctx = &mut ctxs[ci];
                let m = &mut self.members[ctx.member];
                let s = ctx.slot;
                ctx.stats.epochs = epoch;
                ctx.stats.profile.epoch_secs += epoch_share;
                ctx.stats.profile.epoch_flops += ctx.epoch_flops;
                ctx.stats.profile.residual_epochs += 1;

                if let Some(acc) = ctx.accel.as_mut() {
                    let t_extr = Instant::now();
                    gather(&m.beta, &ctx.ws, &mut ctx.ws_beta);
                    let full = acc.push(&ctx.ws_beta);
                    push_snap(&mut ctx.state_snaps, &self.panel[s * n..(s + 1) * n], snap_cap);
                    if full && epoch % acc.m() == 0 {
                        if let Some(c) = acc.coefficients() {
                            let extr = acc.combine(&c);
                            // state is affine in β: combine snapshots
                            let trial_state = acc.combine_series(&c, &ctx.state_snaps);
                            let state = &mut self.panel[s * n..(s + 1) * n];
                            if try_accept(
                                &m.datafit, &m.penalty, y, &mut m.beta, state, &ctx.ws, &extr,
                                &trial_state,
                            ) {
                                ctx.stats.accepted_extrapolations += 1;
                                acc.clear();
                                ctx.state_snaps.clear();
                                gather(&m.beta, &ctx.ws, &mut ctx.ws_beta);
                                acc.push(&ctx.ws_beta);
                                push_snap(
                                    &mut ctx.state_snaps,
                                    &self.panel[s * n..(s + 1) * n],
                                    snap_cap,
                                );
                            } else {
                                ctx.stats.rejected_extrapolations += 1;
                            }
                        }
                    }
                    ctx.stats.profile.extrapolation_secs += t_extr.elapsed().as_secs_f64();
                }

                ctx.epochs_since_check += 1;
                let due = ctx.max_move <= m.inner_tol
                    || ctx.epochs_since_check >= FORCE_CHECK_EVERY
                    || epoch == opts.max_epochs;
                if due {
                    ctx.epochs_since_check = 0;
                    ctx.stats.score_checks += 1;
                    let t_score = Instant::now();
                    let state = &self.panel[s * n..(s + 1) * n];
                    let score =
                        ws_score_max(design, y, &m.datafit, &m.penalty, &m.beta, state, &ctx.ws);
                    ctx.stats.profile.score_secs += t_score.elapsed().as_secs_f64();
                    ctx.stats.profile.epoch_flops += ctx.epoch_flops / 2.0;
                    ctx.stats.ws_score = score;
                    if score <= m.inner_tol {
                        active.remove(idx);
                    }
                }
            }
        }

        ctxs.into_iter().map(|c| c.stats).collect()
    }
}

impl BlockCoords for BatchedCoords<'_> {
    fn n_blocks(&self) -> usize {
        self.design.ncols()
    }

    fn score_pass(&mut self, scores: &mut [f64]) -> f64 {
        // per-fit JobCtl honoring happens at retirement granularity: a
        // cancelled/expired member frees its panel column here, before
        // the batch pays for another panel pass over it
        self.retire_stopped();
        let design = self.design;
        let n = design.nrows();
        let p = design.ncols();
        let b = self.live.len();
        let mut kkt_live = 0.0f64;
        if b > 0 {
            // ---- ONE multi-RHS panel pass for all live members ----
            self.grads.clear();
            self.grads.resize(p * b, 0.0);
            if let Some(shadow) = &self.shadow {
                simd::to_f32(&self.panel[..n * b], &mut self.panel32);
                let prec = self.precision;
                simd::shadow_matmul_t(shadow, &self.panel32, b, prec, &mut self.grads);
            } else {
                design.matmul_t(&self.panel[..n * b], b, &mut self.grads);
            }
            let se = design.stored_entries() as f64;
            self.profile.panel_flops += se * b as f64;

            let mut retire: Vec<usize> = Vec::new();
            for s in 0..b {
                let mi = self.live[s];
                let m = &mut self.members[mi];
                m.n_outer += 1;
                m.profile.panel_flops += se;
                // exact scalar score arithmetic on this member's gradient
                // column (grads[j·b + s] · inv_n ≡ the scalar grad_full)
                let inv_n = m.datafit.inv_n();
                let mut kkt = 0.0f64;
                for j in 0..p {
                    let lj = m.datafit.lipschitz()[j];
                    let sc = if lj == 0.0 {
                        0.0
                    } else {
                        let g = self.grads[j * b + s] * inv_n;
                        if m.penalty.use_cd_score() {
                            (m.beta[j] - m.penalty.prox(m.beta[j] - g / lj, 1.0 / lj, j)).abs()
                        } else {
                            m.penalty.subdiff_distance(m.beta[j], g, j)
                        }
                    };
                    m.scores[j] = sc;
                    kkt = kkt.max(sc);
                }
                let state = &self.panel[s * n..(s + 1) * n];
                let objective = cd::objective(&m.datafit, &m.penalty, self.y, &m.beta, state);
                m.history.push(HistoryPoint {
                    t: self.start.elapsed().as_secs_f64(),
                    objective,
                    kkt,
                    ws_size: if self.use_ws { m.ws_size.min(p) } else { p },
                });
                if kkt <= self.tol {
                    retire.push(s); // certificate passed: retire
                    continue;
                }
                // per-member working-set growth + selection (scalar rules)
                if self.use_ws {
                    let gsupp = (0..p).filter(|&j| m.penalty.in_gsupp(m.beta[j])).count();
                    m.ws_size = m.ws_size.max(2 * gsupp).min(p);
                    m.ws =
                        select_working_set(&mut m.scores, m.ws_size, |j| {
                            m.penalty.in_gsupp(m.beta[j])
                        });
                } else {
                    m.ws = (0..p).collect();
                }
                if m.ws.is_empty() {
                    retire.push(s);
                    continue;
                }
                m.inner_tol = (self.inner_tol_ratio * kkt).max(0.1 * self.tol);
                kkt_live = kkt_live.max(kkt);
            }
            // descending order keeps swap-remove slots valid
            for &slot in retire.iter().rev() {
                self.retire_slot(slot, None, true);
            }
        }
        // outer working set = union of live members' working sets; the
        // shared solve_outer selection reproduces it exactly via the
        // ±∞-score trick below
        self.in_union.fill(false);
        for &mi in &self.live {
            for &j in &self.members[mi].ws {
                self.in_union[j] = true;
            }
        }
        for (j, out) in scores.iter_mut().enumerate() {
            *out = if self.in_union[j] { 1.0 } else { f64::NEG_INFINITY };
        }
        // all-retired ⇒ 0.0 ⇒ the shared loop stops converged
        if self.live.is_empty() {
            0.0
        } else {
            kkt_live
        }
    }

    fn objective(&self) -> f64 {
        let n = self.design.nrows();
        self.live
            .iter()
            .enumerate()
            .map(|(s, &mi)| {
                let m = &self.members[mi];
                cd::objective(
                    &m.datafit,
                    &m.penalty,
                    self.y,
                    &m.beta,
                    &self.panel[s * n..(s + 1) * n],
                )
            })
            .sum()
    }

    fn in_gsupp(&self, j: usize) -> bool {
        self.in_union[j]
    }

    fn inner_solve(&mut self, ws: &[usize], _inner_tol: f64, opts: &SolverOpts) -> InnerStats {
        let design = self.design;
        let n = design.nrows();
        let mut agg = InnerStats::default();
        // route each member: Gram engine members run the exact scalar
        // gram_inner_solver on the shared store; the rest run the
        // interleaved panel epochs (per-member inner tolerances)
        let mut res_slots: Vec<usize> = Vec::new();
        for s in 0..self.live.len() {
            let mi = self.live[s];
            let quad;
            let use_gram;
            {
                let m = &self.members[mi];
                quad = m.datafit.residual_quadratic_scale();
                use_gram =
                    m.dispatch.use_gram(design, &m.ws, self.gram.as_deref(), quad.is_some());
            }
            if use_gram {
                let gram_ref = self.gram.as_ref().expect("use_gram implies a store").clone();
                let m = &mut self.members[mi];
                let state = &mut self.panel[s * n..(s + 1) * n];
                let stats = gram_inner_solver(
                    design,
                    m.datafit.lipschitz(),
                    quad.expect("use_gram implies the Gram contract"),
                    &m.penalty,
                    &mut m.beta,
                    state,
                    &m.ws,
                    &gram_ref,
                    opts.max_epochs,
                    m.inner_tol,
                    opts.anderson_m,
                );
                m.dispatch.record_epochs(stats.epochs);
                m.n_epochs += stats.epochs;
                m.accepted += stats.accepted_extrapolations;
                m.rejected += stats.rejected_extrapolations;
                m.profile.merge(&stats.profile);
                agg.epochs += stats.epochs;
                agg.accepted_extrapolations += stats.accepted_extrapolations;
                agg.rejected_extrapolations += stats.rejected_extrapolations;
                agg.score_checks += stats.score_checks;
                agg.ws_score = agg.ws_score.max(stats.ws_score);
                agg.profile.merge(&stats.profile);
            } else {
                res_slots.push(s);
            }
        }
        if !res_slots.is_empty() {
            let stats_list = self.residual_inner(ws, &res_slots, opts);
            for (k, stats) in stats_list.into_iter().enumerate() {
                let mi = self.live[res_slots[k]];
                let m = &mut self.members[mi];
                m.dispatch.record_epochs(stats.epochs);
                m.n_epochs += stats.epochs;
                m.accepted += stats.accepted_extrapolations;
                m.rejected += stats.rejected_extrapolations;
                m.profile.merge(&stats.profile);
                agg.epochs += stats.epochs;
                agg.accepted_extrapolations += stats.accepted_extrapolations;
                agg.rejected_extrapolations += stats.rejected_extrapolations;
                agg.score_checks += stats.score_checks;
                agg.ws_score = agg.ws_score.max(stats.ws_score);
                agg.profile.merge(&stats.profile);
            }
        }
        agg
    }

    fn final_kkt(&mut self) -> f64 {
        // live members' exact certificates (same pass the scalar solver
        // runs); retired members already carry theirs
        let n = self.design.nrows();
        let mut worst = 0.0f64;
        for s in 0..self.live.len() {
            let mi = self.live[s];
            let m = &self.members[mi];
            let state = &self.panel[s * n..(s + 1) * n];
            let mut fs = vec![0.0; self.all_features.len()];
            coordinate_scores_into(
                self.design,
                self.y,
                &m.datafit,
                &m.penalty,
                &m.beta,
                state,
                &self.all_features,
                &mut fs,
            );
            worst = worst.max(fs.iter().fold(0.0f64, |a, &s| a.max(s)));
        }
        worst
    }

    fn label(&self) -> &'static str {
        "batch"
    }
}

/// Solve `fits.len()` sibling fits on one design simultaneously. Member
/// order is preserved in the outcome. `col_sq_norms` is the coordinator's
/// cached Gram diagonal (unmasked members reuse it); `gram` a shared
/// working-set Gram store for the whole batch (one `GramStore` across all
/// members — masked members are forced onto the residual engine).
///
/// The batch-level `opts.budget` stops the whole loop cooperatively;
/// per-member cancel flags / deadlines retire individual members.
pub fn solve_batch(
    design: &Design,
    y: &[f64],
    fits: Vec<BatchFit>,
    opts: &SolverOpts,
    col_sq_norms: Option<&[f64]>,
    gram: Option<Arc<GramCache>>,
) -> BatchOutcome {
    let p = design.ncols();
    let n = design.nrows();
    // reduced precision cannot certify below its quantisation floor
    // (solve_prepared parity)
    let mut opts_floored;
    let opts = if opts.precision == Precision::F64 {
        opts
    } else {
        opts_floored = opts.clone();
        opts_floored.tol = opts_floored.tol.max(opts.precision.tol_floor());
        &opts_floored
    };
    // label every profile with what the batch actually ran on
    let profile_seed = InnerProfile {
        kernel_isa: simd::isa(),
        precision: opts.precision,
        ..Default::default()
    };
    let n_members = fits.len();
    let mut members = Vec::with_capacity(n_members);
    let mut panel = Vec::with_capacity(n * n_members);
    for fit in fits {
        let penalty = fit.penalty.expect("BatchFit requires a penalty");
        let mut datafit = MaskedQuadratic::new(fit.row_weights);
        datafit.init_cached(design, y, col_sq_norms);
        // non-convex validity (solve_prepared parity, per member)
        let min_l = datafit
            .lipschitz()
            .iter()
            .cloned()
            .filter(|&l| l > 0.0)
            .fold(f64::INFINITY, f64::min);
        if min_l.is_finite() {
            penalty.validate_step(1.0 / min_l);
        }
        let beta = match fit.beta0 {
            Some(b) => {
                assert_eq!(b.len(), p);
                b
            }
            None => vec![0.0; p],
        };
        let state = datafit.init_state(design, y, &beta);
        panel.extend_from_slice(&state);
        members.push(Member {
            ws_size: fit.ws0.unwrap_or(opts.ws_start).min(p).max(1),
            penalty,
            datafit,
            beta,
            ws: Vec::new(),
            inner_tol: opts.tol,
            dispatch: EngineDispatch::new(opts.inner),
            cancel: fit.cancel,
            deadline: fit.deadline,
            history: Vec::new(),
            n_outer: 0,
            n_epochs: 0,
            accepted: 0,
            rejected: 0,
            profile: profile_seed,
            scores: vec![0.0; p],
            done: None,
        });
    }
    // shared Gram store (solve_prepared parity): created only when the
    // requested engine may want it and some member satisfies the
    // contract. Reduced precision never reuses a shared f64 cache.
    let wants_gram = opts.inner != InnerEngine::Residual
        && members.iter().any(|m| m.datafit.residual_quadratic_scale().is_some());
    let gram = match gram {
        Some(g) if opts.precision == Precision::F64 => Some(g),
        _ if wants_gram => Some(Arc::new(GramCache::with_default_budget_at(opts.precision))),
        _ => None,
    };
    // reduced precision routes the panel pass through an f32 design
    // shadow (dense only; sparse panels stay f64)
    let shadow = match (opts.precision, design) {
        (Precision::F64, _) => None,
        (_, Design::Dense(m)) => Some(ShadowF32::from_dense(m)),
        _ => None,
    };
    let mut coords = BatchedCoords {
        design,
        y,
        tol: opts.tol,
        inner_tol_ratio: opts.inner_tol_ratio,
        use_ws: opts.use_ws,
        live: (0..n_members).collect(),
        members,
        panel,
        grads: Vec::new(),
        in_union: vec![false; p],
        all_features: (0..p).collect(),
        gram,
        start: Instant::now(),
        profile: profile_seed,
        precision: opts.precision,
        shadow,
        panel32: Vec::new(),
    };
    let out = solve_outer(&mut coords, opts, None);
    coords.finalize(out.stopped);
    let mut profile = out.profile;
    profile.merge(&coords.profile);
    BatchOutcome {
        members: coords
            .members
            .into_iter()
            .map(|m| m.done.expect("finalize retires every member"))
            .collect(),
        n_outer: out.n_outer,
        profile,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{correlated, CorrelatedSpec};
    use crate::datafit::Quadratic;
    use crate::penalty::{Mcp, L1};
    use crate::solver::skglm::solve;

    fn problem(seed: u64) -> (Design, Vec<f64>, f64) {
        let ds = correlated(
            CorrelatedSpec { n: 80, p: 60, rho: 0.5, nnz: 6, snr: 10.0 },
            seed,
        );
        let n = ds.design.nrows() as f64;
        let mut xty = vec![0.0; ds.design.ncols()];
        ds.design.matvec_t(&ds.y, &mut xty);
        let lam_max = xty.iter().fold(0.0f64, |m, v| m.max(v.abs())) / n;
        (ds.design, ds.y, lam_max)
    }

    #[test]
    fn single_member_batch_is_bitwise_scalar() {
        let (design, y, lam_max) = problem(7);
        for lam_ratio in [0.5, 0.1, 0.02] {
            let lam = lam_max * lam_ratio;
            let opts = SolverOpts::default().with_tol(1e-10);
            let mut f = Quadratic::new();
            let scalar = solve(&design, &y, &mut f, &L1::new(lam), &opts, None, None);
            let out = solve_batch(
                &design,
                &y,
                vec![BatchFit::new(BatchPenalty::L1(L1::new(lam)))],
                &opts,
                None,
                None,
            );
            let m = &out.members[0].result;
            assert_eq!(m.beta.len(), scalar.beta.len());
            for (a, b) in m.beta.iter().zip(scalar.beta.iter()) {
                assert_eq!(a.to_bits(), b.to_bits(), "beta drifted at lam {lam}");
            }
            assert_eq!(m.kkt.to_bits(), scalar.kkt.to_bits());
            assert_eq!(m.n_outer, scalar.n_outer);
            assert_eq!(m.n_epochs, scalar.n_epochs);
            assert_eq!(m.converged, scalar.converged);
            assert!(out.profile.panel_flops > 0.0);
        }
    }

    #[test]
    fn mixed_batch_members_match_their_scalar_runs() {
        let (design, y, lam_max) = problem(13);
        let opts = SolverOpts::default().with_tol(1e-10);
        let lams = [lam_max / 3.0, lam_max / 10.0, lam_max / 30.0, lam_max / 100.0];
        let fits: Vec<BatchFit> = lams
            .iter()
            .map(|&l| BatchFit::new(BatchPenalty::L1(L1::new(l))))
            .collect();
        let out = solve_batch(&design, &y, fits, &opts, None, None);
        for (k, &lam) in lams.iter().enumerate() {
            let mut f = Quadratic::new();
            let scalar = solve(&design, &y, &mut f, &L1::new(lam), &opts, None, None);
            let m = &out.members[k].result;
            for (a, b) in m.beta.iter().zip(scalar.beta.iter()) {
                assert_eq!(a.to_bits(), b.to_bits(), "member {k} drifted");
            }
            assert_eq!(m.n_epochs, scalar.n_epochs, "member {k} epoch count");
            assert!(out.members[k].stopped.is_none());
        }
    }

    #[test]
    fn mcp_members_match_scalar_trajectories() {
        let (design, y, _lam_max) = problem(23);
        // normalize like the MCP paper setup so gamma*L_j > 1 holds
        let mut design = design;
        let _norms = design.normalize_cols((design.nrows() as f64).sqrt());
        let mut xty = vec![0.0; design.ncols()];
        design.matvec_t(&y, &mut xty);
        let lam = xty.iter().fold(0.0f64, |m, v| m.max(v.abs())) / design.nrows() as f64 / 10.0;
        let opts = SolverOpts::default().with_tol(1e-9);
        let out = solve_batch(
            &design,
            &y,
            vec![
                BatchFit::new(BatchPenalty::Mcp(Mcp::new(lam, 3.0))),
                BatchFit::new(BatchPenalty::L1(L1::new(lam))),
            ],
            &opts,
            None,
            None,
        );
        let mut f = Quadratic::new();
        let mcp = solve(&design, &y, &mut f, &Mcp::new(lam, 3.0), &opts, None, None);
        let mut f2 = Quadratic::new();
        let l1 = solve(&design, &y, &mut f2, &L1::new(lam), &opts, None, None);
        for (a, b) in out.members[0].result.beta.iter().zip(mcp.beta.iter()) {
            assert_eq!(a.to_bits(), b.to_bits(), "MCP member drifted");
        }
        for (a, b) in out.members[1].result.beta.iter().zip(l1.beta.iter()) {
            assert_eq!(a.to_bits(), b.to_bits(), "L1 member drifted");
        }
    }

    #[test]
    fn masked_member_matches_row_subset_fit() {
        let (design, y, lam_max) = problem(31);
        let n = design.nrows();
        // mask out every third row; rebuild the kept-rows design densely
        let keep: Vec<bool> = (0..n).map(|i| i % 3 != 0).collect();
        let w: Vec<f64> = keep.iter().map(|&k| if k { 1.0 } else { 0.0 }).collect();
        let rows: Vec<Vec<f64>> = (0..n)
            .filter(|&i| keep[i])
            .map(|i| {
                (0..design.ncols())
                    .map(|j| match &design {
                        Design::Dense(m) => m.col(j)[i],
                        Design::Sparse(_) => unreachable!(),
                    })
                    .collect()
            })
            .collect();
        let y_sub: Vec<f64> = (0..n).filter(|&i| keep[i]).map(|i| y[i]).collect();
        let sub = Design::Dense(crate::linalg::DenseMatrix::from_rows(&rows));
        let lam = lam_max / 10.0;
        let opts = SolverOpts::default().with_tol(1e-10);
        let mut f = Quadratic::new();
        let scalar = solve(&sub, &y_sub, &mut f, &L1::new(lam), &opts, None, None);
        let out = solve_batch(
            &design,
            &y,
            vec![BatchFit::new(BatchPenalty::L1(L1::new(lam)))
                .with_row_weights(Arc::new(w))],
            &opts,
            None,
            None,
        );
        let m = &out.members[0].result;
        assert!(m.converged);
        for (a, b) in m.beta.iter().zip(scalar.beta.iter()) {
            assert!(
                (a - b).abs() < 1e-9,
                "masked fit should match the row-subset fit: {a} vs {b}"
            );
        }
    }

    #[test]
    fn cancelled_member_retires_without_aborting_batch() {
        let (design, y, lam_max) = problem(41);
        let flag = Arc::new(AtomicBool::new(true)); // cancelled from the start
        let lam = lam_max / 20.0;
        let opts = SolverOpts::default().with_tol(1e-10);
        let out = solve_batch(
            &design,
            &y,
            vec![
                BatchFit::new(BatchPenalty::L1(L1::new(lam))).with_cancel(flag),
                BatchFit::new(BatchPenalty::L1(L1::new(lam))),
            ],
            &opts,
            None,
            None,
        );
        assert_eq!(out.members[0].stopped, Some(StopReason::Cancelled));
        assert!(!out.members[0].result.converged);
        assert!(out.members[1].stopped.is_none());
        assert!(out.members[1].result.converged, "survivor must still converge");
        // the cancelled member's partial result matches the untouched warm start
        assert!(out.members[0].result.beta.iter().all(|&b| b == 0.0));
    }

    #[test]
    fn warm_started_batch_continues_a_grid() {
        let (design, y, lam_max) = problem(47);
        let opts = SolverOpts::default().with_tol(1e-10);
        let first = solve_batch(
            &design,
            &y,
            vec![BatchFit::new(BatchPenalty::L1(L1::new(lam_max / 5.0)))],
            &opts,
            None,
            None,
        );
        let warm_beta = first.members[0].result.beta.clone();
        let ws0 = first.members[0].result.history.last().map(|h| h.ws_size);
        let cont = solve_batch(
            &design,
            &y,
            vec![BatchFit::new(BatchPenalty::L1(L1::new(lam_max / 15.0)))
                .warm(warm_beta, ws0)],
            &opts,
            None,
            None,
        );
        let m = &cont.members[0].result;
        assert!(m.converged);
        // warm continuation should beat a cold start on epochs
        let cold = solve_batch(
            &design,
            &y,
            vec![BatchFit::new(BatchPenalty::L1(L1::new(lam_max / 15.0)))],
            &opts,
            None,
            None,
        );
        assert!(m.n_epochs <= cold.members[0].result.n_epochs);
    }

    #[test]
    fn batch_lambda_max_matches_scalar_and_masks() {
        let (design, y, lam_max) = problem(53);
        let n = design.nrows();
        let w: Vec<f64> = (0..n).map(|i| if i % 2 == 0 { 1.0 } else { 0.0 }).collect();
        let lams =
            batch_lambda_max(&design, &y, &[None, Some(Arc::new(w.clone()))]);
        assert!((lams[0] - lam_max).abs() <= 1e-12 * lam_max.max(1.0));
        // masked anchor equals the subset formula
        let mut masked_y = vec![0.0; n];
        for i in 0..n {
            masked_y[i] = w[i] * y[i];
        }
        let mut xty = vec![0.0; design.ncols()];
        design.matvec_t(&masked_y, &mut xty);
        let want = xty.iter().fold(0.0f64, |m, v| m.max(v.abs())) / w.iter().sum::<f64>();
        assert!((lams[1] - want).abs() <= 1e-12 * want.max(1.0));
    }

    #[test]
    fn gram_engine_batch_matches_residual_batch() {
        let (design, y, lam_max) = problem(61);
        let lam = lam_max / 15.0;
        let run = |inner: InnerEngine| {
            let opts = SolverOpts::default().with_tol(1e-12).with_inner(inner);
            solve_batch(
                &design,
                &y,
                vec![
                    BatchFit::new(BatchPenalty::L1(L1::new(lam))),
                    BatchFit::new(BatchPenalty::L1(L1::new(lam / 3.0))),
                ],
                &opts,
                None,
                None,
            )
        };
        let res = run(InnerEngine::Residual);
        let gram = run(InnerEngine::Gram);
        for k in 0..2 {
            let (a, b) = (&res.members[k].result, &gram.members[k].result);
            assert!(a.converged && b.converged);
            assert!(
                (a.objective - b.objective).abs() < 1e-12,
                "member {k}: {} vs {}",
                a.objective,
                b.objective
            );
        }
        // the forced-Gram batch really ran Gram epochs
        assert!(gram.profile.gram_epochs > 0);
    }
}
