//! L-BFGS (Liu & Nocedal 1989) on the squared-hinge SVM primal — the
//! Figure-9 comparator ("l-BFGS" curve):
//!
//! ```text
//! min_β  ½‖β‖² + C Σ_i max(0, 1 − y_i x_iᵀβ)²
//! ```
//!
//! (The plain hinge is non-smooth; liblinear's L2-loss variant is the
//! standard smooth surrogate an L-BFGS baseline optimises.) Two-loop
//! recursion with Armijo backtracking.

use crate::linalg::Design;
use crate::solver::HistoryPoint;
use std::collections::VecDeque;
use std::time::Instant;

/// Squared-hinge primal objective and gradient.
/// `design` is the primal X (n×d), labels ±1.
pub fn sq_hinge_objective(design: &Design, y: &[f64], c: f64, beta: &[f64]) -> f64 {
    let n = design.nrows();
    let mut xb = vec![0.0; n];
    design.matvec(beta, &mut xb);
    let mut loss = 0.0;
    for i in 0..n {
        let m = 1.0 - y[i] * xb[i];
        if m > 0.0 {
            loss += m * m;
        }
    }
    0.5 * crate::linalg::sq_nrm2(beta) + c * loss
}

fn sq_hinge_grad(design: &Design, y: &[f64], c: f64, beta: &[f64], grad: &mut [f64]) {
    let n = design.nrows();
    let mut xb = vec![0.0; n];
    design.matvec(beta, &mut xb);
    // dL/d(xb_i) = −2C y_i max(0, 1 − y_i xb_i)
    let mut w = vec![0.0; n];
    for i in 0..n {
        let m = 1.0 - y[i] * xb[i];
        w[i] = if m > 0.0 { -2.0 * c * y[i] * m } else { 0.0 };
    }
    design.matvec_t(&w, grad);
    for (g, &b) in grad.iter_mut().zip(beta.iter()) {
        *g += b;
    }
}

/// L-BFGS result.
#[derive(Clone, Debug)]
pub struct LbfgsResult {
    pub beta: Vec<f64>,
    pub objective: f64,
    pub iters: usize,
    pub history: Vec<HistoryPoint>,
}

/// Minimise the squared-hinge primal with memory-`m` L-BFGS.
pub fn solve_lbfgs_svm(
    design: &Design,
    y: &[f64],
    c: f64,
    m: usize,
    max_iter: usize,
    tol: f64,
) -> LbfgsResult {
    let start = Instant::now();
    let d = design.ncols();
    let mut beta = vec![0.0; d];
    let mut grad = vec![0.0; d];
    sq_hinge_grad(design, y, c, &beta, &mut grad);
    let mut obj = sq_hinge_objective(design, y, c, &beta);

    // (s, y, rho) memory
    let mut mem: VecDeque<(Vec<f64>, Vec<f64>, f64)> = VecDeque::with_capacity(m);
    let mut history = Vec::new();
    let mut iters = 0;

    for it in 1..=max_iter {
        iters = it;
        // ---- two-loop recursion: q = H_k grad ----
        let mut q = grad.clone();
        let mut alphas = Vec::with_capacity(mem.len());
        for (s, yk, rho) in mem.iter().rev() {
            let alpha = rho * crate::linalg::dot(s, &q);
            crate::linalg::axpy(-alpha, yk, &mut q);
            alphas.push(alpha);
        }
        // initial scaling γ = sᵀy/yᵀy
        if let Some((s, yk, _)) = mem.back() {
            let gamma = crate::linalg::dot(s, yk) / crate::linalg::sq_nrm2(yk).max(1e-300);
            for v in q.iter_mut() {
                *v *= gamma;
            }
        }
        for ((s, yk, rho), &alpha) in mem.iter().zip(alphas.iter().rev()) {
            let b = rho * crate::linalg::dot(yk, &q);
            crate::linalg::axpy(alpha - b, s, &mut q);
        }
        // descent direction
        for v in q.iter_mut() {
            *v = -*v;
        }
        let dir_dot_grad = crate::linalg::dot(&q, &grad);
        let (dir, dg) = if dir_dot_grad < 0.0 {
            (q, dir_dot_grad)
        } else {
            // safeguard: fall back to steepest descent
            let g = grad.iter().map(|v| -v).collect::<Vec<_>>();
            let dg = -crate::linalg::sq_nrm2(&grad);
            (g, dg)
        };

        // ---- Armijo backtracking ----
        let mut step = 1.0f64;
        let mut new_beta;
        let mut new_obj;
        loop {
            new_beta = beta.clone();
            crate::linalg::axpy(step, &dir, &mut new_beta);
            new_obj = sq_hinge_objective(design, y, c, &new_beta);
            if new_obj <= obj + 1e-4 * step * dg || step < 1e-16 {
                break;
            }
            step *= 0.5;
        }

        let mut new_grad = vec![0.0; d];
        sq_hinge_grad(design, y, c, &new_beta, &mut new_grad);
        // memory update
        let s: Vec<f64> = new_beta.iter().zip(beta.iter()).map(|(a, b)| a - b).collect();
        let yk: Vec<f64> = new_grad.iter().zip(grad.iter()).map(|(a, b)| a - b).collect();
        let sy = crate::linalg::dot(&s, &yk);
        if sy > 1e-12 {
            if mem.len() == m {
                mem.pop_front();
            }
            mem.push_back((s, yk, 1.0 / sy));
        }
        beta = new_beta;
        grad = new_grad;
        obj = new_obj;

        let gnorm = crate::linalg::norm_inf(&grad);
        if it % 5 == 0 || gnorm <= tol {
            history.push(HistoryPoint {
                t: start.elapsed().as_secs_f64(),
                objective: obj,
                kkt: gnorm,
                ws_size: d,
            });
        }
        if gnorm <= tol {
            break;
        }
    }
    LbfgsResult { beta, objective: obj, iters, history }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{correlated, CorrelatedSpec};

    fn svm_problem() -> (Design, Vec<f64>) {
        let ds = correlated(CorrelatedSpec { n: 120, p: 20, rho: 0.3, nnz: 5, snr: 10.0 }, 0);
        let y: Vec<f64> = ds.y.iter().map(|&v| if v >= 0.0 { 1.0 } else { -1.0 }).collect();
        (ds.design, y)
    }

    #[test]
    fn grad_matches_finite_differences() {
        let (d, y) = svm_problem();
        let beta: Vec<f64> = (0..20).map(|j| 0.01 * (j as f64 - 10.0)).collect();
        let mut g = vec![0.0; 20];
        sq_hinge_grad(&d, &y, 1.0, &beta, &mut g);
        let eps = 1e-6;
        for j in [0usize, 7, 19] {
            let mut bp = beta.clone();
            bp[j] += eps;
            let mut bm = beta.clone();
            bm[j] -= eps;
            let fd = (sq_hinge_objective(&d, &y, 1.0, &bp)
                - sq_hinge_objective(&d, &y, 1.0, &bm))
                / (2.0 * eps);
            assert!((fd - g[j]).abs() < 1e-4, "j={j}: fd={fd} an={}", g[j]);
        }
    }

    #[test]
    fn converges_to_stationary_point() {
        // squared hinge is C¹ but only piecewise C², so L-BFGS grinds at
        // very tight tolerances; 1e-5 on ‖∇‖∞ is the realistic target
        let (d, y) = svm_problem();
        let res = solve_lbfgs_svm(&d, &y, 1.0, 10, 2000, 1e-5);
        assert!(
            res.history.last().unwrap().kkt <= 1e-5,
            "grad norm {}",
            res.history.last().unwrap().kkt
        );
    }

    #[test]
    fn objective_decreases_monotonically() {
        let (d, y) = svm_problem();
        let res = solve_lbfgs_svm(&d, &y, 10.0, 10, 200, 1e-10);
        for w in res.history.windows(2) {
            assert!(w[1].objective <= w[0].objective + 1e-10);
        }
    }

    #[test]
    fn separable_data_gets_classified() {
        let (d, y) = svm_problem();
        let res = solve_lbfgs_svm(&d, &y, 1.0, 10, 500, 1e-8);
        let mut xb = vec![0.0; d.nrows()];
        d.matvec(&res.beta, &mut xb);
        let acc = xb
            .iter()
            .zip(y.iter())
            .filter(|(s, yi)| (s.signum() - **yi).abs() < 1e-12)
            .count() as f64
            / y.len() as f64;
        assert!(acc > 0.8, "train accuracy {acc}");
    }
}
