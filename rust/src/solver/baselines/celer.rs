//! celer-like working-set Lasso solver (Massias et al. 2018).
//!
//! celer prioritises features by duality: with a feasible dual point
//! `θ = r / max(nλ, ‖Xᵀr‖∞)`, feature j's distance-to-active-constraint is
//! `d_j = (1 − |X_jᵀθ|) / ‖X_j‖`, and the working set keeps the *smallest*
//! `d_j`. This is Lasso-specific (it needs the dual), which is exactly the
//! paper's §2.4 point — the skglm score generalises it. Inner solver: CD
//! with Anderson (celer accelerates in the dual; we reuse the primal
//! Anderson of Algorithm 2, labelled "celer-like" in the benches).

use crate::datafit::{Datafit, Quadratic};
use crate::linalg::Design;
use crate::penalty::L1;
use crate::solver::inner::inner_solver;
use crate::solver::{FitResult, HistoryPoint, SolverOpts};
use std::time::Instant;

/// Lasso-only working-set solve with the duality-based score.
pub fn solve_celer(
    design: &Design,
    y: &[f64],
    lambda: f64,
    opts: &SolverOpts,
) -> FitResult {
    let start = Instant::now();
    let p = design.ncols();
    let n = design.nrows() as f64;
    let mut datafit = Quadratic::new();
    datafit.init(design, y);
    let penalty = L1::new(lambda);
    let col_norms: Vec<f64> = design.col_sq_norms().iter().map(|s| s.sqrt()).collect();

    let mut beta = vec![0.0; p];
    // state = residual Xβ − y
    let mut state = datafit.init_state(design, y, &beta);
    let mut xtr = vec![0.0; p];
    let mut dist = vec![0.0; p];
    let mut result = FitResult {
        beta: Vec::new(),
        objective: f64::NAN,
        kkt: f64::NAN,
        // celer stops on (and finally reports) the Lasso duality gap
        certificate: crate::solver::skglm::Certificate::DualityGap,
        n_outer: 0,
        n_epochs: 0,
        converged: false,
        history: Vec::new(),
        accepted_extrapolations: 0,
        rejected_extrapolations: 0,
        profile: Default::default(),
    };
    let mut ws_size = opts.ws_start.min(p).max(1);

    for outer in 1..=opts.max_outer {
        result.n_outer = outer;
        // Xᵀr (residual sign: state = Xβ − y, r := −state = y − Xβ)
        design.matvec_t(&state, &mut xtr);
        for v in xtr.iter_mut() {
            *v = -*v;
        }
        // duality gap for stopping + history
        let r: Vec<f64> = state.iter().map(|&s| -s).collect();
        let gap = crate::metrics::lasso_gap(design, y, &beta, &r, lambda);
        let objective = crate::linalg::sq_nrm2(&r) / (2.0 * n)
            + lambda * crate::linalg::norm1(&beta);
        result.history.push(HistoryPoint {
            t: start.elapsed().as_secs_f64(),
            objective,
            kkt: gap,
            ws_size: ws_size.min(p),
        });
        if gap <= opts.tol {
            result.converged = true;
            break;
        }
        // KKT scale for the inner tolerance: the gap lives on the
        // objective scale while the inner solver stops on gradient-scale
        // scores, so the two must not be mixed (mixing them collapsed the
        // inner solves to one epoch — EXPERIMENTS.md §Perf)
        let mut kkt_max = 0.0f64;
        for j in 0..p {
            let grad_j = -xtr[j] / n; // ∇_j f = Xᵀ(Xβ−y)/n
            kkt_max = kkt_max.max(crate::penalty::Penalty::subdiff_distance(
                &penalty, beta[j], grad_j, j,
            ));
        }
        // dual point scale
        let scale = (n * lambda).max(crate::linalg::norm_inf(&xtr));
        // d_j = (1 − |X_jᵀ θ|)/‖X_j‖, θ = r/scale
        for j in 0..p {
            dist[j] = if col_norms[j] == 0.0 {
                f64::INFINITY
            } else if beta[j] != 0.0 {
                f64::NEG_INFINITY // force support into the working set
            } else {
                (1.0 - (xtr[j] / scale).abs()) / col_norms[j]
            };
        }
        let nnz = beta.iter().filter(|&&b| b != 0.0).count();
        ws_size = ws_size.max(2 * nnz).min(p);
        let mut idx: Vec<usize> = (0..p).collect();
        if ws_size < p {
            idx.select_nth_unstable_by(ws_size - 1, |&a, &b| {
                dist[a].partial_cmp(&dist[b]).unwrap_or(std::cmp::Ordering::Equal)
            });
            idx.truncate(ws_size);
        }
        idx.sort_unstable();
        // inner tolerance proportional to the current KKT violation
        // (celer ties eps_inner to its outer criterion; ours must be on
        // the score scale the inner solver checks)
        let inner_tol = (opts.inner_tol_ratio * kkt_max).max(0.1 * opts.tol);
        let stats = inner_solver(
            design,
            y,
            &datafit,
            &penalty,
            &mut beta,
            &mut state,
            &idx,
            opts.max_epochs,
            inner_tol,
            opts.anderson_m,
        );
        result.n_epochs += stats.epochs;
        result.accepted_extrapolations += stats.accepted_extrapolations;
    }

    let r: Vec<f64> = state.iter().map(|&s| -s).collect();
    result.kkt = crate::metrics::lasso_gap(design, y, &beta, &r, lambda);
    result.converged = result.converged || result.kkt <= opts.tol;
    result.objective =
        crate::linalg::sq_nrm2(&r) / (2.0 * n) + lambda * crate::linalg::norm1(&beta);
    result.beta = beta;
    result
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{correlated, paper_dataset_small, CorrelatedSpec};
    use crate::penalty::Penalty as _;

    #[test]
    fn reaches_lasso_optimum_dense() {
        let ds = correlated(CorrelatedSpec { n: 80, p: 120, rho: 0.5, nnz: 8, snr: 10.0 }, 0);
        let mut xty = vec![0.0; 120];
        ds.design.matvec_t(&ds.y, &mut xty);
        let lam = crate::linalg::norm_inf(&xty) / 80.0 / 20.0;
        let res = solve_celer(&ds.design, &ds.y, lam, &SolverOpts::default().with_tol(1e-10));
        assert!(res.converged, "gap {}", res.kkt);
        // cross-check against skglm
        let mut f = Quadratic::new();
        let sk = crate::solver::solve(
            &ds.design, &ds.y, &mut f, &L1::new(lam), &SolverOpts::default().with_tol(1e-12), None, None,
        );
        assert!((res.objective - sk.objective).abs() < 1e-8);
    }

    #[test]
    fn reaches_lasso_optimum_sparse() {
        let ds = paper_dataset_small("rcv1", 1).unwrap();
        let mut xty = vec![0.0; ds.p()];
        ds.design.matvec_t(&ds.y, &mut xty);
        let lam = crate::linalg::norm_inf(&xty) / ds.n() as f64 / 20.0;
        let res = solve_celer(&ds.design, &ds.y, lam, &SolverOpts::default().with_tol(1e-9));
        assert!(res.converged, "gap {}", res.kkt);
    }

    #[test]
    fn history_gap_is_decreasing_overall() {
        let ds = correlated(CorrelatedSpec { n: 60, p: 100, rho: 0.6, nnz: 6, snr: 8.0 }, 2);
        let mut xty = vec![0.0; 100];
        ds.design.matvec_t(&ds.y, &mut xty);
        let lam = crate::linalg::norm_inf(&xty) / 60.0 / 50.0;
        let res = solve_celer(&ds.design, &ds.y, lam, &SolverOpts::default().with_tol(1e-10));
        let first = res.history.first().unwrap().kkt;
        let last = res.history.last().unwrap().kkt;
        assert!(last < first);
    }

    // silence unused-import lint for Penalty trait used via L1::new
    #[allow(dead_code)]
    fn _t() {
        let _ = L1::new(1.0).value(0.0, 0);
    }
}
