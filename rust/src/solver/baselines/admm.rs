//! ADMM for the Lasso / elastic net (Boyd et al. 2011, §6.4) — the
//! Figure-7 comparator. As the paper's §E.2 notes, ADMM needs a p×p
//! linear solve per β-update; we cache one dense Cholesky factorisation of
//! `XᵀX/n + (ρ + λ(1−ρ_enet))·I`, which is why this baseline is only run
//! on the moderate-p synthetic dataset of Figure 7.

use crate::linalg::{Design, DenseMatrix};
use crate::penalty::soft_threshold;
use crate::solver::HistoryPoint;
use std::time::Instant;

/// Dense Cholesky factorisation (lower triangular, in place).
pub struct Cholesky {
    l: Vec<f64>,
    n: usize,
}

impl Cholesky {
    /// Factor a symmetric positive-definite matrix (row-major n×n).
    pub fn factor(a: &[f64], n: usize) -> Option<Self> {
        assert_eq!(a.len(), n * n);
        let mut l = vec![0.0; n * n];
        for i in 0..n {
            for j in 0..=i {
                let mut s = a[i * n + j];
                for k in 0..j {
                    s -= l[i * n + k] * l[j * n + k];
                }
                if i == j {
                    if s <= 0.0 {
                        return None;
                    }
                    l[i * n + i] = s.sqrt();
                } else {
                    l[i * n + j] = s / l[j * n + j];
                }
            }
        }
        Some(Self { l, n })
    }

    /// Solve `A x = b` via forward/back substitution.
    pub fn solve(&self, b: &[f64], x: &mut [f64]) {
        let n = self.n;
        // L z = b
        for i in 0..n {
            let mut s = b[i];
            for k in 0..i {
                s -= self.l[i * n + k] * x[k];
            }
            x[i] = s / self.l[i * n + i];
        }
        // Lᵀ x = z
        for i in (0..n).rev() {
            let mut s = x[i];
            for k in i + 1..n {
                s -= self.l[k * n + i] * x[k];
            }
            x[i] = s / self.l[i * n + i];
        }
    }
}

/// ADMM result.
#[derive(Clone, Debug)]
pub struct AdmmResult {
    pub beta: Vec<f64>,
    pub objective: f64,
    pub iters: usize,
    pub history: Vec<HistoryPoint>,
}

/// ADMM for `min ‖y−Xβ‖²/2n + λρ‖β‖₁ + λ(1−ρ)‖β‖²/2` (ρ=1 → Lasso).
/// `rho_admm` is the augmented-Lagrangian parameter.
pub fn solve_admm(
    design: &Design,
    y: &[f64],
    lambda: f64,
    l1_ratio: f64,
    rho_admm: f64,
    max_iter: usize,
    tol: f64,
) -> AdmmResult {
    let start = Instant::now();
    let n = design.nrows();
    let p = design.ncols();
    let nf = n as f64;
    let dense = match design {
        Design::Dense(m) => m.clone(),
        Design::Sparse(s) => s.to_dense(), // Figure-7 scale only
    };
    // A = XᵀX/n + (ρ_admm + λ(1−ρ))·I   (factored once — ADMM's big cost)
    let l2 = lambda * (1.0 - l1_ratio);
    let mut a = vec![0.0; p * p];
    for i in 0..p {
        for j in i..p {
            let v = crate::linalg::dot(dense.col(i), dense.col(j)) / nf;
            a[i * p + j] = v;
            a[j * p + i] = v;
        }
        a[i * p + i] += rho_admm + l2;
    }
    let chol = Cholesky::factor(&a, p).expect("ADMM system must be SPD");
    // Xᵀy/n
    let mut xty = vec![0.0; p];
    design.matvec_t(y, &mut xty);
    for v in xty.iter_mut() {
        *v /= nf;
    }

    let mut beta = vec![0.0; p];
    let mut z = vec![0.0; p];
    let mut u = vec![0.0; p];
    let mut rhs = vec![0.0; p];
    let mut history = Vec::new();
    let mut iters = 0;

    for it in 1..=max_iter {
        iters = it;
        // β-update: (XᵀX/n + (ρ+l2) I) β = Xᵀy/n + ρ(z − u)
        for j in 0..p {
            rhs[j] = xty[j] + rho_admm * (z[j] - u[j]);
        }
        chol.solve(&rhs, &mut beta);
        // z-update: soft threshold
        let mut r_norm = 0.0f64;
        let mut s_norm = 0.0f64;
        for j in 0..p {
            let z_old = z[j];
            z[j] = soft_threshold(beta[j] + u[j], lambda * l1_ratio / rho_admm);
            u[j] += beta[j] - z[j];
            r_norm += (beta[j] - z[j]) * (beta[j] - z[j]);
            s_norm += (z[j] - z_old) * (z[j] - z_old);
        }
        if it % 5 == 0 {
            // objective + gap at the feasible iterate z
            let mut xb = vec![0.0; n];
            design.matvec(&z, &mut xb);
            let r: Vec<f64> = y.iter().zip(xb.iter()).map(|(a, b)| a - b).collect();
            let obj = crate::linalg::sq_nrm2(&r) / (2.0 * nf)
                + lambda * l1_ratio * crate::linalg::norm1(&z)
                + 0.5 * l2 * crate::linalg::sq_nrm2(&z);
            let gap = crate::metrics::enet_gap(design, y, &z, &r, lambda, l1_ratio);
            history.push(HistoryPoint {
                t: start.elapsed().as_secs_f64(),
                objective: obj,
                kkt: gap,
                ws_size: p,
            });
            if r_norm.sqrt() < tol && s_norm.sqrt() < tol {
                break;
            }
        }
    }
    let mut xb = vec![0.0; n];
    design.matvec(&z, &mut xb);
    let r: Vec<f64> = y.iter().zip(xb.iter()).map(|(a, b)| a - b).collect();
    let objective = crate::linalg::sq_nrm2(&r) / (2.0 * nf)
        + lambda * l1_ratio * crate::linalg::norm1(&z)
        + 0.5 * l2 * crate::linalg::sq_nrm2(&z);
    AdmmResult { beta: z, objective, iters, history }
}

/// Convenience: build a dense design from rows (tests).
pub fn dense_from_rows(rows: &[Vec<f64>]) -> Design {
    Design::Dense(DenseMatrix::from_rows(rows))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{correlated, CorrelatedSpec};
    use crate::datafit::Quadratic;
    use crate::penalty::{L1L2, L1};
    use crate::solver::{solve, SolverOpts};

    #[test]
    fn cholesky_round_trip() {
        // A = Mᵀ M + I is SPD
        let m = [1.0, 2.0, 0.5, -1.0];
        let mut a = [0.0; 4];
        for i in 0..2 {
            for j in 0..2 {
                a[i * 2 + j] = m[i] * m[j] + m[i + 2] * m[j + 2] + if i == j { 1.0 } else { 0.0 };
            }
        }
        let ch = Cholesky::factor(&a, 2).unwrap();
        let b = [1.0, -2.0];
        let mut x = [0.0; 2];
        ch.solve(&b, &mut x);
        // verify A x = b
        for i in 0..2 {
            let got = a[i * 2] * x[0] + a[i * 2 + 1] * x[1];
            assert!((got - b[i]).abs() < 1e-12);
        }
    }

    #[test]
    fn cholesky_rejects_indefinite() {
        let a = [1.0, 2.0, 2.0, 1.0]; // eigenvalues 3, −1
        assert!(Cholesky::factor(&a, 2).is_none());
    }

    #[test]
    fn admm_matches_cd_on_lasso() {
        let ds = correlated(CorrelatedSpec { n: 60, p: 40, rho: 0.4, nnz: 5, snr: 10.0 }, 0);
        let mut xty = vec![0.0; 40];
        ds.design.matvec_t(&ds.y, &mut xty);
        let lam = crate::linalg::norm_inf(&xty) / 60.0 / 10.0;
        let admm = solve_admm(&ds.design, &ds.y, lam, 1.0, 1.0, 5000, 1e-10);
        let mut f = Quadratic::new();
        let cd = solve(&ds.design, &ds.y, &mut f, &L1::new(lam), &SolverOpts::default().with_tol(1e-12), None, None);
        assert!(
            (admm.objective - cd.objective).abs() < 1e-7,
            "admm {} vs cd {}",
            admm.objective,
            cd.objective
        );
    }

    #[test]
    fn admm_matches_cd_on_enet() {
        let ds = correlated(CorrelatedSpec { n: 50, p: 30, rho: 0.3, nnz: 4, snr: 10.0 }, 1);
        let mut xty = vec![0.0; 30];
        ds.design.matvec_t(&ds.y, &mut xty);
        let lam = crate::linalg::norm_inf(&xty) / 50.0 / 5.0;
        let admm = solve_admm(&ds.design, &ds.y, lam, 0.5, 1.0, 5000, 1e-10);
        let mut f = Quadratic::new();
        let cd = solve(
            &ds.design, &ds.y, &mut f, &L1L2::new(lam, 0.5), &SolverOpts::default().with_tol(1e-12), None, None,
        );
        assert!((admm.objective - cd.objective).abs() < 1e-7);
    }
}
