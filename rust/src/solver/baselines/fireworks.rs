//! Fireworks-like working-set solver (Rakotomamonjy et al. 2022).
//!
//! The paper's §2.4 critique: fireworks ranks features by
//! `dist(−∇_j f(β), ∂g_j(0))` — the subdifferential **at 0**, not at the
//! current point — "a coarse information". It also ships no acceleration.
//! This baseline implements exactly that: WS scored at 0, plain CD inner
//! solver; the Figure-5 benches quantify the cost of the coarser score.

use crate::datafit::Datafit;
use crate::linalg::Design;
use crate::penalty::Penalty;
use crate::solver::inner::inner_solver;
use crate::solver::{FitResult, HistoryPoint, SolverOpts};
use std::time::Instant;

/// Working-set solve with the at-zero score rule and no Anderson.
pub fn solve_fireworks<D: Datafit, P: Penalty>(
    design: &Design,
    y: &[f64],
    datafit: &mut D,
    penalty: &P,
    opts: &SolverOpts,
) -> FitResult {
    let start = Instant::now();
    let p = design.ncols();
    datafit.init(design, y);
    let mut beta = vec![0.0; p];
    let mut state = datafit.init_state(design, y, &beta);
    let mut grad = vec![0.0; p];
    let mut scores = vec![0.0; p];
    let mut result = FitResult {
        beta: Vec::new(),
        objective: f64::NAN,
        kkt: f64::NAN,
        certificate: crate::solver::skglm::Certificate::Stationarity,
        n_outer: 0,
        n_epochs: 0,
        converged: false,
        history: Vec::new(),
        accepted_extrapolations: 0,
        rejected_extrapolations: 0,
        profile: Default::default(),
    };
    let mut ws_size = opts.ws_start.min(p).max(1);

    for outer in 1..=opts.max_outer {
        result.n_outer = outer;
        datafit.grad_full(design, y, &state, &beta, &mut grad);
        let lipschitz = datafit.lipschitz();
        // true stationarity for stopping/history (same metric as skglm so
        // curves are comparable) ...
        let mut kkt_max = 0.0f64;
        for j in 0..p {
            let s = if lipschitz[j] == 0.0 {
                0.0
            } else {
                penalty.subdiff_distance(beta[j], grad[j], j)
            };
            kkt_max = kkt_max.max(s);
            // ... but the *working-set score* is evaluated at 0 — the
            // fireworks rule the paper criticises:
            scores[j] = if lipschitz[j] == 0.0 {
                0.0
            } else {
                penalty.subdiff_distance(0.0, grad[j], j)
            };
        }
        let objective =
            datafit.value(y, &beta, &state) + penalty.value_sum(&beta);
        result.history.push(HistoryPoint {
            t: start.elapsed().as_secs_f64(),
            objective,
            kkt: kkt_max,
            ws_size: ws_size.min(p),
        });
        if kkt_max <= opts.tol {
            result.converged = true;
            break;
        }
        let nnz = beta.iter().filter(|&&b| b != 0.0).count();
        ws_size = ws_size.max(2 * nnz).min(p);
        // retain the current support
        for j in 0..p {
            if beta[j] != 0.0 {
                scores[j] = f64::INFINITY;
            }
        }
        let mut idx: Vec<usize> = (0..p).collect();
        if ws_size < p {
            idx.select_nth_unstable_by(ws_size - 1, |&a, &b| {
                scores[b].partial_cmp(&scores[a]).unwrap_or(std::cmp::Ordering::Equal)
            });
            idx.truncate(ws_size);
        }
        idx.sort_unstable();
        let inner_tol = (opts.inner_tol_ratio * kkt_max).max(0.1 * opts.tol);
        let stats = inner_solver(
            design, y, datafit, penalty, &mut beta, &mut state, &idx, opts.max_epochs,
            inner_tol, 0, // no acceleration in fireworks
        );
        result.n_epochs += stats.epochs;
    }

    let objective = datafit.value(y, &beta, &state) + penalty.value_sum(&beta);
    result.kkt = crate::metrics::stationarity(design, y, datafit, penalty, &beta, &state);
    result.converged = result.converged || result.kkt <= opts.tol;
    result.objective = objective;
    result.beta = beta;
    result
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{correlated, CorrelatedSpec};
    use crate::datafit::Quadratic;
    use crate::penalty::{Mcp, L1};
    use crate::solver::{solve, SolverOpts};

    #[test]
    fn reaches_lasso_optimum() {
        let ds = correlated(CorrelatedSpec { n: 80, p: 120, rho: 0.5, nnz: 8, snr: 10.0 }, 0);
        let mut xty = vec![0.0; 120];
        ds.design.matvec_t(&ds.y, &mut xty);
        let lam = crate::linalg::norm_inf(&xty) / 80.0 / 20.0;
        let pen = L1::new(lam);
        let mut f1 = Quadratic::new();
        let fw = solve_fireworks(&ds.design, &ds.y, &mut f1, &pen, &SolverOpts::default().with_tol(1e-10));
        let mut f2 = Quadratic::new();
        let sk = solve(&ds.design, &ds.y, &mut f2, &pen, &SolverOpts::default().with_tol(1e-10), None, None);
        assert!(fw.converged);
        assert!((fw.objective - sk.objective).abs() < 1e-8);
    }

    #[test]
    fn handles_mcp() {
        let ds = correlated(CorrelatedSpec { n: 100, p: 150, rho: 0.4, nnz: 10, snr: 8.0 }, 1);
        let mut design = ds.design.clone();
        design.normalize_cols((100.0f64).sqrt());
        let mut xty = vec![0.0; 150];
        design.matvec_t(&ds.y, &mut xty);
        let lam = crate::linalg::norm_inf(&xty) / 100.0 / 10.0;
        let mut f = Quadratic::new();
        let fw = solve_fireworks(
            &design, &ds.y, &mut f, &Mcp::new(lam, 3.0), &SolverOpts::default().with_tol(1e-8),
        );
        assert!(fw.converged, "kkt {}", fw.kkt);
    }
}
