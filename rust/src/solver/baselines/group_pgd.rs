//! Proximal-gradient baseline for **block-separable** penalties: ISTA /
//! FISTA with the per-block radial prox — the full-gradient method the
//! `exp groups` bench compares the block-CD engine against. Uses the
//! global Lipschitz bound `L = ‖X‖_F²/n ≥ ‖XᵀX‖₂/n` (safe, precomputable),
//! which is exactly why it loses: every iteration is an O(n·p) pass at a
//! conservative step, while block CD takes per-block steps `1/L_b`.

use crate::linalg::Design;
use crate::penalty::BlockPenalty;
use crate::solver::partition::BlockPartition;
use crate::solver::HistoryPoint;
use std::time::Instant;

/// Outcome of a block proximal-gradient run.
#[derive(Clone, Debug)]
pub struct GroupPgdResult {
    pub v: Vec<f64>,
    pub objective: f64,
    pub iters: usize,
    pub history: Vec<HistoryPoint>,
}

fn objective<B: BlockPenalty>(
    design: &Design,
    y: &[f64],
    v: &[f64],
    part: &BlockPartition,
    penalty: &B,
    r: &mut [f64],
) -> f64 {
    design.matvec(v, r);
    let n = design.nrows() as f64;
    let mut f = 0.0;
    for (ri, &yi) in r.iter_mut().zip(y.iter()) {
        *ri -= yi;
        f += *ri * *ri;
    }
    f / (2.0 * n) + penalty.value_sum(v, part)
}

/// ISTA (`accelerated = false`) / FISTA (`accelerated = true`) on
/// `‖y−Xβ‖²/2n + Σ_b φ_b(‖β_b‖)`. Stops when the iterate moves less than
/// `tol` in ∞-norm or after `max_iter` full-gradient steps.
pub fn solve_group_pgd<B: BlockPenalty>(
    design: &Design,
    y: &[f64],
    part: &BlockPartition,
    penalty: &B,
    max_iter: usize,
    tol: f64,
    accelerated: bool,
) -> GroupPgdResult {
    let start = Instant::now();
    let n = design.nrows() as f64;
    let p = design.ncols();
    assert_eq!(part.dim(), p, "group PGD solves the single-task feature problem");
    // global Lipschitz: Frobenius bound on ‖XᵀX‖₂/n
    let l_global: f64 = design.col_sq_norms().iter().sum::<f64>() / n;
    let step = if l_global > 0.0 { 1.0 / l_global } else { 1.0 };
    penalty.validate_step(step);

    let mut v = vec![0.0; p];
    let mut z = vec![0.0; p]; // FISTA momentum point
    let mut v_prev = vec![0.0; p];
    let mut point = vec![0.0; p]; // gradient point (z for FISTA, v for ISTA)
    let mut t_mom = 1.0f64;
    let mut r = vec![0.0; design.nrows()];
    let mut grad = vec![0.0; p];
    let mut buf = vec![0.0; part.max_block_len()];
    let mut history = Vec::new();
    let mut iters = 0;

    for it in 1..=max_iter {
        iters = it;
        point.copy_from_slice(if accelerated { &z } else { &v });
        // full gradient Xᵀ(Xpoint − y)/n
        design.matvec(&point, &mut r);
        for (ri, &yi) in r.iter_mut().zip(y.iter()) {
            *ri -= yi;
        }
        design.matvec_t(&r, &mut grad);
        v_prev.copy_from_slice(&v);
        for j in 0..p {
            v[j] = point[j] - step * grad[j] / n;
        }
        // block prox
        for b in 0..part.n_blocks() {
            let len = part.block_len(b);
            let sub = &mut buf[..len];
            part.gather(b, &v, sub);
            penalty.prox(sub, step, b);
            part.scatter(b, sub, &mut v);
        }
        if accelerated {
            let t_next = 0.5 * (1.0 + (1.0 + 4.0 * t_mom * t_mom).sqrt());
            let c = (t_mom - 1.0) / t_next;
            for j in 0..p {
                z[j] = v[j] + c * (v[j] - v_prev[j]);
            }
            t_mom = t_next;
        }
        let max_move = v
            .iter()
            .zip(v_prev.iter())
            .fold(0.0f64, |m, (a, b)| m.max((a - b).abs()));
        if it % 10 == 0 || max_move <= tol || it == max_iter {
            history.push(HistoryPoint {
                t: start.elapsed().as_secs_f64(),
                objective: objective(design, y, &v, part, penalty, &mut r),
                kkt: max_move,
                ws_size: p,
            });
        }
        if max_move <= tol {
            break;
        }
    }
    let obj = objective(design, y, &v, part, penalty, &mut r);
    GroupPgdResult { v, objective: obj, iters, history }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{grouped_correlated, GroupedSpec};
    use crate::estimators::group_lambda_max;
    use crate::penalty::GroupLasso;

    #[test]
    fn matches_block_cd_on_group_lasso() {
        let (ds, part) = grouped_correlated(
            GroupedSpec { n: 60, p: 30, group_size: 5, active_groups: 2, rho: 0.3, snr: 10.0 },
            3,
        );
        let lam = group_lambda_max(&ds.design, &ds.y, &part, None) / 5.0;
        let pen = GroupLasso::new(lam);
        let pgd = solve_group_pgd(&ds.design, &ds.y, &part, &pen, 50_000, 1e-12, true);
        let cd = crate::estimators::group::group_lasso(lam, std::sync::Arc::clone(&part))
            .with_tol(1e-10)
            .fit(&ds.design, &ds.y);
        assert!(
            (pgd.objective - cd.result.objective).abs() < 1e-7,
            "pgd {} vs cd {}",
            pgd.objective,
            cd.result.objective
        );
    }
}
