//! glmnet-like path solver with (sequential) strong rules
//! (Tibshirani et al. 2012) — the Figure-8 comparator.
//!
//! As the paper's §E.3 explains, glmnet is a *path* solver: strong rules
//! screen features using the previous λ on the path,
//! `|X_jᵀ r(λ_{k−1})|/n < 2λ_k − λ_{k−1}  ⇒ discard j`, so a single-λ
//! solve must run the whole continuation path down to the target. That
//! structural handicap (not implementation quality) is what Figure 8
//! shows; this module reproduces it faithfully, including the KKT
//! post-check that re-admits violators.

use crate::datafit::{Datafit, Quadratic};
use crate::linalg::Design;
use crate::penalty::{Penalty, L1L2};
use crate::solver::inner::inner_solver;
use crate::solver::HistoryPoint;
use std::time::Instant;

/// Path-solve down to `lambda_target`; returns the final coefficients and
/// a history point per path step (the black-box harness varies
/// `path_len`/`max_epochs` to trace the Figure-8 curve).
#[derive(Clone, Debug)]
pub struct StrongRulesResult {
    pub beta: Vec<f64>,
    pub objective: f64,
    pub history: Vec<HistoryPoint>,
    /// features screened at the final path step (diagnostics)
    pub final_kept: usize,
}

#[allow(clippy::too_many_arguments)]
pub fn solve_strong_rules_enet(
    design: &Design,
    y: &[f64],
    lambda_target: f64,
    l1_ratio: f64,
    path_len: usize,
    max_epochs: usize,
    tol: f64,
) -> StrongRulesResult {
    let start = Instant::now();
    let p = design.ncols();
    let n = design.nrows() as f64;
    let mut datafit = Quadratic::new();
    datafit.init(design, y);

    // λ_max for the enet's ℓ1 part
    let mut xty = vec![0.0; p];
    design.matvec_t(y, &mut xty);
    let lam_max = crate::linalg::norm_inf(&xty) / (n * l1_ratio);
    let lam_max = lam_max.max(lambda_target * 1.0000001);

    // geometric path λ_max → λ_target
    let path_len = path_len.max(2);
    let ratio = (lambda_target / lam_max).powf(1.0 / (path_len - 1) as f64);
    let mut beta = vec![0.0; p];
    let mut state = datafit.init_state(design, y, &beta); // residual Xβ−y
    let mut history = Vec::new();
    let mut kept = 0usize;
    let mut lam_prev = lam_max;

    for k in 0..path_len {
        let lam = if k == path_len - 1 { lambda_target } else { lam_max * ratio.powi(k as i32) };
        let pen = L1L2::new(lam, l1_ratio);
        // strong rule screen: keep j with |X_jᵀ r|/n >= 2λρ − λ_prev·ρ
        let mut xtr = vec![0.0; p];
        design.matvec_t(&state, &mut xtr); // = Xᵀ(Xβ−y) = −Xᵀr
        let thresh = (2.0 * lam - lam_prev) * l1_ratio;
        let mut ws: Vec<usize> = (0..p)
            .filter(|&j| beta[j] != 0.0 || xtr[j].abs() / n >= thresh)
            .collect();
        if ws.is_empty() {
            ws.push(0);
        }
        // solve on the screened set, then KKT-check everything
        loop {
            inner_solver(
                design, y, &datafit, &pen, &mut beta, &mut state, &ws, max_epochs, tol, 5,
            );
            // KKT check on all features (grad = Xᵀ(Xβ−y)/n)
            let mut grad = vec![0.0; p];
            design.matvec_t(&state, &mut grad);
            for g in grad.iter_mut() {
                *g /= n;
            }
            let mut violators: Vec<usize> = (0..p)
                .filter(|&j| {
                    !ws.contains(&j) && pen.subdiff_distance(beta[j], grad[j], j) > tol
                })
                .collect();
            if violators.is_empty() {
                break;
            }
            ws.append(&mut violators);
            ws.sort_unstable();
            ws.dedup();
        }
        kept = ws.len();
        lam_prev = lam;
        // history point at each path step, reporting the *target-λ* gap so
        // the curve is comparable with single-λ solvers
        let r: Vec<f64> = state.iter().map(|&s| -s).collect();
        let gap =
            crate::metrics::enet_gap(design, y, &beta, &r, lambda_target, l1_ratio);
        let obj = crate::linalg::sq_nrm2(&r) / (2.0 * n)
            + L1L2::new(lambda_target, l1_ratio).value_sum(&beta);
        history.push(HistoryPoint {
            t: start.elapsed().as_secs_f64(),
            objective: obj,
            kkt: gap,
            ws_size: kept,
        });
    }

    let r: Vec<f64> = state.iter().map(|&s| -s).collect();
    let objective = crate::linalg::sq_nrm2(&r) / (2.0 * n)
        + L1L2::new(lambda_target, l1_ratio).value_sum(&beta);
    StrongRulesResult { beta, objective, history, final_kept: kept }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{correlated, CorrelatedSpec};
    use crate::solver::{solve, SolverOpts};

    #[test]
    fn path_reaches_single_lambda_optimum() {
        let ds = correlated(CorrelatedSpec { n: 60, p: 100, rho: 0.5, nnz: 6, snr: 10.0 }, 0);
        let mut xty = vec![0.0; 100];
        ds.design.matvec_t(&ds.y, &mut xty);
        let lam = crate::linalg::norm_inf(&xty) / 60.0 / 20.0;
        let sr = solve_strong_rules_enet(&ds.design, &ds.y, lam, 0.5, 20, 5000, 1e-10);
        let mut f = Quadratic::new();
        let sk = solve(
            &ds.design, &ds.y, &mut f, &L1L2::new(lam, 0.5), &SolverOpts::default().with_tol(1e-12), None, None,
        );
        assert!(
            (sr.objective - sk.objective).abs() < 1e-8,
            "strong-rules {} vs skglm {}",
            sr.objective,
            sk.objective
        );
    }

    #[test]
    fn screening_keeps_few_features_at_high_lambda() {
        let ds = correlated(CorrelatedSpec { n: 80, p: 200, rho: 0.5, nnz: 5, snr: 10.0 }, 1);
        let mut xty = vec![0.0; 200];
        ds.design.matvec_t(&ds.y, &mut xty);
        let lam = crate::linalg::norm_inf(&xty) / 80.0 / 2.0; // mild regularisation
        let sr = solve_strong_rules_enet(&ds.design, &ds.y, lam, 1.0, 10, 5000, 1e-9);
        assert!(sr.final_kept < 200, "screening should discard something");
    }
}
