//! Proximal gradient baselines: ISTA and FISTA (Beck & Teboulle 2009).
//!
//! Full-gradient methods — the paper's Section 1 point that coordinate
//! descent dominates them on "smooth + separable" problems
//! (Richtárik & Takáč 2014, §6.1); included so the benches show it.

use crate::datafit::Datafit;
use crate::linalg::Design;
use crate::penalty::Penalty;
use crate::solver::HistoryPoint;
use std::time::Instant;

/// Outcome of a proximal-gradient run.
#[derive(Clone, Debug)]
pub struct PgdResult {
    pub beta: Vec<f64>,
    pub objective: f64,
    pub iters: usize,
    pub history: Vec<HistoryPoint>,
}

fn prox_step<D: Datafit, P: Penalty>(
    datafit: &D,
    penalty: &P,
    design: &Design,
    y: &[f64],
    point: &[f64],
    step: f64,
    out: &mut [f64],
    grad: &mut [f64],
) {
    let state = datafit.init_state(design, y, point);
    datafit.grad_full(design, y, &state, point, grad);
    for j in 0..point.len() {
        out[j] = penalty.prox(point[j] - step * grad[j], step, j);
    }
}

/// ISTA (`accelerated = false`) / FISTA (`accelerated = true`).
pub fn solve_pgd<D: Datafit, P: Penalty>(
    design: &Design,
    y: &[f64],
    datafit: &mut D,
    penalty: &P,
    max_iter: usize,
    tol: f64,
    accelerated: bool,
) -> PgdResult {
    let start = Instant::now();
    let p = design.ncols();
    datafit.init(design, y);
    let l_global = datafit.global_lipschitz(design);
    let step = if l_global > 0.0 { 1.0 / l_global } else { 1.0 };
    penalty.validate_step(step);

    let mut beta = vec![0.0; p];
    let mut z = beta.clone(); // FISTA momentum point
    let mut beta_new = vec![0.0; p];
    let mut grad = vec![0.0; p];
    let mut t_k = 1.0f64;
    let mut history = Vec::new();
    let mut iters = 0;

    for it in 1..=max_iter {
        iters = it;
        let point = if accelerated { &z } else { &beta };
        prox_step(datafit, penalty, design, y, point, step, &mut beta_new, &mut grad);

        // momentum
        if accelerated {
            let t_next = 0.5 * (1.0 + (1.0 + 4.0 * t_k * t_k).sqrt());
            let coef = (t_k - 1.0) / t_next;
            for j in 0..p {
                z[j] = beta_new[j] + coef * (beta_new[j] - beta[j]);
            }
            t_k = t_next;
        }
        let max_move = beta
            .iter()
            .zip(beta_new.iter())
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f64, f64::max);
        std::mem::swap(&mut beta, &mut beta_new);

        if it % 10 == 0 || max_move / step <= tol {
            let state = datafit.init_state(design, y, &beta);
            let obj = datafit.value(y, &beta, &state) + penalty.value_sum(&beta);
            let kkt = crate::metrics::stationarity(design, y, datafit, penalty, &beta, &state);
            history.push(HistoryPoint { t: start.elapsed().as_secs_f64(), objective: obj, kkt, ws_size: p });
            if kkt <= tol {
                break;
            }
        }
    }
    let state = datafit.init_state(design, y, &beta);
    let objective = datafit.value(y, &beta, &state) + penalty.value_sum(&beta);
    PgdResult { beta, objective, iters, history }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{correlated, CorrelatedSpec};
    use crate::datafit::Quadratic;
    use crate::penalty::L1;
    use crate::solver::{solve, SolverOpts};

    fn problem() -> (Design, Vec<f64>, f64) {
        let ds = correlated(CorrelatedSpec { n: 50, p: 40, rho: 0.4, nnz: 5, snr: 10.0 }, 0);
        let mut xty = vec![0.0; 40];
        ds.design.matvec_t(&ds.y, &mut xty);
        let lam = crate::linalg::norm_inf(&xty) / 50.0 / 10.0;
        (ds.design, ds.y, lam)
    }

    #[test]
    fn ista_matches_cd_optimum() {
        let (d, y, lam) = problem();
        let pen = L1::new(lam);
        let mut f = Quadratic::new();
        let ista = solve_pgd(&d, &y, &mut f, &pen, 50_000, 1e-10, false);
        let mut f2 = Quadratic::new();
        let cd = solve(&d, &y, &mut f2, &pen, &SolverOpts::default().with_tol(1e-10), None, None);
        assert!((ista.objective - cd.objective).abs() < 1e-8, "{} vs {}", ista.objective, cd.objective);
    }

    #[test]
    fn fista_at_least_as_good_under_fixed_budget() {
        // FISTA's iterates oscillate, so iteration counts to a tight kkt
        // tolerance are noisy; the robust claim is objective quality under
        // a fixed small budget.
        let (d, y, lam) = problem();
        let pen = L1::new(lam);
        let mut f1 = Quadratic::new();
        let fista = solve_pgd(&d, &y, &mut f1, &pen, 60, 1e-16, true);
        let mut f2 = Quadratic::new();
        let ista = solve_pgd(&d, &y, &mut f2, &pen, 60, 1e-16, false);
        assert!(
            fista.objective <= ista.objective + 1e-12,
            "fista {} vs ista {}",
            fista.objective,
            ista.objective
        );
    }
}
