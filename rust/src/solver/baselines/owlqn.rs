//! OWL-QN (Andrew & Gao 2007): orthant-wise limited-memory quasi-Newton
//! for `min_β f(β) + λ‖β‖₁` with any smooth [`Datafit`] — the L-BFGS
//! baseline the `exp glms` benchmark pits against prox-Newton on
//! ℓ1-Poisson/probit problems.
//!
//! Standard construction: L-BFGS two-loop recursion on the *smooth*
//! gradient differences, steered by the ℓ1 **pseudo-gradient** (the
//! minimum-norm subgradient), with the search direction sign-projected
//! against the pseudo-gradient and every trial iterate projected onto the
//! orthant chosen at the current point. Backtracking Armijo line search
//! on the composite objective.

use crate::datafit::Datafit;
use crate::linalg::Design;
use crate::solver::baselines::lbfgs::LbfgsResult;
use crate::solver::HistoryPoint;
use std::collections::VecDeque;
use std::time::Instant;

/// ℓ1 pseudo-gradient: the minimum-norm element of `∂(f + λ‖·‖₁)` —
/// zero exactly on the coordinates where 0 is optimal.
fn pseudo_gradient(beta: &[f64], grad: &[f64], lambda: f64, out: &mut [f64]) {
    for ((o, &b), &g) in out.iter_mut().zip(beta.iter()).zip(grad.iter()) {
        *o = if b > 0.0 {
            g + lambda
        } else if b < 0.0 {
            g - lambda
        } else if g + lambda < 0.0 {
            g + lambda
        } else if g - lambda > 0.0 {
            g - lambda
        } else {
            0.0
        };
    }
}

/// Composite objective `f(β) + λ‖β‖₁` (rebuilds the state — this is a
/// baseline, not a hot path).
fn composite_value<D: Datafit>(
    design: &Design,
    y: &[f64],
    datafit: &D,
    lambda: f64,
    beta: &[f64],
) -> f64 {
    let state = datafit.init_state(design, y, beta);
    datafit.value(y, beta, &state) + lambda * crate::linalg::norm1(beta)
}

/// Minimise `f(β) + λ‖β‖₁` with memory-`m` OWL-QN. The datafit only needs
/// the standard smooth protocol (`init_state`/`value`/`grad_full`), so
/// any GLM runs — including Poisson, whose curvature L-BFGS absorbs
/// through its secant pairs rather than explicit Lipschitz bounds.
pub fn solve_owlqn<D: Datafit>(
    design: &Design,
    y: &[f64],
    datafit: &mut D,
    lambda: f64,
    m: usize,
    max_iter: usize,
    tol: f64,
) -> LbfgsResult {
    let start = Instant::now();
    datafit.init(design, y);
    let p = design.ncols();
    let mut beta = vec![0.0; p];
    let mut state = datafit.init_state(design, y, &beta);
    let mut grad = vec![0.0; p];
    datafit.grad_full(design, y, &state, &beta, &mut grad);
    let mut pg = vec![0.0; p];
    pseudo_gradient(&beta, &grad, lambda, &mut pg);
    let mut obj = datafit.value(y, &beta, &state) + lambda * crate::linalg::norm1(&beta);

    let mut mem: VecDeque<(Vec<f64>, Vec<f64>, f64)> = VecDeque::with_capacity(m);
    let mut history = Vec::new();
    let mut iters = 0;

    for it in 1..=max_iter {
        iters = it;
        let pg_norm = crate::linalg::norm_inf(&pg);
        if pg_norm <= tol {
            break;
        }

        // ---- two-loop recursion on the pseudo-gradient ----
        let mut q = pg.clone();
        let mut alphas = Vec::with_capacity(mem.len());
        for (s, yk, rho) in mem.iter().rev() {
            let alpha = rho * crate::linalg::dot(s, &q);
            crate::linalg::axpy(-alpha, yk, &mut q);
            alphas.push(alpha);
        }
        if let Some((s, yk, _)) = mem.back() {
            let gamma = crate::linalg::dot(s, yk) / crate::linalg::sq_nrm2(yk).max(1e-300);
            for v in q.iter_mut() {
                *v *= gamma;
            }
        }
        for ((s, yk, rho), &alpha) in mem.iter().zip(alphas.iter().rev()) {
            let b = rho * crate::linalg::dot(yk, &q);
            crate::linalg::axpy(alpha - b, s, &mut q);
        }
        // descent direction, sign-projected against the pseudo-gradient
        // (OWL-QN: zero any component that disagrees with −pg)
        let mut dir: Vec<f64> = q.iter().map(|v| -v).collect();
        for (d, &g) in dir.iter_mut().zip(pg.iter()) {
            if *d * -g <= 0.0 {
                *d = 0.0;
            }
        }
        let dg = crate::linalg::dot(&dir, &pg);
        if dg >= 0.0 {
            // projection killed the direction: restart from steepest descent
            for (d, &g) in dir.iter_mut().zip(pg.iter()) {
                *d = -g;
            }
            mem.clear();
        }

        // chosen orthant: sign(β_j), or sign(−pg_j) at zero
        let orthant: Vec<f64> = beta
            .iter()
            .zip(pg.iter())
            .map(|(&b, &g)| if b != 0.0 { b.signum() } else { -g.signum() })
            .collect();

        // ---- backtracking with orthant projection ----
        let mut step = 1.0f64;
        let mut new_beta;
        let mut new_obj;
        let accepted = loop {
            new_beta = beta.clone();
            for ((nb, &d), &o) in new_beta.iter_mut().zip(dir.iter()).zip(orthant.iter()) {
                *nb += step * d;
                // π(x; ξ): zero out coordinates leaving the orthant
                if *nb * o < 0.0 {
                    *nb = 0.0;
                }
            }
            new_obj = composite_value(design, y, datafit, lambda, &new_beta);
            // Armijo on the composite objective with the pseudo-gradient
            // as the first-order model (Andrew & Gao, eq. 5)
            let dec: f64 = new_beta
                .iter()
                .zip(beta.iter())
                .zip(pg.iter())
                .map(|((&nb, &b), &g)| g * (nb - b))
                .sum();
            if new_obj <= obj + 1e-4 * dec {
                break true;
            }
            if step < 1e-16 {
                break false;
            }
            step *= 0.5;
        };
        if !accepted {
            // no step size decreases the objective (numeric floor): stop
            // at the current iterate instead of committing an increase
            break;
        }

        state = datafit.init_state(design, y, &new_beta);
        let mut new_grad = vec![0.0; p];
        datafit.grad_full(design, y, &state, &new_beta, &mut new_grad);

        // memory update from SMOOTH gradient differences
        let s: Vec<f64> = new_beta.iter().zip(beta.iter()).map(|(a, b)| a - b).collect();
        let yk: Vec<f64> = new_grad.iter().zip(grad.iter()).map(|(a, b)| a - b).collect();
        let sy = crate::linalg::dot(&s, &yk);
        if sy > 1e-12 {
            if mem.len() == m {
                mem.pop_front();
            }
            mem.push_back((s, yk, 1.0 / sy));
        }
        beta = new_beta;
        grad = new_grad;
        obj = new_obj;
        pseudo_gradient(&beta, &grad, lambda, &mut pg);

        let pg_norm = crate::linalg::norm_inf(&pg);
        if it % 5 == 0 || pg_norm <= tol {
            history.push(HistoryPoint {
                t: start.elapsed().as_secs_f64(),
                objective: obj,
                kkt: pg_norm,
                ws_size: p,
            });
        }
        if pg_norm <= tol {
            break;
        }
    }
    LbfgsResult { beta, objective: obj, iters, history }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{correlated, poisson_correlated, CorrelatedSpec};
    use crate::datafit::{Poisson, Quadratic};
    use crate::estimators::linear::quadratic_lambda_max;
    use crate::estimators::Lasso;

    #[test]
    fn owlqn_matches_cd_on_the_lasso() {
        let ds = correlated(CorrelatedSpec { n: 80, p: 60, rho: 0.4, nnz: 6, snr: 10.0 }, 1);
        let lam = quadratic_lambda_max(&ds.design, &ds.y) / 10.0;
        let reference = Lasso::new(lam).with_tol(1e-12).fit(&ds.design, &ds.y);
        let mut f = Quadratic::new();
        let owl = solve_owlqn(&ds.design, &ds.y, &mut f, lam, 10, 3000, 1e-10);
        let rel = (owl.objective - reference.objective).abs() / reference.objective.abs();
        assert!(rel < 1e-8, "owl {} vs cd {}", owl.objective, reference.objective);
    }

    #[test]
    fn owlqn_solution_is_sparse() {
        let ds = correlated(CorrelatedSpec { n: 100, p: 150, rho: 0.4, nnz: 8, snr: 10.0 }, 2);
        let lam = quadratic_lambda_max(&ds.design, &ds.y) / 5.0;
        let mut f = Quadratic::new();
        let owl = solve_owlqn(&ds.design, &ds.y, &mut f, lam, 10, 3000, 1e-9);
        let nnz = owl.beta.iter().filter(|&&b| b != 0.0).count();
        assert!(nnz > 0 && nnz < 100, "support {nnz} not sparse (orthant projection broken?)");
    }

    #[test]
    fn owlqn_descends_on_poisson() {
        let ds = poisson_correlated(
            CorrelatedSpec { n: 100, p: 50, rho: 0.3, nnz: 5, snr: 0.0 },
            4,
        );
        let lam = crate::solver::glm_lambda_max(&Poisson::new(), &ds.design, &ds.y) / 10.0;
        let mut f = Poisson::new();
        let owl = solve_owlqn(&ds.design, &ds.y, &mut f, lam, 10, 2000, 1e-9);
        for w in owl.history.windows(2) {
            assert!(w[1].objective <= w[0].objective + 1e-10);
        }
        assert!(
            owl.history.last().map(|h| h.kkt <= 1e-6).unwrap_or(false),
            "pseudo-gradient did not reach tolerance: {:?}",
            owl.history.last().map(|h| h.kkt)
        );
    }
}
