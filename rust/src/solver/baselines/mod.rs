//! Baseline solvers — every competitor curve in the paper's figures.
//!
//! | paper figure | baseline | module |
//! |---|---|---|
//! | Figs 2,3,6 | scikit-learn-style full cyclic CD | [`full_cd`] |
//! | Figs 2 | celer-like dual-extrapolation working set | [`celer`] |
//! | Figs 2 | blitz/fireworks-like WS (score at 0) | [`fireworks`] |
//! | Fig 5 | iterative reweighted ℓ1 (Candès et al. 2008) | [`irls`] |
//! | Fig 7 | ADMM (Boyd et al. 2011) | [`admm`] |
//! | Fig 8 | glmnet-like strong-rules path solver | [`strong_rules`] |
//! | Fig 9 | L-BFGS on the (squared-hinge) SVM primal | [`lbfgs`] |
//! | exp glms | OWL-QN (orthant-wise L-BFGS, ℓ1 GLMs) | [`owlqn`] |
//! | — | ISTA / FISTA proximal gradient | [`pgd`] |

pub mod admm;
pub mod celer;
pub mod fireworks;
pub mod full_cd;
pub mod group_pgd;
pub mod irls;
pub mod lbfgs;
pub mod owlqn;
pub mod pgd;
pub mod strong_rules;
