//! scikit-learn-style solver: cyclic coordinate descent over the *full*
//! feature set, no working sets, no acceleration (Pedregosa et al. 2011 —
//! the `sklearn` curve in Figures 2, 3 and 6). With an MCP penalty this is
//! also the picasso-like configuration of Figure 5 (picasso runs CD on the
//! full set with hardcoded non-convex proxes).

use crate::datafit::Datafit;
use crate::linalg::Design;
use crate::penalty::Penalty;
use crate::solver::{solve, FitResult, SolverOpts};

/// Full cyclic CD until `tol` or `max_epochs`.
pub fn solve_full_cd<D: Datafit, P: Penalty>(
    design: &Design,
    y: &[f64],
    datafit: &mut D,
    penalty: &P,
    max_epochs: usize,
    tol: f64,
) -> FitResult {
    let opts = SolverOpts {
        use_ws: false,
        anderson_m: 0,
        max_epochs: max_epochs.max(1),
        // outer iterations only re-check the stopping criterion here
        max_outer: 1000,
        tol,
        ..Default::default()
    };
    solve(design, y, datafit, penalty, &opts, None, None)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{correlated, CorrelatedSpec};
    use crate::datafit::Quadratic;
    use crate::penalty::L1;

    #[test]
    fn matches_skglm_optimum() {
        let ds = correlated(CorrelatedSpec { n: 60, p: 90, rho: 0.5, nnz: 6, snr: 10.0 }, 0);
        let mut xty = vec![0.0; 90];
        ds.design.matvec_t(&ds.y, &mut xty);
        let lam = crate::linalg::norm_inf(&xty) / 60.0 / 10.0;
        let pen = L1::new(lam);
        let mut f1 = Quadratic::new();
        let full = solve_full_cd(&ds.design, &ds.y, &mut f1, &pen, 10_000, 1e-11);
        let mut f2 = Quadratic::new();
        let ws = solve(&ds.design, &ds.y, &mut f2, &pen, &SolverOpts::default().with_tol(1e-11), None, None);
        assert!(full.converged);
        assert!((full.objective - ws.objective).abs() < 1e-9);
    }
}
