//! Iteratively-reweighted ℓ1 for MCP regression (Candès et al. 2008) —
//! the paper's Figure-5 comparator on sparse designs ("this approach
//! requires solving weighted Lassos with some 0 weights").
//!
//! Majorise-minimise: at iterate β^{(k)}, the MCP is linearised at
//! `|β_j^{(k)}|`, giving a weighted Lasso with weights
//! `w_j = MCP'(|β_j^{(k)}|)/λ = max(0, 1 − |β_j|/(γλ))` — zero for
//! coefficients past the MCP knee, which our generic solver handles
//! natively through [`WeightedL1`].

use crate::datafit::{Datafit, Quadratic};
use crate::linalg::Design;
use crate::penalty::{Mcp, Penalty, WeightedL1};
use crate::solver::{solve, FitResult, HistoryPoint, SolverOpts};
use std::time::Instant;

/// Reweighted-ℓ1 MCP solve. `reweightings` majorise-minimise rounds.
pub fn solve_irls_mcp(
    design: &Design,
    y: &[f64],
    lambda: f64,
    gamma: f64,
    reweightings: usize,
    opts: &SolverOpts,
) -> FitResult {
    let start = Instant::now();
    let p = design.ncols();
    let mcp = Mcp::new(lambda, gamma);
    let mut weights = vec![1.0; p];
    let mut beta = vec![0.0; p];
    let mut history: Vec<HistoryPoint> = Vec::new();
    let mut last: Option<FitResult> = None;
    let mut epochs = 0;

    for _round in 0..reweightings.max(1) {
        let pen = WeightedL1::new(lambda, weights.clone());
        let mut datafit = Quadratic::new();
        let res = solve(design, y, &mut datafit, &pen, opts, None, Some(&beta));
        beta = res.beta.clone();
        epochs += res.n_epochs;
        // report the *MCP* objective and stationarity (so Figure-5 curves
        // compare like for like)
        let state = datafit.init_state(design, y, &beta);
        let obj = datafit.value(y, &beta, &state) + mcp.value_sum(&beta);
        let kkt =
            crate::metrics::stationarity(design, y, &datafit, &mcp, &beta, &state);
        history.push(HistoryPoint {
            t: start.elapsed().as_secs_f64(),
            objective: obj,
            kkt,
            ws_size: p,
        });
        last = Some(res);
        if kkt <= opts.tol {
            break;
        }
        // reweight: w_j = max(0, 1 − |β_j|/(γλ))
        for (w, &b) in weights.iter_mut().zip(beta.iter()) {
            *w = (1.0 - b.abs() / (gamma * lambda)).max(0.0);
        }
    }

    let mut out = last.expect("at least one round");
    let final_hist = history.last().cloned();
    out.beta = beta;
    if let Some(h) = final_hist {
        out.objective = h.objective;
        out.kkt = h.kkt;
        out.converged = h.kkt <= opts.tol;
    }
    out.history = history;
    out.n_epochs = epochs;
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{correlated, CorrelatedSpec};

    fn problem() -> (Design, Vec<f64>, f64) {
        let ds = correlated(CorrelatedSpec { n: 150, p: 200, rho: 0.4, nnz: 10, snr: 10.0 }, 0);
        let mut design = ds.design.clone();
        design.normalize_cols((150.0f64).sqrt());
        let mut xty = vec![0.0; 200];
        design.matvec_t(&ds.y, &mut xty);
        let lam = crate::linalg::norm_inf(&xty) / 150.0 / 10.0;
        (design, ds.y, lam)
    }

    #[test]
    fn objective_decreases_across_reweightings() {
        let (d, y, lam) = problem();
        let res = solve_irls_mcp(&d, &y, lam, 3.0, 8, &SolverOpts::default().with_tol(1e-9));
        for w in res.history.windows(2) {
            assert!(
                w[1].objective <= w[0].objective + 1e-9,
                "MM must not increase the MCP objective: {} -> {}",
                w[0].objective,
                w[1].objective
            );
        }
    }

    #[test]
    fn reaches_comparable_objective_to_skglm_mcp() {
        let (d, y, lam) = problem();
        let irls = solve_irls_mcp(&d, &y, lam, 3.0, 10, &SolverOpts::default().with_tol(1e-9));
        let mut f = Quadratic::new();
        let sk = solve(
            &d,
            &y,
            &mut f,
            &Mcp::new(lam, 3.0),
            &SolverOpts::default().with_tol(1e-9),
            None,
            None,
        );
        // both reach critical points; objectives should be in the same
        // ballpark (skglm typically at least as good — Fig. 5)
        assert!(
            sk.objective <= irls.objective + 1e-6,
            "skglm {} vs irls {}",
            sk.objective,
            irls.objective
        );
    }
}
