//! The **single** working-set outer loop (paper Algorithm 1), generic over
//! block structure.
//!
//! Every solver topology built on working sets — scalar CD (`skglm.rs`),
//! the screened Lasso fast path (`screening.rs`), grouped and multitask
//! block CD (`block_cd.rs`) — instantiates [`BlockCoords`] and runs through
//! [`solve_outer`]. The loop owns, once:
//!
//! 1. the optional per-iteration screening hook (gap-safe certificates),
//! 2. the scoring pass → stop test (`max_b score_b ≤ ε`),
//! 3. working-set growth `ws_size = max(ws_size, 2·|gsupp|)` and selection
//!    (top scores, generalized support always retained),
//! 4. delegation to the instantiation's Anderson-accelerated inner solver,
//! 5. the convergence history.
//!
//! What varies per instantiation — how a block is scored, proxed, frozen
//! and swept — lives behind the trait; the control flow does not fork.

use super::inner::{InnerProfile, InnerStats};
use super::skglm::{HistoryPoint, SolverOpts, StopReason};
use std::time::Instant;

/// One problem instance viewed as blocks of coordinates: the contract the
/// generic outer loop drives. The implementor owns the iterate, the
/// datafit state and every scratch buffer; the loop only sees block
/// scores, generalized-support membership and inner-solve delegation.
pub trait BlockCoords {
    /// Number of blocks (p for scalar solvers, #groups, p for multitask).
    fn n_blocks(&self) -> usize;

    /// Optional screening pass, run at the top of every outer iteration
    /// *before* scoring (gap-safe certificates tighten as the gap
    /// shrinks). Implementations must report screened blocks as
    /// `-∞` scores in [`BlockCoords::score_pass`]. Default: no-op.
    fn screen(&mut self) {}

    /// The O(n·p) scoring pass: fill `scores[b]` with the per-block
    /// subdifferential distance (`-∞` = excluded: frozen/screened/empty)
    /// and return the max — the KKT surrogate the stop test uses.
    fn score_pass(&mut self, scores: &mut [f64]) -> f64;

    /// Objective at the current iterate (history/verbose reporting).
    fn objective(&self) -> f64;

    /// Is block `b` in the generalized support (always retained in the
    /// working set)?
    fn in_gsupp(&self, b: usize) -> bool;

    /// Run the instantiation's inner solver (Algorithm 2) on `ws`.
    fn inner_solve(&mut self, ws: &[usize], inner_tol: f64, opts: &SolverOpts) -> InnerStats;

    /// Final optimality metric over every non-excluded block (the exact
    /// KKT/gap check reported to callers after the loop exits).
    fn final_kkt(&mut self) -> f64;

    /// Tag used in verbose per-iteration prints.
    fn label(&self) -> &'static str {
        "skglm"
    }
}

/// What [`solve_outer`] hands back — the instantiation-independent part of
/// a fit result (the caller adds its own coefficient payload).
#[derive(Clone, Debug)]
pub struct OuterOutcome {
    pub objective: f64,
    /// final max optimality violation ([`BlockCoords::final_kkt`])
    pub kkt: f64,
    pub n_outer: usize,
    pub n_epochs: usize,
    pub converged: bool,
    pub history: Vec<HistoryPoint>,
    pub accepted_extrapolations: usize,
    pub rejected_extrapolations: usize,
    /// working-set size the loop ended with (path continuation)
    pub ws_size: usize,
    /// per-stage attribution: inner-solve profiles merged, plus the outer
    /// scoring passes and the final KKT pass under `score_secs`
    pub profile: InnerProfile,
    /// `Some` when a [`super::skglm::SolveBudget`] stopped the loop before
    /// convergence; the objective/kkt fields still describe the partial
    /// iterate.
    pub stopped: Option<StopReason>,
}

/// Run Algorithm 1's outer loop over `coords`. `ws0` seeds the working-set
/// size (path continuation).
pub fn solve_outer<C: BlockCoords>(
    coords: &mut C,
    opts: &SolverOpts,
    ws0: Option<usize>,
) -> OuterOutcome {
    let start = Instant::now();
    let nb = coords.n_blocks();
    let mut scores = vec![0.0; nb];
    let mut out = OuterOutcome {
        objective: f64::NAN,
        kkt: f64::NAN,
        n_outer: 0,
        n_epochs: 0,
        converged: false,
        history: Vec::new(),
        accepted_extrapolations: 0,
        rejected_extrapolations: 0,
        ws_size: ws0.unwrap_or(opts.ws_start).min(nb).max(1),
        profile: InnerProfile::default(),
        stopped: None,
    };

    for outer in 1..=opts.max_outer {
        if let Some(budget) = &opts.budget {
            if let Some(reason) = budget.check(out.n_epochs) {
                out.stopped = Some(reason);
                break;
            }
        }
        out.n_outer = outer;
        coords.screen();

        // ---- scoring pass (the O(n·p) hot spot) ----
        let t_score = Instant::now();
        let kkt_max = coords.score_pass(&mut scores);
        out.profile.score_secs += t_score.elapsed().as_secs_f64();
        let objective = coords.objective();
        let shown_ws = if opts.use_ws { out.ws_size.min(nb) } else { nb };
        out.history.push(HistoryPoint {
            t: start.elapsed().as_secs_f64(),
            objective,
            kkt: kkt_max,
            ws_size: shown_ws,
        });
        if opts.verbose {
            eprintln!(
                "[{}] outer {outer:3}  obj {objective:.6e}  kkt {kkt_max:.3e}  ws {shown_ws}",
                coords.label()
            );
        }
        if kkt_max <= opts.tol {
            out.converged = true;
            break;
        }

        // ---- working-set selection ----
        let ws: Vec<usize> = if opts.use_ws {
            let gsupp = (0..nb).filter(|&b| coords.in_gsupp(b)).count();
            out.ws_size = out.ws_size.max(2 * gsupp).min(nb);
            select_working_set(&mut scores, out.ws_size, |b| coords.in_gsupp(b))
        } else {
            (0..nb).filter(|&b| scores[b] > f64::NEG_INFINITY).collect()
        };
        if ws.is_empty() {
            // every remaining block is excluded/converged
            out.converged = true;
            break;
        }

        // ---- inner solve (Algorithm 2) ----
        let inner_tol = (opts.inner_tol_ratio * kkt_max).max(0.1 * opts.tol);
        let stats = coords.inner_solve(&ws, inner_tol, opts);
        out.n_epochs += stats.epochs;
        out.accepted_extrapolations += stats.accepted_extrapolations;
        out.rejected_extrapolations += stats.rejected_extrapolations;
        out.profile.merge(&stats.profile);
    }

    let t_final = Instant::now();
    out.kkt = coords.final_kkt();
    out.profile.score_secs += t_final.elapsed().as_secs_f64();
    out.converged = out.converged || out.kkt <= opts.tol;
    out.objective = coords.objective();
    out
}

/// Take the `k` highest-scoring blocks, always retaining the current
/// generalized support (their scores are lifted to +∞ first). Blocks
/// scored `-∞` (frozen by screening) are never selected. `scores` is
/// clobbered. Returned set is sorted ascending (cyclic CD sweeps in
/// index order).
pub fn select_working_set(
    scores: &mut [f64],
    k: usize,
    in_gsupp: impl Fn(usize) -> bool,
) -> Vec<usize> {
    let nb = scores.len();
    for (b, s) in scores.iter_mut().enumerate() {
        if in_gsupp(b) {
            *s = f64::INFINITY;
        }
    }
    let k = k.min(nb);
    let mut idx: Vec<usize> = (0..nb).collect();
    if k < nb && k > 0 {
        idx.select_nth_unstable_by(k - 1, |&a, &b| {
            scores[b].partial_cmp(&scores[a]).unwrap_or(std::cmp::Ordering::Equal)
        });
        idx.truncate(k);
    }
    idx.retain(|&b| scores[b] > f64::NEG_INFINITY);
    idx.sort_unstable();
    idx
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn selection_keeps_support_and_top_scores() {
        let beta = [0.0, 2.0, 0.0, 0.0, -1.0];
        let mut scores = vec![0.5, 0.0, 3.0, 0.1, 0.0];
        let ws = select_working_set(&mut scores, 3, |b| beta[b] != 0.0);
        // support {1, 4} forced in; top remaining score is block 2
        assert_eq!(ws, vec![1, 2, 4]);
    }

    #[test]
    fn selection_drops_frozen_blocks() {
        let mut scores = vec![f64::NEG_INFINITY, 1.0, f64::NEG_INFINITY, 0.5];
        let ws = select_working_set(&mut scores, 4, |_| false);
        assert_eq!(ws, vec![1, 3]);
    }

    /// A tiny separable quadratic `½Σ(v_b − t_b)²` with an ℓ1-ish score:
    /// enough to drive the loop end-to-end without a Design.
    struct Toy {
        v: Vec<f64>,
        target: Vec<f64>,
        epochs: usize,
    }

    impl BlockCoords for Toy {
        fn n_blocks(&self) -> usize {
            self.v.len()
        }
        fn score_pass(&mut self, scores: &mut [f64]) -> f64 {
            let mut m = 0.0f64;
            for (b, s) in scores.iter_mut().enumerate() {
                *s = (self.v[b] - self.target[b]).abs();
                m = m.max(*s);
            }
            m
        }
        fn objective(&self) -> f64 {
            self.v
                .iter()
                .zip(self.target.iter())
                .map(|(v, t)| 0.5 * (v - t) * (v - t))
                .sum()
        }
        fn in_gsupp(&self, b: usize) -> bool {
            self.v[b] != 0.0
        }
        fn inner_solve(&mut self, ws: &[usize], _tol: f64, _opts: &SolverOpts) -> InnerStats {
            for &b in ws {
                self.v[b] = self.target[b];
            }
            self.epochs += 1;
            InnerStats { epochs: 1, ..Default::default() }
        }
        fn final_kkt(&mut self) -> f64 {
            let mut s = vec![0.0; self.n_blocks()];
            self.score_pass(&mut s)
        }
    }

    #[test]
    fn loop_converges_on_toy_problem() {
        let mut toy = Toy { v: vec![0.0; 6], target: vec![1.0, 0.0, -2.0, 0.0, 3.0, 0.5], epochs: 0 };
        let opts = SolverOpts { ws_start: 2, tol: 1e-12, ..Default::default() };
        let out = solve_outer(&mut toy, &opts, None);
        assert!(out.converged);
        assert!(out.kkt <= 1e-12);
        assert_eq!(toy.v, toy.target);
        assert!(out.n_outer >= 2, "ws growth should take multiple iterations");
        assert_eq!(out.history.len(), out.n_outer);
    }

    #[test]
    fn epoch_budget_stops_with_partial_iterate() {
        use super::super::skglm::SolveBudget;
        let mut toy = Toy { v: vec![0.0; 6], target: vec![1.0; 6], epochs: 0 };
        let opts = SolverOpts {
            ws_start: 1,
            tol: 1e-12,
            budget: Some(SolveBudget { max_total_epochs: Some(1), ..Default::default() }),
            ..Default::default()
        };
        let out = solve_outer(&mut toy, &opts, None);
        assert_eq!(out.stopped, Some(StopReason::EpochBudget));
        assert!(!out.converged);
        assert!(out.objective.is_finite(), "partial objective must be reported");
        assert!(out.kkt.is_finite(), "partial certificate must be reported");
        assert!(toy.v != toy.target, "budget must have stopped the loop early");
    }

    #[test]
    fn cancel_flag_stops_before_first_iteration() {
        use super::super::skglm::SolveBudget;
        use std::sync::atomic::{AtomicBool, Ordering};
        use std::sync::Arc;
        let flag = Arc::new(AtomicBool::new(false));
        flag.store(true, Ordering::Relaxed);
        let mut toy = Toy { v: vec![0.0; 4], target: vec![1.0; 4], epochs: 0 };
        let opts = SolverOpts {
            budget: Some(SolveBudget { cancel: Some(flag), ..Default::default() }),
            ..Default::default()
        };
        let out = solve_outer(&mut toy, &opts, None);
        assert_eq!(out.stopped, Some(StopReason::Cancelled));
        assert_eq!(out.n_outer, 0);
        assert_eq!(toy.epochs, 0, "no inner work after cancellation");
    }

    #[test]
    fn ws0_seeds_working_set_size() {
        let mut toy = Toy { v: vec![0.0; 6], target: vec![1.0; 6], epochs: 0 };
        let opts = SolverOpts { tol: 1e-12, ..Default::default() };
        let out = solve_outer(&mut toy, &opts, Some(6));
        assert!(out.converged);
        assert_eq!(out.n_outer, 2, "full seed converges after one inner solve");
    }
}
