//! Gap-Safe screening rules (Ndiaye et al. 2017) for the Lasso / elastic
//! net — the convex-only feature-elimination technique the paper contrasts
//! working sets against (§1: "screening rules discard features from the
//! problem ... dynamically").
//!
//! Sphere test: with a feasible dual point `θ` and duality gap `G`, every
//! feature with
//!
//! ```text
//! |X_jᵀθ| + ‖X_j‖ · √(2G/λ²n)  <  1
//! ```
//!
//! is certifiably inactive at the optimum and can be *removed from the
//! problem* (not merely deprioritised). This composes with the working-set
//! solver: screened features never re-enter, shrinking every later scoring
//! pass. Unlike the skglm score it is convex/duality-bound — exactly the
//! paper's motivation for the generic subdifferential score.

use crate::linalg::Design;

/// Result of one dynamic screening pass.
#[derive(Clone, Debug)]
pub struct ScreenResult {
    /// features certified inactive (β̂_j = 0 at every optimum)
    pub screened: Vec<bool>,
    /// number screened
    pub n_screened: usize,
    /// the duality gap used for the certificate
    pub gap: f64,
}

/// Gap-safe sphere test for the Lasso at the point `beta` with residual
/// `r = y − Xβ`. `xtr` must be `Xᵀr` (reused from the scoring pass when
/// available). Features already known screened stay screened (monotone).
pub fn gap_safe_screen_lasso(
    design: &Design,
    y: &[f64],
    beta: &[f64],
    r: &[f64],
    xtr: &[f64],
    lambda: f64,
    col_norms: &[f64],
    prev: Option<&[bool]>,
) -> ScreenResult {
    let n = design.nrows() as f64;
    let p = design.ncols();
    let gap = crate::metrics::lasso_gap(design, y, beta, r, lambda);
    // dual point θ = r / max(nλ, ‖Xᵀr‖∞); radius √(2G)/ (λ√n)
    let scale = (n * lambda).max(crate::linalg::norm_inf(xtr));
    let radius = (2.0 * gap).sqrt() / (lambda * n.sqrt());
    let mut screened = vec![false; p];
    let mut count = 0;
    for j in 0..p {
        let carried = prev.map(|s| s[j]).unwrap_or(false);
        let test = carried
            || (xtr[j] / scale).abs() + col_norms[j] * radius < 1.0;
        screened[j] = test;
        if test {
            count += 1;
        }
    }
    ScreenResult { screened, n_screened: count, gap }
}

/// Lasso solve with dynamic gap-safe screening layered on the working-set
/// solver: every outer iteration first screens, then restricts scoring and
/// the working set to the survivors. Returns the fit plus screening stats.
pub fn solve_lasso_screened(
    design: &Design,
    y: &[f64],
    lambda: f64,
    opts: &crate::solver::SolverOpts,
) -> (crate::solver::FitResult, usize) {
    let mut state = crate::solver::ContinuationState::default();
    solve_lasso_screened_warm(design, y, lambda, opts, &mut state, None)
}

/// [`solve_lasso_screened`] with path continuation: warm β and working-set
/// size come from (and go back into) `continuation`, and the cached Gram
/// diagonal skips the per-fit column-norm pass. The screening mask is
/// rebuilt for **this** λ — certificates are λ-specific, so masks never
/// carry across path points — and grows monotonically within the solve as
/// the duality gap shrinks (at a warm start the gap between neighbouring
/// λs is far too large to certify anything; near convergence it certifies
/// most inactive features). A newly certified feature still holding a
/// nonzero warm value is zeroed — with the residual updated — so the
/// restricted problem stays consistent with the certificate.
pub fn solve_lasso_screened_warm(
    design: &Design,
    y: &[f64],
    lambda: f64,
    opts: &crate::solver::SolverOpts,
    continuation: &mut crate::solver::ContinuationState,
    col_sq_norms: Option<&[f64]>,
) -> (crate::solver::FitResult, usize) {
    use crate::datafit::{Datafit, Quadratic};
    use crate::penalty::{Penalty, L1};
    use crate::solver::inner::inner_solver;

    let p = design.ncols();
    let n = design.nrows() as f64;
    let mut datafit = Quadratic::new();
    datafit.init_cached(design, y, col_sq_norms);
    let penalty = L1::new(lambda);
    let col_norms: Vec<f64> = match col_sq_norms {
        Some(sq) => sq.iter().map(|s| s.sqrt()).collect(),
        None => design.col_sq_norms().iter().map(|s| s.sqrt()).collect(),
    };

    let mut beta = continuation.beta.clone().unwrap_or_else(|| vec![0.0; p]);
    assert_eq!(beta.len(), p);
    let mut state = datafit.init_state(design, y, &beta); // Xβ − y
    let mut xtr = vec![0.0; p];
    let mut screened: Option<Vec<bool>> = None;
    let start = std::time::Instant::now();
    let mut result = crate::solver::FitResult {
        beta: Vec::new(),
        objective: f64::NAN,
        kkt: f64::NAN,
        n_outer: 0,
        n_epochs: 0,
        converged: false,
        history: Vec::new(),
        accepted_extrapolations: 0,
        rejected_extrapolations: 0,
    };
    let mut ws_size = continuation.ws_size.unwrap_or(opts.ws_start).min(p).max(1);

    for outer in 1..=opts.max_outer {
        result.n_outer = outer;
        design.matvec_t(&state, &mut xtr);
        for v in xtr.iter_mut() {
            *v = -*v; // Xᵀr with r = y − Xβ
        }
        let mut r: Vec<f64> = state.iter().map(|&s| -s).collect();
        let sc = gap_safe_screen_lasso(
            design, y, &beta, &r, &xtr, lambda, &col_norms, screened.as_deref(),
        );
        // newly certified features still holding a (warm-start) value are
        // frozen AT ZERO; the residual moves, so refresh r and Xᵀr
        let mut moved = false;
        for j in 0..p {
            if sc.screened[j] && beta[j] != 0.0 {
                datafit.update_state(design, j, -beta[j], &mut state);
                beta[j] = 0.0;
                moved = true;
            }
        }
        if moved {
            design.matvec_t(&state, &mut xtr);
            for v in xtr.iter_mut() {
                *v = -*v;
            }
            r = state.iter().map(|&s| -s).collect();
        }
        // KKT over the survivors only (screened features are certified)
        let mut kkt_max = 0.0f64;
        let mut scores = vec![0.0; p];
        for j in 0..p {
            if sc.screened[j] || col_norms[j] == 0.0 {
                scores[j] = f64::NEG_INFINITY;
                continue;
            }
            let s = penalty.subdiff_distance(beta[j], -xtr[j] / n, j);
            scores[j] = s;
            kkt_max = kkt_max.max(s);
        }
        result.history.push(crate::solver::HistoryPoint {
            t: start.elapsed().as_secs_f64(),
            objective: crate::linalg::sq_nrm2(&r) / (2.0 * n)
                + lambda * crate::linalg::norm1(&beta),
            kkt: kkt_max,
            ws_size: p - sc.n_screened,
        });
        screened = Some(sc.screened);
        if kkt_max <= opts.tol {
            result.converged = true;
            break;
        }
        // working set among survivors
        let nnz = beta.iter().filter(|&&b| b != 0.0).count();
        ws_size = ws_size.max(2 * nnz).min(p);
        for j in 0..p {
            if beta[j] != 0.0 {
                scores[j] = f64::INFINITY;
            }
        }
        let mut idx: Vec<usize> = (0..p).collect();
        if ws_size < p {
            idx.select_nth_unstable_by(ws_size - 1, |&a, &b| {
                scores[b].partial_cmp(&scores[a]).unwrap_or(std::cmp::Ordering::Equal)
            });
            idx.truncate(ws_size);
        }
        idx.retain(|&j| scores[j] > f64::NEG_INFINITY);
        idx.sort_unstable();
        if idx.is_empty() {
            result.converged = true;
            break;
        }
        let inner_tol = (opts.inner_tol_ratio * kkt_max).max(0.1 * opts.tol);
        let stats = inner_solver(
            design, y, &datafit, &penalty, &mut beta, &mut state, &idx, opts.max_epochs,
            inner_tol, opts.anderson_m,
        );
        result.n_epochs += stats.epochs;
        result.accepted_extrapolations += stats.accepted_extrapolations;
    }

    let r: Vec<f64> = state.iter().map(|&s| -s).collect();
    result.kkt = crate::metrics::lasso_gap(design, y, &beta, &r, lambda);
    result.objective =
        crate::linalg::sq_nrm2(&r) / (2.0 * n) + lambda * crate::linalg::norm1(&beta);
    result.beta = beta;
    continuation.beta = Some(result.beta.clone());
    continuation.ws_size = Some(ws_size);
    let n_screened = screened.map(|s| s.iter().filter(|&&x| x).count()).unwrap_or(0);
    (result, n_screened)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{correlated, CorrelatedSpec};
    use crate::estimators::linear::quadratic_lambda_max;
    use crate::solver::SolverOpts;

    fn problem() -> (Design, Vec<f64>) {
        let ds = correlated(CorrelatedSpec { n: 100, p: 300, rho: 0.4, nnz: 8, snr: 10.0 }, 3);
        (ds.design, ds.y)
    }

    #[test]
    fn screening_is_safe() {
        // no screened feature may be active at the optimum
        let (d, y) = problem();
        let lam = quadratic_lambda_max(&d, &y) / 5.0;
        let exact = crate::estimators::Lasso::new(lam).with_tol(1e-12).fit(&d, &y);
        // screen at a crude iterate (after a short run)
        let mut opts = SolverOpts::default().with_tol(1e-3);
        let crude = crate::estimators::Lasso::new(lam).with_solver(opts.clone()).fit(&d, &y);
        let mut xb = vec![0.0; d.nrows()];
        d.matvec(&crude.beta, &mut xb);
        let r: Vec<f64> = y.iter().zip(xb.iter()).map(|(a, b)| a - b).collect();
        let mut xtr = vec![0.0; d.ncols()];
        d.matvec_t(&r, &mut xtr);
        let col_norms: Vec<f64> = d.col_sq_norms().iter().map(|s| s.sqrt()).collect();
        let sc = gap_safe_screen_lasso(&d, &y, &crude.beta, &r, &xtr, lam, &col_norms, None);
        assert!(sc.n_screened > 0, "high lambda should screen something");
        for (j, &s) in sc.screened.iter().enumerate() {
            if s {
                assert_eq!(exact.beta[j], 0.0, "screened feature {j} is active!");
            }
        }
        opts.tol = 1e-12; // silence unused warning path
        let _ = opts;
    }

    #[test]
    fn screened_solver_matches_unscreened_optimum() {
        let (d, y) = problem();
        let lam = quadratic_lambda_max(&d, &y) / 10.0;
        let (fit, n_screened) =
            solve_lasso_screened(&d, &y, lam, &SolverOpts::default().with_tol(1e-10));
        assert!(fit.converged || fit.kkt < 1e-9);
        let plain = crate::estimators::Lasso::new(lam).with_tol(1e-10).fit(&d, &y);
        assert!(
            (fit.objective - plain.objective).abs() < 1e-9,
            "screened {} vs plain {}",
            fit.objective,
            plain.objective
        );
        assert!(n_screened > 0, "should have certified some features away");
    }

    #[test]
    fn screening_monotone_and_stronger_at_high_lambda() {
        let (d, y) = problem();
        let lam_max = quadratic_lambda_max(&d, &y);
        let count_at = |div: f64| {
            let (_, n) = solve_lasso_screened(
                &d,
                &y,
                lam_max / div,
                &SolverOpts::default().with_tol(1e-8),
            );
            n
        };
        let high = count_at(2.0);
        let low = count_at(50.0);
        assert!(high >= low, "screening weaker at high lambda? {high} vs {low}");
    }
}
