//! Gap-Safe screening rules (Ndiaye et al. 2017) for the Lasso / elastic
//! net — the convex-only feature-elimination technique the paper contrasts
//! working sets against (§1: "screening rules discard features from the
//! problem ... dynamically").
//!
//! Sphere test: with a feasible dual point `θ` and duality gap `G`, every
//! feature with
//!
//! ```text
//! |X_jᵀθ| + ‖X_j‖ · √(2G/λ²n)  <  1
//! ```
//!
//! is certifiably inactive at the optimum and can be *removed from the
//! problem* (not merely deprioritised). This composes with the working-set
//! solver: screened features never re-enter, shrinking every later scoring
//! pass. Unlike the skglm score it is convex/duality-bound — exactly the
//! paper's motivation for the generic subdifferential score.

use crate::linalg::Design;

/// Result of one dynamic screening pass.
#[derive(Clone, Debug)]
pub struct ScreenResult {
    /// features certified inactive (β̂_j = 0 at every optimum)
    pub screened: Vec<bool>,
    /// number screened
    pub n_screened: usize,
    /// the duality gap used for the certificate
    pub gap: f64,
}

/// Gap-safe sphere test for the Lasso at the point `beta` with residual
/// `r = y − Xβ`. `xtr` must be `Xᵀr` (reused from the scoring pass when
/// available). Features already known screened stay screened (monotone).
pub fn gap_safe_screen_lasso(
    design: &Design,
    y: &[f64],
    beta: &[f64],
    r: &[f64],
    xtr: &[f64],
    lambda: f64,
    col_norms: &[f64],
    prev: Option<&[bool]>,
) -> ScreenResult {
    let mut screened = vec![false; design.ncols()];
    if let Some(prev) = prev {
        screened.copy_from_slice(prev);
    }
    let (n_screened, gap) = gap_safe_screen_lasso_update(
        design, y, beta, r, xtr, lambda, col_norms, &mut screened,
    );
    ScreenResult { screened, n_screened, gap }
}

/// Buffer-reusing core of [`gap_safe_screen_lasso`]: updates the monotone
/// `screened` mask in place (a screened feature stays screened) and
/// returns `(total screened, duality gap)`. Callers sweeping a λ grid
/// reset the mask between λ points — certificates are λ-specific.
#[allow(clippy::too_many_arguments)]
pub fn gap_safe_screen_lasso_update(
    design: &Design,
    y: &[f64],
    beta: &[f64],
    r: &[f64],
    xtr: &[f64],
    lambda: f64,
    col_norms: &[f64],
    screened: &mut [bool],
) -> (usize, f64) {
    let n = design.nrows() as f64;
    let p = design.ncols();
    assert_eq!(screened.len(), p);
    let gap = crate::metrics::lasso_gap(design, y, beta, r, lambda);
    // dual point θ = r / max(nλ, ‖Xᵀr‖∞); radius √(2G)/ (λ√n)
    let scale = (n * lambda).max(crate::linalg::norm_inf(xtr));
    let radius = (2.0 * gap).sqrt() / (lambda * n.sqrt());
    let mut count = 0;
    for j in 0..p {
        let test = screened[j] || (xtr[j] / scale).abs() + col_norms[j] * radius < 1.0;
        screened[j] = test;
        if test {
            count += 1;
        }
    }
    (count, gap)
}

/// Reusable buffers for the screened path solver: the per-λ loop of a path
/// job allocates these once per sweep instead of once per solve (and per
/// outer pass for the mask) — the allocation-churn satellite of ISSUE 2.
#[derive(Clone, Debug, Default)]
pub struct ScreenWorkspace {
    xtr: Vec<f64>,
    r: Vec<f64>,
    col_norms: Vec<f64>,
    screened: Vec<bool>,
}

impl ScreenWorkspace {
    pub fn new() -> Self {
        Self::default()
    }

    /// Size (or re-size) every buffer for an (n, p) problem and clear the
    /// λ-specific screening mask.
    fn reset(&mut self, n: usize, p: usize) {
        self.xtr.clear();
        self.xtr.resize(p, 0.0);
        self.col_norms.clear();
        self.col_norms.resize(p, 0.0);
        self.screened.clear();
        self.screened.resize(p, false);
        self.r.clear();
        self.r.resize(n, 0.0);
    }
}

/// Lasso solve with dynamic gap-safe screening layered on the working-set
/// solver: every outer iteration first screens, then restricts scoring and
/// the working set to the survivors. Returns the fit plus screening stats.
pub fn solve_lasso_screened(
    design: &Design,
    y: &[f64],
    lambda: f64,
    opts: &crate::solver::SolverOpts,
) -> (crate::solver::FitResult, usize) {
    let mut state = crate::solver::ContinuationState::default();
    solve_lasso_screened_warm(design, y, lambda, opts, &mut state, None)
}

/// [`solve_lasso_screened`] with path continuation: warm β and working-set
/// size come from (and go back into) `continuation`, and the cached Gram
/// diagonal skips the per-fit column-norm pass. The screening mask is
/// rebuilt for **this** λ — certificates are λ-specific, so masks never
/// carry across path points — and grows monotonically within the solve as
/// the duality gap shrinks (at a warm start the gap between neighbouring
/// λs is far too large to certify anything; near convergence it certifies
/// most inactive features). A newly certified feature still holding a
/// nonzero warm value is zeroed — with the residual updated — so the
/// restricted problem stays consistent with the certificate.
pub fn solve_lasso_screened_warm(
    design: &Design,
    y: &[f64],
    lambda: f64,
    opts: &crate::solver::SolverOpts,
    continuation: &mut crate::solver::ContinuationState,
    col_sq_norms: Option<&[f64]>,
) -> (crate::solver::FitResult, usize) {
    let mut work = ScreenWorkspace::new();
    solve_lasso_screened_warm_with(design, y, lambda, opts, continuation, col_sq_norms, &mut work)
}

/// [`solve_lasso_screened_warm`] with caller-owned scratch buffers: the
/// path scheduler's per-λ loop keeps one [`ScreenWorkspace`] for the whole
/// sweep, so no per-solve `Xᵀr` / residual / mask / score allocations
/// survive on the hot path.
pub fn solve_lasso_screened_warm_with(
    design: &Design,
    y: &[f64],
    lambda: f64,
    opts: &crate::solver::SolverOpts,
    continuation: &mut crate::solver::ContinuationState,
    col_sq_norms: Option<&[f64]>,
    work: &mut ScreenWorkspace,
) -> (crate::solver::FitResult, usize) {
    use crate::datafit::{Datafit, Quadratic};
    use crate::solver::gram::{EngineDispatch, InnerEngine};
    use crate::solver::outer::solve_outer;

    let p = design.ncols();
    work.reset(design.nrows(), p);
    let mut datafit = Quadratic::new();
    datafit.init_cached(design, y, col_sq_norms);
    // the sweep-shared Gram store (blocks persist across λ points; the
    // coordinator installs its per-design cache here instead)
    if continuation.gram.is_none() && opts.inner != InnerEngine::Residual {
        continuation.gram =
            Some(std::sync::Arc::new(crate::linalg::gram::GramCache::with_default_budget()));
    }
    match col_sq_norms {
        Some(sq) => {
            assert_eq!(sq.len(), p, "cached col_sq_norms does not match the design");
            for (o, s) in work.col_norms.iter_mut().zip(sq.iter()) {
                *o = s.sqrt();
            }
        }
        None => {
            design.col_sq_norms_into(&mut work.col_norms);
            for v in work.col_norms.iter_mut() {
                *v = v.sqrt();
            }
        }
    }

    let beta = continuation.beta.clone().unwrap_or_else(|| vec![0.0; p]);
    assert_eq!(beta.len(), p);
    let state = datafit.init_state(design, y, &beta); // Xβ − y
    let mut coords = ScreenedLassoCoords {
        design,
        y,
        datafit,
        penalty: crate::penalty::L1::new(lambda),
        lambda,
        beta,
        state,
        work,
        xtr_fresh: false,
        n_screened: 0,
        gram: continuation.gram.clone(),
        dispatch: EngineDispatch::new(opts.inner),
    };
    let out = solve_outer(&mut coords, opts, continuation.ws_size);
    let result = crate::solver::FitResult {
        beta: coords.beta,
        objective: out.objective,
        kkt: out.kkt,
        // ScreenedLassoCoords::final_kkt is the Lasso duality gap
        certificate: crate::solver::skglm::Certificate::DualityGap,
        n_outer: out.n_outer,
        n_epochs: out.n_epochs,
        converged: out.converged,
        history: out.history,
        accepted_extrapolations: out.accepted_extrapolations,
        rejected_extrapolations: out.rejected_extrapolations,
        profile: out.profile,
    };
    continuation.beta = Some(result.beta.clone());
    continuation.ws_size = Some(out.ws_size);
    (result, coords.n_screened)
}

/// The screened-Lasso [`crate::solver::outer::BlockCoords`]
/// instantiation: the shared outer loop
/// with the gap-safe sphere test as its per-iteration screening hook. The
/// `Xᵀr` pass computed for screening is reused by the scoring pass (one
/// O(n·p) kernel per outer iteration, as before the refactor); the final
/// optimality metric is the Lasso duality gap.
struct ScreenedLassoCoords<'a, 'w> {
    design: &'a Design,
    y: &'a [f64],
    datafit: crate::datafit::Quadratic,
    penalty: crate::penalty::L1,
    lambda: f64,
    beta: Vec<f64>,
    /// Xβ − y (the quadratic datafit state)
    state: Vec<f64>,
    work: &'w mut ScreenWorkspace,
    /// work.xtr/work.r match the current state (screen → score reuse)
    xtr_fresh: bool,
    n_screened: usize,
    /// sweep-shared working-set Gram store (inner-engine dispatch)
    gram: Option<std::sync::Arc<crate::linalg::gram::GramCache>>,
    /// per-inner-solve engine selection (cost model + epoch feedback)
    dispatch: crate::solver::gram::EngineDispatch,
}

impl ScreenedLassoCoords<'_, '_> {
    fn refresh_xtr(&mut self) {
        if self.xtr_fresh {
            return;
        }
        self.design.matvec_t(&self.state, &mut self.work.xtr);
        for v in self.work.xtr.iter_mut() {
            *v = -*v; // Xᵀr with r = y − Xβ
        }
        for (ri, &s) in self.work.r.iter_mut().zip(self.state.iter()) {
            *ri = -s;
        }
        self.xtr_fresh = true;
    }
}

impl crate::solver::outer::BlockCoords for ScreenedLassoCoords<'_, '_> {
    fn n_blocks(&self) -> usize {
        self.design.ncols()
    }

    fn screen(&mut self) {
        use crate::datafit::Datafit;
        self.refresh_xtr();
        let (count, _gap) = gap_safe_screen_lasso_update(
            self.design,
            self.y,
            &self.beta,
            &self.work.r,
            &self.work.xtr,
            self.lambda,
            &self.work.col_norms,
            &mut self.work.screened,
        );
        self.n_screened = count;
        // newly certified features still holding a (warm-start) value are
        // frozen AT ZERO; the residual moves, so refresh r and Xᵀr
        let mut moved = false;
        for j in 0..self.beta.len() {
            if self.work.screened[j] && self.beta[j] != 0.0 {
                self.datafit.update_state(self.design, j, -self.beta[j], &mut self.state);
                self.beta[j] = 0.0;
                moved = true;
            }
        }
        if moved {
            self.xtr_fresh = false;
            self.refresh_xtr();
        }
    }

    fn score_pass(&mut self, scores: &mut [f64]) -> f64 {
        use crate::penalty::Penalty;
        self.refresh_xtr();
        let n = self.design.nrows() as f64;
        // KKT over the survivors only (screened features are certified)
        let mut kkt_max = 0.0f64;
        for (j, out) in scores.iter_mut().enumerate() {
            if self.work.screened[j] || self.work.col_norms[j] == 0.0 {
                *out = f64::NEG_INFINITY;
                continue;
            }
            let s = self.penalty.subdiff_distance(self.beta[j], -self.work.xtr[j] / n, j);
            *out = s;
            kkt_max = kkt_max.max(s);
        }
        kkt_max
    }

    fn objective(&self) -> f64 {
        let n = self.design.nrows() as f64;
        crate::linalg::sq_nrm2(&self.state) / (2.0 * n)
            + self.lambda * crate::linalg::norm1(&self.beta)
    }

    fn in_gsupp(&self, j: usize) -> bool {
        self.beta[j] != 0.0
    }

    fn inner_solve(
        &mut self,
        ws: &[usize],
        inner_tol: f64,
        opts: &crate::solver::SolverOpts,
    ) -> crate::solver::inner::InnerStats {
        use crate::datafit::Datafit;
        self.xtr_fresh = false;
        let quad_scale = self.datafit.residual_quadratic_scale();
        let use_gram =
            self.dispatch.use_gram(self.design, ws, self.gram.as_deref(), quad_scale.is_some());
        let stats = if use_gram {
            crate::solver::gram::gram_inner_solver(
                self.design,
                self.datafit.lipschitz(),
                quad_scale.expect("use_gram implies the Gram contract"),
                &self.penalty,
                &mut self.beta,
                &mut self.state,
                ws,
                self.gram.as_ref().expect("use_gram implies a store"),
                opts.max_epochs,
                inner_tol,
                opts.anderson_m,
            )
        } else {
            crate::solver::inner::inner_solver(
                self.design,
                self.y,
                &self.datafit,
                &self.penalty,
                &mut self.beta,
                &mut self.state,
                ws,
                opts.max_epochs,
                inner_tol,
                opts.anderson_m,
            )
        };
        self.dispatch.record_epochs(stats.epochs);
        stats
    }

    fn final_kkt(&mut self) -> f64 {
        // the duality gap is the exact certificate reported for screened
        // solves (and what path callers threshold against)
        for (ri, &s) in self.work.r.iter_mut().zip(self.state.iter()) {
            *ri = -s;
        }
        crate::metrics::lasso_gap(self.design, self.y, &self.beta, &self.work.r, self.lambda)
    }

    fn label(&self) -> &'static str {
        "screened-lasso"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{correlated, CorrelatedSpec};
    use crate::estimators::linear::quadratic_lambda_max;
    use crate::solver::SolverOpts;

    fn problem() -> (Design, Vec<f64>) {
        let ds = correlated(CorrelatedSpec { n: 100, p: 300, rho: 0.4, nnz: 8, snr: 10.0 }, 3);
        (ds.design, ds.y)
    }

    #[test]
    fn screening_is_safe() {
        // no screened feature may be active at the optimum
        let (d, y) = problem();
        let lam = quadratic_lambda_max(&d, &y) / 5.0;
        let exact = crate::estimators::Lasso::new(lam).with_tol(1e-12).fit(&d, &y);
        // screen at a crude iterate (after a short run)
        let mut opts = SolverOpts::default().with_tol(1e-3);
        let crude = crate::estimators::Lasso::new(lam).with_solver(opts.clone()).fit(&d, &y);
        let mut xb = vec![0.0; d.nrows()];
        d.matvec(&crude.beta, &mut xb);
        let r: Vec<f64> = y.iter().zip(xb.iter()).map(|(a, b)| a - b).collect();
        let mut xtr = vec![0.0; d.ncols()];
        d.matvec_t(&r, &mut xtr);
        let col_norms: Vec<f64> = d.col_sq_norms().iter().map(|s| s.sqrt()).collect();
        let sc = gap_safe_screen_lasso(&d, &y, &crude.beta, &r, &xtr, lam, &col_norms, None);
        assert!(sc.n_screened > 0, "high lambda should screen something");
        for (j, &s) in sc.screened.iter().enumerate() {
            if s {
                assert_eq!(exact.beta[j], 0.0, "screened feature {j} is active!");
            }
        }
        opts.tol = 1e-12; // silence unused warning path
        let _ = opts;
    }

    #[test]
    fn screened_solver_matches_unscreened_optimum() {
        let (d, y) = problem();
        let lam = quadratic_lambda_max(&d, &y) / 10.0;
        let (fit, n_screened) =
            solve_lasso_screened(&d, &y, lam, &SolverOpts::default().with_tol(1e-10));
        assert!(fit.converged || fit.kkt < 1e-9);
        let plain = crate::estimators::Lasso::new(lam).with_tol(1e-10).fit(&d, &y);
        assert!(
            (fit.objective - plain.objective).abs() < 1e-9,
            "screened {} vs plain {}",
            fit.objective,
            plain.objective
        );
        assert!(n_screened > 0, "should have certified some features away");
    }

    #[test]
    fn workspace_reuse_matches_fresh_solves() {
        // one ScreenWorkspace across a descending λ sweep (what the path
        // scheduler does) must reproduce the fresh-buffer results exactly
        let (d, y) = problem();
        let lam_max = quadratic_lambda_max(&d, &y);
        let opts = SolverOpts::default().with_tol(1e-9);
        let sq = d.col_sq_norms();

        let mut shared = ScreenWorkspace::new();
        let mut cont_a = crate::solver::ContinuationState::default();
        let mut cont_b = crate::solver::ContinuationState::default();
        for div in [2.0, 5.0, 20.0] {
            let lam = lam_max / div;
            let (fit_a, scr_a) = solve_lasso_screened_warm_with(
                &d, &y, lam, &opts, &mut cont_a, Some(&sq), &mut shared,
            );
            let (fit_b, scr_b) =
                solve_lasso_screened_warm(&d, &y, lam, &opts, &mut cont_b, Some(&sq));
            assert_eq!(scr_a, scr_b, "screen counts diverged at λ_max/{div}");
            assert!(
                (fit_a.objective - fit_b.objective).abs() < 1e-12,
                "objectives diverged at λ_max/{div}: {} vs {}",
                fit_a.objective,
                fit_b.objective
            );
            for (a, b) in fit_a.beta.iter().zip(fit_b.beta.iter()) {
                assert!((a - b).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn screening_monotone_and_stronger_at_high_lambda() {
        let (d, y) = problem();
        let lam_max = quadratic_lambda_max(&d, &y);
        let count_at = |div: f64| {
            let (_, n) = solve_lasso_screened(
                &d,
                &y,
                lam_max / div,
                &SolverOpts::default().with_tol(1e-8),
            );
            n
        };
        let high = count_at(2.0);
        let low = count_at(50.0);
        assert!(high >= low, "screening weaker at high lambda? {high} vs {low}");
    }
}
