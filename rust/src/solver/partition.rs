//! Block partitions of the coefficient vector — the abstraction that lets
//! one block-coordinate engine host the scalar, grouped and multitask
//! solvers (paper Appendix D: `g(W) = Σ_j φ(‖W_j‖)`).
//!
//! A partition splits the packed coefficient vector `v` (β for the
//! single-task problems, row-major flattened `W` for multitask) into
//! disjoint blocks of coordinate indices:
//!
//! - **scalar**: p blocks of size 1 — the working-set CD solver of
//!   Algorithm 1 is the block engine instantiated here;
//! - **groups**: arbitrary user-supplied feature groups (structured
//!   sparsity / group lasso);
//! - **multitask**: p uniform blocks of size T — the rows of `W`.
//!
//! Stored CSR-style (`indices` + `offsets`) so arbitrary groups cost one
//! gather per block access while the uniform cases stay cache-friendly
//! contiguous runs.

/// A disjoint, exhaustive partition of `0..dim` into blocks.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct BlockPartition {
    /// concatenated coordinate indices, block by block
    indices: Vec<usize>,
    /// block boundaries into `indices` (`offsets.len() == n_blocks + 1`)
    offsets: Vec<usize>,
    /// total coordinate count (`== indices.len()`)
    dim: usize,
    /// largest block size (scratch-buffer sizing)
    max_block: usize,
}

impl BlockPartition {
    /// The trivial partition: `dim` blocks of size 1 (scalar CD).
    pub fn scalar(dim: usize) -> Self {
        Self::uniform(dim, 1)
    }

    /// `n_blocks` contiguous blocks of `block_size` coordinates each
    /// (multitask rows: `uniform(p, n_tasks)`).
    pub fn uniform(n_blocks: usize, block_size: usize) -> Self {
        assert!(block_size >= 1, "blocks must be non-empty");
        let dim = n_blocks * block_size;
        Self {
            indices: (0..dim).collect(),
            offsets: (0..=n_blocks).map(|b| b * block_size).collect(),
            dim,
            max_block: if n_blocks == 0 { 0 } else { block_size },
        }
    }

    /// Contiguous feature groups of the given sizes covering `0..Σ sizes`
    /// (the common group-lasso layout; the last group may be ragged).
    pub fn contiguous(sizes: &[usize]) -> Self {
        let dim: usize = sizes.iter().sum();
        let mut offsets = Vec::with_capacity(sizes.len() + 1);
        offsets.push(0usize);
        let mut max_block = 0usize;
        for &s in sizes {
            assert!(s >= 1, "blocks must be non-empty");
            max_block = max_block.max(s);
            offsets.push(offsets.last().unwrap() + s);
        }
        Self { indices: (0..dim).collect(), offsets, dim, max_block }
    }

    /// `p` features split into contiguous groups of `group_size` (the last
    /// group keeps the remainder) — the `--groups <size>` CLI layout.
    pub fn contiguous_equal(p: usize, group_size: usize) -> Self {
        assert!(group_size >= 1 && group_size <= p.max(1));
        let full = p / group_size;
        let rem = p - full * group_size;
        let mut sizes = vec![group_size; full];
        if rem > 0 {
            sizes.push(rem);
        }
        Self::contiguous(&sizes)
    }

    /// Arbitrary user-supplied groups. Validates that the groups form a
    /// true partition of `0..dim` (every coordinate in exactly one group).
    pub fn from_groups(groups: &[Vec<usize>], dim: usize) -> Self {
        let mut seen = vec![false; dim];
        let mut indices = Vec::with_capacity(dim);
        let mut offsets = Vec::with_capacity(groups.len() + 1);
        offsets.push(0usize);
        let mut max_block = 0usize;
        for (b, g) in groups.iter().enumerate() {
            assert!(!g.is_empty(), "group {b} is empty");
            for &j in g {
                assert!(j < dim, "group {b} references coordinate {j} >= dim {dim}");
                assert!(!seen[j], "coordinate {j} appears in more than one group");
                seen[j] = true;
                indices.push(j);
            }
            max_block = max_block.max(g.len());
            offsets.push(indices.len());
        }
        assert!(
            seen.iter().all(|&s| s),
            "groups must cover every coordinate in 0..{dim}"
        );
        Self { indices, offsets, dim, max_block }
    }

    #[inline]
    pub fn n_blocks(&self) -> usize {
        self.offsets.len() - 1
    }

    /// Total packed dimension.
    #[inline]
    pub fn dim(&self) -> usize {
        self.dim
    }

    /// Coordinate indices of block `b`.
    #[inline]
    pub fn coords(&self, b: usize) -> &[usize] {
        &self.indices[self.offsets[b]..self.offsets[b + 1]]
    }

    #[inline]
    pub fn block_len(&self, b: usize) -> usize {
        self.offsets[b + 1] - self.offsets[b]
    }

    /// Largest block size (scratch-buffer sizing).
    #[inline]
    pub fn max_block_len(&self) -> usize {
        self.max_block
    }

    /// Range of block `b` in the *packed* (partition-ordered) layout.
    #[inline]
    pub fn packed_range(&self, b: usize) -> std::ops::Range<usize> {
        self.offsets[b]..self.offsets[b + 1]
    }

    /// Block boundaries into the packed layout (kernel chunking).
    #[inline]
    pub fn offsets(&self) -> &[usize] {
        &self.offsets
    }

    /// The flattened coordinate order (grouped linalg kernels:
    /// [`crate::linalg::Design::matvec_t_groups`]).
    #[inline]
    pub fn flat_indices(&self) -> &[usize] {
        &self.indices
    }

    /// All block sizes equal 1 with identity coordinate order — the block
    /// engine then reduces exactly to scalar CD.
    pub fn is_scalar(&self) -> bool {
        self.max_block <= 1 && self.indices.iter().enumerate().all(|(k, &j)| k == j)
    }

    /// Gather `v[coords(b)]` into `out[..block_len(b)]`.
    #[inline]
    pub fn gather(&self, b: usize, v: &[f64], out: &mut [f64]) {
        for (o, &j) in out.iter_mut().zip(self.coords(b).iter()) {
            *o = v[j];
        }
    }

    /// Scatter `vals[..block_len(b)]` back into `v[coords(b)]`.
    #[inline]
    pub fn scatter(&self, b: usize, vals: &[f64], v: &mut [f64]) {
        for (&x, &j) in vals.iter().zip(self.coords(b).iter()) {
            v[j] = x;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalar_partition_is_trivial() {
        let p = BlockPartition::scalar(5);
        assert_eq!(p.n_blocks(), 5);
        assert_eq!(p.dim(), 5);
        assert!(p.is_scalar());
        assert_eq!(p.coords(3), &[3]);
        assert_eq!(p.max_block_len(), 1);
    }

    #[test]
    fn uniform_blocks_are_rows() {
        let p = BlockPartition::uniform(3, 4); // 3 rows of W with T=4
        assert_eq!(p.n_blocks(), 3);
        assert_eq!(p.dim(), 12);
        assert_eq!(p.coords(1), &[4, 5, 6, 7]);
        assert!(!p.is_scalar());
    }

    #[test]
    fn contiguous_equal_handles_ragged_tail() {
        let p = BlockPartition::contiguous_equal(10, 4);
        assert_eq!(p.n_blocks(), 3);
        assert_eq!(p.block_len(0), 4);
        assert_eq!(p.block_len(2), 2);
        assert_eq!(p.coords(2), &[8, 9]);
    }

    #[test]
    fn from_groups_accepts_scattered_partitions() {
        let p = BlockPartition::from_groups(&[vec![2, 0], vec![1, 3, 4]], 5);
        assert_eq!(p.n_blocks(), 2);
        assert_eq!(p.coords(0), &[2, 0]);
        assert_eq!(p.max_block_len(), 3);
        let mut buf = [0.0; 3];
        let v = [10.0, 11.0, 12.0, 13.0, 14.0];
        p.gather(1, &v, &mut buf);
        assert_eq!(buf, [11.0, 13.0, 14.0]);
    }

    #[test]
    #[should_panic(expected = "more than one group")]
    fn overlapping_groups_rejected() {
        BlockPartition::from_groups(&[vec![0, 1], vec![1, 2]], 3);
    }

    #[test]
    #[should_panic(expected = "must cover")]
    fn non_covering_groups_rejected() {
        BlockPartition::from_groups(&[vec![0, 1]], 3);
    }

    #[test]
    fn gather_scatter_round_trip() {
        let p = BlockPartition::from_groups(&[vec![3, 1], vec![0, 2]], 4);
        let mut v = [1.0, 2.0, 3.0, 4.0];
        let mut buf = [0.0; 2];
        p.gather(0, &v, &mut buf);
        assert_eq!(buf, [4.0, 2.0]);
        buf[0] = -1.0;
        p.scatter(0, &buf, &mut v);
        assert_eq!(v, [1.0, 2.0, 3.0, -1.0]);
    }
}
