//! The skglm working-set solver (paper Algorithm 1) — the **scalar**
//! instantiation of the shared block-coordinate core.
//!
//! Outer loop (owned by [`crate::solver::outer::solve_outer`], shared with
//! the grouped/multitask block engine and the screened Lasso fast path):
//! 1. score every feature by its optimality violation
//!    `score_j = dist(−∇_j f(β), ∂g_j(β_j))` (Eq. 2; `score^cd` of Eq. 24
//!    for penalties that request it),
//! 2. stop if `max_j score_j ≤ ε`,
//! 3. grow the working set: `ws_size = max(ws_size, 2·|gsupp(β)|)`, take
//!    the `ws_size` features with the largest scores while always
//!    retaining the current generalized support,
//! 4. run the Anderson-accelerated inner solver (Algorithm 2) on the
//!    restricted problem.
//!
//! This module contributes the scalar [`BlockCoords`] implementation: the
//! fused full-gradient scoring pass (step 1) is the only O(n·p) operation
//! — it is the hot spot the L1 Pallas kernel implements; the solver routes
//! it through an optional [`GradEngine`] (PJRT) and falls back to the
//! native datafit path.

use super::gram::{gram_inner_solver, EngineDispatch, InnerEngine};
use super::inner::{inner_solver, InnerProfile, InnerStats};
use super::outer::{solve_outer, BlockCoords};
use crate::datafit::Datafit;
use crate::linalg::gram::GramCache;
use crate::linalg::simd::{self, Precision, ShadowF32};
use crate::linalg::Design;
use crate::penalty::Penalty;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Instant;

/// Pluggable full-gradient engine (the PJRT runtime implements this for
/// dense quadratic scoring; `None`/unsupported shapes fall back to the
/// native `Datafit::grad_full`).
pub trait GradEngine {
    /// Compute the full gradient into `out`. Return false when this
    /// engine cannot serve the request (wrong shape/datafit), in which
    /// case the solver falls back to the native path.
    fn grad_full(
        &mut self,
        design: &Design,
        y: &[f64],
        state: &[f64],
        beta: &[f64],
        out: &mut [f64],
    ) -> bool;

    fn name(&self) -> &'static str;
}

/// Reduced-precision scoring engine: serves the dense quadratic full
/// scan (`∇f = scale · Xᵀ state`) from an f32 shadow of the design.
/// Installed by `solve_prepared` when `SolverOpts::precision` is not
/// f64 and no caller engine is present; every other shape keeps the
/// native f64 path. KKT metrics computed from these gradients carry the
/// precision's quantisation error, which is why reduced modes clamp the
/// tolerance to [`Precision::tol_floor`].
struct ShadowGrad {
    prec: Precision,
    /// `Datafit::residual_quadratic_scale` of the datafit (1/n)
    scale: f64,
    shadow: ShadowF32,
    state32: Vec<f32>,
}

impl GradEngine for ShadowGrad {
    fn grad_full(
        &mut self,
        _design: &Design,
        _y: &[f64],
        state: &[f64],
        _beta: &[f64],
        out: &mut [f64],
    ) -> bool {
        simd::to_f32(state, &mut self.state32);
        simd::shadow_matvec_t(&self.shadow, &self.state32, self.prec, self.scale, out);
        true
    }

    fn name(&self) -> &'static str {
        match self.prec {
            Precision::F32 => "shadow-f32",
            _ => "shadow-mixed",
        }
    }
}

/// Solver options (defaults match the paper's experiments: M = 5,
/// `ws_start = 10`, doubling growth).
#[derive(Clone, Debug)]
pub struct SolverOpts {
    /// outer (working-set) iterations
    pub max_outer: usize,
    /// CD epochs per inner solve
    pub max_epochs: usize,
    /// stopping tolerance on the max optimality violation
    pub tol: f64,
    /// initial working-set size
    pub ws_start: usize,
    /// working sets on/off (ablation, Figure 6)
    pub use_ws: bool,
    /// Anderson memory M (0 disables acceleration — ablation, Figure 6)
    pub anderson_m: usize,
    /// inner solve stops at `max(inner_tol_ratio · kkt_max, 0.1·tol)`
    pub inner_tol_ratio: f64,
    /// inner engine for quadratic datafits: residual CD, Gram-domain CD,
    /// or per-inner-solve cost-model dispatch (`solver::gram`). Ignored
    /// (residual) for datafits without the Gram contract.
    pub inner: InnerEngine,
    pub verbose: bool,
    /// Cooperative execution budget, checked at the top of every outer
    /// iteration. `None` (the default) means run to convergence.
    pub budget: Option<SolveBudget>,
    /// Numeric precision of the full-design passes (scoring scans, Gram
    /// assembly off-diagonals, batched panels). Inner CD epochs, KKT and
    /// certificates always run in f64; reduced precision clamps `tol` to
    /// [`crate::linalg::simd::Precision::tol_floor`]. The default comes
    /// from `SKGLM_PRECISION` (set by `--precision`), else `f64`.
    pub precision: Precision,
}

/// Why a solve stopped before converging (see [`SolveBudget`]). The
/// partial result is still well-formed: the outer loops compute the final
/// objective and optimality certificate on whatever iterate they reached.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StopReason {
    /// The cancel flag was raised by another thread.
    Cancelled,
    /// The wall-clock deadline passed.
    Deadline,
    /// The cumulative inner-epoch budget was exhausted.
    EpochBudget,
}

impl StopReason {
    pub fn name(self) -> &'static str {
        match self {
            StopReason::Cancelled => "cancelled",
            StopReason::Deadline => "deadline",
            StopReason::EpochBudget => "epoch_budget",
        }
    }
}

/// Cooperative execution budget. Every outer loop (working-set CD,
/// screened Lasso, block CD, prox-Newton — they all share this options
/// struct) polls `check` once per outer iteration, so a budgeted solve
/// stops within one outer iteration of the limit and still returns a
/// finite partial objective with its [`Certificate`]. All fields are
/// optional; an empty budget never fires.
#[derive(Clone, Debug, Default)]
pub struct SolveBudget {
    /// Absolute wall-clock deadline.
    pub deadline: Option<Instant>,
    /// Cap on cumulative inner CD epochs across the whole solve.
    pub max_total_epochs: Option<usize>,
    /// Externally raised cancellation flag (e.g. a scheduler job control).
    pub cancel: Option<Arc<AtomicBool>>,
}

impl SolveBudget {
    pub fn is_unlimited(&self) -> bool {
        self.deadline.is_none() && self.max_total_epochs.is_none() && self.cancel.is_none()
    }

    /// Poll the budget; `epochs_done` is the cumulative epoch count so
    /// far. Cancellation takes precedence over the deadline, which takes
    /// precedence over the epoch cap.
    pub fn check(&self, epochs_done: usize) -> Option<StopReason> {
        if let Some(flag) = &self.cancel {
            if flag.load(Ordering::Relaxed) {
                return Some(StopReason::Cancelled);
            }
        }
        if let Some(deadline) = self.deadline {
            if Instant::now() >= deadline {
                return Some(StopReason::Deadline);
            }
        }
        if let Some(cap) = self.max_total_epochs {
            if epochs_done >= cap {
                return Some(StopReason::EpochBudget);
            }
        }
        None
    }
}

impl Default for SolverOpts {
    fn default() -> Self {
        Self {
            max_outer: 100,
            max_epochs: 10_000,
            tol: 1e-8,
            ws_start: 10,
            use_ws: true,
            anderson_m: 5,
            inner_tol_ratio: 0.1,
            inner: InnerEngine::default(),
            verbose: false,
            budget: None,
            precision: simd::default_precision(),
        }
    }
}

impl SolverOpts {
    pub fn with_tol(mut self, tol: f64) -> Self {
        self.tol = tol;
        self
    }
    pub fn without_ws(mut self) -> Self {
        self.use_ws = false;
        self
    }
    pub fn without_acceleration(mut self) -> Self {
        self.anderson_m = 0;
        self
    }
    /// Select the inner engine ([`InnerEngine::Auto`] for cost-model
    /// dispatch).
    pub fn with_inner(mut self, inner: InnerEngine) -> Self {
        self.inner = inner;
        self
    }
    /// Attach a cooperative execution budget (deadline / epoch cap /
    /// cancel flag); see [`SolveBudget`].
    pub fn with_budget(mut self, budget: SolveBudget) -> Self {
        self.budget = Some(budget);
        self
    }
    /// Convenience: cap wall-clock time from now.
    pub fn with_time_limit(mut self, limit: std::time::Duration) -> Self {
        let mut budget = self.budget.take().unwrap_or_default();
        budget.deadline = Some(Instant::now() + limit);
        self.budget = Some(budget);
        self
    }
    /// Select the full-design pass precision (see
    /// [`crate::linalg::simd::Precision`]).
    pub fn with_precision(mut self, precision: Precision) -> Self {
        self.precision = precision;
        self
    }
}

/// What a fit result's final `kkt` field measures — the optimality
/// certificate the solver actually computed, exposed so downstream
/// oracles (the scenario conformance runner, benchmark gates) can check
/// `kkt ≤ tol` against the declared tolerance without re-deriving which
/// metric a given solve path reports.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum Certificate {
    /// Max distance from `−∇f(β)` to `∂g(β)` over non-excluded blocks —
    /// the working-set subdifferential metric (valid for convex and
    /// non-convex penalties alike).
    #[default]
    Stationarity,
    /// The Lasso duality gap (objective-scale): reported by the gap-safe
    /// screened fast path and the celer baseline. Bounds suboptimality
    /// directly.
    DualityGap,
}

impl Certificate {
    pub fn name(self) -> &'static str {
        match self {
            Certificate::Stationarity => "stationarity",
            Certificate::DualityGap => "duality_gap",
        }
    }
}

/// One point of the convergence trace.
#[derive(Clone, Debug)]
pub struct HistoryPoint {
    /// seconds since solve start
    pub t: f64,
    pub objective: f64,
    /// max optimality violation
    pub kkt: f64,
    pub ws_size: usize,
}

/// Solve outcome.
#[derive(Clone, Debug)]
pub struct FitResult {
    pub beta: Vec<f64>,
    pub objective: f64,
    /// final max optimality violation (see `certificate` for the metric)
    pub kkt: f64,
    /// which optimality metric `kkt` is (stationarity vs duality gap)
    pub certificate: Certificate,
    pub n_outer: usize,
    pub n_epochs: usize,
    pub converged: bool,
    pub history: Vec<HistoryPoint>,
    pub accepted_extrapolations: usize,
    pub rejected_extrapolations: usize,
    /// per-stage wall-time / flop attribution (epochs vs scoring vs
    /// extrapolation vs Gram assembly) — see `exp gram`
    pub profile: InnerProfile,
}

impl FitResult {
    pub fn support(&self) -> Vec<usize> {
        self.beta
            .iter()
            .enumerate()
            .filter(|(_, &b)| b != 0.0)
            .map(|(j, _)| j)
            .collect()
    }
}

/// Cross-solve state carried along a regularization path: the previous
/// solution (warm start) and the working-set size it converged with, so
/// the next λ resumes from a realistic set size instead of re-growing
/// from `ws_start`. Produced/consumed by [`solve_continued`] and the
/// coordinator's path jobs.
#[derive(Clone, Debug, Default)]
pub struct ContinuationState {
    /// previous solution (β warm start); `None` = cold start
    pub beta: Option<Vec<f64>>,
    /// working-set size the previous solve ended with
    pub ws_size: Option<usize>,
    /// shared working-set Gram store: blocks assembled at one λ are
    /// reused at the next (and, when the coordinator installs its
    /// per-design cache here, across jobs). Created lazily on first use.
    pub gram: Option<Arc<GramCache>>,
}

impl ContinuationState {
    /// Record the outcome of a solve as the warm state for the next one.
    pub fn update_from(&mut self, result: &FitResult) {
        self.beta = Some(result.beta.clone());
        self.ws_size = result.history.last().map(|h| h.ws_size);
    }
}

/// Run Algorithm 1. `beta0` warm-starts (regularization paths).
#[allow(clippy::too_many_arguments)]
pub fn solve<D: Datafit, P: Penalty>(
    design: &Design,
    y: &[f64],
    datafit: &mut D,
    penalty: &P,
    opts: &SolverOpts,
    engine: Option<&mut dyn GradEngine>,
    beta0: Option<&[f64]>,
) -> FitResult {
    datafit.init(design, y);
    solve_prepared(design, y, datafit, penalty, opts, engine, beta0, None, None, None)
}

/// Run Algorithm 1 threading a [`ContinuationState`] through: warm-starts
/// from `state`, then updates it with the outcome — the entry point path
/// sweeps use so working-set growth persists between λ points.
/// `col_sq_norms` is an optional cached Gram diagonal
/// ([`Datafit::init_cached`]).
#[allow(clippy::too_many_arguments)]
pub fn solve_continued<D: Datafit, P: Penalty>(
    design: &Design,
    y: &[f64],
    datafit: &mut D,
    penalty: &P,
    opts: &SolverOpts,
    engine: Option<&mut dyn GradEngine>,
    state: &mut ContinuationState,
    frozen: Option<&[bool]>,
    col_sq_norms: Option<&[f64]>,
) -> FitResult {
    datafit.init_cached(design, y, col_sq_norms);
    // a path sweep shares one Gram store across its λ points: install it
    // in the continuation on first use (the coordinator pre-installs its
    // per-design cache instead, sharing blocks across jobs too)
    if state.gram.is_none()
        && opts.inner != InnerEngine::Residual
        && datafit.residual_quadratic_scale().is_some()
    {
        state.gram = Some(Arc::new(GramCache::with_default_budget()));
    }
    let gram = state.gram.clone();
    let result = solve_prepared(
        design,
        y,
        datafit,
        penalty,
        opts,
        engine,
        state.beta.as_deref(),
        state.ws_size,
        frozen,
        gram,
    );
    state.update_from(&result);
    result
}

/// Algorithm 1 on an already-initialized datafit ([`Datafit::init`] — or
/// [`Datafit::init_cached`] with cached Gram diagonals — must have run).
///
/// `ws0` seeds the working-set size (path continuation); `frozen` marks
/// features certified inactive at this λ (e.g. by a gap-safe screening
/// pass) — they are excluded from scoring, the working set and the final
/// KKT metric, shrinking every O(n·p) pass. Warm starts must be zero on
/// frozen coordinates (callers holding a certificate must zero them
/// first, as `screening::solve_lasso_screened_warm` does internally).
/// `gram` is the shared working-set Gram store the inner-engine
/// dispatcher draws on; `None` creates a solve-local one when the
/// requested [`SolverOpts::inner`] engine may need it.
#[allow(clippy::too_many_arguments)]
pub fn solve_prepared<D: Datafit, P: Penalty>(
    design: &Design,
    y: &[f64],
    datafit: &mut D,
    penalty: &P,
    opts: &SolverOpts,
    engine: Option<&mut dyn GradEngine>,
    beta0: Option<&[f64]>,
    ws0: Option<usize>,
    frozen: Option<&[bool]>,
    gram: Option<Arc<GramCache>>,
) -> FitResult {
    let p = design.ncols();

    // reduced precision cannot certify below its quantisation floor:
    // clamp the tolerance before the outer loop sees it
    let mut opts_floored;
    let opts = if opts.precision == Precision::F64 {
        opts
    } else {
        opts_floored = opts.clone();
        opts_floored.tol = opts_floored.tol.max(opts.precision.tol_floor());
        &opts_floored
    };

    // non-convex validity (Assumption 6): largest CD step is 1/min L_j>0
    let min_l = datafit
        .lipschitz()
        .iter()
        .cloned()
        .filter(|&l| l > 0.0)
        .fold(f64::INFINITY, f64::min);
    if min_l.is_finite() {
        penalty.validate_step(1.0 / min_l);
    }

    let beta = match beta0 {
        Some(b) => {
            assert_eq!(b.len(), p);
            b.to_vec()
        }
        None => vec![0.0; p],
    };
    let state = datafit.init_state(design, y, &beta);
    let is_frozen = |j: usize| frozen.map(|m| m[j]).unwrap_or(false);
    let all_features: Vec<usize> = (0..p).filter(|&j| !is_frozen(j)).collect();
    // the Gram engine needs a store: use the caller's shared one, or
    // create a solve-local one when the engine selection may want it.
    // Reduced precision never reuses a shared cache (its blocks would
    // mix assembly precisions) and builds a solve-local store at the
    // requested precision instead.
    let wants_gram =
        opts.inner != InnerEngine::Residual && datafit.residual_quadratic_scale().is_some();
    let gram = match gram {
        Some(g) if opts.precision == Precision::F64 => Some(g),
        _ if wants_gram => Some(Arc::new(GramCache::with_default_budget_at(opts.precision))),
        _ => None,
    };
    // reduced-precision scoring: dense quadratic full scans go through
    // the f32 design shadow; anything else keeps the native f64 path
    let mut shadow_engine = None;
    if engine.is_none() && opts.precision != Precision::F64 {
        if let (Design::Dense(m), Some(scale)) = (design, datafit.residual_quadratic_scale()) {
            shadow_engine = Some(ShadowGrad {
                prec: opts.precision,
                scale,
                shadow: ShadowF32::from_dense(m),
                state32: Vec::new(),
            });
        }
    }
    let engine = match shadow_engine.as_mut() {
        Some(e) => Some(e as &mut dyn GradEngine),
        None => engine,
    };
    let mut coords = ScalarCoords {
        design,
        y,
        datafit: &*datafit,
        penalty,
        engine,
        beta,
        state,
        grad: vec![0.0; p],
        frozen,
        all_features,
        gram,
        dispatch: EngineDispatch::new(opts.inner),
    };
    let out = solve_outer(&mut coords, opts, ws0);
    // label the flop counters with what actually ran — scalar-f64 and
    // avx2-f32 flops are not comparable across hosts
    let mut profile = out.profile;
    profile.kernel_isa = simd::isa();
    profile.precision = opts.precision;
    FitResult {
        beta: coords.beta,
        objective: out.objective,
        kkt: out.kkt,
        certificate: Certificate::Stationarity,
        n_outer: out.n_outer,
        n_epochs: out.n_epochs,
        converged: out.converged,
        history: out.history,
        accepted_extrapolations: out.accepted_extrapolations,
        rejected_extrapolations: out.rejected_extrapolations,
        profile,
    }
}

/// The scalar [`BlockCoords`] instantiation (p blocks of size 1): the
/// fused PJRT-routable scoring pass, per-coordinate scores (`score^∂` or
/// `score^cd`), and delegation to the scalar inner solver — exactly
/// Algorithm 1's per-iteration work, with the control flow owned by
/// [`solve_outer`].
struct ScalarCoords<'a, 'e, D: Datafit, P: Penalty> {
    design: &'a Design,
    y: &'a [f64],
    datafit: &'a D,
    penalty: &'a P,
    engine: Option<&'e mut dyn GradEngine>,
    beta: Vec<f64>,
    state: Vec<f64>,
    grad: Vec<f64>,
    /// features certified inactive at this λ (screening certificate)
    frozen: Option<&'a [bool]>,
    /// the non-frozen features (final KKT pass / no-ws ablation)
    all_features: Vec<usize>,
    /// shared working-set Gram store (None ⇒ residual engine only)
    gram: Option<Arc<GramCache>>,
    /// per-inner-solve engine selection (cost model + epoch feedback)
    dispatch: EngineDispatch,
}

impl<D: Datafit, P: Penalty> BlockCoords for ScalarCoords<'_, '_, D, P> {
    fn n_blocks(&self) -> usize {
        self.design.ncols()
    }

    fn score_pass(&mut self, scores: &mut [f64]) -> f64 {
        // the O(np) hot spot; PJRT-routable
        let native = match self.engine.as_deref_mut() {
            Some(e) => {
                !e.grad_full(self.design, self.y, &self.state, &self.beta, &mut self.grad)
            }
            None => true,
        };
        if native {
            self.datafit.grad_full(self.design, self.y, &self.state, &self.beta, &mut self.grad);
        }
        let lipschitz = self.datafit.lipschitz();
        let is_frozen = |j: usize| self.frozen.map(|m| m[j]).unwrap_or(false);
        let mut kkt_max = 0.0f64;
        for (j, out) in scores.iter_mut().enumerate() {
            if is_frozen(j) {
                // certified inactive at this λ: out of scoring and ws
                *out = f64::NEG_INFINITY;
                continue;
            }
            let s = if lipschitz[j] == 0.0 {
                0.0
            } else if self.penalty.use_cd_score() {
                (self.beta[j]
                    - self.penalty.prox(
                        self.beta[j] - self.grad[j] / lipschitz[j],
                        1.0 / lipschitz[j],
                        j,
                    ))
                .abs()
            } else {
                self.penalty.subdiff_distance(self.beta[j], self.grad[j], j)
            };
            *out = s;
            kkt_max = kkt_max.max(s);
        }
        kkt_max
    }

    fn objective(&self) -> f64 {
        super::cd::objective(self.datafit, self.penalty, self.y, &self.beta, &self.state)
    }

    fn in_gsupp(&self, j: usize) -> bool {
        self.penalty.in_gsupp(self.beta[j])
    }

    fn inner_solve(&mut self, ws: &[usize], inner_tol: f64, opts: &SolverOpts) -> InnerStats {
        // engine dispatch (Auto: Gram when |ws|²·E + assembly beats the
        // residual engine's 2·nnz(ws)·E; see solver::gram)
        let quad_scale = self.datafit.residual_quadratic_scale();
        let use_gram =
            self.dispatch.use_gram(self.design, ws, self.gram.as_deref(), quad_scale.is_some());
        let stats = if use_gram {
            gram_inner_solver(
                self.design,
                self.datafit.lipschitz(),
                quad_scale.expect("use_gram implies the Gram contract"),
                self.penalty,
                &mut self.beta,
                &mut self.state,
                ws,
                self.gram.as_ref().expect("use_gram implies a store"),
                opts.max_epochs,
                inner_tol,
                opts.anderson_m,
            )
        } else {
            inner_solver(
                self.design,
                self.y,
                self.datafit,
                self.penalty,
                &mut self.beta,
                &mut self.state,
                ws,
                opts.max_epochs,
                inner_tol,
                opts.anderson_m,
            )
        };
        self.dispatch.record_epochs(stats.epochs);
        stats
    }

    fn final_kkt(&mut self) -> f64 {
        // the O(n·p) KKT check runs on the kernel engine (frozen features
        // are already excluded from `all_features`; `coordinate_score`
        // returns 0 for empty columns and computes its own per-coordinate
        // gradients — no full-gradient pass needed here)
        let mut final_scores = vec![0.0; self.all_features.len()];
        super::inner::coordinate_scores_into(
            self.design,
            self.y,
            self.datafit,
            self.penalty,
            &self.beta,
            &self.state,
            &self.all_features,
            &mut final_scores,
        );
        final_scores.iter().fold(0.0f64, |m, &s| m.max(s))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{correlated, paper_dataset_small, CorrelatedSpec};
    use crate::datafit::Quadratic;
    use crate::penalty::{Mcp, L1};

    fn lambda_max(design: &Design, y: &[f64]) -> f64 {
        let n = design.nrows() as f64;
        let mut xty = vec![0.0; design.ncols()];
        design.matvec_t(y, &mut xty);
        xty.iter().fold(0.0f64, |m, v| m.max(v.abs())) / n
    }

    #[test]
    fn converges_on_dense_lasso() {
        let ds = correlated(CorrelatedSpec { n: 100, p: 200, rho: 0.5, nnz: 10, snr: 10.0 }, 0);
        let lam = lambda_max(&ds.design, &ds.y) / 20.0;
        let mut f = Quadratic::new();
        let res = solve(
            &ds.design,
            &ds.y,
            &mut f,
            &L1::new(lam),
            &SolverOpts::default().with_tol(1e-10),
            None,
            None,
        );
        assert!(res.converged, "kkt = {}", res.kkt);
        assert!(res.kkt <= 1e-10);
        assert!(!res.support().is_empty());
        assert!(res.support().len() < 100, "solution should be sparse");
    }

    #[test]
    fn converges_on_sparse_design() {
        let ds = paper_dataset_small("rcv1", 0).unwrap();
        let lam = lambda_max(&ds.design, &ds.y) / 50.0;
        let mut f = Quadratic::new();
        let res = solve(
            &ds.design,
            &ds.y,
            &mut f,
            &L1::new(lam),
            &SolverOpts::default().with_tol(1e-8),
            None,
            None,
        );
        assert!(res.converged, "kkt = {}", res.kkt);
    }

    #[test]
    fn with_and_without_ws_reach_same_optimum() {
        let ds = correlated(CorrelatedSpec { n: 80, p: 150, rho: 0.6, nnz: 8, snr: 10.0 }, 3);
        let lam = lambda_max(&ds.design, &ds.y) / 10.0;
        let pen = L1::new(lam);
        let mut f1 = Quadratic::new();
        let a = solve(&ds.design, &ds.y, &mut f1, &pen, &SolverOpts::default().with_tol(1e-12), None, None);
        let mut f2 = Quadratic::new();
        let b = solve(
            &ds.design,
            &ds.y,
            &mut f2,
            &pen,
            &SolverOpts::default().with_tol(1e-12).without_ws(),
            None,
            None,
        );
        assert!((a.objective - b.objective).abs() < 1e-10, "{} vs {}", a.objective, b.objective);
    }

    #[test]
    fn lambda_max_gives_zero_solution() {
        let ds = correlated(CorrelatedSpec { n: 50, p: 80, rho: 0.3, nnz: 5, snr: 10.0 }, 1);
        let lam = lambda_max(&ds.design, &ds.y) * 1.001;
        let mut f = Quadratic::new();
        let res = solve(&ds.design, &ds.y, &mut f, &L1::new(lam), &SolverOpts::default(), None, None);
        assert!(res.support().is_empty(), "beta must be 0 at lambda_max");
        assert_eq!(res.n_outer, 1, "should stop immediately");
    }

    #[test]
    fn mcp_reaches_critical_point_and_is_sparser_than_lasso() {
        let ds = correlated(CorrelatedSpec { n: 200, p: 400, rho: 0.5, nnz: 20, snr: 8.0 }, 5);
        // normalise columns to sqrt(n) as the paper does for MCP
        let mut design = ds.design.clone();
        design.normalize_cols((ds.n() as f64).sqrt());
        let lam = lambda_max(&design, &ds.y) / 10.0;
        let mut f1 = Quadratic::new();
        let lasso = solve(
            &design, &ds.y, &mut f1, &L1::new(lam), &SolverOpts::default().with_tol(1e-9), None, None,
        );
        let mut f2 = Quadratic::new();
        let mcp = solve(
            &design,
            &ds.y,
            &mut f2,
            &Mcp::new(lam, 3.0),
            &SolverOpts::default().with_tol(1e-9),
            None,
            None,
        );
        assert!(mcp.converged, "MCP kkt = {}", mcp.kkt);
        assert!(
            mcp.support().len() <= lasso.support().len(),
            "MCP ({}) should be at least as sparse as Lasso ({})",
            mcp.support().len(),
            lasso.support().len()
        );
    }

    #[test]
    fn warm_start_converges_in_fewer_epochs() {
        let ds = correlated(CorrelatedSpec { n: 100, p: 200, rho: 0.5, nnz: 10, snr: 10.0 }, 9);
        let lam = lambda_max(&ds.design, &ds.y) / 30.0;
        let pen = L1::new(lam);
        let mut f = Quadratic::new();
        let cold = solve(&ds.design, &ds.y, &mut f, &pen, &SolverOpts::default().with_tol(1e-10), None, None);
        let mut f2 = Quadratic::new();
        let warm = solve(
            &ds.design,
            &ds.y,
            &mut f2,
            &pen,
            &SolverOpts::default().with_tol(1e-10),
            None,
            Some(&cold.beta),
        );
        assert!(warm.n_epochs <= cold.n_epochs);
        assert!(warm.converged);
    }

    #[test]
    fn history_is_monotone_in_time_and_objective_decreases() {
        let ds = correlated(CorrelatedSpec { n: 100, p: 300, rho: 0.6, nnz: 15, snr: 5.0 }, 11);
        let lam = lambda_max(&ds.design, &ds.y) / 100.0;
        let mut f = Quadratic::new();
        let res = solve(&ds.design, &ds.y, &mut f, &L1::new(lam), &SolverOpts::default(), None, None);
        for w in res.history.windows(2) {
            assert!(w[1].t >= w[0].t);
            assert!(w[1].objective <= w[0].objective + 1e-12);
        }
    }

    #[test]
    fn warm_start_via_continuation_state_threads_ws_size() {
        let ds = correlated(CorrelatedSpec { n: 80, p: 120, rho: 0.4, nnz: 6, snr: 10.0 }, 17);
        let lam = lambda_max(&ds.design, &ds.y) / 10.0;
        let pen = L1::new(lam);
        let mut state = ContinuationState::default();
        let mut f = Quadratic::new();
        let first = solve_continued(
            &ds.design, &ds.y, &mut f, &pen, &SolverOpts::default().with_tol(1e-10), None,
            &mut state, None, None,
        );
        assert!(first.converged);
        assert_eq!(state.beta.as_deref(), Some(&first.beta[..]));
        assert!(state.ws_size.is_some());
        // continuing at a smaller λ from the stored state reaches the
        // same optimum as a cold solve, in no more epochs
        let pen2 = L1::new(lam / 2.0);
        let mut f2 = Quadratic::new();
        let warm = solve_continued(
            &ds.design, &ds.y, &mut f2, &pen2, &SolverOpts::default().with_tol(1e-10), None,
            &mut state, None, None,
        );
        let mut f3 = Quadratic::new();
        let cold = solve(
            &ds.design, &ds.y, &mut f3, &pen2, &SolverOpts::default().with_tol(1e-10), None, None,
        );
        assert!((warm.objective - cold.objective).abs() < 1e-9);
        assert!(warm.n_epochs <= cold.n_epochs);
    }

    #[test]
    fn gram_auto_and_residual_engines_reach_the_same_optimum() {
        let ds = correlated(CorrelatedSpec { n: 150, p: 90, rho: 0.5, nnz: 8, snr: 10.0 }, 31);
        let lam = lambda_max(&ds.design, &ds.y) / 15.0;
        let pen = L1::new(lam);
        let run = |inner: super::InnerEngine| {
            let mut f = Quadratic::new();
            solve(
                &ds.design,
                &ds.y,
                &mut f,
                &pen,
                &SolverOpts::default().with_tol(1e-12).with_inner(inner),
                None,
                None,
            )
        };
        let residual = run(super::InnerEngine::Residual);
        let gram = run(super::InnerEngine::Gram);
        let auto = run(super::InnerEngine::Auto);
        assert!(residual.converged && gram.converged && auto.converged);
        for other in [&gram, &auto] {
            assert!((residual.objective - other.objective).abs() < 1e-12);
            for (a, b) in residual.beta.iter().zip(other.beta.iter()) {
                assert!((a - b).abs() < 1e-10, "{a} vs {b}");
            }
        }
        // the forced Gram run actually ran Gram epochs and assembled blocks
        assert!(gram.profile.gram_epochs > 0);
        assert!(gram.profile.gram_assembly_flops > 0.0);
        assert_eq!(gram.profile.residual_epochs, 0);
        // n ≫ |ws| here: the auto dispatcher should have picked Gram
        assert!(auto.profile.gram_epochs > 0, "auto never engaged the Gram engine");
        // residual stays bit-true to the pre-ISSUE-5 solver
        assert_eq!(residual.profile.gram_epochs, 0);
    }

    #[test]
    fn frozen_features_are_excluded_without_changing_the_optimum() {
        let ds = correlated(CorrelatedSpec { n: 80, p: 120, rho: 0.4, nnz: 6, snr: 10.0 }, 21);
        let lam = lambda_max(&ds.design, &ds.y) / 5.0;
        let pen = L1::new(lam);
        let mut f = Quadratic::new();
        let exact = solve(
            &ds.design, &ds.y, &mut f, &pen, &SolverOpts::default().with_tol(1e-12), None, None,
        );
        // freeze features that are zero at the optimum with a strict
        // subgradient margin (what a gap-safe certificate guarantees)
        let state = f.init_state(&ds.design, &ds.y, &exact.beta);
        let mut grad = vec![0.0; ds.p()];
        f.grad_full(&ds.design, &ds.y, &state, &exact.beta, &mut grad);
        let frozen: Vec<bool> = (0..ds.p())
            .map(|j| exact.beta[j] == 0.0 && grad[j].abs() < 0.9 * lam)
            .collect();
        assert!(frozen.iter().any(|&x| x), "margin features must exist");
        let mut f2 = Quadratic::new();
        f2.init(&ds.design, &ds.y);
        let res = solve_prepared(
            &ds.design,
            &ds.y,
            &mut f2,
            &pen,
            &SolverOpts::default().with_tol(1e-12),
            None,
            None,
            None,
            Some(&frozen),
            None,
        );
        assert!(res.converged);
        assert!((res.objective - exact.objective).abs() < 1e-10);
        for (j, &fz) in frozen.iter().enumerate() {
            if fz {
                assert_eq!(res.beta[j], 0.0, "frozen feature {j} moved");
            }
        }
    }

}
