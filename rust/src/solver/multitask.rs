//! Multitask (block) solver — Algorithm 1/2 lifted to rows of
//! `W ∈ R^{p×T}` for the M/EEG inverse problem (paper §3.2, Appendix D).
//!
//! One "coordinate" is a row `W_{j,:}`; the block CD update is
//! `W_{j,:} ← prox_{g_j/L_j}(W_{j,:} − ∇_{j,:} f / L_j)` with the radial
//! prox of Proposition 18. Working sets and the Anderson-with-guard
//! acceleration carry over verbatim (the iterate buffer stores the
//! flattened working-set rows).

use super::anderson::Anderson;
use super::skglm::{HistoryPoint, SolverOpts};
use crate::datafit::multitask::QuadraticMultiTask;
use crate::linalg::Design;
use crate::penalty::BlockPenalty;
use std::time::Instant;

/// Multitask fit outcome. `w` is row-major: `w[j*T + t]`.
#[derive(Clone, Debug)]
pub struct MultiTaskFit {
    pub w: Vec<f64>,
    pub n_tasks: usize,
    pub objective: f64,
    pub kkt: f64,
    pub converged: bool,
    pub n_outer: usize,
    pub n_epochs: usize,
    pub history: Vec<HistoryPoint>,
}

impl MultiTaskFit {
    /// Rows with a nonzero entry.
    pub fn row_support(&self) -> Vec<usize> {
        let t = self.n_tasks;
        (0..self.w.len() / t)
            .filter(|&j| self.w[j * t..(j + 1) * t].iter().any(|&v| v != 0.0))
            .collect()
    }
}

fn objective<B: BlockPenalty>(
    datafit: &QuadraticMultiTask,
    penalty: &B,
    w: &[f64],
    state: &[f64],
    n_tasks: usize,
) -> f64 {
    let mut g = 0.0;
    for j in 0..w.len() / n_tasks {
        g += penalty.value(&w[j * n_tasks..(j + 1) * n_tasks]);
    }
    datafit.value(state) + g
}

/// One block-CD epoch over `ws`. Returns max scaled row move.
fn block_cd_epoch<B: BlockPenalty>(
    design: &Design,
    datafit: &QuadraticMultiTask,
    penalty: &B,
    w: &mut [f64],
    state: &mut [f64],
    ws: &[usize],
    grad_buf: &mut [f64],
    delta_buf: &mut [f64],
) -> f64 {
    let t = datafit.n_tasks();
    let lipschitz = datafit.lipschitz();
    let mut max_move = 0.0f64;
    for &j in ws {
        let lj = lipschitz[j];
        if lj == 0.0 {
            continue;
        }
        datafit.grad_row(design, state, j, grad_buf);
        let row = &mut w[j * t..(j + 1) * t];
        let mut changed = false;
        for k in 0..t {
            delta_buf[k] = row[k]; // stash old
            row[k] -= grad_buf[k] / lj;
        }
        penalty.prox(row, 1.0 / lj);
        for k in 0..t {
            let d = row[k] - delta_buf[k];
            delta_buf[k] = d;
            if d != 0.0 {
                changed = true;
                max_move = max_move.max(lj * d.abs());
            }
        }
        if changed {
            datafit.update_state(design, j, delta_buf, state);
        }
    }
    max_move
}

/// Max block score over a set of rows.
fn score_rows<B: BlockPenalty>(
    design: &Design,
    datafit: &QuadraticMultiTask,
    penalty: &B,
    w: &[f64],
    state: &[f64],
    rows: &[usize],
    grad_buf: &mut [f64],
    out: Option<&mut [f64]>,
) -> f64 {
    let t = datafit.n_tasks();
    let mut kkt = 0.0f64;
    let mut out = out;
    for (k, &j) in rows.iter().enumerate() {
        let s = if datafit.lipschitz()[j] == 0.0 {
            0.0
        } else {
            datafit.grad_row(design, state, j, grad_buf);
            penalty.subdiff_distance(&w[j * t..(j + 1) * t], grad_buf)
        };
        if let Some(o) = out.as_deref_mut() {
            o[k] = s;
        }
        kkt = kkt.max(s);
    }
    kkt
}

/// Solve the multitask problem. `y` is task-major (`y[t*n + i]`).
pub fn solve_multitask<B: BlockPenalty>(
    design: &Design,
    y: &[f64],
    n_tasks: usize,
    penalty: &B,
    opts: &SolverOpts,
) -> MultiTaskFit {
    let start = Instant::now();
    let p = design.ncols();
    let mut datafit = QuadraticMultiTask::new();
    datafit.init(design, n_tasks);

    let mut w = vec![0.0; p * n_tasks];
    let mut state = datafit.init_state(design, y, &w);
    let mut grad_buf = vec![0.0; n_tasks];
    let mut delta_buf = vec![0.0; n_tasks];
    let mut scores = vec![0.0; p];
    let all_rows: Vec<usize> = (0..p).collect();

    let mut fit = MultiTaskFit {
        w: Vec::new(),
        n_tasks,
        objective: f64::NAN,
        kkt: f64::NAN,
        converged: false,
        n_outer: 0,
        n_epochs: 0,
        history: Vec::new(),
    };
    let mut ws_size = opts.ws_start.min(p).max(1);

    for outer in 1..=opts.max_outer {
        fit.n_outer = outer;
        let kkt = score_rows(
            design, &datafit, penalty, &w, &state, &all_rows, &mut grad_buf, Some(&mut scores),
        );
        fit.history.push(HistoryPoint {
            t: start.elapsed().as_secs_f64(),
            objective: objective(&datafit, penalty, &w, &state, n_tasks),
            kkt,
            ws_size: if opts.use_ws { ws_size.min(p) } else { p },
        });
        if kkt <= opts.tol {
            fit.converged = true;
            break;
        }

        let ws: Vec<usize> = if opts.use_ws {
            let gsupp = (0..p)
                .filter(|&j| penalty.in_gsupp(&w[j * n_tasks..(j + 1) * n_tasks]))
                .count();
            ws_size = ws_size.max(2 * gsupp).min(p);
            let mut idx: Vec<usize> = (0..p).collect();
            for j in 0..p {
                if penalty.in_gsupp(&w[j * n_tasks..(j + 1) * n_tasks]) {
                    scores[j] = f64::INFINITY;
                }
            }
            if ws_size < p {
                idx.select_nth_unstable_by(ws_size - 1, |&a, &b| {
                    scores[b].partial_cmp(&scores[a]).unwrap_or(std::cmp::Ordering::Equal)
                });
                idx.truncate(ws_size);
            }
            idx.sort_unstable();
            idx
        } else {
            all_rows.clone()
        };

        // inner: block CD + guarded Anderson on flattened ws rows
        let inner_tol = (opts.inner_tol_ratio * kkt).max(0.1 * opts.tol);
        let mut accel =
            if opts.anderson_m >= 2 { Some(Anderson::new(opts.anderson_m)) } else { None };
        let mut flat = vec![0.0; ws.len() * n_tasks];
        let gather = |w: &[f64], flat: &mut [f64]| {
            for (k, &j) in ws.iter().enumerate() {
                flat[k * n_tasks..(k + 1) * n_tasks]
                    .copy_from_slice(&w[j * n_tasks..(j + 1) * n_tasks]);
            }
        };
        if let Some(acc) = accel.as_mut() {
            gather(&w, &mut flat);
            acc.push(&flat);
        }
        for epoch in 1..=opts.max_epochs {
            fit.n_epochs += 1;
            block_cd_epoch(
                design, &datafit, penalty, &mut w, &mut state, &ws, &mut grad_buf,
                &mut delta_buf,
            );
            if let Some(acc) = accel.as_mut() {
                gather(&w, &mut flat);
                let full = acc.push(&flat);
                if full && epoch % acc.m() == 0 {
                    if let Some(extr) = acc.extrapolate() {
                        // objective guard
                        let cur_obj = objective(&datafit, penalty, &w, &state, n_tasks);
                        let mut w_try = w.clone();
                        for (k, &j) in ws.iter().enumerate() {
                            w_try[j * n_tasks..(j + 1) * n_tasks]
                                .copy_from_slice(&extr[k * n_tasks..(k + 1) * n_tasks]);
                        }
                        let state_try = datafit.init_state(design, y, &w_try);
                        let try_obj =
                            objective(&datafit, penalty, &w_try, &state_try, n_tasks);
                        if try_obj < cur_obj {
                            w = w_try;
                            state = state_try;
                            acc.clear();
                            gather(&w, &mut flat);
                            acc.push(&flat);
                        }
                    }
                }
            }
            if epoch % 10 == 0 {
                let s = score_rows(
                    design, &datafit, penalty, &w, &state, &ws, &mut grad_buf, None,
                );
                if s <= inner_tol {
                    break;
                }
            }
        }
    }

    fit.kkt =
        score_rows(design, &datafit, penalty, &w, &state, &all_rows, &mut grad_buf, None);
    fit.converged = fit.converged || fit.kkt <= opts.tol;
    fit.objective = objective(&datafit, penalty, &w, &state, n_tasks);
    fit.w = w;
    fit
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::meeg::{simulate, MeegSpec};
    use crate::penalty::{BlockL21, BlockMcp};

    fn meeg_to_problem(
        pb: &crate::data::meeg::MeegProblem,
    ) -> (Design, Vec<f64>, usize) {
        let n = pb.gain.nrows();
        let t = pb.measurements.ncols();
        let mut y = vec![0.0; n * t];
        for tt in 0..t {
            for i in 0..n {
                y[tt * n + i] = pb.measurements.get(i, tt);
            }
        }
        (Design::Dense(pb.gain.clone()), y, t)
    }

    fn block_lambda_max(design: &Design, y: &[f64], n_tasks: usize) -> f64 {
        // max_j ||X_jᵀ Y||_2 / n
        let n = design.nrows();
        let mut best = 0.0f64;
        for j in 0..design.ncols() {
            let mut s = 0.0;
            for t in 0..n_tasks {
                let d = design.col_dot(j, &y[t * n..(t + 1) * n]);
                s += d * d;
            }
            best = best.max(s.sqrt() / n as f64);
        }
        best
    }

    #[test]
    fn l21_converges_and_is_row_sparse() {
        let pb = simulate(MeegSpec { n_sensors: 40, n_sources: 120, n_times: 8, ..Default::default() }, 0);
        let (design, y, t) = meeg_to_problem(&pb);
        let lam = block_lambda_max(&design, &y, t) / 3.0;
        let fit = solve_multitask(
            &design,
            &y,
            t,
            &BlockL21::new(lam),
            &SolverOpts::default().with_tol(1e-8),
        );
        assert!(fit.converged, "kkt {}", fit.kkt);
        let sup = fit.row_support();
        assert!(!sup.is_empty());
        assert!(sup.len() < 60, "row support {} should be small", sup.len());
    }

    #[test]
    fn block_mcp_converges() {
        let pb = simulate(MeegSpec { n_sensors: 40, n_sources: 120, n_times: 8, ..Default::default() }, 1);
        let (design, y, t) = meeg_to_problem(&pb);
        let lam = block_lambda_max(&design, &y, t) / 3.0;
        let fit = solve_multitask(
            &design,
            &y,
            t,
            &BlockMcp::new(lam, 100.0),
            &SolverOpts::default().with_tol(1e-7),
        );
        assert!(fit.converged, "kkt {}", fit.kkt);
    }

    #[test]
    fn ws_and_full_reach_same_objective_l21() {
        let pb = simulate(MeegSpec { n_sensors: 30, n_sources: 80, n_times: 5, ..Default::default() }, 2);
        let (design, y, t) = meeg_to_problem(&pb);
        let lam = block_lambda_max(&design, &y, t) / 4.0;
        let pen = BlockL21::new(lam);
        let a = solve_multitask(&design, &y, t, &pen, &SolverOpts::default().with_tol(1e-10));
        let b = solve_multitask(
            &design,
            &y,
            t,
            &pen,
            &SolverOpts::default().with_tol(1e-10).without_ws(),
        );
        assert!((a.objective - b.objective).abs() < 1e-8, "{} vs {}", a.objective, b.objective);
    }
}
