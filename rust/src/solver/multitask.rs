//! Multitask (block) solves — Algorithm 1/2 lifted to rows of
//! `W ∈ R^{p×T}` for the M/EEG inverse problem (paper §3.2, Appendix D).
//!
//! Since the block-coordinate refactor this module contains **no solver
//! loop of its own**: `solve_multitask` instantiates the shared engine
//! ([`crate::solver::block_cd`]) with the uniform row partition
//! `BlockPartition::uniform(p, T)` and the [`QuadraticMultiTask`]
//! datafit, so working sets, the guarded Anderson acceleration (now with
//! affine state snapshots instead of full state replays) and the
//! convergence history are exactly the scalar solver's, block-lifted.

use super::block_cd::{solve_blocks, BlockFitResult};
use super::partition::BlockPartition;
use super::skglm::{HistoryPoint, SolverOpts};
use crate::datafit::multitask::QuadraticMultiTask;
use crate::linalg::Design;
use crate::penalty::BlockPenalty;

/// Multitask fit outcome. `w` is row-major: `w[j*T + t]`.
#[derive(Clone, Debug)]
pub struct MultiTaskFit {
    pub w: Vec<f64>,
    pub n_tasks: usize,
    pub objective: f64,
    pub kkt: f64,
    pub converged: bool,
    pub n_outer: usize,
    pub n_epochs: usize,
    pub history: Vec<HistoryPoint>,
}

impl MultiTaskFit {
    /// Rows with a **finite** nonzero entry. A divergent non-convex fit
    /// (NaN/∞ coefficients) contributes no support rows instead of
    /// poisoning downstream selection — the same NaN-last treatment as
    /// `PathResult`'s best-point selectors.
    pub fn row_support(&self) -> Vec<usize> {
        let t = self.n_tasks;
        (0..self.w.len() / t)
            .filter(|&j| {
                self.w[j * t..(j + 1) * t].iter().any(|&v| v != 0.0 && v.is_finite())
            })
            .collect()
    }

    /// Whether the reported objective is a real number (false for a
    /// divergent fit — callers comparing objectives should order with
    /// [`crate::util::order::nan_last`]).
    pub fn objective_is_finite(&self) -> bool {
        self.objective.is_finite()
    }
}

/// Solve the multitask problem through the shared block engine. `y` is
/// task-major (`y[t*n + i]`).
pub fn solve_multitask<B: BlockPenalty>(
    design: &Design,
    y: &[f64],
    n_tasks: usize,
    penalty: &B,
    opts: &SolverOpts,
) -> MultiTaskFit {
    let part = BlockPartition::uniform(design.ncols(), n_tasks);
    let mut datafit = QuadraticMultiTask::new(n_tasks);
    let result = solve_blocks(design, y, &part, &mut datafit, penalty, opts, None);
    multitask_fit_from(result, n_tasks)
}

/// Repackage a [`BlockFitResult`] as the multitask-facing fit type.
pub fn multitask_fit_from(result: BlockFitResult, n_tasks: usize) -> MultiTaskFit {
    MultiTaskFit {
        w: result.v,
        n_tasks,
        objective: result.objective,
        kkt: result.kkt,
        converged: result.converged,
        n_outer: result.n_outer,
        n_epochs: result.n_epochs,
        history: result.history,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::meeg::{simulate, MeegSpec};
    use crate::penalty::{BlockL21, BlockMcp};

    fn meeg_to_problem(
        pb: &crate::data::meeg::MeegProblem,
    ) -> (Design, Vec<f64>, usize) {
        let n = pb.gain.nrows();
        let t = pb.measurements.ncols();
        let mut y = vec![0.0; n * t];
        for tt in 0..t {
            for i in 0..n {
                y[tt * n + i] = pb.measurements.get(i, tt);
            }
        }
        (Design::Dense(pb.gain.clone()), y, t)
    }

    fn block_lambda_max(design: &Design, y: &[f64], n_tasks: usize) -> f64 {
        // max_j ||X_jᵀ Y||_2 / n
        let n = design.nrows();
        let mut best = 0.0f64;
        for j in 0..design.ncols() {
            let mut s = 0.0;
            for t in 0..n_tasks {
                let d = design.col_dot(j, &y[t * n..(t + 1) * n]);
                s += d * d;
            }
            best = best.max(s.sqrt() / n as f64);
        }
        best
    }

    #[test]
    fn l21_converges_and_is_row_sparse() {
        let pb = simulate(MeegSpec { n_sensors: 40, n_sources: 120, n_times: 8, ..Default::default() }, 0);
        let (design, y, t) = meeg_to_problem(&pb);
        let lam = block_lambda_max(&design, &y, t) / 3.0;
        let fit = solve_multitask(
            &design,
            &y,
            t,
            &BlockL21::new(lam),
            &SolverOpts::default().with_tol(1e-8),
        );
        assert!(fit.converged, "kkt {}", fit.kkt);
        let sup = fit.row_support();
        assert!(!sup.is_empty());
        assert!(sup.len() < 60, "row support {} should be small", sup.len());
    }

    #[test]
    fn block_mcp_converges() {
        let pb = simulate(MeegSpec { n_sensors: 40, n_sources: 120, n_times: 8, ..Default::default() }, 1);
        let (design, y, t) = meeg_to_problem(&pb);
        let lam = block_lambda_max(&design, &y, t) / 3.0;
        let fit = solve_multitask(
            &design,
            &y,
            t,
            &BlockMcp::new(lam, 100.0),
            &SolverOpts::default().with_tol(1e-7),
        );
        assert!(fit.converged, "kkt {}", fit.kkt);
    }

    #[test]
    fn ws_and_full_reach_same_objective_l21() {
        let pb = simulate(MeegSpec { n_sensors: 30, n_sources: 80, n_times: 5, ..Default::default() }, 2);
        let (design, y, t) = meeg_to_problem(&pb);
        let lam = block_lambda_max(&design, &y, t) / 4.0;
        let pen = BlockL21::new(lam);
        let a = solve_multitask(&design, &y, t, &pen, &SolverOpts::default().with_tol(1e-10));
        let b = solve_multitask(
            &design,
            &y,
            t,
            &pen,
            &SolverOpts::default().with_tol(1e-10).without_ws(),
        );
        assert!((a.objective - b.objective).abs() < 1e-8, "{} vs {}", a.objective, b.objective);
    }

    #[test]
    fn row_support_ignores_non_finite_rows() {
        // satellite regression: a divergent block fit (NaN row) must not
        // count toward the support nor panic selection
        let fit = MultiTaskFit {
            w: vec![0.0, 0.0, f64::NAN, f64::NAN, 1.0, 0.0, 0.0, f64::INFINITY],
            n_tasks: 2,
            objective: f64::NAN,
            kkt: f64::NAN,
            converged: false,
            n_outer: 1,
            n_epochs: 1,
            history: Vec::new(),
        };
        assert_eq!(fit.row_support(), vec![2], "only the finite nonzero row counts");
        assert!(!fit.objective_is_finite());
    }
}
