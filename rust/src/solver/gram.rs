//! Gram-domain inner engine (ISSUE 5 tentpole): Algorithm 2 with the
//! working-set gradient maintained from `G_ws = X_wsᵀ X_ws` instead of
//! the residual.
//!
//! For an exact residual quadratic
//! ([`crate::datafit::Datafit::residual_quadratic_scale`] = `Some(c)`,
//! i.e. `∇f = c·Xᵀ(Xβ − y)`), a coordinate move `β_j += δ` changes the
//! working-set gradient by `δ·c·G_ws[:, j]` — an O(|ws|) update where the
//! residual engine pays two O(n) column passes. The whole inner solve
//! touches the design exactly three times:
//!
//! 1. Gram assembly — incremental, served by the shared byte-budgeted
//!    [`GramCache`]: only blocks never computed before (by this solve, by
//!    earlier λ points of a path sweep, or by sibling jobs on the same
//!    design) are assembled;
//! 2. the entry gradient `g = c·X_wsᵀ s` (one restricted pass);
//! 3. the exit state refresh `s += Σ Δβ_j X_j` (one restricted pass).
//!
//! Everything in between — epochs, the gated stationarity score, the
//! Anderson guard — runs on O(|ws|)-sized vectors. The guard carries over
//! from the residual engine unchanged in structure: the packed
//! ws-gradient is **affine in β** (it is `c·X_wsᵀ X β − c·X_wsᵀ y`), so
//! extrapolated gradients are snapshot combinations exactly like the
//! residual snapshots of `solver::inner`, and the objective test uses the
//! exact quadratic identity `f(b) − f(a) = ½(∇f(a) + ∇f(b))ᵀ(b − a)`
//! restricted to the working set.
//!
//! [`InnerEngine`] + [`EngineDispatch`] implement the cost-model
//! dispatcher that routes each inner solve (`skglm.rs` scalar coords and
//! the screened-Lasso fast path both consult it): Gram wins when
//! `assembly + |ws|²·E + 2·nnz(ws)  <  2·nnz(ws)·E`, with `E` the
//! epochs-per-inner-solve estimate adapted from the previous inner solve.

use super::anderson::Anderson;
use super::cd::{cd_epoch_core, EpochState};
use super::inner::InnerStats;
use crate::linalg::gram::GramCache;
use crate::linalg::Design;
use crate::penalty::Penalty;
use std::time::Instant;

/// Which inner engine a solve should use for quadratic datafits.
/// Non-quadratic datafits always run the residual engine regardless.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum InnerEngine {
    /// Cost-model dispatch per inner solve (CLI default).
    Auto,
    /// Always the residual-domain engine (library default — bitwise
    /// identical to the pre-ISSUE-5 solver).
    #[default]
    Residual,
    /// Always the Gram-domain engine (equivalence tests, benches).
    Gram,
}

impl std::str::FromStr for InnerEngine {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "auto" => Ok(InnerEngine::Auto),
            "residual" => Ok(InnerEngine::Residual),
            "gram" => Ok(InnerEngine::Gram),
            other => Err(format!("unknown inner engine {other:?} (auto|residual|gram)")),
        }
    }
}

/// Initial epochs-per-inner-solve estimate before any inner solve has
/// run (the paper's problems typically take O(10) accelerated epochs).
const EPOCHS_ESTIMATE_INIT: usize = 16;

/// The dispatcher's cost model: modelled flops of a Gram-engine inner
/// solve (`assembly + |ws|²·E + 2·nnz(ws)` for the entry/exit passes)
/// against a residual one (`2·nnz(ws)·E`).
pub fn gram_pays_off(m: usize, nnz_ws: usize, projected_assembly: f64, epochs_est: usize) -> bool {
    let e = epochs_est.max(1) as f64;
    let gram = projected_assembly + (m * m) as f64 * e + 2.0 * nnz_ws as f64;
    let residual = 2.0 * nnz_ws as f64 * e;
    gram < residual
}

/// Per-solve dispatcher state: the requested [`InnerEngine`] plus the
/// adaptive epoch estimate fed back from each inner solve.
#[derive(Clone, Debug)]
pub struct EngineDispatch {
    requested: InnerEngine,
    last_epochs: usize,
}

impl EngineDispatch {
    pub fn new(requested: InnerEngine) -> Self {
        Self { requested, last_epochs: EPOCHS_ESTIMATE_INIT }
    }

    /// Feed back the epoch count of the inner solve just run.
    pub fn record_epochs(&mut self, epochs: usize) {
        if epochs > 0 {
            self.last_epochs = epochs;
        }
    }

    /// Decide the engine for the next inner solve. `quadratic` is whether
    /// the datafit opted into the Gram contract
    /// ([`crate::datafit::Datafit::residual_quadratic_scale`]).
    pub fn use_gram(
        &self,
        design: &Design,
        ws: &[usize],
        gram: Option<&GramCache>,
        quadratic: bool,
    ) -> bool {
        if !quadratic || ws.is_empty() {
            return false;
        }
        let gram = match gram {
            Some(g) => g,
            None => return false,
        };
        match self.requested {
            InnerEngine::Residual => false,
            InnerEngine::Gram => true,
            InnerEngine::Auto => {
                let nnz_ws = design.subset_stored_entries(ws);
                let projected = gram.projected_assembly_flops(design, ws);
                gram_pays_off(ws.len(), nnz_ws, projected, self.last_epochs)
            }
        }
    }
}

/// Gram-domain [`EpochState`]: the packed working-set gradient `g` is
/// updated from row `pos` of the symmetric `|ws| × |ws|` block `gw`
/// (row-major; row = column by symmetry, so the access is contiguous).
struct GramEpoch<'a> {
    /// packed ws gradient, `g[k] = ∇_{ws[k]} f`
    g: &'a mut [f64],
    /// symmetric Gram block in ws order (unscaled `X_wsᵀX_ws`)
    gw: &'a [f64],
    /// the datafit's gradient scale `c` (`1/n` for `Quadratic`)
    scale: f64,
    m: usize,
}

impl EpochState for GramEpoch<'_> {
    #[inline]
    fn grad(&mut self, pos: usize, _j: usize, _beta: &[f64]) -> f64 {
        self.g[pos]
    }

    #[inline]
    fn commit(&mut self, pos: usize, _j: usize, delta: f64) {
        let row = &self.gw[pos * self.m..(pos + 1) * self.m];
        let cd = delta * self.scale;
        for (gl, &glk) in self.g.iter_mut().zip(row.iter()) {
            *gl += cd * glk;
        }
    }
}

/// Algorithm 2 in the Gram domain. Same contract as
/// [`super::inner::inner_solver`]: mutates `beta`/`state` in place (the
/// residual `state` is refreshed once on exit), `anderson_m = 0` disables
/// acceleration. `scale` is the datafit's
/// [`crate::datafit::Datafit::residual_quadratic_scale`] and `lipschitz`
/// its per-coordinate constants.
#[allow(clippy::too_many_arguments)]
pub fn gram_inner_solver<P: Penalty>(
    design: &Design,
    lipschitz: &[f64],
    scale: f64,
    penalty: &P,
    beta: &mut [f64],
    state: &mut [f64],
    ws: &[usize],
    gram: &GramCache,
    max_epochs: usize,
    tol: f64,
    anderson_m: usize,
) -> InnerStats {
    let m = ws.len();
    let mut stats = InnerStats::default();
    if m == 0 {
        return stats;
    }

    // ---- 1. Gram assembly (incremental; shared cache) ----
    let t_asm = Instant::now();
    let mut gw = Vec::new();
    let asm = gram.ensure_gather(design, ws, &mut gw);
    stats.profile.gram_assembly_secs += t_asm.elapsed().as_secs_f64();
    stats.profile.gram_assembly_flops += asm.flops as f64;

    // ---- 2. entry gradient: the one restricted residual-domain pass ----
    let nnz_ws = design.subset_stored_entries(ws);
    let mut g = vec![0.0; m];
    design.matvec_t_subset(state, ws, &mut g);
    for v in g.iter_mut() {
        *v *= scale;
    }
    stats.profile.epoch_flops += nnz_ws as f64;

    // entry point (β₀, g₀): the exit refresh and the quadratic objective
    // identity are both relative to it
    let b0: Vec<f64> = ws.iter().map(|&j| beta[j]).collect();
    let g0 = g.clone();

    // f(β) − f(β₀) + Σ_ws g_j(β_j), exact for the quadratic datafit:
    // f(b) − f(a) = ½(∇f(a) + ∇f(b))ᵀ(b − a), supported on ws
    let rel_objective = |bw: &[f64], gv: &[f64]| -> f64 {
        let mut df = 0.0;
        let mut pen = 0.0;
        for (k, &j) in ws.iter().enumerate() {
            df += 0.5 * (gv[k] + g0[k]) * (bw[k] - b0[k]);
            pen += penalty.value(bw[k], j);
        }
        df + pen
    };

    let mut accel = if anderson_m >= 2 { Some(Anderson::new(anderson_m)) } else { None };
    let mut ws_beta = vec![0.0; m];
    // gradient snapshots aligned with the Anderson pushes (g is affine in
    // β, so snapshot combination is exact — same guard as the residual
    // engine's state snapshots)
    let mut g_snaps: Vec<Vec<f64>> = Vec::new();
    let snap_cap = anderson_m + 1;
    let push_snap = |snaps: &mut Vec<Vec<f64>>, g: &[f64]| {
        if snaps.len() == snap_cap {
            snaps.remove(0);
        }
        snaps.push(g.to_vec());
    };

    if let Some(acc) = accel.as_mut() {
        for (o, &j) in ws_beta.iter_mut().zip(ws.iter()) {
            *o = beta[j];
        }
        acc.push(&ws_beta);
        push_snap(&mut g_snaps, &g);
    }

    for epoch in 1..=max_epochs {
        stats.epochs = epoch;
        // alternate sweep direction (Proposition 13 hypothesis 3)
        let t_epoch = Instant::now();
        let max_move = {
            let mut st = GramEpoch { g: &mut g, gw: &gw, scale, m };
            cd_epoch_core(penalty, lipschitz, beta, ws, epoch % 2 == 0, &mut st)
        };
        stats.profile.epoch_secs += t_epoch.elapsed().as_secs_f64();
        stats.profile.epoch_flops += (m * m) as f64;
        stats.profile.gram_epochs += 1;
        let _ = max_move; // the O(|ws|) score below replaces the move gate

        if let Some(acc) = accel.as_mut() {
            let t_extr = Instant::now();
            for (o, &j) in ws_beta.iter_mut().zip(ws.iter()) {
                *o = beta[j];
            }
            let full = acc.push(&ws_beta);
            push_snap(&mut g_snaps, &g);
            if full && epoch % acc.m() == 0 {
                if let Some(c) = acc.coefficients() {
                    let extr = acc.combine(&c);
                    let g_trial = acc.combine_series(&c, &g_snaps);
                    let trial = rel_objective(&extr, &g_trial);
                    let current = rel_objective(&ws_beta, &g);
                    // same guard as the residual engine: accept iff the
                    // (ws-restricted) objective strictly decreases and the
                    // trial stays in the penalty's domain
                    if trial.is_finite() && trial < current {
                        for (k, &j) in ws.iter().enumerate() {
                            beta[j] = extr[k];
                        }
                        g.copy_from_slice(&g_trial);
                        stats.accepted_extrapolations += 1;
                        acc.clear();
                        g_snaps.clear();
                        for (o, &j) in ws_beta.iter_mut().zip(ws.iter()) {
                            *o = beta[j];
                        }
                        acc.push(&ws_beta);
                        push_snap(&mut g_snaps, &g);
                    } else {
                        stats.rejected_extrapolations += 1;
                    }
                }
            }
            stats.profile.extrapolation_secs += t_extr.elapsed().as_secs_f64();
        }

        // stationarity from the maintained ws gradient: O(|ws|), so it
        // runs every epoch — no move-bound gating needed (the residual
        // engine gates because its check costs O(|ws|·n))
        let t_score = Instant::now();
        stats.score_checks += 1;
        let mut score = 0.0f64;
        for (k, &j) in ws.iter().enumerate() {
            let lj = lipschitz[j];
            if lj == 0.0 {
                continue;
            }
            let s = if penalty.use_cd_score() {
                (beta[j] - penalty.prox(beta[j] - g[k] / lj, 1.0 / lj, j)).abs()
            } else {
                penalty.subdiff_distance(beta[j], g[k], j)
            };
            score = score.max(s);
        }
        stats.ws_score = score;
        stats.profile.score_secs += t_score.elapsed().as_secs_f64();
        if score <= tol {
            break;
        }
    }

    // ---- 3. exit: refresh the residual state from the entry point ----
    let t_exit = Instant::now();
    for (k, &j) in ws.iter().enumerate() {
        let delta = beta[j] - b0[k];
        if delta != 0.0 {
            design.col_axpy(j, delta, state);
        }
    }
    stats.profile.epoch_secs += t_exit.elapsed().as_secs_f64();
    stats.profile.epoch_flops += nnz_ws as f64;
    stats
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{correlated, CorrelatedSpec};
    use crate::datafit::{Datafit, Quadratic};
    use crate::penalty::L1;
    use crate::solver::inner::inner_solver;

    fn lasso_problem() -> (Design, Vec<f64>, Quadratic, L1) {
        let ds = correlated(CorrelatedSpec { n: 60, p: 40, rho: 0.5, nnz: 5, snr: 10.0 }, 42);
        let mut f = Quadratic::new();
        f.init(&ds.design, &ds.y);
        let state0 = f.init_state(&ds.design, &ds.y, &vec![0.0; ds.p()]);
        let mut grad0 = vec![0.0; ds.p()];
        f.grad_full(&ds.design, &ds.y, &state0, &vec![0.0; ds.p()], &mut grad0);
        let lam = grad0.iter().fold(0.0f64, |m, g| m.max(g.abs())) / 10.0;
        (ds.design, ds.y, f, L1::new(lam))
    }

    #[test]
    fn gram_inner_matches_residual_inner_on_a_full_ws() {
        let (d, y, f, pen) = lasso_problem();
        let p = d.ncols();
        let ws: Vec<usize> = (0..p).collect();
        let scale = f.residual_quadratic_scale().unwrap();

        let mut beta_r = vec![0.0; p];
        let mut state_r = f.init_state(&d, &y, &beta_r);
        let sr = inner_solver(&d, &y, &f, &pen, &mut beta_r, &mut state_r, &ws, 3000, 1e-12, 5);

        let gram = GramCache::with_default_budget();
        let mut beta_g = vec![0.0; p];
        let mut state_g = f.init_state(&d, &y, &beta_g);
        let sg = gram_inner_solver(
            &d, f.lipschitz(), scale, &pen, &mut beta_g, &mut state_g, &ws, &gram, 3000, 1e-12, 5,
        );
        assert!(sr.ws_score <= 1e-12 && sg.ws_score <= 1e-12, "{} / {}", sr.ws_score, sg.ws_score);
        for (a, b) in beta_r.iter().zip(beta_g.iter()) {
            assert!((a - b).abs() < 1e-10, "betas diverged: {a} vs {b}");
        }
        // the exit refresh leaves a consistent residual state
        let fresh = f.init_state(&d, &y, &beta_g);
        for (a, b) in state_g.iter().zip(fresh.iter()) {
            assert!((a - b).abs() < 1e-9, "state drifted: {a} vs {b}");
        }
        assert!(sg.profile.gram_epochs > 0);
        assert!(sg.profile.gram_assembly_flops > 0.0);
        assert_eq!(sg.profile.residual_epochs, 0);
    }

    #[test]
    fn gram_extrapolation_guard_holds() {
        let (d, y, f, pen) = lasso_problem();
        let p = d.ncols();
        let ws: Vec<usize> = (0..p).collect();
        let scale = f.residual_quadratic_scale().unwrap();
        let gram = GramCache::with_default_budget();
        let mut beta = vec![0.0; p];
        let mut state = f.init_state(&d, &y, &beta);
        let mut prev = f.value(&y, &beta, &state) + pen.value_sum(&beta);
        for _ in 0..30 {
            gram_inner_solver(
                &d, f.lipschitz(), scale, &pen, &mut beta, &mut state, &ws, &gram, 5,
                f64::MIN_POSITIVE, 5,
            );
            let cur = f.value(&y, &beta, &state) + pen.value_sum(&beta);
            assert!(cur <= prev + 1e-10, "objective increased {prev} -> {cur}");
            prev = cur;
        }
    }

    #[test]
    fn second_solve_reuses_assembled_blocks() {
        let (d, y, f, pen) = lasso_problem();
        let p = d.ncols();
        let ws: Vec<usize> = (0..p / 2).collect();
        let scale = f.residual_quadratic_scale().unwrap();
        let gram = GramCache::with_default_budget();
        let mut beta = vec![0.0; p];
        let mut state = f.init_state(&d, &y, &beta);
        let s1 = gram_inner_solver(
            &d, f.lipschitz(), scale, &pen, &mut beta, &mut state, &ws, &gram, 50, 1e-10, 5,
        );
        assert!(s1.profile.gram_assembly_flops > 0.0);
        // same ws again: zero new assembly
        let s2 = gram_inner_solver(
            &d, f.lipschitz(), scale, &pen, &mut beta, &mut state, &ws, &gram, 50, 1e-10, 5,
        );
        assert_eq!(s2.profile.gram_assembly_flops, 0.0);
        // grown ws: only the new rows
        let grown: Vec<usize> = (0..p / 2 + 4).collect();
        let s3 = gram_inner_solver(
            &d, f.lipschitz(), scale, &pen, &mut beta, &mut state, &grown, &gram, 50, 1e-10, 5,
        );
        assert!(s3.profile.gram_assembly_flops > 0.0);
        assert!(s3.profile.gram_assembly_flops < s1.profile.gram_assembly_flops);
    }

    #[test]
    fn dispatcher_prefers_gram_when_n_dominates_ws() {
        // tall problem, small ws: m²·E ≪ 2·n·m·E
        let d: Design = crate::linalg::DenseMatrix::zeros(2000, 50).into();
        let gram = GramCache::with_default_budget();
        let ws: Vec<usize> = (0..10).collect();
        let disp = EngineDispatch::new(InnerEngine::Auto);
        assert!(disp.use_gram(&d, &ws, Some(&gram), true));
        // and never for non-quadratic datafits or when no cache exists
        assert!(!disp.use_gram(&d, &ws, None, true));
        assert!(!disp.use_gram(&d, &ws, Some(&gram), false));
        // fixed choices are honoured
        assert!(EngineDispatch::new(InnerEngine::Gram).use_gram(&d, &ws, Some(&gram), true));
        assert!(!EngineDispatch::new(InnerEngine::Residual).use_gram(&d, &ws, Some(&gram), true));
    }

    #[test]
    fn dispatcher_prefers_residual_on_wide_sparse_ws() {
        // |ws|² per epoch dwarfs the sparse column passes: residual wins
        let mut trips = Vec::new();
        for j in 0..400usize {
            trips.push((j % 20, j, 1.0));
        }
        let d: Design = crate::linalg::CscMatrix::from_triplets(20, 400, &trips).into();
        let gram = GramCache::with_default_budget();
        let ws: Vec<usize> = (0..300).collect();
        let disp = EngineDispatch::new(InnerEngine::Auto);
        // nnz(ws) = 300 (one entry per column) vs |ws|² = 90 000 per epoch
        assert!(!disp.use_gram(&d, &ws, Some(&gram), true));
    }

    #[test]
    fn engine_parses_from_cli_strings() {
        assert_eq!("auto".parse::<InnerEngine>().unwrap(), InnerEngine::Auto);
        assert_eq!("residual".parse::<InnerEngine>().unwrap(), InnerEngine::Residual);
        assert_eq!("gram".parse::<InnerEngine>().unwrap(), InnerEngine::Gram);
        assert!("graham".parse::<InnerEngine>().is_err());
    }
}
