//! The shared block-coordinate engine: Algorithm 1/2 generic over a
//! [`BlockPartition`] — one "coordinate" is a block `v_b`, the CD update is
//! `v_b ← prox_{g_b/L_b}(v_b − ∇_b f / L_b)` with the radial prox of
//! Proposition 18, and working sets, the guarded Anderson acceleration
//! (on packed working-set block vectors, with affine state snapshots) and
//! gap-safe screening per block carry over from the scalar solver.
//!
//! Instantiations:
//! - **groups**: [`crate::datafit::GroupedQuadratic`] × group penalties
//!   (group Lasso / weighted / group MCP / group SCAD);
//! - **multitask**: [`crate::datafit::multitask::QuadraticMultiTask`] ×
//!   row penalties — `solve_multitask` is now a thin wrapper here;
//! - **scalar**: the trivial partition reproduces scalar CD exactly
//!   (property-tested against `solve_lasso`-family solves to 1e-12).
//!
//! The outer loop itself lives in [`crate::solver::outer`] — this module
//! only implements the [`BlockCoords`] contract (scoring, screening,
//! inner solve) for block problems.

use super::anderson::Anderson;
use super::inner::InnerStats;
use super::outer::{solve_outer, BlockCoords};
use super::partition::BlockPartition;
use super::skglm::{ContinuationState, HistoryPoint, SolverOpts};
use crate::linalg::Design;
use crate::penalty::BlockPenalty;

/// Forced stationarity evaluation at least every this many epochs, even
/// while the cheap move bound stays large (mirrors the scalar inner
/// solver's gating).
const FORCE_CHECK_EVERY: usize = 50;

/// A smooth datafit viewed through a block partition: per-**block**
/// Lipschitz bounds, block gradients, and a state vector maintained
/// across block moves — the block analogue of [`crate::datafit::Datafit`].
pub trait BlockDatafit: Clone + Send + Sync {
    /// Precompute per-block Lipschitz bounds for this (design, target)
    /// pair. Must be called before solving. `col_sq_norms` is the cached
    /// Gram diagonal when the scheduler has one (skips the O(nnz) pass).
    fn init_cached(&mut self, design: &Design, y: &[f64], col_sq_norms: Option<&[f64]>);

    fn init(&mut self, design: &Design, y: &[f64]) {
        self.init_cached(design, y, None);
    }

    /// Per-block Lipschitz bounds `L_b` (length `n_blocks`). Valid after
    /// [`BlockDatafit::init_cached`]. Any upper bound on the spectral
    /// norm of the block Hessian is sound (the grouped quadratic uses the
    /// Frobenius bound `Σ_{j∈b} ‖X_j‖²/n`).
    fn block_lipschitz(&self) -> &[f64];

    /// Build the solver-maintained state for packed coefficients `v`.
    fn init_state(&self, design: &Design, y: &[f64], v: &[f64]) -> Vec<f64>;

    /// Maintain the state after `v_b += delta` (`delta` in block order).
    fn update_state(&self, design: &Design, b: usize, delta: &[f64], state: &mut [f64]);

    /// Datafit value at the current point.
    fn value(&self, y: &[f64], v: &[f64], state: &[f64]) -> f64;

    /// `∇_b f(v)` into `out[..block_len(b)]`.
    fn grad_block(
        &self,
        design: &Design,
        y: &[f64],
        state: &[f64],
        v: &[f64],
        b: usize,
        out: &mut [f64],
    );

    /// Full gradient in **packed** (partition) order — the O(n·p) scoring
    /// pass. Implementations override with a fused kernel-engine pass
    /// (grouped quadratic → [`Design::matvec_t_groups`]); the default
    /// walks blocks.
    fn grad_all(
        &self,
        design: &Design,
        y: &[f64],
        state: &[f64],
        v: &[f64],
        part: &BlockPartition,
        out: &mut [f64],
    ) {
        for b in 0..part.n_blocks() {
            let rng = part.packed_range(b);
            self.grad_block(design, y, state, v, b, &mut out[rng]);
        }
    }

    /// Whether the state is affine in `v` (all built-in block datafits:
    /// residuals). Enables the snapshot-combine Anderson path.
    fn state_is_affine(&self) -> bool {
        true
    }

    fn name(&self) -> &'static str;
}

/// Gap-safe screening configuration for convex group-ℓ2,1 problems on the
/// grouped **quadratic** datafit (state = Xβ − y). Per block `b` the
/// sphere test is `‖X_bᵀθ‖ + ‖X_b‖_F · √(2G)/(λ√n) < w_b` with the dual
/// point `θ = r / max(nλ, max_b ‖X_bᵀr‖/w_b)` — the block analogue of
/// `gap_safe_screen_lasso_update`. Unsound for non-convex penalties and
/// non-residual states; callers only enable it where it applies.
#[derive(Clone, Debug)]
pub struct GroupScreenCfg {
    pub lambda: f64,
    /// per-block dual-norm weights (`penalty.block_weight`)
    pub weights: Vec<f64>,
    /// per-block Frobenius norms `‖X_b‖_F` ([`Design::group_sq_norms`])
    pub block_frob: Vec<f64>,
}

/// Outcome of a block-coordinate solve. `v` is the packed coefficient
/// vector in natural coordinate order (β, or row-major flattened `W`).
#[derive(Clone, Debug)]
pub struct BlockFitResult {
    pub v: Vec<f64>,
    pub objective: f64,
    /// final max per-block optimality violation (`certificate` names the
    /// metric — always block stationarity for this engine)
    pub kkt: f64,
    /// which optimality metric `kkt` is
    pub certificate: crate::solver::skglm::Certificate,
    pub n_outer: usize,
    pub n_epochs: usize,
    pub converged: bool,
    pub history: Vec<HistoryPoint>,
    pub accepted_extrapolations: usize,
    pub rejected_extrapolations: usize,
    /// blocks certified inactive by the gap-safe pass (0 when disabled)
    pub n_screened: usize,
    /// per-stage wall-time attribution from the shared outer loop
    pub profile: crate::solver::inner::InnerProfile,
}

impl BlockFitResult {
    /// Blocks with any finite nonzero coordinate (NaN/∞ entries from a
    /// divergent non-convex fit do **not** count as support).
    pub fn block_support(&self, part: &BlockPartition) -> Vec<usize> {
        (0..part.n_blocks())
            .filter(|&b| {
                part.coords(b).iter().any(|&j| self.v[j] != 0.0 && self.v[j].is_finite())
            })
            .collect()
    }
}

/// The [`BlockCoords`] instantiation for block-CD problems: owns the
/// iterate, state and every scratch buffer; drives block epochs and the
/// packed-vector Anderson acceleration.
pub struct BlockCdCoords<'a, D: BlockDatafit, B: BlockPenalty> {
    design: &'a Design,
    y: &'a [f64],
    datafit: &'a D,
    penalty: &'a B,
    part: &'a BlockPartition,
    v: Vec<f64>,
    state: Vec<f64>,
    /// packed gradient (partition order), shared between the screening
    /// hook and the scoring pass within one outer iteration
    grad: Vec<f64>,
    grad_fresh: bool,
    frozen: Vec<bool>,
    gsupp: Vec<bool>,
    /// scratch: old block values / proposed values / gradient-then-delta
    buf_old: Vec<f64>,
    buf_new: Vec<f64>,
    buf_grad: Vec<f64>,
    screen_cfg: Option<GroupScreenCfg>,
    screen_r: Vec<f64>,
    /// per-block ‖X_bᵀr‖ scratch (screening hook; allocated once)
    screen_xtbr: Vec<f64>,
    n_screened: usize,
}

impl<'a, D: BlockDatafit, B: BlockPenalty> BlockCdCoords<'a, D, B> {
    /// Build the coords for an already-initialized datafit. `v0`
    /// warm-starts; `frozen` marks blocks certified inactive by the
    /// caller (e.g. a previous screening pass at the same λ).
    pub fn new(
        design: &'a Design,
        y: &'a [f64],
        datafit: &'a D,
        penalty: &'a B,
        part: &'a BlockPartition,
        v0: Option<&[f64]>,
        frozen: Option<&[bool]>,
    ) -> Self {
        let dim = part.dim();
        let nb = part.n_blocks();
        let v = match v0 {
            Some(w) => {
                assert_eq!(w.len(), dim);
                w.to_vec()
            }
            None => vec![0.0; dim],
        };
        let state = datafit.init_state(design, y, &v);
        let frozen = match frozen {
            Some(f) => {
                assert_eq!(f.len(), nb);
                f.to_vec()
            }
            None => vec![false; nb],
        };
        let mb = part.max_block_len();
        Self {
            design,
            y,
            datafit,
            penalty,
            part,
            v,
            state,
            grad: vec![0.0; dim],
            grad_fresh: false,
            frozen,
            gsupp: vec![false; nb],
            buf_old: vec![0.0; mb],
            buf_new: vec![0.0; mb],
            buf_grad: vec![0.0; mb],
            screen_cfg: None,
            screen_r: Vec::new(),
            screen_xtbr: Vec::new(),
            n_screened: 0,
        }
    }

    /// Enable the per-block gap-safe screening hook (convex group-ℓ2,1 on
    /// the grouped quadratic datafit only — see [`GroupScreenCfg`]).
    pub fn with_gap_screening(mut self, cfg: GroupScreenCfg) -> Self {
        assert!(self.penalty.is_convex(), "gap-safe screening needs a convex penalty");
        assert_eq!(cfg.weights.len(), self.part.n_blocks());
        assert_eq!(cfg.block_frob.len(), self.part.n_blocks());
        self.screen_r = vec![0.0; self.state.len()];
        self.screen_xtbr = vec![0.0; self.part.n_blocks()];
        self.screen_cfg = Some(cfg);
        self
    }

    /// Consume the coords, returning `(v, n_screened)`.
    pub fn into_parts(self) -> (Vec<f64>, usize) {
        (self.v, self.n_screened)
    }

    fn refresh_grad(&mut self) {
        if !self.grad_fresh {
            self.datafit
                .grad_all(self.design, self.y, &self.state, &self.v, self.part, &mut self.grad);
            self.grad_fresh = true;
        }
    }

    /// One cyclic block-CD epoch over `ws` (reversed when `rev`). Returns
    /// the max scaled move `max_b L_b·‖Δv_b‖_∞`.
    fn block_epoch(&mut self, ws: &[usize], rev: bool) -> f64 {
        let mut max_move = 0.0f64;
        if rev {
            for &b in ws.iter().rev() {
                max_move = max_move.max(self.sweep_block(b));
            }
        } else {
            for &b in ws {
                max_move = max_move.max(self.sweep_block(b));
            }
        }
        max_move
    }

    /// The block-CD update `v_b ← prox_{g_b/L_b}(v_b − ∇_b f/L_b)`.
    /// Returns the scaled move `L_b·‖Δv_b‖_∞` (0 when nothing changed).
    fn sweep_block(&mut self, b: usize) -> f64 {
        let lb = self.datafit.block_lipschitz()[b];
        if lb == 0.0 {
            return 0.0;
        }
        let len = self.part.block_len(b);
        let old = &mut self.buf_old[..len];
        self.part.gather(b, &self.v, old);
        let grad = &mut self.buf_grad[..len];
        self.datafit.grad_block(self.design, self.y, &self.state, &self.v, b, grad);
        let new = &mut self.buf_new[..len];
        for k in 0..len {
            new[k] = old[k] - grad[k] / lb;
        }
        self.penalty.prox(new, 1.0 / lb, b);
        // reuse the gradient buffer for the delta
        let mut changed = false;
        let mut max_abs = 0.0f64;
        for k in 0..len {
            let d = new[k] - old[k];
            grad[k] = d;
            if d != 0.0 {
                changed = true;
                max_abs = max_abs.max(d.abs());
            }
        }
        if changed {
            let new = &self.buf_new[..len];
            self.part.scatter(b, new, &mut self.v);
            let delta = &self.buf_grad[..len];
            self.datafit.update_state(self.design, b, delta, &mut self.state);
        }
        lb * max_abs
    }

    /// Max per-block score over `ws` (the gated stationarity check).
    fn ws_score_max(&mut self, ws: &[usize]) -> f64 {
        let lipschitz = self.datafit.block_lipschitz();
        let mut kkt = 0.0f64;
        for &b in ws {
            if lipschitz[b] == 0.0 {
                continue;
            }
            let len = self.part.block_len(b);
            let grad = &mut self.buf_grad[..len];
            self.datafit.grad_block(self.design, self.y, &self.state, &self.v, b, grad);
            let vb = &mut self.buf_old[..len];
            self.part.gather(b, &self.v, vb);
            kkt = kkt.max(self.penalty.subdiff_distance(vb, grad, b));
        }
        kkt
    }

    /// Gather the `ws` blocks of `v` into the packed Anderson vector.
    fn gather_ws(&self, ws: &[usize], out: &mut [f64]) {
        let mut k = 0;
        for &b in ws {
            for &j in self.part.coords(b) {
                out[k] = self.v[j];
                k += 1;
            }
        }
    }

    /// Penalty value restricted to `ws` at the packed candidate `cand`.
    fn ws_penalty_value(&mut self, ws: &[usize], cand: Option<&[f64]>) -> f64 {
        let mut g = 0.0;
        let mut k = 0usize;
        for &b in ws {
            let len = self.part.block_len(b);
            let vb = &mut self.buf_old[..len];
            match cand {
                Some(c) => vb.copy_from_slice(&c[k..k + len]),
                None => self.part.gather(b, &self.v, vb),
            }
            g += self.penalty.value(vb, b);
            k += len;
        }
        if !g.is_finite() && cand.is_none() {
            // current iterate must stay in-domain
            return f64::INFINITY;
        }
        g
    }

    /// Non-affine fallback: build the trial state by replaying block
    /// updates from the current iterate to the extrapolated one.
    fn replay_state(&mut self, ws: &[usize], extr: &[f64]) -> Vec<f64> {
        let mut trial = self.state.clone();
        let mut k = 0usize;
        for &b in ws {
            let len = self.part.block_len(b);
            let delta = &mut self.buf_grad[..len];
            let mut any = false;
            for (d, &j) in delta.iter_mut().zip(self.part.coords(b).iter()) {
                *d = extr[k] - self.v[j];
                if *d != 0.0 {
                    any = true;
                }
                k += 1;
            }
            if any {
                self.datafit.update_state(self.design, b, delta, &mut trial);
            }
        }
        trial
    }

    /// Objective guard: commit `extr` iff it strictly decreases the
    /// working-set-restricted objective.
    fn try_accept(&mut self, ws: &[usize], extr: &[f64], trial_state: &[f64]) -> bool {
        let g_ext = self.ws_penalty_value(ws, Some(extr));
        if !g_ext.is_finite() {
            return false;
        }
        let f_cur = self.datafit.value(self.y, &self.v, &self.state);
        let g_cur = self.ws_penalty_value(ws, None);
        let f_ext = self.datafit.value(self.y, &self.v, trial_state);
        if f_ext + g_ext < f_cur + g_cur {
            let mut k = 0usize;
            for &b in ws {
                for &j in self.part.coords(b) {
                    self.v[j] = extr[k];
                    k += 1;
                }
            }
            self.state.copy_from_slice(trial_state);
            true
        } else {
            false
        }
    }
}

impl<D: BlockDatafit, B: BlockPenalty> BlockCoords for BlockCdCoords<'_, D, B> {
    fn n_blocks(&self) -> usize {
        self.part.n_blocks()
    }

    fn screen(&mut self) {
        // take the cfg out (and restore it below) so its buffers can be
        // read while &mut self methods run — no per-iteration deep clone
        let Some(cfg) = self.screen_cfg.take() else { return };
        self.refresh_grad();
        let n = self.design.nrows() as f64;
        let nl = n * cfg.lambda;
        // r = y − Xβ = −state (grouped quadratic residual convention)
        for (ri, &s) in self.screen_r.iter_mut().zip(self.state.iter()) {
            *ri = -s;
        }
        // ‖X_bᵀr‖ = n·‖g_b‖ (the packed gradient is −Xᵀr/n)
        let nb = self.part.n_blocks();
        let mut scale = nl;
        for b in 0..nb {
            let g = &self.grad[self.part.packed_range(b)];
            let x = n * crate::linalg::nrm2(g);
            self.screen_xtbr[b] = x;
            scale = scale.max(x / cfg.weights[b]);
        }
        let primal = self.objective();
        let mut dev = 0.0;
        for (&ri, &yi) in self.screen_r.iter().zip(self.y.iter()) {
            let d = ri / scale - yi / nl;
            dev += d * d;
        }
        let dual = crate::linalg::sq_nrm2(self.y) / (2.0 * n) - nl * cfg.lambda / 2.0 * dev;
        let gap = (primal - dual).max(0.0);
        let radius = (2.0 * gap).sqrt() / (cfg.lambda * n.sqrt());
        let mut moved = false;
        for b in 0..nb {
            if self.frozen[b] {
                continue;
            }
            if self.screen_xtbr[b] / scale + cfg.block_frob[b] * radius < cfg.weights[b] {
                self.frozen[b] = true;
                // a newly certified block still holding a warm value is
                // frozen AT ZERO; the state moves with it
                let len = self.part.block_len(b);
                let delta = &mut self.buf_grad[..len];
                let mut any = false;
                for (d, &j) in delta.iter_mut().zip(self.part.coords(b).iter()) {
                    *d = -self.v[j];
                    if *d != 0.0 {
                        any = true;
                    }
                    self.v[j] = 0.0;
                }
                if any {
                    self.datafit.update_state(self.design, b, delta, &mut self.state);
                    moved = true;
                }
            }
        }
        if moved {
            self.grad_fresh = false;
        }
        self.n_screened = self.frozen.iter().filter(|&&f| f).count();
        self.screen_cfg = Some(cfg);
    }

    fn score_pass(&mut self, scores: &mut [f64]) -> f64 {
        self.refresh_grad();
        let lipschitz = self.datafit.block_lipschitz();
        let mut kkt_max = 0.0f64;
        for b in 0..self.part.n_blocks() {
            let len = self.part.block_len(b);
            let vb = &mut self.buf_old[..len];
            self.part.gather(b, &self.v, vb);
            self.gsupp[b] = self.penalty.in_gsupp(vb);
            if self.frozen[b] {
                scores[b] = f64::NEG_INFINITY;
                continue;
            }
            let s = if lipschitz[b] == 0.0 {
                0.0
            } else {
                let g = &self.grad[self.part.packed_range(b)];
                self.penalty.subdiff_distance(vb, g, b)
            };
            scores[b] = s;
            kkt_max = kkt_max.max(s);
        }
        kkt_max
    }

    fn objective(&self) -> f64 {
        self.datafit.value(self.y, &self.v, &self.state)
            + self.penalty.value_sum(&self.v, self.part)
    }

    fn in_gsupp(&self, b: usize) -> bool {
        self.gsupp[b]
    }

    fn inner_solve(&mut self, ws: &[usize], inner_tol: f64, opts: &SolverOpts) -> InnerStats {
        // v is about to move: the cached packed gradient goes stale
        self.grad_fresh = false;
        let mut stats = InnerStats::default();
        let affine = self.datafit.state_is_affine();
        let mut accel =
            if opts.anderson_m >= 2 { Some(Anderson::new(opts.anderson_m)) } else { None };
        let ws_dim: usize = ws.iter().map(|&b| self.part.block_len(b)).sum();
        let mut ws_v = vec![0.0; ws_dim];
        let mut state_snaps: Vec<Vec<f64>> = Vec::new();
        let snap_cap = opts.anderson_m + 1;
        let push_snap = |snaps: &mut Vec<Vec<f64>>, state: &[f64]| {
            if snaps.len() == snap_cap {
                snaps.remove(0);
            }
            snaps.push(state.to_vec());
        };

        if let Some(acc) = accel.as_mut() {
            self.gather_ws(ws, &mut ws_v);
            acc.push(&ws_v);
            if affine {
                push_snap(&mut state_snaps, &self.state);
            }
        }

        let mut epochs_since_check = 0usize;
        for epoch in 1..=opts.max_epochs {
            stats.epochs = epoch;
            // alternate sweep direction (Proposition 13 hypothesis 3)
            let max_move = self.block_epoch(ws, epoch % 2 == 0);

            if let Some(acc) = accel.as_mut() {
                self.gather_ws(ws, &mut ws_v);
                let full = acc.push(&ws_v);
                if affine {
                    push_snap(&mut state_snaps, &self.state);
                }
                if full && epoch % acc.m() == 0 {
                    if let Some(c) = acc.coefficients() {
                        let extr = acc.combine(&c);
                        let trial_state = if affine {
                            acc.combine_series(&c, &state_snaps)
                        } else {
                            self.replay_state(ws, &extr)
                        };
                        if self.try_accept(ws, &extr, &trial_state) {
                            stats.accepted_extrapolations += 1;
                            acc.clear();
                            state_snaps.clear();
                            self.gather_ws(ws, &mut ws_v);
                            acc.push(&ws_v);
                            if affine {
                                push_snap(&mut state_snaps, &self.state);
                            }
                        } else {
                            stats.rejected_extrapolations += 1;
                        }
                    }
                }
            }

            // cheap move bound gates the O(|ws|·n) stationarity evaluation
            epochs_since_check += 1;
            let due = max_move <= inner_tol
                || epochs_since_check >= FORCE_CHECK_EVERY
                || epoch == opts.max_epochs;
            if due {
                epochs_since_check = 0;
                stats.score_checks += 1;
                let score = self.ws_score_max(ws);
                stats.ws_score = score;
                if score <= inner_tol {
                    return stats;
                }
            }
        }
        // no post-loop recompute: on epoch == max_epochs the forced due
        // check above already evaluated (and recorded) the final ws score
        stats
    }

    fn final_kkt(&mut self) -> f64 {
        // frozen blocks are certified inactive: excluded from the metric
        let active: Vec<usize> =
            (0..self.part.n_blocks()).filter(|&b| !self.frozen[b]).collect();
        self.ws_score_max(&active)
    }

    fn label(&self) -> &'static str {
        "block-cd"
    }
}

/// Solve a block-separable problem through the shared engine. The datafit
/// must already be constructed for `part` (e.g. `GroupedQuadratic::new`);
/// `init_cached` is called here.
pub fn solve_blocks<D: BlockDatafit, B: BlockPenalty>(
    design: &Design,
    y: &[f64],
    part: &BlockPartition,
    datafit: &mut D,
    penalty: &B,
    opts: &SolverOpts,
    v0: Option<&[f64]>,
) -> BlockFitResult {
    let mut state =
        ContinuationState { beta: v0.map(|v| v.to_vec()), ..ContinuationState::default() };
    solve_blocks_continued(design, y, part, datafit, penalty, opts, &mut state, None, None)
}

/// [`solve_blocks`] threading a [`ContinuationState`] (warm packed
/// coefficients + working-set size) — the entry point block path sweeps
/// use. `screen` enables the per-block gap-safe hook where it is sound
/// (convex group-ℓ2,1 × grouped quadratic).
#[allow(clippy::too_many_arguments)]
pub fn solve_blocks_continued<D: BlockDatafit, B: BlockPenalty>(
    design: &Design,
    y: &[f64],
    part: &BlockPartition,
    datafit: &mut D,
    penalty: &B,
    opts: &SolverOpts,
    continuation: &mut ContinuationState,
    col_sq_norms: Option<&[f64]>,
    screen: Option<GroupScreenCfg>,
) -> BlockFitResult {
    datafit.init_cached(design, y, col_sq_norms);

    // non-convex validity (Assumption 6): largest block step is 1/min L_b
    let min_l = datafit
        .block_lipschitz()
        .iter()
        .cloned()
        .filter(|&l| l > 0.0)
        .fold(f64::INFINITY, f64::min);
    if min_l.is_finite() {
        penalty.validate_step(1.0 / min_l);
    }

    let mut coords = BlockCdCoords::new(
        design,
        y,
        datafit,
        penalty,
        part,
        continuation.beta.as_deref(),
        None,
    );
    if let Some(cfg) = screen {
        coords = coords.with_gap_screening(cfg);
    }
    let out = solve_outer(&mut coords, opts, continuation.ws_size);
    let (v, n_screened) = coords.into_parts();
    let result = BlockFitResult {
        v,
        objective: out.objective,
        kkt: out.kkt,
        certificate: crate::solver::skglm::Certificate::Stationarity,
        n_outer: out.n_outer,
        n_epochs: out.n_epochs,
        converged: out.converged,
        history: out.history,
        accepted_extrapolations: out.accepted_extrapolations,
        rejected_extrapolations: out.rejected_extrapolations,
        n_screened,
        profile: out.profile,
    };
    continuation.beta = Some(result.v.clone());
    continuation.ws_size = Some(out.ws_size);
    result
}

/// Smallest λ whose solution is all-zero for a block problem:
/// `max_b ‖∇_b f(0)‖₂ / w_b` (`w_b = penalty.block_weight`, 1 when
/// unweighted). `weights` is optional per-block dual-norm weights.
pub fn block_lambda_max_for<D: BlockDatafit>(
    design: &Design,
    y: &[f64],
    datafit: &mut D,
    part: &BlockPartition,
    weights: Option<&[f64]>,
) -> f64 {
    datafit.init(design, y);
    let v0 = vec![0.0; part.dim()];
    let state = datafit.init_state(design, y, &v0);
    let mut grad = vec![0.0; part.dim()];
    datafit.grad_all(design, y, &state, &v0, part, &mut grad);
    let mut best = 0.0f64;
    for b in 0..part.n_blocks() {
        let g = &grad[part.packed_range(b)];
        let w = weights.map(|w| w[b]).unwrap_or(1.0);
        best = best.max(crate::linalg::nrm2(g) / w);
    }
    best
}
