//! `skglm` — CLI launcher for the skglm-rs framework.
//!
//! ```text
//! skglm solve   --dataset rcv1 --penalty l1 --lambda-ratio 0.01 [--engine pjrt]
//! skglm path    --penalty mcp --points 20   # warm-started sweep via the scheduler
//! skglm exp     <fig1..fig10|table1|table2|pathsched|all> [--full]
//! skglm conform [--smoke] [--filter l1]  # scenario conformance corpus
//! skglm analyze [--root .]          # self-hosted static-analysis pass
//! skglm serve   --listen 127.0.0.1:7878 --workers 4   # TCP fit service
//! skglm client  submit --model lasso --watch          # protocol client
//! skglm info                        # capability table + runtime probe
//! ```

use anyhow::{bail, Result};
use skglm::bench::figures::{run_experiment, Scale, ALL_EXPERIMENTS};
use skglm::cli::Args;
use skglm::data::{correlated, paper_dataset, paper_dataset_small, CorrelatedSpec, Dataset};
use skglm::datafit::Quadratic;
use skglm::estimators::linear::quadratic_lambda_max;
use skglm::penalty::{L1L2, Lq, Mcp, Scad, L1};
use skglm::solver::{solve, FitResult, SolverOpts};

fn main() {
    let mut args = Args::from_env();
    let code = match dispatch(&mut args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            2
        }
    };
    std::process::exit(code);
}

fn dispatch(args: &mut Args) -> Result<()> {
    // global thread budget: --threads > SKGLM_THREADS > hardware; shared
    // by the kernel engine and every worker pool (see ARCHITECTURE.md
    // §Kernel engine)
    if let Some(t) = args.take_threads()? {
        skglm::linalg::parallel::set_thread_budget(t);
    }
    // global many-fit batching gate: --batch > SKGLM_BATCH > on (see
    // ARCHITECTURE.md §Batched fits); the library reads the env var
    if let Some(on) = args.take_batch()? {
        std::env::set_var("SKGLM_BATCH", if on { "1" } else { "0" });
    }
    // kernel ISA pin: --isa > SKGLM_ISA > runtime probe (see
    // ARCHITECTURE.md §Kernel ISA & precision); pinned process-wide
    if let Some(name) = args.take_isa()? {
        skglm::linalg::simd::install_isa(&name);
    }
    // full-design pass precision: --precision > SKGLM_PRECISION > f64;
    // SolverOpts::default() reads the env var
    if let Some(p) = args.take_precision()? {
        std::env::set_var("SKGLM_PRECISION", p.as_str());
    }
    match args.subcommand() {
        Some("solve") => cmd_solve(args),
        Some("path") => cmd_path(args),
        Some("cv") => cmd_cv(args),
        Some("exp") => cmd_exp(args),
        Some("conform") => cmd_conform(args),
        Some("analyze") => cmd_analyze(args),
        Some("serve") => cmd_serve(args),
        Some("client") => cmd_client(args),
        Some("synth") => cmd_synth(args),
        Some("info") => cmd_info(args),
        Some(other) => bail!("unknown subcommand {other:?}\n{USAGE}"),
        None => {
            println!("{USAGE}");
            Ok(())
        }
    }
}

const USAGE: &str = "usage:
  skglm solve --dataset <name|libsvm-path> \\
              --penalty <l1|enet|mcp|scad|l05|group_lasso|group_mcp|group_scad> \\
              [--datafit quadratic|poisson|probit] --lambda-ratio 0.1 \\
              [--gamma 3.0] [--rho 0.5] [--groups 10] [--tol 1e-8] \\
              [--inner auto|residual|gram] \\
              [--engine native|pjrt] [--no-ws] [--no-accel] [--seed 42] [--small]
  skglm path  --penalty <l1|mcp|scad|l05|group_lasso|group_mcp|group_scad> \\
              [--datafit quadratic|poisson|probit] [--groups 10] \\
              [--inner auto|residual|gram] \\
              [--points 20] [--min-ratio 1e-3] [--gamma 3.0] [--small] [--seed 42]
  skglm cv    --dataset <name> [--folds 5] [--points 15] [--workers 4] [--small]
  skglm exp   <fig1..fig10|table1|table2|pathsched|kernels|glms|groups|gram|batch|simd|analysis|scenarios|summary|all> [--full]
  skglm conform [--smoke] [--filter <substr>] [--corpus <scenarios.jsonl>]
  skglm analyze [--root <repo>] [--quiet]
  skglm serve [--listen 127.0.0.1:7878] [--workers 4] [--queue 32] \\
              [--frame-bytes N] [--cache-bytes N] [--tenant-bytes N] \\
              [--faults <plan>] [--demo [--lambdas 8]]
  skglm client [ping|stats|status|cancel|submit|shutdown] \\
              [--addr 127.0.0.1:7878] [--tenant cli] [--session cli] \\
              [--timeout-s 30] [--retries 6] [--retry-seed 0] [--job <id>] \\
              [--kind fit|path] [--model lasso|enet|mcp|scad|lq|poisson] \\
              [--lambda-ratio 0.1] [--points 16] [--min-ratio 0.01] \\
              [--deadline-ms N] [--priority interactive|batch] \\
              [--dataset fig1|correlated|poisson] [--scale 0.02] \\
              [--n 200] [--p 400] [--data-seed 42] [--watch]
  skglm client --script smoke [--transcript <out.json>]
  skglm synth --dataset <rcv1|news20|...|fig1> --out <file.svm> [--small]
  skglm info

  --datafit poisson|probit routes the fit through the prox-Newton outer
  solver (curvature-adaptive GLMs; penalty must be l1). the group_*
  penalties run on the block-coordinate engine over contiguous feature
  groups of --groups <size> features each. --inner picks the inner engine
  for quadratic fits: residual CD, Gram-domain CD (O(|ws|) updates on
  cached working-set Grams), or cost-model auto dispatch (the default;
  non-quadratic datafits always run residual). every subcommand accepts
  --threads N (kernel + worker thread budget; overrides the SKGLM_THREADS
  env var; defaults to hardware parallelism) and --batch on|off (many-fit
  batching: CV folds and fusible sibling jobs solved as one multi-RHS
  panel batch; overrides the SKGLM_BATCH env var; defaults to on — each
  batch member is bit-identical to the scalar solver, so the switch is
  for A/B benchmarking). --isa scalar|avx2|avx2fma|neon|neonfma|auto pins
  the micro-kernel ISA (overrides SKGLM_ISA; auto probes the CPU; an
  unsupported request falls back to scalar) and --precision f64|f32|mixed
  picks the full-design pass precision (overrides SKGLM_PRECISION;
  reduced modes keep CD epochs and KKT certificates in f64 and clamp
  --tol to the mode's certified floor; see ARCHITECTURE.md §Kernel ISA &
  precision). `exp summary` rolls every
  repo-root BENCH_*.json into BENCH_SUMMARY.json. `conform` runs the
  declarative scenario conformance corpus (scenarios.jsonl at the repo
  root when present, else the built-in corpus) — every datafit × penalty
  through the real scheduler, cross-engine / thread-count / warm-vs-cold
  oracles per scenario — and exits non-zero when any scenario fails;
  --smoke runs the CI gate subset, --filter selects scenarios whose
  id/datafit/penalty contains the substring. `serve` runs the TCP fit
  service (length-prefixed JSON frames; see ARCHITECTURE.md §Service):
  admission control at --queue depth, per-tenant cache byte budgets, and
  a --faults plan (or SKGLM_FAULTS) for deterministic fault injection;
  --demo drives a geometric λ sweep through the wire against the running
  service. `client` talks to a service: submit/cancel/status/stats/ping/
  shutdown verbs, --watch streams job events to the terminal, and
  --script smoke self-hosts the scripted loopback acceptance session CI
  runs (exits non-zero when any step degrades). `analyze` runs the
  self-hosted static-analysis pass (panic-audit, lock-order,
  atomic-ordering, unsafe-audit, determinism, doc-conformance, isa-gate;
  see
  ARCHITECTURE.md §Static analysis) over the source tree at --root,
  writes BENCH_analysis.json, and exits non-zero on any finding not
  covered by an inline `// lint: allow(rule, reason)` suppression";

/// Load `name` as a libsvm file when it names one on disk.
fn try_load_libsvm(name: &str) -> Option<Result<Dataset>> {
    if !std::path::Path::new(name).exists() {
        return None;
    }
    Some(skglm::data::libsvm::parse_file(name).map(|parsed| Dataset {
        name: name.to_string(),
        design: parsed.x.into(),
        y: parsed.y,
        beta_true: Vec::new(),
    }))
}

fn load_dataset(args: &mut Args) -> Result<Dataset> {
    let name = args.get_or("dataset", "rcv1");
    let seed = args.get_usize("seed", 42)? as u64;
    let small = args.has("small");
    if let Some(parsed) = try_load_libsvm(&name) {
        return parsed;
    }
    if name == "fig1" {
        return Ok(correlated(CorrelatedSpec::figure1(if small { 0.1 } else { 1.0 }), seed));
    }
    let ds = if small { paper_dataset_small(&name, seed) } else { paper_dataset(&name, seed) };
    ds.ok_or_else(|| anyhow::anyhow!("unknown dataset {name:?} (and not a file)"))
}

fn print_fit(res: &FitResult, n: usize) {
    println!("converged      : {}", res.converged);
    println!("objective      : {:.10e}", res.objective);
    println!("kkt violation  : {:.3e}", res.kkt);
    println!("support size   : {}", res.support().len());
    println!("outer iters    : {}", res.n_outer);
    println!("cd epochs      : {}", res.n_epochs);
    println!("extrapolations : {} accepted / {} rejected", res.accepted_extrapolations, res.rejected_extrapolations);
    let pr = &res.profile;
    println!(
        "kernel floor   : {} isa, {} precision",
        pr.kernel_isa.as_str(),
        pr.precision.as_str()
    );
    if pr.gram_epochs > 0 || pr.residual_epochs > 0 {
        println!(
            "inner engines  : {} gram / {} residual epochs ({:.2} Mflop epochs, {:.2} Mflop gram assembly)",
            pr.gram_epochs,
            pr.residual_epochs,
            pr.epoch_flops / 1e6,
            pr.gram_assembly_flops / 1e6
        );
    }
    if let Some(h) = res.history.last() {
        println!("solve time     : {:.3}s  (n={n})", h.t);
    }
}

/// Build the GLM workload for `--datafit poisson|probit`: a libsvm file
/// when one is named (targets validated here, not by library asserts),
/// else the correlated synthetic generator with model-consistent targets
/// (dataset name `synthetic`, the default).
fn load_glm_dataset(args: &mut Args, datafit: &str) -> Result<Dataset> {
    let name = args.get_or("dataset", "synthetic");
    let seed = args.get_usize("seed", 42)? as u64;
    let small = args.has("small");
    if let Some(parsed) = try_load_libsvm(&name) {
        let ds = parsed?;
        match datafit {
            "poisson" => {
                if let Some(bad) = ds.y.iter().find(|&&v| v < 0.0 || v.fract() != 0.0) {
                    bail!(
                        "{name}: poisson targets must be nonnegative counts, found {bad}"
                    );
                }
            }
            _ => {
                if let Some(bad) = ds.y.iter().find(|&&v| v != 1.0 && v != -1.0) {
                    bail!("{name}: probit labels must be ±1, found {bad}");
                }
            }
        }
        return Ok(ds);
    }
    if name != "synthetic" {
        bail!("unknown dataset {name:?} (not a file; --datafit {datafit} takes a libsvm path or the default synthetic workload)");
    }
    let spec = CorrelatedSpec::figure1(if small { 0.1 } else { 0.5 });
    Ok(match datafit {
        "poisson" => skglm::data::poisson_correlated(spec, seed),
        _ => skglm::data::probit_correlated(spec, seed),
    })
}

/// λ_max + prox-Newton solve for one GLM datafit type.
fn run_glm_fit<D: skglm::datafit::Datafit + Default>(
    ds: &Dataset,
    ratio: f64,
    opts: &SolverOpts,
) -> (f64, FitResult) {
    let mut f = D::default();
    let lam_max = skglm::solver::glm_lambda_max(&f, &ds.design, &ds.y);
    let r = skglm::solver::solve_prox_newton(
        &ds.design,
        &ds.y,
        &mut f,
        &L1::new(lam_max * ratio),
        opts,
        None,
    );
    (lam_max, r)
}

/// One prox-Newton fit (`solve --datafit poisson|probit`).
fn cmd_solve_glm(args: &mut Args, datafit: &str) -> Result<()> {
    if !matches!(datafit, "poisson" | "probit") {
        bail!("unknown datafit {datafit:?} (quadratic|poisson|probit)");
    }
    let penalty = args.get_or("penalty", "l1");
    if penalty != "l1" {
        bail!("--datafit {datafit} supports --penalty l1 only (got {penalty:?})");
    }
    let ratio = args.get_f64("lambda-ratio", 0.1)?;
    let tol = args.get_f64("tol", 1e-8)?;
    let mut opts = SolverOpts::default().with_tol(tol);
    if args.has("no-ws") {
        opts.use_ws = false;
    }
    if args.has("no-accel") {
        opts.anderson_m = 0;
    }
    opts.verbose = args.has("verbose");
    let ds = load_glm_dataset(args, datafit)?;
    args.finish()?;

    let (lam_max, res) = match datafit {
        "poisson" => run_glm_fit::<skglm::datafit::Poisson>(&ds, ratio, &opts),
        _ => run_glm_fit::<skglm::datafit::Probit>(&ds, ratio, &opts),
    };
    println!(
        "dataset {} (n={}, p={}), datafit {datafit}, lambda = {:.3e} (ratio {ratio})",
        ds.name,
        ds.n(),
        ds.p(),
        lam_max * ratio
    );
    println!("solver         : prox-newton (outer Newton x inner CD)");
    print_fit(&res, ds.n());
    Ok(())
}

/// One block-engine fit (`solve --penalty group_lasso|group_mcp|group_scad`).
fn cmd_solve_group(args: &mut Args, penalty: &str) -> Result<()> {
    use skglm::penalty::{GroupMcp, GroupScad};
    use skglm::solver::BlockPartition;
    use std::sync::Arc;
    let ratio = args.get_f64("lambda-ratio", 0.1)?;
    let gamma = args.get_f64("gamma", if penalty == "group_scad" { 3.7 } else { 3.0 })?;
    let group_size = args.get_usize("groups", 10)?;
    let tol = args.get_f64("tol", 1e-8)?;
    let mut opts = SolverOpts::default().with_tol(tol);
    if args.has("no-ws") {
        opts.use_ws = false;
    }
    if args.has("no-accel") {
        opts.anderson_m = 0;
    }
    opts.verbose = args.has("verbose");
    let mut ds = load_dataset(args)?;
    args.finish()?;
    if group_size == 0 || group_size > ds.p() {
        bail!("--groups must be in 1..={} (got {group_size})", ds.p());
    }
    // non-convex group penalties follow the paper's √n column
    // normalization (keeps every block step inside the MCP/SCAD
    // semi-convex regime on heterogeneous designs)
    if penalty != "group_lasso" {
        ds.design.normalize_cols((ds.n() as f64).sqrt());
    }

    let part = Arc::new(BlockPartition::contiguous_equal(ds.p(), group_size));
    let lam_max = skglm::estimators::group_lambda_max(&ds.design, &ds.y, &part, None);
    let lam = lam_max * ratio;
    println!(
        "dataset {} (n={}, p={}, {} groups of <= {group_size}), penalty {penalty}, lambda = {:.3e} (ratio {ratio})",
        ds.name,
        ds.n(),
        ds.p(),
        part.n_blocks(),
        lam
    );
    println!("solver         : block-coordinate engine (shared outer loop)");
    let fit = match penalty {
        // the convex constructor enables gap-safe block screening, so the
        // "screened blocks" line below reports the real certificate count
        "group_lasso" => skglm::estimators::group::group_lasso(lam, Arc::clone(&part))
            .with_opts(opts)
            .fit(&ds.design, &ds.y),
        "group_mcp" => skglm::estimators::group::GroupEstimator::from_parts(
            GroupMcp::new(lam, gamma),
            Arc::clone(&part),
            opts,
        )
        .fit(&ds.design, &ds.y),
        "group_scad" => skglm::estimators::group::GroupEstimator::from_parts(
            GroupScad::new(lam, gamma),
            Arc::clone(&part),
            opts,
        )
        .fit(&ds.design, &ds.y),
        other => bail!("unknown group penalty {other:?}"),
    };
    let r = &fit.result;
    println!("converged      : {}", r.converged);
    println!("objective      : {:.10e}", r.objective);
    println!("kkt violation  : {:.3e}", r.kkt);
    println!("group support  : {} / {}", fit.group_support().len(), part.n_blocks());
    println!("outer iters    : {}", r.n_outer);
    println!("cd epochs      : {}", r.n_epochs);
    println!("screened blocks: {}", r.n_screened);
    if let Some(h) = r.history.last() {
        println!("solve time     : {:.3}s  (n={})", h.t, ds.n());
    }
    Ok(())
}

/// Parse the `--inner auto|residual|gram` knob (the CLI's quadratic
/// fits route adaptively by default; the engine is inert for datafits
/// without the Gram contract).
fn take_inner(args: &mut Args) -> Result<skglm::solver::InnerEngine> {
    args.get_or("inner", "auto")
        .parse::<skglm::solver::InnerEngine>()
        .map_err(|e| anyhow::anyhow!(e))
}

fn cmd_solve(args: &mut Args) -> Result<()> {
    let inner = take_inner(args)?;
    let datafit = args.get_or("datafit", "quadratic");
    if datafit != "quadratic" {
        return cmd_solve_glm(args, &datafit);
    }
    let pen_name = args.get_or("penalty", "l1");
    if pen_name.starts_with("group_") {
        return cmd_solve_group(args, &pen_name);
    }
    let ds = load_dataset(args)?;
    let penalty = args.get_or("penalty", "l1");
    let ratio = args.get_f64("lambda-ratio", 0.1)?;
    let gamma = args.get_f64("gamma", 3.0)?;
    let rho = args.get_f64("rho", 0.5)?;
    let tol = args.get_f64("tol", 1e-8)?;
    let engine = args.get_or("engine", "native");
    let mut opts = SolverOpts::default().with_tol(tol).with_inner(inner);
    if args.has("no-ws") {
        opts.use_ws = false;
    }
    if args.has("no-accel") {
        opts.anderson_m = 0;
    }
    opts.verbose = args.has("verbose");
    args.finish()?;

    // MCP/SCAD: paper convention, normalise columns to √n
    let needs_norm = matches!(penalty.as_str(), "mcp" | "scad" | "l05");
    let mut design = ds.design.clone();
    if needs_norm {
        design.normalize_cols((ds.n() as f64).sqrt());
    }
    let lam_max = quadratic_lambda_max(&design, &ds.y);
    let lam = lam_max * ratio;
    println!(
        "dataset {} (n={}, p={}), penalty {penalty}, lambda = {:.3e} (ratio {ratio})",
        ds.name,
        ds.n(),
        ds.p(),
        lam
    );

    let mut datafit = Quadratic::new();
    let mut pjrt_engine = None;
    if engine == "pjrt" {
        let rt = skglm::runtime::PjrtRuntime::cpu()?;
        match skglm::runtime::PjrtGradEngine::for_design(&rt, &design) {
            Ok(e) => {
                println!("scoring engine : pjrt ({})", rt.platform());
                pjrt_engine = Some(e);
            }
            Err(e) => println!("scoring engine : native (pjrt unavailable: {e})"),
        }
    }
    let engine_ref: Option<&mut dyn skglm::solver::GradEngine> =
        pjrt_engine.as_mut().map(|e| e as &mut dyn skglm::solver::GradEngine);

    let res = match penalty.as_str() {
        "l1" => solve(&design, &ds.y, &mut datafit, &L1::new(lam), &opts, engine_ref, None),
        "enet" => solve(&design, &ds.y, &mut datafit, &L1L2::new(lam, rho), &opts, engine_ref, None),
        "mcp" => solve(&design, &ds.y, &mut datafit, &Mcp::new(lam, gamma), &opts, engine_ref, None),
        "scad" => solve(&design, &ds.y, &mut datafit, &Scad::new(lam, gamma), &opts, engine_ref, None),
        "l05" => solve(&design, &ds.y, &mut datafit, &Lq::half(lam), &opts, engine_ref, None),
        other => bail!("unknown penalty {other:?}"),
    };
    print_fit(&res, ds.n());
    if let Some(e) = &pjrt_engine {
        println!("pjrt grad calls: {}", e.calls);
    }
    Ok(())
}

fn cmd_path(args: &mut Args) -> Result<()> {
    use skglm::coordinator::{specs, FitScheduler, JobEvent};
    use std::sync::Arc;
    let inner = take_inner(args)?;
    let datafit = args.get_or("datafit", "quadratic");
    let penalty = args.get_or("penalty", "l1");
    let points = args.get_usize("points", 20)?;
    let min_ratio = args.get_f64("min-ratio", 1e-3)?;
    let gamma = args.get_f64("gamma", if penalty.ends_with("scad") { 3.7 } else { 3.0 })?;
    let group_size = args.get_usize("groups", 10)?;
    let seed = args.get_usize("seed", 42)? as u64;
    let small = args.has("small");
    args.finish()?;

    // λ is a placeholder everywhere below: the path job anchors the grid
    // at its own λ_max
    let (ds, spec) = match datafit.as_str() {
        "quadratic" if penalty.starts_with("group_") => {
            // group-sparse synthetic workload + block-engine path specs
            let scale = if small { 0.1 } else { 1.0 };
            let p = ((2000.0 * scale) as usize).max(8);
            let n = ((1000.0 * scale) as usize).max(8);
            let gs = group_size.clamp(1, p);
            let (gds, part) = skglm::data::grouped_correlated(
                skglm::data::GroupedSpec {
                    n,
                    p,
                    group_size: gs,
                    active_groups: (p / gs / 10).max(1),
                    rho: 0.6,
                    snr: 5.0,
                },
                seed,
            );
            let spec = match penalty.as_str() {
                "group_lasso" => specs::group_lasso(1.0, part),
                "group_mcp" => specs::group_mcp(1.0, gamma, part),
                "group_scad" => specs::group_scad(1.0, gamma, part),
                other => bail!("unknown group penalty {other:?}"),
            };
            (Arc::new(gds), spec)
        }
        "quadratic" => {
            let ds =
                Arc::new(correlated(CorrelatedSpec::figure1(if small { 0.1 } else { 1.0 }), seed));
            let spec = match penalty.as_str() {
                "l1" => specs::lasso(1.0),
                "mcp" => specs::mcp(1.0, gamma),
                "scad" => specs::scad(1.0, gamma),
                "l05" => specs::lq(1.0, 0.5),
                other => bail!("unknown penalty {other:?}"),
            };
            (ds, spec)
        }
        glm @ ("poisson" | "probit") => {
            if penalty != "l1" {
                bail!("--datafit {glm} supports --penalty l1 only (got {penalty:?})");
            }
            let spec_cfg = CorrelatedSpec::figure1(if small { 0.1 } else { 0.5 });
            if glm == "poisson" {
                (
                    Arc::new(skglm::data::poisson_correlated(spec_cfg, seed)),
                    specs::poisson_l1(1.0),
                )
            } else {
                (
                    Arc::new(skglm::data::probit_correlated(spec_cfg, seed)),
                    specs::probit_l1(1.0),
                )
            }
        }
        other => bail!("unknown datafit {other:?} (quadratic|poisson|probit)"),
    };
    let ratios = skglm::estimators::path::geometric_grid(min_ratio, points);
    let sched = FitScheduler::start(1);
    let job = sched.submit_path(
        Arc::clone(&ds),
        spec,
        ratios,
        SolverOpts::default().with_tol(1e-7).with_inner(inner),
    );
    println!(
        "datafit {datafit} / penalty {penalty}: streaming {points} warm-started path points (job {job})"
    );
    println!("lambda_ratio  support  est_err    pred_mse   exact  epochs  screened");
    loop {
        match sched.events.recv() {
            Ok(JobEvent::PathPoint(p)) => println!(
                "{:<12.4e}  {:<7}  {:<9.3e}  {:<9.3e}  {:<5}  {:<6}  {}",
                p.point.lambda_ratio,
                p.point.support_size,
                p.point.estimation_error.unwrap_or(f64::NAN),
                p.point.prediction_mse.unwrap_or(f64::NAN),
                p.point.recovery.as_ref().map(|r| r.exact).unwrap_or(false),
                p.epochs,
                p.n_screened
            ),
            Ok(JobEvent::PathDone(s)) => {
                println!(
                    "{}: {} points in {:.2}s ({} CD epochs total)",
                    s.label, s.n_points, s.total_time, s.total_epochs
                );
                break;
            }
            Ok(JobEvent::FitDone(_)) => {}
            Ok(JobEvent::Failed { job_id, message }) => {
                bail!("path job {job_id} failed on its worker: {message}")
            }
            Ok(JobEvent::Cancelled { job_id, points_emitted }) => {
                bail!("path job {job_id} was cancelled after {points_emitted} points")
            }
            Ok(JobEvent::SchedulerDown) | Err(_) => bail!("scheduler died"),
        }
    }
    sched.shutdown();
    Ok(())
}

fn cmd_exp(args: &mut Args) -> Result<()> {
    let name = args
        .positional
        .get(1)
        .cloned()
        .ok_or_else(|| anyhow::anyhow!("exp needs a name: {ALL_EXPERIMENTS:?} or all"))?;
    let scale = if args.has("full") { Scale::Full } else { Scale::Smoke };
    args.finish()?;
    let outputs = run_experiment(&name, scale)?;
    for p in outputs {
        println!("wrote {}", p.display());
    }
    Ok(())
}

fn cmd_analyze(args: &mut Args) -> Result<()> {
    let root = args.get_or("root", ".");
    let quiet = args.has("quiet");
    args.finish()?;
    let outputs = skglm::analysis::run(std::path::Path::new(&root), quiet)?;
    for p in outputs {
        println!("wrote {}", p.display());
    }
    Ok(())
}

fn cmd_conform(args: &mut Args) -> Result<()> {
    let corpus = args.get("corpus");
    let filter = args.get("filter");
    let smoke = args.has("smoke");
    args.finish()?;
    let outputs =
        skglm::bench::scenario::conform(corpus.as_deref(), filter.as_deref(), smoke)?;
    for p in outputs {
        println!("wrote {}", p.display());
    }
    Ok(())
}

/// Map a [`skglm::coordinator::ClientError`] into the CLI error surface.
fn client_err<T>(r: std::result::Result<T, skglm::coordinator::ClientError>) -> Result<T> {
    r.map_err(|e| anyhow::anyhow!("{e}"))
}

fn cmd_serve(args: &mut Args) -> Result<()> {
    use skglm::coordinator::service::{spawn, ExitReason, ServiceConfig};
    use skglm::coordinator::FaultPlan;
    let listen = args.get_or("listen", "127.0.0.1:7878");
    let workers = args.get_usize("workers", 4)?;
    let max_queue = args.get_usize("queue", 32)?;
    let max_frame =
        args.get_usize("frame-bytes", skglm::coordinator::wire::DEFAULT_MAX_FRAME)?;
    let cache_bytes = args.get_usize("cache-bytes", 0)?;
    let tenant_bytes = args.get_usize("tenant-bytes", 0)?;
    let faults_cli = args.get("faults");
    let demo = args.has("demo");
    let n_lambdas = args.get_usize("lambdas", 8)?;
    args.finish()?;

    let faults = FaultPlan::from_env(faults_cli.as_deref())
        .map_err(|e| anyhow::anyhow!("bad fault plan: {e}"))?;
    if !faults.is_empty() {
        eprintln!("fault injection ACTIVE: {faults:?}");
    }
    let handle = spawn(ServiceConfig {
        addr: listen,
        workers,
        max_queue,
        max_frame,
        cache_bytes: (cache_bytes > 0).then_some(cache_bytes),
        tenant_bytes: (tenant_bytes > 0).then_some(tenant_bytes),
        faults,
    })?;
    println!(
        "fit service listening on {} ({workers} workers, admission queue {max_queue})",
        handle.addr
    );

    let demo_result = if demo {
        let addr = handle.addr.to_string();
        let r = run_serve_demo(&addr, n_lambdas.max(2));
        handle.stop();
        r
    } else {
        println!("stop with: skglm client shutdown --addr {}", handle.addr);
        Ok(())
    };
    let exit = handle.join();
    demo_result?;
    match exit {
        ExitReason::Stopped => Ok(()),
        ExitReason::SchedulerDown => {
            bail!("service exited: worker pool died (scheduler down)")
        }
    }
}

/// `serve --demo`: drive a geometric λ sweep of single lasso fits plus
/// one streamed path job through the wire against the freshly spawned
/// service — the same spacing the path solver uses, not an arithmetic
/// grid, and exercising the real client/submit/stream round trip.
fn run_serve_demo(addr: &str, n_lambdas: usize) -> Result<()> {
    use skglm::coordinator::{ClientConfig, ServiceClient};
    use skglm::util::json::Json;
    use std::time::Duration;

    let mut c = client_err(ServiceClient::connect(ClientConfig {
        addr: addr.to_string(),
        tenant: "demo".to_string(),
        session: "serve-demo".to_string(),
        ..ClientConfig::default()
    }))?;
    let dataset = || Json::obj().with("kind", "fig1").with("scale", 0.05).with("seed", 42.0);
    let ratios = skglm::estimators::path::geometric_grid(1e-2, n_lambdas);
    let mut remaining = 0usize;
    for &r in &ratios {
        client_err(c.submit_retrying(&[
            ("kind", Json::Str("fit".to_string())),
            ("model", Json::Str("lasso".to_string())),
            ("lambda_ratio", Json::Num(r)),
            ("dataset", dataset()),
        ]))?;
        remaining += 1;
    }
    client_err(c.submit_retrying(&[
        ("kind", Json::Str("path".to_string())),
        ("model", Json::Str("lasso".to_string())),
        ("grid", Json::obj().with("min_ratio", 1e-2).with("count", n_lambdas as f64)),
        ("dataset", dataset()),
    ]))?;
    remaining += 1;
    println!("submitted {remaining} jobs over the wire; streaming events");
    println!(
        "{:<12} {:<4} {:<12} {:<8} {:<7} outcome",
        "event", "job", "lambda_ratio", "support", "epochs"
    );
    while remaining > 0 {
        let ev = client_err(c.next_event(Duration::from_secs(120)))?;
        let ty = ev.get("type").and_then(Json::as_str).unwrap_or("?").to_string();
        let job = ev.get("job").and_then(Json::as_f64).unwrap_or(-1.0) as i64;
        let ratio = ev.get("lambda_ratio").and_then(Json::as_f64);
        let support = ev.get("support_size").and_then(Json::as_f64);
        let epochs =
            ev.get("epochs").or_else(|| ev.get("total_epochs")).and_then(Json::as_f64);
        let outcome = ev.get("outcome").and_then(Json::as_str).unwrap_or("");
        println!(
            "{:<12} {:<4} {:<12} {:<8} {:<7} {}",
            ty,
            job,
            ratio.map(|v| format!("{v:.4e}")).unwrap_or_else(|| "-".to_string()),
            support.map(|v| (v as usize).to_string()).unwrap_or_else(|| "-".to_string()),
            epochs.map(|v| (v as usize).to_string()).unwrap_or_else(|| "-".to_string()),
            outcome
        );
        match ty.as_str() {
            "fit_done" | "path_done" | "failed" | "cancelled" => remaining -= 1,
            "scheduler_down" => bail!("service workers died mid-demo"),
            _ => {}
        }
    }
    let stats = client_err(c.stats())?;
    println!("service stats: {}", stats.render());
    let _ = c.shutdown_server();
    Ok(())
}

fn cmd_client(args: &mut Args) -> Result<()> {
    use skglm::coordinator::{ClientConfig, ServiceClient};
    use skglm::util::json::Json;
    use std::time::Duration;

    // --script smoke: the scripted loopback acceptance session (the CI
    // gate); self-hosts its own faulted service on an ephemeral port
    if let Some(script) = args.get("script") {
        let transcript = args.get("transcript");
        args.finish()?;
        if script != "smoke" {
            bail!("unknown --script {script:?} (available: smoke)");
        }
        let (report, passed) = skglm::coordinator::smoke::run_smoke();
        let text = report.render();
        match &transcript {
            Some(path) => {
                std::fs::write(path, text.as_bytes())?;
                eprintln!("transcript -> {path}");
            }
            None => println!("{text}"),
        }
        if !passed {
            bail!("serve-smoke acceptance session FAILED (see transcript)");
        }
        println!("serve-smoke acceptance session passed");
        return Ok(());
    }

    let verb = args.positional.get(1).cloned().unwrap_or_else(|| "ping".to_string());
    let cfg = ClientConfig {
        addr: args.get_or("addr", "127.0.0.1:7878"),
        tenant: args.get_or("tenant", "cli"),
        session: args.get_or("session", "cli"),
        io_timeout: Duration::from_secs_f64(args.get_f64("timeout-s", 30.0)?.max(0.1)),
        max_retries: args.get_usize("retries", 6)?,
        retry_seed: args.get_usize("retry-seed", 0)? as u64,
        ..ClientConfig::default()
    };

    match verb.as_str() {
        "ping" | "stats" | "shutdown" => {
            args.finish()?;
            let mut c = client_err(ServiceClient::connect(cfg))?;
            let reply = client_err(match verb.as_str() {
                "ping" => c.ping(),
                "stats" => c.stats(),
                _ => c.shutdown_server(),
            })?;
            println!("{}", reply.render());
        }
        "status" | "cancel" => {
            let job = args
                .get("job")
                .ok_or_else(|| anyhow::anyhow!("{verb} needs --job <id>"))?
                .parse::<u64>()
                .map_err(|_| anyhow::anyhow!("--job expects an integer job id"))?;
            args.finish()?;
            let mut c = client_err(ServiceClient::connect(cfg))?;
            let reply =
                client_err(if verb == "status" { c.status(job) } else { c.cancel(job) })?;
            println!("{}", reply.render());
        }
        "submit" => {
            let kind = args.get_or("kind", "fit");
            let model = args.get_or("model", "lasso");
            let ratio = args.get_f64("lambda-ratio", 0.1)?;
            let points = args.get_usize("points", 16)?;
            let min_ratio = args.get_f64("min-ratio", 0.01)?;
            let deadline_ms = args.get_usize("deadline-ms", 0)?;
            let priority = args.get("priority");
            let ds_kind = args.get_or("dataset", "fig1");
            let scale = args.get_f64("scale", 0.02)?;
            let n = args.get_usize("n", 200)?;
            let p = args.get_usize("p", 400)?;
            let data_seed = args.get_usize("data-seed", 42)?;
            let watch = args.has("watch");
            args.finish()?;

            let dataset = if ds_kind == "fig1" {
                Json::obj()
                    .with("kind", "fig1")
                    .with("scale", scale)
                    .with("seed", data_seed as f64)
            } else {
                Json::obj()
                    .with("kind", ds_kind.as_str())
                    .with("n", n as f64)
                    .with("p", p as f64)
                    .with("seed", data_seed as f64)
            };
            let mut body: Vec<(&str, Json)> = vec![
                ("kind", Json::Str(kind.clone())),
                ("model", Json::Str(model)),
                ("dataset", dataset),
            ];
            if kind == "path" {
                body.push((
                    "grid",
                    Json::obj().with("min_ratio", min_ratio).with("count", points as f64),
                ));
            } else {
                body.push(("lambda_ratio", Json::Num(ratio)));
            }
            if deadline_ms > 0 {
                body.push(("deadline_ms", Json::Num(deadline_ms as f64)));
            }
            if let Some(pr) = &priority {
                body.push(("priority", Json::Str(pr.clone())));
            }
            // --precision (resolved into SKGLM_PRECISION by the global
            // dispatch above) rides the wire so the *service* solves at
            // the requested precision; f64 is the wire default
            let precision = skglm::linalg::simd::default_precision();
            if precision != skglm::linalg::simd::Precision::F64 {
                body.push(("precision", Json::Str(precision.as_str().to_string())));
            }
            let io_timeout = cfg.io_timeout;
            let mut c = client_err(ServiceClient::connect(cfg))?;
            let accepted = client_err(c.submit_retrying(&body))?;
            println!("{}", accepted.render());
            if watch {
                let job = accepted.get("job").and_then(Json::as_f64).unwrap_or(0.0) as u64;
                let (pts, terminal) = client_err(c.wait_terminal(job, io_timeout))?;
                for pt in &pts {
                    println!("{}", pt.render());
                }
                println!("{}", terminal.render());
            }
        }
        other => bail!(
            "unknown client verb {other:?} (ping|stats|status|cancel|submit|shutdown, or --script smoke)"
        ),
    }
    Ok(())
}

fn cmd_cv(args: &mut Args) -> Result<()> {
    let folds = args.get_usize("folds", 5)?;
    let points = args.get_usize("points", 15)?;
    let workers = args.get_usize("workers", 4)?;
    let min_ratio = args.get_f64("min-ratio", 1e-3)?;
    let seed = args.get_usize("seed", 42)? as u64;
    let ds = load_dataset(args)?;
    args.finish()?;
    let ratios = skglm::estimators::path::geometric_grid(min_ratio, points);
    let t0 = std::time::Instant::now();
    let cv = skglm::estimators::lasso_cv(
        &ds,
        &ratios,
        folds,
        &skglm::solver::SolverOpts::default().with_tol(1e-8),
        seed,
        workers,
    );
    println!("{folds}-fold CV over {points} lambdas on {} ({:.2}s):", ds.name, t0.elapsed().as_secs_f64());
    println!("lambda_ratio   cv_mse");
    for (r, m) in cv.lambda_ratios.iter().zip(cv.cv_mse.iter()) {
        let mark = if (r - cv.lambda_ratios[cv.best_index]).abs() < 1e-15 { "  <-- best" } else { "" };
        println!("{r:<12.4e}  {m:.6e}{mark}");
    }
    println!(
        "best lambda {:.4e}; refit support size {}",
        cv.best_lambda,
        cv.beta.iter().filter(|&&b| b != 0.0).count()
    );
    Ok(())
}

fn cmd_synth(args: &mut Args) -> Result<()> {
    let out = args
        .get("out")
        .ok_or_else(|| anyhow::anyhow!("synth needs --out <file.svm>"))?;
    let ds = load_dataset(args)?;
    args.finish()?;
    let x = match &ds.design {
        skglm::linalg::Design::Sparse(s) => s.clone(),
        skglm::linalg::Design::Dense(m) => {
            // densify via triplets (fig1-style synthetic exports)
            let mut trips = Vec::new();
            for j in 0..m.ncols() {
                for (i, &v) in m.col(j).iter().enumerate() {
                    if v != 0.0 {
                        trips.push((i, j, v));
                    }
                }
            }
            skglm::linalg::CscMatrix::from_triplets(m.nrows(), m.ncols(), &trips)
        }
    };
    let data = skglm::data::libsvm::LibsvmData { x, y: ds.y.clone() };
    let mut f = std::io::BufWriter::new(std::fs::File::create(&out)?);
    skglm::data::libsvm::write_libsvm(&data, &mut f)?;
    use std::io::Write;
    f.flush()?;
    println!("wrote {} (n={}, p={}) in libsvm format", out, ds.n(), ds.p());
    Ok(())
}

fn cmd_info(args: &mut Args) -> Result<()> {
    args.finish()?;
    println!("skglm-rs — NeurIPS 2022 'Beyond L1' reproduction\n");
    println!("{}", skglm::bench::capability::capability_table().text());
    match skglm::runtime::PjrtRuntime::cpu() {
        Ok(rt) => println!("PJRT runtime: ok (platform {})", rt.platform()),
        Err(e) => println!("PJRT runtime: unavailable ({e})"),
    }
    let artifacts = skglm::runtime::client::artifacts_dir();
    let count = std::fs::read_dir(&artifacts)
        .map(|d| d.filter_map(|e| e.ok()).filter(|e| e.path().extension().map(|x| x == "txt").unwrap_or(false)).count())
        .unwrap_or(0);
    println!("artifacts dir : {} ({count} HLO artifacts)", artifacts.display());
    Ok(())
}
