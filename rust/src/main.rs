//! `skglm` — CLI launcher for the skglm-rs framework.
//!
//! ```text
//! skglm solve   --dataset rcv1 --penalty l1 --lambda-ratio 0.01 [--engine pjrt]
//! skglm path    --penalty mcp --points 20   # warm-started sweep via the scheduler
//! skglm exp     <fig1..fig10|table1|table2|pathsched|all> [--full]
//! skglm conform [--smoke] [--filter l1]  # scenario conformance corpus
//! skglm serve   --workers 4         # demo of the path-aware fit scheduler
//! skglm info                        # capability table + runtime probe
//! ```

use anyhow::{bail, Result};
use skglm::bench::figures::{run_experiment, Scale, ALL_EXPERIMENTS};
use skglm::cli::Args;
use skglm::data::{correlated, paper_dataset, paper_dataset_small, CorrelatedSpec, Dataset};
use skglm::datafit::Quadratic;
use skglm::estimators::linear::quadratic_lambda_max;
use skglm::penalty::{L1L2, Lq, Mcp, Scad, L1};
use skglm::solver::{solve, FitResult, SolverOpts};

fn main() {
    let mut args = Args::from_env();
    let code = match dispatch(&mut args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            2
        }
    };
    std::process::exit(code);
}

fn dispatch(args: &mut Args) -> Result<()> {
    // global thread budget: --threads > SKGLM_THREADS > hardware; shared
    // by the kernel engine and every worker pool (see ARCHITECTURE.md
    // §Kernel engine)
    if let Some(t) = args.take_threads()? {
        skglm::linalg::parallel::set_thread_budget(t);
    }
    match args.subcommand() {
        Some("solve") => cmd_solve(args),
        Some("path") => cmd_path(args),
        Some("cv") => cmd_cv(args),
        Some("exp") => cmd_exp(args),
        Some("conform") => cmd_conform(args),
        Some("serve") => cmd_serve(args),
        Some("synth") => cmd_synth(args),
        Some("info") => cmd_info(args),
        Some(other) => bail!("unknown subcommand {other:?}\n{USAGE}"),
        None => {
            println!("{USAGE}");
            Ok(())
        }
    }
}

const USAGE: &str = "usage:
  skglm solve --dataset <name|libsvm-path> \\
              --penalty <l1|enet|mcp|scad|l05|group_lasso|group_mcp|group_scad> \\
              [--datafit quadratic|poisson|probit] --lambda-ratio 0.1 \\
              [--gamma 3.0] [--rho 0.5] [--groups 10] [--tol 1e-8] \\
              [--inner auto|residual|gram] \\
              [--engine native|pjrt] [--no-ws] [--no-accel] [--seed 42] [--small]
  skglm path  --penalty <l1|mcp|scad|l05|group_lasso|group_mcp|group_scad> \\
              [--datafit quadratic|poisson|probit] [--groups 10] \\
              [--inner auto|residual|gram] \\
              [--points 20] [--min-ratio 1e-3] [--gamma 3.0] [--small] [--seed 42]
  skglm cv    --dataset <name> [--folds 5] [--points 15] [--workers 4] [--small]
  skglm exp   <fig1..fig10|table1|table2|pathsched|kernels|glms|groups|gram|scenarios|summary|all> [--full]
  skglm conform [--smoke] [--filter <substr>] [--corpus <scenarios.jsonl>]
  skglm serve [--workers 4] [--lambdas 8]
  skglm synth --dataset <rcv1|news20|...|fig1> --out <file.svm> [--small]
  skglm info

  --datafit poisson|probit routes the fit through the prox-Newton outer
  solver (curvature-adaptive GLMs; penalty must be l1). the group_*
  penalties run on the block-coordinate engine over contiguous feature
  groups of --groups <size> features each. --inner picks the inner engine
  for quadratic fits: residual CD, Gram-domain CD (O(|ws|) updates on
  cached working-set Grams), or cost-model auto dispatch (the default;
  non-quadratic datafits always run residual). every subcommand accepts
  --threads N (kernel + worker thread budget; overrides the SKGLM_THREADS
  env var; defaults to hardware parallelism). `exp summary` rolls every
  repo-root BENCH_*.json into BENCH_SUMMARY.json. `conform` runs the
  declarative scenario conformance corpus (scenarios.jsonl at the repo
  root when present, else the built-in corpus) — every datafit × penalty
  through the real scheduler, cross-engine / thread-count / warm-vs-cold
  oracles per scenario — and exits non-zero when any scenario fails;
  --smoke runs the CI gate subset, --filter selects scenarios whose
  id/datafit/penalty contains the substring";

/// Load `name` as a libsvm file when it names one on disk.
fn try_load_libsvm(name: &str) -> Option<Result<Dataset>> {
    if !std::path::Path::new(name).exists() {
        return None;
    }
    Some(skglm::data::libsvm::parse_file(name).map(|parsed| Dataset {
        name: name.to_string(),
        design: parsed.x.into(),
        y: parsed.y,
        beta_true: Vec::new(),
    }))
}

fn load_dataset(args: &mut Args) -> Result<Dataset> {
    let name = args.get_or("dataset", "rcv1");
    let seed = args.get_usize("seed", 42)? as u64;
    let small = args.has("small");
    if let Some(parsed) = try_load_libsvm(&name) {
        return parsed;
    }
    if name == "fig1" {
        return Ok(correlated(CorrelatedSpec::figure1(if small { 0.1 } else { 1.0 }), seed));
    }
    let ds = if small { paper_dataset_small(&name, seed) } else { paper_dataset(&name, seed) };
    ds.ok_or_else(|| anyhow::anyhow!("unknown dataset {name:?} (and not a file)"))
}

fn print_fit(res: &FitResult, n: usize) {
    println!("converged      : {}", res.converged);
    println!("objective      : {:.10e}", res.objective);
    println!("kkt violation  : {:.3e}", res.kkt);
    println!("support size   : {}", res.support().len());
    println!("outer iters    : {}", res.n_outer);
    println!("cd epochs      : {}", res.n_epochs);
    println!("extrapolations : {} accepted / {} rejected", res.accepted_extrapolations, res.rejected_extrapolations);
    let pr = &res.profile;
    if pr.gram_epochs > 0 || pr.residual_epochs > 0 {
        println!(
            "inner engines  : {} gram / {} residual epochs ({:.2} Mflop epochs, {:.2} Mflop gram assembly)",
            pr.gram_epochs,
            pr.residual_epochs,
            pr.epoch_flops / 1e6,
            pr.gram_assembly_flops / 1e6
        );
    }
    if let Some(h) = res.history.last() {
        println!("solve time     : {:.3}s  (n={n})", h.t);
    }
}

/// Build the GLM workload for `--datafit poisson|probit`: a libsvm file
/// when one is named (targets validated here, not by library asserts),
/// else the correlated synthetic generator with model-consistent targets
/// (dataset name `synthetic`, the default).
fn load_glm_dataset(args: &mut Args, datafit: &str) -> Result<Dataset> {
    let name = args.get_or("dataset", "synthetic");
    let seed = args.get_usize("seed", 42)? as u64;
    let small = args.has("small");
    if let Some(parsed) = try_load_libsvm(&name) {
        let ds = parsed?;
        match datafit {
            "poisson" => {
                if let Some(bad) = ds.y.iter().find(|&&v| v < 0.0 || v.fract() != 0.0) {
                    bail!(
                        "{name}: poisson targets must be nonnegative counts, found {bad}"
                    );
                }
            }
            _ => {
                if let Some(bad) = ds.y.iter().find(|&&v| v != 1.0 && v != -1.0) {
                    bail!("{name}: probit labels must be ±1, found {bad}");
                }
            }
        }
        return Ok(ds);
    }
    if name != "synthetic" {
        bail!("unknown dataset {name:?} (not a file; --datafit {datafit} takes a libsvm path or the default synthetic workload)");
    }
    let spec = CorrelatedSpec::figure1(if small { 0.1 } else { 0.5 });
    Ok(match datafit {
        "poisson" => skglm::data::poisson_correlated(spec, seed),
        _ => skglm::data::probit_correlated(spec, seed),
    })
}

/// λ_max + prox-Newton solve for one GLM datafit type.
fn run_glm_fit<D: skglm::datafit::Datafit + Default>(
    ds: &Dataset,
    ratio: f64,
    opts: &SolverOpts,
) -> (f64, FitResult) {
    let mut f = D::default();
    let lam_max = skglm::solver::glm_lambda_max(&f, &ds.design, &ds.y);
    let r = skglm::solver::solve_prox_newton(
        &ds.design,
        &ds.y,
        &mut f,
        &L1::new(lam_max * ratio),
        opts,
        None,
    );
    (lam_max, r)
}

/// One prox-Newton fit (`solve --datafit poisson|probit`).
fn cmd_solve_glm(args: &mut Args, datafit: &str) -> Result<()> {
    if !matches!(datafit, "poisson" | "probit") {
        bail!("unknown datafit {datafit:?} (quadratic|poisson|probit)");
    }
    let penalty = args.get_or("penalty", "l1");
    if penalty != "l1" {
        bail!("--datafit {datafit} supports --penalty l1 only (got {penalty:?})");
    }
    let ratio = args.get_f64("lambda-ratio", 0.1)?;
    let tol = args.get_f64("tol", 1e-8)?;
    let mut opts = SolverOpts::default().with_tol(tol);
    if args.has("no-ws") {
        opts.use_ws = false;
    }
    if args.has("no-accel") {
        opts.anderson_m = 0;
    }
    opts.verbose = args.has("verbose");
    let ds = load_glm_dataset(args, datafit)?;
    args.finish()?;

    let (lam_max, res) = match datafit {
        "poisson" => run_glm_fit::<skglm::datafit::Poisson>(&ds, ratio, &opts),
        _ => run_glm_fit::<skglm::datafit::Probit>(&ds, ratio, &opts),
    };
    println!(
        "dataset {} (n={}, p={}), datafit {datafit}, lambda = {:.3e} (ratio {ratio})",
        ds.name,
        ds.n(),
        ds.p(),
        lam_max * ratio
    );
    println!("solver         : prox-newton (outer Newton x inner CD)");
    print_fit(&res, ds.n());
    Ok(())
}

/// One block-engine fit (`solve --penalty group_lasso|group_mcp|group_scad`).
fn cmd_solve_group(args: &mut Args, penalty: &str) -> Result<()> {
    use skglm::penalty::{GroupMcp, GroupScad};
    use skglm::solver::BlockPartition;
    use std::sync::Arc;
    let ratio = args.get_f64("lambda-ratio", 0.1)?;
    let gamma = args.get_f64("gamma", if penalty == "group_scad" { 3.7 } else { 3.0 })?;
    let group_size = args.get_usize("groups", 10)?;
    let tol = args.get_f64("tol", 1e-8)?;
    let mut opts = SolverOpts::default().with_tol(tol);
    if args.has("no-ws") {
        opts.use_ws = false;
    }
    if args.has("no-accel") {
        opts.anderson_m = 0;
    }
    opts.verbose = args.has("verbose");
    let mut ds = load_dataset(args)?;
    args.finish()?;
    if group_size == 0 || group_size > ds.p() {
        bail!("--groups must be in 1..={} (got {group_size})", ds.p());
    }
    // non-convex group penalties follow the paper's √n column
    // normalization (keeps every block step inside the MCP/SCAD
    // semi-convex regime on heterogeneous designs)
    if penalty != "group_lasso" {
        ds.design.normalize_cols((ds.n() as f64).sqrt());
    }

    let part = Arc::new(BlockPartition::contiguous_equal(ds.p(), group_size));
    let lam_max = skglm::estimators::group_lambda_max(&ds.design, &ds.y, &part, None);
    let lam = lam_max * ratio;
    println!(
        "dataset {} (n={}, p={}, {} groups of <= {group_size}), penalty {penalty}, lambda = {:.3e} (ratio {ratio})",
        ds.name,
        ds.n(),
        ds.p(),
        part.n_blocks(),
        lam
    );
    println!("solver         : block-coordinate engine (shared outer loop)");
    let fit = match penalty {
        // the convex constructor enables gap-safe block screening, so the
        // "screened blocks" line below reports the real certificate count
        "group_lasso" => skglm::estimators::group::group_lasso(lam, Arc::clone(&part))
            .with_opts(opts)
            .fit(&ds.design, &ds.y),
        "group_mcp" => skglm::estimators::group::GroupEstimator::from_parts(
            GroupMcp::new(lam, gamma),
            Arc::clone(&part),
            opts,
        )
        .fit(&ds.design, &ds.y),
        "group_scad" => skglm::estimators::group::GroupEstimator::from_parts(
            GroupScad::new(lam, gamma),
            Arc::clone(&part),
            opts,
        )
        .fit(&ds.design, &ds.y),
        other => bail!("unknown group penalty {other:?}"),
    };
    let r = &fit.result;
    println!("converged      : {}", r.converged);
    println!("objective      : {:.10e}", r.objective);
    println!("kkt violation  : {:.3e}", r.kkt);
    println!("group support  : {} / {}", fit.group_support().len(), part.n_blocks());
    println!("outer iters    : {}", r.n_outer);
    println!("cd epochs      : {}", r.n_epochs);
    println!("screened blocks: {}", r.n_screened);
    if let Some(h) = r.history.last() {
        println!("solve time     : {:.3}s  (n={})", h.t, ds.n());
    }
    Ok(())
}

/// Parse the `--inner auto|residual|gram` knob (the CLI's quadratic
/// fits route adaptively by default; the engine is inert for datafits
/// without the Gram contract).
fn take_inner(args: &mut Args) -> Result<skglm::solver::InnerEngine> {
    args.get_or("inner", "auto")
        .parse::<skglm::solver::InnerEngine>()
        .map_err(|e| anyhow::anyhow!(e))
}

fn cmd_solve(args: &mut Args) -> Result<()> {
    let inner = take_inner(args)?;
    let datafit = args.get_or("datafit", "quadratic");
    if datafit != "quadratic" {
        return cmd_solve_glm(args, &datafit);
    }
    let pen_name = args.get_or("penalty", "l1");
    if pen_name.starts_with("group_") {
        return cmd_solve_group(args, &pen_name);
    }
    let ds = load_dataset(args)?;
    let penalty = args.get_or("penalty", "l1");
    let ratio = args.get_f64("lambda-ratio", 0.1)?;
    let gamma = args.get_f64("gamma", 3.0)?;
    let rho = args.get_f64("rho", 0.5)?;
    let tol = args.get_f64("tol", 1e-8)?;
    let engine = args.get_or("engine", "native");
    let mut opts = SolverOpts::default().with_tol(tol).with_inner(inner);
    if args.has("no-ws") {
        opts.use_ws = false;
    }
    if args.has("no-accel") {
        opts.anderson_m = 0;
    }
    opts.verbose = args.has("verbose");
    args.finish()?;

    // MCP/SCAD: paper convention, normalise columns to √n
    let needs_norm = matches!(penalty.as_str(), "mcp" | "scad" | "l05");
    let mut design = ds.design.clone();
    if needs_norm {
        design.normalize_cols((ds.n() as f64).sqrt());
    }
    let lam_max = quadratic_lambda_max(&design, &ds.y);
    let lam = lam_max * ratio;
    println!(
        "dataset {} (n={}, p={}), penalty {penalty}, lambda = {:.3e} (ratio {ratio})",
        ds.name,
        ds.n(),
        ds.p(),
        lam
    );

    let mut datafit = Quadratic::new();
    let mut pjrt_engine = None;
    if engine == "pjrt" {
        let rt = skglm::runtime::PjrtRuntime::cpu()?;
        match skglm::runtime::PjrtGradEngine::for_design(&rt, &design) {
            Ok(e) => {
                println!("scoring engine : pjrt ({})", rt.platform());
                pjrt_engine = Some(e);
            }
            Err(e) => println!("scoring engine : native (pjrt unavailable: {e})"),
        }
    }
    let engine_ref: Option<&mut dyn skglm::solver::GradEngine> =
        pjrt_engine.as_mut().map(|e| e as &mut dyn skglm::solver::GradEngine);

    let res = match penalty.as_str() {
        "l1" => solve(&design, &ds.y, &mut datafit, &L1::new(lam), &opts, engine_ref, None),
        "enet" => solve(&design, &ds.y, &mut datafit, &L1L2::new(lam, rho), &opts, engine_ref, None),
        "mcp" => solve(&design, &ds.y, &mut datafit, &Mcp::new(lam, gamma), &opts, engine_ref, None),
        "scad" => solve(&design, &ds.y, &mut datafit, &Scad::new(lam, gamma), &opts, engine_ref, None),
        "l05" => solve(&design, &ds.y, &mut datafit, &Lq::half(lam), &opts, engine_ref, None),
        other => bail!("unknown penalty {other:?}"),
    };
    print_fit(&res, ds.n());
    if let Some(e) = &pjrt_engine {
        println!("pjrt grad calls: {}", e.calls);
    }
    Ok(())
}

fn cmd_path(args: &mut Args) -> Result<()> {
    use skglm::coordinator::{specs, FitScheduler, JobEvent};
    use std::sync::Arc;
    let inner = take_inner(args)?;
    let datafit = args.get_or("datafit", "quadratic");
    let penalty = args.get_or("penalty", "l1");
    let points = args.get_usize("points", 20)?;
    let min_ratio = args.get_f64("min-ratio", 1e-3)?;
    let gamma = args.get_f64("gamma", if penalty.ends_with("scad") { 3.7 } else { 3.0 })?;
    let group_size = args.get_usize("groups", 10)?;
    let seed = args.get_usize("seed", 42)? as u64;
    let small = args.has("small");
    args.finish()?;

    // λ is a placeholder everywhere below: the path job anchors the grid
    // at its own λ_max
    let (ds, spec) = match datafit.as_str() {
        "quadratic" if penalty.starts_with("group_") => {
            // group-sparse synthetic workload + block-engine path specs
            let scale = if small { 0.1 } else { 1.0 };
            let p = ((2000.0 * scale) as usize).max(8);
            let n = ((1000.0 * scale) as usize).max(8);
            let gs = group_size.clamp(1, p);
            let (gds, part) = skglm::data::grouped_correlated(
                skglm::data::GroupedSpec {
                    n,
                    p,
                    group_size: gs,
                    active_groups: (p / gs / 10).max(1),
                    rho: 0.6,
                    snr: 5.0,
                },
                seed,
            );
            let spec = match penalty.as_str() {
                "group_lasso" => specs::group_lasso(1.0, part),
                "group_mcp" => specs::group_mcp(1.0, gamma, part),
                "group_scad" => specs::group_scad(1.0, gamma, part),
                other => bail!("unknown group penalty {other:?}"),
            };
            (Arc::new(gds), spec)
        }
        "quadratic" => {
            let ds =
                Arc::new(correlated(CorrelatedSpec::figure1(if small { 0.1 } else { 1.0 }), seed));
            let spec = match penalty.as_str() {
                "l1" => specs::lasso(1.0),
                "mcp" => specs::mcp(1.0, gamma),
                "scad" => specs::scad(1.0, gamma),
                "l05" => specs::lq(1.0, 0.5),
                other => bail!("unknown penalty {other:?}"),
            };
            (ds, spec)
        }
        glm @ ("poisson" | "probit") => {
            if penalty != "l1" {
                bail!("--datafit {glm} supports --penalty l1 only (got {penalty:?})");
            }
            let spec_cfg = CorrelatedSpec::figure1(if small { 0.1 } else { 0.5 });
            if glm == "poisson" {
                (
                    Arc::new(skglm::data::poisson_correlated(spec_cfg, seed)),
                    specs::poisson_l1(1.0),
                )
            } else {
                (
                    Arc::new(skglm::data::probit_correlated(spec_cfg, seed)),
                    specs::probit_l1(1.0),
                )
            }
        }
        other => bail!("unknown datafit {other:?} (quadratic|poisson|probit)"),
    };
    let ratios = skglm::estimators::path::geometric_grid(min_ratio, points);
    let mut sched = FitScheduler::start(1);
    let job = sched.submit_path(
        Arc::clone(&ds),
        spec,
        ratios,
        SolverOpts::default().with_tol(1e-7).with_inner(inner),
    );
    println!(
        "datafit {datafit} / penalty {penalty}: streaming {points} warm-started path points (job {job})"
    );
    println!("lambda_ratio  support  est_err    pred_mse   exact  epochs  screened");
    loop {
        match sched.events.recv() {
            Ok(JobEvent::PathPoint(p)) => println!(
                "{:<12.4e}  {:<7}  {:<9.3e}  {:<9.3e}  {:<5}  {:<6}  {}",
                p.point.lambda_ratio,
                p.point.support_size,
                p.point.estimation_error.unwrap_or(f64::NAN),
                p.point.prediction_mse.unwrap_or(f64::NAN),
                p.point.recovery.as_ref().map(|r| r.exact).unwrap_or(false),
                p.epochs,
                p.n_screened
            ),
            Ok(JobEvent::PathDone(s)) => {
                println!(
                    "{}: {} points in {:.2}s ({} CD epochs total)",
                    s.label, s.n_points, s.total_time, s.total_epochs
                );
                break;
            }
            Ok(JobEvent::FitDone(_)) => {}
            Ok(JobEvent::Failed { job_id, message }) => {
                bail!("path job {job_id} failed on its worker: {message}")
            }
            Err(_) => bail!("scheduler died"),
        }
    }
    sched.shutdown();
    Ok(())
}

fn cmd_exp(args: &mut Args) -> Result<()> {
    let name = args
        .positional
        .get(1)
        .cloned()
        .ok_or_else(|| anyhow::anyhow!("exp needs a name: {ALL_EXPERIMENTS:?} or all"))?;
    let scale = if args.has("full") { Scale::Full } else { Scale::Smoke };
    args.finish()?;
    let outputs = run_experiment(&name, scale)?;
    for p in outputs {
        println!("wrote {}", p.display());
    }
    Ok(())
}

fn cmd_conform(args: &mut Args) -> Result<()> {
    let corpus = args.get("corpus");
    let filter = args.get("filter");
    let smoke = args.has("smoke");
    args.finish()?;
    let outputs =
        skglm::bench::scenario::conform(corpus.as_deref(), filter.as_deref(), smoke)?;
    for p in outputs {
        println!("wrote {}", p.display());
    }
    Ok(())
}

fn cmd_serve(args: &mut Args) -> Result<()> {
    use skglm::coordinator::{specs, FitScheduler, JobEvent};
    use std::sync::Arc;
    let workers = args.get_usize("workers", 4)?;
    let n_lambdas = args.get_usize("lambdas", 8)?;
    args.finish()?;

    let ds = Arc::new(correlated(CorrelatedSpec::figure1(0.2), 42));
    let lam_max = quadratic_lambda_max(&ds.design, &ds.y);
    let mut sched = FitScheduler::start(workers);
    println!("fit scheduler up with {workers} workers; mixed single-fit + path workload");

    // single fits across the model zoo (trait-based specs, shared Arc dataset)
    let mut jobs = 0usize;
    for k in 0..n_lambdas {
        let lam = lam_max / (10.0 * (k + 1) as f64);
        sched.submit_fit(Arc::clone(&ds), specs::lasso(lam), SolverOpts::default());
        jobs += 1;
    }
    sched.submit_fit(Arc::clone(&ds), specs::elastic_net(lam_max / 20.0, 0.5), SolverOpts::default());
    sched.submit_fit(Arc::clone(&ds), specs::mcp(lam_max / 20.0, 3.0), SolverOpts::default());
    jobs += 2;
    // prox-Newton GLM jobs share the queue with the CD jobs
    let pois = Arc::new(skglm::data::poisson_correlated(CorrelatedSpec::figure1(0.2), 42));
    let pois_lmax = specs::poisson_l1(1.0).lambda_max(&pois.design, &pois.y);
    sched.submit_fit(Arc::clone(&pois), specs::poisson_l1(pois_lmax / 10.0), SolverOpts::default());
    let prob = Arc::new(skglm::data::probit_correlated(CorrelatedSpec::figure1(0.2), 42));
    let prob_lmax = specs::probit_l1(1.0).lambda_max(&prob.design, &prob.y);
    sched.submit_fit(Arc::clone(&prob), specs::probit_l1(prob_lmax / 10.0), SolverOpts::default());
    jobs += 2;
    // one warm-started path sweep, streamed per-λ
    let path_points = 8;
    let ratios = skglm::estimators::path::geometric_grid(1e-2, path_points);
    sched.submit_path(Arc::clone(&ds), specs::lasso(1.0), ratios, SolverOpts::default().with_tol(1e-7));
    jobs += 1;

    println!("{:<24} {:<4} {:<8} {:<7} wall_s", "event", "job", "support", "epochs");
    // count TERMINAL events (FitDone / PathDone / Failed) rather than a
    // fixed total: a path job that fails mid-sweep emits fewer points
    // than planned, and a fixed count would hang on recv forever
    let mut remaining = jobs;
    while remaining > 0 {
        match sched.events.recv() {
            Ok(JobEvent::FitDone(o)) => {
                let tag = format!("fit {}", o.label);
                let warm = if o.warm_started { "  (warm)" } else { "" };
                println!(
                    "{:<24} {:<4} {:<8} {:<7} {:.3}{}",
                    tag,
                    o.job_id,
                    o.result.support().len(),
                    o.result.n_epochs,
                    o.wall_time,
                    warm
                );
                remaining -= 1;
            }
            Ok(JobEvent::PathPoint(p)) => {
                let tag = format!("path point #{}", p.index);
                println!(
                    "{:<24} {:<4} {:<8} {:<7} {:.3}",
                    tag, p.job_id, p.point.support_size, p.epochs, p.wall_time
                );
            }
            Ok(JobEvent::PathDone(s)) => {
                let tag = format!("path done ({} pts)", s.n_points);
                println!(
                    "{:<24} {:<4} {:<8} {:<7} {:.3}",
                    tag, s.job_id, "-", s.total_epochs, s.total_time
                );
                remaining -= 1;
            }
            Ok(JobEvent::Failed { job_id, message }) => {
                println!("{:<24} {:<4} {message}", "job FAILED", job_id);
                remaining -= 1;
            }
            Err(_) => bail!("scheduler died"),
        }
    }
    let stats = sched.cache().stats();
    println!(
        "cache: designs {} hit / {} miss, coefficients {} hit / {} miss",
        stats.design_hits, stats.design_misses, stats.coef_hits, stats.coef_misses
    );
    sched.shutdown();
    Ok(())
}

fn cmd_cv(args: &mut Args) -> Result<()> {
    let folds = args.get_usize("folds", 5)?;
    let points = args.get_usize("points", 15)?;
    let workers = args.get_usize("workers", 4)?;
    let min_ratio = args.get_f64("min-ratio", 1e-3)?;
    let seed = args.get_usize("seed", 42)? as u64;
    let ds = load_dataset(args)?;
    args.finish()?;
    let ratios = skglm::estimators::path::geometric_grid(min_ratio, points);
    let t0 = std::time::Instant::now();
    let cv = skglm::estimators::lasso_cv(
        &ds,
        &ratios,
        folds,
        &skglm::solver::SolverOpts::default().with_tol(1e-8),
        seed,
        workers,
    );
    println!("{folds}-fold CV over {points} lambdas on {} ({:.2}s):", ds.name, t0.elapsed().as_secs_f64());
    println!("lambda_ratio   cv_mse");
    for (r, m) in cv.lambda_ratios.iter().zip(cv.cv_mse.iter()) {
        let mark = if (r - cv.lambda_ratios[cv.best_index]).abs() < 1e-15 { "  <-- best" } else { "" };
        println!("{r:<12.4e}  {m:.6e}{mark}");
    }
    println!(
        "best lambda {:.4e}; refit support size {}",
        cv.best_lambda,
        cv.beta.iter().filter(|&&b| b != 0.0).count()
    );
    Ok(())
}

fn cmd_synth(args: &mut Args) -> Result<()> {
    let out = args
        .get("out")
        .ok_or_else(|| anyhow::anyhow!("synth needs --out <file.svm>"))?;
    let ds = load_dataset(args)?;
    args.finish()?;
    let x = match &ds.design {
        skglm::linalg::Design::Sparse(s) => s.clone(),
        skglm::linalg::Design::Dense(m) => {
            // densify via triplets (fig1-style synthetic exports)
            let mut trips = Vec::new();
            for j in 0..m.ncols() {
                for (i, &v) in m.col(j).iter().enumerate() {
                    if v != 0.0 {
                        trips.push((i, j, v));
                    }
                }
            }
            skglm::linalg::CscMatrix::from_triplets(m.nrows(), m.ncols(), &trips)
        }
    };
    let data = skglm::data::libsvm::LibsvmData { x, y: ds.y.clone() };
    let mut f = std::io::BufWriter::new(std::fs::File::create(&out)?);
    skglm::data::libsvm::write_libsvm(&data, &mut f)?;
    use std::io::Write;
    f.flush()?;
    println!("wrote {} (n={}, p={}) in libsvm format", out, ds.n(), ds.p());
    Ok(())
}

fn cmd_info(args: &mut Args) -> Result<()> {
    args.finish()?;
    println!("skglm-rs — NeurIPS 2022 'Beyond L1' reproduction\n");
    println!("{}", skglm::bench::capability::capability_table().text());
    match skglm::runtime::PjrtRuntime::cpu() {
        Ok(rt) => println!("PJRT runtime: ok (platform {})", rt.platform()),
        Err(e) => println!("PJRT runtime: unavailable ({e})"),
    }
    let artifacts = skglm::runtime::client::artifacts_dir();
    let count = std::fs::read_dir(&artifacts)
        .map(|d| d.filter_map(|e| e.ok()).filter(|e| e.path().extension().map(|x| x == "txt").unwrap_or(false)).count())
        .unwrap_or(0);
    println!("artifacts dir : {} ({count} HLO artifacts)", artifacts.display());
    Ok(())
}
