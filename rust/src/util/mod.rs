//! Dependency-free utilities standing in for crates that are unavailable
//! in this offline environment (`rand`, `proptest`, `serde_json`).

pub mod json;
pub mod order;
pub mod quickcheck;
pub mod rng;
pub mod table;
