//! Dependency-free utilities standing in for crates that are unavailable
//! in this offline environment (`rand`, `proptest`, `serde_json`).

pub mod json;
pub mod order;
pub mod quickcheck;
pub mod rng;
pub mod table;

/// Resolve a byte-budget env var: a positive integer wins, anything else
/// (unset, unparseable, zero) falls back to `default`. Shared by the
/// scheduler cache (`SKGLM_CACHE_BYTES`) and the Gram store
/// (`SKGLM_GRAM_BYTES`).
pub fn env_byte_budget(var: &str, default: usize) -> usize {
    std::env::var(var)
        .ok()
        .and_then(|v| v.parse::<usize>().ok())
        .filter(|&b| b > 0)
        .unwrap_or(default)
}

/// Acquire a mutex, recovering from poisoning instead of panicking.
///
/// The scheduler/cache panic-survival contract (worker panics are
/// caught, the job fails, the process lives) would be defeated if one
/// panicked worker permanently poisoned a shared mutex: every later
/// `lock().unwrap()` would cascade the panic. Each call site using this
/// helper is responsible for keeping the guarded data consistent at
/// every await-free panic point (the repo convention is mutate-last:
/// compute, then push/store under the lock).
pub fn lock_or_recover<T>(m: &std::sync::Mutex<T>) -> std::sync::MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|poisoned| poisoned.into_inner())
}

/// [`std::sync::Condvar::wait`] with the same poison-recovery policy as
/// [`lock_or_recover`].
pub fn wait_or_recover<'a, T>(
    cv: &std::sync::Condvar,
    guard: std::sync::MutexGuard<'a, T>,
) -> std::sync::MutexGuard<'a, T> {
    cv.wait(guard).unwrap_or_else(|poisoned| poisoned.into_inner())
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::{Arc, Condvar, Mutex};

    #[test]
    fn lock_or_recover_survives_poison() {
        let m = Arc::new(Mutex::new(7usize));
        let m2 = Arc::clone(&m);
        let _ = std::thread::spawn(move || {
            let _g = m2.lock().unwrap();
            panic!("poison it");
        })
        .join();
        assert!(m.is_poisoned());
        let g = lock_or_recover(&m);
        assert_eq!(*g, 7);
    }

    #[test]
    fn wait_or_recover_wakes_after_poisoned_notify() {
        let pair = Arc::new((Mutex::new(false), Condvar::new()));
        let pair2 = Arc::clone(&pair);
        let _ = std::thread::spawn(move || {
            let (m, cv) = &*pair2;
            let mut g = m.lock().unwrap();
            *g = true;
            cv.notify_all();
            panic!("poison while holding");
        })
        .join();
        let (m, cv) = &*pair;
        let mut g = lock_or_recover(m);
        while !*g {
            g = wait_or_recover(cv, g);
        }
        assert!(*g);
    }
}
