//! Dependency-free utilities standing in for crates that are unavailable
//! in this offline environment (`rand`, `proptest`, `serde_json`).

pub mod json;
pub mod order;
pub mod quickcheck;
pub mod rng;
pub mod table;

/// Resolve a byte-budget env var: a positive integer wins, anything else
/// (unset, unparseable, zero) falls back to `default`. Shared by the
/// scheduler cache (`SKGLM_CACHE_BYTES`) and the Gram store
/// (`SKGLM_GRAM_BYTES`).
pub fn env_byte_budget(var: &str, default: usize) -> usize {
    std::env::var(var)
        .ok()
        .and_then(|v| v.parse::<usize>().ok())
        .filter(|&b| b > 0)
        .unwrap_or(default)
}
