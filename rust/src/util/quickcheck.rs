//! Minimal property-based testing driver (proptest is not available in
//! this offline environment).
//!
//! `check(seed, cases, gen, prop)` draws `cases` random inputs from `gen`
//! and asserts `prop` on each; on failure it performs a simple greedy
//! shrink (if a shrinker is supplied) and reports the minimal
//! counter-example together with the case seed so the failure replays
//! deterministically.

use super::rng::Rng;

/// Run a property over `cases` random inputs.
///
/// Panics with the failing input's `Debug` representation and its case
/// index, which together with `seed` makes the failure reproducible.
pub fn check<T, G, P>(seed: u64, cases: usize, mut gen: G, mut prop: P)
where
    T: std::fmt::Debug,
    G: FnMut(&mut Rng) -> T,
    P: FnMut(&T) -> Result<(), String>,
{
    for case in 0..cases {
        let mut rng = Rng::seed_from_u64(seed.wrapping_add(case as u64));
        let input = gen(&mut rng);
        if let Err(msg) = prop(&input) {
            panic!(
                "property failed (seed={seed}, case={case}):\n  input: {input:?}\n  {msg}"
            );
        }
    }
}

/// Like [`check`] but with a shrinker: on failure, repeatedly tries the
/// candidates produced by `shrink` and recurses into the first one that
/// still fails, reporting the minimal failing input found.
pub fn check_shrink<T, G, P, S>(
    seed: u64,
    cases: usize,
    mut gen: G,
    mut prop: P,
    mut shrink: S,
) where
    T: std::fmt::Debug + Clone,
    G: FnMut(&mut Rng) -> T,
    P: FnMut(&T) -> Result<(), String>,
    S: FnMut(&T) -> Vec<T>,
{
    for case in 0..cases {
        let mut rng = Rng::seed_from_u64(seed.wrapping_add(case as u64));
        let input = gen(&mut rng);
        if let Err(first_msg) = prop(&input) {
            // Greedy shrink loop.
            let mut best = input.clone();
            let mut best_msg = first_msg;
            let mut improved = true;
            let mut budget = 200usize;
            while improved && budget > 0 {
                improved = false;
                for cand in shrink(&best) {
                    budget = budget.saturating_sub(1);
                    if let Err(msg) = prop(&cand) {
                        best = cand;
                        best_msg = msg;
                        improved = true;
                        break;
                    }
                    if budget == 0 {
                        break;
                    }
                }
            }
            panic!(
                "property failed (seed={seed}, case={case}):\n  minimal input: {best:?}\n  {best_msg}"
            );
        }
    }
}

/// Helper: assert two floats are close (absolute + relative tolerance).
pub fn close(a: f64, b: f64, tol: f64) -> Result<(), String> {
    let scale = 1.0f64.max(a.abs()).max(b.abs());
    if (a - b).abs() <= tol * scale {
        Ok(())
    } else {
        Err(format!("|{a} - {b}| = {} > {tol} * {scale}", (a - b).abs()))
    }
}

/// Helper: assert a predicate with a formatted message on failure.
pub fn ensure(cond: bool, msg: impl Into<String>) -> Result<(), String> {
    if cond {
        Ok(())
    } else {
        Err(msg.into())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check(0, 100, |r| r.uniform(), |&u| ensure((0.0..1.0).contains(&u), "out of range"));
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics() {
        check(0, 100, |r| r.below(10), |&n| ensure(n < 5, format!("{n} >= 5")));
    }

    #[test]
    fn shrinker_minimises() {
        let result = std::panic::catch_unwind(|| {
            check_shrink(
                0,
                50,
                |r| r.below(1000) + 10,
                |&n| ensure(n < 10, format!("{n} >= 10")),
                |&n| if n > 10 { vec![n / 2, n - 1] } else { vec![] },
            );
        });
        let err = *result.unwrap_err().downcast::<String>().unwrap();
        // greedy shrink should land exactly on the boundary value 10
        assert!(err.contains("minimal input: 10"), "{err}");
    }

    #[test]
    fn close_tolerates_scale() {
        assert!(close(1e6, 1e6 + 0.5, 1e-6).is_ok());
        assert!(close(1.0, 1.1, 1e-6).is_err());
    }
}
