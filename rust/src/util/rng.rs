//! Dependency-free pseudo-random number generation.
//!
//! The environment has no `rand` crate, so we implement xoshiro256++
//! (Blackman & Vigna, 2019) with a SplitMix64 seeder. Deterministic across
//! platforms, which is exactly what the reproduction harness needs: every
//! figure is regenerated from a fixed seed.

/// xoshiro256++ generator.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via SplitMix64 so that small / similar seeds give unrelated
    /// streams.
    pub fn seed_from_u64(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        let s = [next(), next(), next(), next()];
        Self { s }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        // 53 high bits -> [0,1) double.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn uniform_range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in [0, n). Uses Lemire's method-lite (modulo is fine
    /// for our non-cryptographic needs, but we reject to kill modulo bias).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0);
        let n64 = n as u64;
        let zone = u64::MAX - (u64::MAX % n64);
        loop {
            let v = self.next_u64();
            if v < zone {
                return (v % n64) as usize;
            }
        }
    }

    /// Standard normal via Box–Muller (polar form avoided to stay
    /// branch-cheap; trig form is fine off the hot path).
    pub fn normal(&mut self) -> f64 {
        let u1 = loop {
            let u = self.uniform();
            if u > 0.0 {
                break u;
            }
        };
        let u2 = self.uniform();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Vector of standard normals.
    pub fn normal_vec(&mut self, len: usize) -> Vec<f64> {
        (0..len).map(|_| self.normal()).collect()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from [0, n) (partial Fisher–Yates).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.below(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }

    /// Bernoulli(p).
    #[inline]
    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.uniform() < p
    }

    /// Poisson(λ) count. Knuth's product-of-uniforms method for small λ;
    /// a clamped normal approximation for λ ≥ 30 (where it is accurate to
    /// well under the sampling noise of any dataset we generate).
    pub fn poisson(&mut self, lambda: f64) -> u64 {
        assert!(lambda >= 0.0 && lambda.is_finite(), "poisson rate must be finite ≥ 0");
        if lambda == 0.0 {
            return 0;
        }
        if lambda < 30.0 {
            let l = (-lambda).exp();
            let mut k = 0u64;
            let mut p = 1.0f64;
            loop {
                p *= self.uniform();
                if p <= l {
                    return k;
                }
                k += 1;
            }
        }
        let v = lambda + lambda.sqrt() * self.normal();
        if v <= 0.0 {
            0
        } else {
            v.round() as u64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::seed_from_u64(42);
        let mut b = Rng::seed_from_u64(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Rng::seed_from_u64(1);
        let mut b = Rng::seed_from_u64(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn uniform_in_unit_interval() {
        let mut r = Rng::seed_from_u64(7);
        for _ in 0..10_000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn uniform_mean_near_half() {
        let mut r = Rng::seed_from_u64(11);
        let m: f64 = (0..100_000).map(|_| r.uniform()).sum::<f64>() / 100_000.0;
        assert!((m - 0.5).abs() < 0.01, "mean {m}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::seed_from_u64(13);
        let xs: Vec<f64> = (0..200_000).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / xs.len() as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn below_covers_range_uniformly() {
        let mut r = Rng::seed_from_u64(17);
        let mut counts = [0usize; 5];
        for _ in 0..50_000 {
            counts[r.below(5)] += 1;
        }
        for &c in &counts {
            assert!((c as f64 - 10_000.0).abs() < 600.0, "{counts:?}");
        }
    }

    #[test]
    fn sample_indices_distinct_and_in_range() {
        let mut r = Rng::seed_from_u64(19);
        let idx = r.sample_indices(100, 30);
        assert_eq!(idx.len(), 30);
        let mut seen = std::collections::HashSet::new();
        for &i in &idx {
            assert!(i < 100);
            assert!(seen.insert(i), "duplicate index {i}");
        }
    }

    #[test]
    fn poisson_moments() {
        let mut r = Rng::seed_from_u64(29);
        for &lam in &[0.5, 3.0, 12.0, 50.0] {
            let n = 50_000;
            let xs: Vec<f64> = (0..n).map(|_| r.poisson(lam) as f64).collect();
            let mean = xs.iter().sum::<f64>() / n as f64;
            let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
            assert!((mean - lam).abs() < 0.05 * lam.max(1.0), "λ={lam}: mean {mean}");
            assert!((var - lam).abs() < 0.1 * lam.max(1.0), "λ={lam}: var {var}");
        }
        assert_eq!(r.poisson(0.0), 0);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::seed_from_u64(23);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
