//! Plain-text table and CSV emitters for the benchmark reports
//! (the paper's tables/figures are regenerated as markdown tables and CSV
//! series under `results/`).

/// A simple column-aligned table builder.
#[derive(Clone, Debug, Default)]
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(header: &[&str]) -> Self {
        Self { header: header.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(cells.len(), self.header.len(), "row width mismatch");
        self.rows.push(cells);
        self
    }

    pub fn n_rows(&self) -> usize {
        self.rows.len()
    }

    /// GitHub-flavoured markdown.
    pub fn markdown(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!("| {} |\n", self.header.join(" | ")));
        out.push_str(&format!(
            "|{}\n",
            self.header.iter().map(|_| "---|").collect::<String>()
        ));
        for r in &self.rows {
            out.push_str(&format!("| {} |\n", r.join(" | ")));
        }
        out
    }

    /// Monospace-aligned text (for terminal output).
    pub fn text(&self) -> String {
        let ncol = self.header.len();
        let mut width = vec![0usize; ncol];
        for (i, h) in self.header.iter().enumerate() {
            width[i] = h.len();
        }
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                width[i] = width[i].max(c.len());
            }
        }
        let fmt_row = |cells: &[String]| -> String {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:<w$}", c, w = width[i]))
                .collect::<Vec<_>>()
                .join("  ")
        };
        let mut out = fmt_row(&self.header);
        out.push('\n');
        out.push_str(&"-".repeat(width.iter().sum::<usize>() + 2 * (ncol - 1)));
        out.push('\n');
        for r in &self.rows {
            out.push_str(&fmt_row(r));
            out.push('\n');
        }
        out
    }

    /// CSV with minimal quoting.
    pub fn csv(&self) -> String {
        let quote = |s: &str| -> String {
            if s.contains(',') || s.contains('"') || s.contains('\n') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.to_string()
            }
        };
        let mut out = self.header.iter().map(|h| quote(h)).collect::<Vec<_>>().join(",");
        out.push('\n');
        for r in &self.rows {
            out.push_str(&r.iter().map(|c| quote(c)).collect::<Vec<_>>().join(","));
            out.push('\n');
        }
        out
    }
}

/// Format a float in short scientific notation for table cells.
pub fn sci(x: f64) -> String {
    if x == 0.0 {
        "0".to_string()
    } else if x.is_nan() {
        "nan".to_string()
    } else {
        format!("{x:.2e}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn markdown_shape() {
        let mut t = Table::new(&["solver", "time"]);
        t.row(vec!["skglm".into(), "0.5".into()]);
        let md = t.markdown();
        assert!(md.starts_with("| solver | time |\n|---|---|\n"));
        assert!(md.contains("| skglm | 0.5 |"));
    }

    #[test]
    fn csv_quotes_commas() {
        let mut t = Table::new(&["a"]);
        t.row(vec!["x,y".into()]);
        assert_eq!(t.csv(), "a\n\"x,y\"\n");
    }

    #[test]
    fn text_aligns_columns() {
        let mut t = Table::new(&["long_header", "b"]);
        t.row(vec!["x".into(), "yy".into()]);
        let txt = t.text();
        let lines: Vec<&str> = txt.lines().collect();
        assert!(lines[0].starts_with("long_header"));
        assert!(lines[2].starts_with("x          "));
    }

    #[test]
    fn sci_formats() {
        assert_eq!(sci(0.0), "0");
        assert_eq!(sci(1234.5), "1.23e3");
    }

    #[test]
    #[should_panic(expected = "row width mismatch")]
    fn ragged_row_panics() {
        let mut t = Table::new(&["a", "b"]);
        t.row(vec!["only-one".into()]);
    }
}
