//! NaN-last total ordering for objective/error comparisons.
//!
//! Path reports and CV selection compare per-λ objectives with
//! `min_by`; a single NaN (a divergent non-convex fit) used to panic the
//! whole report through `partial_cmp(..).unwrap()`. [`nan_last`] orders
//! every NaN *after* every real number, so min-selection silently skips
//! divergent points while still returning one if nothing else exists.

use std::cmp::Ordering;

/// Total order on f64 with all NaNs greater than all non-NaNs (and equal
/// to each other): `min_by(nan_last)` picks the smallest real value.
#[inline]
pub fn nan_last(a: f64, b: f64) -> Ordering {
    match (a.is_nan(), b.is_nan()) {
        (true, true) => Ordering::Equal,
        (true, false) => Ordering::Greater,
        (false, true) => Ordering::Less,
        (false, false) => a.partial_cmp(&b).expect("both non-NaN"),
    }
}

/// [`nan_last`] lifted to `Option<f64>`, with `None` ordered like NaN
/// (last) — the shape `PathPoint`'s optional metrics compare in.
#[inline]
pub fn nan_last_opt(a: Option<f64>, b: Option<f64>) -> Ordering {
    nan_last(a.unwrap_or(f64::NAN), b.unwrap_or(f64::NAN))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reals_order_normally() {
        assert_eq!(nan_last(1.0, 2.0), Ordering::Less);
        assert_eq!(nan_last(2.0, 1.0), Ordering::Greater);
        assert_eq!(nan_last(1.0, 1.0), Ordering::Equal);
        assert_eq!(nan_last(f64::NEG_INFINITY, f64::INFINITY), Ordering::Less);
    }

    #[test]
    fn nans_sort_last() {
        assert_eq!(nan_last(f64::NAN, 1.0), Ordering::Greater);
        assert_eq!(nan_last(1.0, f64::NAN), Ordering::Less);
        assert_eq!(nan_last(f64::NAN, f64::NAN), Ordering::Equal);
        // min_by over a NaN-contaminated slice picks the real minimum
        let xs = [f64::NAN, 3.0, 1.0, f64::NAN, 2.0];
        let m = xs.iter().cloned().min_by(|a, b| nan_last(*a, *b)).unwrap();
        assert_eq!(m, 1.0);
    }

    #[test]
    fn options_order_none_last() {
        assert_eq!(nan_last_opt(Some(1.0), None), Ordering::Less);
        assert_eq!(nan_last_opt(None, Some(1.0)), Ordering::Greater);
        assert_eq!(nan_last_opt(None, None), Ordering::Equal);
    }
}
