//! Tiny JSON writer (serde is unavailable offline). Only what the result
//! emitters need: objects, arrays, strings, numbers, booleans. Numbers are
//! written with enough digits to round-trip f64.

use std::fmt::Write as _;

/// A JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    /// Insertion-ordered object (stable output for diffs/goldens).
    Obj(Vec<(String, Json)>),
    /// Pre-rendered JSON emitted verbatim (no parser offline; the
    /// BENCH_SUMMARY roll-up embeds whole BENCH_*.json files with it).
    /// The caller is responsible for the content being valid JSON.
    Raw(String),
}

impl Json {
    pub fn obj() -> Self {
        Json::Obj(Vec::new())
    }

    /// Insert (or replace) a field; builder-style.
    pub fn with(mut self, key: &str, val: impl Into<Json>) -> Self {
        if let Json::Obj(fields) = &mut self {
            if let Some(f) = fields.iter_mut().find(|(k, _)| k == key) {
                f.1 = val.into();
            } else {
                fields.push((key.to_string(), val.into()));
            }
        } else {
            panic!("with() on non-object");
        }
        self
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(fields) => fields.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn render(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Raw(s) => out.push_str(s),
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.is_finite() {
                    // {:?} on f64 gives shortest round-trip representation
                    let _ = write!(out, "{x:?}");
                } else {
                    out.push_str("null"); // JSON has no Inf/NaN
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\r' => out.push_str("\\r"),
                        '\t' => out.push_str("\\t"),
                        c if (c as u32) < 0x20 => {
                            let _ = write!(out, "\\u{:04x}", c as u32);
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(items) => {
                out.push('[');
                for (i, v) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(fields) => {
                out.push('{');
                for (i, (k, v)) in fields.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

impl From<f64> for Json {
    fn from(x: f64) -> Self {
        Json::Num(x)
    }
}
impl From<usize> for Json {
    fn from(x: usize) -> Self {
        Json::Num(x as f64)
    }
}
impl From<u64> for Json {
    fn from(x: u64) -> Self {
        Json::Num(x as f64)
    }
}
impl From<i64> for Json {
    fn from(x: i64) -> Self {
        Json::Num(x as f64)
    }
}
impl From<bool> for Json {
    fn from(b: bool) -> Self {
        Json::Bool(b)
    }
}
impl From<&str> for Json {
    fn from(s: &str) -> Self {
        Json::Str(s.to_string())
    }
}
impl From<String> for Json {
    fn from(s: String) -> Self {
        Json::Str(s)
    }
}
impl From<Vec<f64>> for Json {
    fn from(v: Vec<f64>) -> Self {
        Json::Arr(v.into_iter().map(Json::Num).collect())
    }
}
impl From<Vec<Json>> for Json {
    fn from(v: Vec<Json>) -> Self {
        Json::Arr(v)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_nested_object() {
        let j = Json::obj()
            .with("name", "lasso")
            .with("gap", 1e-9)
            .with("ok", true)
            .with("curve", vec![1.0, 0.5, 0.25]);
        assert_eq!(
            j.render(),
            r#"{"name":"lasso","gap":1e-9,"ok":true,"curve":[1.0,0.5,0.25]}"#
        );
    }

    #[test]
    fn escapes_strings() {
        let j = Json::Str("a\"b\\c\nd".to_string());
        assert_eq!(j.render(), r#""a\"b\\c\nd""#);
    }

    #[test]
    fn with_replaces_existing_key() {
        let j = Json::obj().with("k", 1.0).with("k", 2.0);
        assert_eq!(j.render(), r#"{"k":2.0}"#);
        assert_eq!(j.get("k"), Some(&Json::Num(2.0)));
    }

    #[test]
    fn non_finite_becomes_null() {
        assert_eq!(Json::Num(f64::INFINITY).render(), "null");
        assert_eq!(Json::Num(f64::NAN).render(), "null");
    }

    #[test]
    fn raw_embeds_verbatim() {
        let j = Json::obj().with("inner", Json::Raw(r#"{"a":[1,2]}"#.to_string()));
        assert_eq!(j.render(), r#"{"inner":{"a":[1,2]}}"#);
    }
}
