//! Tiny JSON writer **and parser** (serde is unavailable offline). The
//! writer covers what the result emitters need: objects, arrays, strings,
//! numbers, booleans — numbers are written with enough digits to
//! round-trip f64. The parser ([`Json::parse`]) is the recursive-descent
//! inverse the scenario-corpus loader uses on `scenarios.jsonl`; the wire
//! layer feeds it untrusted network frames through
//! [`Json::parse_limited`], which bounds input size, string size and
//! nesting depth (a depth bomb would otherwise blow the stack) and
//! reports typed [`JsonError`]s.

use std::fmt::Write as _;

/// Resource limits applied while parsing untrusted input.
#[derive(Clone, Copy, Debug)]
pub struct ParseLimits {
    /// Maximum input length in bytes (checked before parsing starts).
    pub max_bytes: usize,
    /// Maximum array/object nesting depth.
    pub max_depth: usize,
    /// Maximum decoded length of any single string, in bytes.
    pub max_string: usize,
}

impl Default for ParseLimits {
    fn default() -> Self {
        // Generous for trusted local files; the wire layer tightens
        // max_bytes to its frame cap.
        Self { max_bytes: 64 << 20, max_depth: 64, max_string: 4 << 20 }
    }
}

/// Typed parse failure; `Display` renders the legacy string form.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum JsonError {
    /// Input longer than [`ParseLimits::max_bytes`].
    TooLarge { bytes: usize, limit: usize },
    /// Nesting deeper than [`ParseLimits::max_depth`].
    TooDeep { limit: usize, at: usize },
    /// A string longer than [`ParseLimits::max_string`].
    StringTooLong { limit: usize, at: usize },
    /// Any other grammar violation, with the byte offset.
    Syntax { message: String, at: usize },
}

impl std::fmt::Display for JsonError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            JsonError::TooLarge { bytes, limit } => {
                write!(f, "input of {bytes} bytes exceeds limit of {limit}")
            }
            JsonError::TooDeep { limit, at } => {
                write!(f, "nesting deeper than {limit} at byte {at}")
            }
            JsonError::StringTooLong { limit, at } => {
                write!(f, "string longer than {limit} bytes at byte {at}")
            }
            JsonError::Syntax { message, at } => write!(f, "{message} at byte {at}"),
        }
    }
}

/// A JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    /// Insertion-ordered object (stable output for diffs/goldens).
    Obj(Vec<(String, Json)>),
    /// Pre-rendered JSON emitted verbatim (no parser offline; the
    /// BENCH_SUMMARY roll-up embeds whole BENCH_*.json files with it).
    /// The caller is responsible for the content being valid JSON.
    Raw(String),
}

impl Json {
    pub fn obj() -> Self {
        Json::Obj(Vec::new())
    }

    /// Insert (or replace) a field; builder-style.
    pub fn with(mut self, key: &str, val: impl Into<Json>) -> Self {
        if let Json::Obj(fields) = &mut self {
            if let Some(f) = fields.iter_mut().find(|(k, _)| k == key) {
                f.1 = val.into();
            } else {
                fields.push((key.to_string(), val.into()));
            }
        } else {
            panic!("with() on non-object");
        }
        self
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(fields) => fields.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn render(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    /// Parse one JSON document. Strict: the whole input must be consumed
    /// (modulo surrounding whitespace), and errors report the byte
    /// offset. `Raw` is a write-only variant and is never produced.
    /// Default [`ParseLimits`] apply (so even trusted-file callers cannot
    /// blow the stack on deep nesting); errors are stringified for
    /// compatibility — use [`Json::parse_limited`] for typed errors.
    pub fn parse(input: &str) -> Result<Json, String> {
        Json::parse_limited(input, ParseLimits::default()).map_err(|e| e.to_string())
    }

    /// Parse one JSON document from untrusted input under explicit
    /// resource limits, reporting typed [`JsonError`]s.
    pub fn parse_limited(input: &str, limits: ParseLimits) -> Result<Json, JsonError> {
        if input.len() > limits.max_bytes {
            return Err(JsonError::TooLarge { bytes: input.len(), limit: limits.max_bytes });
        }
        let mut p = Parser { bytes: input.as_bytes(), pos: 0, depth: 0, limits };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(JsonError::Syntax {
                message: "trailing characters".to_string(),
                at: p.pos,
            });
        }
        Ok(v)
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    /// Numeric field holding an exact non-negative integer.
    pub fn as_usize(&self) -> Option<usize> {
        match self {
            Json::Num(x) if *x >= 0.0 && x.fract() == 0.0 && *x <= u64::MAX as f64 => {
                Some(*x as usize)
            }
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(items) => Some(items),
            _ => None,
        }
    }

    /// Object fields in insertion order (`None` for non-objects).
    pub fn fields(&self) -> Option<&[(String, Json)]> {
        match self {
            Json::Obj(fields) => Some(fields),
            _ => None,
        }
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Raw(s) => out.push_str(s),
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.is_finite() {
                    // {:?} on f64 gives shortest round-trip representation
                    let _ = write!(out, "{x:?}");
                } else {
                    out.push_str("null"); // JSON has no Inf/NaN
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\r' => out.push_str("\\r"),
                        '\t' => out.push_str("\\t"),
                        c if (c as u32) < 0x20 => {
                            let _ = write!(out, "\\u{:04x}", c as u32);
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(items) => {
                out.push('[');
                for (i, v) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(fields) => {
                out.push('{');
                for (i, (k, v)) in fields.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

impl From<f64> for Json {
    fn from(x: f64) -> Self {
        Json::Num(x)
    }
}
impl From<usize> for Json {
    fn from(x: usize) -> Self {
        Json::Num(x as f64)
    }
}
impl From<u64> for Json {
    fn from(x: u64) -> Self {
        Json::Num(x as f64)
    }
}
impl From<i64> for Json {
    fn from(x: i64) -> Self {
        Json::Num(x as f64)
    }
}
impl From<bool> for Json {
    fn from(b: bool) -> Self {
        Json::Bool(b)
    }
}
impl From<&str> for Json {
    fn from(s: &str) -> Self {
        Json::Str(s.to_string())
    }
}
impl From<String> for Json {
    fn from(s: String) -> Self {
        Json::Str(s)
    }
}
impl From<Vec<f64>> for Json {
    fn from(v: Vec<f64>) -> Self {
        Json::Arr(v.into_iter().map(Json::Num).collect())
    }
}
impl From<Vec<Json>> for Json {
    fn from(v: Vec<Json>) -> Self {
        Json::Arr(v)
    }
}

/// Recursive-descent JSON reader over raw bytes. Numbers parse through
/// Rust's f64 parser (same shortest-round-trip grammar the writer emits);
/// strings handle the standard escapes including `\uXXXX` surrogate
/// pairs.
struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
    depth: usize,
    limits: ParseLimits,
}

impl<'a> Parser<'a> {
    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn err(&self, msg: &str) -> JsonError {
        JsonError::Syntax { message: msg.to_string(), at: self.pos }
    }

    fn enter(&mut self) -> Result<(), JsonError> {
        self.depth += 1;
        if self.depth > self.limits.max_depth {
            return Err(JsonError::TooDeep { limit: self.limits.max_depth, at: self.pos });
        }
        Ok(())
    }

    fn expect_literal(&mut self, lit: &str, val: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(val)
        } else {
            Err(self.err(&format!("expected {lit:?}")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            None => Err(self.err("unexpected end of input")),
            Some(b'n') => self.expect_literal("null", Json::Null),
            Some(b't') => self.expect_literal("true", Json::Bool(true)),
            Some(b'f') => self.expect_literal("false", Json::Bool(false)),
            Some(b'"') => self.string().map(Json::Str),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(c) => Err(self.err(&format!("unexpected character {:?}", c as char))),
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])
            .map_err(|_| self.err("invalid number bytes"))?;
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| JsonError::Syntax { message: format!("invalid number {text:?}"), at: start })
    }

    fn string(&mut self) -> Result<String, JsonError> {
        debug_assert_eq!(self.peek(), Some(b'"'));
        self.pos += 1;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(_) if out.len() > self.limits.max_string => {
                    return Err(JsonError::StringTooLong {
                        limit: self.limits.max_string,
                        at: self.pos,
                    });
                }
                Some(b'\\') => {
                    self.pos += 1;
                    let esc = self.peek().ok_or_else(|| self.err("unterminated escape"))?;
                    self.pos += 1;
                    match esc {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{0008}'),
                        b'f' => out.push('\u{000c}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            let hi = self.hex4()?;
                            let cp = if (0xD800..0xDC00).contains(&hi) {
                                // high surrogate: a \uXXXX low surrogate must follow
                                if self.bytes[self.pos..].starts_with(b"\\u") {
                                    self.pos += 2;
                                    let lo = self.hex4()?;
                                    if !(0xDC00..0xE000).contains(&lo) {
                                        return Err(self.err("invalid low surrogate"));
                                    }
                                    0x10000 + ((hi - 0xD800) << 10) + (lo - 0xDC00)
                                } else {
                                    return Err(self.err("lone high surrogate"));
                                }
                            } else {
                                hi
                            };
                            out.push(
                                char::from_u32(cp)
                                    .ok_or_else(|| self.err("invalid unicode escape"))?,
                            );
                        }
                        other => {
                            return Err(
                                self.err(&format!("invalid escape \\{}", other as char))
                            )
                        }
                    }
                }
                Some(c) if c < 0x20 => return Err(self.err("raw control byte in string")),
                Some(_) => {
                    // copy one UTF-8 scalar (input is a &str, so boundaries are valid)
                    let rest = std::str::from_utf8(&self.bytes[self.pos..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let c = rest.chars().next().unwrap();
                    out.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        if self.pos + 4 > self.bytes.len() {
            return Err(self.err("truncated \\u escape"));
        }
        let text = std::str::from_utf8(&self.bytes[self.pos..self.pos + 4])
            .map_err(|_| self.err("invalid \\u escape"))?;
        let v = u32::from_str_radix(text, 16)
            .map_err(|_| self.err(&format!("invalid \\u escape {text:?}")))?;
        self.pos += 4;
        Ok(v)
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.enter()?;
        self.pos += 1; // '['
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            self.depth -= 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    self.depth -= 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.enter()?;
        self.pos += 1; // '{'
        let mut fields = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            self.depth -= 1;
            return Ok(Json::Obj(fields));
        }
        loop {
            self.skip_ws();
            if self.peek() != Some(b'"') {
                return Err(self.err("expected string key"));
            }
            let key = self.string()?;
            self.skip_ws();
            if self.peek() != Some(b':') {
                return Err(self.err("expected ':'"));
            }
            self.pos += 1;
            self.skip_ws();
            let val = self.value()?;
            fields.push((key, val));
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    self.depth -= 1;
                    return Ok(Json::Obj(fields));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_nested_object() {
        let j = Json::obj()
            .with("name", "lasso")
            .with("gap", 1e-9)
            .with("ok", true)
            .with("curve", vec![1.0, 0.5, 0.25]);
        assert_eq!(
            j.render(),
            r#"{"name":"lasso","gap":1e-9,"ok":true,"curve":[1.0,0.5,0.25]}"#
        );
    }

    #[test]
    fn escapes_strings() {
        let j = Json::Str("a\"b\\c\nd".to_string());
        assert_eq!(j.render(), r#""a\"b\\c\nd""#);
    }

    #[test]
    fn with_replaces_existing_key() {
        let j = Json::obj().with("k", 1.0).with("k", 2.0);
        assert_eq!(j.render(), r#"{"k":2.0}"#);
        assert_eq!(j.get("k"), Some(&Json::Num(2.0)));
    }

    #[test]
    fn non_finite_becomes_null() {
        assert_eq!(Json::Num(f64::INFINITY).render(), "null");
        assert_eq!(Json::Num(f64::NAN).render(), "null");
    }

    #[test]
    fn raw_embeds_verbatim() {
        let j = Json::obj().with("inner", Json::Raw(r#"{"a":[1,2]}"#.to_string()));
        assert_eq!(j.render(), r#"{"inner":{"a":[1,2]}}"#);
    }

    #[test]
    fn parse_round_trips_writer_output() {
        let j = Json::obj()
            .with("name", "lasso")
            .with("gap", 1e-9)
            .with("neg", -2.5)
            .with("ok", true)
            .with("none", Json::Null)
            .with("curve", vec![1.0, 0.5, 0.25])
            .with("nested", Json::obj().with("k", "v\n\"q\""));
        assert_eq!(Json::parse(&j.render()), Ok(j));
    }

    #[test]
    fn parse_handles_whitespace_and_escapes() {
        let j = Json::parse(" { \"a\" : [ 1 , 2.5e-3 , \"x\\u00e9\\t\" ] } ").unwrap();
        let arr = j.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[0].as_f64(), Some(1.0));
        assert_eq!(arr[1].as_f64(), Some(2.5e-3));
        assert_eq!(arr[2].as_str(), Some("é\t"));
    }

    #[test]
    fn parse_surrogate_pair() {
        let j = Json::parse(r#""🦀""#).unwrap();
        assert_eq!(j.as_str(), Some("🦀"));
    }

    #[test]
    fn parse_rejects_malformed_input() {
        for bad in [
            "",
            "{",
            "[1,]",
            "{\"a\":}",
            "{\"a\":1,}",
            "tru",
            "1.2.3",
            "\"unterminated",
            "{\"a\":1} trailing",
            "nan",
        ] {
            assert!(Json::parse(bad).is_err(), "accepted malformed {bad:?}");
        }
    }

    #[test]
    fn depth_bomb_gets_typed_rejection_not_stack_overflow() {
        let limits = ParseLimits { max_depth: 32, ..Default::default() };
        let bomb = "[".repeat(100_000); // would recurse 100k deep unchecked
        match Json::parse_limited(&bomb, limits) {
            Err(JsonError::TooDeep { limit: 32, .. }) => {}
            other => panic!("expected TooDeep, got {other:?}"),
        }
        // mixed array/object nesting counts too
        let mixed = format!("{}1{}", "{\"k\":[".repeat(40), "]}".repeat(40));
        assert!(matches!(
            Json::parse_limited(&mixed, limits),
            Err(JsonError::TooDeep { .. })
        ));
        // default limits also protect Json::parse (stringified error)
        assert!(Json::parse(&bomb).is_err());
    }

    #[test]
    fn depth_within_limit_is_accepted() {
        let limits = ParseLimits { max_depth: 32, ..Default::default() };
        let ok = format!("{}1{}", "[".repeat(32), "]".repeat(32));
        assert!(Json::parse_limited(&ok, limits).is_ok());
        let too_deep = format!("{}1{}", "[".repeat(33), "]".repeat(33));
        assert!(Json::parse_limited(&too_deep, limits).is_err());
    }

    #[test]
    fn oversized_input_and_strings_get_typed_rejection() {
        let limits = ParseLimits { max_bytes: 64, max_string: 16, ..Default::default() };
        let big = format!("[{}]", "1,".repeat(100));
        match Json::parse_limited(&big, limits) {
            Err(JsonError::TooLarge { limit: 64, .. }) => {}
            other => panic!("expected TooLarge, got {other:?}"),
        }
        let long_str = format!("\"{}\"", "x".repeat(40));
        match Json::parse_limited(&long_str, limits) {
            Err(JsonError::StringTooLong { limit: 16, .. }) => {}
            other => panic!("expected StringTooLong, got {other:?}"),
        }
        let short_str = format!("\"{}\"", "x".repeat(10));
        assert!(Json::parse_limited(&short_str, limits).is_ok());
    }

    #[test]
    fn accessors_are_type_checked() {
        assert_eq!(Json::Num(3.0).as_usize(), Some(3));
        assert_eq!(Json::Num(3.5).as_usize(), None);
        assert_eq!(Json::Num(-1.0).as_usize(), None);
        assert_eq!(Json::Bool(true).as_f64(), None);
        assert_eq!(Json::Str("x".into()).as_bool(), None);
        assert!(Json::obj().with("k", 1.0).fields().unwrap().len() == 1);
    }
}
