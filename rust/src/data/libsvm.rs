//! libsvm / svmlight format parser.
//!
//! The paper's datasets (rcv1, news20, finance, kdda, url, real-sim) ship
//! in this format: one sample per line, `label idx:val idx:val ...`, with
//! 1-based feature indices. This environment has no network access so the
//! benchmarks run on synthetic stand-ins (see [`crate::data::synthetic`]),
//! but the parser makes the harness run on the real files whenever they
//! are present (drop them under `data/` and pass `--dataset path`).

use crate::linalg::CscMatrix;
use anyhow::{bail, Context, Result};
use std::io::BufRead;
use std::path::Path;

/// A supervised dataset: design + targets.
#[derive(Clone, Debug)]
pub struct LibsvmData {
    pub x: CscMatrix,
    pub y: Vec<f64>,
}

/// Parse libsvm text from a reader. `min_features` lets the caller force a
/// feature-count (files may not mention trailing all-zero features).
pub fn parse_reader<R: BufRead>(reader: R, min_features: usize) -> Result<LibsvmData> {
    let mut triplets: Vec<(usize, usize, f64)> = Vec::new();
    let mut y: Vec<f64> = Vec::new();
    let mut p = min_features;

    for (lineno, line) in reader.lines().enumerate() {
        let line = line.context("I/O error reading libsvm data")?;
        let line = line.split('#').next().unwrap_or("").trim(); // strip comments
        if line.is_empty() {
            continue;
        }
        let mut parts = line.split_ascii_whitespace();
        let label = parts
            .next()
            .ok_or_else(|| anyhow::anyhow!("line {}: empty", lineno + 1))?;
        let label: f64 = label
            .parse()
            .with_context(|| format!("line {}: bad label {label:?}", lineno + 1))?;
        let row = y.len();
        y.push(label);
        // real exporters (e.g. hash-bucketed featurizers) emit pairs out
        // of order, so collect and sort per row; a *duplicate* index is
        // still a genuine data error (ambiguous value) and is rejected
        let mut pairs: Vec<(usize, f64)> = Vec::new();
        for tok in parts {
            let (idx, val) = tok
                .split_once(':')
                .with_context(|| format!("line {}: bad pair {tok:?}", lineno + 1))?;
            let idx: usize = idx
                .parse()
                .with_context(|| format!("line {}: bad index {idx:?}", lineno + 1))?;
            if idx == 0 {
                bail!("line {}: libsvm indices are 1-based, got 0", lineno + 1);
            }
            let val: f64 = val
                .parse()
                .with_context(|| format!("line {}: bad value {val:?}", lineno + 1))?;
            pairs.push((idx, val));
        }
        pairs.sort_unstable_by_key(|&(idx, _)| idx);
        for w in pairs.windows(2) {
            if w[0].0 == w[1].0 {
                bail!("line {}: duplicate feature index {}", lineno + 1, w[0].0);
            }
        }
        for (idx, val) in pairs {
            p = p.max(idx);
            if val != 0.0 {
                triplets.push((row, idx - 1, val));
            }
        }
    }
    let n = y.len();
    Ok(LibsvmData { x: CscMatrix::from_triplets(n, p, &triplets), y })
}

/// Parse a libsvm file from disk.
pub fn parse_file(path: impl AsRef<Path>) -> Result<LibsvmData> {
    let f = std::fs::File::open(path.as_ref())
        .with_context(|| format!("opening {}", path.as_ref().display()))?;
    parse_reader(std::io::BufReader::new(f), 0)
}

/// Write a dataset in libsvm format (used for round-trip tests and for
/// exporting the synthetic stand-ins for external tools).
pub fn write_libsvm(data: &LibsvmData, out: &mut impl std::io::Write) -> Result<()> {
    let n = data.x.nrows();
    let p = data.x.ncols();
    // CSC is column-major; gather per-row pairs first.
    let mut rows: Vec<Vec<(usize, f64)>> = vec![Vec::new(); n];
    for j in 0..p {
        let (ridx, vals) = data.x.col(j);
        for (&i, &v) in ridx.iter().zip(vals.iter()) {
            rows[i as usize].push((j + 1, v));
        }
    }
    for (i, pairs) in rows.iter().enumerate() {
        write!(out, "{}", data.y[i])?;
        for (j, v) in pairs {
            write!(out, " {j}:{v}")?;
        }
        writeln!(out)?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    #[test]
    fn parses_basic_file() {
        let text = "1 1:0.5 3:2.0\n-1 2:1.5\n";
        let d = parse_reader(Cursor::new(text), 0).unwrap();
        assert_eq!(d.y, vec![1.0, -1.0]);
        assert_eq!(d.x.nrows(), 2);
        assert_eq!(d.x.ncols(), 3);
        assert_eq!(d.x.col_dot(0, &[1.0, 1.0]), 0.5);
        assert_eq!(d.x.col_dot(1, &[1.0, 1.0]), 1.5);
        assert_eq!(d.x.col_dot(2, &[1.0, 1.0]), 2.0);
    }

    #[test]
    fn strips_comments_and_blank_lines() {
        let text = "# header\n1 1:1.0 # trailing\n\n2 2:3.0\n";
        let d = parse_reader(Cursor::new(text), 0).unwrap();
        assert_eq!(d.y, vec![1.0, 2.0]);
    }

    #[test]
    fn respects_min_features() {
        let d = parse_reader(Cursor::new("1 1:1\n"), 10).unwrap();
        assert_eq!(d.x.ncols(), 10);
    }

    #[test]
    fn rejects_zero_index() {
        assert!(parse_reader(Cursor::new("1 0:1\n"), 0).is_err());
    }

    #[test]
    fn accepts_out_of_order_indices() {
        // real exported files carry unsorted rows; values must land on
        // the right columns after the per-row sort
        let d = parse_reader(Cursor::new("1 3:1.5 1:0.5\n-1 2:2.0 1:1.0\n"), 0).unwrap();
        assert_eq!(d.x.ncols(), 3);
        assert_eq!(d.x.col_dot(0, &[1.0, 0.0]), 0.5);
        assert_eq!(d.x.col_dot(2, &[1.0, 0.0]), 1.5);
        assert_eq!(d.x.col_dot(0, &[0.0, 1.0]), 1.0);
        assert_eq!(d.x.col_dot(1, &[0.0, 1.0]), 2.0);
    }

    #[test]
    fn rejects_duplicate_indices() {
        let err = parse_reader(Cursor::new("1 2:1 2:3\n"), 0).unwrap_err();
        assert!(format!("{err}").contains("duplicate feature index 2"), "{err}");
        // duplicates are caught even when they arrive out of order
        assert!(parse_reader(Cursor::new("1 3:1 1:2 3:4\n"), 0).is_err());
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse_reader(Cursor::new("abc 1:1\n"), 0).is_err());
        assert!(parse_reader(Cursor::new("1 1:abc\n"), 0).is_err());
        assert!(parse_reader(Cursor::new("1 nocolon\n"), 0).is_err());
    }

    #[test]
    fn round_trip() {
        let text = "1 1:0.5 3:2\n-1 2:1.5\n0.25 1:-1\n";
        let d = parse_reader(Cursor::new(text), 0).unwrap();
        let mut buf = Vec::new();
        write_libsvm(&d, &mut buf).unwrap();
        let d2 = parse_reader(Cursor::new(buf), d.x.ncols()).unwrap();
        assert_eq!(d.y, d2.y);
        assert_eq!(d.x, d2.x);
    }
}
