//! Dataset substrate: libsvm parsing, synthetic generators (including the
//! paper-dataset stand-ins) and the simulated M/EEG inverse problem.

pub mod libsvm;
pub mod meeg;
pub mod synthetic;

pub use synthetic::{
    correlated, grouped_correlated, paper_dataset, paper_dataset_small, poisson_correlated,
    probit_correlated, sparse, with_poisson_targets, with_probit_targets, CorrelatedSpec,
    Dataset, GroupedSpec, SparseSpec,
};
