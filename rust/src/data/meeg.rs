//! Simulated M/EEG inverse problem (paper Figure 4 substitute).
//!
//! The paper uses real MNE auditory-stimulation data: reconstruct cortical
//! source currents from scalp sensors via a leadfield (gain) matrix
//! G ∈ R^{sensors × sources}, multitask over T time points. We have no
//! access to MNE data, so we simulate the physics that drives the paper's
//! conclusion: the leadfield mixes *spatially smooth* sensor topographies,
//! so nearby sources are heavily correlated, and two bilateral sources
//! (one per auditory cortex) are planted. The ℓ2,1 penalty's amplitude
//! bias then tends to split / mislocalize sources, while block non-convex
//! penalties (block-MCP / block-SCAD) recover both exactly — the
//! Figure-4 claim, checked here via support-recovery metrics instead of
//! brain plots.

use crate::linalg::DenseMatrix;
use crate::util::rng::Rng;

/// A simulated multitask M/EEG problem.
#[derive(Clone, Debug)]
pub struct MeegProblem {
    /// Gain / leadfield matrix, sensors × sources.
    pub gain: DenseMatrix,
    /// Measurements, sensors × time (column-major: col t = sensors at t).
    pub measurements: DenseMatrix,
    /// Planted source activations, sources × time.
    pub sources_true: DenseMatrix,
    /// Indices of active sources.
    pub active: Vec<usize>,
    /// Source positions on a 1-D "cortex" in [-1, 1]; sign = hemisphere.
    pub positions: Vec<f64>,
}

/// Spec for the simulator.
#[derive(Clone, Copy, Debug)]
pub struct MeegSpec {
    pub n_sensors: usize,
    pub n_sources: usize,
    pub n_times: usize,
    /// spatial smoothness of sensor topographies (higher = more correlated
    /// neighbouring sources = harder localisation)
    pub smoothness: f64,
    pub snr: f64,
}

impl Default for MeegSpec {
    fn default() -> Self {
        Self { n_sensors: 60, n_times: 20, n_sources: 300, smoothness: 12.0, snr: 4.0 }
    }
}

/// Simulate a right-auditory-stimulation-like dataset: one active source
/// per hemisphere, amplitudes 1.0 (left) and 1.4 (right — contralateral
/// dominance), smooth damped-sine time courses.
pub fn simulate(spec: MeegSpec, seed: u64) -> MeegProblem {
    let MeegSpec { n_sensors, n_sources, n_times, smoothness, snr } = spec;
    let mut rng = Rng::seed_from_u64(seed);

    // Source positions: uniform grid over [-1, 1]; hemisphere = sign.
    let positions: Vec<f64> =
        (0..n_sources).map(|j| -1.0 + 2.0 * (j as f64 + 0.5) / n_sources as f64).collect();
    // Sensor positions on the same axis (scalp ring simplification).
    let sensor_pos: Vec<f64> =
        (0..n_sensors).map(|i| -1.0 + 2.0 * (i as f64 + 0.5) / n_sensors as f64).collect();

    // Leadfield: Gaussian spatial falloff + small random perturbation —
    // neighbouring sources produce near-identical topographies, which is
    // what makes the inverse problem ill-posed.
    let mut gain = DenseMatrix::zeros(n_sensors, n_sources);
    for j in 0..n_sources {
        for i in 0..n_sensors {
            let d = sensor_pos[i] - positions[j];
            let v = (-smoothness * d * d).exp() + 0.02 * rng.normal();
            gain.set(i, j, v);
        }
    }
    // normalise leadfield columns (standard depth-weighting surrogate)
    let norms: Vec<f64> = gain.col_sq_norms().iter().map(|s| s.sqrt()).collect();
    for (j, &nm) in norms.iter().enumerate() {
        if nm > 0.0 {
            gain.scale_col(j, 1.0 / nm);
        }
    }

    // Two active sources: one per hemisphere, near ±0.5 ("auditory
    // cortices"), right stronger (contralateral to right-ear stimulus).
    let pick = |target: f64| -> usize {
        positions
            .iter()
            .enumerate()
            .min_by(|a, b| (a.1 - target).abs().partial_cmp(&(b.1 - target).abs()).unwrap())
            .unwrap()
            .0
    };
    let left = pick(-0.5);
    let right = pick(0.5);
    let active = vec![left, right];

    // Damped-sine time courses (N100-like response).
    let mut sources_true = DenseMatrix::zeros(n_sources, n_times);
    for (k, &j) in active.iter().enumerate() {
        let amp = if k == 0 { 1.0 } else { 1.4 };
        let phase = 0.3 * k as f64;
        for t in 0..n_times {
            let tt = t as f64 / n_times as f64;
            let v = amp * (2.0 * std::f64::consts::PI * (2.0 * tt + phase)).sin()
                * (-2.0 * tt).exp();
            sources_true.set(j, t, v);
        }
    }

    // Measurements M = G S + noise at target SNR (Frobenius).
    let mut meas = DenseMatrix::zeros(n_sensors, n_times);
    for t in 0..n_times {
        let mut col = vec![0.0; n_sensors];
        // G * S[:, t]
        let s_col: Vec<f64> = (0..n_sources).map(|j| sources_true.get(j, t)).collect();
        gain.matvec(&s_col, &mut col);
        for i in 0..n_sensors {
            meas.set(i, t, col[i]);
        }
    }
    let sig_fro: f64 = meas.raw().iter().map(|v| v * v).sum::<f64>().sqrt();
    let noise: Vec<f64> = rng.normal_vec(n_sensors * n_times);
    let noise_fro: f64 = noise.iter().map(|v| v * v).sum::<f64>().sqrt();
    let scale = sig_fro / (snr * noise_fro);
    let mut meas_noisy = DenseMatrix::zeros(n_sensors, n_times);
    for t in 0..n_times {
        for i in 0..n_sensors {
            meas_noisy.set(i, t, meas.get(i, t) + scale * noise[t * n_sensors + i]);
        }
    }

    MeegProblem { gain, measurements: meas_noisy, sources_true, active, positions }
}

/// Localisation report for a recovered source matrix.
#[derive(Clone, Debug)]
pub struct Localization {
    /// recovered active source indices (rows with nonzero norm)
    pub recovered: Vec<usize>,
    /// true active indices
    pub truth: Vec<usize>,
    /// number of hemispheres (sign of position) containing >=1 recovered source
    pub hemispheres_hit: usize,
    /// max |position error| between each true source and nearest recovered (∞ if missed)
    pub max_position_error: f64,
}

/// Evaluate support recovery of an estimate W (sources × time).
pub fn localize(problem: &MeegProblem, w: &DenseMatrix, row_norm_tol: f64) -> Localization {
    let n_sources = problem.gain.ncols();
    let n_times = w.ncols();
    let mut recovered = Vec::new();
    for j in 0..n_sources {
        let mut s = 0.0;
        for t in 0..n_times {
            let v = w.get(j, t);
            s += v * v;
        }
        if s.sqrt() > row_norm_tol {
            recovered.push(j);
        }
    }
    let mut hems = [false, false];
    for &j in &recovered {
        if problem.positions[j] < 0.0 {
            hems[0] = true;
        } else {
            hems[1] = true;
        }
    }
    let mut max_err = 0.0f64;
    for &jt in &problem.active {
        let pt = problem.positions[jt];
        // nearest recovered source in the same hemisphere
        let err = recovered
            .iter()
            .filter(|&&j| problem.positions[j] * pt > 0.0)
            .map(|&j| (problem.positions[j] - pt).abs())
            .fold(f64::INFINITY, f64::min);
        max_err = max_err.max(err);
    }
    Localization {
        recovered,
        truth: problem.active.clone(),
        hemispheres_hit: hems.iter().filter(|&&h| h).count(),
        max_position_error: max_err,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn simulation_shapes() {
        let pb = simulate(MeegSpec::default(), 0);
        assert_eq!(pb.gain.nrows(), 60);
        assert_eq!(pb.gain.ncols(), 300);
        assert_eq!(pb.measurements.nrows(), 60);
        assert_eq!(pb.measurements.ncols(), 20);
        assert_eq!(pb.active.len(), 2);
    }

    #[test]
    fn active_sources_one_per_hemisphere() {
        let pb = simulate(MeegSpec::default(), 1);
        assert!(pb.positions[pb.active[0]] < 0.0);
        assert!(pb.positions[pb.active[1]] > 0.0);
    }

    #[test]
    fn leadfield_columns_unit_norm() {
        let pb = simulate(MeegSpec::default(), 2);
        for nsq in pb.gain.col_sq_norms() {
            assert!((nsq - 1.0).abs() < 1e-10);
        }
    }

    #[test]
    fn neighbouring_sources_highly_correlated() {
        let pb = simulate(MeegSpec::default(), 3);
        let (a, b) = (pb.gain.col(150), pb.gain.col(151));
        let corr = crate::linalg::dot(a, b);
        assert!(corr > 0.9, "neighbour leadfield corr {corr} — problem not ill-posed enough");
    }

    #[test]
    fn localize_on_ground_truth_is_perfect() {
        let pb = simulate(MeegSpec::default(), 4);
        let loc = localize(&pb, &pb.sources_true, 1e-8);
        assert_eq!(loc.recovered, pb.active);
        assert_eq!(loc.hemispheres_hit, 2);
        assert!(loc.max_position_error < 1e-12);
    }

    #[test]
    fn localize_flags_missed_hemisphere() {
        let pb = simulate(MeegSpec::default(), 5);
        // estimate with only the left source active
        let mut w = DenseMatrix::zeros(pb.gain.ncols(), pb.measurements.ncols());
        w.set(pb.active[0], 0, 1.0);
        let loc = localize(&pb, &w, 1e-8);
        assert_eq!(loc.hemispheres_hit, 1);
        assert!(loc.max_position_error.is_infinite());
    }
}
