//! Coordinator: the process-level runtime around the solver library.
//!
//! Three pieces:
//!
//! - [`pool`] — a std-thread worker pool used to parallelise experiment
//!   sweeps (input-order results);
//! - [`job`] — the trait-based fit abstraction ([`FitSpec`]): any
//!   datafit × penalty combination the solver layer supports, packaged
//!   with its path/normalization/screening conventions;
//! - [`scheduler`] — the path-aware fit scheduler ([`FitScheduler`]):
//!   a job queue executing single fits and warm-started λ-path sweeps on
//!   worker threads, streaming results back in completion order, with a
//!   per-dataset design/Gram/coefficient cache ([`cache`]) shared across
//!   jobs via `Arc<Dataset>`.
//!
//! This is the long-running-process shape of the library (a model-fitting
//! microservice); tokio is unavailable offline, so it is a compact
//! std::sync::mpsc equivalent.
//!
//! On top of the scheduler sits the production service stack:
//!
//! - [`wire`] — length-prefixed JSON framing with typed, recoverable
//!   error taxonomy for untrusted input;
//! - [`service`] — the TCP front door (`skglm serve`): admission
//!   control, per-job deadlines and priorities, cancellation (explicit
//!   or on client disconnect), per-tenant cache byte budgets, and an
//!   event router that fans the scheduler's stream out to subscribers;
//! - [`client`] — the protocol client (`skglm client`) with timeouts and
//!   exponential-backoff-with-jitter retries;
//! - [`fault`] — the deterministic fault-injection plan
//!   (`SKGLM_FAULTS` / `--faults`) behind every robustness test;
//! - [`smoke`] — the scripted loopback acceptance session CI runs.

pub mod cache;
pub mod client;
pub mod fault;
pub mod job;
pub mod pool;
pub mod scheduler;
pub mod service;
pub mod smoke;
pub mod wire;

pub use cache::{CacheStats, DatasetCache};
pub use client::{ClientConfig, ClientError, ServiceClient};
pub use fault::{FaultPlan, FaultSpec};
pub use job::{specs, BatchedFitSpec, BlockSpec, FitSpec, GlmSpec, SolverTopology};
pub use pool::run_parallel;
pub use scheduler::{
    FitOutcome, FitScheduler, FusionStats, Job, JobCtl, JobEvent, JobPolicy, PathPointOutcome,
    PathSummary, Priority,
};
pub use service::{ExitReason, ServiceConfig, ServiceHandle};
pub use wire::{FrameReader, WireError};
