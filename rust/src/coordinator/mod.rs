//! Coordinator: the process-level runtime around the solver library.
//!
//! The paper's contribution is the solver, so L3's coordination layer is
//! deliberately thin (per the session architecture note): a std-thread
//! worker pool ([`pool`]) used to parallelise experiment sweeps, and a
//! fit service ([`service`]) that owns a job queue, executes fits on
//! worker threads and streams results back — the shape a model-serving
//! deployment of the library would take (tokio is unavailable offline;
//! the service is a compact std::sync::mpsc equivalent).

pub mod pool;
pub mod service;

pub use pool::run_parallel;
pub use service::{FitJob, FitOutcome, SolveService};
