//! Coordinator: the process-level runtime around the solver library.
//!
//! Three pieces:
//!
//! - [`pool`] — a std-thread worker pool used to parallelise experiment
//!   sweeps (input-order results);
//! - [`job`] — the trait-based fit abstraction ([`FitSpec`]): any
//!   datafit × penalty combination the solver layer supports, packaged
//!   with its path/normalization/screening conventions;
//! - [`scheduler`] — the path-aware fit scheduler ([`FitScheduler`]):
//!   a job queue executing single fits and warm-started λ-path sweeps on
//!   worker threads, streaming results back in completion order, with a
//!   per-dataset design/Gram/coefficient cache ([`cache`]) shared across
//!   jobs via `Arc<Dataset>`.
//!
//! This is the long-running-process shape of the library (a model-fitting
//! microservice); tokio is unavailable offline, so it is a compact
//! std::sync::mpsc equivalent.

pub mod cache;
pub mod job;
pub mod pool;
pub mod scheduler;

pub use cache::{CacheStats, DatasetCache};
pub use job::{specs, BlockSpec, FitSpec, GlmSpec, SolverTopology};
pub use pool::run_parallel;
pub use scheduler::{
    FitOutcome, FitScheduler, Job, JobEvent, PathPointOutcome, PathSummary,
};
