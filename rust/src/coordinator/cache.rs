//! Shared per-dataset state for the fit scheduler: normalized designs,
//! Gram diagonals (column squared norms), **working-set Gram block
//! stores** and warm-start coefficients, keyed by (dataset identity,
//! datafit/penalty family) and shared across jobs through the existing
//! `Arc<Dataset>` plumbing.
//!
//! Dataset identity is the `Arc` allocation (`Arc::as_ptr`): jobs that
//! share a dataset must share the same `Arc<Dataset>` — exactly how the
//! service has always been used (a λ sweep clones the `Arc`, not the
//! design). Every design entry **pins its dataset** (holds the `Arc`),
//! so an address can never be reused by a new dataset while its key is
//! live, and the coefficient maps are only touched after `design_entry`
//! has pinned the same `Arc` — stale hits by pointer reuse are thereby
//! impossible.
//!
//! The cache is **byte-budgeted** (ISSUE 5 satellite): coefficients,
//! owned design copies and Gram blocks are accounted, and when the total
//! exceeds the budget the least-recently-used entries are evicted
//! (counted in [`CacheStats::evictions`]). Eviction only drops the
//! cache's `Arc` — jobs holding an entry keep it alive; they just stop
//! sharing with future jobs. The budget resolves `SKGLM_CACHE_BYTES` >
//! [`DEFAULT_CACHE_BUDGET`], or [`DatasetCache::with_budget`].

use crate::data::Dataset;
use crate::linalg::gram::GramCache;
use crate::linalg::Design;
use crate::util::lock_or_recover;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

/// Default cache byte budget (1 GiB), overridable with the
/// `SKGLM_CACHE_BYTES` env var or [`DatasetCache::with_budget`].
pub const DEFAULT_CACHE_BUDGET: usize = 1 << 30;

/// Cached design state for one (dataset, normalization) pair. Holds the
/// dataset `Arc`, pinning the allocation its cache key points at.
pub struct DesignEntry {
    owner: Arc<Dataset>,
    /// √n-normalized copy of the design (None ⇒ use the original).
    normalized: Option<Arc<Design>>,
    /// Gram diagonal `‖X_j‖²` of the (possibly normalized) design.
    pub col_sq_norms: Arc<Vec<f64>>,
    /// Column scales applied by normalization (β_orig = scale ⊙ β).
    pub scales: Option<Arc<Vec<f64>>>,
    /// Byte-budgeted working-set Gram block store for this design: the
    /// Gram inner engine's blocks persist here across λ points of a path
    /// sweep and across every job (CV folds, repeated fits) sharing the
    /// entry.
    pub gram: Arc<GramCache>,
}

impl DesignEntry {
    /// The design jobs should solve on (normalized copy when the spec's
    /// convention asks for it, the dataset's own otherwise).
    pub fn design(&self) -> &Design {
        match &self.normalized {
            Some(d) => d,
            None => &self.owner.design,
        }
    }

    /// Bytes this entry contributes to the cache budget: owned data only
    /// (the unnormalized design belongs to the dataset, not the cache),
    /// including the live Gram store.
    fn bytes(&self) -> usize {
        let mut b = self.col_sq_norms.len() * 8;
        if let Some(d) = &self.normalized {
            // ~12 bytes/stored entry covers CSC value + row index
            b += d.stored_entries() * 12;
        }
        if let Some(s) = &self.scales {
            b += s.len() * 8;
        }
        b + self.gram.bytes()
    }
}

struct DesignSlot {
    entry: Arc<DesignEntry>,
    last_used: u64,
}

struct CoefEntry {
    lambda: f64,
    beta: Vec<f64>,
    last_used: u64,
}

impl CoefEntry {
    fn bytes(&self) -> usize {
        self.beta.len() * 8 + 64
    }
}

/// Hit/miss/eviction counters (observability; `skglm serve` prints them).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CacheStats {
    pub design_hits: usize,
    pub design_misses: usize,
    pub coef_hits: usize,
    pub coef_misses: usize,
    /// entries dropped by byte-budget LRU eviction
    pub evictions: usize,
}

type CoefKey = (usize, bool, &'static str, &'static str);

/// The scheduler's shared cache. All methods take `&self`; internal
/// locking is per-map and never held across a solve.
pub struct DatasetCache {
    designs: Mutex<HashMap<(usize, bool), DesignSlot>>,
    coefs: Mutex<HashMap<CoefKey, CoefEntry>>,
    design_hits: AtomicUsize,
    design_misses: AtomicUsize,
    coef_hits: AtomicUsize,
    coef_misses: AtomicUsize,
    evictions: AtomicUsize,
    tick: AtomicU64,
    budget_bytes: usize,
}

impl Default for DatasetCache {
    fn default() -> Self {
        Self::new()
    }
}

impl DatasetCache {
    pub fn new() -> Self {
        Self::with_budget(crate::util::env_byte_budget("SKGLM_CACHE_BYTES", DEFAULT_CACHE_BUDGET))
    }

    /// Cache with an explicit byte budget (tests, embedders).
    pub fn with_budget(budget_bytes: usize) -> Self {
        Self {
            designs: Mutex::new(HashMap::new()),
            coefs: Mutex::new(HashMap::new()),
            design_hits: AtomicUsize::new(0),
            design_misses: AtomicUsize::new(0),
            coef_hits: AtomicUsize::new(0),
            coef_misses: AtomicUsize::new(0),
            evictions: AtomicUsize::new(0),
            tick: AtomicU64::new(0),
            budget_bytes: budget_bytes.max(1),
        }
    }

    /// Identity of a shared dataset (the `Arc` allocation).
    pub fn dataset_key(dataset: &Arc<Dataset>) -> usize {
        Arc::as_ptr(dataset) as usize
    }

    fn touch(&self) -> u64 {
        // relaxed is sound: ticks only order LRU recency among entries,
        // an advisory heuristic — no other memory hangs off this counter
        self.tick.fetch_add(1, Ordering::Relaxed) + 1
    }

    /// Bump a statistics counter. Relaxed ordering is sound: these are
    /// monotonic advisory counters read only by [`DatasetCache::stats`]
    /// for observability — nothing synchronises with them.
    fn bump(counter: &AtomicUsize) {
        counter.fetch_add(1, Ordering::Relaxed);
    }

    /// Design + Gram-diagonal + Gram-block entry for (dataset,
    /// normalization), computed once and shared by every job on the
    /// dataset. The √n normalization copy — a full O(nnz) design clone —
    /// happens at most once per dataset instead of once per MCP/SCAD job.
    pub fn design_entry(&self, dataset: &Arc<Dataset>, normalize: bool) -> Arc<DesignEntry> {
        let key = (Self::dataset_key(dataset), normalize);
        {
            let mut map = lock_or_recover(&self.designs);
            if let Some(slot) = map.get_mut(&key) {
                slot.last_used = self.touch();
                Self::bump(&self.design_hits);
                return Arc::clone(&slot.entry);
            }
        }
        // Compute outside the lock; a racing job may compute the same
        // entry, in which case the first insert wins (identical content).
        let entry = if normalize {
            let mut d = dataset.design.clone();
            let scales = d.normalize_cols((dataset.n() as f64).sqrt());
            let norms = d.col_sq_norms();
            Arc::new(DesignEntry {
                owner: Arc::clone(dataset),
                normalized: Some(Arc::new(d)),
                col_sq_norms: Arc::new(norms),
                scales: Some(Arc::new(scales)),
                gram: Arc::new(GramCache::with_default_budget()),
            })
        } else {
            Arc::new(DesignEntry {
                owner: Arc::clone(dataset),
                normalized: None,
                col_sq_norms: Arc::new(dataset.design.col_sq_norms()),
                scales: None,
                gram: Arc::new(GramCache::with_default_budget()),
            })
        };
        Self::bump(&self.design_misses);
        let out = {
            let mut map = lock_or_recover(&self.designs);
            let slot = map
                .entry(key)
                .or_insert_with(|| DesignSlot { entry, last_used: 0 });
            slot.last_used = self.touch();
            Arc::clone(&slot.entry)
        };
        self.enforce_budget(Some(key), None);
        out
    }

    /// Most recent solution stored for (dataset, normalization, datafit,
    /// penalty family), with the λ it was solved at. Only convex specs
    /// should consume this (any warm start reaches the same optimum).
    pub fn warm_coef(
        &self,
        dataset: &Arc<Dataset>,
        normalize: bool,
        datafit: &'static str,
        family: &'static str,
    ) -> Option<(f64, Vec<f64>)> {
        let key = (Self::dataset_key(dataset), normalize, datafit, family);
        let mut map = lock_or_recover(&self.coefs);
        match map.get_mut(&key) {
            Some(entry) => {
                entry.last_used = self.touch();
                Self::bump(&self.coef_hits);
                Some((entry.lambda, entry.beta.clone()))
            }
            None => {
                Self::bump(&self.coef_misses);
                None
            }
        }
    }

    /// Store the latest solution for the key (overwrites).
    pub fn store_coef(
        &self,
        dataset: &Arc<Dataset>,
        normalize: bool,
        datafit: &'static str,
        family: &'static str,
        lambda: f64,
        beta: &[f64],
    ) {
        let key = (Self::dataset_key(dataset), normalize, datafit, family);
        {
            let mut map = lock_or_recover(&self.coefs);
            let last_used = self.touch();
            map.insert(key, CoefEntry { lambda, beta: beta.to_vec(), last_used });
        }
        self.enforce_budget(None, Some(key));
    }

    /// Current accounted bytes (designs + coefficients + Gram blocks).
    pub fn bytes(&self) -> usize {
        let d: usize = lock_or_recover(&self.designs).values().map(|s| s.entry.bytes()).sum();
        let c: usize = lock_or_recover(&self.coefs).values().map(|e| e.bytes()).sum();
        d + c
    }

    /// Bytes accounted to one dataset across both maps and both
    /// normalization variants (service per-tenant budget metering).
    pub fn bytes_for(&self, dataset: &Arc<Dataset>) -> usize {
        let ds_key = Self::dataset_key(dataset);
        let d: usize = lock_or_recover(&self.designs)
            .iter()
            .filter(|((k, _), _)| *k == ds_key)
            .map(|(_, s)| s.entry.bytes())
            .sum();
        let c: usize = lock_or_recover(&self.coefs)
            .iter()
            .filter(|((k, _, _, _), _)| *k == ds_key)
            .map(|(_, e)| e.bytes())
            .sum();
        d + c
    }

    /// Drop every cache entry belonging to one dataset (both
    /// normalization variants, designs and coefficients). Returns the
    /// bytes freed; the drops are counted as evictions. The service calls
    /// this to reclaim a tenant's idle datasets when its byte budget is
    /// exceeded.
    pub fn evict_dataset(&self, dataset: &Arc<Dataset>) -> usize {
        let ds_key = Self::dataset_key(dataset);
        let mut freed = 0usize;
        {
            let mut map = lock_or_recover(&self.designs);
            let keys: Vec<(usize, bool)> =
                map.keys().filter(|(k, _)| *k == ds_key).copied().collect();
            for key in keys {
                if let Some(slot) = map.remove(&key) {
                    freed += slot.entry.bytes();
                    Self::bump(&self.evictions);
                }
            }
        }
        {
            let mut map = lock_or_recover(&self.coefs);
            let keys: Vec<CoefKey> =
                map.keys().filter(|(k, _, _, _)| *k == ds_key).copied().collect();
            for key in keys {
                if let Some(entry) = map.remove(&key) {
                    freed += entry.bytes();
                    Self::bump(&self.evictions);
                }
            }
        }
        freed
    }

    /// Re-run budget enforcement with no protected entry. The scheduler
    /// calls this after every job: Gram stores grow **during** solves, so
    /// waiting for the next insert would leave the budget unenforced for
    /// the whole lifetime of a quiet serve workload.
    pub fn enforce_budget_now(&self) {
        self.enforce_budget(None, None);
    }

    /// Remove an entry iff its `last_used` still matches the observed
    /// tick — a concurrent touch between victim selection and removal
    /// promotes the entry to MRU, and evicting it then would thrash the
    /// very reuse the cache exists for.
    fn remove_design_if_untouched(&self, key: (usize, bool), seen: u64) -> bool {
        let mut map = lock_or_recover(&self.designs);
        match map.get(&key) {
            Some(slot) if slot.last_used == seen => {
                map.remove(&key);
                true
            }
            _ => false,
        }
    }

    fn remove_coef_if_untouched(&self, key: CoefKey, seen: u64) -> bool {
        let mut map = lock_or_recover(&self.coefs);
        match map.get(&key) {
            Some(entry) if entry.last_used == seen => {
                map.remove(&key);
                true
            }
            _ => false,
        }
    }

    /// LRU eviction until the accounted bytes fit the budget. The entry
    /// just touched (`keep_*`) is never evicted — the cache must always
    /// be able to serve the request that grew it.
    fn enforce_budget(&self, keep_design: Option<(usize, bool)>, keep_coef: Option<CoefKey>) {
        loop {
            if self.bytes() <= self.budget_bytes {
                return;
            }
            // oldest evictable entry across both maps
            let oldest_design = {
                let map = lock_or_recover(&self.designs);
                map.iter()
                    .filter(|(k, _)| Some(**k) != keep_design)
                    .min_by_key(|(_, s)| s.last_used)
                    .map(|(k, s)| (*k, s.last_used))
            };
            let oldest_coef = {
                let map = lock_or_recover(&self.coefs);
                map.iter()
                    .filter(|(k, _)| Some(**k) != keep_coef)
                    .min_by_key(|(_, e)| e.last_used)
                    .map(|(k, e)| (*k, e.last_used))
            };
            // removal is tick-guarded: if a concurrent caller touched the
            // victim meanwhile we just loop and pick a new one
            let evicted = match (oldest_design, oldest_coef) {
                (Some((dk, dt)), Some((_, ct))) if dt <= ct => {
                    self.remove_design_if_untouched(dk, dt)
                }
                (_, Some((ck, ct))) => self.remove_coef_if_untouched(ck, ct),
                (Some((dk, dt)), None) => self.remove_design_if_untouched(dk, dt),
                (None, None) => false,
            };
            if !evicted && oldest_design.is_none() && oldest_coef.is_none() {
                return; // nothing evictable (only protected entries left)
            }
            if evicted {
                Self::bump(&self.evictions);
            }
        }
    }

    pub fn stats(&self) -> CacheStats {
        CacheStats {
            design_hits: self.design_hits.load(Ordering::Relaxed),
            design_misses: self.design_misses.load(Ordering::Relaxed),
            coef_hits: self.coef_hits.load(Ordering::Relaxed),
            coef_misses: self.coef_misses.load(Ordering::Relaxed),
            evictions: self.evictions.load(Ordering::Relaxed),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{correlated, CorrelatedSpec};

    fn ds() -> Arc<Dataset> {
        Arc::new(correlated(CorrelatedSpec { n: 30, p: 40, rho: 0.3, nnz: 4, snr: 10.0 }, 2))
    }

    #[test]
    fn design_entry_computed_once() {
        let cache = DatasetCache::new();
        let d = ds();
        let a = cache.design_entry(&d, false);
        let b = cache.design_entry(&d, false);
        assert!(Arc::ptr_eq(&a, &b));
        let s = cache.stats();
        assert_eq!(s.design_misses, 1);
        assert_eq!(s.design_hits, 1);
        assert_eq!(s.evictions, 0);
        // unnormalized entry exposes the original design
        assert!(std::ptr::eq(a.design(), &d.design));
    }

    #[test]
    fn design_entry_pins_its_dataset() {
        let cache = DatasetCache::new();
        let d = ds();
        let weak = Arc::downgrade(&d);
        let entry = cache.design_entry(&d, false);
        drop(d);
        // the entry holds the Arc, so the keyed address cannot be
        // reallocated to a different dataset while the cache is alive
        assert!(weak.upgrade().is_some());
        assert_eq!(entry.design().ncols(), 40);
    }

    #[test]
    fn normalized_entry_has_unit_sqrt_n_columns() {
        let cache = DatasetCache::new();
        let d = ds();
        let e = cache.design_entry(&d, true);
        let n = d.n() as f64;
        for (&sq, &scale) in e.col_sq_norms.iter().zip(e.scales.as_ref().unwrap().iter()) {
            if scale != 1.0 {
                assert!((sq - n).abs() < 1e-8, "normalized col sq norm {sq} != n {n}");
            }
        }
        // distinct from the unnormalized entry
        let raw = cache.design_entry(&d, false);
        assert!(!Arc::ptr_eq(&e, &raw));
    }

    #[test]
    fn coef_roundtrip_and_stats() {
        let cache = DatasetCache::new();
        let d = ds();
        assert!(cache.warm_coef(&d, false, "quadratic", "l1").is_none());
        cache.store_coef(&d, false, "quadratic", "l1", 0.2, &[1.0, 0.0]);
        let (lam, beta) = cache.warm_coef(&d, false, "quadratic", "l1").unwrap();
        assert_eq!(lam, 0.2);
        assert_eq!(beta, vec![1.0, 0.0]);
        // different family is a different key
        assert!(cache.warm_coef(&d, false, "quadratic", "mcp").is_none());
        let s = cache.stats();
        assert_eq!(s.coef_hits, 1);
        assert_eq!(s.coef_misses, 2);
    }

    #[test]
    fn byte_budget_evicts_lru_and_counts() {
        // budget sized so ONE normalized entry fits but two don't
        // (normalized copy ≈ 30·40·12 bytes plus norms/scales)
        let cache = DatasetCache::with_budget(20_000);
        let d1 = ds();
        let d2 = Arc::new(correlated(
            CorrelatedSpec { n: 30, p: 40, rho: 0.3, nnz: 4, snr: 10.0 },
            3,
        ));
        let _e1 = cache.design_entry(&d1, true);
        assert_eq!(cache.stats().evictions, 0);
        let _e2 = cache.design_entry(&d2, true);
        let s = cache.stats();
        assert!(s.evictions >= 1, "second entry must evict the LRU first one");
        assert!(cache.bytes() <= 20_000, "cache over budget: {} bytes", cache.bytes());
        // d1 was evicted: asking again recomputes (miss, not hit)
        let misses_before = cache.stats().design_misses;
        let _e1_again = cache.design_entry(&d1, true);
        assert_eq!(cache.stats().design_misses, misses_before + 1);
    }

    #[test]
    fn coefficients_participate_in_the_budget() {
        let cache = DatasetCache::with_budget(2_000);
        let d = ds();
        // several large coefficient entries under different families
        cache.store_coef(&d, false, "quadratic", "l1", 0.1, &vec![1.0; 100]);
        cache.store_coef(&d, false, "quadratic", "mcp", 0.1, &vec![1.0; 100]);
        cache.store_coef(&d, false, "quadratic", "scad", 0.1, &vec![1.0; 100]);
        let s = cache.stats();
        assert!(s.evictions >= 1, "coef entries must be evicted under budget pressure");
        assert!(cache.bytes() <= 2_000);
        // the most recently stored family survives
        assert!(cache.warm_coef(&d, false, "quadratic", "scad").is_some());
    }

    #[test]
    fn most_recent_entry_is_never_evicted_even_when_oversized() {
        // a budget no entry can fit: the just-inserted one must survive
        let cache = DatasetCache::with_budget(1);
        let d = ds();
        let e = cache.design_entry(&d, true);
        assert_eq!(e.design().ncols(), 40);
        let map_len = cache.designs.lock().unwrap().len();
        assert_eq!(map_len, 1, "the entry that grew the cache must be served");
    }

    #[test]
    fn enforce_budget_now_accounts_gram_growth_between_inserts() {
        // the bare entry fits the budget; its Gram store growing during a
        // "solve" pushes it over, and enforce_budget_now (what the
        // scheduler calls after each job) must evict
        let cache = DatasetCache::with_budget(6_000);
        let d = ds();
        let entry = cache.design_entry(&d, false);
        assert_eq!(cache.stats().evictions, 0);
        let ws: Vec<usize> = (0..40).collect();
        let mut gw = Vec::new();
        entry.gram.ensure_gather(entry.design(), &ws, &mut gw);
        assert!(cache.bytes() > 6_000, "gram growth must be accounted: {}", cache.bytes());
        cache.enforce_budget_now();
        assert!(cache.stats().evictions >= 1);
        assert!(cache.bytes() <= 6_000);
    }

    #[test]
    fn design_entry_carries_a_shared_gram_store() {
        let cache = DatasetCache::new();
        let d = ds();
        let a = cache.design_entry(&d, false);
        let b = cache.design_entry(&d, false);
        assert!(Arc::ptr_eq(&a.gram, &b.gram), "jobs must share one Gram store");
        assert_eq!(a.gram.n_slots(), 0);
    }

    #[test]
    fn per_dataset_metering_and_eviction() {
        let cache = DatasetCache::new();
        let d1 = ds();
        let d2 = Arc::new(correlated(
            CorrelatedSpec { n: 30, p: 40, rho: 0.3, nnz: 4, snr: 10.0 },
            7,
        ));
        let _e1 = cache.design_entry(&d1, false);
        let _e2 = cache.design_entry(&d2, true);
        cache.store_coef(&d1, false, "quadratic", "l1", 0.5, &[1.0; 40]);
        let b1 = cache.bytes_for(&d1);
        let b2 = cache.bytes_for(&d2);
        assert!(b1 > 0 && b2 > 0);
        assert_eq!(cache.bytes(), b1 + b2, "per-dataset meters must sum to the total");
        let freed = cache.evict_dataset(&d1);
        assert_eq!(freed, b1);
        assert_eq!(cache.bytes_for(&d1), 0);
        assert_eq!(cache.bytes_for(&d2), b2, "evicting one tenant's dataset spares others");
        assert!(cache.stats().evictions >= 2, "design + coef entries count as evictions");
    }
}
