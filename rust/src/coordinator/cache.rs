//! Shared per-dataset state for the fit scheduler: normalized designs,
//! Gram diagonals (column squared norms) and warm-start coefficients,
//! keyed by (dataset identity, datafit/penalty family) and shared across
//! jobs through the existing `Arc<Dataset>` plumbing.
//!
//! Dataset identity is the `Arc` allocation (`Arc::as_ptr`): jobs that
//! share a dataset must share the same `Arc<Dataset>` — exactly how the
//! service has always been used (a λ sweep clones the `Arc`, not the
//! design). Every design entry **pins its dataset** (holds the `Arc`),
//! so an address can never be reused by a new dataset while its key is
//! live, and the coefficient maps are only touched after `design_entry`
//! has pinned the same `Arc` — stale hits by pointer reuse are thereby
//! impossible. The flip side: entries live for the scheduler's lifetime
//! (a λ-sweep service working a bounded dataset set, not an unbounded
//! stream; drop the scheduler to release them).

use crate::data::Dataset;
use crate::linalg::Design;
use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

/// Cached design state for one (dataset, normalization) pair. Holds the
/// dataset `Arc`, pinning the allocation its cache key points at.
pub struct DesignEntry {
    owner: Arc<Dataset>,
    /// √n-normalized copy of the design (None ⇒ use the original).
    normalized: Option<Arc<Design>>,
    /// Gram diagonal `‖X_j‖²` of the (possibly normalized) design.
    pub col_sq_norms: Arc<Vec<f64>>,
    /// Column scales applied by normalization (β_orig = scale ⊙ β).
    pub scales: Option<Arc<Vec<f64>>>,
}

impl DesignEntry {
    /// The design jobs should solve on (normalized copy when the spec's
    /// convention asks for it, the dataset's own otherwise).
    pub fn design(&self) -> &Design {
        match &self.normalized {
            Some(d) => d,
            None => &self.owner.design,
        }
    }
}

struct CoefEntry {
    lambda: f64,
    beta: Vec<f64>,
}

/// Hit/miss counters (observability; `skglm serve` prints them).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CacheStats {
    pub design_hits: usize,
    pub design_misses: usize,
    pub coef_hits: usize,
    pub coef_misses: usize,
}

type CoefKey = (usize, bool, &'static str, &'static str);

/// The scheduler's shared cache. All methods take `&self`; internal
/// locking is per-map and never held across a solve.
#[derive(Default)]
pub struct DatasetCache {
    designs: Mutex<HashMap<(usize, bool), Arc<DesignEntry>>>,
    coefs: Mutex<HashMap<CoefKey, CoefEntry>>,
    design_hits: AtomicUsize,
    design_misses: AtomicUsize,
    coef_hits: AtomicUsize,
    coef_misses: AtomicUsize,
}

impl DatasetCache {
    pub fn new() -> Self {
        Self::default()
    }

    /// Identity of a shared dataset (the `Arc` allocation).
    pub fn dataset_key(dataset: &Arc<Dataset>) -> usize {
        Arc::as_ptr(dataset) as usize
    }

    /// Design + Gram-diagonal entry for (dataset, normalization),
    /// computed once and shared by every job on the dataset. The √n
    /// normalization copy — a full O(nnz) design clone — happens at most
    /// once per dataset instead of once per MCP/SCAD job.
    pub fn design_entry(&self, dataset: &Arc<Dataset>, normalize: bool) -> Arc<DesignEntry> {
        let key = (Self::dataset_key(dataset), normalize);
        {
            let map = self.designs.lock().unwrap();
            if let Some(entry) = map.get(&key) {
                self.design_hits.fetch_add(1, Ordering::Relaxed);
                return Arc::clone(entry);
            }
        }
        // Compute outside the lock; a racing job may compute the same
        // entry, in which case the first insert wins (identical content).
        let entry = if normalize {
            let mut d = dataset.design.clone();
            let scales = d.normalize_cols((dataset.n() as f64).sqrt());
            let norms = d.col_sq_norms();
            Arc::new(DesignEntry {
                owner: Arc::clone(dataset),
                normalized: Some(Arc::new(d)),
                col_sq_norms: Arc::new(norms),
                scales: Some(Arc::new(scales)),
            })
        } else {
            Arc::new(DesignEntry {
                owner: Arc::clone(dataset),
                normalized: None,
                col_sq_norms: Arc::new(dataset.design.col_sq_norms()),
                scales: None,
            })
        };
        self.design_misses.fetch_add(1, Ordering::Relaxed);
        let mut map = self.designs.lock().unwrap();
        Arc::clone(map.entry(key).or_insert(entry))
    }

    /// Most recent solution stored for (dataset, normalization, datafit,
    /// penalty family), with the λ it was solved at. Only convex specs
    /// should consume this (any warm start reaches the same optimum).
    pub fn warm_coef(
        &self,
        dataset: &Arc<Dataset>,
        normalize: bool,
        datafit: &'static str,
        family: &'static str,
    ) -> Option<(f64, Vec<f64>)> {
        let key = (Self::dataset_key(dataset), normalize, datafit, family);
        let map = self.coefs.lock().unwrap();
        match map.get(&key) {
            Some(entry) => {
                self.coef_hits.fetch_add(1, Ordering::Relaxed);
                Some((entry.lambda, entry.beta.clone()))
            }
            None => {
                self.coef_misses.fetch_add(1, Ordering::Relaxed);
                None
            }
        }
    }

    /// Store the latest solution for the key (overwrites).
    pub fn store_coef(
        &self,
        dataset: &Arc<Dataset>,
        normalize: bool,
        datafit: &'static str,
        family: &'static str,
        lambda: f64,
        beta: &[f64],
    ) {
        let key = (Self::dataset_key(dataset), normalize, datafit, family);
        let mut map = self.coefs.lock().unwrap();
        map.insert(key, CoefEntry { lambda, beta: beta.to_vec() });
    }

    pub fn stats(&self) -> CacheStats {
        CacheStats {
            design_hits: self.design_hits.load(Ordering::Relaxed),
            design_misses: self.design_misses.load(Ordering::Relaxed),
            coef_hits: self.coef_hits.load(Ordering::Relaxed),
            coef_misses: self.coef_misses.load(Ordering::Relaxed),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{correlated, CorrelatedSpec};

    fn ds() -> Arc<Dataset> {
        Arc::new(correlated(CorrelatedSpec { n: 30, p: 40, rho: 0.3, nnz: 4, snr: 10.0 }, 2))
    }

    #[test]
    fn design_entry_computed_once() {
        let cache = DatasetCache::new();
        let d = ds();
        let a = cache.design_entry(&d, false);
        let b = cache.design_entry(&d, false);
        assert!(Arc::ptr_eq(&a, &b));
        let s = cache.stats();
        assert_eq!(s.design_misses, 1);
        assert_eq!(s.design_hits, 1);
        // unnormalized entry exposes the original design
        assert!(std::ptr::eq(a.design(), &d.design));
    }

    #[test]
    fn design_entry_pins_its_dataset() {
        let cache = DatasetCache::new();
        let d = ds();
        let weak = Arc::downgrade(&d);
        let entry = cache.design_entry(&d, false);
        drop(d);
        // the entry holds the Arc, so the keyed address cannot be
        // reallocated to a different dataset while the cache is alive
        assert!(weak.upgrade().is_some());
        assert_eq!(entry.design().ncols(), 40);
    }

    #[test]
    fn normalized_entry_has_unit_sqrt_n_columns() {
        let cache = DatasetCache::new();
        let d = ds();
        let e = cache.design_entry(&d, true);
        let n = d.n() as f64;
        for (&sq, &scale) in e.col_sq_norms.iter().zip(e.scales.as_ref().unwrap().iter()) {
            if scale != 1.0 {
                assert!((sq - n).abs() < 1e-8, "normalized col sq norm {sq} != n {n}");
            }
        }
        // distinct from the unnormalized entry
        let raw = cache.design_entry(&d, false);
        assert!(!Arc::ptr_eq(&e, &raw));
    }

    #[test]
    fn coef_roundtrip_and_stats() {
        let cache = DatasetCache::new();
        let d = ds();
        assert!(cache.warm_coef(&d, false, "quadratic", "l1").is_none());
        cache.store_coef(&d, false, "quadratic", "l1", 0.2, &[1.0, 0.0]);
        let (lam, beta) = cache.warm_coef(&d, false, "quadratic", "l1").unwrap();
        assert_eq!(lam, 0.2);
        assert_eq!(beta, vec![1.0, 0.0]);
        // different family is a different key
        assert!(cache.warm_coef(&d, false, "quadratic", "mcp").is_none());
        let s = cache.stats();
        assert_eq!(s.coef_hits, 1);
        assert_eq!(s.coef_misses, 2);
    }
}
