//! Path-aware fit scheduler: a leader/worker queue over trait-based
//! [`FitSpec`] jobs with completion-order result streaming, priority
//! classes, cooperative cancellation and per-job deadlines.
//!
//! Replaces the old closed-enum `SolveService`. Two job shapes:
//!
//! - [`Job::Fit`] — one (spec, λ) solve. Convex specs warm-start from the
//!   coefficient cache when a previous job solved the same
//!   (dataset, datafit, family).
//! - [`Job::Path`] — a whole λ grid swept **on one worker** with
//!   warm-started coefficients and persistent working-set size between
//!   points ([`crate::solver::ContinuationState`]), plus a per-λ gap-safe
//!   screening pass for specs that support it. Each solved point streams
//!   back immediately as [`JobEvent::PathPoint`] — callers see the path
//!   fill in completion order rather than waiting for the sweep.
//!
//! Robustness policy (the production service rides on these):
//!
//! - **Priorities** ([`Priority`]): interactive jobs are always popped
//!   before batch jobs, and a *running* batch path cooperatively yields
//!   at λ-point granularity when interactive work is waiting — the
//!   remainder of the sweep is requeued as [`Job::PathResume`] with its
//!   warm [`ContinuationState`] intact, so no work is lost.
//! - **Cancellation** ([`FitScheduler::cancel`]): raises a flag that the
//!   solver polls between outer iterations (via
//!   [`crate::solver::SolveBudget`]) and the path loop polls between λ
//!   points; a cancelled job frees its worker within one λ point and
//!   emits [`JobEvent::Cancelled`] as its terminal event.
//! - **Deadlines** ([`JobPolicy::deadline`]): a deadline-exceeded solve
//!   stops cooperatively and still reports a finite partial objective
//!   with its optimality [`crate::solver::Certificate`]; the terminal
//!   event carries `timed_out = true`.
//! - **Liveness** ([`JobEvent::SchedulerDown`]): the last worker to exit
//!   (graceful shutdown or fault-injected death) emits a terminal
//!   `SchedulerDown`, so consumers never block forever on a dead pool.
//!
//! Results stream back over a channel in completion order, every event
//! tagged with its job id; jobs from different callers interleave freely.
//! Built on std::sync::mpsc since tokio is unavailable offline.

use super::cache::DatasetCache;
use super::job::FitSpec;
use crate::data::Dataset;
use crate::util::{lock_or_recover, wait_or_recover};
use crate::estimators::path::PathPoint;
use crate::linalg::parallel::{register_solver_workers, SolverWorkersGuard};
use crate::metrics::{estimation_error, prediction_mse, support_recovery};
use crate::solver::screening::{solve_lasso_screened_warm_with, ScreenWorkspace};
use crate::solver::{ContinuationState, FitResult, SolverOpts};
use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// A schedulable unit of work.
pub enum Job {
    /// One fit at a fixed λ.
    Fit { dataset: Arc<Dataset>, spec: Box<dyn FitSpec>, opts: SolverOpts },
    /// A warm-started sweep over `ratios · λ_max` (sorted descending
    /// internally — warm starts flow from high λ to low).
    Path { dataset: Arc<Dataset>, spec: Box<dyn FitSpec>, ratios: Vec<f64>, opts: SolverOpts },
    /// Internal: the remainder of a preempted path sweep, carrying its
    /// warm continuation state. Produced by the worker when a batch path
    /// yields to interactive work; never constructed by callers.
    PathResume(Box<PathResume>),
}

/// Scheduling class. Interactive jobs are popped before batch jobs and
/// preempt running batch paths at λ-point granularity.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum Priority {
    Interactive,
    #[default]
    Batch,
}

/// Per-job scheduling policy (see [`FitScheduler::submit_with`]).
#[derive(Clone, Debug, Default)]
pub struct JobPolicy {
    pub priority: Priority,
    /// Cooperative wall-clock deadline: the job stops within one outer
    /// iteration / λ point of this instant and reports partial results.
    pub deadline: Option<Instant>,
}

impl JobPolicy {
    pub fn interactive() -> Self {
        Self { priority: Priority::Interactive, deadline: None }
    }
    pub fn with_deadline(mut self, deadline: Instant) -> Self {
        self.deadline = Some(deadline);
        self
    }
}

/// Shared per-job control block: the cancellation flag (also handed to
/// the solver via [`crate::solver::SolveBudget`]), the deadline, and the
/// priority class.
#[derive(Debug)]
pub struct JobCtl {
    cancel: Arc<AtomicBool>,
    deadline: Option<Instant>,
    priority: Priority,
}

impl JobCtl {
    fn new(policy: &JobPolicy) -> Self {
        Self {
            cancel: Arc::new(AtomicBool::new(false)),
            deadline: policy.deadline,
            priority: policy.priority,
        }
    }

    pub fn cancel(&self) {
        // relaxed is sound: the flag is the entire message — cancellation
        // is cooperative polling, no other data is published through it
        self.cancel.store(true, Ordering::Relaxed);
    }
    pub fn is_cancelled(&self) -> bool {
        self.cancel.load(Ordering::Relaxed)
    }
    pub fn deadline_exceeded(&self) -> bool {
        self.deadline.is_some_and(|d| Instant::now() >= d)
    }
    pub fn priority(&self) -> Priority {
        self.priority
    }

    /// Clone `base` with this job's budget (deadline + cancel flag)
    /// merged in; caller-provided budget fields win.
    fn solver_opts(&self, base: &SolverOpts) -> SolverOpts {
        let mut opts = base.clone();
        let mut budget = opts.budget.take().unwrap_or_default();
        if budget.deadline.is_none() {
            budget.deadline = self.deadline;
        }
        if budget.cancel.is_none() {
            budget.cancel = Some(Arc::clone(&self.cancel));
        }
        opts.budget = Some(budget);
        opts
    }
}

/// A completed single fit.
pub struct FitOutcome {
    pub job_id: u64,
    pub label: String,
    pub lambda: f64,
    pub result: FitResult,
    pub wall_time: f64,
    /// true when the coefficient cache seeded the solve
    pub warm_started: bool,
    /// true when the job's deadline stopped the solve before convergence;
    /// `result` then holds the partial iterate with its certificate
    pub timed_out: bool,
}

/// One solved point of a path job, streamed as soon as it finishes.
pub struct PathPointOutcome {
    pub job_id: u64,
    /// position in the (descending) ratio grid
    pub index: usize,
    pub point: PathPoint,
    pub epochs: usize,
    /// features certified inactive by the gap-safe pass at this λ
    pub n_screened: usize,
    pub wall_time: f64,
    /// the solve's final optimality violation at this λ (`certificate`
    /// names the metric) — conformance oracles check it against the
    /// declared tolerance instead of re-deriving KKT residuals
    pub kkt: f64,
    pub converged: bool,
    pub certificate: crate::solver::Certificate,
}

/// Terminal event of a path job.
pub struct PathSummary {
    pub job_id: u64,
    pub label: String,
    /// points actually emitted (== `n_planned` unless the job timed out)
    pub n_points: usize,
    /// points the λ grid asked for
    pub n_planned: usize,
    pub total_epochs: usize,
    pub total_time: f64,
    /// true when the deadline cut the sweep short; the emitted points
    /// (including a final partial one with its certificate) still stand
    pub timed_out: bool,
}

/// Everything the scheduler streams back, tagged with its job id.
pub enum JobEvent {
    FitDone(FitOutcome),
    PathPoint(PathPointOutcome),
    PathDone(PathSummary),
    /// The job's solve panicked on its worker. The worker caught the
    /// panic and keeps serving the queue — one divergent fit cannot take
    /// down a mixed batch — and the original panic message is preserved
    /// here instead of being lost to a dead thread.
    ///
    /// `Failed` is the job's **terminal** event: a path job that fails
    /// mid-sweep emits its points so far, then `Failed`, and **no**
    /// `PathDone` — consumers must count job-terminal events
    /// (`FitDone`/`PathDone`/`Failed`/`Cancelled`), not a fixed per-point
    /// total, or they will block forever on a failed sweep.
    Failed { job_id: u64, message: String },
    /// Terminal event of a cancelled job. A cancelled path stops within
    /// one λ point; `points_emitted` counts the `PathPoint`s that were
    /// streamed before the cancellation landed (0 for fits and for jobs
    /// cancelled while still queued).
    Cancelled { job_id: u64, points_emitted: usize },
    /// The last worker exited (graceful shutdown or fault-injected
    /// death): no further events will ever arrive. Consumers must treat
    /// this as terminal for every outstanding job instead of blocking on
    /// `events.recv()` forever.
    SchedulerDown,
}

impl JobEvent {
    /// Job id carried by the event; [`JobEvent::SchedulerDown`] is not
    /// job-scoped and reports `u64::MAX`.
    pub fn job_id(&self) -> u64 {
        match self {
            JobEvent::FitDone(o) => o.job_id,
            JobEvent::PathPoint(o) => o.job_id,
            JobEvent::PathDone(s) => s.job_id,
            JobEvent::Failed { job_id, .. } => *job_id,
            JobEvent::Cancelled { job_id, .. } => *job_id,
            JobEvent::SchedulerDown => u64::MAX,
        }
    }

    /// Is this the last event the job will ever emit?
    pub fn is_terminal(&self) -> bool {
        !matches!(self, JobEvent::PathPoint(_))
    }
}

/// Best-effort extraction of a panic payload's message (`&str` and
/// `String` payloads cover `panic!`/`assert!`/`expect`). Shared with the
/// experiment pool ([`crate::coordinator::pool::run_parallel`]).
pub(crate) fn panic_message(payload: Box<dyn std::any::Any + Send>) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

struct QueuedJob {
    id: u64,
    job: Job,
    ctl: Arc<JobCtl>,
}

#[derive(Default)]
struct QueueState {
    interactive: VecDeque<QueuedJob>,
    batch: VecDeque<QueuedJob>,
    /// workers asked to exit after the queues drain (graceful shutdown)
    graceful_exits: usize,
    /// workers asked to exit immediately (fault injection)
    kill_now: usize,
}

/// Two-class FIFO job queue with condvar wakeups. Interactive beats
/// batch; exit requests are honored immediately (`kill_now`) or only
/// once both queues are empty (`graceful_exits`).
struct JobQueue {
    state: Mutex<QueueState>,
    cv: Condvar,
}

impl JobQueue {
    fn new() -> Self {
        Self { state: Mutex::new(QueueState::default()), cv: Condvar::new() }
    }

    fn push(&self, qj: QueuedJob) {
        let mut st = lock_or_recover(&self.state);
        match qj.ctl.priority() {
            Priority::Interactive => st.interactive.push_back(qj),
            Priority::Batch => st.batch.push_back(qj),
        }
        drop(st);
        self.cv.notify_one();
    }

    /// Requeue a preempted path remainder at the *front* of the batch
    /// queue: it resumes as soon as interactive work drains, ahead of
    /// batch jobs that were submitted after it started.
    fn push_resume_front(&self, qj: QueuedJob) {
        let mut st = lock_or_recover(&self.state);
        st.batch.push_front(qj);
        drop(st);
        self.cv.notify_one();
    }

    /// Block for the next job; `None` means "this worker should exit".
    fn pop_blocking(&self) -> Option<QueuedJob> {
        let mut st = lock_or_recover(&self.state);
        loop {
            if st.kill_now > 0 {
                st.kill_now -= 1;
                return None;
            }
            if let Some(j) = st.interactive.pop_front() {
                return Some(j);
            }
            if let Some(j) = st.batch.pop_front() {
                return Some(j);
            }
            if st.graceful_exits > 0 {
                st.graceful_exits -= 1;
                return None;
            }
            st = wait_or_recover(&self.cv, st);
        }
    }

    fn interactive_waiting(&self) -> bool {
        !lock_or_recover(&self.state).interactive.is_empty()
    }

    fn depth(&self) -> usize {
        let st = lock_or_recover(&self.state);
        st.interactive.len() + st.batch.len()
    }

    fn request_exit(&self, n: usize, immediate: bool) {
        let mut st = lock_or_recover(&self.state);
        if immediate {
            st.kill_now += n;
        } else {
            st.graceful_exits += n;
        }
        drop(st);
        self.cv.notify_all();
    }
}

/// The scheduler: submit jobs, stream events, cancel, shut down cleanly.
pub struct FitScheduler {
    queue: Arc<JobQueue>,
    /// Completion-order event stream.
    pub events: Receiver<JobEvent>,
    workers: Vec<JoinHandle<()>>,
    next_id: AtomicU64,
    cache: Arc<DatasetCache>,
    /// Control blocks of queued + running jobs (removed at terminal emit).
    registry: Arc<Mutex<HashMap<u64, Arc<JobCtl>>>>,
    /// Workers still alive (the last one to exit emits `SchedulerDown`).
    workers_alive: Arc<AtomicUsize>,
    /// Registers the worker count against the kernel-engine thread budget
    /// for the scheduler's lifetime: each job's kernels then get
    /// `budget / workers` threads, so kernel × worker parallelism never
    /// oversubscribes the machine. Released on shutdown/drop.
    _kernel_budget: SolverWorkersGuard,
}

impl FitScheduler {
    /// Spawn `n_workers` solver threads (at least one).
    pub fn start(n_workers: usize) -> Self {
        Self::start_with_cache(n_workers, Arc::new(DatasetCache::new()))
    }

    /// Spawn with an explicit (e.g. budget-restricted) dataset cache —
    /// the service uses this to wire tenant byte budgets into the LRU.
    pub fn start_with_cache(n_workers: usize, cache: Arc<DatasetCache>) -> Self {
        let n_workers = n_workers.max(1);
        let queue = Arc::new(JobQueue::new());
        let (ev_tx, ev_rx) = channel::<JobEvent>();
        let registry: Arc<Mutex<HashMap<u64, Arc<JobCtl>>>> =
            Arc::new(Mutex::new(HashMap::new()));
        let workers_alive = Arc::new(AtomicUsize::new(n_workers));
        let workers = (0..n_workers)
            .map(|_| {
                let queue = Arc::clone(&queue);
                let ev_tx = ev_tx.clone();
                let cache = Arc::clone(&cache);
                let registry = Arc::clone(&registry);
                let alive = Arc::clone(&workers_alive);
                std::thread::spawn(move || {
                    while let Some(qj) = queue.pop_blocking() {
                        let QueuedJob { id, job, ctl } = qj;
                        if ctl.is_cancelled() {
                            lock_or_recover(&registry).remove(&id);
                            let _ = ev_tx
                                .send(JobEvent::Cancelled { job_id: id, points_emitted: 0 });
                            continue;
                        }
                        // a panicking solve (divergent fit, violated
                        // penalty regime, ...) is surfaced as a Failed
                        // event; the worker survives to run the rest of
                        // the batch
                        let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(
                            || run_job(id, job, &ctl, &cache, &ev_tx, &queue),
                        ));
                        match res {
                            // preempted path: its registry entry stays
                            // live for cancellation until it resumes
                            Ok(RunOutcome::Requeued) => {}
                            Ok(RunOutcome::Terminal) => {
                                lock_or_recover(&registry).remove(&id);
                            }
                            Err(payload) => {
                                lock_or_recover(&registry).remove(&id);
                                let _ = ev_tx.send(JobEvent::Failed {
                                    job_id: id,
                                    message: panic_message(payload),
                                });
                            }
                        }
                    }
                    // last worker out signals liveness loss before the
                    // event channel closes
                    if alive.fetch_sub(1, Ordering::SeqCst) == 1 {
                        let _ = ev_tx.send(JobEvent::SchedulerDown);
                    }
                })
            })
            .collect();
        let _kernel_budget = register_solver_workers(n_workers);
        Self {
            queue,
            events: ev_rx,
            workers,
            next_id: AtomicU64::new(0),
            cache,
            registry,
            workers_alive,
            _kernel_budget,
        }
    }

    /// Submit any [`Job`] with default policy (batch, no deadline).
    pub fn submit(&self, job: Job) -> u64 {
        self.submit_with(job, JobPolicy::default()).0
    }

    /// Submit with an explicit [`JobPolicy`]; returns the job id and its
    /// control block (for out-of-band cancellation).
    pub fn submit_with(&self, job: Job, policy: JobPolicy) -> (u64, Arc<JobCtl>) {
        let id = self.next_id.fetch_add(1, Ordering::SeqCst);
        let ctl = Arc::new(JobCtl::new(&policy));
        lock_or_recover(&self.registry).insert(id, Arc::clone(&ctl));
        self.queue.push(QueuedJob { id, job, ctl: Arc::clone(&ctl) });
        (id, ctl)
    }

    /// Submit a single fit.
    pub fn submit_fit(
        &self,
        dataset: Arc<Dataset>,
        spec: Box<dyn FitSpec>,
        opts: SolverOpts,
    ) -> u64 {
        self.submit(Job::Fit { dataset, spec, opts })
    }

    /// Submit a warm-started path sweep (one worker, streamed points).
    pub fn submit_path(
        &self,
        dataset: Arc<Dataset>,
        spec: Box<dyn FitSpec>,
        ratios: Vec<f64>,
        opts: SolverOpts,
    ) -> u64 {
        self.submit(Job::Path { dataset, spec, ratios, opts })
    }

    /// Request cancellation of a queued or running job. Returns false
    /// when the job already reached a terminal event (or never existed).
    /// Cancellation is cooperative: a running solve stops within one
    /// outer iteration, a path within one λ point, and the job's
    /// terminal event is [`JobEvent::Cancelled`].
    pub fn cancel(&self, job_id: u64) -> bool {
        match lock_or_recover(&self.registry).get(&job_id) {
            Some(ctl) => {
                ctl.cancel();
                true
            }
            None => false,
        }
    }

    /// Jobs queued or running (registry size — drops to zero as terminal
    /// events are emitted). The service's admission control polls this.
    pub fn pending(&self) -> usize {
        lock_or_recover(&self.registry).len()
    }

    /// Jobs waiting in the queues (not yet picked up by a worker).
    pub fn queue_depth(&self) -> usize {
        self.queue.depth()
    }

    /// Workers currently alive (fault observability).
    pub fn workers_alive(&self) -> usize {
        self.workers_alive.load(Ordering::SeqCst)
    }

    /// Fault injection: make `n` workers exit as soon as they are idle,
    /// *without* draining the queues first — queued jobs orphan, and when
    /// the last worker dies [`JobEvent::SchedulerDown`] is emitted.
    pub fn kill_workers(&self, n: usize) {
        self.queue.request_exit(n, true);
    }

    /// Move the event receiver out (the service's router thread owns it;
    /// the scheduler keeps a closed placeholder).
    pub fn split_events(&mut self) -> Receiver<JobEvent> {
        let (tx, rx) = channel::<JobEvent>();
        drop(tx);
        std::mem::replace(&mut self.events, rx)
    }

    /// Next event, never blocking forever: a closed channel (all workers
    /// gone) maps to [`JobEvent::SchedulerDown`].
    pub fn recv_event(&self) -> JobEvent {
        self.events.recv().unwrap_or(JobEvent::SchedulerDown)
    }

    /// Like [`FitScheduler::recv_event`] with a timeout (`None` = no
    /// event yet).
    pub fn recv_event_timeout(&self, timeout: Duration) -> Option<JobEvent> {
        match self.events.recv_timeout(timeout) {
            Ok(e) => Some(e),
            Err(RecvTimeoutError::Timeout) => None,
            Err(RecvTimeoutError::Disconnected) => Some(JobEvent::SchedulerDown),
        }
    }

    /// Block until `count` events arrive (any kind, completion order).
    ///
    /// Counting caveat: a path job that fails mid-sweep emits fewer
    /// events than `n_points + 1` (its terminal event is
    /// [`JobEvent::Failed`]) — size an expected count only from jobs you
    /// know cannot fail, or drain `self.events` with a terminal-event
    /// loop instead.
    pub fn collect_events(&self, count: usize) -> Vec<JobEvent> {
        // lint: allow(panic-audit, documented contract: panics when all workers died; test/bench helper, not on the service path)
        (0..count).map(|_| self.events.recv().expect("worker died")).collect()
    }

    /// Block until `count` single-fit outcomes arrive. Panics if a path
    /// event interleaves (use [`FitScheduler::collect_events`] for mixed
    /// workloads) or a job failed — the failure's original panic message
    /// is included.
    pub fn collect_fits(&self, count: usize) -> Vec<FitOutcome> {
        self.collect_events(count)
            .into_iter()
            .map(|e| match e {
                JobEvent::FitDone(o) => o,
                JobEvent::Failed { job_id, message } => {
                    // lint: allow(panic-audit, documented contract: re-raises the job's original panic; test/bench helper, not on the service path)
                    panic!("job {job_id} failed on its worker: {message}")
                }
                // lint: allow(panic-audit, documented contract: mixed workloads must use collect_events)
                other => panic!(
                    "collect_fits saw a path event (job {}); use collect_events",
                    other.job_id()
                ),
            })
            .collect()
    }

    /// The shared dataset/coefficient cache (stats, tests).
    pub fn cache(&self) -> &DatasetCache {
        &self.cache
    }

    /// Shared handle to the cache (service tenant accounting).
    pub fn cache_arc(&self) -> Arc<DatasetCache> {
        Arc::clone(&self.cache)
    }

    /// Graceful shutdown: queued jobs finish, then workers exit. Safe to
    /// call with jobs in flight even when their events are never read —
    /// workers ignore send failures on a dropped receiver.
    pub fn shutdown(self) {
        self.queue.request_exit(self.workers.len(), false);
        for w in self.workers {
            let _ = w.join();
        }
    }
}

enum RunOutcome {
    Terminal,
    Requeued,
}

fn run_job(
    id: u64,
    job: Job,
    ctl: &Arc<JobCtl>,
    cache: &DatasetCache,
    out: &Sender<JobEvent>,
    queue: &Arc<JobQueue>,
) -> RunOutcome {
    match job {
        Job::Fit { dataset, spec, opts } => {
            run_fit(id, &dataset, spec, &opts, ctl, cache, out);
            RunOutcome::Terminal
        }
        Job::Path { dataset, spec, mut ratios, opts } => {
            // warm starts flow from high λ (sparse) to low λ (dense)
            ratios.sort_by(|a, b| b.partial_cmp(a).unwrap_or(std::cmp::Ordering::Equal));
            let entry = cache.design_entry(&dataset, spec.normalize_design());
            let lambda_max = spec.lambda_max(entry.design(), &dataset.y);
            let mut state = ContinuationState::default();
            // one Gram store for the whole sweep AND for sibling jobs:
            // blocks computed at λᵢ are exactly reusable at λᵢ₊₁
            state.gram = Some(Arc::clone(&entry.gram));
            let rs = PathResume {
                dataset,
                spec,
                ratios,
                lambda_max,
                next_index: 0,
                state,
                total_epochs: 0,
                emitted: 0,
                elapsed_before: 0.0,
                opts,
            };
            run_path_segment(id, rs, ctl, cache, out, queue)
        }
        Job::PathResume(rs) => run_path_segment(id, *rs, ctl, cache, out, queue),
    }
}

fn run_fit(
    id: u64,
    dataset: &Arc<Dataset>,
    spec: Box<dyn FitSpec>,
    opts: &SolverOpts,
    ctl: &Arc<JobCtl>,
    cache: &DatasetCache,
    out: &Sender<JobEvent>,
) {
    let t0 = Instant::now();
    let normalize = spec.normalize_design();
    let entry = cache.design_entry(dataset, normalize);
    let design = entry.design();
    let mut state = ContinuationState::default();
    // hand the solve the per-design Gram store: blocks assembled by this
    // job are reused by every later job on the same (dataset, norm) entry
    state.gram = Some(Arc::clone(&entry.gram));
    let mut warm_started = false;
    if spec.is_convex() {
        if let Some((_lambda, beta)) =
            cache.warm_coef(dataset, normalize, spec.datafit_name(), spec.family())
        {
            state.beta = Some(beta);
            warm_started = true;
        }
    }
    let opts = ctl.solver_opts(opts);
    let result =
        spec.solve(design, &dataset.y, &opts, &mut state, Some(&entry.col_sq_norms), None);
    if ctl.is_cancelled() {
        let _ = out.send(JobEvent::Cancelled { job_id: id, points_emitted: 0 });
        return;
    }
    if spec.is_convex() {
        cache.store_coef(
            dataset,
            normalize,
            spec.datafit_name(),
            spec.family(),
            spec.lambda(),
            &result.beta,
        );
    }
    let timed_out = !result.converged && ctl.deadline_exceeded();
    let _ = out.send(JobEvent::FitDone(FitOutcome {
        job_id: id,
        label: spec.label(),
        lambda: spec.lambda(),
        result,
        wall_time: t0.elapsed().as_secs_f64(),
        warm_started,
        timed_out,
    }));
    // Gram blocks grew *during* the solve; re-check the byte budget now
    // rather than waiting for the next cache insert
    cache.enforce_budget_now();
}

/// The remainder of a path sweep: everything a worker needs to continue
/// from `next_index` with warm starts intact after a preemption.
pub struct PathResume {
    dataset: Arc<Dataset>,
    spec: Box<dyn FitSpec>,
    /// full grid, sorted descending
    ratios: Vec<f64>,
    lambda_max: f64,
    next_index: usize,
    state: ContinuationState,
    total_epochs: usize,
    /// points streamed so far
    emitted: usize,
    /// wall time spent in earlier segments
    elapsed_before: f64,
    opts: SolverOpts,
}

fn run_path_segment(
    id: u64,
    mut rs: PathResume,
    ctl: &Arc<JobCtl>,
    cache: &DatasetCache,
    out: &Sender<JobEvent>,
    queue: &Arc<JobQueue>,
) -> RunOutcome {
    let seg0 = Instant::now();
    let normalize = rs.spec.normalize_design();
    let entry = cache.design_entry(&rs.dataset, normalize);
    let design = entry.design();
    let n_planned = rs.ratios.len();
    let opts = ctl.solver_opts(&rs.opts);
    let beta_true = if rs.dataset.beta_true.is_empty() {
        None
    } else {
        Some(rs.dataset.beta_true.clone())
    };
    // screening support is λ-independent; decide once for the sweep
    let gap_screened = rs.spec.supports_gap_screening();
    // one scratch workspace for the segment (buffer-reuse satellite):
    // xtr / residual / mask / score buffers live across λ points
    let mut screen_work = ScreenWorkspace::new();

    while rs.next_index < n_planned {
        if ctl.is_cancelled() {
            let _ = out.send(JobEvent::Cancelled { job_id: id, points_emitted: rs.emitted });
            return RunOutcome::Terminal;
        }
        if ctl.deadline_exceeded() {
            let _ = out.send(JobEvent::PathDone(path_summary(id, &rs, seg0, true)));
            cache.enforce_budget_now();
            return RunOutcome::Terminal;
        }
        // cooperative preemption: a batch sweep yields between λ points
        // whenever interactive work is waiting; the remainder requeues at
        // the front of the batch queue with its warm state intact
        if ctl.priority() == Priority::Batch && queue.interactive_waiting() {
            rs.elapsed_before += seg0.elapsed().as_secs_f64();
            let ctl = Arc::clone(ctl);
            queue.push_resume_front(QueuedJob { id, job: Job::PathResume(Box::new(rs)), ctl });
            return RunOutcome::Requeued;
        }

        let index = rs.next_index;
        // lint: allow(panic-audit, next_index stays below ratios.len by the PathResume invariant re-established before every requeue)
        let ratio = rs.ratios[index];
        let pt0 = Instant::now();
        let lambda = rs.lambda_max * ratio;

        // Gap-safe screening runs *inside* the solve for specs that
        // support it (quadratic × ℓ1): the mask is rebuilt per λ — a λᵢ
        // certificate is invalid at λᵢ₊₁ < λᵢ — and tightens as the gap
        // shrinks. What persists between points is the ContinuationState
        // (warm β + working-set size).
        let (result, n_screened) = if gap_screened {
            solve_lasso_screened_warm_with(
                design,
                &rs.dataset.y,
                lambda,
                &opts,
                &mut rs.state,
                Some(&entry.col_sq_norms),
                &mut screen_work,
            )
        } else {
            let point_spec = rs.spec.at_lambda(lambda);
            let r = point_spec.solve(
                design,
                &rs.dataset.y,
                &opts,
                &mut rs.state,
                Some(&entry.col_sq_norms),
                None,
            );
            (r, 0)
        };
        rs.total_epochs += result.n_epochs;
        if ctl.is_cancelled() {
            // the cancel landed mid-solve: drop the partial point
            let _ = out.send(JobEvent::Cancelled { job_id: id, points_emitted: rs.emitted });
            return RunOutcome::Terminal;
        }
        // a deadline that fired mid-solve still yields a well-formed
        // partial point (finite objective + certificate); emit it, then
        // the timed-out terminal
        let interrupted = !result.converged && ctl.deadline_exceeded();

        // Metrics vs. ground truth are computed in ORIGINAL coordinates:
        // for normalized specs the solve ran on X·diag(s), so the
        // original-design coefficients are s ⊙ β and the prediction uses
        // the dataset's own design.
        let support_size = result.support().len();
        let (recovery, est, pred) = match beta_true.as_deref() {
            None => (None, None, None),
            Some(bt) => {
                let rescaled: Option<Vec<f64>> = entry.scales.as_ref().map(|scales| {
                    result.beta.iter().zip(scales.iter()).map(|(b, s)| b * s).collect()
                });
                let metric_beta: &[f64] = rescaled.as_deref().unwrap_or(&result.beta);
                let metric_design: &crate::linalg::Design =
                    if rescaled.is_some() { &rs.dataset.design } else { design };
                (
                    Some(support_recovery(metric_beta, bt, 1e-8)),
                    Some(estimation_error(metric_beta, bt)),
                    Some(prediction_mse(metric_design, metric_beta, bt)),
                )
            }
        };
        let point = PathPoint {
            lambda,
            lambda_ratio: ratio,
            objective: result.objective,
            support_size,
            recovery,
            estimation_error: est,
            prediction_mse: pred,
            beta: result.beta,
        };
        let _ = out.send(JobEvent::PathPoint(PathPointOutcome {
            job_id: id,
            index,
            point,
            epochs: result.n_epochs,
            n_screened,
            wall_time: pt0.elapsed().as_secs_f64(),
            kkt: result.kkt,
            converged: result.converged,
            certificate: result.certificate,
        }));
        rs.emitted += 1;
        rs.next_index += 1;
        if interrupted {
            let _ = out.send(JobEvent::PathDone(path_summary(id, &rs, seg0, true)));
            cache.enforce_budget_now();
            return RunOutcome::Terminal;
        }
    }

    // seed future single fits on this dataset with the densest solution
    if rs.spec.is_convex() {
        if let Some(beta) = &rs.state.beta {
            cache.store_coef(
                &rs.dataset,
                normalize,
                rs.spec.datafit_name(),
                rs.spec.family(),
                rs.lambda_max * rs.ratios.last().copied().unwrap_or(1.0),
                beta,
            );
        }
    }
    let _ = out.send(JobEvent::PathDone(path_summary(id, &rs, seg0, false)));
    // the sweep's Gram blocks count against the cache budget; enforce it
    // at job completion (stores grow during solves, not at insert time)
    cache.enforce_budget_now();
    RunOutcome::Terminal
}

fn path_summary(id: u64, rs: &PathResume, seg0: Instant, timed_out: bool) -> PathSummary {
    PathSummary {
        job_id: id,
        label: rs.spec.label(),
        n_points: rs.emitted,
        n_planned: rs.ratios.len(),
        total_epochs: rs.total_epochs,
        total_time: rs.elapsed_before + seg0.elapsed().as_secs_f64(),
        timed_out,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::job::specs;
    use crate::data::{correlated, CorrelatedSpec};
    use crate::estimators::linear::quadratic_lambda_max;
    use crate::estimators::Lasso;

    fn dataset(seed: u64) -> Arc<Dataset> {
        Arc::new(correlated(
            CorrelatedSpec { n: 60, p: 80, rho: 0.4, nnz: 5, snr: 10.0 },
            seed,
        ))
    }

    #[test]
    fn sweep_over_lambda_completes() {
        let ds = dataset(0);
        let lam_max = quadratic_lambda_max(&ds.design, &ds.y);
        let sched = FitScheduler::start(2);
        for k in 1..=6 {
            sched.submit_fit(
                Arc::clone(&ds),
                specs::lasso(lam_max / (2.0 * k as f64)),
                SolverOpts::default(),
            );
        }
        let mut outcomes = sched.collect_fits(6);
        sched.shutdown();
        assert_eq!(outcomes.len(), 6);
        outcomes.sort_by_key(|o| o.job_id);
        // smaller lambda (later ids) -> larger support
        let first = outcomes.first().unwrap().result.support().len();
        let last = outcomes.last().unwrap().result.support().len();
        assert!(last >= first);
        for o in &outcomes {
            assert!(o.result.converged);
            assert!(o.wall_time >= 0.0);
            assert!(!o.timed_out);
        }
    }

    #[test]
    fn mixed_trait_jobs() {
        let ds = dataset(1);
        let lam = quadratic_lambda_max(&ds.design, &ds.y) / 10.0;
        let sched = FitScheduler::start(2);
        sched.submit_fit(Arc::clone(&ds), specs::lasso(lam), SolverOpts::default());
        sched.submit_fit(Arc::clone(&ds), specs::elastic_net(lam, 0.5), SolverOpts::default());
        sched.submit_fit(Arc::clone(&ds), specs::mcp(lam, 3.0), SolverOpts::default());
        let outcomes = sched.collect_fits(3);
        sched.shutdown();
        assert_eq!(outcomes.len(), 3);
        let labels: Vec<String> = outcomes.iter().map(|o| o.label.clone()).collect();
        for l in ["quadratic/l1", "quadratic/l1l2", "quadratic/mcp"] {
            assert!(labels.iter().any(|x| x == l), "missing {l} in {labels:?}");
        }
    }

    #[test]
    fn coefficient_cache_warm_starts_second_convex_fit() {
        let ds = dataset(2);
        let lam_max = quadratic_lambda_max(&ds.design, &ds.y);
        let sched = FitScheduler::start(1);
        let opts = SolverOpts::default().with_tol(1e-10);
        sched.submit_fit(Arc::clone(&ds), specs::lasso(lam_max / 5.0), opts.clone());
        let first = sched.collect_fits(1);
        assert!(!first[0].warm_started);
        sched.submit_fit(Arc::clone(&ds), specs::lasso(lam_max / 7.0), opts.clone());
        let second = sched.collect_fits(1);
        assert!(second[0].warm_started, "second lasso fit should reuse cached coefficients");
        // warm start must not change the optimum
        let reference = Lasso::new(lam_max / 7.0).with_tol(1e-10).fit(&ds.design, &ds.y);
        assert!((second[0].result.objective - reference.objective).abs() < 1e-8);
        let stats = sched.cache().stats();
        assert!(stats.design_hits >= 1);
        assert_eq!(stats.coef_hits, 1);
        sched.shutdown();
    }

    #[test]
    fn non_convex_fits_never_reuse_coefficients() {
        let ds = dataset(3);
        let lam = quadratic_lambda_max(&ds.design, &ds.y) / 8.0;
        let sched = FitScheduler::start(1);
        sched.submit_fit(Arc::clone(&ds), specs::mcp(lam, 3.0), SolverOpts::default());
        sched.submit_fit(Arc::clone(&ds), specs::mcp(lam / 2.0, 3.0), SolverOpts::default());
        let outcomes = sched.collect_fits(2);
        sched.shutdown();
        assert!(outcomes.iter().all(|o| !o.warm_started));
    }

    #[test]
    fn shutdown_without_jobs() {
        let sched = FitScheduler::start(3);
        sched.shutdown(); // must not hang
    }

    /// A spec whose solve panics — stands in for a divergent fit.
    struct PanicSpec;
    impl crate::coordinator::job::FitSpec for PanicSpec {
        fn label(&self) -> String {
            "panic/test".into()
        }
        fn datafit_name(&self) -> &'static str {
            "panic"
        }
        fn family(&self) -> &'static str {
            "test"
        }
        fn lambda(&self) -> f64 {
            0.1
        }
        fn is_convex(&self) -> bool {
            false // keep it away from the coefficient cache
        }
        fn normalize_design(&self) -> bool {
            false
        }
        fn lambda_max(&self, _d: &crate::linalg::Design, _y: &[f64]) -> f64 {
            1.0
        }
        fn at_lambda(&self, _l: f64) -> Box<dyn crate::coordinator::job::FitSpec> {
            Box::new(PanicSpec)
        }
        fn solve(
            &self,
            _design: &crate::linalg::Design,
            _y: &[f64],
            _opts: &SolverOpts,
            _state: &mut ContinuationState,
            _col_sq_norms: Option<&[f64]>,
            _frozen: Option<&[bool]>,
        ) -> crate::solver::FitResult {
            panic!("synthetic divergence: step outside the valid regime");
        }
    }

    #[test]
    fn worker_panic_surfaces_as_failed_event_and_batch_survives() {
        let ds = dataset(5);
        let lam = quadratic_lambda_max(&ds.design, &ds.y) / 10.0;
        let sched = FitScheduler::start(1); // one worker: it must survive
        let bad = sched.submit_fit(Arc::clone(&ds), Box::new(PanicSpec), SolverOpts::default());
        let good = sched.submit_fit(Arc::clone(&ds), specs::lasso(lam), SolverOpts::default());
        let events = sched.collect_events(2);
        let mut saw_failed = false;
        let mut saw_done = false;
        for e in events {
            match e {
                JobEvent::Failed { job_id, message } => {
                    assert_eq!(job_id, bad);
                    assert!(
                        message.contains("synthetic divergence"),
                        "original panic message lost: {message:?}"
                    );
                    saw_failed = true;
                }
                JobEvent::FitDone(o) => {
                    assert_eq!(o.job_id, good);
                    assert!(o.result.converged);
                    saw_done = true;
                }
                _ => panic!("unexpected event"),
            }
        }
        assert!(saw_failed && saw_done, "one divergent fit must not take down the batch");
        sched.shutdown();
    }

    /// Delegating spec that sleeps before every solve — deterministic
    /// slowness for cancellation/deadline/preemption tests.
    struct SlowSpec {
        inner: Box<dyn FitSpec>,
        ms: u64,
    }
    impl FitSpec for SlowSpec {
        fn label(&self) -> String {
            self.inner.label()
        }
        fn datafit_name(&self) -> &'static str {
            self.inner.datafit_name()
        }
        fn family(&self) -> &'static str {
            self.inner.family()
        }
        fn lambda(&self) -> f64 {
            self.inner.lambda()
        }
        fn is_convex(&self) -> bool {
            false
        }
        fn normalize_design(&self) -> bool {
            self.inner.normalize_design()
        }
        fn lambda_max(&self, d: &crate::linalg::Design, y: &[f64]) -> f64 {
            self.inner.lambda_max(d, y)
        }
        fn at_lambda(&self, lambda: f64) -> Box<dyn FitSpec> {
            Box::new(SlowSpec { inner: self.inner.at_lambda(lambda), ms: self.ms })
        }
        fn solve(
            &self,
            design: &crate::linalg::Design,
            y: &[f64],
            opts: &SolverOpts,
            state: &mut ContinuationState,
            col_sq_norms: Option<&[f64]>,
            frozen: Option<&[bool]>,
        ) -> FitResult {
            std::thread::sleep(Duration::from_millis(self.ms));
            self.inner.solve(design, y, opts, state, col_sq_norms, frozen)
        }
    }

    fn slow_lasso(lam: f64, ms: u64) -> Box<dyn FitSpec> {
        Box::new(SlowSpec { inner: specs::lasso(lam), ms })
    }

    #[test]
    fn cancel_stops_path_within_one_point_and_frees_worker() {
        let ds = dataset(6);
        let sched = FitScheduler::start(1);
        let ratios: Vec<f64> = (1..=32).map(|k| 1.0 / (k as f64 + 1.0)).collect();
        let (path_id, _ctl) = sched.submit_with(
            Job::Path {
                dataset: Arc::clone(&ds),
                spec: slow_lasso(1.0, 25),
                ratios,
                opts: SolverOpts::default(),
            },
            JobPolicy::default(),
        );
        // wait for the first streamed point, then cancel
        match sched.recv_event_timeout(Duration::from_secs(30)) {
            Some(JobEvent::PathPoint(p)) => assert_eq!(p.job_id, path_id),
            other => panic!("expected first PathPoint, got {:?}", other.map(|e| e.job_id())),
        }
        assert!(sched.cancel(path_id));
        let mut extra_points = 0;
        loop {
            match sched.recv_event_timeout(Duration::from_secs(30)) {
                Some(JobEvent::PathPoint(_)) => extra_points += 1,
                Some(JobEvent::Cancelled { job_id, points_emitted }) => {
                    assert_eq!(job_id, path_id);
                    assert_eq!(points_emitted, 1 + extra_points);
                    break;
                }
                other => panic!("unexpected event {:?}", other.map(|e| e.job_id())),
            }
        }
        assert!(
            extra_points <= 1,
            "cancelled path must stop within one λ point, saw {extra_points} more"
        );
        // the worker is free again: a fresh fit completes
        let lam = quadratic_lambda_max(&ds.design, &ds.y) / 10.0;
        sched.submit_fit(Arc::clone(&ds), specs::lasso(lam), SolverOpts::default());
        match sched.recv_event_timeout(Duration::from_secs(30)) {
            Some(JobEvent::FitDone(o)) => assert!(o.result.converged),
            other => panic!("worker wedged after cancel: {:?}", other.map(|e| e.job_id())),
        }
        sched.shutdown();
    }

    #[test]
    fn deadline_returns_partial_path_with_certificate() {
        let ds = dataset(7);
        let sched = FitScheduler::start(1);
        let ratios: Vec<f64> = (1..=16).map(|k| 1.0 / (k as f64 + 1.0)).collect();
        let deadline = Instant::now() + Duration::from_millis(90);
        let (job_id, _ctl) = sched.submit_with(
            Job::Path {
                dataset: Arc::clone(&ds),
                spec: slow_lasso(1.0, 40),
                ratios,
                opts: SolverOpts::default(),
            },
            JobPolicy::default().with_deadline(deadline),
        );
        let mut points = 0;
        loop {
            match sched.recv_event_timeout(Duration::from_secs(30)) {
                Some(JobEvent::PathPoint(p)) => {
                    assert!(p.point.objective.is_finite(), "partial point objective not finite");
                    assert!(p.kkt.is_finite(), "partial point certificate not finite");
                    points += 1;
                }
                Some(JobEvent::PathDone(s)) => {
                    assert_eq!(s.job_id, job_id);
                    assert!(s.timed_out, "deadline-bounded sweep must report timed_out");
                    assert_eq!(s.n_points, points);
                    assert_eq!(s.n_planned, 16);
                    assert!(s.n_points < 16, "sweep should have been cut short");
                    break;
                }
                other => panic!("unexpected event {:?}", other.map(|e| e.job_id())),
            }
        }
        sched.shutdown();
    }

    #[test]
    fn interactive_fit_preempts_batch_path_between_points() {
        let ds = dataset(8);
        let lam = quadratic_lambda_max(&ds.design, &ds.y) / 10.0;
        let sched = FitScheduler::start(1); // single worker forces preemption
        let ratios: Vec<f64> = (1..=12).map(|k| 1.0 / (k as f64 + 1.0)).collect();
        let n_points = ratios.len();
        let (path_id, _) = sched.submit_with(
            Job::Path {
                dataset: Arc::clone(&ds),
                spec: slow_lasso(1.0, 20),
                ratios,
                opts: SolverOpts::default(),
            },
            JobPolicy::default(),
        );
        // let the sweep start, then inject an interactive fit
        std::thread::sleep(Duration::from_millis(50));
        let (fit_id, _) = sched.submit_with(
            Job::Fit {
                dataset: Arc::clone(&ds),
                spec: specs::lasso(lam),
                opts: SolverOpts::default(),
            },
            JobPolicy::interactive(),
        );
        let mut order = Vec::new();
        let mut indices = Vec::new();
        let mut terminals = 0;
        while terminals < 2 {
            match sched.recv_event_timeout(Duration::from_secs(60)) {
                Some(JobEvent::PathPoint(p)) => {
                    assert_eq!(p.job_id, path_id);
                    indices.push(p.index);
                }
                Some(JobEvent::FitDone(o)) => {
                    assert_eq!(o.job_id, fit_id);
                    order.push("fit");
                    terminals += 1;
                }
                Some(JobEvent::PathDone(s)) => {
                    assert_eq!(s.job_id, path_id);
                    assert!(!s.timed_out);
                    assert_eq!(s.n_points, n_points, "preempted sweep must still finish");
                    order.push("path");
                    terminals += 1;
                }
                other => panic!("unexpected event {:?}", other.map(|e| e.job_id())),
            }
        }
        assert_eq!(
            order,
            vec!["fit", "path"],
            "interactive fit must complete before the batch sweep"
        );
        // every λ index exactly once, in order, across the preemption
        assert_eq!(indices, (0..n_points).collect::<Vec<_>>());
        sched.shutdown();
    }

    #[test]
    fn cancel_while_queued_never_runs() {
        let ds = dataset(9);
        let sched = FitScheduler::start(1);
        // occupy the single worker
        let ratios: Vec<f64> = (1..=8).map(|k| 1.0 / (k as f64 + 1.0)).collect();
        let (path_id, _) = sched.submit_with(
            Job::Path {
                dataset: Arc::clone(&ds),
                spec: slow_lasso(1.0, 25),
                ratios,
                opts: SolverOpts::default(),
            },
            JobPolicy::default(),
        );
        // queue an interactive fit and cancel it before it can start
        let (queued_id, _) = sched.submit_with(
            Job::Fit {
                dataset: Arc::clone(&ds),
                spec: Box::new(PanicSpec), // would fail loudly if it ever ran
                opts: SolverOpts::default(),
            },
            JobPolicy::interactive(),
        );
        assert!(sched.cancel(queued_id));
        sched.cancel(path_id);
        let mut saw_queued_cancel = false;
        let mut terminals = 0;
        while terminals < 2 {
            match sched.recv_event_timeout(Duration::from_secs(30)) {
                Some(JobEvent::Cancelled { job_id, points_emitted }) => {
                    if job_id == queued_id {
                        assert_eq!(points_emitted, 0);
                        saw_queued_cancel = true;
                    }
                    terminals += 1;
                }
                Some(JobEvent::PathPoint(_)) => {}
                Some(JobEvent::PathDone(_)) | Some(JobEvent::FitDone(_)) => terminals += 1,
                Some(JobEvent::Failed { message, .. }) => {
                    panic!("cancelled queued job ran anyway: {message}")
                }
                other => panic!("unexpected event {:?}", other.map(|e| e.job_id())),
            }
        }
        assert!(saw_queued_cancel);
        sched.shutdown();
    }

    #[test]
    fn killed_workers_surface_scheduler_down() {
        let sched = FitScheduler::start(2);
        assert_eq!(sched.workers_alive(), 2);
        sched.kill_workers(2);
        match sched.recv_event_timeout(Duration::from_secs(30)) {
            Some(JobEvent::SchedulerDown) => {}
            other => panic!("expected SchedulerDown, got {:?}", other.map(|e| e.job_id())),
        }
        assert_eq!(sched.workers_alive(), 0);
        // the channel is closed now; recv_event keeps reporting down
        // instead of blocking or panicking
        assert!(matches!(sched.recv_event(), JobEvent::SchedulerDown));
        // submitting into a dead pool must not panic (the service layer
        // rejects before this point; the queue just holds the job)
        let ds = dataset(10);
        sched.submit_fit(Arc::clone(&ds), specs::lasso(0.5), SolverOpts::default());
        sched.shutdown();
    }
}
