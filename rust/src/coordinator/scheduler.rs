//! Path-aware fit scheduler: a leader/worker queue over trait-based
//! [`FitSpec`] jobs with completion-order result streaming.
//!
//! Replaces the old closed-enum `SolveService`. Two job shapes:
//!
//! - [`Job::Fit`] — one (spec, λ) solve. Convex specs warm-start from the
//!   coefficient cache when a previous job solved the same
//!   (dataset, datafit, family).
//! - [`Job::Path`] — a whole λ grid swept **on one worker** with
//!   warm-started coefficients and persistent working-set size between
//!   points ([`crate::solver::ContinuationState`]), plus a per-λ gap-safe
//!   screening pass for specs that support it. Each solved point streams
//!   back immediately as [`JobEvent::PathPoint`] — callers see the path
//!   fill in completion order rather than waiting for the sweep.
//!
//! Results stream back over a channel in completion order, every event
//! tagged with its job id; jobs from different callers interleave freely.
//! Built on std::sync::mpsc since tokio is unavailable offline.

use super::cache::DatasetCache;
use super::job::FitSpec;
use crate::data::Dataset;
use crate::estimators::path::PathPoint;
use crate::linalg::parallel::{register_solver_workers, SolverWorkersGuard};
use crate::metrics::{estimation_error, prediction_mse, support_recovery};
use crate::solver::screening::{solve_lasso_screened_warm_with, ScreenWorkspace};
use crate::solver::{ContinuationState, FitResult, SolverOpts};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

/// A schedulable unit of work.
pub enum Job {
    /// One fit at a fixed λ.
    Fit { dataset: Arc<Dataset>, spec: Box<dyn FitSpec>, opts: SolverOpts },
    /// A warm-started sweep over `ratios · λ_max` (sorted descending
    /// internally — warm starts flow from high λ to low).
    Path { dataset: Arc<Dataset>, spec: Box<dyn FitSpec>, ratios: Vec<f64>, opts: SolverOpts },
}

/// A completed single fit.
pub struct FitOutcome {
    pub job_id: u64,
    pub label: String,
    pub lambda: f64,
    pub result: FitResult,
    pub wall_time: f64,
    /// true when the coefficient cache seeded the solve
    pub warm_started: bool,
}

/// One solved point of a path job, streamed as soon as it finishes.
pub struct PathPointOutcome {
    pub job_id: u64,
    /// position in the (descending) ratio grid
    pub index: usize,
    pub point: PathPoint,
    pub epochs: usize,
    /// features certified inactive by the gap-safe pass at this λ
    pub n_screened: usize,
    pub wall_time: f64,
    /// the solve's final optimality violation at this λ (`certificate`
    /// names the metric) — conformance oracles check it against the
    /// declared tolerance instead of re-deriving KKT residuals
    pub kkt: f64,
    pub converged: bool,
    pub certificate: crate::solver::Certificate,
}

/// Terminal event of a path job.
pub struct PathSummary {
    pub job_id: u64,
    pub label: String,
    pub n_points: usize,
    pub total_epochs: usize,
    pub total_time: f64,
}

/// Everything the scheduler streams back, tagged with its job id.
pub enum JobEvent {
    FitDone(FitOutcome),
    PathPoint(PathPointOutcome),
    PathDone(PathSummary),
    /// The job's solve panicked on its worker. The worker caught the
    /// panic and keeps serving the queue — one divergent fit cannot take
    /// down a mixed batch — and the original panic message is preserved
    /// here instead of being lost to a dead thread.
    ///
    /// `Failed` is the job's **terminal** event: a path job that fails
    /// mid-sweep emits its points so far, then `Failed`, and **no**
    /// `PathDone` — consumers must count job-terminal events
    /// (`FitDone`/`PathDone`/`Failed`), not a fixed per-point total, or
    /// they will block forever on a failed sweep (see `skglm serve`).
    Failed { job_id: u64, message: String },
}

impl JobEvent {
    pub fn job_id(&self) -> u64 {
        match self {
            JobEvent::FitDone(o) => o.job_id,
            JobEvent::PathPoint(o) => o.job_id,
            JobEvent::PathDone(s) => s.job_id,
            JobEvent::Failed { job_id, .. } => *job_id,
        }
    }
}

/// Best-effort extraction of a panic payload's message (`&str` and
/// `String` payloads cover `panic!`/`assert!`/`expect`). Shared with the
/// experiment pool ([`crate::coordinator::pool::run_parallel`]).
pub(crate) fn panic_message(payload: Box<dyn std::any::Any + Send>) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

enum Msg {
    Job(u64, Job),
    Shutdown,
}

/// The scheduler: submit jobs, stream events, shut down cleanly.
pub struct FitScheduler {
    tx: Sender<Msg>,
    /// Completion-order event stream.
    pub events: Receiver<JobEvent>,
    workers: Vec<JoinHandle<()>>,
    next_id: u64,
    cache: Arc<DatasetCache>,
    /// Registers the worker count against the kernel-engine thread budget
    /// for the scheduler's lifetime: each job's kernels then get
    /// `budget / workers` threads, so kernel × worker parallelism never
    /// oversubscribes the machine. Released on shutdown/drop.
    _kernel_budget: SolverWorkersGuard,
}

impl FitScheduler {
    /// Spawn `n_workers` solver threads (at least one).
    pub fn start(n_workers: usize) -> Self {
        let (tx, rx) = channel::<Msg>();
        let rx = Arc::new(Mutex::new(rx));
        let (ev_tx, ev_rx) = channel::<JobEvent>();
        let cache = Arc::new(DatasetCache::new());
        let workers = (0..n_workers.max(1))
            .map(|_| {
                let rx = Arc::clone(&rx);
                let ev_tx = ev_tx.clone();
                let cache = Arc::clone(&cache);
                std::thread::spawn(move || loop {
                    let msg = {
                        let guard = rx.lock().unwrap();
                        guard.recv()
                    };
                    match msg {
                        Ok(Msg::Job(id, job)) => {
                            // a panicking solve (divergent fit, violated
                            // penalty regime, ...) is surfaced as a Failed
                            // event; the worker survives to run the rest
                            // of the batch
                            let res = std::panic::catch_unwind(
                                std::panic::AssertUnwindSafe(|| {
                                    run_job(id, job, &cache, &ev_tx)
                                }),
                            );
                            if let Err(payload) = res {
                                let _ = ev_tx.send(JobEvent::Failed {
                                    job_id: id,
                                    message: panic_message(payload),
                                });
                            }
                        }
                        Ok(Msg::Shutdown) | Err(_) => break,
                    }
                })
            })
            .collect();
        let _kernel_budget = register_solver_workers(n_workers.max(1));
        Self { tx, events: ev_rx, workers, next_id: 0, cache, _kernel_budget }
    }

    /// Submit any [`Job`]; returns its id.
    pub fn submit(&mut self, job: Job) -> u64 {
        let id = self.next_id;
        self.next_id += 1;
        self.tx.send(Msg::Job(id, job)).expect("scheduler is down");
        id
    }

    /// Submit a single fit.
    pub fn submit_fit(
        &mut self,
        dataset: Arc<Dataset>,
        spec: Box<dyn FitSpec>,
        opts: SolverOpts,
    ) -> u64 {
        self.submit(Job::Fit { dataset, spec, opts })
    }

    /// Submit a warm-started path sweep (one worker, streamed points).
    pub fn submit_path(
        &mut self,
        dataset: Arc<Dataset>,
        spec: Box<dyn FitSpec>,
        ratios: Vec<f64>,
        opts: SolverOpts,
    ) -> u64 {
        self.submit(Job::Path { dataset, spec, ratios, opts })
    }

    /// Block until `count` events arrive (any kind, completion order).
    ///
    /// Counting caveat: a path job that fails mid-sweep emits fewer
    /// events than `n_points + 1` (its terminal event is
    /// [`JobEvent::Failed`]) — size an expected count only from jobs you
    /// know cannot fail, or drain `self.events` with a terminal-event
    /// loop instead.
    pub fn collect_events(&self, count: usize) -> Vec<JobEvent> {
        (0..count).map(|_| self.events.recv().expect("worker died")).collect()
    }

    /// Block until `count` single-fit outcomes arrive. Panics if a path
    /// event interleaves (use [`FitScheduler::collect_events`] for mixed
    /// workloads) or a job failed — the failure's original panic message
    /// is included.
    pub fn collect_fits(&self, count: usize) -> Vec<FitOutcome> {
        self.collect_events(count)
            .into_iter()
            .map(|e| match e {
                JobEvent::FitDone(o) => o,
                JobEvent::Failed { job_id, message } => {
                    panic!("job {job_id} failed on its worker: {message}")
                }
                other => panic!(
                    "collect_fits saw a path event (job {}); use collect_events",
                    other.job_id()
                ),
            })
            .collect()
    }

    /// The shared dataset/coefficient cache (stats, tests).
    pub fn cache(&self) -> &DatasetCache {
        &self.cache
    }

    /// Graceful shutdown: queued jobs finish, then workers exit. Safe to
    /// call with jobs in flight even when their events are never read —
    /// workers ignore send failures on a dropped receiver.
    pub fn shutdown(self) {
        for _ in &self.workers {
            let _ = self.tx.send(Msg::Shutdown);
        }
        for w in self.workers {
            let _ = w.join();
        }
    }
}

fn run_job(id: u64, job: Job, cache: &DatasetCache, out: &Sender<JobEvent>) {
    match job {
        Job::Fit { dataset, spec, opts } => run_fit(id, &dataset, spec, &opts, cache, out),
        Job::Path { dataset, spec, ratios, opts } => {
            run_path(id, &dataset, spec, ratios, &opts, cache, out)
        }
    }
}

fn run_fit(
    id: u64,
    dataset: &Arc<Dataset>,
    spec: Box<dyn FitSpec>,
    opts: &SolverOpts,
    cache: &DatasetCache,
    out: &Sender<JobEvent>,
) {
    let t0 = Instant::now();
    let normalize = spec.normalize_design();
    let entry = cache.design_entry(dataset, normalize);
    let design = entry.design();
    let mut state = ContinuationState::default();
    // hand the solve the per-design Gram store: blocks assembled by this
    // job are reused by every later job on the same (dataset, norm) entry
    state.gram = Some(Arc::clone(&entry.gram));
    let mut warm_started = false;
    if spec.is_convex() {
        if let Some((_lambda, beta)) =
            cache.warm_coef(dataset, normalize, spec.datafit_name(), spec.family())
        {
            state.beta = Some(beta);
            warm_started = true;
        }
    }
    let result =
        spec.solve(design, &dataset.y, opts, &mut state, Some(&entry.col_sq_norms), None);
    if spec.is_convex() {
        cache.store_coef(
            dataset,
            normalize,
            spec.datafit_name(),
            spec.family(),
            spec.lambda(),
            &result.beta,
        );
    }
    let _ = out.send(JobEvent::FitDone(FitOutcome {
        job_id: id,
        label: spec.label(),
        lambda: spec.lambda(),
        result,
        wall_time: t0.elapsed().as_secs_f64(),
        warm_started,
    }));
    // Gram blocks grew *during* the solve; re-check the byte budget now
    // rather than waiting for the next cache insert
    cache.enforce_budget_now();
}

fn run_path(
    id: u64,
    dataset: &Arc<Dataset>,
    spec: Box<dyn FitSpec>,
    mut ratios: Vec<f64>,
    opts: &SolverOpts,
    cache: &DatasetCache,
    out: &Sender<JobEvent>,
) {
    let t0 = Instant::now();
    let normalize = spec.normalize_design();
    let entry = cache.design_entry(dataset, normalize);
    let design = entry.design();
    let y = &dataset.y;
    let lambda_max = spec.lambda_max(design, y);
    // warm starts flow from high λ (sparse) to low λ (dense)
    ratios.sort_by(|a, b| b.partial_cmp(a).unwrap_or(std::cmp::Ordering::Equal));
    let beta_true =
        if dataset.beta_true.is_empty() { None } else { Some(dataset.beta_true.as_slice()) };
    let mut state = ContinuationState::default();
    // one Gram store for the whole sweep AND for sibling jobs: blocks
    // computed at λᵢ are exactly reusable at λᵢ₊₁ (incremental growth)
    state.gram = Some(Arc::clone(&entry.gram));
    let mut total_epochs = 0;
    // screening support is λ-independent; decide once for the sweep
    let gap_screened = spec.supports_gap_screening();
    // one scratch workspace for the whole sweep (buffer-reuse satellite):
    // xtr / residual / mask / score buffers live across λ points
    let mut screen_work = ScreenWorkspace::new();

    for (index, &ratio) in ratios.iter().enumerate() {
        let pt0 = Instant::now();
        let lambda = lambda_max * ratio;

        // Gap-safe screening runs *inside* the solve for specs that
        // support it (quadratic × ℓ1): the mask is rebuilt per λ — a λᵢ
        // certificate is invalid at λᵢ₊₁ < λᵢ — and tightens as the gap
        // shrinks. What persists between points is the ContinuationState
        // (warm β + working-set size).
        let (result, n_screened) = if gap_screened {
            solve_lasso_screened_warm_with(
                design,
                y,
                lambda,
                opts,
                &mut state,
                Some(&entry.col_sq_norms),
                &mut screen_work,
            )
        } else {
            let point_spec = spec.at_lambda(lambda);
            let r = point_spec.solve(design, y, opts, &mut state, Some(&entry.col_sq_norms), None);
            (r, 0)
        };
        total_epochs += result.n_epochs;

        // Metrics vs. ground truth are computed in ORIGINAL coordinates:
        // for normalized specs the solve ran on X·diag(s), so the
        // original-design coefficients are s ⊙ β and the prediction uses
        // the dataset's own design.
        let support_size = result.support().len();
        let (recovery, est, pred) = match beta_true {
            None => (None, None, None),
            Some(bt) => {
                let rescaled: Option<Vec<f64>> = entry.scales.as_ref().map(|scales| {
                    result.beta.iter().zip(scales.iter()).map(|(b, s)| b * s).collect()
                });
                let metric_beta: &[f64] = rescaled.as_deref().unwrap_or(&result.beta);
                let metric_design: &crate::linalg::Design =
                    if rescaled.is_some() { &dataset.design } else { design };
                (
                    Some(support_recovery(metric_beta, bt, 1e-8)),
                    Some(estimation_error(metric_beta, bt)),
                    Some(prediction_mse(metric_design, metric_beta, bt)),
                )
            }
        };
        let point = PathPoint {
            lambda,
            lambda_ratio: ratio,
            objective: result.objective,
            support_size,
            recovery,
            estimation_error: est,
            prediction_mse: pred,
            beta: result.beta,
        };
        let _ = out.send(JobEvent::PathPoint(PathPointOutcome {
            job_id: id,
            index,
            point,
            epochs: result.n_epochs,
            n_screened,
            wall_time: pt0.elapsed().as_secs_f64(),
            kkt: result.kkt,
            converged: result.converged,
            certificate: result.certificate,
        }));
    }

    // seed future single fits on this dataset with the densest solution
    if spec.is_convex() {
        if let Some(beta) = &state.beta {
            cache.store_coef(
                dataset,
                normalize,
                spec.datafit_name(),
                spec.family(),
                lambda_max * ratios.last().copied().unwrap_or(1.0),
                beta,
            );
        }
    }
    let _ = out.send(JobEvent::PathDone(PathSummary {
        job_id: id,
        label: spec.label(),
        n_points: ratios.len(),
        total_epochs,
        total_time: t0.elapsed().as_secs_f64(),
    }));
    // the sweep's Gram blocks count against the cache budget; enforce it
    // at job completion (stores grow during solves, not at insert time)
    cache.enforce_budget_now();
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::job::specs;
    use crate::data::{correlated, CorrelatedSpec};
    use crate::estimators::linear::quadratic_lambda_max;
    use crate::estimators::Lasso;

    fn dataset(seed: u64) -> Arc<Dataset> {
        Arc::new(correlated(
            CorrelatedSpec { n: 60, p: 80, rho: 0.4, nnz: 5, snr: 10.0 },
            seed,
        ))
    }

    #[test]
    fn sweep_over_lambda_completes() {
        let ds = dataset(0);
        let lam_max = quadratic_lambda_max(&ds.design, &ds.y);
        let mut sched = FitScheduler::start(2);
        for k in 1..=6 {
            sched.submit_fit(
                Arc::clone(&ds),
                specs::lasso(lam_max / (2.0 * k as f64)),
                SolverOpts::default(),
            );
        }
        let mut outcomes = sched.collect_fits(6);
        sched.shutdown();
        assert_eq!(outcomes.len(), 6);
        outcomes.sort_by_key(|o| o.job_id);
        // smaller lambda (later ids) -> larger support
        let first = outcomes.first().unwrap().result.support().len();
        let last = outcomes.last().unwrap().result.support().len();
        assert!(last >= first);
        for o in &outcomes {
            assert!(o.result.converged);
            assert!(o.wall_time >= 0.0);
        }
    }

    #[test]
    fn mixed_trait_jobs() {
        let ds = dataset(1);
        let lam = quadratic_lambda_max(&ds.design, &ds.y) / 10.0;
        let mut sched = FitScheduler::start(2);
        sched.submit_fit(Arc::clone(&ds), specs::lasso(lam), SolverOpts::default());
        sched.submit_fit(Arc::clone(&ds), specs::elastic_net(lam, 0.5), SolverOpts::default());
        sched.submit_fit(Arc::clone(&ds), specs::mcp(lam, 3.0), SolverOpts::default());
        let outcomes = sched.collect_fits(3);
        sched.shutdown();
        assert_eq!(outcomes.len(), 3);
        let labels: Vec<String> = outcomes.iter().map(|o| o.label.clone()).collect();
        for l in ["quadratic/l1", "quadratic/l1l2", "quadratic/mcp"] {
            assert!(labels.iter().any(|x| x == l), "missing {l} in {labels:?}");
        }
    }

    #[test]
    fn coefficient_cache_warm_starts_second_convex_fit() {
        let ds = dataset(2);
        let lam_max = quadratic_lambda_max(&ds.design, &ds.y);
        let mut sched = FitScheduler::start(1);
        let opts = SolverOpts::default().with_tol(1e-10);
        sched.submit_fit(Arc::clone(&ds), specs::lasso(lam_max / 5.0), opts.clone());
        let first = sched.collect_fits(1);
        assert!(!first[0].warm_started);
        sched.submit_fit(Arc::clone(&ds), specs::lasso(lam_max / 7.0), opts.clone());
        let second = sched.collect_fits(1);
        assert!(second[0].warm_started, "second lasso fit should reuse cached coefficients");
        // warm start must not change the optimum
        let reference = Lasso::new(lam_max / 7.0).with_tol(1e-10).fit(&ds.design, &ds.y);
        assert!((second[0].result.objective - reference.objective).abs() < 1e-8);
        let stats = sched.cache().stats();
        assert!(stats.design_hits >= 1);
        assert_eq!(stats.coef_hits, 1);
        sched.shutdown();
    }

    #[test]
    fn non_convex_fits_never_reuse_coefficients() {
        let ds = dataset(3);
        let lam = quadratic_lambda_max(&ds.design, &ds.y) / 8.0;
        let mut sched = FitScheduler::start(1);
        sched.submit_fit(Arc::clone(&ds), specs::mcp(lam, 3.0), SolverOpts::default());
        sched.submit_fit(Arc::clone(&ds), specs::mcp(lam / 2.0, 3.0), SolverOpts::default());
        let outcomes = sched.collect_fits(2);
        sched.shutdown();
        assert!(outcomes.iter().all(|o| !o.warm_started));
    }

    #[test]
    fn shutdown_without_jobs() {
        let sched = FitScheduler::start(3);
        sched.shutdown(); // must not hang
    }

    /// A spec whose solve panics — stands in for a divergent fit.
    struct PanicSpec;
    impl crate::coordinator::job::FitSpec for PanicSpec {
        fn label(&self) -> String {
            "panic/test".into()
        }
        fn datafit_name(&self) -> &'static str {
            "panic"
        }
        fn family(&self) -> &'static str {
            "test"
        }
        fn lambda(&self) -> f64 {
            0.1
        }
        fn is_convex(&self) -> bool {
            false // keep it away from the coefficient cache
        }
        fn normalize_design(&self) -> bool {
            false
        }
        fn lambda_max(&self, _d: &crate::linalg::Design, _y: &[f64]) -> f64 {
            1.0
        }
        fn at_lambda(&self, _l: f64) -> Box<dyn crate::coordinator::job::FitSpec> {
            Box::new(PanicSpec)
        }
        fn solve(
            &self,
            _design: &crate::linalg::Design,
            _y: &[f64],
            _opts: &SolverOpts,
            _state: &mut ContinuationState,
            _col_sq_norms: Option<&[f64]>,
            _frozen: Option<&[bool]>,
        ) -> crate::solver::FitResult {
            panic!("synthetic divergence: step outside the valid regime");
        }
    }

    #[test]
    fn worker_panic_surfaces_as_failed_event_and_batch_survives() {
        let ds = dataset(5);
        let lam = quadratic_lambda_max(&ds.design, &ds.y) / 10.0;
        let mut sched = FitScheduler::start(1); // one worker: it must survive
        let bad = sched.submit_fit(Arc::clone(&ds), Box::new(PanicSpec), SolverOpts::default());
        let good = sched.submit_fit(Arc::clone(&ds), specs::lasso(lam), SolverOpts::default());
        let events = sched.collect_events(2);
        let mut saw_failed = false;
        let mut saw_done = false;
        for e in events {
            match e {
                JobEvent::Failed { job_id, message } => {
                    assert_eq!(job_id, bad);
                    assert!(
                        message.contains("synthetic divergence"),
                        "original panic message lost: {message:?}"
                    );
                    saw_failed = true;
                }
                JobEvent::FitDone(o) => {
                    assert_eq!(o.job_id, good);
                    assert!(o.result.converged);
                    saw_done = true;
                }
                _ => panic!("unexpected event"),
            }
        }
        assert!(saw_failed && saw_done, "one divergent fit must not take down the batch");
        sched.shutdown();
    }
}
