//! Path-aware fit scheduler: a leader/worker queue over trait-based
//! [`FitSpec`] jobs with completion-order result streaming, priority
//! classes, cooperative cancellation and per-job deadlines.
//!
//! Replaces the old closed-enum `SolveService`. Two job shapes:
//!
//! - [`Job::Fit`] — one (spec, λ) solve. Convex specs warm-start from the
//!   coefficient cache when a previous job solved the same
//!   (dataset, datafit, family).
//! - [`Job::Path`] — a whole λ grid swept **on one worker** with
//!   warm-started coefficients and persistent working-set size between
//!   points ([`crate::solver::ContinuationState`]), plus a per-λ gap-safe
//!   screening pass for specs that support it. Each solved point streams
//!   back immediately as [`JobEvent::PathPoint`] — callers see the path
//!   fill in completion order rather than waiting for the sweep.
//!
//! Robustness policy (the production service rides on these):
//!
//! - **Priorities** ([`Priority`]): interactive jobs are always popped
//!   before batch jobs, and a *running* batch path cooperatively yields
//!   at λ-point granularity when interactive work is waiting — the
//!   remainder of the sweep is requeued as [`Job::PathResume`] with its
//!   warm [`ContinuationState`] intact, so no work is lost.
//! - **Cancellation** ([`FitScheduler::cancel`]): raises a flag that the
//!   solver polls between outer iterations (via
//!   [`crate::solver::SolveBudget`]) and the path loop polls between λ
//!   points; a cancelled job frees its worker within one λ point and
//!   emits [`JobEvent::Cancelled`] as its terminal event.
//! - **Deadlines** ([`JobPolicy::deadline`]): a deadline-exceeded solve
//!   stops cooperatively and still reports a finite partial objective
//!   with its optimality [`crate::solver::Certificate`]; the terminal
//!   event carries `timed_out = true`.
//! - **Liveness** ([`JobEvent::SchedulerDown`]): the last worker to exit
//!   (graceful shutdown or fault-injected death) emits a terminal
//!   `SchedulerDown`, so consumers never block forever on a dead pool.
//!
//! Results stream back over a channel in completion order, every event
//! tagged with its job id; jobs from different callers interleave freely.
//! Built on std::sync::mpsc since tokio is unavailable offline.

use super::cache::DatasetCache;
use super::job::FitSpec;
use crate::data::Dataset;
use crate::util::{lock_or_recover, wait_or_recover};
use crate::estimators::path::PathPoint;
use crate::linalg::parallel::{register_solver_workers, SolverWorkersGuard};
use crate::metrics::{estimation_error, prediction_mse, support_recovery};
use crate::solver::screening::{solve_lasso_screened_warm_with, ScreenWorkspace};
use crate::solver::{
    solve_batch, BatchFit, ContinuationState, FitResult, SolverOpts, StopReason,
};
use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// A schedulable unit of work.
pub enum Job {
    /// One fit at a fixed λ.
    Fit { dataset: Arc<Dataset>, spec: Box<dyn FitSpec>, opts: SolverOpts },
    /// A warm-started sweep over `ratios · λ_max` (sorted descending
    /// internally — warm starts flow from high λ to low).
    Path { dataset: Arc<Dataset>, spec: Box<dyn FitSpec>, ratios: Vec<f64>, opts: SolverOpts },
    /// Internal: the remainder of a preempted path sweep, carrying its
    /// warm continuation state. Produced by the worker when a batch path
    /// yields to interactive work; never constructed by callers.
    PathResume(Box<PathResume>),
}

/// Scheduling class. Interactive jobs are popped before batch jobs and
/// preempt running batch paths at λ-point granularity.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum Priority {
    Interactive,
    #[default]
    Batch,
}

/// Per-job scheduling policy (see [`FitScheduler::submit_with`]).
#[derive(Clone, Debug, Default)]
pub struct JobPolicy {
    pub priority: Priority,
    /// Cooperative wall-clock deadline: the job stops within one outer
    /// iteration / λ point of this instant and reports partial results.
    pub deadline: Option<Instant>,
}

impl JobPolicy {
    pub fn interactive() -> Self {
        Self { priority: Priority::Interactive, deadline: None }
    }
    pub fn with_deadline(mut self, deadline: Instant) -> Self {
        self.deadline = Some(deadline);
        self
    }
}

/// Shared per-job control block: the cancellation flag (also handed to
/// the solver via [`crate::solver::SolveBudget`]), the deadline, and the
/// priority class.
#[derive(Debug)]
pub struct JobCtl {
    cancel: Arc<AtomicBool>,
    deadline: Option<Instant>,
    priority: Priority,
}

impl JobCtl {
    fn new(policy: &JobPolicy) -> Self {
        Self {
            cancel: Arc::new(AtomicBool::new(false)),
            deadline: policy.deadline,
            priority: policy.priority,
        }
    }

    pub fn cancel(&self) {
        // relaxed is sound: the flag is the entire message — cancellation
        // is cooperative polling, no other data is published through it
        self.cancel.store(true, Ordering::Relaxed);
    }
    pub fn is_cancelled(&self) -> bool {
        self.cancel.load(Ordering::Relaxed)
    }
    pub fn deadline_exceeded(&self) -> bool {
        self.deadline.is_some_and(|d| Instant::now() >= d)
    }
    pub fn priority(&self) -> Priority {
        self.priority
    }

    /// The raw cancellation flag — handed to a fused batch member so a
    /// single member retires (freeing its panel column) without touching
    /// its siblings.
    pub(crate) fn cancel_flag(&self) -> Arc<AtomicBool> {
        Arc::clone(&self.cancel)
    }

    /// The job's wall-clock deadline, if any (fused batch members carry
    /// it individually).
    pub(crate) fn deadline(&self) -> Option<Instant> {
        self.deadline
    }

    /// Clone `base` with this job's budget (deadline + cancel flag)
    /// merged in; caller-provided budget fields win.
    fn solver_opts(&self, base: &SolverOpts) -> SolverOpts {
        let mut opts = base.clone();
        let mut budget = opts.budget.take().unwrap_or_default();
        if budget.deadline.is_none() {
            budget.deadline = self.deadline;
        }
        if budget.cancel.is_none() {
            budget.cancel = Some(Arc::clone(&self.cancel));
        }
        opts.budget = Some(budget);
        opts
    }
}

/// A completed single fit.
pub struct FitOutcome {
    pub job_id: u64,
    pub label: String,
    pub lambda: f64,
    pub result: FitResult,
    pub wall_time: f64,
    /// true when the coefficient cache seeded the solve
    pub warm_started: bool,
    /// true when the job's deadline stopped the solve before convergence;
    /// `result` then holds the partial iterate with its certificate
    pub timed_out: bool,
}

/// One solved point of a path job, streamed as soon as it finishes.
pub struct PathPointOutcome {
    pub job_id: u64,
    /// position in the (descending) ratio grid
    pub index: usize,
    pub point: PathPoint,
    pub epochs: usize,
    /// features certified inactive by the gap-safe pass at this λ
    pub n_screened: usize,
    pub wall_time: f64,
    /// the solve's final optimality violation at this λ (`certificate`
    /// names the metric) — conformance oracles check it against the
    /// declared tolerance instead of re-deriving KKT residuals
    pub kkt: f64,
    pub converged: bool,
    pub certificate: crate::solver::Certificate,
}

/// Terminal event of a path job.
pub struct PathSummary {
    pub job_id: u64,
    pub label: String,
    /// points actually emitted (== `n_planned` unless the job timed out)
    pub n_points: usize,
    /// points the λ grid asked for
    pub n_planned: usize,
    pub total_epochs: usize,
    pub total_time: f64,
    /// true when the deadline cut the sweep short; the emitted points
    /// (including a final partial one with its certificate) still stand
    pub timed_out: bool,
}

/// Everything the scheduler streams back, tagged with its job id.
pub enum JobEvent {
    FitDone(FitOutcome),
    PathPoint(PathPointOutcome),
    PathDone(PathSummary),
    /// The job's solve panicked on its worker. The worker caught the
    /// panic and keeps serving the queue — one divergent fit cannot take
    /// down a mixed batch — and the original panic message is preserved
    /// here instead of being lost to a dead thread.
    ///
    /// `Failed` is the job's **terminal** event: a path job that fails
    /// mid-sweep emits its points so far, then `Failed`, and **no**
    /// `PathDone` — consumers must count job-terminal events
    /// (`FitDone`/`PathDone`/`Failed`/`Cancelled`), not a fixed per-point
    /// total, or they will block forever on a failed sweep.
    Failed { job_id: u64, message: String },
    /// Terminal event of a cancelled job. A cancelled path stops within
    /// one λ point; `points_emitted` counts the `PathPoint`s that were
    /// streamed before the cancellation landed (0 for fits and for jobs
    /// cancelled while still queued).
    Cancelled { job_id: u64, points_emitted: usize },
    /// The last worker exited (graceful shutdown or fault-injected
    /// death): no further events will ever arrive. Consumers must treat
    /// this as terminal for every outstanding job instead of blocking on
    /// `events.recv()` forever.
    SchedulerDown,
}

impl JobEvent {
    /// Job id carried by the event; [`JobEvent::SchedulerDown`] is not
    /// job-scoped and reports `u64::MAX`.
    pub fn job_id(&self) -> u64 {
        match self {
            JobEvent::FitDone(o) => o.job_id,
            JobEvent::PathPoint(o) => o.job_id,
            JobEvent::PathDone(s) => s.job_id,
            JobEvent::Failed { job_id, .. } => *job_id,
            JobEvent::Cancelled { job_id, .. } => *job_id,
            JobEvent::SchedulerDown => u64::MAX,
        }
    }

    /// Is this the last event the job will ever emit?
    pub fn is_terminal(&self) -> bool {
        !matches!(self, JobEvent::PathPoint(_))
    }
}

/// Best-effort extraction of a panic payload's message (`&str` and
/// `String` payloads cover `panic!`/`assert!`/`expect`). Shared with the
/// experiment pool ([`crate::coordinator::pool::run_parallel`]).
pub(crate) fn panic_message(payload: Box<dyn std::any::Any + Send>) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

/// Most sibling fits one batched job will absorb (lead + 31): panel
/// memory grows linearly in B and the per-pass kernel win saturates well
/// before this.
const MAX_BATCH_FUSE: usize = 32;

/// Scheduler-side many-fit fusion counters. All counters are monotone
/// event/work tallies updated with `Ordering::Relaxed`: each one is an
/// independent statistic — no other data is published through them, and
/// readers only ever want a (possibly slightly stale) snapshot, so no
/// ordering edge is needed.
#[derive(Default)]
struct FusionCounters {
    /// fused batched jobs executed (each coalesces ≥ 2 sibling fits)
    batched_jobs: AtomicU64,
    /// member fits those batched jobs carried
    batched_fits: AtomicU64,
    /// modelled flops spent in multi-RHS panel passes, over all batches
    panel_flops: AtomicU64,
    /// total modelled flops of those batched solves (panel ratio base)
    total_flops: AtomicU64,
    /// modelled flops executed at reduced (f32/mixed) precision — kept
    /// apart because scalar-f64 and vector-f32 flops are not comparable
    reduced_precision_flops: AtomicU64,
}

impl FusionCounters {
    fn record(&self, n_members: usize, profile: &crate::solver::InnerProfile) {
        // relaxed throughout: monotone counters, no publication (struct-level note)
        self.batched_jobs.fetch_add(1, Ordering::Relaxed);
        self.batched_fits.fetch_add(n_members as u64, Ordering::Relaxed);
        self.panel_flops.fetch_add(profile.panel_flops as u64, Ordering::Relaxed);
        self.total_flops.fetch_add(profile.total_flops() as u64, Ordering::Relaxed);
        if profile.precision != crate::linalg::Precision::F64 {
            self.reduced_precision_flops
                .fetch_add(profile.total_flops() as u64, Ordering::Relaxed);
        }
    }

    /// Record one fused *path* job: `n_members` sweeps coalesced, with
    /// flops accumulated across every λ point's batched solve.
    /// `reduced` marks flops executed at f32/mixed precision.
    fn record_path(&self, n_members: usize, panel_flops: f64, total_flops: f64, reduced: bool) {
        // relaxed throughout: monotone counters, no publication (struct-level note)
        self.batched_jobs.fetch_add(1, Ordering::Relaxed);
        self.batched_fits.fetch_add(n_members as u64, Ordering::Relaxed);
        self.panel_flops.fetch_add(panel_flops as u64, Ordering::Relaxed);
        self.total_flops.fetch_add(total_flops as u64, Ordering::Relaxed);
        if reduced {
            self.reduced_precision_flops.fetch_add(total_flops as u64, Ordering::Relaxed);
        }
    }

    fn snapshot(&self) -> FusionStats {
        FusionStats {
            batched_jobs: self.batched_jobs.load(Ordering::Relaxed),
            batched_fits: self.batched_fits.load(Ordering::Relaxed),
            panel_flops: self.panel_flops.load(Ordering::Relaxed),
            total_flops: self.total_flops.load(Ordering::Relaxed),
            reduced_precision_flops: self.reduced_precision_flops.load(Ordering::Relaxed),
            kernel_isa: crate::linalg::simd::isa(),
        }
    }
}

/// Point-in-time snapshot of the scheduler's many-fit fusion activity
/// ([`FitScheduler::fusion_stats`]; surfaced by the service `stats` verb).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct FusionStats {
    /// fused batched jobs executed
    pub batched_jobs: u64,
    /// member fits coalesced into those jobs
    pub batched_fits: u64,
    /// modelled flops in multi-RHS panel passes
    pub panel_flops: u64,
    /// total modelled flops of the batched solves
    pub total_flops: u64,
    /// modelled flops executed at reduced (f32/mixed) precision —
    /// scalar-f64 and vector-f32 flops are not comparable, so the split
    /// travels with the totals
    pub reduced_precision_flops: u64,
    /// effective kernel ISA of this process (labels the flop counters)
    pub kernel_isa: crate::linalg::KernelIsa,
}

impl FusionStats {
    /// Mean members per fused job (0 when nothing fused yet).
    pub fn fits_per_batch(&self) -> f64 {
        if self.batched_jobs == 0 {
            0.0
        } else {
            self.batched_fits as f64 / self.batched_jobs as f64
        }
    }

    /// Share of the batched solves' modelled work done by panel kernels.
    pub fn panel_flop_ratio(&self) -> f64 {
        if self.total_flops == 0 {
            0.0
        } else {
            self.panel_flops as f64 / self.total_flops as f64
        }
    }
}

struct QueuedJob {
    id: u64,
    job: Job,
    ctl: Arc<JobCtl>,
}

#[derive(Default)]
struct QueueState {
    interactive: VecDeque<QueuedJob>,
    batch: VecDeque<QueuedJob>,
    /// workers asked to exit after the queues drain (graceful shutdown)
    graceful_exits: usize,
    /// workers asked to exit immediately (fault injection)
    kill_now: usize,
}

/// Two-class FIFO job queue with condvar wakeups. Interactive beats
/// batch; exit requests are honored immediately (`kill_now`) or only
/// once both queues are empty (`graceful_exits`).
struct JobQueue {
    state: Mutex<QueueState>,
    cv: Condvar,
}

impl JobQueue {
    fn new() -> Self {
        Self { state: Mutex::new(QueueState::default()), cv: Condvar::new() }
    }

    fn push(&self, qj: QueuedJob) {
        let mut st = lock_or_recover(&self.state);
        match qj.ctl.priority() {
            Priority::Interactive => st.interactive.push_back(qj),
            Priority::Batch => st.batch.push_back(qj),
        }
        drop(st);
        self.cv.notify_one();
    }

    /// Requeue a preempted path remainder at the *front* of the batch
    /// queue: it resumes as soon as interactive work drains, ahead of
    /// batch jobs that were submitted after it started.
    fn push_resume_front(&self, qj: QueuedJob) {
        let mut st = lock_or_recover(&self.state);
        st.batch.push_front(qj);
        drop(st);
        self.cv.notify_one();
    }

    /// Block for the next job; `None` means "this worker should exit".
    fn pop_blocking(&self) -> Option<QueuedJob> {
        let mut st = lock_or_recover(&self.state);
        loop {
            if st.kill_now > 0 {
                st.kill_now -= 1;
                return None;
            }
            if let Some(j) = st.interactive.pop_front() {
                return Some(j);
            }
            if let Some(j) = st.batch.pop_front() {
                return Some(j);
            }
            if st.graceful_exits > 0 {
                st.graceful_exits -= 1;
                return None;
            }
            st = wait_or_recover(&self.cv, st);
        }
    }

    fn interactive_waiting(&self) -> bool {
        !lock_or_recover(&self.state).interactive.is_empty()
    }

    /// Pop every queued batch-priority `Job::Fit` fusible with a lead fit
    /// on (`dataset`, `normalize`, `opts`) — up to `cap` — preserving the
    /// queue order of everything else. Fusible means: same cached
    /// `DesignEntry` (pointer-identical dataset + same normalization), a
    /// batchable spec, and solver knobs identical to the lead's (one
    /// `SolverOpts` drives the whole batched solve; per-member deadlines
    /// and cancel flags ride on the `BatchFit`s instead).
    fn take_siblings(
        &self,
        dataset: &Arc<Dataset>,
        normalize: bool,
        opts: &SolverOpts,
        cap: usize,
    ) -> Vec<QueuedJob> {
        let mut taken = Vec::new();
        let mut st = lock_or_recover(&self.state);
        let mut kept = VecDeque::with_capacity(st.batch.len());
        while let Some(qj) = st.batch.pop_front() {
            if taken.len() < cap && is_fusible_sibling(&qj, dataset, normalize, opts) {
                taken.push(qj);
            } else {
                kept.push_back(qj);
            }
        }
        st.batch = kept;
        taken
    }

    /// Pop every queued batch-priority `Job::Path` fusible with a lead
    /// sweep on (`dataset`, `normalize`, `opts`, `ratios`) — up to `cap` —
    /// preserving the queue order of everything else. On top of the fit
    /// fusion key ([`JobQueue::take_siblings`]) a path sibling must also
    /// sweep the *same ratio grid*, so the fused runner can advance every
    /// member in λ-lockstep with one batched solve per point.
    fn take_path_siblings(
        &self,
        dataset: &Arc<Dataset>,
        normalize: bool,
        opts: &SolverOpts,
        ratios: &[f64],
        cap: usize,
    ) -> Vec<QueuedJob> {
        let mut taken = Vec::new();
        let mut st = lock_or_recover(&self.state);
        let mut kept = VecDeque::with_capacity(st.batch.len());
        while let Some(qj) = st.batch.pop_front() {
            if taken.len() < cap && is_fusible_path_sibling(&qj, dataset, normalize, opts, ratios)
            {
                taken.push(qj);
            } else {
                kept.push_back(qj);
            }
        }
        st.batch = kept;
        taken
    }

    fn depth(&self) -> usize {
        let st = lock_or_recover(&self.state);
        st.interactive.len() + st.batch.len()
    }

    fn request_exit(&self, n: usize, immediate: bool) {
        let mut st = lock_or_recover(&self.state);
        if immediate {
            st.kill_now += n;
        } else {
            st.graceful_exits += n;
        }
        drop(st);
        self.cv.notify_all();
    }
}

/// Can `qj` join a fused batch led by a fit on (`dataset`, `normalize`,
/// `opts`)? See [`JobQueue::take_siblings`].
fn is_fusible_sibling(
    qj: &QueuedJob,
    dataset: &Arc<Dataset>,
    normalize: bool,
    opts: &SolverOpts,
) -> bool {
    match &qj.job {
        Job::Fit { dataset: ds, spec, opts: jopts } => {
            Arc::ptr_eq(ds, dataset)
                && spec.normalize_design() == normalize
                && spec.batch_penalty().is_some()
                && fusible_opts(opts, jopts)
        }
        _ => false,
    }
}

/// Can `qj` join a fused batched *path* led by a sweep on (`dataset`,
/// `normalize`, `opts`) over `lead_ratios` (sorted descending)? See
/// [`JobQueue::take_path_siblings`].
fn is_fusible_path_sibling(
    qj: &QueuedJob,
    dataset: &Arc<Dataset>,
    normalize: bool,
    opts: &SolverOpts,
    lead_ratios: &[f64],
) -> bool {
    match &qj.job {
        Job::Path { dataset: ds, spec, ratios, opts: jopts } => {
            Arc::ptr_eq(ds, dataset)
                && spec.normalize_design() == normalize
                && spec.batch_penalty().is_some()
                && fusible_opts(opts, jopts)
                && same_grid(lead_ratios, ratios)
        }
        _ => false,
    }
}

/// Exact (bitwise) grid equality after sorting `other` descending — the
/// lead's grid is already sorted when fusion is attempted. Fused members
/// advance in λ-lockstep, so approximate grid matches are not fusible.
fn same_grid(sorted_desc: &[f64], other: &[f64]) -> bool {
    if sorted_desc.len() != other.len() {
        return false;
    }
    let mut o = other.to_vec();
    o.sort_by(|a, b| b.partial_cmp(a).unwrap_or(std::cmp::Ordering::Equal));
    sorted_desc.iter().zip(&o).all(|(a, b)| a == b)
}

/// One `SolverOpts` drives every member of a batched solve, so siblings
/// must agree on all solver knobs; a caller-provided
/// [`crate::solver::SolveBudget`] cannot be split per member, so only
/// budget-free jobs fuse (per-member deadlines/cancellation come from the
/// [`JobCtl`] instead).
fn fusible_opts(a: &SolverOpts, b: &SolverOpts) -> bool {
    a.budget.is_none()
        && b.budget.is_none()
        && a.max_outer == b.max_outer
        && a.max_epochs == b.max_epochs
        && a.tol == b.tol
        && a.ws_start == b.ws_start
        && a.use_ws == b.use_ws
        && a.anderson_m == b.anderson_m
        && a.inner_tol_ratio == b.inner_tol_ratio
        && a.inner == b.inner
        && a.precision == b.precision
}

/// The scheduler: submit jobs, stream events, cancel, shut down cleanly.
pub struct FitScheduler {
    queue: Arc<JobQueue>,
    /// Completion-order event stream.
    pub events: Receiver<JobEvent>,
    workers: Vec<JoinHandle<()>>,
    next_id: AtomicU64,
    cache: Arc<DatasetCache>,
    /// Control blocks of queued + running jobs (removed at terminal emit).
    registry: Arc<Mutex<HashMap<u64, Arc<JobCtl>>>>,
    /// Many-fit fusion counters (monotone, Relaxed — see [`FusionStats`]).
    fusion: Arc<FusionCounters>,
    /// Workers still alive (the last one to exit emits `SchedulerDown`).
    workers_alive: Arc<AtomicUsize>,
    /// Registers the worker count against the kernel-engine thread budget
    /// for the scheduler's lifetime: each job's kernels then get
    /// `budget / workers` threads, so kernel × worker parallelism never
    /// oversubscribes the machine. Released on shutdown/drop.
    _kernel_budget: SolverWorkersGuard,
}

impl FitScheduler {
    /// Spawn `n_workers` solver threads (at least one).
    pub fn start(n_workers: usize) -> Self {
        Self::start_with_cache(n_workers, Arc::new(DatasetCache::new()))
    }

    /// Spawn with an explicit (e.g. budget-restricted) dataset cache —
    /// the service uses this to wire tenant byte budgets into the LRU.
    pub fn start_with_cache(n_workers: usize, cache: Arc<DatasetCache>) -> Self {
        let n_workers = n_workers.max(1);
        let queue = Arc::new(JobQueue::new());
        let (ev_tx, ev_rx) = channel::<JobEvent>();
        let registry: Arc<Mutex<HashMap<u64, Arc<JobCtl>>>> =
            Arc::new(Mutex::new(HashMap::new()));
        let fusion = Arc::new(FusionCounters::default());
        let workers_alive = Arc::new(AtomicUsize::new(n_workers));
        let workers = (0..n_workers)
            .map(|_| {
                let queue = Arc::clone(&queue);
                let ev_tx = ev_tx.clone();
                let cache = Arc::clone(&cache);
                let registry = Arc::clone(&registry);
                let fusion = Arc::clone(&fusion);
                let alive = Arc::clone(&workers_alive);
                std::thread::spawn(move || {
                    while let Some(qj) = queue.pop_blocking() {
                        let QueuedJob { id, job, ctl } = qj;
                        if ctl.is_cancelled() {
                            lock_or_recover(&registry).remove(&id);
                            let _ = ev_tx
                                .send(JobEvent::Cancelled { job_id: id, points_emitted: 0 });
                            continue;
                        }
                        // a panicking solve (divergent fit, violated
                        // penalty regime, ...) is surfaced as a Failed
                        // event; the worker survives to run the rest of
                        // the batch
                        let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(
                            || run_job(id, job, &ctl, &cache, &ev_tx, &queue, &registry, &fusion),
                        ));
                        match res {
                            // preempted path: its registry entry stays
                            // live for cancellation until it resumes
                            Ok(RunOutcome::Requeued) => {}
                            Ok(RunOutcome::Terminal) => {
                                lock_or_recover(&registry).remove(&id);
                            }
                            Err(payload) => {
                                lock_or_recover(&registry).remove(&id);
                                let _ = ev_tx.send(JobEvent::Failed {
                                    job_id: id,
                                    message: panic_message(payload),
                                });
                            }
                        }
                    }
                    // last worker out signals liveness loss before the
                    // event channel closes
                    if alive.fetch_sub(1, Ordering::SeqCst) == 1 {
                        let _ = ev_tx.send(JobEvent::SchedulerDown);
                    }
                })
            })
            .collect();
        let _kernel_budget = register_solver_workers(n_workers);
        Self {
            queue,
            events: ev_rx,
            workers,
            next_id: AtomicU64::new(0),
            cache,
            registry,
            fusion,
            workers_alive,
            _kernel_budget,
        }
    }

    /// Submit any [`Job`] with default policy (batch, no deadline).
    pub fn submit(&self, job: Job) -> u64 {
        self.submit_with(job, JobPolicy::default()).0
    }

    /// Submit with an explicit [`JobPolicy`]; returns the job id and its
    /// control block (for out-of-band cancellation).
    pub fn submit_with(&self, job: Job, policy: JobPolicy) -> (u64, Arc<JobCtl>) {
        let id = self.next_id.fetch_add(1, Ordering::SeqCst);
        let ctl = Arc::new(JobCtl::new(&policy));
        lock_or_recover(&self.registry).insert(id, Arc::clone(&ctl));
        self.queue.push(QueuedJob { id, job, ctl: Arc::clone(&ctl) });
        (id, ctl)
    }

    /// Submit a single fit.
    pub fn submit_fit(
        &self,
        dataset: Arc<Dataset>,
        spec: Box<dyn FitSpec>,
        opts: SolverOpts,
    ) -> u64 {
        self.submit(Job::Fit { dataset, spec, opts })
    }

    /// Submit a warm-started path sweep (one worker, streamed points).
    pub fn submit_path(
        &self,
        dataset: Arc<Dataset>,
        spec: Box<dyn FitSpec>,
        ratios: Vec<f64>,
        opts: SolverOpts,
    ) -> u64 {
        self.submit(Job::Path { dataset, spec, ratios, opts })
    }

    /// Request cancellation of a queued or running job. Returns false
    /// when the job already reached a terminal event (or never existed).
    /// Cancellation is cooperative: a running solve stops within one
    /// outer iteration, a path within one λ point, and the job's
    /// terminal event is [`JobEvent::Cancelled`].
    pub fn cancel(&self, job_id: u64) -> bool {
        match lock_or_recover(&self.registry).get(&job_id) {
            Some(ctl) => {
                ctl.cancel();
                true
            }
            None => false,
        }
    }

    /// Jobs queued or running (registry size — drops to zero as terminal
    /// events are emitted). The service's admission control polls this.
    pub fn pending(&self) -> usize {
        lock_or_recover(&self.registry).len()
    }

    /// Jobs waiting in the queues (not yet picked up by a worker).
    pub fn queue_depth(&self) -> usize {
        self.queue.depth()
    }

    /// Workers currently alive (fault observability).
    pub fn workers_alive(&self) -> usize {
        self.workers_alive.load(Ordering::SeqCst)
    }

    /// Fault injection: make `n` workers exit as soon as they are idle,
    /// *without* draining the queues first — queued jobs orphan, and when
    /// the last worker dies [`JobEvent::SchedulerDown`] is emitted.
    pub fn kill_workers(&self, n: usize) {
        self.queue.request_exit(n, true);
    }

    /// Move the event receiver out (the service's router thread owns it;
    /// the scheduler keeps a closed placeholder).
    pub fn split_events(&mut self) -> Receiver<JobEvent> {
        let (tx, rx) = channel::<JobEvent>();
        drop(tx);
        std::mem::replace(&mut self.events, rx)
    }

    /// Next event, never blocking forever: a closed channel (all workers
    /// gone) maps to [`JobEvent::SchedulerDown`].
    pub fn recv_event(&self) -> JobEvent {
        self.events.recv().unwrap_or(JobEvent::SchedulerDown)
    }

    /// Like [`FitScheduler::recv_event`] with a timeout (`None` = no
    /// event yet).
    pub fn recv_event_timeout(&self, timeout: Duration) -> Option<JobEvent> {
        match self.events.recv_timeout(timeout) {
            Ok(e) => Some(e),
            Err(RecvTimeoutError::Timeout) => None,
            Err(RecvTimeoutError::Disconnected) => Some(JobEvent::SchedulerDown),
        }
    }

    /// Block until `count` events arrive (any kind, completion order).
    ///
    /// Counting caveat: a path job that fails mid-sweep emits fewer
    /// events than `n_points + 1` (its terminal event is
    /// [`JobEvent::Failed`]) — size an expected count only from jobs you
    /// know cannot fail, or drain `self.events` with a terminal-event
    /// loop instead.
    pub fn collect_events(&self, count: usize) -> Vec<JobEvent> {
        // lint: allow(panic-audit, documented contract: panics when all workers died; test/bench helper, not on the service path)
        (0..count).map(|_| self.events.recv().expect("worker died")).collect()
    }

    /// Block until `count` single-fit outcomes arrive. Panics if a path
    /// event interleaves (use [`FitScheduler::collect_events`] for mixed
    /// workloads) or a job failed — the failure's original panic message
    /// is included.
    pub fn collect_fits(&self, count: usize) -> Vec<FitOutcome> {
        self.collect_events(count)
            .into_iter()
            .map(|e| match e {
                JobEvent::FitDone(o) => o,
                JobEvent::Failed { job_id, message } => {
                    // lint: allow(panic-audit, documented contract: re-raises the job's original panic; test/bench helper, not on the service path)
                    panic!("job {job_id} failed on its worker: {message}")
                }
                // lint: allow(panic-audit, documented contract: mixed workloads must use collect_events)
                other => panic!(
                    "collect_fits saw a path event (job {}); use collect_events",
                    other.job_id()
                ),
            })
            .collect()
    }

    /// Snapshot of the many-fit fusion counters (the service `stats`
    /// verb and `skglm client stats` surface these).
    pub fn fusion_stats(&self) -> FusionStats {
        self.fusion.snapshot()
    }

    /// The shared dataset/coefficient cache (stats, tests).
    pub fn cache(&self) -> &DatasetCache {
        &self.cache
    }

    /// Shared handle to the cache (service tenant accounting).
    pub fn cache_arc(&self) -> Arc<DatasetCache> {
        Arc::clone(&self.cache)
    }

    /// Graceful shutdown: queued jobs finish, then workers exit. Safe to
    /// call with jobs in flight even when their events are never read —
    /// workers ignore send failures on a dropped receiver.
    pub fn shutdown(self) {
        self.queue.request_exit(self.workers.len(), false);
        for w in self.workers {
            let _ = w.join();
        }
    }
}

enum RunOutcome {
    Terminal,
    Requeued,
}

#[allow(clippy::too_many_arguments)]
fn run_job(
    id: u64,
    job: Job,
    ctl: &Arc<JobCtl>,
    cache: &DatasetCache,
    out: &Sender<JobEvent>,
    queue: &Arc<JobQueue>,
    registry: &Mutex<HashMap<u64, Arc<JobCtl>>>,
    fusion: &FusionCounters,
) -> RunOutcome {
    match job {
        Job::Fit { dataset, spec, opts } => {
            // many-fit fusion: a batch-priority batchable fit absorbs
            // every queued sibling on the same DesignEntry into one
            // multi-RHS batched solve (interactive fits stay scalar —
            // fusing would trade their latency for siblings' throughput)
            if ctl.priority() == Priority::Batch
                && opts.budget.is_none()
                && spec.batch_penalty().is_some()
            {
                let siblings = queue.take_siblings(
                    &dataset,
                    spec.normalize_design(),
                    &opts,
                    MAX_BATCH_FUSE - 1,
                );
                if !siblings.is_empty() {
                    run_fit_batch(
                        id, dataset, spec, opts, ctl, siblings, cache, out, registry, fusion,
                    );
                    return RunOutcome::Terminal;
                }
            }
            run_fit(id, &dataset, spec, &opts, ctl, cache, out);
            RunOutcome::Terminal
        }
        Job::Path { dataset, spec, mut ratios, opts } => {
            // warm starts flow from high λ (sparse) to low λ (dense)
            ratios.sort_by(|a, b| b.partial_cmp(a).unwrap_or(std::cmp::Ordering::Equal));
            // many-sweep fusion: a batch-priority batchable path absorbs
            // queued siblings sweeping the same grid on the same
            // DesignEntry; the fused runner advances all of them in
            // λ-lockstep, one multi-RHS batched solve per point
            if ctl.priority() == Priority::Batch
                && opts.budget.is_none()
                && spec.batch_penalty().is_some()
            {
                let siblings = queue.take_path_siblings(
                    &dataset,
                    spec.normalize_design(),
                    &opts,
                    &ratios,
                    MAX_BATCH_FUSE - 1,
                );
                if !siblings.is_empty() {
                    return run_path_batch(
                        id, dataset, spec, ratios, opts, ctl, siblings, cache, out, queue,
                        registry, fusion,
                    );
                }
            }
            let entry = cache.design_entry(&dataset, spec.normalize_design());
            let lambda_max = spec.lambda_max(entry.design(), &dataset.y);
            let mut state = ContinuationState::default();
            // one Gram store for the whole sweep AND for sibling jobs:
            // blocks computed at λᵢ are exactly reusable at λᵢ₊₁
            state.gram = Some(Arc::clone(&entry.gram));
            let rs = PathResume {
                dataset,
                spec,
                ratios,
                lambda_max,
                next_index: 0,
                state,
                total_epochs: 0,
                emitted: 0,
                elapsed_before: 0.0,
                opts,
            };
            run_path_segment(id, rs, ctl, cache, out, queue)
        }
        Job::PathResume(rs) => run_path_segment(id, *rs, ctl, cache, out, queue),
    }
}

fn run_fit(
    id: u64,
    dataset: &Arc<Dataset>,
    spec: Box<dyn FitSpec>,
    opts: &SolverOpts,
    ctl: &Arc<JobCtl>,
    cache: &DatasetCache,
    out: &Sender<JobEvent>,
) {
    let t0 = Instant::now();
    let normalize = spec.normalize_design();
    let entry = cache.design_entry(dataset, normalize);
    let design = entry.design();
    let mut state = ContinuationState::default();
    // hand the solve the per-design Gram store: blocks assembled by this
    // job are reused by every later job on the same (dataset, norm) entry
    state.gram = Some(Arc::clone(&entry.gram));
    let mut warm_started = false;
    if spec.is_convex() {
        if let Some((_lambda, beta)) =
            cache.warm_coef(dataset, normalize, spec.datafit_name(), spec.family())
        {
            state.beta = Some(beta);
            warm_started = true;
        }
    }
    let opts = ctl.solver_opts(opts);
    let result =
        spec.solve(design, &dataset.y, &opts, &mut state, Some(&entry.col_sq_norms), None);
    if ctl.is_cancelled() {
        let _ = out.send(JobEvent::Cancelled { job_id: id, points_emitted: 0 });
        return;
    }
    if spec.is_convex() {
        cache.store_coef(
            dataset,
            normalize,
            spec.datafit_name(),
            spec.family(),
            spec.lambda(),
            &result.beta,
        );
    }
    let timed_out = !result.converged && ctl.deadline_exceeded();
    let _ = out.send(JobEvent::FitDone(FitOutcome {
        job_id: id,
        label: spec.label(),
        lambda: spec.lambda(),
        result,
        wall_time: t0.elapsed().as_secs_f64(),
        warm_started,
        timed_out,
    }));
    // Gram blocks grew *during* the solve; re-check the byte budget now
    // rather than waiting for the next cache insert
    cache.enforce_budget_now();
}

/// One fused batched job: the lead fit plus every sibling
/// [`JobQueue::take_siblings`] pulled off the batch queue, solved as one
/// [`solve_batch`] call over a shared residual panel. Per-job semantics
/// are preserved: each member streams its own terminal [`JobEvent`]
/// (`FitDone`, or `Cancelled` for a member cancelled before or during the
/// solve), cancellation of one member never aborts its siblings, and a
/// member whose deadline fires retires with a partial result and
/// `timed_out = true` while the rest run on.
#[allow(clippy::too_many_arguments)]
fn run_fit_batch(
    lead_id: u64,
    dataset: Arc<Dataset>,
    lead_spec: Box<dyn FitSpec>,
    opts: SolverOpts,
    lead_ctl: &Arc<JobCtl>,
    siblings: Vec<QueuedJob>,
    cache: &DatasetCache,
    out: &Sender<JobEvent>,
    registry: &Mutex<HashMap<u64, Arc<JobCtl>>>,
    fusion: &FusionCounters,
) {
    struct MemberJob {
        id: u64,
        spec: Box<dyn FitSpec>,
        ctl: Arc<JobCtl>,
        warm_started: bool,
        lead: bool,
    }

    let t0 = Instant::now();
    let normalize = lead_spec.normalize_design();
    let entry = cache.design_entry(&dataset, normalize);
    let design = entry.design();

    // roster: lead first, then siblings in queue order; a sibling
    // cancelled while it was still queued terminates here without ever
    // occupying a panel column
    let mut members = vec![MemberJob {
        id: lead_id,
        spec: lead_spec,
        ctl: Arc::clone(lead_ctl),
        warm_started: false,
        lead: true,
    }];
    for qj in siblings {
        let QueuedJob { id, job, ctl } = qj;
        match job {
            Job::Fit { spec, .. } => {
                if ctl.is_cancelled() {
                    lock_or_recover(registry).remove(&id);
                    let _ = out.send(JobEvent::Cancelled { job_id: id, points_emitted: 0 });
                    continue;
                }
                members.push(MemberJob { id, spec, ctl, warm_started: false, lead: false });
            }
            // lint: allow(panic-audit, take_siblings filters on is_fusible_sibling which only matches Job::Fit)
            _ => unreachable!("take_siblings only returns Fit jobs"),
        }
    }

    let mut fits = Vec::with_capacity(members.len());
    for m in &mut members {
        // lint: allow(panic-audit, is_fusible_sibling admits only specs with batch_penalty Some)
        let pen = m.spec.batch_penalty().expect("fusion key requires a batchable spec");
        let mut fit = BatchFit::new(pen);
        if let Some(w) = m.spec.row_weights() {
            fit = fit.with_row_weights(w);
        }
        if m.spec.is_convex() {
            if let Some((_lambda, beta)) =
                cache.warm_coef(&dataset, normalize, m.spec.datafit_name(), m.spec.family())
            {
                fit = fit.warm(beta, None);
                m.warm_started = true;
            }
        }
        // per-member budget: the member's own cancel flag and deadline —
        // NOT merged into the shared SolverOpts, so one member stopping
        // never stops the batch
        fit = fit.with_cancel(m.ctl.cancel_flag());
        if let Some(d) = m.ctl.deadline() {
            fit = fit.with_deadline(d);
        }
        fits.push(fit);
    }

    let outcome = solve_batch(
        design,
        &dataset.y,
        fits,
        &opts,
        Some(&entry.col_sq_norms),
        Some(Arc::clone(&entry.gram)),
    );
    fusion.record(members.len(), &outcome.profile);

    let wall = t0.elapsed().as_secs_f64();
    for (m, member_out) in members.iter().zip(outcome.members) {
        if member_out.stopped == Some(StopReason::Cancelled) || m.ctl.is_cancelled() {
            let _ = out.send(JobEvent::Cancelled { job_id: m.id, points_emitted: 0 });
        } else {
            if m.spec.is_convex() {
                cache.store_coef(
                    &dataset,
                    normalize,
                    m.spec.datafit_name(),
                    m.spec.family(),
                    m.spec.lambda(),
                    &member_out.result.beta,
                );
            }
            let timed_out = member_out.stopped == Some(StopReason::Deadline)
                || (!member_out.result.converged && m.ctl.deadline_exceeded());
            let _ = out.send(JobEvent::FitDone(FitOutcome {
                job_id: m.id,
                label: m.spec.label(),
                lambda: m.spec.lambda(),
                result: member_out.result,
                wall_time: wall,
                warm_started: m.warm_started,
                timed_out,
            }));
        }
        // the worker loop only clears the lead's registry entry; sibling
        // entries are ours to retire with their terminal events
        if !m.lead {
            lock_or_recover(registry).remove(&m.id);
        }
    }
    cache.enforce_budget_now();
}

/// The remainder of a path sweep: everything a worker needs to continue
/// from `next_index` with warm starts intact after a preemption.
pub struct PathResume {
    dataset: Arc<Dataset>,
    spec: Box<dyn FitSpec>,
    /// full grid, sorted descending
    ratios: Vec<f64>,
    lambda_max: f64,
    next_index: usize,
    state: ContinuationState,
    total_epochs: usize,
    /// points streamed so far
    emitted: usize,
    /// wall time spent in earlier segments
    elapsed_before: f64,
    opts: SolverOpts,
}

fn run_path_segment(
    id: u64,
    mut rs: PathResume,
    ctl: &Arc<JobCtl>,
    cache: &DatasetCache,
    out: &Sender<JobEvent>,
    queue: &Arc<JobQueue>,
) -> RunOutcome {
    let seg0 = Instant::now();
    let normalize = rs.spec.normalize_design();
    let entry = cache.design_entry(&rs.dataset, normalize);
    let design = entry.design();
    let n_planned = rs.ratios.len();
    let opts = ctl.solver_opts(&rs.opts);
    // screening support is λ-independent; decide once for the sweep
    let gap_screened = rs.spec.supports_gap_screening();
    // one scratch workspace for the segment (buffer-reuse satellite):
    // xtr / residual / mask / score buffers live across λ points
    let mut screen_work = ScreenWorkspace::new();

    while rs.next_index < n_planned {
        if ctl.is_cancelled() {
            let _ = out.send(JobEvent::Cancelled { job_id: id, points_emitted: rs.emitted });
            return RunOutcome::Terminal;
        }
        if ctl.deadline_exceeded() {
            let _ = out.send(JobEvent::PathDone(path_summary(id, &rs, seg0, true)));
            cache.enforce_budget_now();
            return RunOutcome::Terminal;
        }
        // cooperative preemption: a batch sweep yields between λ points
        // whenever interactive work is waiting; the remainder requeues at
        // the front of the batch queue with its warm state intact
        if ctl.priority() == Priority::Batch && queue.interactive_waiting() {
            rs.elapsed_before += seg0.elapsed().as_secs_f64();
            let ctl = Arc::clone(ctl);
            queue.push_resume_front(QueuedJob { id, job: Job::PathResume(Box::new(rs)), ctl });
            return RunOutcome::Requeued;
        }

        let index = rs.next_index;
        // lint: allow(panic-audit, next_index stays below ratios.len by the PathResume invariant re-established before every requeue)
        let ratio = rs.ratios[index];
        let pt0 = Instant::now();
        let lambda = rs.lambda_max * ratio;

        // Gap-safe screening runs *inside* the solve for specs that
        // support it (quadratic × ℓ1): the mask is rebuilt per λ — a λᵢ
        // certificate is invalid at λᵢ₊₁ < λᵢ — and tightens as the gap
        // shrinks. What persists between points is the ContinuationState
        // (warm β + working-set size).
        let (result, n_screened) = if gap_screened {
            solve_lasso_screened_warm_with(
                design,
                &rs.dataset.y,
                lambda,
                &opts,
                &mut rs.state,
                Some(&entry.col_sq_norms),
                &mut screen_work,
            )
        } else {
            let point_spec = rs.spec.at_lambda(lambda);
            let r = point_spec.solve(
                design,
                &rs.dataset.y,
                &opts,
                &mut rs.state,
                Some(&entry.col_sq_norms),
                None,
            );
            (r, 0)
        };
        rs.total_epochs += result.n_epochs;
        if ctl.is_cancelled() {
            // the cancel landed mid-solve: drop the partial point
            let _ = out.send(JobEvent::Cancelled { job_id: id, points_emitted: rs.emitted });
            return RunOutcome::Terminal;
        }
        // a deadline that fired mid-solve still yields a well-formed
        // partial point (finite objective + certificate); emit it, then
        // the timed-out terminal
        let interrupted = !result.converged && ctl.deadline_exceeded();

        let epochs = result.n_epochs;
        let kkt = result.kkt;
        let converged = result.converged;
        let certificate = result.certificate;
        let point = make_path_point(&entry, &rs.dataset, result, lambda, ratio);
        let _ = out.send(JobEvent::PathPoint(PathPointOutcome {
            job_id: id,
            index,
            point,
            epochs,
            n_screened,
            wall_time: pt0.elapsed().as_secs_f64(),
            kkt,
            converged,
            certificate,
        }));
        rs.emitted += 1;
        rs.next_index += 1;
        if interrupted {
            let _ = out.send(JobEvent::PathDone(path_summary(id, &rs, seg0, true)));
            cache.enforce_budget_now();
            return RunOutcome::Terminal;
        }
    }

    // seed future single fits on this dataset with the densest solution
    if rs.spec.is_convex() {
        if let Some(beta) = &rs.state.beta {
            cache.store_coef(
                &rs.dataset,
                normalize,
                rs.spec.datafit_name(),
                rs.spec.family(),
                rs.lambda_max * rs.ratios.last().copied().unwrap_or(1.0),
                beta,
            );
        }
    }
    let _ = out.send(JobEvent::PathDone(path_summary(id, &rs, seg0, false)));
    // the sweep's Gram blocks count against the cache budget; enforce it
    // at job completion (stores grow during solves, not at insert time)
    cache.enforce_budget_now();
    RunOutcome::Terminal
}

fn path_summary(id: u64, rs: &PathResume, seg0: Instant, timed_out: bool) -> PathSummary {
    PathSummary {
        job_id: id,
        label: rs.spec.label(),
        n_points: rs.emitted,
        n_planned: rs.ratios.len(),
        total_epochs: rs.total_epochs,
        total_time: rs.elapsed_before + seg0.elapsed().as_secs_f64(),
        timed_out,
    }
}

/// Build the streamed [`PathPoint`] for one solved λ point. Metrics vs.
/// ground truth are computed in ORIGINAL coordinates: for normalized
/// specs the solve ran on X·diag(s), so the original-design coefficients
/// are s ⊙ β and the prediction uses the dataset's own design.
fn make_path_point(
    entry: &super::cache::DesignEntry,
    dataset: &Dataset,
    result: FitResult,
    lambda: f64,
    ratio: f64,
) -> PathPoint {
    let support_size = result.support().len();
    let (recovery, est, pred) = if dataset.beta_true.is_empty() {
        (None, None, None)
    } else {
        let bt: &[f64] = &dataset.beta_true;
        let rescaled: Option<Vec<f64>> = entry
            .scales
            .as_ref()
            .map(|scales| result.beta.iter().zip(scales.iter()).map(|(b, s)| b * s).collect());
        let metric_beta: &[f64] = rescaled.as_deref().unwrap_or(&result.beta);
        let metric_design: &crate::linalg::Design =
            if rescaled.is_some() { &dataset.design } else { entry.design() };
        (
            Some(support_recovery(metric_beta, bt, 1e-8)),
            Some(estimation_error(metric_beta, bt)),
            Some(prediction_mse(metric_design, metric_beta, bt)),
        )
    };
    PathPoint {
        lambda,
        lambda_ratio: ratio,
        objective: result.objective,
        support_size,
        recovery,
        estimation_error: est,
        prediction_mse: pred,
        beta: result.beta,
    }
}

/// One fused batched *path* job: the lead sweep plus every sibling
/// [`JobQueue::take_path_siblings`] pulled off the batch queue, advanced
/// in λ-lockstep — each grid point is one [`solve_batch`] call over a
/// shared residual panel, with every member warm-continued from its own
/// previous point. Per-job semantics are preserved:
///
/// - each member streams its own [`JobEvent::PathPoint`]s and terminal
///   event (`PathDone`, or `Cancelled` with its emitted-point count);
/// - cancelling one member frees its panel column without touching its
///   siblings; a member whose deadline fires emits its final partial
///   point and a `timed_out` summary while the rest sweep on;
/// - cooperative preemption **de-fuses**: when interactive work is
///   waiting, every surviving member is requeued at the batch-queue front
///   as its own [`Job::PathResume`] with warm state intact (it may later
///   resume scalar — identical arithmetic, point for point).
///
/// Fused members skip the gap-safe screening fast path (`n_screened = 0`
/// on their points): the multi-RHS panel amortization replaces it, and
/// the streamed objectives/certificates meet the same tolerance.
#[allow(clippy::too_many_arguments)]
fn run_path_batch(
    lead_id: u64,
    dataset: Arc<Dataset>,
    lead_spec: Box<dyn FitSpec>,
    ratios: Vec<f64>,
    opts: SolverOpts,
    lead_ctl: &Arc<JobCtl>,
    siblings: Vec<QueuedJob>,
    cache: &DatasetCache,
    out: &Sender<JobEvent>,
    queue: &Arc<JobQueue>,
    registry: &Mutex<HashMap<u64, Arc<JobCtl>>>,
    fusion: &FusionCounters,
) -> RunOutcome {
    struct PathMember {
        id: u64,
        ctl: Arc<JobCtl>,
        rs: PathResume,
        lead: bool,
    }

    let seg0 = Instant::now();
    let normalize = lead_spec.normalize_design();
    let entry = cache.design_entry(&dataset, normalize);
    let design = entry.design();
    let n_planned = ratios.len();

    let make_rs = |spec: Box<dyn FitSpec>, ratios: Vec<f64>, opts: SolverOpts| -> PathResume {
        let lambda_max = spec.lambda_max(design, &dataset.y);
        let mut state = ContinuationState::default();
        state.gram = Some(Arc::clone(&entry.gram));
        PathResume {
            dataset: Arc::clone(&dataset),
            spec,
            ratios,
            lambda_max,
            next_index: 0,
            state,
            total_epochs: 0,
            emitted: 0,
            elapsed_before: 0.0,
            opts,
        }
    };

    // roster: lead first, then siblings in queue order; a sibling
    // cancelled while it was still queued terminates here without ever
    // occupying a panel column
    let mut members = vec![PathMember {
        id: lead_id,
        ctl: Arc::clone(lead_ctl),
        rs: make_rs(lead_spec, ratios.clone(), opts.clone()),
        lead: true,
    }];
    for qj in siblings {
        let QueuedJob { id, job, ctl } = qj;
        match job {
            Job::Path { spec, ratios: mut r, opts: jopts, .. } => {
                if ctl.is_cancelled() {
                    lock_or_recover(registry).remove(&id);
                    let _ = out.send(JobEvent::Cancelled { job_id: id, points_emitted: 0 });
                    continue;
                }
                r.sort_by(|a, b| b.partial_cmp(a).unwrap_or(std::cmp::Ordering::Equal));
                members.push(PathMember { id, ctl, rs: make_rs(spec, r, jopts), lead: false });
            }
            // lint: allow(panic-audit, take_path_siblings filters on is_fusible_path_sibling which only matches Job::Path)
            _ => unreachable!("take_path_siblings only returns Path jobs"),
        }
    }
    if members.len() == 1 {
        // every joiner was pre-cancelled: run the lead as a plain sweep
        // lint: allow(panic-audit, roster always holds the lead — it is pushed unconditionally above)
        let m = members.pop().expect("roster holds the lead");
        return run_path_segment(m.id, m.rs, lead_ctl, cache, out, queue);
    }

    let n_fused = members.len();
    let mut panel_flops = 0.0;
    let mut total_flops = 0.0;
    let mut index = 0;

    while index < n_planned && !members.is_empty() {
        // per-member cancel/deadline checks between λ points
        members.retain_mut(|m| {
            if m.ctl.is_cancelled() {
                let _ =
                    out.send(JobEvent::Cancelled { job_id: m.id, points_emitted: m.rs.emitted });
                if !m.lead {
                    lock_or_recover(registry).remove(&m.id);
                }
                false
            } else if m.ctl.deadline_exceeded() {
                let _ = out.send(JobEvent::PathDone(path_summary(m.id, &m.rs, seg0, true)));
                if !m.lead {
                    lock_or_recover(registry).remove(&m.id);
                }
                false
            } else {
                true
            }
        });
        if members.is_empty() {
            break;
        }
        // cooperative preemption de-fuses the batch: each survivor
        // resumes as its own scalar sweep with warm state intact, ahead
        // of batch jobs submitted after the fused job started
        if queue.interactive_waiting() {
            let elapsed = seg0.elapsed().as_secs_f64();
            let mut lead_requeued = false;
            for mut m in members.drain(..).rev() {
                m.rs.elapsed_before += elapsed;
                lead_requeued |= m.lead;
                let ctl = Arc::clone(&m.ctl);
                queue.push_resume_front(QueuedJob {
                    id: m.id,
                    job: Job::PathResume(Box::new(m.rs)),
                    ctl,
                });
            }
            let reduced = opts.precision != crate::linalg::Precision::F64;
            fusion.record_path(n_fused, panel_flops, total_flops, reduced);
            cache.enforce_budget_now();
            return if lead_requeued { RunOutcome::Requeued } else { RunOutcome::Terminal };
        }

        // lint: allow(panic-audit, the loop exits above once index reaches ratios.len)
        let ratio = ratios[index];
        let pt0 = Instant::now();
        let mut fits = Vec::with_capacity(members.len());
        for m in &members {
            let lambda = m.rs.lambda_max * ratio;
            let pen = m
                .rs
                .spec
                .batch_penalty()
                // lint: allow(panic-audit, the fusion trigger and is_fusible_path_sibling both require batch_penalty Some)
                .expect("fusion key requires a batchable spec")
                .with_lambda(lambda);
            let mut fit = BatchFit::new(pen);
            if let Some(w) = m.rs.spec.row_weights() {
                fit = fit.with_row_weights(w);
            }
            if let Some(beta) = &m.rs.state.beta {
                fit = fit.warm(beta.clone(), m.rs.state.ws_size);
            }
            // per-member budget rides on the BatchFit, never on the
            // shared SolverOpts — one member stopping never stops the rest
            fit = fit.with_cancel(m.ctl.cancel_flag());
            if let Some(d) = m.ctl.deadline() {
                fit = fit.with_deadline(d);
            }
            fits.push(fit);
        }
        let outcome = solve_batch(
            design,
            &dataset.y,
            fits,
            &opts,
            Some(&entry.col_sq_norms),
            Some(Arc::clone(&entry.gram)),
        );
        panel_flops += outcome.profile.panel_flops;
        total_flops += outcome.profile.total_flops();

        let wall = pt0.elapsed().as_secs_f64();
        let mut keep = Vec::with_capacity(members.len());
        for (m, mo) in members.iter_mut().zip(outcome.members) {
            if mo.stopped == Some(StopReason::Cancelled) || m.ctl.is_cancelled() {
                // the cancel landed mid-solve: drop the partial point
                let _ =
                    out.send(JobEvent::Cancelled { job_id: m.id, points_emitted: m.rs.emitted });
                if !m.lead {
                    lock_or_recover(registry).remove(&m.id);
                }
                keep.push(false);
                continue;
            }
            let interrupted = mo.stopped == Some(StopReason::Deadline)
                || (!mo.result.converged && m.ctl.deadline_exceeded());
            m.rs.total_epochs += mo.result.n_epochs;
            m.rs.state.update_from(&mo.result);
            let lambda = m.rs.lambda_max * ratio;
            let epochs = mo.result.n_epochs;
            let kkt = mo.result.kkt;
            let converged = mo.result.converged;
            let certificate = mo.result.certificate;
            let point = make_path_point(&entry, &dataset, mo.result, lambda, ratio);
            let _ = out.send(JobEvent::PathPoint(PathPointOutcome {
                job_id: m.id,
                index,
                point,
                epochs,
                n_screened: 0,
                wall_time: wall,
                kkt,
                converged,
                certificate,
            }));
            m.rs.emitted += 1;
            m.rs.next_index += 1;
            if interrupted {
                // deadline fired mid-solve: the partial point stands,
                // followed by this member's timed-out terminal
                let _ = out.send(JobEvent::PathDone(path_summary(m.id, &m.rs, seg0, true)));
                if !m.lead {
                    lock_or_recover(registry).remove(&m.id);
                }
                keep.push(false);
            } else {
                keep.push(true);
            }
        }
        let mut keep_it = keep.into_iter();
        members.retain(|_| keep_it.next().unwrap_or(true));
        index += 1;
    }

    for m in &members {
        // seed future single fits on this dataset with the densest
        // solution (mirrors the scalar sweep)
        if m.rs.spec.is_convex() {
            if let Some(beta) = &m.rs.state.beta {
                cache.store_coef(
                    &m.rs.dataset,
                    normalize,
                    m.rs.spec.datafit_name(),
                    m.rs.spec.family(),
                    m.rs.lambda_max * m.rs.ratios.last().copied().unwrap_or(1.0),
                    beta,
                );
            }
        }
        let _ = out.send(JobEvent::PathDone(path_summary(m.id, &m.rs, seg0, false)));
        if !m.lead {
            lock_or_recover(registry).remove(&m.id);
        }
    }
    let reduced = opts.precision != crate::linalg::Precision::F64;
    fusion.record_path(n_fused, panel_flops, total_flops, reduced);
    cache.enforce_budget_now();
    RunOutcome::Terminal
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::job::specs;
    use crate::data::{correlated, CorrelatedSpec};
    use crate::estimators::linear::quadratic_lambda_max;
    use crate::estimators::Lasso;

    fn dataset(seed: u64) -> Arc<Dataset> {
        Arc::new(correlated(
            CorrelatedSpec { n: 60, p: 80, rho: 0.4, nnz: 5, snr: 10.0 },
            seed,
        ))
    }

    #[test]
    fn sweep_over_lambda_completes() {
        let ds = dataset(0);
        let lam_max = quadratic_lambda_max(&ds.design, &ds.y);
        let sched = FitScheduler::start(2);
        for k in 1..=6 {
            sched.submit_fit(
                Arc::clone(&ds),
                specs::lasso(lam_max / (2.0 * k as f64)),
                SolverOpts::default(),
            );
        }
        let mut outcomes = sched.collect_fits(6);
        sched.shutdown();
        assert_eq!(outcomes.len(), 6);
        outcomes.sort_by_key(|o| o.job_id);
        // smaller lambda (later ids) -> larger support
        let first = outcomes.first().unwrap().result.support().len();
        let last = outcomes.last().unwrap().result.support().len();
        assert!(last >= first);
        for o in &outcomes {
            assert!(o.result.converged);
            assert!(o.wall_time >= 0.0);
            assert!(!o.timed_out);
        }
    }

    #[test]
    fn mixed_trait_jobs() {
        let ds = dataset(1);
        let lam = quadratic_lambda_max(&ds.design, &ds.y) / 10.0;
        let sched = FitScheduler::start(2);
        sched.submit_fit(Arc::clone(&ds), specs::lasso(lam), SolverOpts::default());
        sched.submit_fit(Arc::clone(&ds), specs::elastic_net(lam, 0.5), SolverOpts::default());
        sched.submit_fit(Arc::clone(&ds), specs::mcp(lam, 3.0), SolverOpts::default());
        let outcomes = sched.collect_fits(3);
        sched.shutdown();
        assert_eq!(outcomes.len(), 3);
        let labels: Vec<String> = outcomes.iter().map(|o| o.label.clone()).collect();
        for l in ["quadratic/l1", "quadratic/l1l2", "quadratic/mcp"] {
            assert!(labels.iter().any(|x| x == l), "missing {l} in {labels:?}");
        }
    }

    #[test]
    fn coefficient_cache_warm_starts_second_convex_fit() {
        let ds = dataset(2);
        let lam_max = quadratic_lambda_max(&ds.design, &ds.y);
        let sched = FitScheduler::start(1);
        let opts = SolverOpts::default().with_tol(1e-10);
        sched.submit_fit(Arc::clone(&ds), specs::lasso(lam_max / 5.0), opts.clone());
        let first = sched.collect_fits(1);
        assert!(!first[0].warm_started);
        sched.submit_fit(Arc::clone(&ds), specs::lasso(lam_max / 7.0), opts.clone());
        let second = sched.collect_fits(1);
        assert!(second[0].warm_started, "second lasso fit should reuse cached coefficients");
        // warm start must not change the optimum
        let reference = Lasso::new(lam_max / 7.0).with_tol(1e-10).fit(&ds.design, &ds.y);
        assert!((second[0].result.objective - reference.objective).abs() < 1e-8);
        let stats = sched.cache().stats();
        assert!(stats.design_hits >= 1);
        assert_eq!(stats.coef_hits, 1);
        sched.shutdown();
    }

    #[test]
    fn non_convex_fits_never_reuse_coefficients() {
        let ds = dataset(3);
        let lam = quadratic_lambda_max(&ds.design, &ds.y) / 8.0;
        let sched = FitScheduler::start(1);
        sched.submit_fit(Arc::clone(&ds), specs::mcp(lam, 3.0), SolverOpts::default());
        sched.submit_fit(Arc::clone(&ds), specs::mcp(lam / 2.0, 3.0), SolverOpts::default());
        let outcomes = sched.collect_fits(2);
        sched.shutdown();
        assert!(outcomes.iter().all(|o| !o.warm_started));
    }

    #[test]
    fn shutdown_without_jobs() {
        let sched = FitScheduler::start(3);
        sched.shutdown(); // must not hang
    }

    /// A spec whose solve panics — stands in for a divergent fit.
    struct PanicSpec;
    impl crate::coordinator::job::FitSpec for PanicSpec {
        fn label(&self) -> String {
            "panic/test".into()
        }
        fn datafit_name(&self) -> &'static str {
            "panic"
        }
        fn family(&self) -> &'static str {
            "test"
        }
        fn lambda(&self) -> f64 {
            0.1
        }
        fn is_convex(&self) -> bool {
            false // keep it away from the coefficient cache
        }
        fn normalize_design(&self) -> bool {
            false
        }
        fn lambda_max(&self, _d: &crate::linalg::Design, _y: &[f64]) -> f64 {
            1.0
        }
        fn at_lambda(&self, _l: f64) -> Box<dyn crate::coordinator::job::FitSpec> {
            Box::new(PanicSpec)
        }
        fn solve(
            &self,
            _design: &crate::linalg::Design,
            _y: &[f64],
            _opts: &SolverOpts,
            _state: &mut ContinuationState,
            _col_sq_norms: Option<&[f64]>,
            _frozen: Option<&[bool]>,
        ) -> crate::solver::FitResult {
            panic!("synthetic divergence: step outside the valid regime");
        }
    }

    #[test]
    fn worker_panic_surfaces_as_failed_event_and_batch_survives() {
        let ds = dataset(5);
        let lam = quadratic_lambda_max(&ds.design, &ds.y) / 10.0;
        let sched = FitScheduler::start(1); // one worker: it must survive
        let bad = sched.submit_fit(Arc::clone(&ds), Box::new(PanicSpec), SolverOpts::default());
        let good = sched.submit_fit(Arc::clone(&ds), specs::lasso(lam), SolverOpts::default());
        let events = sched.collect_events(2);
        let mut saw_failed = false;
        let mut saw_done = false;
        for e in events {
            match e {
                JobEvent::Failed { job_id, message } => {
                    assert_eq!(job_id, bad);
                    assert!(
                        message.contains("synthetic divergence"),
                        "original panic message lost: {message:?}"
                    );
                    saw_failed = true;
                }
                JobEvent::FitDone(o) => {
                    assert_eq!(o.job_id, good);
                    assert!(o.result.converged);
                    saw_done = true;
                }
                _ => panic!("unexpected event"),
            }
        }
        assert!(saw_failed && saw_done, "one divergent fit must not take down the batch");
        sched.shutdown();
    }

    /// Delegating spec that sleeps before every solve — deterministic
    /// slowness for cancellation/deadline/preemption tests.
    struct SlowSpec {
        inner: Box<dyn FitSpec>,
        ms: u64,
    }
    impl FitSpec for SlowSpec {
        fn label(&self) -> String {
            self.inner.label()
        }
        fn datafit_name(&self) -> &'static str {
            self.inner.datafit_name()
        }
        fn family(&self) -> &'static str {
            self.inner.family()
        }
        fn lambda(&self) -> f64 {
            self.inner.lambda()
        }
        fn is_convex(&self) -> bool {
            false
        }
        fn normalize_design(&self) -> bool {
            self.inner.normalize_design()
        }
        fn lambda_max(&self, d: &crate::linalg::Design, y: &[f64]) -> f64 {
            self.inner.lambda_max(d, y)
        }
        fn at_lambda(&self, lambda: f64) -> Box<dyn FitSpec> {
            Box::new(SlowSpec { inner: self.inner.at_lambda(lambda), ms: self.ms })
        }
        fn solve(
            &self,
            design: &crate::linalg::Design,
            y: &[f64],
            opts: &SolverOpts,
            state: &mut ContinuationState,
            col_sq_norms: Option<&[f64]>,
            frozen: Option<&[bool]>,
        ) -> FitResult {
            std::thread::sleep(Duration::from_millis(self.ms));
            self.inner.solve(design, y, opts, state, col_sq_norms, frozen)
        }
    }

    fn slow_lasso(lam: f64, ms: u64) -> Box<dyn FitSpec> {
        Box::new(SlowSpec { inner: specs::lasso(lam), ms })
    }

    #[test]
    fn cancel_stops_path_within_one_point_and_frees_worker() {
        let ds = dataset(6);
        let sched = FitScheduler::start(1);
        let ratios: Vec<f64> = (1..=32).map(|k| 1.0 / (k as f64 + 1.0)).collect();
        let (path_id, _ctl) = sched.submit_with(
            Job::Path {
                dataset: Arc::clone(&ds),
                spec: slow_lasso(1.0, 25),
                ratios,
                opts: SolverOpts::default(),
            },
            JobPolicy::default(),
        );
        // wait for the first streamed point, then cancel
        match sched.recv_event_timeout(Duration::from_secs(30)) {
            Some(JobEvent::PathPoint(p)) => assert_eq!(p.job_id, path_id),
            other => panic!("expected first PathPoint, got {:?}", other.map(|e| e.job_id())),
        }
        assert!(sched.cancel(path_id));
        let mut extra_points = 0;
        loop {
            match sched.recv_event_timeout(Duration::from_secs(30)) {
                Some(JobEvent::PathPoint(_)) => extra_points += 1,
                Some(JobEvent::Cancelled { job_id, points_emitted }) => {
                    assert_eq!(job_id, path_id);
                    assert_eq!(points_emitted, 1 + extra_points);
                    break;
                }
                other => panic!("unexpected event {:?}", other.map(|e| e.job_id())),
            }
        }
        assert!(
            extra_points <= 1,
            "cancelled path must stop within one λ point, saw {extra_points} more"
        );
        // the worker is free again: a fresh fit completes
        let lam = quadratic_lambda_max(&ds.design, &ds.y) / 10.0;
        sched.submit_fit(Arc::clone(&ds), specs::lasso(lam), SolverOpts::default());
        match sched.recv_event_timeout(Duration::from_secs(30)) {
            Some(JobEvent::FitDone(o)) => assert!(o.result.converged),
            other => panic!("worker wedged after cancel: {:?}", other.map(|e| e.job_id())),
        }
        sched.shutdown();
    }

    #[test]
    fn deadline_returns_partial_path_with_certificate() {
        let ds = dataset(7);
        let sched = FitScheduler::start(1);
        let ratios: Vec<f64> = (1..=16).map(|k| 1.0 / (k as f64 + 1.0)).collect();
        let deadline = Instant::now() + Duration::from_millis(90);
        let (job_id, _ctl) = sched.submit_with(
            Job::Path {
                dataset: Arc::clone(&ds),
                spec: slow_lasso(1.0, 40),
                ratios,
                opts: SolverOpts::default(),
            },
            JobPolicy::default().with_deadline(deadline),
        );
        let mut points = 0;
        loop {
            match sched.recv_event_timeout(Duration::from_secs(30)) {
                Some(JobEvent::PathPoint(p)) => {
                    assert!(p.point.objective.is_finite(), "partial point objective not finite");
                    assert!(p.kkt.is_finite(), "partial point certificate not finite");
                    points += 1;
                }
                Some(JobEvent::PathDone(s)) => {
                    assert_eq!(s.job_id, job_id);
                    assert!(s.timed_out, "deadline-bounded sweep must report timed_out");
                    assert_eq!(s.n_points, points);
                    assert_eq!(s.n_planned, 16);
                    assert!(s.n_points < 16, "sweep should have been cut short");
                    break;
                }
                other => panic!("unexpected event {:?}", other.map(|e| e.job_id())),
            }
        }
        sched.shutdown();
    }

    #[test]
    fn interactive_fit_preempts_batch_path_between_points() {
        let ds = dataset(8);
        let lam = quadratic_lambda_max(&ds.design, &ds.y) / 10.0;
        let sched = FitScheduler::start(1); // single worker forces preemption
        let ratios: Vec<f64> = (1..=12).map(|k| 1.0 / (k as f64 + 1.0)).collect();
        let n_points = ratios.len();
        let (path_id, _) = sched.submit_with(
            Job::Path {
                dataset: Arc::clone(&ds),
                spec: slow_lasso(1.0, 20),
                ratios,
                opts: SolverOpts::default(),
            },
            JobPolicy::default(),
        );
        // let the sweep start, then inject an interactive fit
        std::thread::sleep(Duration::from_millis(50));
        let (fit_id, _) = sched.submit_with(
            Job::Fit {
                dataset: Arc::clone(&ds),
                spec: specs::lasso(lam),
                opts: SolverOpts::default(),
            },
            JobPolicy::interactive(),
        );
        let mut order = Vec::new();
        let mut indices = Vec::new();
        let mut terminals = 0;
        while terminals < 2 {
            match sched.recv_event_timeout(Duration::from_secs(60)) {
                Some(JobEvent::PathPoint(p)) => {
                    assert_eq!(p.job_id, path_id);
                    indices.push(p.index);
                }
                Some(JobEvent::FitDone(o)) => {
                    assert_eq!(o.job_id, fit_id);
                    order.push("fit");
                    terminals += 1;
                }
                Some(JobEvent::PathDone(s)) => {
                    assert_eq!(s.job_id, path_id);
                    assert!(!s.timed_out);
                    assert_eq!(s.n_points, n_points, "preempted sweep must still finish");
                    order.push("path");
                    terminals += 1;
                }
                other => panic!("unexpected event {:?}", other.map(|e| e.job_id())),
            }
        }
        assert_eq!(
            order,
            vec!["fit", "path"],
            "interactive fit must complete before the batch sweep"
        );
        // every λ index exactly once, in order, across the preemption
        assert_eq!(indices, (0..n_points).collect::<Vec<_>>());
        sched.shutdown();
    }

    #[test]
    fn cancel_while_queued_never_runs() {
        let ds = dataset(9);
        let sched = FitScheduler::start(1);
        // occupy the single worker
        let ratios: Vec<f64> = (1..=8).map(|k| 1.0 / (k as f64 + 1.0)).collect();
        let (path_id, _) = sched.submit_with(
            Job::Path {
                dataset: Arc::clone(&ds),
                spec: slow_lasso(1.0, 25),
                ratios,
                opts: SolverOpts::default(),
            },
            JobPolicy::default(),
        );
        // queue an interactive fit and cancel it before it can start
        let (queued_id, _) = sched.submit_with(
            Job::Fit {
                dataset: Arc::clone(&ds),
                spec: Box::new(PanicSpec), // would fail loudly if it ever ran
                opts: SolverOpts::default(),
            },
            JobPolicy::interactive(),
        );
        assert!(sched.cancel(queued_id));
        sched.cancel(path_id);
        let mut saw_queued_cancel = false;
        let mut terminals = 0;
        while terminals < 2 {
            match sched.recv_event_timeout(Duration::from_secs(30)) {
                Some(JobEvent::Cancelled { job_id, points_emitted }) => {
                    if job_id == queued_id {
                        assert_eq!(points_emitted, 0);
                        saw_queued_cancel = true;
                    }
                    terminals += 1;
                }
                Some(JobEvent::PathPoint(_)) => {}
                Some(JobEvent::PathDone(_)) | Some(JobEvent::FitDone(_)) => terminals += 1,
                Some(JobEvent::Failed { message, .. }) => {
                    panic!("cancelled queued job ran anyway: {message}")
                }
                other => panic!("unexpected event {:?}", other.map(|e| e.job_id())),
            }
        }
        assert!(saw_queued_cancel);
        sched.shutdown();
    }

    #[test]
    fn sibling_fits_fuse_into_one_batched_job() {
        let ds = dataset(11);
        let lam_max = quadratic_lambda_max(&ds.design, &ds.y);
        let sched = FitScheduler::start(1);
        let opts = SolverOpts::default().with_tol(1e-10);
        // occupy the single worker so the lasso fits pile up in the queue
        sched.submit_fit(Arc::clone(&ds), slow_lasso(lam_max / 3.0, 400), opts.clone());
        let lams: Vec<f64> = (2..=5).map(|k| lam_max / (2.0 * k as f64)).collect();
        for &lam in &lams {
            sched.submit_fit(Arc::clone(&ds), specs::lasso(lam), opts.clone());
        }
        let outcomes = sched.collect_fits(5);
        // the blocker ran scalar; the four lasso fits fused into one job
        let stats = sched.fusion_stats();
        assert_eq!(stats.batched_jobs, 1, "expected exactly one fused job");
        assert_eq!(stats.batched_fits, 4, "all four siblings should have fused");
        assert!((stats.fits_per_batch() - 4.0).abs() < 1e-12);
        assert!(
            stats.panel_flop_ratio() > 0.0 && stats.panel_flop_ratio() < 1.0,
            "panel ratio {} outside (0,1)",
            stats.panel_flop_ratio()
        );
        // every member solved its own λ to its own certificate
        for &lam in &lams {
            let o = outcomes
                .iter()
                .find(|o| (o.lambda - lam).abs() < 1e-15)
                .expect("member outcome missing");
            assert!(o.result.converged, "member at λ={lam} did not converge");
            assert!(!o.timed_out);
            let reference = Lasso::new(lam).with_tol(1e-10).fit(&ds.design, &ds.y);
            assert!(
                (o.result.objective - reference.objective).abs() < 1e-10,
                "fused member objective drifted from scalar at λ={lam}"
            );
        }
        sched.shutdown();
    }

    #[test]
    fn cancel_one_member_leaves_siblings_running() {
        let ds = dataset(12);
        let lam_max = quadratic_lambda_max(&ds.design, &ds.y);
        let sched = FitScheduler::start(1);
        let opts = SolverOpts::default().with_tol(1e-10);
        sched.submit_fit(Arc::clone(&ds), slow_lasso(lam_max / 3.0, 400), opts.clone());
        let a = sched.submit_fit(Arc::clone(&ds), specs::lasso(lam_max / 4.0), opts.clone());
        let b = sched.submit_fit(Arc::clone(&ds), specs::lasso(lam_max / 6.0), opts.clone());
        let c = sched.submit_fit(Arc::clone(&ds), specs::lasso(lam_max / 8.0), opts.clone());
        assert!(sched.cancel(b), "cancel must land while b is still queued");
        let mut done = Vec::new();
        let mut cancelled = Vec::new();
        for _ in 0..4 {
            match sched.recv_event_timeout(Duration::from_secs(60)) {
                Some(JobEvent::FitDone(o)) => done.push(o),
                Some(JobEvent::Cancelled { job_id, points_emitted }) => {
                    assert_eq!(points_emitted, 0);
                    cancelled.push(job_id);
                }
                other => panic!("unexpected event {:?}", other.map(|e| e.job_id())),
            }
        }
        assert_eq!(cancelled, vec![b], "only the cancelled member may terminate Cancelled");
        for id in [a, c] {
            let o = done.iter().find(|o| o.job_id == id).expect("sibling outcome missing");
            assert!(o.result.converged, "surviving sibling {id} must converge");
        }
        let stats = sched.fusion_stats();
        assert_eq!(stats.batched_jobs, 1);
        assert_eq!(stats.batched_fits, 2, "the cancelled member never joins the panel");
        sched.shutdown();
    }

    #[test]
    fn deadline_member_reports_partial_without_stopping_siblings() {
        let ds = dataset(13);
        let lam_max = quadratic_lambda_max(&ds.design, &ds.y);
        let sched = FitScheduler::start(1);
        let opts = SolverOpts::default().with_tol(1e-10);
        sched.submit_fit(Arc::clone(&ds), slow_lasso(lam_max / 3.0, 400), opts.clone());
        let a = sched.submit_fit(Arc::clone(&ds), specs::lasso(lam_max / 4.0), opts.clone());
        // a deadline already in the past: the member must retire at the
        // first scoring pass with a finite partial result
        let (b, _) = sched.submit_with(
            Job::Fit {
                dataset: Arc::clone(&ds),
                spec: specs::lasso(lam_max / 6.0),
                opts: opts.clone(),
            },
            JobPolicy::default().with_deadline(Instant::now()),
        );
        let c = sched.submit_fit(Arc::clone(&ds), specs::lasso(lam_max / 8.0), opts.clone());
        let outcomes = sched.collect_fits(4);
        let stats = sched.fusion_stats();
        assert_eq!(stats.batched_jobs, 1);
        assert_eq!(stats.batched_fits, 3, "the deadline member still joins the batch");
        let bo = outcomes.iter().find(|o| o.job_id == b).expect("deadline member outcome");
        assert!(bo.timed_out, "expired deadline must surface as timed_out");
        assert!(!bo.result.converged);
        assert!(bo.result.objective.is_finite(), "partial result must be well-formed");
        assert!(bo.result.kkt.is_finite());
        for id in [a, c] {
            let o = outcomes.iter().find(|o| o.job_id == id).expect("sibling outcome");
            assert!(o.result.converged, "sibling {id} must run to its certificate");
            assert!(!o.timed_out);
        }
        sched.shutdown();
    }

    #[test]
    fn interactive_and_non_batchable_fits_never_fuse() {
        let ds = dataset(14);
        let lam = quadratic_lambda_max(&ds.design, &ds.y) / 5.0;
        let sched = FitScheduler::start(1);
        let opts = SolverOpts::default();
        sched.submit_fit(Arc::clone(&ds), slow_lasso(lam, 300), opts.clone());
        // interactive siblings: latency wins over throughput — no fusion
        for _ in 0..2 {
            sched.submit_with(
                Job::Fit {
                    dataset: Arc::clone(&ds),
                    spec: specs::lasso(lam),
                    opts: opts.clone(),
                },
                JobPolicy::interactive(),
            );
        }
        // SCAD has no batchable penalty form: stays scalar even at batch
        // priority
        sched.submit_fit(Arc::clone(&ds), specs::scad(lam, 3.7), opts.clone());
        sched.submit_fit(Arc::clone(&ds), specs::scad(lam / 2.0, 3.7), opts.clone());
        let outcomes = sched.collect_fits(5);
        assert_eq!(outcomes.len(), 5);
        let stats = sched.fusion_stats();
        assert_eq!(stats.batched_jobs, 0, "nothing here is allowed to fuse");
        assert_eq!(stats.batched_fits, 0);
        assert_eq!(stats.fits_per_batch(), 0.0);
        assert_eq!(stats.panel_flop_ratio(), 0.0);
        sched.shutdown();
    }

    #[test]
    fn sibling_paths_fuse_and_match_cold_sweeps() {
        let ds = dataset(15);
        let lam_max = quadratic_lambda_max(&ds.design, &ds.y);
        let sched = FitScheduler::start(1);
        let opts = SolverOpts::default().with_tol(1e-10);
        let ratios = vec![0.5, 0.25, 0.1, 0.05];
        // occupy the single worker so both sweeps pile up in the queue
        sched.submit_fit(Arc::clone(&ds), slow_lasso(lam_max / 3.0, 400), opts.clone());
        let lasso_id =
            sched.submit_path(Arc::clone(&ds), specs::lasso(1.0), ratios.clone(), opts.clone());
        let mcp_id =
            sched.submit_path(Arc::clone(&ds), specs::mcp(1.0, 3.0), ratios.clone(), opts.clone());
        // blocker FitDone + 2 × (4 points + PathDone)
        let events = sched.collect_events(1 + 2 * (ratios.len() + 1));
        let stats = sched.fusion_stats();
        assert_eq!(stats.batched_jobs, 1, "the two sweeps should fuse into one job");
        assert_eq!(stats.batched_fits, 2);
        assert!(stats.panel_flop_ratio() > 0.0 && stats.panel_flop_ratio() < 1.0);
        for id in [lasso_id, mcp_id] {
            let points: Vec<_> = events
                .iter()
                .filter_map(|e| match e {
                    JobEvent::PathPoint(p) if p.job_id == id => Some(p),
                    _ => None,
                })
                .collect();
            assert_eq!(points.len(), ratios.len(), "member {id} must stream every point");
            for p in &points {
                assert!(p.converged, "fused member point at λ={} did not converge", p.point.lambda);
                assert!(p.kkt <= 1e-10, "fused member kkt {} above tol", p.kkt);
                assert_eq!(p.n_screened, 0, "fused sweeps skip the screening fast path");
            }
            let done = events
                .iter()
                .find_map(|e| match e {
                    JobEvent::PathDone(s) if s.job_id == id => Some(s),
                    _ => None,
                })
                .expect("member summary missing");
            assert_eq!(done.n_points, ratios.len());
            assert!(!done.timed_out);
        }
        // fused lasso points must not be worse than cold scalar fits
        for p in events.iter().filter_map(|e| match e {
            JobEvent::PathPoint(p) if p.job_id == lasso_id => Some(p),
            _ => None,
        }) {
            let cold = Lasso::new(p.point.lambda).with_tol(1e-10).fit(&ds.design, &ds.y);
            assert!(
                p.point.objective <= cold.objective + 1e-8,
                "fused objective {} worse than cold {} at λ={}",
                p.point.objective,
                cold.objective,
                p.point.lambda
            );
        }
        sched.shutdown();
    }

    #[test]
    fn precancelled_path_sibling_falls_back_to_scalar_sweep() {
        let ds = dataset(16);
        let lam_max = quadratic_lambda_max(&ds.design, &ds.y);
        let sched = FitScheduler::start(1);
        let opts = SolverOpts::default().with_tol(1e-8);
        let ratios = vec![0.4, 0.1];
        sched.submit_fit(Arc::clone(&ds), slow_lasso(lam_max / 3.0, 300), opts.clone());
        let lead =
            sched.submit_path(Arc::clone(&ds), specs::lasso(1.0), ratios.clone(), opts.clone());
        let sib =
            sched.submit_path(Arc::clone(&ds), specs::lasso(1.0), ratios.clone(), opts.clone());
        assert!(sched.cancel(sib), "cancel must land while the sibling is still queued");
        // blocker FitDone + sibling Cancelled + lead (2 points + PathDone)
        let events = sched.collect_events(2 + ratios.len() + 1);
        assert!(events.iter().any(|e| matches!(
            e,
            JobEvent::Cancelled { job_id, points_emitted: 0 } if *job_id == sib
        )));
        let lead_points = events
            .iter()
            .filter(|e| matches!(e, JobEvent::PathPoint(p) if p.job_id == lead))
            .count();
        assert_eq!(lead_points, ratios.len(), "lead must complete its sweep scalar");
        let stats = sched.fusion_stats();
        assert_eq!(stats.batched_jobs, 0, "a lone lead must not count as a fused job");
        sched.shutdown();
    }

    #[test]
    fn killed_workers_surface_scheduler_down() {
        let sched = FitScheduler::start(2);
        assert_eq!(sched.workers_alive(), 2);
        sched.kill_workers(2);
        match sched.recv_event_timeout(Duration::from_secs(30)) {
            Some(JobEvent::SchedulerDown) => {}
            other => panic!("expected SchedulerDown, got {:?}", other.map(|e| e.job_id())),
        }
        assert_eq!(sched.workers_alive(), 0);
        // the channel is closed now; recv_event keeps reporting down
        // instead of blocking or panicking
        assert!(matches!(sched.recv_event(), JobEvent::SchedulerDown));
        // submitting into a dead pool must not panic (the service layer
        // rejects before this point; the queue just holds the job)
        let ds = dataset(10);
        sched.submit_fit(Arc::clone(&ds), specs::lasso(0.5), SolverOpts::default());
        sched.shutdown();
    }
}
