//! Tiny work-stealing-free thread pool: run a batch of closures on up to
//! `threads` workers and return results in input order.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Run all jobs, at most `threads` at a time; preserves input order in the
/// output. Panics in jobs propagate.
pub fn run_parallel<T, F>(jobs: Vec<F>, threads: usize) -> Vec<T>
where
    T: Send,
    F: FnOnce() -> T + Send,
{
    let n = jobs.len();
    if n == 0 {
        return Vec::new();
    }
    let threads = threads.max(1).min(n);
    if threads == 1 {
        return jobs.into_iter().map(|j| j()).collect();
    }
    // register against the kernel-engine budget for the batch's lifetime:
    // each job's kernels then get `budget / threads` threads, so job-level
    // × kernel-level parallelism (e.g. `skglm cv --workers N`) never
    // oversubscribes the machine
    let _kernel_budget = crate::linalg::parallel::register_solver_workers(threads);
    let next = AtomicUsize::new(0);
    let jobs: Vec<Mutex<Option<F>>> = jobs.into_iter().map(|j| Mutex::new(Some(j))).collect();
    let results: Vec<Mutex<Option<T>>> = (0..n).map(|_| Mutex::new(None)).collect();

    std::thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let job = jobs[i].lock().unwrap().take().expect("job taken twice");
                let out = job();
                *results[i].lock().unwrap() = Some(out);
            });
        }
    });
    results
        .into_iter()
        .map(|m| m.into_inner().unwrap().expect("job did not complete"))
        .collect()
}

/// Number of worker threads to use by default: the kernel engine's global
/// thread budget (`--threads` > `SKGLM_THREADS` > hardware parallelism),
/// so job-level and kernel-level parallelism read one consistent number.
pub fn default_threads() -> usize {
    crate::linalg::parallel::thread_budget()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_order() {
        let jobs: Vec<_> = (0..20).map(|i| move || i * i).collect();
        let out = run_parallel(jobs, 4);
        assert_eq!(out, (0..20).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn single_thread_path() {
        let jobs: Vec<_> = (0..5).map(|i| move || i + 1).collect();
        assert_eq!(run_parallel(jobs, 1), vec![1, 2, 3, 4, 5]);
    }

    #[test]
    fn empty_input() {
        let jobs: Vec<Box<dyn FnOnce() -> i32 + Send>> = vec![];
        assert!(run_parallel(jobs, 8).is_empty());
    }

    #[test]
    fn more_threads_than_jobs() {
        let jobs: Vec<_> = (0..3).map(|i| move || i).collect();
        assert_eq!(run_parallel(jobs, 64), vec![0, 1, 2]);
    }
}
