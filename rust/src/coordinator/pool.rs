//! Tiny work-stealing-free thread pool: run a batch of closures on up to
//! `threads` workers and return results in input order.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Run all jobs, at most `threads` at a time; preserves input order in the
/// output.
///
/// A panicking job no longer kills its worker mid-batch: panics are
/// caught per job so the *other* jobs still complete, then the first
/// panic is re-raised with its job index and original message attached —
/// one divergent fit cannot silently swallow a CV fold batch.
pub fn run_parallel<T, F>(jobs: Vec<F>, threads: usize) -> Vec<T>
where
    T: Send,
    F: FnOnce() -> T + Send,
{
    let n = jobs.len();
    if n == 0 {
        return Vec::new();
    }
    let threads = threads.max(1).min(n);
    if threads == 1 {
        return jobs.into_iter().map(|j| j()).collect();
    }
    // register against the kernel-engine budget for the batch's lifetime:
    // each job's kernels then get `budget / threads` threads, so job-level
    // × kernel-level parallelism (e.g. `skglm cv --workers N`) never
    // oversubscribes the machine
    let _kernel_budget = crate::linalg::parallel::register_solver_workers(threads);
    let next = AtomicUsize::new(0);
    let jobs: Vec<Mutex<Option<F>>> = jobs.into_iter().map(|j| Mutex::new(Some(j))).collect();
    let results: Vec<Mutex<Option<T>>> = (0..n).map(|_| Mutex::new(None)).collect();
    let failures: Mutex<Vec<(usize, String)>> = Mutex::new(Vec::new());

    std::thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| loop {
                // relaxed claim counter: indices only partition jobs;
                // results flow through the per-slot mutexes
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let job = jobs[i].lock().unwrap().take().expect("job taken twice");
                match std::panic::catch_unwind(std::panic::AssertUnwindSafe(job)) {
                    Ok(out) => *results[i].lock().unwrap() = Some(out),
                    Err(payload) => {
                        let msg = super::scheduler::panic_message(payload);
                        failures.lock().unwrap().push((i, msg));
                    }
                }
            });
        }
    });
    let mut failures = failures.into_inner().unwrap();
    if !failures.is_empty() {
        failures.sort_by_key(|(i, _)| *i);
        let (i, msg) = &failures[0];
        panic!("pool job {i} panicked ({} of {n} jobs failed): {msg}", failures.len());
    }
    results
        .into_iter()
        .map(|m| m.into_inner().unwrap().expect("job did not complete"))
        .collect()
}

/// Number of worker threads to use by default: the kernel engine's global
/// thread budget (`--threads` > `SKGLM_THREADS` > hardware parallelism),
/// so job-level and kernel-level parallelism read one consistent number.
pub fn default_threads() -> usize {
    crate::linalg::parallel::thread_budget()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_order() {
        let jobs: Vec<_> = (0..20).map(|i| move || i * i).collect();
        let out = run_parallel(jobs, 4);
        assert_eq!(out, (0..20).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn single_thread_path() {
        let jobs: Vec<_> = (0..5).map(|i| move || i + 1).collect();
        assert_eq!(run_parallel(jobs, 1), vec![1, 2, 3, 4, 5]);
    }

    #[test]
    fn empty_input() {
        let jobs: Vec<Box<dyn FnOnce() -> i32 + Send>> = vec![];
        assert!(run_parallel(jobs, 8).is_empty());
    }

    #[test]
    fn more_threads_than_jobs() {
        let jobs: Vec<_> = (0..3).map(|i| move || i).collect();
        assert_eq!(run_parallel(jobs, 64), vec![0, 1, 2]);
    }

    #[test]
    fn panicking_job_reports_index_and_message_after_batch() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        static COMPLETED: AtomicUsize = AtomicUsize::new(0);
        let jobs: Vec<Box<dyn FnOnce() -> usize + Send>> = (0..6)
            .map(|i| {
                Box::new(move || {
                    if i == 2 {
                        panic!("fold diverged");
                    }
                    COMPLETED.fetch_add(1, Ordering::SeqCst);
                    i
                }) as Box<dyn FnOnce() -> usize + Send>
            })
            .collect();
        let err =
            std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| run_parallel(jobs, 3)))
                .unwrap_err();
        let msg = err
            .downcast_ref::<String>()
            .cloned()
            .unwrap_or_else(|| "?".into());
        assert!(msg.contains("pool job 2"), "index lost: {msg}");
        assert!(msg.contains("fold diverged"), "original message lost: {msg}");
        // the other five jobs ran to completion despite the panic
        assert_eq!(COMPLETED.load(Ordering::SeqCst), 5);
    }
}
