//! Deterministic fault injection for the fit service (`SKGLM_FAULTS` /
//! `--faults`). Every degradation path the service claims to survive is
//! exercised by injecting the degradation on purpose:
//!
//! | directive            | effect                                              |
//! |----------------------|-----------------------------------------------------|
//! | `panic@N`            | the N-th accepted submit panics on its worker       |
//! | `panic_seed=S`       | any job whose dataset seed is S panics              |
//! | `slow=MS`            | every solve sleeps MS ms first                      |
//! | `slow=MS@N`          | only the N-th accepted submit sleeps                |
//! | `worker_exit@N`      | one worker dies when the N-th submit is accepted    |
//! | `die_seed=S`         | one worker dies when a seed-S job is accepted       |
//! | `drop_conn_tenant=T@N` | close tenant T's connections after N frames sent  |
//! | `truncate_tenant=T@N`  | truncate tenant T's N-th outbound frame           |
//! | `cache_bytes=B`      | shrink the dataset-cache byte budget to B           |
//! | `tenant_bytes=B`     | shrink the per-tenant byte budget to B              |
//!
//! Counters are deterministic (accepted-submit order / per-connection
//! frame order), so a scripted session can predict exactly which of its
//! jobs and frames degrade. Plans compose comma-separated:
//! `slow=150,panic_seed=666999,truncate_tenant=evil@2`.

use super::job::FitSpec;
use crate::solver::{ContinuationState, FitResult, SolverOpts};
use std::time::Duration;

/// Parsed fault plan (empty by default — no faults).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct FaultPlan {
    /// accepted-submit indices (0-based) whose solve panics
    pub panic_jobs: Vec<usize>,
    /// dataset seeds whose solve panics
    pub panic_seeds: Vec<u64>,
    /// sleep applied to every solve, ms
    pub slow_all_ms: Option<u64>,
    /// (accepted-submit index, ms) targeted slowness
    pub slow_jobs: Vec<(usize, u64)>,
    /// (dataset seed, ms) — any job on a seed-S dataset sleeps per solve
    pub slow_seeds: Vec<(u64, u64)>,
    /// accepted-submit indices that kill one worker on acceptance
    pub worker_exit_jobs: Vec<usize>,
    /// dataset seeds that kill one worker on acceptance
    pub die_seeds: Vec<u64>,
    /// (tenant, frames) — close the connection after N outbound frames
    pub drop_conn_tenant: Vec<(String, usize)>,
    /// (tenant, frame index 1-based) — truncate that outbound frame
    pub truncate_tenant: Vec<(String, usize)>,
    /// override for the dataset-cache byte budget
    pub cache_bytes: Option<usize>,
    /// override for the per-tenant byte budget
    pub tenant_bytes: Option<usize>,
}

impl FaultPlan {
    pub fn is_empty(&self) -> bool {
        *self == FaultPlan::default()
    }

    /// Parse a comma-separated plan; unknown directives are errors (a
    /// fault plan that silently no-ops would defeat the harness).
    pub fn parse(s: &str) -> Result<FaultPlan, String> {
        let mut plan = FaultPlan::default();
        for part in s.split(',').map(str::trim).filter(|p| !p.is_empty()) {
            let (key, rest) = match part.split_once('=') {
                Some((k, v)) => (k, Some(v)),
                None => match part.split_once('@') {
                    Some((k, _)) => (k, None),
                    None => (part, None),
                },
            };
            match key {
                "panic" => plan.panic_jobs.push(parse_at(part, "panic")?),
                "panic_seed" => plan.panic_seeds.push(parse_num(rest, part)?),
                "slow" => {
                    let v = rest.ok_or_else(|| format!("slow needs =MS in {part:?}"))?;
                    match v.split_once('@') {
                        Some((ms, idx)) => plan.slow_jobs.push((
                            idx.parse().map_err(|_| format!("bad index in {part:?}"))?,
                            ms.parse().map_err(|_| format!("bad ms in {part:?}"))?,
                        )),
                        None => {
                            plan.slow_all_ms =
                                Some(v.parse().map_err(|_| format!("bad ms in {part:?}"))?)
                        }
                    }
                }
                "slow_seed" => {
                    let v = rest.ok_or_else(|| format!("slow_seed needs =SEED@MS in {part:?}"))?;
                    let (seed, ms) =
                        v.split_once('@').ok_or_else(|| format!("missing @MS in {part:?}"))?;
                    plan.slow_seeds.push((
                        seed.parse().map_err(|_| format!("bad seed in {part:?}"))?,
                        ms.parse().map_err(|_| format!("bad ms in {part:?}"))?,
                    ));
                }
                "worker_exit" => plan.worker_exit_jobs.push(parse_at(part, "worker_exit")?),
                "die_seed" => plan.die_seeds.push(parse_num(rest, part)?),
                "drop_conn_tenant" => {
                    let (t, n) = parse_tenant_at(rest, part)?;
                    plan.drop_conn_tenant.push((t, n));
                }
                "truncate_tenant" => {
                    let (t, n) = parse_tenant_at(rest, part)?;
                    plan.truncate_tenant.push((t, n));
                }
                "cache_bytes" => plan.cache_bytes = Some(parse_num(rest, part)? as usize),
                "tenant_bytes" => plan.tenant_bytes = Some(parse_num(rest, part)? as usize),
                other => return Err(format!("unknown fault directive {other:?} in {part:?}")),
            }
        }
        Ok(plan)
    }

    /// Resolve the active plan: an explicit `--faults` value wins, then
    /// `SKGLM_FAULTS`, then the empty plan.
    pub fn from_env(cli: Option<&str>) -> Result<FaultPlan, String> {
        match cli {
            Some(s) => FaultPlan::parse(s),
            None => match std::env::var("SKGLM_FAULTS") {
                Ok(s) if !s.trim().is_empty() => FaultPlan::parse(&s),
                _ => Ok(FaultPlan::default()),
            },
        }
    }

    /// Faults for the `submit_index`-th accepted submit of a job whose
    /// dataset seed is `seed`.
    pub fn job_faults(&self, submit_index: usize, seed: u64) -> JobFaults {
        let mut slow_ms = self.slow_all_ms.unwrap_or(0);
        if let Some(&(_, ms)) =
            self.slow_jobs.iter().find(|&&(idx, _)| idx == submit_index)
        {
            slow_ms = slow_ms.max(ms);
        }
        if let Some(&(_, ms)) = self.slow_seeds.iter().find(|&&(s, _)| s == seed) {
            slow_ms = slow_ms.max(ms);
        }
        JobFaults {
            panic: self.panic_jobs.contains(&submit_index) || self.panic_seeds.contains(&seed),
            slow_ms,
            kill_worker: self.worker_exit_jobs.contains(&submit_index)
                || self.die_seeds.contains(&seed),
        }
    }

    /// Connection faults for a tenant, or `None` when unaffected.
    pub fn conn_faults(&self, tenant: &str) -> ConnFaults {
        ConnFaults {
            drop_after: self
                .drop_conn_tenant
                .iter()
                .find(|(t, _)| t == tenant)
                .map(|&(_, n)| n),
            truncate_at: self
                .truncate_tenant
                .iter()
                .find(|(t, _)| t == tenant)
                .map(|&(_, n)| n),
        }
    }
}

fn parse_at(part: &str, key: &str) -> Result<usize, String> {
    part.strip_prefix(key)
        .and_then(|r| r.strip_prefix('@'))
        .and_then(|n| n.parse().ok())
        .ok_or_else(|| format!("{key} needs @INDEX in {part:?}"))
}

fn parse_num(rest: Option<&str>, part: &str) -> Result<u64, String> {
    rest.and_then(|v| v.parse().ok()).ok_or_else(|| format!("bad number in {part:?}"))
}

fn parse_tenant_at(rest: Option<&str>, part: &str) -> Result<(String, usize), String> {
    let v = rest.ok_or_else(|| format!("missing =TENANT@N in {part:?}"))?;
    let (t, n) = v.split_once('@').ok_or_else(|| format!("missing @N in {part:?}"))?;
    Ok((t.to_string(), n.parse().map_err(|_| format!("bad frame count in {part:?}"))?))
}

/// Faults resolved for one job.
#[derive(Clone, Copy, Debug, Default)]
pub struct JobFaults {
    pub panic: bool,
    pub slow_ms: u64,
    pub kill_worker: bool,
}

impl JobFaults {
    pub fn is_empty(&self) -> bool {
        !self.panic && self.slow_ms == 0 && !self.kill_worker
    }
}

/// Faults resolved for one connection.
#[derive(Clone, Copy, Debug, Default)]
pub struct ConnFaults {
    /// close the socket after this many outbound frames
    pub drop_after: Option<usize>,
    /// truncate this (1-based) outbound frame, then close
    pub truncate_at: Option<usize>,
}

/// Delegating [`FitSpec`] wrapper that injects slowness and/or a panic
/// into every solve (path points included, via `at_lambda`).
pub struct FaultSpec {
    inner: Box<dyn FitSpec>,
    slow_ms: u64,
    panic: bool,
}

impl FaultSpec {
    pub fn wrap(inner: Box<dyn FitSpec>, faults: &JobFaults) -> Box<dyn FitSpec> {
        if faults.slow_ms == 0 && !faults.panic {
            return inner;
        }
        Box::new(FaultSpec { inner, slow_ms: faults.slow_ms, panic: faults.panic })
    }
}

impl FitSpec for FaultSpec {
    fn label(&self) -> String {
        self.inner.label()
    }
    fn datafit_name(&self) -> &'static str {
        self.inner.datafit_name()
    }
    fn family(&self) -> &'static str {
        self.inner.family()
    }
    fn lambda(&self) -> f64 {
        self.inner.lambda()
    }
    fn is_convex(&self) -> bool {
        // keep injected jobs away from the coefficient cache: a panic
        // mid-solve must not poison warm starts for healthy jobs
        false
    }
    fn normalize_design(&self) -> bool {
        self.inner.normalize_design()
    }
    fn lambda_max(&self, design: &crate::linalg::Design, y: &[f64]) -> f64 {
        self.inner.lambda_max(design, y)
    }
    fn at_lambda(&self, lambda: f64) -> Box<dyn FitSpec> {
        Box::new(FaultSpec {
            inner: self.inner.at_lambda(lambda),
            slow_ms: self.slow_ms,
            panic: self.panic,
        })
    }
    fn solve(
        &self,
        design: &crate::linalg::Design,
        y: &[f64],
        opts: &SolverOpts,
        state: &mut ContinuationState,
        col_sq_norms: Option<&[f64]>,
        frozen: Option<&[bool]>,
    ) -> FitResult {
        if self.slow_ms > 0 {
            std::thread::sleep(Duration::from_millis(self.slow_ms));
        }
        if self.panic {
            panic!("injected worker fault (fault plan)");
        }
        self.inner.solve(design, y, opts, state, col_sq_norms, frozen)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_composite_plan() {
        let plan = FaultPlan::parse(
            "panic@3,slow=150,slow=40@5,slow_seed=111@200,worker_exit@7,panic_seed=666999,\
             die_seed=42,drop_conn_tenant=evil@2,truncate_tenant=chaos@1,cache_bytes=4096,\
             tenant_bytes=1024",
        )
        .unwrap();
        assert_eq!(plan.panic_jobs, vec![3]);
        assert_eq!(plan.slow_all_ms, Some(150));
        assert_eq!(plan.slow_jobs, vec![(5, 40)]);
        assert_eq!(plan.slow_seeds, vec![(111, 200)]);
        assert_eq!(plan.worker_exit_jobs, vec![7]);
        assert_eq!(plan.panic_seeds, vec![666999]);
        assert_eq!(plan.die_seeds, vec![42]);
        assert_eq!(plan.drop_conn_tenant, vec![("evil".to_string(), 2)]);
        assert_eq!(plan.truncate_tenant, vec![("chaos".to_string(), 1)]);
        assert_eq!(plan.cache_bytes, Some(4096));
        assert_eq!(plan.tenant_bytes, Some(1024));
        assert!(!plan.is_empty());
    }

    #[test]
    fn empty_and_unknown_plans() {
        assert!(FaultPlan::parse("").unwrap().is_empty());
        assert!(FaultPlan::parse("  ").unwrap().is_empty());
        assert!(FaultPlan::parse("frobnicate=1").is_err());
        assert!(FaultPlan::parse("panic").is_err(), "panic needs an index");
        assert!(FaultPlan::parse("slow").is_err(), "slow needs a duration");
    }

    #[test]
    fn job_faults_resolve_by_index_and_seed() {
        let plan = FaultPlan::parse("panic@1,slow=10,slow=90@2,die_seed=7").unwrap();
        let f0 = plan.job_faults(0, 0);
        assert!(!f0.panic && f0.slow_ms == 10 && !f0.kill_worker);
        let f1 = plan.job_faults(1, 0);
        assert!(f1.panic);
        let f2 = plan.job_faults(2, 7);
        assert!(f2.slow_ms == 90 && f2.kill_worker);
    }

    #[test]
    fn conn_faults_resolve_by_tenant() {
        let plan = FaultPlan::parse("drop_conn_tenant=evil@3").unwrap();
        assert_eq!(plan.conn_faults("evil").drop_after, Some(3));
        assert_eq!(plan.conn_faults("good").drop_after, None);
        assert_eq!(plan.conn_faults("good").truncate_at, None);
    }
}
