//! Trait-based fit specifications — the open replacement for the old
//! closed `EstimatorSpec` enum.
//!
//! A [`FitSpec`] is *any* datafit × penalty combination the solver layer
//! supports, packaged with the conventions the scheduler needs to run it
//! well: its λ_max rule (path grids), whether the paper's √n column
//! normalization applies (MCP/SCAD/ℓ_q), whether the objective is convex
//! (safe coefficient-cache reuse), and whether gap-safe screening is
//! sound for it (quadratic × ℓ1). [`GlmSpec`] is the generic
//! implementation — one monomorphized `solve` call behind a trait object
//! — and [`specs`] provides constructors for the paper's model zoo.

use crate::datafit::{Datafit, GroupedQuadratic, Logistic, Poisson, Probit, Quadratic};
use crate::datafit::multitask::QuadraticMultiTask;
use crate::estimators::linear::quadratic_lambda_max;
use crate::linalg::Design;
use crate::penalty::{
    BatchPenalty, BlockPenalty, GroupLasso, GroupMcp, GroupScad, WeightedGroupLasso, L1L2, Lq,
    Mcp, Penalty, Scad, L1,
};
use crate::solver::{
    block_lambda_max_for, glm_lambda_max, solve_batch, solve_blocks_continued, solve_continued,
    solve_prox_newton_continued, BatchFit, BlockDatafit, BlockPartition, ContinuationState,
    FitResult, GroupScreenCfg, SolverOpts,
};
use std::sync::Arc;

/// Which outer solver drives a [`GlmSpec`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SolverTopology {
    /// Direct working-set CD (Algorithm 1) — requires precomputable
    /// per-coordinate Lipschitz constants.
    DirectCd,
    /// Prox-Newton outer × CD inner ([`crate::solver::prox_newton`]) —
    /// curvature-adaptive; the only valid topology for datafits with
    /// unbounded curvature (Poisson).
    ProxNewton,
}

/// An executable fit specification: everything the scheduler needs to run
/// one (datafit, penalty, λ) problem on a worker, including along a
/// warm-started path.
pub trait FitSpec: Send + Sync {
    /// Human-readable tag used in streamed results (e.g. `quadratic/mcp`).
    fn label(&self) -> String;

    /// The datafit's [`Datafit::name`] (coefficient-cache key part).
    fn datafit_name(&self) -> &'static str;

    /// Penalty-family tag (coefficient-cache key part), e.g. `"l1"`.
    fn family(&self) -> &'static str;

    /// Current regularization strength.
    fn lambda(&self) -> f64;

    /// Convex objective? Controls warm-start reuse across jobs: for
    /// convex problems any starting point converges to the same optimum,
    /// so cached coefficients are safe to reuse; non-convex fits always
    /// cold-start (the critical point reached depends on the init).
    fn is_convex(&self) -> bool;

    /// Whether the paper's √n column-normalization convention applies
    /// (MCP / SCAD / ℓ_q); the scheduler then solves on the cached
    /// normalized design.
    fn normalize_design(&self) -> bool;

    /// Smallest λ whose solution is all-zero (anchors path grids).
    fn lambda_max(&self, design: &Design, y: &[f64]) -> f64;

    /// The same specification at a different λ (path sweeps).
    fn at_lambda(&self, lambda: f64) -> Box<dyn FitSpec>;

    /// Gap-safe screening is sound for this spec (convex quadratic × ℓ1).
    fn supports_gap_screening(&self) -> bool {
        false
    }

    /// This spec's penalty in the batched solver's closed universe, if
    /// the spec is eligible for many-fit fusion (direct-CD quadratic ×
    /// a [`Penalty::as_batchable`] penalty). `None` — the default —
    /// opts out: the scheduler never coalesces jobs carrying this spec.
    fn batch_penalty(&self) -> Option<BatchPenalty> {
        None
    }

    /// Per-row 0/1 observation weights (CV-fold membership masks);
    /// `None` = fit on every row. Weighted specs run the masked
    /// quadratic datafit — standalone via a one-member batch, fused as
    /// a panel column of a batched job.
    fn row_weights(&self) -> Option<Arc<Vec<f64>>> {
        None
    }

    /// Solve on `design`/`y`, warm-starting from `state` and updating it
    /// with the outcome. `col_sq_norms` is the cached Gram diagonal
    /// (skips the per-fit O(nnz) recomputation); `frozen` marks features
    /// certified inactive at this λ (excluded from scoring and the
    /// working set).
    fn solve(
        &self,
        design: &Design,
        y: &[f64],
        opts: &SolverOpts,
        state: &mut ContinuationState,
        col_sq_norms: Option<&[f64]>,
        frozen: Option<&[bool]>,
    ) -> FitResult;
}

/// Closure type producing the penalty at a given λ (path sweeps).
pub type MakePenalty<P> = Arc<dyn Fn(f64) -> P + Send + Sync>;
/// Closure type computing λ_max for the datafit.
pub type LambdaMax = Arc<dyn Fn(&Design, &[f64]) -> f64 + Send + Sync>;

/// Generic [`FitSpec`]: any [`Datafit`] × [`Penalty`] the solver layer
/// accepts, monomorphized once behind the trait object.
pub struct GlmSpec<D: Datafit + 'static, P: Penalty + 'static> {
    datafit: D,
    penalty: P,
    family: &'static str,
    lambda: f64,
    normalize: bool,
    topology: SolverTopology,
    make: MakePenalty<P>,
    lambda_max: LambdaMax,
}

impl<D: Datafit + 'static, P: Penalty + 'static> GlmSpec<D, P> {
    /// Build a spec from its parts. `make(λ)` must construct the penalty
    /// at strength λ; `lambda_max` anchors path grids for the datafit.
    pub fn new(
        datafit: D,
        family: &'static str,
        lambda: f64,
        normalize: bool,
        make: MakePenalty<P>,
        lambda_max: LambdaMax,
    ) -> Self {
        let penalty = make(lambda);
        Self {
            datafit,
            penalty,
            family,
            lambda,
            normalize,
            topology: SolverTopology::DirectCd,
            make,
            lambda_max,
        }
    }

    /// Route this spec through the prox-Newton outer solver (the datafit
    /// must implement the raw-curvature protocol).
    pub fn with_prox_newton(mut self) -> Self {
        self.topology = SolverTopology::ProxNewton;
        self
    }

    /// Box into a trait object (scheduler job form).
    pub fn boxed(self) -> Box<dyn FitSpec> {
        Box::new(self)
    }
}

impl<D: Datafit + 'static, P: Penalty + 'static> FitSpec for GlmSpec<D, P> {
    fn label(&self) -> String {
        format!("{}/{}", self.datafit.name(), self.family)
    }

    fn datafit_name(&self) -> &'static str {
        self.datafit.name()
    }

    fn family(&self) -> &'static str {
        self.family
    }

    fn lambda(&self) -> f64 {
        self.lambda
    }

    fn is_convex(&self) -> bool {
        self.penalty.is_convex()
    }

    fn normalize_design(&self) -> bool {
        self.normalize
    }

    fn lambda_max(&self, design: &Design, y: &[f64]) -> f64 {
        (self.lambda_max)(design, y)
    }

    fn at_lambda(&self, lambda: f64) -> Box<dyn FitSpec> {
        Box::new(GlmSpec {
            datafit: self.datafit.clone(),
            penalty: (self.make)(lambda),
            family: self.family,
            lambda,
            normalize: self.normalize,
            topology: self.topology,
            make: Arc::clone(&self.make),
            lambda_max: Arc::clone(&self.lambda_max),
        })
    }

    fn supports_gap_screening(&self) -> bool {
        // the screened-lasso fast path IS a direct-CD solve: a quadratic
        // × ℓ1 spec explicitly routed to prox-Newton must not be hijacked
        // by it, or topology comparisons silently measure direct CD
        self.topology == SolverTopology::DirectCd
            && self.datafit_name() == "quadratic"
            && self.family == "l1"
    }

    fn batch_penalty(&self) -> Option<BatchPenalty> {
        // the batched engine is a direct-CD quadratic solver: prox-Newton
        // topologies and non-quadratic datafits must never be coalesced
        // into it, whatever their penalty
        if self.topology != SolverTopology::DirectCd || self.datafit.name() != "quadratic" {
            return None;
        }
        self.penalty.as_batchable()
    }

    fn solve(
        &self,
        design: &Design,
        y: &[f64],
        opts: &SolverOpts,
        state: &mut ContinuationState,
        col_sq_norms: Option<&[f64]>,
        frozen: Option<&[bool]>,
    ) -> FitResult {
        let mut datafit = self.datafit.clone();
        match self.topology {
            SolverTopology::DirectCd => solve_continued(
                design,
                y,
                &mut datafit,
                &self.penalty,
                opts,
                None,
                state,
                frozen,
                col_sq_norms,
            ),
            // prox-Newton has no screening support: `frozen` certificates
            // only ever come from specs with `supports_gap_screening()`,
            // which no prox-Newton spec reports
            SolverTopology::ProxNewton => solve_prox_newton_continued(
                design,
                y,
                &mut datafit,
                &self.penalty,
                opts,
                state,
                col_sq_norms,
            ),
        }
    }
}

/// A batchable [`FitSpec`] carrying optional per-row 0/1 observation
/// weights — the job form of one member of a fused many-fit batch.
///
/// Wrapping is what lets CV folds become *sibling scheduler jobs*: k
/// wrapped specs over the same dataset differ only in their row masks,
/// so the scheduler's fusion pass coalesces them into one
/// [`solve_batch`] call sharing every design read. A wrapped spec also
/// runs correctly standalone (no siblings queued): the masked path
/// routes through a one-member batch, which is bitwise the arithmetic
/// the fused path would run for that member.
pub struct BatchedFitSpec {
    inner: Box<dyn FitSpec>,
    weights: Option<Arc<Vec<f64>>>,
}

impl BatchedFitSpec {
    /// Wrap a batchable spec. Panics if the spec opted out of batching —
    /// a weighted fit on a non-batchable spec has no engine to run on.
    pub fn new(inner: Box<dyn FitSpec>) -> Self {
        assert!(
            inner.batch_penalty().is_some(),
            "spec {} is not batchable (direct-CD quadratic × {{l1, mcp}} only)",
            inner.label()
        );
        Self { inner, weights: None }
    }

    /// Attach per-row 0/1 weights (CV-fold membership mask).
    pub fn with_row_weights(mut self, weights: Arc<Vec<f64>>) -> Self {
        self.weights = Some(weights);
        self
    }

    pub fn boxed(self) -> Box<dyn FitSpec> {
        Box::new(self)
    }
}

impl FitSpec for BatchedFitSpec {
    fn label(&self) -> String {
        if self.weights.is_some() {
            format!("{}+mask", self.inner.label())
        } else {
            self.inner.label()
        }
    }

    fn datafit_name(&self) -> &'static str {
        self.inner.datafit_name()
    }

    fn family(&self) -> &'static str {
        self.inner.family()
    }

    fn lambda(&self) -> f64 {
        self.inner.lambda()
    }

    fn is_convex(&self) -> bool {
        // a masked member's optimum is a *fold* optimum, not the
        // full-data one: sharing the coefficient cache with unmasked
        // jobs of the same (datafit, family) would warm-start — and,
        // worse, store — the wrong solution, so masked specs report
        // non-convex to opt out of cache reuse entirely
        self.weights.is_none() && self.inner.is_convex()
    }

    fn normalize_design(&self) -> bool {
        self.inner.normalize_design()
    }

    fn lambda_max(&self, design: &Design, y: &[f64]) -> f64 {
        match &self.weights {
            None => self.inner.lambda_max(design, y),
            Some(w) => {
                crate::solver::batch_lambda_max(design, y, &[Some(Arc::clone(w))])[0]
            }
        }
    }

    fn at_lambda(&self, lambda: f64) -> Box<dyn FitSpec> {
        Box::new(BatchedFitSpec {
            inner: self.inner.at_lambda(lambda),
            weights: self.weights.clone(),
        })
    }

    fn supports_gap_screening(&self) -> bool {
        // the screened fast path has no masked-row support
        self.weights.is_none() && self.inner.supports_gap_screening()
    }

    fn batch_penalty(&self) -> Option<BatchPenalty> {
        self.inner.batch_penalty()
    }

    fn row_weights(&self) -> Option<Arc<Vec<f64>>> {
        self.weights.clone()
    }

    fn solve(
        &self,
        design: &Design,
        y: &[f64],
        opts: &SolverOpts,
        state: &mut ContinuationState,
        col_sq_norms: Option<&[f64]>,
        frozen: Option<&[bool]>,
    ) -> FitResult {
        let Some(weights) = &self.weights else {
            return self.inner.solve(design, y, opts, state, col_sq_norms, frozen);
        };
        // standalone masked solve: a one-member batch — bitwise the
        // arithmetic the fused scheduler path runs for this member
        let pen = self.batch_penalty().expect("checked at construction");
        let mut fit = BatchFit::new(pen).with_row_weights(Arc::clone(weights));
        if let Some(beta) = &state.beta {
            fit = fit.warm(beta.clone(), state.ws_size);
        }
        let mut out =
            solve_batch(design, y, vec![fit], opts, col_sq_norms, state.gram.clone());
        let member = out.members.pop().expect("one-member batch returns one result");
        state.update_from(&member.result);
        member.result
    }
}

/// Closure type producing a block penalty at a given λ (path sweeps).
pub type MakeBlockPenalty<B> = Arc<dyn Fn(f64) -> B + Send + Sync>;

/// Generic block-problem [`FitSpec`]: any [`BlockDatafit`] ×
/// [`BlockPenalty`] over a [`BlockPartition`] — group penalties and
/// multitask fits become first-class scheduler jobs (warm `Job::Path`
/// sweeps, dataset/coefficient cache, CV) through this one
/// monomorphization, exactly as [`GlmSpec`] does for scalar models.
pub struct BlockSpec<D: BlockDatafit + 'static, B: BlockPenalty + 'static> {
    datafit: D,
    penalty: B,
    part: Arc<BlockPartition>,
    family: &'static str,
    lambda: f64,
    make: MakeBlockPenalty<B>,
    /// per-block dual-norm weights (λ_max grids / screening radii);
    /// `None` = all ones
    weights: Option<Arc<Vec<f64>>>,
    /// enable the per-block gap-safe screening hook inside solves —
    /// sound only for the grouped quadratic × (weighted) ℓ2,1 case
    gap_screen: bool,
}

impl<D: BlockDatafit + 'static, B: BlockPenalty + 'static> BlockSpec<D, B> {
    pub fn new(
        datafit: D,
        part: Arc<BlockPartition>,
        family: &'static str,
        lambda: f64,
        make: MakeBlockPenalty<B>,
    ) -> Self {
        let penalty = make(lambda);
        Self { datafit, penalty, part, family, lambda, make, weights: None, gap_screen: false }
    }

    /// Attach per-block dual-norm weights (weighted group Lasso).
    pub fn with_weights(mut self, weights: Vec<f64>) -> Self {
        assert_eq!(weights.len(), self.part.n_blocks());
        self.weights = Some(Arc::new(weights));
        self
    }

    /// Enable gap-safe block screening (grouped quadratic × convex ℓ2,1
    /// penalties only — asserted at solve time).
    pub fn with_gap_screening(mut self) -> Self {
        self.gap_screen = true;
        self
    }

    pub fn boxed(self) -> Box<dyn FitSpec> {
        Box::new(self)
    }
}

impl<D: BlockDatafit + 'static, B: BlockPenalty + 'static> FitSpec for BlockSpec<D, B> {
    fn label(&self) -> String {
        format!("{}/{}", self.datafit.name(), self.family)
    }

    fn datafit_name(&self) -> &'static str {
        self.datafit.name()
    }

    fn family(&self) -> &'static str {
        self.family
    }

    fn lambda(&self) -> f64 {
        self.lambda
    }

    fn is_convex(&self) -> bool {
        self.penalty.is_convex()
    }

    fn normalize_design(&self) -> bool {
        // block specs solve on the raw design: the grouped Lipschitz
        // bounds already absorb column-scale heterogeneity, and the
        // multitask M/EEG convention keeps the leadfield unscaled
        false
    }

    fn lambda_max(&self, design: &Design, y: &[f64]) -> f64 {
        let mut datafit = self.datafit.clone();
        let weights = self.weights.as_deref().map(|w| &w[..]);
        block_lambda_max_for(design, y, &mut datafit, &self.part, weights)
    }

    fn at_lambda(&self, lambda: f64) -> Box<dyn FitSpec> {
        Box::new(BlockSpec {
            datafit: self.datafit.clone(),
            penalty: (self.make)(lambda),
            part: Arc::clone(&self.part),
            family: self.family,
            lambda,
            make: Arc::clone(&self.make),
            weights: self.weights.clone(),
            gap_screen: self.gap_screen,
        })
    }

    // the scalar screened-lasso fast path must never hijack a block spec:
    // block screening runs *inside* solve() via GroupScreenCfg instead
    fn supports_gap_screening(&self) -> bool {
        false
    }

    fn solve(
        &self,
        design: &Design,
        y: &[f64],
        opts: &SolverOpts,
        state: &mut ContinuationState,
        col_sq_norms: Option<&[f64]>,
        _frozen: Option<&[bool]>,
    ) -> FitResult {
        let mut datafit = self.datafit.clone();
        let screen = if self.gap_screen && self.penalty.is_convex() {
            // the sphere test assumes the grouped quadratic's residual
            // state and column-partition — reject misuse loudly instead
            // of certifying wrong zeros on another datafit
            assert_eq!(
                self.datafit.name(),
                "grouped_quadratic",
                "gap-safe block screening is only sound for the grouped quadratic datafit"
            );
            let weights: Vec<f64> = match &self.weights {
                Some(w) => w.as_ref().clone(),
                None => vec![1.0; self.part.n_blocks()],
            };
            let grouped_sq = match col_sq_norms {
                Some(sq) => crate::linalg::group_reduce_sq(
                    sq,
                    self.part.flat_indices(),
                    self.part.offsets(),
                ),
                None => design.group_sq_norms(self.part.flat_indices(), self.part.offsets()),
            };
            Some(GroupScreenCfg {
                lambda: self.lambda,
                weights,
                block_frob: grouped_sq.iter().map(|s| s.sqrt()).collect(),
            })
        } else {
            None
        };
        let result = solve_blocks_continued(
            design,
            y,
            &self.part,
            &mut datafit,
            &self.penalty,
            opts,
            state,
            col_sq_norms,
            screen,
        );
        FitResult {
            beta: result.v,
            objective: result.objective,
            kkt: result.kkt,
            certificate: result.certificate,
            n_outer: result.n_outer,
            n_epochs: result.n_epochs,
            converged: result.converged,
            history: result.history,
            accepted_extrapolations: result.accepted_extrapolations,
            rejected_extrapolations: result.rejected_extrapolations,
            profile: result.profile,
        }
    }
}

/// Constructors for the paper's model zoo. Anything not listed here can
/// be built directly with [`GlmSpec::new`] — the point of the trait-based
/// job layer is that the scheduler does not enumerate models.
pub mod specs {
    use super::*;

    fn quad_lambda_max() -> LambdaMax {
        Arc::new(|d: &Design, y: &[f64]| quadratic_lambda_max(d, y))
    }

    /// Lasso: quadratic × ℓ1.
    pub fn lasso(lambda: f64) -> Box<dyn FitSpec> {
        let make: MakePenalty<L1> = Arc::new(L1::new);
        GlmSpec::new(Quadratic::new(), "l1", lambda, false, make, quad_lambda_max()).boxed()
    }

    /// Weighted Lasso: quadratic × per-feature-weighted ℓ1
    /// (`Σ_j λ w_j |β_j|`, weights ≥ 0; `w_j = 0` leaves feature j
    /// unpenalized). λ_max is taken over the penalized features only:
    /// `max_{j: w_j>0} |X_jᵀy| / (n w_j)` — with any zero weight the
    /// solution at λ_max is not identically zero (unpenalized features
    /// stay free), matching the weighted-ℓ1 KKT conditions.
    pub fn weighted_lasso(lambda: f64, weights: Vec<f64>) -> Box<dyn FitSpec> {
        use crate::penalty::WeightedL1;
        let shared = Arc::new(weights);
        let for_make = Arc::clone(&shared);
        let make: MakePenalty<WeightedL1> =
            Arc::new(move |l| WeightedL1::new(l, for_make.as_ref().clone()));
        let for_lmax = Arc::clone(&shared);
        let lmax: LambdaMax = Arc::new(move |d: &Design, y: &[f64]| {
            assert_eq!(for_lmax.len(), d.ncols(), "weights must match the design width");
            let n = d.nrows() as f64;
            let mut xty = vec![0.0; d.ncols()];
            d.matvec_t(y, &mut xty);
            xty.iter()
                .zip(for_lmax.iter())
                .filter(|(_, &w)| w > 0.0)
                .map(|(g, &w)| g.abs() / (n * w))
                .fold(0.0, f64::max)
        });
        GlmSpec::new(Quadratic::new(), "weighted_l1", lambda, false, make, lmax).boxed()
    }

    /// Elastic net: quadratic × (ρ‖·‖₁ + (1−ρ)‖·‖²/2).
    pub fn elastic_net(lambda: f64, l1_ratio: f64) -> Box<dyn FitSpec> {
        let make: MakePenalty<L1L2> = Arc::new(move |l| L1L2::new(l, l1_ratio));
        let lmax: LambdaMax = Arc::new(move |d: &Design, y: &[f64]| {
            quadratic_lambda_max(d, y) / l1_ratio.max(1e-12)
        });
        GlmSpec::new(Quadratic::new(), "l1l2", lambda, false, make, lmax).boxed()
    }

    /// MCP regression (paper √n normalization convention).
    pub fn mcp(lambda: f64, gamma: f64) -> Box<dyn FitSpec> {
        let make: MakePenalty<Mcp> = Arc::new(move |l| Mcp::new(l, gamma));
        GlmSpec::new(Quadratic::new(), "mcp", lambda, true, make, quad_lambda_max()).boxed()
    }

    /// SCAD regression (paper √n normalization convention).
    pub fn scad(lambda: f64, gamma: f64) -> Box<dyn FitSpec> {
        let make: MakePenalty<Scad> = Arc::new(move |l| Scad::new(l, gamma));
        GlmSpec::new(Quadratic::new(), "scad", lambda, true, make, quad_lambda_max()).boxed()
    }

    /// ℓ_q (q < 1) regression, `score^cd` scoring (paper Appendix C).
    pub fn lq(lambda: f64, q: f64) -> Box<dyn FitSpec> {
        let make: MakePenalty<Lq> = Arc::new(move |l| Lq::new(l, q));
        GlmSpec::new(Quadratic::new(), "lq", lambda, true, make, quad_lambda_max()).boxed()
    }

    /// ℓ1-regularised logistic regression (labels ±1).
    pub fn logistic_l1(lambda: f64) -> Box<dyn FitSpec> {
        let make: MakePenalty<L1> = Arc::new(L1::new);
        let lmax: LambdaMax = Arc::new(|d: &Design, y: &[f64]| {
            let n = d.nrows() as f64;
            let mut xty = vec![0.0; d.ncols()];
            d.matvec_t(y, &mut xty);
            crate::linalg::norm_inf(&xty) / (2.0 * n)
        });
        GlmSpec::new(Logistic::new(), "l1", lambda, false, make, lmax).boxed()
    }

    /// ℓ1-regularised **Poisson** regression (count targets, `exp` link).
    /// Unbounded curvature ⇒ routed through the prox-Newton topology.
    pub fn poisson_l1(lambda: f64) -> Box<dyn FitSpec> {
        let make: MakePenalty<L1> = Arc::new(L1::new);
        let lmax: LambdaMax =
            Arc::new(|d: &Design, y: &[f64]| glm_lambda_max(&Poisson::new(), d, y));
        GlmSpec::new(Poisson::new(), "l1", lambda, false, make, lmax)
            .with_prox_newton()
            .boxed()
    }

    /// ℓ1-regularised **probit** regression (labels ±1), prox-Newton
    /// topology (its bounded curvature also admits direct CD; Newton is
    /// the faster default for well-conditioned problems).
    pub fn probit_l1(lambda: f64) -> Box<dyn FitSpec> {
        let make: MakePenalty<L1> = Arc::new(L1::new);
        let lmax: LambdaMax =
            Arc::new(|d: &Design, y: &[f64]| glm_lambda_max(&Probit::new(), d, y));
        GlmSpec::new(Probit::new(), "l1", lambda, false, make, lmax)
            .with_prox_newton()
            .boxed()
    }

    /// Group Lasso over `part` (unweighted), gap-safe block screening on.
    pub fn group_lasso(lambda: f64, part: Arc<BlockPartition>) -> Box<dyn FitSpec> {
        let make: MakeBlockPenalty<GroupLasso> = Arc::new(GroupLasso::new);
        BlockSpec::new(GroupedQuadratic::new(Arc::clone(&part)), part, "group_lasso", lambda, make)
            .with_gap_screening()
            .boxed()
    }

    /// √|b|-weighted group Lasso over `part`, gap-safe block screening on.
    pub fn weighted_group_lasso(lambda: f64, part: Arc<BlockPartition>) -> Box<dyn FitSpec> {
        let weights: Vec<f64> =
            (0..part.n_blocks()).map(|b| (part.block_len(b) as f64).sqrt()).collect();
        let w = weights.clone();
        let make: MakeBlockPenalty<WeightedGroupLasso> =
            Arc::new(move |l| WeightedGroupLasso::new(l, w.clone()));
        BlockSpec::new(
            GroupedQuadratic::new(Arc::clone(&part)),
            part,
            "weighted_group_lasso",
            lambda,
            make,
        )
        .with_weights(weights)
        .with_gap_screening()
        .boxed()
    }

    /// Group MCP over `part` (non-convex — no screening, no warm-start
    /// reuse across jobs).
    pub fn group_mcp(lambda: f64, gamma: f64, part: Arc<BlockPartition>) -> Box<dyn FitSpec> {
        let make: MakeBlockPenalty<GroupMcp> = Arc::new(move |l| GroupMcp::new(l, gamma));
        BlockSpec::new(GroupedQuadratic::new(Arc::clone(&part)), part, "group_mcp", lambda, make)
            .boxed()
    }

    /// Group SCAD over `part`.
    pub fn group_scad(lambda: f64, gamma: f64, part: Arc<BlockPartition>) -> Box<dyn FitSpec> {
        let make: MakeBlockPenalty<GroupScad> = Arc::new(move |l| GroupScad::new(l, gamma));
        BlockSpec::new(GroupedQuadratic::new(Arc::clone(&part)), part, "group_scad", lambda, make)
            .boxed()
    }

    /// Multitask Lasso (ℓ2,1 on rows of `W ∈ R^{p×T}`) as a schedulable
    /// spec: the dataset's `y` must be task-major of length `n·T`.
    pub fn multitask_l21(lambda: f64, p: usize, n_tasks: usize) -> Box<dyn FitSpec> {
        let part = Arc::new(BlockPartition::uniform(p, n_tasks));
        let make: MakeBlockPenalty<crate::penalty::BlockL21> =
            Arc::new(crate::penalty::BlockL21::new);
        BlockSpec::new(QuadraticMultiTask::new(n_tasks), part, "l21", lambda, make).boxed()
    }

    /// Multitask block-MCP spec (non-convex rows).
    pub fn multitask_mcp(
        lambda: f64,
        gamma: f64,
        p: usize,
        n_tasks: usize,
    ) -> Box<dyn FitSpec> {
        let part = Arc::new(BlockPartition::uniform(p, n_tasks));
        let make: MakeBlockPenalty<crate::penalty::BlockMcp> =
            Arc::new(move |l| crate::penalty::BlockMcp::new(l, gamma));
        BlockSpec::new(QuadraticMultiTask::new(n_tasks), part, "block_mcp", lambda, make).boxed()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{correlated, CorrelatedSpec};

    #[test]
    fn spec_metadata_matches_conventions() {
        let l = specs::lasso(0.1);
        assert!(l.is_convex());
        assert!(!l.normalize_design());
        assert!(l.supports_gap_screening());
        assert_eq!(l.family(), "l1");
        assert_eq!(l.datafit_name(), "quadratic");

        let m = specs::mcp(0.1, 3.0);
        assert!(!m.is_convex());
        assert!(m.normalize_design());
        assert!(!m.supports_gap_screening());

        let e = specs::elastic_net(0.1, 0.5);
        assert!(e.is_convex());
        assert!(!e.supports_gap_screening());

        let po = specs::poisson_l1(0.1);
        assert!(po.is_convex());
        assert!(!po.normalize_design());
        assert!(!po.supports_gap_screening());
        assert_eq!(po.datafit_name(), "poisson");

        let pr = specs::probit_l1(0.1);
        assert_eq!(pr.datafit_name(), "probit");
        assert!(!pr.supports_gap_screening());
    }

    #[test]
    fn prox_newton_topology_disables_gap_screening() {
        // regression: the screened-lasso fast path is a direct-CD solve;
        // it must not hijack a quadratic×ℓ1 spec routed to prox-Newton
        let make: MakePenalty<L1> = Arc::new(L1::new);
        let lmax: LambdaMax = Arc::new(|d: &Design, y: &[f64]| quadratic_lambda_max(d, y));
        let spec =
            GlmSpec::new(Quadratic::new(), "l1", 0.1, false, make, lmax).with_prox_newton();
        assert!(!spec.supports_gap_screening());
        assert!(spec.at_lambda(0.05).as_ref().label().contains("quadratic"));
        assert!(!spec.at_lambda(0.05).supports_gap_screening(), "topology lost by at_lambda");
    }

    #[test]
    fn poisson_spec_solves_through_the_trait_object() {
        let ds = crate::data::poisson_correlated(
            CorrelatedSpec { n: 80, p: 60, rho: 0.4, nnz: 5, snr: 0.0 },
            3,
        );
        let lam_max = specs::poisson_l1(1.0).lambda_max(&ds.design, &ds.y);
        let spec = specs::poisson_l1(lam_max / 10.0);
        let mut state = ContinuationState::default();
        let fit = spec.solve(
            &ds.design,
            &ds.y,
            &SolverOpts::default().with_tol(1e-8),
            &mut state,
            None,
            None,
        );
        assert!(fit.converged, "kkt = {}", fit.kkt);
        assert!(!fit.support().is_empty());
        assert!(state.beta.is_some());
    }

    #[test]
    fn block_spec_metadata_and_solve_match_direct_engine() {
        use crate::data::{grouped_correlated, GroupedSpec};
        let (ds, part) = grouped_correlated(
            GroupedSpec { n: 70, p: 40, group_size: 5, active_groups: 2, rho: 0.3, snr: 8.0 },
            2,
        );
        let spec = specs::group_lasso(1.0, Arc::clone(&part));
        assert!(spec.is_convex());
        assert!(!spec.normalize_design());
        assert!(
            !spec.supports_gap_screening(),
            "block specs must not route through the scalar screened fast path"
        );
        assert_eq!(spec.family(), "group_lasso");
        assert_eq!(spec.datafit_name(), "grouped_quadratic");
        assert_eq!(spec.label(), "grouped_quadratic/group_lasso");

        let lam_max = spec.lambda_max(&ds.design, &ds.y);
        let direct_lmax =
            crate::estimators::group_lambda_max(&ds.design, &ds.y, &part, None);
        assert!((lam_max - direct_lmax).abs() < 1e-14);

        let at = spec.at_lambda(lam_max / 4.0);
        assert_eq!(at.lambda(), lam_max / 4.0);
        let mut state = ContinuationState::default();
        let fit = at.solve(
            &ds.design,
            &ds.y,
            &SolverOpts::default().with_tol(1e-10),
            &mut state,
            None,
            None,
        );
        assert!(fit.converged, "kkt {}", fit.kkt);
        let direct = crate::estimators::group::group_lasso(lam_max / 4.0, Arc::clone(&part))
            .with_tol(1e-10)
            .fit(&ds.design, &ds.y);
        assert!((fit.objective - direct.result.objective).abs() < 1e-9);

        let mcp = specs::group_mcp(0.1, 3.0, Arc::clone(&part));
        assert!(!mcp.is_convex());
        assert!(!mcp.supports_gap_screening());

        let mt = specs::multitask_l21(0.1, 12, 3);
        assert!(mt.is_convex());
        assert_eq!(mt.datafit_name(), "quadratic_multitask");
        assert_eq!(mt.family(), "l21");
    }

    #[test]
    fn batched_fit_spec_masked_solve_matches_row_subset() {
        use crate::linalg::DenseMatrix;
        let ds = correlated(CorrelatedSpec { n: 66, p: 40, rho: 0.4, nnz: 5, snr: 10.0 }, 11);
        let keep: Vec<usize> = (0..66).filter(|i| i % 3 != 0).collect();
        let mut mask = vec![0.0; 66];
        for &i in &keep {
            mask[i] = 1.0;
        }
        let lam = quadratic_lambda_max(&ds.design, &ds.y) / 5.0;
        let spec = BatchedFitSpec::new(specs::lasso(lam)).with_row_weights(Arc::new(mask));
        assert!(!spec.is_convex(), "masked specs must opt out of coefficient-cache reuse");
        assert!(!spec.supports_gap_screening());
        assert!(spec.batch_penalty().is_some());
        assert!(spec.label().ends_with("+mask"));
        assert!(spec.row_weights().is_some());

        let mut state = ContinuationState::default();
        let opts = SolverOpts::default().with_tol(1e-10);
        let fit = spec.solve(&ds.design, &ds.y, &opts, &mut state, None, None);
        assert!(fit.converged, "kkt {}", fit.kkt);
        assert!(state.beta.is_some(), "masked solve must still feed continuation");

        let rows: Vec<Vec<f64>> = keep
            .iter()
            .map(|&i| match &ds.design {
                Design::Dense(m) => (0..m.ncols()).map(|j| m.get(i, j)).collect(),
                Design::Sparse(_) => unreachable!("fixture is dense"),
            })
            .collect();
        let sub: Design = DenseMatrix::from_rows(&rows).into();
        let y_sub: Vec<f64> = keep.iter().map(|&i| ds.y[i]).collect();
        let reference = crate::estimators::Lasso::new(lam).with_tol(1e-10).fit(&sub, &y_sub);
        for (a, b) in fit.beta.iter().zip(reference.beta.iter()) {
            assert!((a - b).abs() < 1e-9, "masked member drifted: {a} vs {b}");
        }
    }

    #[test]
    fn batched_fit_spec_unmasked_delegates_to_inner() {
        let ds = correlated(CorrelatedSpec { n: 50, p: 30, rho: 0.3, nnz: 4, snr: 10.0 }, 3);
        let lam = quadratic_lambda_max(&ds.design, &ds.y) / 6.0;
        let wrapped = BatchedFitSpec::new(specs::lasso(lam));
        assert!(wrapped.is_convex());
        assert!(wrapped.supports_gap_screening());
        assert_eq!(wrapped.label(), "quadratic/l1");
        let opts = SolverOpts::default().with_tol(1e-10);
        let mut s1 = ContinuationState::default();
        let mut s2 = ContinuationState::default();
        let a = wrapped.solve(&ds.design, &ds.y, &opts, &mut s1, None, None);
        let b = specs::lasso(lam).solve(&ds.design, &ds.y, &opts, &mut s2, None, None);
        assert_eq!(a.beta, b.beta, "unmasked wrapper must be a transparent pass-through");
        // λ-continuation keeps the mask and the batchability
        let next = wrapped.at_lambda(lam / 2.0);
        assert_eq!(next.lambda(), lam / 2.0);
        assert!(next.batch_penalty().is_some());
    }

    #[test]
    #[should_panic(expected = "not batchable")]
    fn batched_fit_spec_rejects_non_batchable_specs() {
        BatchedFitSpec::new(specs::poisson_l1(0.1));
    }

    #[test]
    fn batch_penalty_hook_matches_topology_and_family() {
        assert!(specs::lasso(0.1).batch_penalty().is_some());
        assert!(specs::mcp(0.1, 3.0).batch_penalty().is_some());
        // SCAD has no batchable form yet
        assert!(specs::scad(0.1, 3.7).batch_penalty().is_none());
        // non-quadratic datafits and prox-Newton topologies never batch
        assert!(specs::logistic_l1(0.1).batch_penalty().is_none());
        assert!(specs::poisson_l1(0.1).batch_penalty().is_none());
        let make: MakePenalty<L1> = Arc::new(L1::new);
        let lmax: LambdaMax = Arc::new(|d: &Design, y: &[f64]| quadratic_lambda_max(d, y));
        let pn = GlmSpec::new(Quadratic::new(), "l1", 0.1, false, make, lmax).with_prox_newton();
        assert!(pn.batch_penalty().is_none());
        // block specs keep the default opt-out
        let part = Arc::new(BlockPartition::uniform(12, 3));
        assert!(specs::group_lasso(0.1, part).batch_penalty().is_none());
    }

    #[test]
    fn at_lambda_rebuilds_penalty() {
        let l = specs::lasso(0.1);
        let l2 = l.at_lambda(0.05);
        assert_eq!(l2.lambda(), 0.05);
        assert_eq!(l2.label(), l.label());
    }

    #[test]
    fn spec_solve_matches_estimator_api() {
        let ds = correlated(CorrelatedSpec { n: 60, p: 90, rho: 0.4, nnz: 6, snr: 10.0 }, 5);
        let lam = quadratic_lambda_max(&ds.design, &ds.y) / 10.0;
        let spec = specs::lasso(lam);
        let mut state = ContinuationState::default();
        let fit = spec.solve(
            &ds.design,
            &ds.y,
            &SolverOpts::default().with_tol(1e-10),
            &mut state,
            None,
            None,
        );
        let reference =
            crate::estimators::Lasso::new(lam).with_tol(1e-10).fit(&ds.design, &ds.y);
        assert!((fit.objective - reference.objective).abs() < 1e-10);
        assert!(state.beta.is_some());
        assert!(state.ws_size.is_some());
    }

    #[test]
    fn cached_gram_diagonal_gives_identical_fit() {
        let ds = correlated(CorrelatedSpec { n: 50, p: 70, rho: 0.3, nnz: 5, snr: 10.0 }, 8);
        let lam = quadratic_lambda_max(&ds.design, &ds.y) / 8.0;
        let spec = specs::lasso(lam);
        let norms = ds.design.col_sq_norms();
        let mut s1 = ContinuationState::default();
        let mut s2 = ContinuationState::default();
        let opts = SolverOpts::default().with_tol(1e-10);
        let a = spec.solve(&ds.design, &ds.y, &opts, &mut s1, None, None);
        let b = spec.solve(&ds.design, &ds.y, &opts, &mut s2, Some(&norms), None);
        assert_eq!(a.beta, b.beta);
    }
}
