//! Length-prefixed JSON framing for the fit service (`skglm serve`).
//!
//! A frame is a 4-byte big-endian length followed by that many bytes of
//! UTF-8 JSON. Requests carry an envelope — `v` (protocol version),
//! `verb`, `req` (client-chosen correlation id), `session`, `tenant` —
//! and responses echo `req` so replies and subscription events can share
//! one connection. Every degradation of untrusted input maps to a typed
//! [`WireError`] so the service can answer with a structured error frame
//! instead of dropping the connection: oversized frames are drained (the
//! stream stays in sync), parse/depth/string-bomb failures surface the
//! [`JsonError`] variant, and only genuine I/O loss (`Io`/`Truncated`)
//! tears the connection down.
//!
//! [`read_frame`] is the blocking server-side reader; [`FrameReader`] is
//! the resumable client-side variant that tolerates read timeouts landing
//! mid-frame (bytes accumulate across `poll` calls instead of losing
//! sync).

use crate::util::json::{Json, JsonError, ParseLimits};
use std::io::{Read, Write};

/// Protocol version stamped on every request envelope.
pub const WIRE_VERSION: u64 = 1;

/// Default cap on a single frame's payload (4 MiB): big enough for any
/// legitimate request, small enough that a hostile length prefix cannot
/// balloon memory.
pub const DEFAULT_MAX_FRAME: usize = 4 << 20;

/// Parse limits applied to frame payloads of at most `max_frame` bytes.
pub fn frame_limits(max_frame: usize) -> ParseLimits {
    ParseLimits { max_bytes: max_frame, max_depth: 32, max_string: max_frame }
}

/// Everything that can go wrong reading a frame.
#[derive(Debug)]
pub enum WireError {
    /// Transport failure (includes read timeouts — inspect `kind()`).
    Io(std::io::Error),
    /// EOF landed mid-frame: the peer vanished or truncated a frame.
    Truncated { got: usize, want: usize },
    /// Length prefix beyond the cap. The payload was drained, so the
    /// stream is still in sync and the connection can answer and live on.
    Oversized { len: usize, max: usize },
    /// Payload is not valid JSON within limits (syntax, depth bomb,
    /// string bomb, ...).
    BadJson(JsonError),
    /// Payload is not UTF-8.
    NotUtf8,
}

impl std::fmt::Display for WireError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            WireError::Io(e) => write!(f, "i/o: {e}"),
            WireError::Truncated { got, want } => {
                write!(f, "truncated frame: got {got} of {want} bytes")
            }
            WireError::Oversized { len, max } => {
                write!(f, "oversized frame: {len} bytes exceeds cap of {max}")
            }
            WireError::BadJson(e) => write!(f, "bad json: {e}"),
            WireError::NotUtf8 => write!(f, "frame is not utf-8"),
        }
    }
}

impl WireError {
    /// Can the connection keep serving after this error? Oversized and
    /// malformed payloads were fully consumed (stream still framed);
    /// I/O loss and truncation were not.
    pub fn recoverable(&self) -> bool {
        matches!(self, WireError::Oversized { .. } | WireError::BadJson(_) | WireError::NotUtf8)
    }

    /// Stable error code used in `{"type":"error","code":...}` frames.
    pub fn code(&self) -> &'static str {
        match self {
            WireError::Io(_) => "io",
            WireError::Truncated { .. } => "truncated_frame",
            WireError::Oversized { .. } => "oversized_frame",
            WireError::BadJson(JsonError::TooDeep { .. }) => "depth_limit",
            WireError::BadJson(JsonError::TooLarge { .. })
            | WireError::BadJson(JsonError::StringTooLong { .. }) => "size_limit",
            WireError::BadJson(JsonError::Syntax { .. }) => "parse_error",
            WireError::NotUtf8 => "not_utf8",
        }
    }
}

/// Serialize `frame` as one length-prefixed frame.
pub fn write_frame(w: &mut impl Write, frame: &Json) -> std::io::Result<()> {
    let body = frame.render();
    let len = body.len() as u32;
    w.write_all(&len.to_be_bytes())?;
    w.write_all(body.as_bytes())?;
    w.flush()
}

/// Raw variant for fault injection: write `keep` bytes of the payload
/// while the length prefix promises all of it (a deliberately truncated
/// frame).
pub fn write_truncated_frame(
    w: &mut impl Write,
    frame: &Json,
    keep: usize,
) -> std::io::Result<()> {
    let body = frame.render();
    let len = body.len() as u32;
    w.write_all(&len.to_be_bytes())?;
    w.write_all(&body.as_bytes()[..keep.min(body.len())])?;
    w.flush()
}

/// Read to fill `buf`, returning how many bytes landed before EOF.
fn read_full(r: &mut impl Read, buf: &mut [u8]) -> Result<usize, std::io::Error> {
    let mut got = 0;
    while got < buf.len() {
        match r.read(&mut buf[got..]) {
            Ok(0) => break,
            Ok(n) => got += n,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(e) => return Err(e),
        }
    }
    Ok(got)
}

/// Blocking read of one frame. `Ok(None)` is a clean close (EOF exactly
/// at a frame boundary); EOF anywhere else is [`WireError::Truncated`].
/// An oversized frame is drained before returning the error, so the next
/// `read_frame` call starts at the next frame.
pub fn read_frame(r: &mut impl Read, max_frame: usize) -> Result<Option<Json>, WireError> {
    let mut len_buf = [0u8; 4];
    match read_full(r, &mut len_buf).map_err(WireError::Io)? {
        0 => return Ok(None),
        4 => {}
        got => return Err(WireError::Truncated { got, want: 4 }),
    }
    let len = u32::from_be_bytes(len_buf) as usize;
    if len > max_frame {
        // drain to stay in sync
        let mut remaining = len;
        let mut sink = [0u8; 8192];
        while remaining > 0 {
            let take = remaining.min(sink.len());
            let got = read_full(r, &mut sink[..take]).map_err(WireError::Io)?;
            if got == 0 {
                return Err(WireError::Truncated { got: len - remaining, want: len });
            }
            remaining -= got;
        }
        return Err(WireError::Oversized { len, max: max_frame });
    }
    let mut buf = vec![0u8; len];
    let got = read_full(r, &mut buf).map_err(WireError::Io)?;
    if got < len {
        return Err(WireError::Truncated { got, want: len });
    }
    parse_payload(&buf, max_frame).map(Some)
}

fn parse_payload(buf: &[u8], max_frame: usize) -> Result<Json, WireError> {
    let text = std::str::from_utf8(buf).map_err(|_| WireError::NotUtf8)?;
    Json::parse_limited(text, frame_limits(max_frame)).map_err(WireError::BadJson)
}

/// Resumable frame reader: accumulates bytes across `poll` calls so a
/// read timeout mid-frame does not lose stream sync (the client uses
/// this with `set_read_timeout`).
#[derive(Default)]
pub struct FrameReader {
    buf: Vec<u8>,
    /// payload bytes of an oversized frame still to be discarded
    skip: usize,
}

/// What one [`FrameReader::poll`] produced.
pub enum Poll {
    /// A complete frame.
    Frame(Json),
    /// Not enough bytes yet (e.g. the read timed out mid-frame); call
    /// `poll` again.
    Pending,
    /// Clean EOF at a frame boundary.
    Eof,
}

impl FrameReader {
    pub fn new() -> Self {
        Self::default()
    }

    /// Read once and try to complete a frame. Timeouts
    /// (`WouldBlock`/`TimedOut`) surface as `Ok(Pending)`; all other
    /// errors are fatal for the connection.
    pub fn poll(&mut self, r: &mut impl Read, max_frame: usize) -> Result<Poll, WireError> {
        loop {
            // serve a complete frame from the buffer first
            if self.buf.len() >= 4 {
                let len =
                    // lint: allow(panic-audit, the buf.len >= 4 guard above keeps 0..=3 in bounds)
                    u32::from_be_bytes([self.buf[0], self.buf[1], self.buf[2], self.buf[3]])
                        as usize;
                if len > max_frame {
                    // drop the prefix; remaining payload bytes will be
                    // skipped as they arrive
                    let have = self.buf.len() - 4;
                    if have >= len {
                        self.buf.drain(..4 + len);
                    } else {
                        // mark how much is left to skip by keeping a
                        // synthetic state: simplest is to consume what we
                        // have and remember the deficit in-band
                        self.buf.clear();
                        self.skip = len - have;
                    }
                    return Err(WireError::Oversized { len, max: max_frame });
                }
                if self.buf.len() >= 4 + len {
                    let payload: Vec<u8> = self.buf.drain(..4 + len).skip(4).collect();
                    return parse_payload(&payload, max_frame).map(Poll::Frame);
                }
            }
            // need more bytes
            let mut chunk = [0u8; 8192];
            match r.read(&mut chunk) {
                Ok(0) => {
                    if self.buf.is_empty() && self.skip == 0 {
                        return Ok(Poll::Eof);
                    }
                    return Err(WireError::Truncated {
                        got: self.buf.len(),
                        want: self.buf.len().max(4),
                    });
                }
                Ok(n) => {
                    let mut data = &chunk[..n];
                    if self.skip > 0 {
                        let eat = self.skip.min(data.len());
                        self.skip -= eat;
                        data = &data[eat..];
                    }
                    self.buf.extend_from_slice(data);
                }
                Err(e)
                    if e.kind() == std::io::ErrorKind::WouldBlock
                        || e.kind() == std::io::ErrorKind::TimedOut =>
                {
                    return Ok(Poll::Pending)
                }
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
                Err(e) => return Err(WireError::Io(e)),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    fn frame_bytes(j: &Json) -> Vec<u8> {
        let mut out = Vec::new();
        write_frame(&mut out, j).unwrap();
        out
    }

    #[test]
    fn frame_round_trip() {
        let j = Json::obj().with("verb", "ping").with("req", 1u64);
        let bytes = frame_bytes(&j);
        let mut cur = Cursor::new(bytes);
        let back = read_frame(&mut cur, DEFAULT_MAX_FRAME).unwrap().unwrap();
        assert_eq!(back, j);
        // clean EOF after the frame
        assert!(matches!(read_frame(&mut cur, DEFAULT_MAX_FRAME), Ok(None)));
    }

    #[test]
    fn oversized_frame_is_drained_and_stream_stays_in_sync() {
        let big = Json::obj().with("blob", "x".repeat(4096));
        let small = Json::obj().with("verb", "ping");
        let mut bytes = frame_bytes(&big);
        bytes.extend_from_slice(&frame_bytes(&small));
        let mut cur = Cursor::new(bytes);
        match read_frame(&mut cur, 256) {
            Err(WireError::Oversized { max: 256, .. }) => {}
            other => panic!("expected Oversized, got {other:?}"),
        }
        // the next frame is still readable
        let back = read_frame(&mut cur, 256).unwrap().unwrap();
        assert_eq!(back, small);
    }

    #[test]
    fn truncated_frame_is_typed() {
        let j = Json::obj().with("verb", "status").with("job", 3u64);
        let mut bytes = Vec::new();
        write_truncated_frame(&mut bytes, &j, 5).unwrap();
        let mut cur = Cursor::new(bytes);
        match read_frame(&mut cur, DEFAULT_MAX_FRAME) {
            Err(WireError::Truncated { got: 5, .. }) => {}
            other => panic!("expected Truncated, got {other:?}"),
        }
    }

    #[test]
    fn depth_bomb_payload_is_typed_not_fatal() {
        let bomb = "[".repeat(10_000);
        let mut bytes = (bomb.len() as u32).to_be_bytes().to_vec();
        bytes.extend_from_slice(bomb.as_bytes());
        let mut cur = Cursor::new(bytes);
        match read_frame(&mut cur, DEFAULT_MAX_FRAME) {
            Err(e @ WireError::BadJson(JsonError::TooDeep { .. })) => {
                assert!(e.recoverable());
                assert_eq!(e.code(), "depth_limit");
            }
            other => panic!("expected depth error, got {other:?}"),
        }
    }

    #[test]
    fn frame_reader_resumes_across_split_reads() {
        let j = Json::obj().with("verb", "submit").with("req", 9u64);
        let bytes = frame_bytes(&j);
        // feed the frame in two halves through a reader that times out
        // in between
        struct TwoPart {
            parts: Vec<Vec<u8>>,
            timeouts_between: bool,
        }
        impl Read for TwoPart {
            fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
                if self.parts.is_empty() {
                    return Ok(0);
                }
                if self.timeouts_between && self.parts.len() == 1 {
                    self.timeouts_between = false;
                    return Err(std::io::Error::from(std::io::ErrorKind::WouldBlock));
                }
                let part = self.parts.remove(0);
                buf[..part.len()].copy_from_slice(&part);
                Ok(part.len())
            }
        }
        let mid = bytes.len() / 2;
        let mut r = TwoPart {
            parts: vec![bytes[..mid].to_vec(), bytes[mid..].to_vec()],
            timeouts_between: true,
        };
        let mut fr = FrameReader::new();
        // first half arrives
        assert!(matches!(fr.poll(&mut r, DEFAULT_MAX_FRAME), Ok(Poll::Pending)));
        // second half completes the frame
        match fr.poll(&mut r, DEFAULT_MAX_FRAME) {
            Ok(Poll::Frame(back)) => assert_eq!(back, j),
            _ => panic!("expected completed frame"),
        }
        assert!(matches!(fr.poll(&mut r, DEFAULT_MAX_FRAME), Ok(Poll::Eof)));
    }

    #[test]
    fn frame_reader_skips_oversized_then_recovers() {
        let big = Json::obj().with("blob", "y".repeat(2048));
        let small = Json::obj().with("verb", "ping");
        let mut bytes = frame_bytes(&big);
        bytes.extend_from_slice(&frame_bytes(&small));
        let mut cur = Cursor::new(bytes);
        let mut fr = FrameReader::new();
        match fr.poll(&mut cur, 128) {
            Err(WireError::Oversized { .. }) => {}
            other => panic!("expected Oversized, got {:?}", other.is_ok()),
        }
        match fr.poll(&mut cur, 128) {
            Ok(Poll::Frame(back)) => assert_eq!(back, small),
            _ => panic!("reader did not resync after oversized frame"),
        }
    }
}
