//! Scripted loopback acceptance session for the fit service
//! (`skglm client --script smoke`, run by CI).
//!
//! Self-hosts a service on an ephemeral port under a deterministic
//! [`FaultPlan`] and drives every robustness claim end to end through
//! real sockets: typed error frames on malformed/bomb/oversized input
//! (connection survives each), admission-control rejection with
//! `retry_after_ms` plus a client retry that eventually lands,
//! mid-path cancellation within one λ point, deadline-bounded partial
//! results with optimality certificates, an injected worker panic
//! survived by resubmission, a mid-stream client disconnect that frees
//! (not wedges) the worker, tenant byte-budget enforcement, injected
//! frame truncation and connection drops, and finally a full worker-pool
//! death that surfaces as `scheduler_down` and a nonzero service exit.
//!
//! Every step lands in a structured JSON transcript (CI uploads it as an
//! artifact); any failed step fails the suite.

use super::client::{ClientConfig, ClientError, ServiceClient};
use super::fault::FaultPlan;
use super::service::{spawn, ExitReason, ServiceConfig};
use crate::util::json::Json;
use std::time::Duration;

/// Dataset seeds the fault plan keys on (arbitrary, just distinctive).
const SLOW_SEED: u64 = 111;
const PANIC_SEED: u64 = 666999;
const DIE_SEED: u64 = 424242;

const EVENT_TIMEOUT: Duration = Duration::from_secs(30);

struct Transcript {
    steps: Vec<Json>,
    passed: bool,
}

impl Transcript {
    fn new() -> Self {
        Self { steps: Vec::new(), passed: true }
    }

    fn record(&mut self, name: &str, ok: bool, detail: String) {
        if !ok {
            self.passed = false;
        }
        eprintln!("  [{}] {name}: {detail}", if ok { "ok" } else { "FAIL" });
        self.steps.push(
            Json::obj()
                .with("name", name)
                .with("ok", ok)
                .with("detail", detail.as_str()),
        );
    }

    fn into_json(self, exit: &str) -> (Json, bool) {
        let passed = self.passed;
        (
            Json::obj()
                .with("suite", "serve-smoke")
                .with("passed", passed)
                .with("service_exit", exit)
                .with("steps", Json::Arr(self.steps)),
            passed,
        )
    }
}

fn client(addr: &str, tenant: &str) -> Result<ServiceClient, ClientError> {
    ServiceClient::connect(ClientConfig {
        addr: addr.to_string(),
        tenant: tenant.to_string(),
        session: format!("smoke-{tenant}"),
        max_retries: 12,
        retry_seed: 7,
        ..ClientConfig::default()
    })
}

fn dataset(kind: &str, n: f64, p: f64, seed: u64) -> Json {
    Json::obj()
        .with("kind", kind)
        .with("n", n)
        .with("p", p)
        .with("seed", seed as f64)
}

fn fit_body(seed: u64) -> Vec<(&'static str, Json)> {
    vec![
        ("kind", Json::Str("fit".into())),
        ("model", Json::Str("lasso".into())),
        ("lambda_ratio", Json::Num(0.1)),
        ("dataset", dataset("correlated", 40.0, 60.0, seed)),
    ]
}

fn path_body(seed: u64, count: f64) -> Vec<(&'static str, Json)> {
    vec![
        ("kind", Json::Str("path".into())),
        ("model", Json::Str("lasso".into())),
        ("grid", Json::obj().with("min_ratio", 0.05).with("count", count)),
        ("dataset", dataset("correlated", 40.0, 60.0, seed)),
    ]
}

/// Run the whole scripted session; returns the transcript and overall
/// pass/fail.
pub fn run_smoke() -> (Json, bool) {
    let mut t = Transcript::new();
    let faults = FaultPlan::parse(&format!(
        "slow_seed={SLOW_SEED}@200,panic_seed={PANIC_SEED},die_seed={DIE_SEED},\
         truncate_tenant=chaos@2,drop_conn_tenant=evil@3"
    ))
    .expect("static fault plan parses");
    let handle = match spawn(ServiceConfig {
        addr: "127.0.0.1:0".to_string(),
        workers: 2,
        max_queue: 3,
        max_frame: 64 << 10,
        tenant_bytes: Some(150_000),
        faults,
        ..ServiceConfig::default()
    }) {
        Ok(h) => h,
        Err(e) => {
            t.record("spawn", false, format!("bind failed: {e}"));
            return t.into_json("never_started");
        }
    };
    let addr = handle.addr.to_string();

    if let Err(e) = drive(&addr, &mut t) {
        t.record("session", false, format!("aborted: {e}"));
    }

    // the finale killed every worker; the service must exit loudly
    let exit = handle.join();
    t.record(
        "service_exit_is_scheduler_down",
        exit == ExitReason::SchedulerDown,
        format!("{exit:?}"),
    );
    t.into_json(if exit == ExitReason::SchedulerDown { "scheduler_down" } else { "stopped" })
}

fn drive(addr: &str, t: &mut Transcript) -> Result<(), ClientError> {
    let mut c = client(addr, "smoke")?;

    // --- liveness ---
    let pong = c.ping()?;
    t.record(
        "ping",
        pong.get("type").and_then(Json::as_str) == Some("pong"),
        pong.render(),
    );

    // --- typed errors; the connection must survive every one ---
    c.send_bytes(&{
        let mut b = 7u32.to_be_bytes().to_vec();
        b.extend_from_slice(b"not-jso");
        b
    })?;
    let err = c.recv_any(EVENT_TIMEOUT)?;
    t.record(
        "malformed_frame_typed_error",
        err.get("code").and_then(Json::as_str) == Some("parse_error"),
        err.render(),
    );

    let bomb = "[".repeat(50_000);
    c.send_bytes(&{
        let mut b = (bomb.len() as u32).to_be_bytes().to_vec();
        b.extend_from_slice(bomb.as_bytes());
        b
    })?;
    let err = c.recv_any(EVENT_TIMEOUT)?;
    t.record(
        "depth_bomb_typed_error",
        err.get("code").and_then(Json::as_str) == Some("depth_limit"),
        err.render(),
    );

    let huge = vec![b'x'; 80 << 10]; // over the 64 KiB frame cap
    c.send_bytes(&{
        let mut b = (huge.len() as u32).to_be_bytes().to_vec();
        b.extend_from_slice(&huge);
        b
    })?;
    let err = c.recv_any(EVENT_TIMEOUT)?;
    t.record(
        "oversized_frame_typed_error",
        err.get("code").and_then(Json::as_str) == Some("oversized_frame"),
        err.render(),
    );

    let err = c.request_frame(
        "submit",
        &[("model", Json::Str("lasso".into())), ("frobnicate", Json::Num(1.0))],
    )?;
    t.record(
        "unknown_field_typed_error",
        err.get("code").and_then(Json::as_str) == Some("unknown_field"),
        err.render(),
    );

    let err = c.request_frame(
        "submit",
        &[("model", Json::Str("lasso".into())), ("lambda_ratio", Json::Num(1.5))],
    )?;
    t.record(
        "out_of_range_lambda_typed_error",
        err.get("code").and_then(Json::as_str) == Some("bad_lambda"),
        err.render(),
    );

    let err = c.request_frame("submit", &[("model", Json::Str("ridge".into()))])?;
    t.record(
        "unknown_model_typed_error",
        err.get("code").and_then(Json::as_str) == Some("bad_model"),
        err.render(),
    );

    let err = c.request_frame(
        "submit",
        &[("model", Json::Str("lasso".into())), ("precision", Json::Str("f16".into()))],
    )?;
    t.record(
        "unknown_precision_typed_error",
        err.get("code").and_then(Json::as_str) == Some("bad_precision"),
        err.render(),
    );

    let pong = c.ping()?;
    t.record(
        "connection_survived_all_bad_input",
        pong.get("type").and_then(Json::as_str) == Some("pong"),
        pong.render(),
    );

    // --- happy-path fit with certificate ---
    let acc = c.submit(&fit_body(1))?;
    let job = acc.get("job").and_then(Json::as_f64).unwrap_or(-1.0) as u64;
    let (_, done) = c.wait_terminal(job, EVENT_TIMEOUT)?;
    let obj = done.get("objective").and_then(Json::as_f64).unwrap_or(f64::NAN);
    t.record(
        "fit_done_with_certificate",
        done.get("type").and_then(Json::as_str) == Some("fit_done")
            && done.get("outcome").and_then(Json::as_str) == Some("ok")
            && done.get("certificate").and_then(Json::as_str).is_some()
            && obj.is_finite(),
        done.render(),
    );
    let st = c.status(job)?;
    t.record(
        "status_after_done",
        st.get("state").and_then(Json::as_str) == Some("ok"),
        st.render(),
    );

    // --- admission control: fill the queue, get rejected, retry in ---
    let mut slow_jobs = Vec::new();
    for _ in 0..3 {
        let acc = c.submit(&path_body(SLOW_SEED, 4.0))?;
        slow_jobs.push(acc.get("job").and_then(Json::as_f64).unwrap_or(-1.0) as u64);
    }
    let rejected = match c.submit(&fit_body(1)) {
        Err(ClientError::Server { code, retry_after_ms, .. }) if code == "rejected" => {
            t.record(
                "backpressure_rejection_with_retry_hint",
                retry_after_ms.is_some(),
                format!("rejected, retry_after_ms={retry_after_ms:?}"),
            );
            true
        }
        other => {
            t.record(
                "backpressure_rejection_with_retry_hint",
                false,
                format!("expected rejection, got {other:?}"),
            );
            false
        }
    };
    if rejected {
        let acc = c.submit_retrying(&fit_body(1))?;
        let job = acc.get("job").and_then(Json::as_f64).unwrap_or(-1.0) as u64;
        let (_, done) = c.wait_terminal(job, EVENT_TIMEOUT)?;
        t.record(
            "client_retry_with_backoff_lands",
            done.get("outcome").and_then(Json::as_str) == Some("ok"),
            done.render(),
        );
    }
    for id in slow_jobs {
        let _ = c.wait_terminal(id, EVENT_TIMEOUT)?;
    }

    // --- cancellation stops a path within one λ point ---
    let acc = c.submit(&path_body(SLOW_SEED, 8.0))?;
    let job = acc.get("job").and_then(Json::as_f64).unwrap_or(-1.0) as u64;
    let first = c.next_event(EVENT_TIMEOUT)?;
    let saw_point = first.get("type").and_then(Json::as_str) == Some("path_point");
    c.cancel(job)?;
    let (points, term) = c.wait_terminal(job, EVENT_TIMEOUT)?;
    let emitted = 1 + points.len(); // the point read before cancelling
    t.record(
        "cancel_stops_path_mid_sweep",
        saw_point
            && term.get("type").and_then(Json::as_str) == Some("cancelled")
            && emitted < 8,
        format!("emitted {emitted} of 8 before cancel; terminal {}", term.render()),
    );

    // --- deadline returns partial results with certificates ---
    let mut body = path_body(SLOW_SEED, 8.0);
    body.push(("deadline_ms", Json::Num(500.0)));
    let acc = c.submit(&body)?;
    let job = acc.get("job").and_then(Json::as_f64).unwrap_or(-1.0) as u64;
    let (points, term) = c.wait_terminal(job, EVENT_TIMEOUT)?;
    let n_points = term.get("n_points").and_then(Json::as_f64).unwrap_or(-1.0) as usize;
    let all_finite = points.iter().all(|p| {
        p.get("objective").and_then(Json::as_f64).map(f64::is_finite).unwrap_or(false)
            && p.get("certificate").and_then(Json::as_str).is_some()
    });
    t.record(
        "deadline_bounded_partial_path",
        term.get("outcome").and_then(Json::as_str) == Some("timeout")
            && n_points < 8
            && n_points == points.len()
            && all_finite,
        format!("{n_points}/8 points before the deadline; terminal {}", term.render()),
    );

    // --- injected worker panic → typed failure → resubmit succeeds ---
    let acc = c.submit(&fit_body(PANIC_SEED))?;
    let job = acc.get("job").and_then(Json::as_f64).unwrap_or(-1.0) as u64;
    let (_, term) = c.wait_terminal(job, EVENT_TIMEOUT)?;
    t.record(
        "worker_panic_is_typed_failure",
        term.get("type").and_then(Json::as_str) == Some("failed")
            && term
                .get("message")
                .and_then(Json::as_str)
                .is_some_and(|m| m.contains("injected")),
        term.render(),
    );
    let acc = c.submit_retrying(&fit_body(2))?;
    let job = acc.get("job").and_then(Json::as_f64).unwrap_or(-1.0) as u64;
    let (_, done) = c.wait_terminal(job, EVENT_TIMEOUT)?;
    t.record(
        "resubmit_after_panic_succeeds",
        done.get("outcome").and_then(Json::as_str) == Some("ok"),
        done.render(),
    );

    // --- a vanishing client frees (not wedges) its worker ---
    {
        let mut ghost = client(addr, "vanish")?;
        let acc = ghost.submit(&path_body(SLOW_SEED, 8.0))?;
        let _first = ghost.next_event(EVENT_TIMEOUT)?;
        let _ = acc;
        ghost.abandon(); // vanish mid-stream
    }
    // the orphaned job is cancelled within one λ point; a fresh fit must
    // get a worker promptly
    let acc = c.submit(&fit_body(3))?;
    let job = acc.get("job").and_then(Json::as_f64).unwrap_or(-1.0) as u64;
    let (_, done) = c.wait_terminal(job, Duration::from_secs(10))?;
    let stats = c.stats()?;
    t.record(
        "disconnect_does_not_wedge_workers",
        done.get("outcome").and_then(Json::as_str) == Some("ok")
            && stats.get("workers_alive").and_then(Json::as_f64) == Some(2.0),
        format!("fit after ghost disconnect: {}; {}", done.render(), stats.render()),
    );

    // --- tenant byte budget ---
    {
        let mut hoarder = client(addr, "hoarder")?;
        let acc = hoarder.submit(&[
            ("kind", Json::Str("fit".into())),
            ("model", Json::Str("lasso".into())),
            ("lambda_ratio", Json::Num(0.1)),
            ("dataset", dataset("correlated", 50.0, 100.0, 9)),
        ])?;
        let job = acc.get("job").and_then(Json::as_f64).unwrap_or(-1.0) as u64;
        let _ = hoarder.wait_terminal(job, EVENT_TIMEOUT)?;
        let err = hoarder.request_frame(
            "submit",
            &[
                ("kind", Json::Str("fit".into())),
                ("model", Json::Str("lasso".into())),
                ("lambda_ratio", Json::Num(0.1)),
                ("dataset", dataset("correlated", 200.0, 400.0, 10)),
            ],
        )?;
        t.record(
            "tenant_budget_typed_rejection",
            err.get("code").and_then(Json::as_str) == Some("tenant_budget"),
            err.render(),
        );
    }

    // --- injected frame truncation (tenant-scoped) ---
    {
        let mut chaos = client(addr, "chaos")?;
        let _acc = chaos.submit(&fit_body(4))?; // reply frame 1 is fine
        // frame 2 (the fit_done) is truncated by the fault plan
        let got = chaos.recv_any(EVENT_TIMEOUT);
        let truncated = matches!(
            got,
            Err(ClientError::Wire(super::wire::WireError::Truncated { .. }))
                | Err(ClientError::Io(_))
        );
        t.record(
            "injected_truncation_detected_by_client",
            truncated,
            format!("{got:?}"),
        );
    }

    // --- injected mid-stream disconnect (tenant-scoped) ---
    {
        let mut evil = client(addr, "evil")?;
        let _acc = evil.submit(&path_body(1, 4.0))?; // frame 1
        let mut frames = 0;
        let outcome = loop {
            match evil.next_event(EVENT_TIMEOUT) {
                Ok(_) => frames += 1,
                Err(e) => break e,
            }
        };
        t.record(
            "injected_disconnect_detected_by_client",
            frames < 5 && matches!(outcome, ClientError::Io(_) | ClientError::Wire(_)),
            format!("{frames} events then {outcome:?}"),
        );
    }
    let pong = c.ping()?;
    t.record(
        "server_alive_after_conn_faults",
        pong.get("type").and_then(Json::as_str) == Some("pong"),
        pong.render(),
    );

    // --- finale: kill the whole pool; death must be loud ---
    let _ = c.submit(&fit_body(DIE_SEED));
    let _ = c.submit(&fit_body(DIE_SEED));
    let mut workers_alive = f64::NAN;
    for _ in 0..100 {
        match c.stats() {
            Ok(s) => {
                workers_alive = s.get("workers_alive").and_then(Json::as_f64).unwrap_or(f64::NAN);
                if workers_alive == 0.0 {
                    break;
                }
            }
            // the service tears connections down as it stops — that, too,
            // is the pool dying loudly rather than hanging
            Err(_) => {
                workers_alive = 0.0;
                break;
            }
        }
        std::thread::sleep(Duration::from_millis(100));
    }
    t.record(
        "worker_pool_death_is_observable",
        workers_alive == 0.0,
        format!("workers_alive reached {workers_alive}"),
    );
    Ok(())
}
