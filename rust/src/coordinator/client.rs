//! Client side of the fit service protocol (`skglm client`).
//!
//! [`ServiceClient`] speaks the [`super::wire`] framing over one TCP
//! connection with per-call timeouts. Requests and streamed job events
//! share the connection: while waiting for a reply the client queues any
//! event frames that arrive, and [`ServiceClient::next_event`] drains
//! them later — so a `status` round-trip mid-stream never loses a
//! `path_point`.
//!
//! [`ServiceClient::submit_retrying`] is the production submit path:
//! admission rejections (`code:"rejected"`) honor the server's
//! `retry_after_ms` hint plus exponential backoff with deterministic
//! jitter (seeded [`crate::util::rng::Rng`] — no clock-derived
//! randomness, so scripted sessions replay exactly), and transient
//! terminal failures (an injected worker panic surfacing as a `failed`
//! event) can be resubmitted by the caller with the same machinery.

use super::wire::{write_frame, FrameReader, Poll, WireError, DEFAULT_MAX_FRAME, WIRE_VERSION};
use crate::util::json::Json;
use crate::util::rng::Rng;
use std::collections::VecDeque;
use std::io::Write as _;
use std::net::{Shutdown, TcpStream, ToSocketAddrs};
use std::time::{Duration, Instant};

/// Client configuration.
#[derive(Clone, Debug)]
pub struct ClientConfig {
    pub addr: String,
    pub tenant: String,
    pub session: String,
    /// TCP connect timeout.
    pub connect_timeout: Duration,
    /// Per-reply / per-event wait budget.
    pub io_timeout: Duration,
    /// Submit attempts before giving up on a saturated queue.
    pub max_retries: usize,
    /// Seed for backoff jitter (deterministic replay).
    pub retry_seed: u64,
}

impl Default for ClientConfig {
    fn default() -> Self {
        Self {
            addr: "127.0.0.1:7878".to_string(),
            tenant: "anon".to_string(),
            session: "cli".to_string(),
            connect_timeout: Duration::from_secs(2),
            io_timeout: Duration::from_secs(30),
            max_retries: 6,
            retry_seed: 0,
        }
    }
}

/// Everything a client call can fail with.
#[derive(Debug)]
pub enum ClientError {
    Io(std::io::Error),
    Wire(WireError),
    /// No reply/event within the io timeout.
    Timeout,
    /// The server answered with `{"type":"error"}`.
    Server { code: String, message: String, retry_after_ms: Option<u64> },
    /// Retries exhausted against a saturated admission queue.
    RetriesExhausted { attempts: usize },
}

impl std::fmt::Display for ClientError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ClientError::Io(e) => write!(f, "i/o: {e}"),
            ClientError::Wire(e) => write!(f, "wire: {e}"),
            ClientError::Timeout => write!(f, "timed out waiting for the server"),
            ClientError::Server { code, message, .. } => {
                write!(f, "server error [{code}]: {message}")
            }
            ClientError::RetriesExhausted { attempts } => {
                write!(f, "gave up after {attempts} rejected submits")
            }
        }
    }
}

impl From<std::io::Error> for ClientError {
    fn from(e: std::io::Error) -> Self {
        ClientError::Io(e)
    }
}

/// Frame types that are streamed job events rather than direct replies.
fn is_event(frame: &Json) -> bool {
    matches!(
        frame.get("type").and_then(Json::as_str),
        Some("path_point" | "path_done" | "fit_done" | "failed" | "cancelled" | "scheduler_down")
    )
}

/// One connection to the fit service.
pub struct ServiceClient {
    stream: TcpStream,
    reader: FrameReader,
    cfg: ClientConfig,
    next_req: u64,
    /// event frames that arrived while waiting for a reply
    queued: VecDeque<Json>,
    rng: Rng,
}

impl ServiceClient {
    /// Connect with the configured timeout.
    pub fn connect(cfg: ClientConfig) -> Result<Self, ClientError> {
        let addr = cfg
            .addr
            .to_socket_addrs()?
            .next()
            .ok_or_else(|| std::io::Error::new(std::io::ErrorKind::NotFound, "bad address"))?;
        let stream = TcpStream::connect_timeout(&addr, cfg.connect_timeout)?;
        // short poll interval so FrameReader can interleave waiting with
        // deadline checks without losing partial frames
        stream.set_read_timeout(Some(Duration::from_millis(25)))?;
        stream.set_nodelay(true)?;
        let rng = Rng::seed_from_u64(cfg.retry_seed);
        Ok(Self {
            stream,
            reader: FrameReader::new(),
            cfg,
            next_req: 1,
            queued: VecDeque::new(),
            rng,
        })
    }

    /// The session/tenant envelope with a fresh correlation id.
    fn envelope(&mut self, verb: &str) -> (Json, u64) {
        let req = self.next_req;
        self.next_req += 1;
        let env = Json::obj()
            .with("v", WIRE_VERSION)
            .with("verb", verb)
            .with("req", req as f64)
            .with("session", self.cfg.session.as_str())
            .with("tenant", self.cfg.tenant.as_str());
        (env, req)
    }

    /// Send a fully-formed frame (the fault harness uses this to send
    /// deliberately malformed envelopes).
    pub fn send_raw(&mut self, frame: &Json) -> Result<(), ClientError> {
        write_frame(&mut self.stream, frame)?;
        Ok(())
    }

    /// Send raw bytes on the wire (deliberately broken framing).
    pub fn send_bytes(&mut self, bytes: &[u8]) -> Result<(), ClientError> {
        self.stream.write_all(bytes)?;
        self.stream.flush()?;
        Ok(())
    }

    /// Read one frame of any kind within `timeout`.
    pub fn recv_any(&mut self, timeout: Duration) -> Result<Json, ClientError> {
        if let Some(f) = self.queued.pop_front() {
            return Ok(f);
        }
        let deadline = Instant::now() + timeout;
        loop {
            match self.reader.poll(&mut self.stream, DEFAULT_MAX_FRAME) {
                Ok(Poll::Frame(f)) => return Ok(f),
                Ok(Poll::Pending) => {
                    if Instant::now() >= deadline {
                        return Err(ClientError::Timeout);
                    }
                }
                Ok(Poll::Eof) => {
                    return Err(ClientError::Io(std::io::Error::new(
                        std::io::ErrorKind::UnexpectedEof,
                        "server closed the connection",
                    )))
                }
                Err(e) => return Err(ClientError::Wire(e)),
            }
        }
    }

    /// Wait for the reply to request `req`, queueing any event frames
    /// that arrive in between.
    fn recv_reply(&mut self, req: u64) -> Result<Json, ClientError> {
        let deadline = Instant::now() + self.cfg.io_timeout;
        // first drain already-queued frames in case the reply raced in
        if let Some(pos) = self
            .queued
            .iter()
            .position(|f| !is_event(f) && f.get("req").and_then(Json::as_f64) == Some(req as f64))
        {
            if let Some(frame) = self.queued.remove(pos) {
                return Ok(frame);
            }
        }
        loop {
            let remaining = deadline.saturating_duration_since(Instant::now());
            if remaining.is_zero() {
                return Err(ClientError::Timeout);
            }
            let frame = self.recv_any(remaining)?;
            if !is_event(&frame)
                && frame.get("req").and_then(Json::as_f64) == Some(req as f64)
            {
                return Ok(frame);
            }
            self.queued.push_back(frame);
        }
    }

    /// One verb round-trip: envelope + `extra` fields, wait for the
    /// echoed `req`. Server `{"type":"error"}` replies map to
    /// [`ClientError::Server`].
    pub fn request(&mut self, verb: &str, extra: &[(&str, Json)]) -> Result<Json, ClientError> {
        let (mut frame, req) = self.envelope(verb);
        for (k, v) in extra {
            frame = frame.with(k, v.clone());
        }
        self.send_raw(&frame)?;
        let reply = self.recv_reply(req)?;
        if reply.get("type").and_then(Json::as_str) == Some("error") {
            return Err(ClientError::Server {
                code: reply
                    .get("code")
                    .and_then(Json::as_str)
                    .unwrap_or("unknown")
                    .to_string(),
                message: reply
                    .get("message")
                    .and_then(Json::as_str)
                    .unwrap_or("")
                    .to_string(),
                retry_after_ms: reply
                    .get("retry_after_ms")
                    .and_then(Json::as_f64)
                    .map(|ms| ms as u64),
            });
        }
        Ok(reply)
    }

    /// Like [`ServiceClient::request`] but returns error replies as
    /// frames instead of `Err` (the harness asserts on typed rejections).
    pub fn request_frame(
        &mut self,
        verb: &str,
        extra: &[(&str, Json)],
    ) -> Result<Json, ClientError> {
        match self.request(verb, extra) {
            Ok(f) => Ok(f),
            Err(ClientError::Server { code, message, .. }) => Ok(Json::obj()
                .with("type", "error")
                .with("code", code.as_str())
                .with("message", message.as_str())),
            Err(e) => Err(e),
        }
    }

    pub fn ping(&mut self) -> Result<Json, ClientError> {
        self.request("ping", &[])
    }

    /// Submit once; the reply is the `accepted` frame (job id in `job`).
    pub fn submit(&mut self, body: &[(&str, Json)]) -> Result<Json, ClientError> {
        self.request("submit", body)
    }

    /// Submit with retry: admission rejections back off exponentially
    /// (base 50 ms, doubled per attempt, ×[0.5, 1.5) deterministic
    /// jitter) and honor the server's `retry_after_ms` hint as a floor.
    pub fn submit_retrying(&mut self, body: &[(&str, Json)]) -> Result<Json, ClientError> {
        let mut backoff = Duration::from_millis(50);
        for _ in 0..self.cfg.max_retries.max(1) {
            match self.request("submit", body) {
                Ok(accepted) => return Ok(accepted),
                Err(ClientError::Server { code, retry_after_ms, .. }) if code == "rejected" => {
                    // server hint is a floor under the exponential curve
                    let hint = retry_after_ms.unwrap_or(0);
                    let jitter = self.rng.uniform_range(0.5, 1.5);
                    let wait = backoff
                        .mul_f64(jitter)
                        .max(Duration::from_millis(hint))
                        .min(Duration::from_secs(5));
                    std::thread::sleep(wait);
                    backoff = (backoff * 2).min(Duration::from_secs(2));
                }
                Err(other) => return Err(other),
            }
        }
        Err(ClientError::RetriesExhausted { attempts: self.cfg.max_retries.max(1) })
    }

    pub fn cancel(&mut self, job: u64) -> Result<Json, ClientError> {
        self.request("cancel", &[("job", Json::Num(job as f64))])
    }

    pub fn status(&mut self, job: u64) -> Result<Json, ClientError> {
        self.request("status", &[("job", Json::Num(job as f64))])
    }

    pub fn stats(&mut self) -> Result<Json, ClientError> {
        self.request("stats", &[])
    }

    pub fn shutdown_server(&mut self) -> Result<Json, ClientError> {
        self.request("shutdown", &[])
    }

    /// Next streamed event within `timeout` (queued frames first).
    pub fn next_event(&mut self, timeout: Duration) -> Result<Json, ClientError> {
        if let Some(pos) = self.queued.iter().position(is_event) {
            if let Some(frame) = self.queued.remove(pos) {
                return Ok(frame);
            }
        }
        let deadline = Instant::now() + timeout;
        loop {
            let remaining = deadline.saturating_duration_since(Instant::now());
            if remaining.is_zero() {
                return Err(ClientError::Timeout);
            }
            let frame = self.recv_any(remaining)?;
            if is_event(&frame) {
                return Ok(frame);
            }
            self.queued.push_back(frame);
        }
    }

    /// Drain events for `job` until its terminal event (anything but
    /// `path_point`); returns `(points, terminal)`.
    pub fn wait_terminal(
        &mut self,
        job: u64,
        timeout: Duration,
    ) -> Result<(Vec<Json>, Json), ClientError> {
        let deadline = Instant::now() + timeout;
        let mut points = Vec::new();
        // events for *other* jobs are stashed and re-queued on return, so
        // interleaved streams never lose frames to a focused wait
        let mut stash = Vec::new();
        let result = loop {
            let remaining = deadline.saturating_duration_since(Instant::now());
            if remaining.is_zero() {
                break Err(ClientError::Timeout);
            }
            let ev = match self.next_event(remaining) {
                Ok(ev) => ev,
                Err(e) => break Err(e),
            };
            let ty = ev.get("type").and_then(Json::as_str).unwrap_or("");
            if ty == "scheduler_down" {
                break Ok((points, ev));
            }
            if ev.get("job").and_then(Json::as_f64) != Some(job as f64) {
                stash.push(ev);
                continue;
            }
            if ty == "path_point" {
                points.push(ev);
            } else {
                break Ok((points, ev));
            }
        };
        for ev in stash {
            self.queued.push_back(ev);
        }
        result
    }

    /// Half-close the socket (simulates a client vanishing mid-stream —
    /// the integration tests use this to prove workers don't wedge).
    pub fn abandon(self) {
        let _ = self.stream.shutdown(Shutdown::Both);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn event_classification() {
        assert!(is_event(&Json::obj().with("type", "path_point")));
        assert!(is_event(&Json::obj().with("type", "scheduler_down")));
        assert!(!is_event(&Json::obj().with("type", "accepted")));
        assert!(!is_event(&Json::obj().with("type", "error")));
    }

    #[test]
    fn envelope_carries_identity_and_fresh_req() {
        // no server needed: envelope construction is pure
        let cfg = ClientConfig {
            tenant: "team-a".to_string(),
            session: "s1".to_string(),
            ..ClientConfig::default()
        };
        // a loopback pair just to satisfy the struct; never written to
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let stream = TcpStream::connect(listener.local_addr().unwrap()).unwrap();
        let mut c = ServiceClient {
            stream,
            reader: FrameReader::new(),
            cfg,
            next_req: 1,
            queued: VecDeque::new(),
            rng: Rng::seed_from_u64(0),
        };
        let (env, req1) = c.envelope("ping");
        assert_eq!(req1, 1);
        assert_eq!(env.get("verb").and_then(Json::as_str), Some("ping"));
        assert_eq!(env.get("tenant").and_then(Json::as_str), Some("team-a"));
        let (_, req2) = c.envelope("ping");
        assert_eq!(req2, 2);
    }
}
