//! The production fit service: a std-only TCP front door over
//! [`FitScheduler`] (`skglm serve`).
//!
//! Concurrent clients speak the length-prefixed JSON protocol of
//! [`super::wire`]; every request envelope carries `session` and `tenant`
//! ids, and every reply echoes the request's `req` correlation id so
//! replies and streamed job events share one connection. The service
//! enforces the robustness contract end to end:
//!
//! - **Typed errors, never dropped connections** — malformed frames,
//!   unknown fields, out-of-range λ, depth/size bombs all get
//!   `{"type":"error","code":...}` frames and the connection lives on;
//!   only genuine transport loss tears it down.
//! - **Admission control** — at most `max_queue` jobs queued or running;
//!   beyond that submits are rejected with `code:"rejected"` and a
//!   `retry_after_ms` hint (clients back off instead of piling on).
//! - **Deadlines** — `deadline_ms` becomes a cooperative
//!   [`crate::solver::SolveBudget`]; a deadline-exceeded job still
//!   returns its partial result with a finite objective and its
//!   optimality certificate, marked `outcome:"timeout"`.
//! - **Priorities** — `priority:"interactive"` fits preempt running
//!   batch path sweeps at λ-point granularity (scheduler-level
//!   [`Priority`]).
//! - **Cancellation** — `cancel` (or the submitting client
//!   disconnecting mid-stream) stops the job within one λ point and
//!   frees the worker; orphaned jobs never wedge the pool.
//! - **Tenant byte budgets** — each tenant's datasets are metered
//!   against the shared [`DatasetCache`]; a tenant over budget has its
//!   idle datasets evicted first and is refused with
//!   `code:"tenant_budget"` only when eviction cannot make room.
//! - **Fault injection** — a [`FaultPlan`] deterministically injects
//!   worker panics, slow solves, worker deaths, truncated frames and
//!   dropped connections, so every degradation path above is testable.
//!
//! The scheduler's event stream is owned by one **router** thread that
//! fans events out to per-connection writer threads; when the last
//! worker dies ([`JobEvent::SchedulerDown`]) the router fails every live
//! job, broadcasts `{"type":"scheduler_down"}`, and brings the service
//! down with a nonzero exit — no consumer ever blocks on a dead pool.

use super::cache::DatasetCache;
use super::fault::{ConnFaults, FaultPlan, FaultSpec};
use super::job::{specs, FitSpec};
use super::scheduler::{FitScheduler, Job, JobEvent, JobPolicy, Priority};
use super::wire::{read_frame, write_frame, write_truncated_frame, WireError, DEFAULT_MAX_FRAME};
use crate::data::{correlated, poisson_correlated, CorrelatedSpec, Dataset};
use crate::util::json::Json;
use crate::util::lock_or_recover;
use std::collections::{HashMap, VecDeque};
use std::io::Write as _;
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Server configuration (see `skglm serve --help` for the CLI surface).
#[derive(Clone, Debug)]
pub struct ServiceConfig {
    /// Bind address; port 0 picks an ephemeral port (tests).
    pub addr: String,
    /// Solver worker threads.
    pub workers: usize,
    /// Admission cap: jobs queued or running before submits are rejected.
    pub max_queue: usize,
    /// Per-frame payload cap in bytes.
    pub max_frame: usize,
    /// Byte budget for the shared dataset/coefficient cache.
    pub cache_bytes: Option<usize>,
    /// Per-tenant byte budget inside that cache.
    pub tenant_bytes: Option<usize>,
    /// Active fault plan (empty in production).
    pub faults: FaultPlan,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        Self {
            addr: "127.0.0.1:0".to_string(),
            workers: 2,
            max_queue: 32,
            max_frame: DEFAULT_MAX_FRAME,
            cache_bytes: None,
            tenant_bytes: None,
            faults: FaultPlan::default(),
        }
    }
}

/// Why the service stopped.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ExitReason {
    /// Clean stop ([`ServiceHandle::stop`] or a `shutdown` verb).
    Stopped,
    /// The worker pool died with work outstanding (fault or panic storm).
    SchedulerDown,
}

/// What a job is doing right now (the `status` verb reports this).
#[derive(Clone, Debug, PartialEq, Eq)]
enum JobState {
    Live,
    Done(&'static str),
}

/// Whether a job was submitted as a single fit or a path sweep — fits
/// run as 1-point paths internally, and the router folds their
/// `PathPoint` + `PathDone` pair back into one `fit_done` frame.
#[derive(Clone, Copy, PartialEq, Eq)]
enum JobKind {
    Fit,
    Path,
}

struct JobRecord {
    kind: JobKind,
    tenant: String,
    label: String,
    req: u64,
    /// writer channels of every subscribed connection
    sinks: Vec<Sender<Json>>,
    points_emitted: usize,
    /// fit-kind only: the solved point, folded into `fit_done`
    fit_point: Option<Json>,
    state: JobState,
}

#[derive(Default)]
struct JobTable {
    live: HashMap<u64, JobRecord>,
    /// terminal outcomes kept for late `status` queries (bounded)
    done: VecDeque<(u64, JobRecord)>,
}

impl JobTable {
    fn record(&mut self, id: u64) -> Option<&mut JobRecord> {
        self.live.get_mut(&id)
    }

    fn finish(&mut self, id: u64, outcome: &'static str) {
        if let Some(mut rec) = self.live.remove(&id) {
            rec.state = JobState::Done(outcome);
            rec.sinks.clear();
            rec.fit_point = None;
            self.done.push_back((id, rec));
            while self.done.len() > 256 {
                self.done.pop_front();
            }
        }
    }

    fn status_of(&self, id: u64) -> Option<(&JobRecord, &'static str)> {
        if let Some(rec) = self.live.get(&id) {
            return Some((rec, "live"));
        }
        self.done.iter().rev().find(|(i, _)| *i == id).map(|(_, rec)| {
            let s = match rec.state {
                JobState::Done(s) => s,
                JobState::Live => "live",
            };
            (rec, s)
        })
    }
}

/// Per-tenant accounting: which cached datasets the tenant created and
/// how many of its jobs are still live (cancellation-on-disconnect and
/// budget eviction both consult this).
#[derive(Default)]
struct TenantLedger {
    /// tenant → dataset descriptor keys it has materialized
    datasets: HashMap<String, Vec<String>>,
}

struct ServerShared {
    scheduler: Mutex<Option<FitScheduler>>,
    cache: Arc<DatasetCache>,
    jobs: Mutex<JobTable>,
    tenants: Mutex<TenantLedger>,
    /// descriptor key → materialized dataset (shared across submits)
    datasets: Mutex<HashMap<String, Arc<Dataset>>>,
    /// accepted submits, total (fault-plan index space)
    submits: AtomicUsize,
    stop: Arc<AtomicBool>,
    stop_requested: Arc<AtomicBool>,
    config: ServiceConfig,
}

impl ServerShared {
    fn with_scheduler<R>(&self, f: impl FnOnce(&FitScheduler) -> R) -> Option<R> {
        lock_or_recover(&self.scheduler).as_ref().map(f)
    }
}

/// A running service instance.
pub struct ServiceHandle {
    /// The actual bound address (resolves port 0).
    pub addr: SocketAddr,
    shared: Arc<ServerShared>,
    accept: Option<JoinHandle<()>>,
    router: Option<JoinHandle<ExitReason>>,
}

impl ServiceHandle {
    /// Ask the service to stop accepting and shut down.
    pub fn stop(&self) {
        self.shared.stop_requested.store(true, Ordering::SeqCst);
        self.shared.stop.store(true, Ordering::SeqCst);
    }

    /// Is the service still running?
    pub fn is_running(&self) -> bool {
        !self.shared.stop.load(Ordering::SeqCst)
    }

    /// Block until the service has fully stopped (accept loop, workers
    /// and router all joined) and report why it exited.
    pub fn join(mut self) -> ExitReason {
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
        // graceful worker shutdown: the last worker emits SchedulerDown,
        // which lets the router exit its recv loop
        if let Some(sched) = lock_or_recover(&self.shared.scheduler).take() {
            sched.shutdown();
        }
        match self.router.take() {
            Some(h) => h.join().unwrap_or(ExitReason::SchedulerDown),
            None => ExitReason::Stopped,
        }
    }
}

/// Spawn the service: bind, start the scheduler + router, accept loop.
pub fn spawn(config: ServiceConfig) -> std::io::Result<ServiceHandle> {
    let listener = TcpListener::bind(&config.addr)?;
    let addr = listener.local_addr()?;
    listener.set_nonblocking(true)?;

    let mut config = config;
    if let Some(b) = config.faults.cache_bytes {
        config.cache_bytes = Some(b);
    }
    if let Some(b) = config.faults.tenant_bytes {
        config.tenant_bytes = Some(b);
    }
    let cache = Arc::new(match config.cache_bytes {
        Some(b) => DatasetCache::with_budget(b),
        None => DatasetCache::new(),
    });
    let mut scheduler = FitScheduler::start_with_cache(config.workers, Arc::clone(&cache));
    let events = scheduler.split_events();

    let shared = Arc::new(ServerShared {
        scheduler: Mutex::new(Some(scheduler)),
        cache,
        jobs: Mutex::new(JobTable::default()),
        tenants: Mutex::new(TenantLedger::default()),
        datasets: Mutex::new(HashMap::new()),
        submits: AtomicUsize::new(0),
        stop: Arc::new(AtomicBool::new(false)),
        stop_requested: Arc::new(AtomicBool::new(false)),
        config,
    });

    let router = {
        let shared = Arc::clone(&shared);
        std::thread::spawn(move || route_events(events, &shared))
    };

    let accept = {
        let shared = Arc::clone(&shared);
        std::thread::spawn(move || {
            while !shared.stop.load(Ordering::SeqCst) {
                match listener.accept() {
                    Ok((stream, _peer)) => {
                        let shared = Arc::clone(&shared);
                        std::thread::spawn(move || serve_connection(stream, &shared));
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        std::thread::sleep(Duration::from_millis(5));
                    }
                    Err(_) => break,
                }
            }
        })
    };

    Ok(ServiceHandle { addr, shared, accept: Some(accept), router: Some(router) })
}

// ---------------------------------------------------------------------
// router: scheduler events → subscriber frames
// ---------------------------------------------------------------------

fn route_events(events: Receiver<JobEvent>, shared: &ServerShared) -> ExitReason {
    for event in events.iter() {
        match event {
            JobEvent::SchedulerDown => {
                let clean = shared.stop_requested.load(Ordering::SeqCst);
                if !clean {
                    // fail every live job, tell every subscriber, and
                    // bring the whole service down: a dead pool must be
                    // loud, not a silent hang
                    let mut jobs = lock_or_recover(&shared.jobs);
                    let ids: Vec<u64> = jobs.live.keys().copied().collect();
                    for id in ids {
                        if let Some(rec) = jobs.record(id) {
                            let frame = Json::obj()
                                .with("type", "scheduler_down")
                                .with("job", id as f64)
                                .with("req", rec.req as f64);
                            for sink in &rec.sinks {
                                let _ = sink.send(frame.clone());
                            }
                        }
                        jobs.finish(id, "scheduler_down");
                    }
                    shared.stop.store(true, Ordering::SeqCst);
                }
                return if clean { ExitReason::Stopped } else { ExitReason::SchedulerDown };
            }
            ev => {
                let id = ev.job_id();
                let terminal = ev.is_terminal();
                let mut jobs = lock_or_recover(&shared.jobs);
                let Some(rec) = jobs.record(id) else { continue };
                let (frame, outcome) = event_frame(ev, rec);
                if let Some(frame) = frame {
                    rec.sinks.retain(|sink| sink.send(frame.clone()).is_ok());
                }
                if terminal {
                    jobs.finish(id, outcome);
                }
            }
        }
    }
    // channel closed without SchedulerDown: all workers already joined
    ExitReason::Stopped
}

/// Render one scheduler event as a wire frame for `rec`'s subscribers.
/// Returns `(frame, terminal_outcome)`; `frame` is `None` when the event
/// is folded into a later one (a fit-kind job's single `PathPoint`).
fn event_frame(ev: JobEvent, rec: &mut JobRecord) -> (Option<Json>, &'static str) {
    let base = |ty: &str, job: u64| {
        Json::obj()
            .with("type", ty)
            .with("job", job as f64)
            .with("req", rec.req as f64)
    };
    match ev {
        JobEvent::PathPoint(p) => {
            rec.points_emitted += 1;
            let point = base("path_point", p.job_id)
                .with("index", p.index as f64)
                .with("lambda", p.point.lambda)
                .with("lambda_ratio", p.point.lambda_ratio)
                .with("objective", p.point.objective)
                .with("support_size", p.point.support_size as f64)
                .with("epochs", p.epochs as f64)
                .with("n_screened", p.n_screened as f64)
                .with("kkt", p.kkt)
                .with("converged", p.converged)
                .with("certificate", p.certificate.name());
            if rec.kind == JobKind::Fit {
                // folded into fit_done at PathDone
                rec.fit_point = Some(point);
                (None, "live")
            } else {
                (Some(point), "live")
            }
        }
        JobEvent::PathDone(s) => {
            let outcome = if s.timed_out { "timeout" } else { "ok" };
            if rec.kind == JobKind::Fit {
                let mut frame = match rec.fit_point.take() {
                    Some(point) => {
                        let mut f = point;
                        if let Json::Obj(fields) = &mut f {
                            fields.retain(|(k, _)| k != "type" && k != "index");
                        }
                        f.with("type", "fit_done")
                    }
                    // deadline hit before the single point finished:
                    // still a typed terminal frame, with no point data
                    None => base("fit_done", s.job_id),
                };
                frame = frame
                    .with("label", s.label.as_str())
                    .with("total_epochs", s.total_epochs as f64)
                    .with("total_time", s.total_time)
                    .with("outcome", outcome);
                (Some(frame), outcome)
            } else {
                let frame = base("path_done", s.job_id)
                    .with("label", s.label.as_str())
                    .with("n_points", s.n_points as f64)
                    .with("n_planned", s.n_planned as f64)
                    .with("total_epochs", s.total_epochs as f64)
                    .with("total_time", s.total_time)
                    .with("outcome", outcome);
                (Some(frame), outcome)
            }
        }
        JobEvent::FitDone(o) => {
            // direct Job::Fit submissions (not used by the wire path, but
            // kept total so library users can share a service scheduler)
            let outcome = if o.timed_out { "timeout" } else { "ok" };
            let frame = base("fit_done", o.job_id)
                .with("label", o.label.as_str())
                .with("lambda", o.lambda)
                .with("objective", o.result.objective)
                .with("support_size", o.result.support().len() as f64)
                .with("kkt", o.result.kkt)
                .with("converged", o.result.converged)
                .with("certificate", o.result.certificate.name())
                .with("outcome", outcome);
            (Some(frame), outcome)
        }
        JobEvent::Failed { job_id, message } => {
            let frame = base("failed", job_id).with("message", message.as_str());
            (Some(frame), "failed")
        }
        JobEvent::Cancelled { job_id, points_emitted } => {
            let frame =
                base("cancelled", job_id).with("points_emitted", points_emitted as f64);
            (Some(frame), "cancelled")
        }
        // lint: allow(panic-audit, the router loop consumes SchedulerDown before event_frame runs; a routing bug here should crash loudly)
        JobEvent::SchedulerDown => unreachable!("handled by the router loop"),
    }
}

// ---------------------------------------------------------------------
// per-connection reader / writer
// ---------------------------------------------------------------------

/// Writer thread: serializes frames from the channel onto the socket,
/// applying connection-scoped faults (frame truncation / mid-stream
/// disconnect) when the plan targets this connection's tenant.
fn run_writer(stream: TcpStream, frames: Receiver<Json>, faults: Arc<Mutex<ConnFaults>>) {
    let mut stream = stream;
    let mut sent = 0usize;
    for frame in frames.iter() {
        let f = *lock_or_recover(&faults);
        if let Some(n) = f.truncate_at {
            if sent + 1 == n {
                let _ = write_truncated_frame(&mut stream, &frame, 5);
                let _ = stream.flush();
                let _ = stream.shutdown(Shutdown::Both);
                return;
            }
        }
        if let Some(n) = f.drop_after {
            if sent >= n {
                let _ = stream.shutdown(Shutdown::Both);
                return;
            }
        }
        if write_frame(&mut stream, &frame).is_err() {
            return;
        }
        sent += 1;
    }
}

fn serve_connection(stream: TcpStream, shared: &ServerShared) {
    let Ok(write_half) = stream.try_clone() else { return };
    let (tx, rx) = channel::<Json>();
    let conn_faults = Arc::new(Mutex::new(ConnFaults::default()));
    let writer = {
        let faults = Arc::clone(&conn_faults);
        std::thread::spawn(move || run_writer(write_half, rx, faults))
    };

    let mut conn = ConnState {
        tx,
        tenant: None,
        submitted: Vec::new(),
        faults: conn_faults,
    };
    let mut stream = stream;
    let max_frame = shared.config.max_frame;
    loop {
        if shared.stop.load(Ordering::SeqCst) {
            break;
        }
        match read_frame(&mut stream, max_frame) {
            Ok(Some(frame)) => handle_request(frame, &mut conn, shared),
            Ok(None) => break, // clean close
            Err(e) if e.recoverable() => {
                // typed rejection; the connection keeps serving
                let _ = conn.tx.send(error_frame(0, e.code(), &e.to_string()));
            }
            Err(_) => break, // transport loss / truncation
        }
    }
    // a submitter that vanished mid-stream must not wedge a worker:
    // cancel every still-live job this connection owns, which frees the
    // worker within one λ point
    for id in &conn.submitted {
        let live = lock_or_recover(&shared.jobs).live.contains_key(id);
        if live {
            shared.with_scheduler(|s| s.cancel(*id));
        }
    }
    drop(conn);
    let _ = writer.join();
}

struct ConnState {
    tx: Sender<Json>,
    tenant: Option<String>,
    /// jobs this connection submitted (cancelled if it disconnects)
    submitted: Vec<u64>,
    faults: Arc<Mutex<ConnFaults>>,
}

fn error_frame(req: u64, code: &str, message: &str) -> Json {
    Json::obj()
        .with("type", "error")
        .with("req", req as f64)
        .with("code", code)
        .with("message", message)
}

// ---------------------------------------------------------------------
// request dispatch
// ---------------------------------------------------------------------

const ENVELOPE_FIELDS: &[&str] = &["v", "verb", "req", "session", "tenant"];

fn handle_request(frame: Json, conn: &mut ConnState, shared: &ServerShared) {
    let req = frame.get("req").and_then(Json::as_f64).unwrap_or(0.0) as u64;
    let reply = dispatch(&frame, req, conn, shared);
    let _ = conn.tx.send(reply);
}

fn dispatch(frame: &Json, req: u64, conn: &mut ConnState, shared: &ServerShared) -> Json {
    let Some(fields) = frame.fields() else {
        return error_frame(req, "bad_request", "request frame must be a json object");
    };
    let Some(verb) = frame.get("verb").and_then(Json::as_str) else {
        return error_frame(req, "bad_request", "missing string field 'verb'");
    };
    if let Some(v) = frame.get("v").and_then(Json::as_f64) {
        if v as u64 != super::wire::WIRE_VERSION {
            return error_frame(req, "bad_version", "unsupported protocol version");
        }
    }
    let tenant = frame
        .get("tenant")
        .and_then(Json::as_str)
        .unwrap_or("anon")
        .to_string();
    if conn.tenant.as_deref() != Some(&tenant) {
        conn.tenant = Some(tenant.clone());
        // connection-scoped fault plan activates once the tenant is known
        *lock_or_recover(&conn.faults) = shared.config.faults.conn_faults(&tenant);
    }

    let verb_fields: &[&str] = match verb {
        "ping" | "stats" | "shutdown" => &[],
        "submit" => &[
            "kind", "model", "dataset", "lambda_ratio", "grid", "params", "deadline_ms",
            "priority", "tol", "precision", "isa",
        ],
        "cancel" | "status" | "subscribe" => &["job"],
        _ => return error_frame(req, "unknown_verb", &format!("unknown verb {verb:?}")),
    };
    for (key, _) in fields {
        if !ENVELOPE_FIELDS.contains(&key.as_str()) && !verb_fields.contains(&key.as_str()) {
            return error_frame(
                req,
                "unknown_field",
                &format!("field {key:?} is not valid for verb {verb:?}"),
            );
        }
    }

    match verb {
        "ping" => Json::obj()
            .with("type", "pong")
            .with("req", req as f64)
            .with("v", super::wire::WIRE_VERSION as f64),
        "stats" => {
            let stats = shared.cache.stats();
            let (pending, workers, fusion) = shared
                .with_scheduler(|s| (s.pending(), s.workers_alive(), s.fusion_stats()))
                .unwrap_or((0, 0, Default::default()));
            Json::obj()
                .with("type", "stats")
                .with("req", req as f64)
                .with("pending", pending as f64)
                .with("workers_alive", workers as f64)
                .with("cache_bytes", shared.cache.bytes() as f64)
                .with("evictions", stats.evictions as f64)
                // many-fit fusion counters (scheduler-lifetime monotone)
                .with("batched_jobs", fusion.batched_jobs as f64)
                .with("batched_fits", fusion.batched_fits as f64)
                .with("fits_per_batch", fusion.fits_per_batch())
                .with("panel_flop_ratio", fusion.panel_flop_ratio())
                // kernel floor labels: flop counters are only comparable
                // within one (isa, precision) combination
                .with("reduced_precision_flops", fusion.reduced_precision_flops as f64)
                .with("kernel_isa", crate::linalg::simd::isa().as_str())
                .with("default_precision", crate::linalg::simd::default_precision().as_str())
        }
        "shutdown" => {
            shared.stop_requested.store(true, Ordering::SeqCst);
            shared.stop.store(true, Ordering::SeqCst);
            Json::obj().with("type", "shutting_down").with("req", req as f64)
        }
        "cancel" => {
            let Some(job) = frame.get("job").and_then(Json::as_f64) else {
                return error_frame(req, "bad_request", "cancel needs a numeric 'job'");
            };
            let found = shared.with_scheduler(|s| s.cancel(job as u64)).unwrap_or(false);
            Json::obj()
                .with("type", "cancel_ok")
                .with("req", req as f64)
                .with("job", job)
                .with("found", found)
        }
        "status" => {
            let Some(job) = frame.get("job").and_then(Json::as_f64) else {
                return error_frame(req, "bad_request", "status needs a numeric 'job'");
            };
            let jobs = lock_or_recover(&shared.jobs);
            match jobs.status_of(job as u64) {
                Some((rec, state)) => Json::obj()
                    .with("type", "status")
                    .with("req", req as f64)
                    .with("job", job)
                    .with("state", state)
                    .with("label", rec.label.as_str())
                    .with("tenant", rec.tenant.as_str())
                    .with("points_emitted", rec.points_emitted as f64),
                None => error_frame(req, "job_not_found", "no such job"),
            }
        }
        "subscribe" => {
            let Some(job) = frame.get("job").and_then(Json::as_f64) else {
                return error_frame(req, "bad_request", "subscribe needs a numeric 'job'");
            };
            let mut jobs = lock_or_recover(&shared.jobs);
            match jobs.record(job as u64) {
                Some(rec) => {
                    rec.sinks.push(conn.tx.clone());
                    Json::obj()
                        .with("type", "subscribed")
                        .with("req", req as f64)
                        .with("job", job)
                }
                None => error_frame(req, "job_not_found", "job is not live"),
            }
        }
        "submit" => handle_submit(frame, req, &tenant, conn, shared),
        // lint: allow(panic-audit, the verb whitelist above returns unknown_verb first; this arm is dead by construction)
        _ => unreachable!("verbs validated above"),
    }
}

// ---------------------------------------------------------------------
// submit: validation → admission → tenant budget → scheduler
// ---------------------------------------------------------------------

/// A validated dataset descriptor (also the cache-registry key).
struct DatasetRef {
    key: String,
    seed: u64,
    build: Box<dyn FnOnce() -> Dataset>,
    /// rough residency estimate (design bytes) for admission-time
    /// tenant-budget checks, before the dataset is materialized
    est_bytes: usize,
}

fn parse_dataset(spec: &Json) -> Result<DatasetRef, String> {
    let Some(fields) = spec.fields() else {
        return Err("'dataset' must be an object".to_string());
    };
    let kind = spec
        .get("kind")
        .and_then(Json::as_str)
        .ok_or("dataset needs a string 'kind'")?;
    let seed = spec.get("seed").and_then(Json::as_f64).unwrap_or(0.0) as u64;
    let num = |k: &str, default: f64| spec.get(k).and_then(Json::as_f64).unwrap_or(default);
    let allowed: &[&str] = match kind {
        "fig1" => &["kind", "seed", "scale"],
        "correlated" | "poisson" => &["kind", "seed", "n", "p", "rho", "nnz", "snr"],
        other => return Err(format!("unknown dataset kind {other:?}")),
    };
    for (key, _) in fields {
        if !allowed.contains(&key.as_str()) {
            return Err(format!("field {key:?} is not valid for dataset kind {kind:?}"));
        }
    }
    match kind {
        "fig1" => {
            let scale = num("scale", 0.05);
            if !(0.001..=1.0).contains(&scale) {
                return Err(format!("fig1 scale {scale} out of range (0.001..=1)"));
            }
            let cs = CorrelatedSpec::figure1(scale);
            Ok(DatasetRef {
                key: format!("fig1:{scale}:{seed}"),
                seed,
                est_bytes: cs.n * cs.p * 8,
                build: Box::new(move || correlated(cs, seed)),
            })
        }
        "correlated" | "poisson" => {
            let n = num("n", 100.0) as usize;
            let p = num("p", 200.0) as usize;
            if !(4..=20_000).contains(&n) || !(4..=50_000).contains(&p) {
                return Err(format!("dataset size n={n}, p={p} out of range"));
            }
            let cs = CorrelatedSpec {
                n,
                p,
                rho: num("rho", 0.6).clamp(0.0, 0.99),
                nnz: (num("nnz", 10.0) as usize).min(p),
                snr: num("snr", 5.0),
            };
            let poisson = kind == "poisson";
            Ok(DatasetRef {
                key: format!("{kind}:{n}:{p}:{}:{}:{}:{seed}", cs.rho, cs.nnz, cs.snr),
                seed,
                est_bytes: n * p * 8,
                build: Box::new(move || {
                    if poisson {
                        poisson_correlated(cs, seed)
                    } else {
                        correlated(cs, seed)
                    }
                }),
            })
        }
        // lint: allow(panic-audit, kind is validated before dispatch; this arm is dead by construction)
        _ => unreachable!("kind validated above"),
    }
}

fn parse_model(frame: &Json) -> Result<Box<dyn FitSpec>, String> {
    let model = frame
        .get("model")
        .and_then(Json::as_str)
        .ok_or("submit needs a string 'model'")?;
    let params = frame.get("params");
    let param = |k: &str, default: f64| {
        params.and_then(|p| p.get(k)).and_then(Json::as_f64).unwrap_or(default)
    };
    // λ is a placeholder: path submission re-anchors it at λ_max · ratio
    let spec: Box<dyn FitSpec> = match model {
        "lasso" => specs::lasso(1.0),
        "elastic_net" => {
            let r = param("l1_ratio", 0.5);
            if !(0.0 < r && r <= 1.0) {
                return Err(format!("l1_ratio {r} out of range (0,1]"));
            }
            specs::elastic_net(1.0, r)
        }
        "mcp" => {
            let g = param("gamma", 3.0);
            if g <= 1.0 {
                return Err(format!("mcp gamma {g} must be > 1"));
            }
            specs::mcp(1.0, g)
        }
        "scad" => {
            let g = param("gamma", 3.7);
            if g <= 2.0 {
                return Err(format!("scad gamma {g} must be > 2"));
            }
            specs::scad(1.0, g)
        }
        "lq" => {
            let q = param("q", 0.5);
            if !(0.0 < q && q < 1.0) {
                return Err(format!("lq q {q} out of range (0,1)"));
            }
            specs::lq(1.0, q)
        }
        "poisson" => specs::poisson_l1(1.0),
        other => return Err(format!("unknown model {other:?}")),
    };
    Ok(spec)
}

fn handle_submit(
    frame: &Json,
    req: u64,
    tenant: &str,
    conn: &mut ConnState,
    shared: &ServerShared,
) -> Json {
    // ---- validation (all typed rejections, connection survives) ----
    let kind = match frame.get("kind").and_then(Json::as_str) {
        Some("fit") => JobKind::Fit,
        Some("path") => JobKind::Path,
        Some(other) => {
            return error_frame(req, "bad_request", &format!("unknown kind {other:?}"))
        }
        None => JobKind::Fit,
    };
    let ratios: Vec<f64> = match kind {
        JobKind::Fit => {
            let r = frame.get("lambda_ratio").and_then(Json::as_f64).unwrap_or(0.1);
            if !(r > 0.0 && r <= 1.0) || !r.is_finite() {
                return error_frame(
                    req,
                    "bad_lambda",
                    &format!("lambda_ratio {r} out of range (0,1]"),
                );
            }
            vec![r]
        }
        JobKind::Path => {
            if let Some(grid) = frame.get("grid") {
                let min = grid.get("min_ratio").and_then(Json::as_f64).unwrap_or(0.01);
                let count = grid.get("count").and_then(Json::as_f64).unwrap_or(16.0) as usize;
                if !(min > 0.0 && min < 1.0) {
                    return error_frame(
                        req,
                        "bad_lambda",
                        &format!("grid min_ratio {min} out of range (0,1)"),
                    );
                }
                if !(2..=1024).contains(&count) {
                    return error_frame(
                        req,
                        "bad_request",
                        &format!("grid count {count} out of range (2..=1024)"),
                    );
                }
                crate::estimators::path::geometric_grid(min, count)
            } else {
                crate::estimators::path::geometric_grid(0.01, 16)
            }
        }
    };
    let dataset_spec = frame.get("dataset").cloned().unwrap_or_else(|| {
        Json::obj().with("kind", "fig1").with("scale", 0.02).with("seed", 0.0)
    });
    let ds_ref = match parse_dataset(&dataset_spec) {
        Ok(d) => d,
        Err(msg) => return error_frame(req, "bad_dataset", &msg),
    };
    let spec = match parse_model(frame) {
        Ok(s) => s,
        Err(msg) => return error_frame(req, "bad_model", &msg),
    };

    // ---- admission control (bounded queue; reject with retry hint) ----
    let pending = shared.with_scheduler(|s| s.pending()).unwrap_or(usize::MAX);
    if pending >= shared.config.max_queue {
        let retry_ms = 100 * (1 + pending.min(20)) as f64;
        return error_frame(req, "rejected", "admission queue is full")
            .with("retry_after_ms", retry_ms)
            .with("pending", pending as f64);
    }

    // ---- tenant byte budget (evict idle datasets before refusing) ----
    let dataset = {
        let mut registry = lock_or_recover(&shared.datasets);
        if let Some(budget) = shared.config.tenant_bytes {
            if !registry.contains_key(&ds_ref.key) {
                let mut ledger = lock_or_recover(&shared.tenants);
                let keys = ledger.datasets.entry(tenant.to_string()).or_default();
                let used = |registry: &HashMap<String, Arc<Dataset>>, keys: &[String]| {
                    keys.iter()
                        .filter_map(|k| registry.get(k))
                        .map(|ds| shared.cache.bytes_for(ds))
                        .sum::<usize>()
                };
                if used(&registry, keys) + ds_ref.est_bytes > budget {
                    // over budget: evict this tenant's datasets, but only
                    // when none of its jobs are still running on them
                    let has_live_jobs = lock_or_recover(&shared.jobs)
                        .live
                        .values()
                        .any(|r| r.tenant == tenant);
                    if !has_live_jobs {
                        for k in keys.iter() {
                            if let Some(ds) = registry.get(k) {
                                shared.cache.evict_dataset(ds);
                            }
                            registry.remove(k);
                        }
                        keys.clear();
                    }
                }
                if used(&registry, keys) + ds_ref.est_bytes > budget {
                    return error_frame(
                        req,
                        "tenant_budget",
                        &format!(
                            "tenant {tenant:?} would exceed its {budget}-byte cache budget"
                        ),
                    )
                    .with("budget_bytes", budget as f64)
                    .with("estimated_bytes", ds_ref.est_bytes as f64);
                }
                keys.push(ds_ref.key.clone());
            }
        }
        match registry.get(&ds_ref.key) {
            Some(ds) => Arc::clone(ds),
            None => {
                let ds = Arc::new((ds_ref.build)());
                registry.insert(ds_ref.key.clone(), Arc::clone(&ds));
                ds
            }
        }
    };

    // ---- policy: priority + deadline ----
    let priority = match frame.get("priority").and_then(Json::as_str) {
        Some("interactive") => Priority::Interactive,
        Some("batch") => Priority::Batch,
        Some(other) => {
            return error_frame(req, "bad_request", &format!("unknown priority {other:?}"))
        }
        // interactive single fits, batch path sweeps by default
        None => match kind {
            JobKind::Fit => Priority::Interactive,
            JobKind::Path => Priority::Batch,
        },
    };
    let mut policy = JobPolicy { priority, deadline: None };
    if let Some(ms) = frame.get("deadline_ms").and_then(Json::as_f64) {
        if !(ms > 0.0) || !ms.is_finite() {
            return error_frame(req, "bad_request", &format!("deadline_ms {ms} invalid"));
        }
        policy = policy.with_deadline(Instant::now() + Duration::from_millis(ms as u64));
    }
    let mut opts = crate::solver::SolverOpts::default();
    if let Some(tol) = frame.get("tol").and_then(Json::as_f64) {
        if !(tol > 0.0) || !tol.is_finite() {
            return error_frame(req, "bad_request", &format!("tol {tol} invalid"));
        }
        opts = opts.with_tol(tol);
    }
    // ---- kernel floor: precision is honored, isa is assert-only ------
    // (the ISA is probed once per process; a submit cannot change it, so
    // a concrete request that disagrees is a typed rejection, never a
    // silent default)
    if let Some(p) = frame.get("precision") {
        let Some(name) = p.as_str() else {
            return error_frame(req, "bad_precision", "precision must be a string");
        };
        match crate::linalg::Precision::parse(name) {
            Some(prec) => opts = opts.with_precision(prec),
            None => {
                return error_frame(
                    req,
                    "bad_precision",
                    &format!("unknown precision {name:?} (expected f64, f32 or mixed)"),
                )
            }
        }
    }
    if let Some(i) = frame.get("isa") {
        let Some(name) = i.as_str() else {
            return error_frame(req, "bad_precision", "isa must be a string");
        };
        if name != "auto" {
            let active = crate::linalg::simd::isa();
            match crate::linalg::KernelIsa::parse(name) {
                None => {
                    return error_frame(req, "bad_precision", &format!("unknown isa {name:?}"))
                }
                Some(want) if want != active => {
                    return error_frame(
                        req,
                        "bad_precision",
                        &format!(
                            "isa {name:?} is not active on this host (running {})",
                            active.as_str()
                        ),
                    )
                }
                Some(_) => {}
            }
        }
    }

    // ---- fault plan (deterministic by accepted-submit index / seed) ----
    let submit_index = shared.submits.fetch_add(1, Ordering::SeqCst);
    let jf = shared.config.faults.job_faults(submit_index, ds_ref.seed);
    let spec = FaultSpec::wrap(spec, &jf);
    let label = spec.label();
    if jf.kill_worker {
        shared.with_scheduler(|s| s.kill_workers(1));
    }

    // ---- submit: fits run as 1-point paths (λ_max anchored inside) ----
    let job = Job::Path { dataset, spec, ratios: ratios.clone(), opts };
    let Some((id, _ctl)) = shared.with_scheduler(|s| s.submit_with(job, policy)) else {
        return error_frame(req, "scheduler_down", "worker pool is shut down");
    };
    lock_or_recover(&shared.jobs).live.insert(
        id,
        JobRecord {
            kind,
            tenant: tenant.to_string(),
            label: label.clone(),
            req,
            sinks: vec![conn.tx.clone()],
            points_emitted: 0,
            fit_point: None,
            state: JobState::Live,
        },
    );
    conn.submitted.push(id);
    Json::obj()
        .with("type", "accepted")
        .with("req", req as f64)
        .with("job", id as f64)
        .with("label", label.as_str())
        .with("n_points", ratios.len() as f64)
        .with(
            "kind",
            match kind {
                JobKind::Fit => "fit",
                JobKind::Path => "path",
            },
        )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn config_default_is_loopback_ephemeral() {
        let cfg = ServiceConfig::default();
        assert_eq!(cfg.addr, "127.0.0.1:0");
        assert!(cfg.max_queue > 0 && cfg.workers > 0);
    }

    #[test]
    fn service_spawns_and_stops_cleanly() {
        let handle = spawn(ServiceConfig {
            workers: 1,
            ..ServiceConfig::default()
        })
        .expect("bind loopback");
        assert!(handle.is_running());
        assert_ne!(handle.addr.port(), 0);
        handle.stop();
        assert_eq!(handle.join(), ExitReason::Stopped);
    }

    #[test]
    fn dataset_descriptor_validation() {
        let good = Json::obj().with("kind", "fig1").with("scale", 0.02).with("seed", 3.0);
        let d = parse_dataset(&good).unwrap();
        assert_eq!(d.key, "fig1:0.02:3");
        assert_eq!(d.seed, 3);
        assert!(d.est_bytes > 0);

        let bad_kind = Json::obj().with("kind", "exotic");
        assert!(parse_dataset(&bad_kind).is_err());
        let bad_field = Json::obj().with("kind", "fig1").with("frobnicate", 1.0);
        assert!(parse_dataset(&bad_field).is_err());
        let bad_scale = Json::obj().with("kind", "fig1").with("scale", 50.0);
        assert!(parse_dataset(&bad_scale).is_err());
    }

    #[test]
    fn model_validation() {
        let lasso = Json::obj().with("model", "lasso");
        assert_eq!(parse_model(&lasso).unwrap().family(), "l1");
        let mcp = Json::obj()
            .with("model", "mcp")
            .with("params", Json::obj().with("gamma", 3.0));
        assert_eq!(parse_model(&mcp).unwrap().family(), "mcp");
        let bad_gamma = Json::obj()
            .with("model", "mcp")
            .with("params", Json::obj().with("gamma", 0.5));
        assert!(parse_model(&bad_gamma).is_err());
        let unknown = Json::obj().with("model", "ridge");
        assert!(parse_model(&unknown).is_err());
    }
}
