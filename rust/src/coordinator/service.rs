//! Fit service: a leader/worker queue over the estimator API.
//!
//! Callers submit [`FitJob`]s; worker threads execute them with the
//! library's solvers; results stream back over a channel in completion
//! order (each tagged with its job id). This is the long-running-process
//! shape of the library (a model-fitting microservice), built on
//! std::sync::mpsc since tokio is unavailable offline.

use crate::data::Dataset;
use crate::estimators::{ElasticNet, Lasso, McpRegressor};
use crate::solver::{FitResult, SolverOpts};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

/// Which estimator a job runs.
#[derive(Clone, Debug, PartialEq)]
pub enum EstimatorSpec {
    Lasso { lambda: f64 },
    ElasticNet { lambda: f64, l1_ratio: f64 },
    Mcp { lambda: f64, gamma: f64 },
}

/// A fit request. The dataset is shared (`Arc`) so a sweep over λ doesn't
/// copy the design per job.
#[derive(Clone)]
pub struct FitJob {
    pub id: u64,
    pub dataset: Arc<Dataset>,
    pub spec: EstimatorSpec,
    pub opts: SolverOpts,
}

/// A completed fit.
pub struct FitOutcome {
    pub id: u64,
    pub spec: EstimatorSpec,
    pub result: FitResult,
    pub wall_time: f64,
}

enum Msg {
    Job(FitJob),
    Shutdown,
}

/// The service: submit jobs, receive outcomes, shut down cleanly.
pub struct SolveService {
    tx: Sender<Msg>,
    pub results: Receiver<FitOutcome>,
    workers: Vec<JoinHandle<()>>,
    submitted: u64,
}

impl SolveService {
    pub fn start(n_workers: usize) -> Self {
        let (tx, rx) = channel::<Msg>();
        let rx = Arc::new(Mutex::new(rx));
        let (res_tx, res_rx) = channel::<FitOutcome>();
        let workers = (0..n_workers.max(1))
            .map(|_| {
                let rx = Arc::clone(&rx);
                let res_tx = res_tx.clone();
                std::thread::spawn(move || loop {
                    let msg = {
                        let guard = rx.lock().unwrap();
                        guard.recv()
                    };
                    match msg {
                        Ok(Msg::Job(job)) => {
                            let t0 = std::time::Instant::now();
                            let result = run_job(&job);
                            let _ = res_tx.send(FitOutcome {
                                id: job.id,
                                spec: job.spec,
                                result,
                                wall_time: t0.elapsed().as_secs_f64(),
                            });
                        }
                        Ok(Msg::Shutdown) | Err(_) => break,
                    }
                })
            })
            .collect();
        Self { tx, results: res_rx, workers, submitted: 0 }
    }

    /// Submit a job; returns its id.
    pub fn submit(&mut self, dataset: Arc<Dataset>, spec: EstimatorSpec, opts: SolverOpts) -> u64 {
        let id = self.submitted;
        self.submitted += 1;
        self.tx
            .send(Msg::Job(FitJob { id, dataset, spec, opts }))
            .expect("service is down");
        id
    }

    /// Block until `count` outcomes arrive.
    pub fn collect(&self, count: usize) -> Vec<FitOutcome> {
        (0..count).map(|_| self.results.recv().expect("worker died")).collect()
    }

    /// Graceful shutdown: drains workers.
    pub fn shutdown(self) {
        for _ in &self.workers {
            let _ = self.tx.send(Msg::Shutdown);
        }
        for w in self.workers {
            let _ = w.join();
        }
    }
}

fn run_job(job: &FitJob) -> FitResult {
    let ds = &job.dataset;
    match job.spec {
        EstimatorSpec::Lasso { lambda } => {
            Lasso::new(lambda).with_solver(job.opts.clone()).fit(&ds.design, &ds.y)
        }
        EstimatorSpec::ElasticNet { lambda, l1_ratio } => {
            ElasticNet::new(lambda, l1_ratio).with_solver(job.opts.clone()).fit(&ds.design, &ds.y)
        }
        EstimatorSpec::Mcp { lambda, gamma } => {
            McpRegressor::new(lambda, gamma).with_solver(job.opts.clone()).fit(&ds.design, &ds.y).0
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{correlated, CorrelatedSpec};

    #[test]
    fn sweep_over_lambda_completes() {
        let ds = Arc::new(correlated(
            CorrelatedSpec { n: 60, p: 80, rho: 0.4, nnz: 5, snr: 10.0 },
            0,
        ));
        let lam_max = Lasso::lambda_max(&ds.design, &ds.y);
        let mut svc = SolveService::start(2);
        for k in 1..=6 {
            svc.submit(
                Arc::clone(&ds),
                EstimatorSpec::Lasso { lambda: lam_max / (2.0 * k as f64) },
                SolverOpts::default(),
            );
        }
        let mut outcomes = svc.collect(6);
        svc.shutdown();
        assert_eq!(outcomes.len(), 6);
        outcomes.sort_by_key(|o| o.id);
        // smaller lambda (later ids) -> larger support
        let first = outcomes.first().unwrap().result.support().len();
        let last = outcomes.last().unwrap().result.support().len();
        assert!(last >= first);
        for o in &outcomes {
            assert!(o.result.converged);
            assert!(o.wall_time >= 0.0);
        }
    }

    #[test]
    fn mixed_estimators() {
        let ds = Arc::new(correlated(
            CorrelatedSpec { n: 80, p: 60, rho: 0.3, nnz: 5, snr: 10.0 },
            1,
        ));
        let lam = Lasso::lambda_max(&ds.design, &ds.y) / 10.0;
        let mut svc = SolveService::start(2);
        svc.submit(Arc::clone(&ds), EstimatorSpec::Lasso { lambda: lam }, SolverOpts::default());
        svc.submit(
            Arc::clone(&ds),
            EstimatorSpec::ElasticNet { lambda: lam, l1_ratio: 0.5 },
            SolverOpts::default(),
        );
        svc.submit(
            Arc::clone(&ds),
            EstimatorSpec::Mcp { lambda: lam, gamma: 3.0 },
            SolverOpts::default(),
        );
        let outcomes = svc.collect(3);
        svc.shutdown();
        assert_eq!(outcomes.len(), 3);
    }

    #[test]
    fn shutdown_without_jobs() {
        let svc = SolveService::start(3);
        svc.shutdown(); // must not hang
    }
}
