//! `skglm analyze`: the self-hosted static-analysis pass.
//!
//! PR 6 built systematic conformance checking for *numerics*
//! (scenarios.jsonl oracles); this module is the counterpart for
//! *code-level* invariants. A hand-rolled lexer ([`lexer`]) feeds seven
//! project-specific lint rules ([`rules`]): panic-audit, lock-order,
//! atomic-ordering, unsafe-audit, determinism, doc-conformance,
//! isa-gate. The run
//! emits `BENCH_analysis.json` (rolled into `BENCH_SUMMARY.json` like
//! every other gate) and fails — a real `Err`, so CI trips — when any
//! finding survives suppression.
//!
//! Everything here is std-only and offline: the analyzer scans the
//! checked-out tree it is part of, so `skglm analyze` run at the repo
//! root audits the very binary that runs it.

pub mod lexer;
pub mod rules;

use crate::bench::report::{ensure_dir, results_dir};
use crate::util::json::Json;
use anyhow::{Context, Result};
use std::path::{Path, PathBuf};

use lexer::SourceFile;
use rules::{DocContext, Outcome, RULES};

/// A full analysis run over one source tree.
#[derive(Clone, Debug)]
pub struct Report {
    pub files_scanned: usize,
    pub total_lines: usize,
    pub outcome: Outcome,
}

impl Report {
    pub fn to_json(&self) -> Json {
        let findings = Json::Arr(
            self.outcome
                .findings
                .iter()
                .map(|f| {
                    Json::obj()
                        .with("rule_id", f.rule_id.as_str())
                        .with("file", f.file.as_str())
                        .with("line", f.line)
                        .with("severity", f.severity.as_str())
                        .with("excerpt", f.excerpt.as_str())
                        .with("justification", f.justification.as_str())
                })
                .collect(),
        );
        let suppressions = Json::Arr(
            self.outcome
                .suppressions
                .iter()
                .map(|s| {
                    Json::obj()
                        .with("rule_id", s.rule_id.as_str())
                        .with("file", s.file.as_str())
                        .with("line", s.line)
                        .with("reason", s.reason.as_str())
                        .with("used", s.used)
                })
                .collect(),
        );
        let unsafe_inventory = Json::Arr(
            self.outcome
                .unsafe_inventory
                .iter()
                .map(|u| {
                    Json::obj()
                        .with("file", u.file.as_str())
                        .with("line", u.line)
                        .with("excerpt", u.excerpt.as_str())
                        .with("has_safety", u.has_safety)
                })
                .collect(),
        );
        let rules = Json::Arr(
            RULES
                .iter()
                .map(|(id, desc)| {
                    let n = self
                        .outcome
                        .findings
                        .iter()
                        .filter(|f| f.rule_id == *id)
                        .count();
                    Json::obj()
                        .with("id", *id)
                        .with("description", *desc)
                        .with("findings", n)
                })
                .collect(),
        );
        Json::obj()
            .with("experiment", "analysis")
            .with("files_scanned", self.files_scanned)
            .with("total_lines", self.total_lines)
            .with("findings_total", self.outcome.findings.len())
            .with("suppressions_total", self.outcome.suppressions.len())
            .with("unsafe_total", self.outcome.unsafe_inventory.len())
            .with("rules", rules)
            .with("findings", findings)
            .with("suppressions", suppressions)
            .with("unsafe_inventory", unsafe_inventory)
    }
}

/// Recursively collect `.rs` files.
fn collect_rs(dir: &Path, out: &mut Vec<PathBuf>) -> Result<()> {
    let entries =
        std::fs::read_dir(dir).with_context(|| format!("reading {}", dir.display()))?;
    for entry in entries {
        let entry = entry.with_context(|| format!("reading entry in {}", dir.display()))?;
        let path = entry.path();
        if path.is_dir() {
            // skip build output if the walker is ever pointed at a root
            let name = entry.file_name();
            if name == "target" || name == ".git" {
                continue;
            }
            collect_rs(&path, out)?;
        } else if path.extension().map(|e| e == "rs").unwrap_or(false) {
            out.push(path);
        }
    }
    Ok(())
}

/// Lex and lint the source tree under `root`. Scans `root/rust/src`
/// when present (the repo layout), else `root/src`, else `root` itself
/// — the fallbacks keep fixture trees in tests trivial to build.
pub fn analyze_repo(root: &Path) -> Result<Report> {
    let scan = if root.join("rust").join("src").is_dir() {
        root.join("rust").join("src")
    } else if root.join("src").is_dir() {
        root.join("src")
    } else {
        root.to_path_buf()
    };
    let mut paths = Vec::new();
    collect_rs(&scan, &mut paths)?;
    paths.sort();
    if paths.is_empty() {
        anyhow::bail!("no .rs files found under {}", scan.display());
    }

    let mut files = Vec::with_capacity(paths.len());
    let mut total_lines = 0usize;
    for p in &paths {
        let text = std::fs::read_to_string(p)
            .with_context(|| format!("reading {}", p.display()))?;
        let rel = p
            .strip_prefix(root)
            .unwrap_or(p)
            .to_string_lossy()
            .replace('\\', "/");
        total_lines += text.lines().count();
        files.push(SourceFile::parse(&rel, &text));
    }

    let docs = DocContext {
        architecture: std::fs::read_to_string(root.join("ARCHITECTURE.md")).unwrap_or_default(),
        scenarios_jsonl: std::fs::read_to_string(root.join("scenarios.jsonl")).ok(),
    };
    let outcome = rules::run_all(&files, &docs);
    Ok(Report { files_scanned: files.len(), total_lines, outcome })
}

/// Emit `BENCH_analysis.json` (results dir always; repo root only
/// outside `SKGLM_RESULTS` redirection, the shared BENCH convention).
pub fn write_report(report: &Report) -> Result<Vec<PathBuf>> {
    let dir = results_dir().join("analysis");
    ensure_dir(&dir)?;
    let json = report.to_json();
    let mut written = Vec::new();
    let path = dir.join("BENCH_analysis.json");
    std::fs::write(&path, json.render())
        .with_context(|| format!("writing {}", path.display()))?;
    written.push(path);
    if std::env::var_os("SKGLM_RESULTS").is_none() {
        let root = PathBuf::from("BENCH_analysis.json");
        std::fs::write(&root, json.render())
            .with_context(|| format!("writing {}", root.display()))?;
        written.push(root);
    }
    Ok(written)
}

/// The `skglm analyze` / `exp analysis` entry point: scan → emit →
/// **fail** (a real error, so the CI gate trips) when any finding
/// survives suppression. `quiet` drops the per-finding lines but keeps
/// the summary.
pub fn run(root: &Path, quiet: bool) -> Result<Vec<PathBuf>> {
    let report = analyze_repo(root)?;
    let written = write_report(&report)?;
    if !quiet {
        for f in &report.outcome.findings {
            eprintln!(
                "[analyze] {}:{} [{}] {}\n[analyze]     {}",
                f.file, f.line, f.rule_id, f.excerpt, f.justification
            );
        }
        for s in report.outcome.suppressions.iter().filter(|s| !s.used) {
            eprintln!(
                "[analyze] note: unused suppression at {}:{} for {} ({})",
                s.file, s.line, s.rule_id, s.reason
            );
        }
    }
    let unsafe_total = report.outcome.unsafe_inventory.len();
    eprintln!(
        "[analyze] {} files / {} lines scanned: {} finding(s), {} suppression(s), {} unsafe site(s)",
        report.files_scanned,
        report.total_lines,
        report.outcome.findings.len(),
        report.outcome.suppressions.len(),
        unsafe_total,
    );
    if !report.outcome.findings.is_empty() {
        anyhow::bail!(
            "{} static-analysis finding(s); fix them or justify with `// lint: allow(rule, reason)`",
            report.outcome.findings.len()
        );
    }
    Ok(written)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fixture_tree(stem: &str, files: &[(&str, &str)]) -> PathBuf {
        let root =
            std::env::temp_dir().join(format!("skglm_analyze_{stem}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&root);
        for (rel, body) in files {
            let p = root.join(rel);
            std::fs::create_dir_all(p.parent().expect("fixture paths have parents")).unwrap();
            std::fs::write(&p, body).unwrap();
        }
        root
    }

    #[test]
    fn violating_tree_fails_and_clean_tree_passes() {
        let bad = fixture_tree(
            "bad",
            &[(
                "rust/src/coordinator/wire.rs",
                "fn f(v: Vec<u8>) -> u8 { v.first().copied().unwrap() }\n",
            )],
        );
        let report = analyze_repo(&bad).unwrap();
        assert_eq!(report.files_scanned, 1);
        assert_eq!(report.outcome.findings.len(), 1);
        assert_eq!(report.outcome.findings[0].rule_id, "panic-audit");

        let good = fixture_tree(
            "good",
            &[(
                "rust/src/coordinator/wire.rs",
                "fn f(v: Vec<u8>) -> u8 { v.first().copied().unwrap_or(0) }\n",
            )],
        );
        let report = analyze_repo(&good).unwrap();
        assert!(report.outcome.findings.is_empty(), "{:?}", report.outcome.findings);

        let _ = std::fs::remove_dir_all(&bad);
        let _ = std::fs::remove_dir_all(&good);
    }

    #[test]
    fn src_fallback_layout_is_scanned() {
        let root = fixture_tree(
            "fallback",
            &[("src/lib.rs", "pub fn ok() -> usize { 1 }\n")],
        );
        let report = analyze_repo(&root).unwrap();
        assert_eq!(report.files_scanned, 1);
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn run_writes_report_and_fails_on_findings() {
        let _guard = crate::bench::report::results_env_lock();
        let tmp = std::env::temp_dir().join(format!("skglm_analysis_out_{}", std::process::id()));
        std::env::set_var("SKGLM_RESULTS", &tmp);
        let bad = fixture_tree(
            "run_bad",
            &[(
                "rust/src/coordinator/cache.rs",
                "fn f(&self) { self.state.lock().unwrap(); }\n",
            )],
        );
        let err = run(&bad, true).unwrap_err();
        assert!(err.to_string().contains("finding"), "{err}");
        let written = tmp.join("analysis").join("BENCH_analysis.json");
        assert!(written.exists(), "report written even on failure");
        let raw = std::fs::read_to_string(&written).unwrap();
        assert!(raw.contains("\"experiment\":\"analysis\""), "{raw}");
        assert!(raw.contains("panic-audit"), "{raw}");
        std::env::remove_var("SKGLM_RESULTS");
        let _ = std::fs::remove_dir_all(&tmp);
        let _ = std::fs::remove_dir_all(&bad);
    }

    #[test]
    fn report_json_shape() {
        let root = fixture_tree(
            "shape",
            &[(
                "rust/src/linalg/parallel.rs",
                "fn f(p: *mut f64) {\n// SAFETY: caller guarantees exclusive access\nunsafe { *p = 1.0; }\n}\n",
            )],
        );
        let report = analyze_repo(&root).unwrap();
        assert!(report.outcome.findings.is_empty(), "{:?}", report.outcome.findings);
        assert_eq!(report.outcome.unsafe_inventory.len(), 1);
        let rendered = report.to_json().render();
        for key in [
            "\"experiment\":\"analysis\"",
            "\"files_scanned\"",
            "\"findings_total\"",
            "\"rules\"",
            "\"unsafe_inventory\"",
            "\"has_safety\":true",
        ] {
            assert!(rendered.contains(key), "missing {key} in {rendered}");
        }
        let _ = std::fs::remove_dir_all(&root);
    }
}
