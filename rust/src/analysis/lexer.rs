//! Lightweight Rust source lexer for the static-analysis pass.
//!
//! No `syn` offline — mirroring the `util/json.rs` philosophy, this is a
//! hand-rolled character-level scanner, not a parser. It produces a
//! per-line model that is exactly what lexical lint rules need:
//!
//! - `code`: the line with comments removed and string/char literal
//!   *contents* blanked (so `"panic!"` inside a string never trips the
//!   panic-audit rule);
//! - `comment`: the comment text on the line (line comments and the
//!   in-line share of block comments) — justification comments and
//!   `lint: allow(...)` suppressions are read from here;
//! - `strings`: the string literals that *end* on the line (the
//!   doc-conformance rule reads error-code literals from these);
//! - `is_test`: whether the line sits inside a `#[cfg(test)]` item or a
//!   `#[test]` function (brace-depth tracked), so rules can exempt test
//!   code.
//!
//! It also records per-function line spans ([`FnSpan`]) for the
//! lock-order rule's acquisition sequences. Known approximations (all
//! conservative for this repo's style): attributes and macros are not
//! expanded, and a `fn` signature is recognized lexically (`fn name(`),
//! so function-like macro bodies attribute to the enclosing item.

/// One lexed source line.
#[derive(Clone, Debug, Default)]
pub struct Line {
    /// Comment-free code with string/char contents blanked to `""`/`''`.
    pub code: String,
    /// Comment text on this line (without the `//` / `/* */` markers).
    pub comment: String,
    /// String literals terminating on this line, in order.
    pub strings: Vec<String>,
    /// Inside a `#[cfg(test)]` item or `#[test]` function.
    pub is_test: bool,
}

/// A function's 1-based inclusive line span.
#[derive(Clone, Debug)]
pub struct FnSpan {
    pub name: String,
    pub start: usize,
    pub end: usize,
}

/// An inline `// lint: allow(rule, reason)` suppression. It applies to
/// the line it sits on and to the immediately following line (so a
/// comment-only line can annotate the statement below it).
#[derive(Clone, Debug)]
pub struct Suppression {
    pub rule: String,
    pub reason: String,
    /// 1-based line.
    pub line: usize,
}

/// One lexed source file.
#[derive(Clone, Debug)]
pub struct SourceFile {
    /// Repo-relative path with forward slashes.
    pub path: String,
    /// Raw text lines (for excerpts).
    pub raw: Vec<String>,
    pub lines: Vec<Line>,
    pub fns: Vec<FnSpan>,
    pub suppressions: Vec<Suppression>,
}

#[derive(Clone, Copy, PartialEq)]
enum St {
    Code,
    LineComment,
    /// Nestable `/* */`, with current depth.
    Block(u32),
    Str,
    /// Raw string with this many `#`s in its delimiter.
    RawStr(u32),
    CharLit,
}

impl SourceFile {
    /// Lex `text` into the per-line model.
    pub fn parse(path: &str, text: &str) -> SourceFile {
        let raw: Vec<String> = text.lines().map(|l| l.to_string()).collect();
        let mut lines: Vec<Line> = Vec::with_capacity(raw.len());
        let mut cur = Line::default();
        let mut cur_str = String::new();
        let mut st = St::Code;

        let chars: Vec<char> = text.chars().collect();
        let mut i = 0usize;
        while i < chars.len() {
            let c = chars[i];
            if c == '\n' {
                // a newline ends the line in every state; Str/RawStr and
                // Block comments simply continue on the next line
                if st == St::LineComment {
                    st = St::Code;
                }
                lines.push(std::mem::take(&mut cur));
                i += 1;
                continue;
            }
            match st {
                St::Code => {
                    let next = chars.get(i + 1).copied();
                    if c == '/' && next == Some('/') {
                        st = St::LineComment;
                        i += 2;
                    } else if c == '/' && next == Some('*') {
                        st = St::Block(1);
                        i += 2;
                    } else if c == '"' {
                        cur.code.push_str("\"\"");
                        cur_str.clear();
                        st = St::Str;
                        i += 1;
                    } else if (c == 'r' || c == 'b') && raw_string_hashes(&chars, i).is_some() {
                        let (hashes, consumed) =
                            raw_string_hashes(&chars, i).expect("checked above");
                        cur.code.push_str("\"\"");
                        cur_str.clear();
                        st = St::RawStr(hashes);
                        i += consumed;
                    } else if c == '\'' {
                        if char_literal_starts(&chars, i) {
                            cur.code.push_str("''");
                            st = St::CharLit;
                            i += 1;
                        } else {
                            // lifetime: keep as code
                            cur.code.push(c);
                            i += 1;
                        }
                    } else {
                        cur.code.push(c);
                        i += 1;
                    }
                }
                St::LineComment => {
                    cur.comment.push(c);
                    i += 1;
                }
                St::Block(depth) => {
                    let next = chars.get(i + 1).copied();
                    if c == '*' && next == Some('/') {
                        st = if depth == 1 { St::Code } else { St::Block(depth - 1) };
                        i += 2;
                    } else if c == '/' && next == Some('*') {
                        st = St::Block(depth + 1);
                        i += 2;
                    } else {
                        cur.comment.push(c);
                        i += 1;
                    }
                }
                St::Str => {
                    if c == '\\' {
                        // keep escapes verbatim; fidelity is not needed
                        cur_str.push(c);
                        if let Some(&n) = chars.get(i + 1) {
                            if n != '\n' {
                                cur_str.push(n);
                            }
                        }
                        i += 2;
                    } else if c == '"' {
                        cur.strings.push(std::mem::take(&mut cur_str));
                        st = St::Code;
                        i += 1;
                    } else {
                        cur_str.push(c);
                        i += 1;
                    }
                }
                St::RawStr(hashes) => {
                    if c == '"' && closes_raw(&chars, i, hashes) {
                        cur.strings.push(std::mem::take(&mut cur_str));
                        st = St::Code;
                        i += 1 + hashes as usize;
                    } else {
                        cur_str.push(c);
                        i += 1;
                    }
                }
                St::CharLit => {
                    if c == '\\' {
                        i += 2;
                    } else if c == '\'' {
                        st = St::Code;
                        i += 1;
                    } else {
                        i += 1;
                    }
                }
            }
        }
        if !cur.code.is_empty() || !cur.comment.is_empty() || !cur.strings.is_empty() {
            lines.push(cur);
        }
        while lines.len() < raw.len() {
            lines.push(Line::default());
        }

        let mut file = SourceFile {
            path: path.to_string(),
            raw,
            lines,
            fns: Vec::new(),
            suppressions: Vec::new(),
        };
        file.mark_regions();
        file.collect_suppressions();
        file
    }

    /// Brace-depth pass: mark `#[cfg(test)]` / `#[test]` regions and
    /// record function spans.
    fn mark_regions(&mut self) {
        let mut depth: i64 = 0;
        // (close_at_depth) for an open test region
        let mut test_regions: Vec<i64> = Vec::new();
        // armed by a test attribute, waiting for its item's `{`
        let mut test_pending = false;
        // armed by `fn name(`, waiting for the body's `{`
        let mut fn_pending: Option<String> = None;
        // open functions: (name, start_line, close_at_depth)
        let mut fn_stack: Vec<(String, usize, i64)> = Vec::new();
        let mut spans: Vec<FnSpan> = Vec::new();

        for idx in 0..self.lines.len() {
            let code = self.lines[idx].code.clone();
            if code.contains("#[cfg(test)]") || code.contains("#[test]") {
                test_pending = true;
            }
            if let Some(name) = fn_decl_name(&code) {
                fn_pending = Some(name);
            }
            // a `;` before the body's `{` means a bodiless declaration
            // (trait method signature): drop the pending fn
            for ch in code.chars() {
                match ch {
                    '{' => {
                        if test_pending {
                            test_regions.push(depth);
                            test_pending = false;
                        }
                        if let Some(name) = fn_pending.take() {
                            fn_stack.push((name, idx + 1, depth));
                        }
                        depth += 1;
                    }
                    '}' => {
                        depth -= 1;
                        while test_regions.last() == Some(&depth) {
                            test_regions.pop();
                            // the closer line itself is still test code
                            self.lines[idx].is_test = true;
                        }
                        while fn_stack.last().map(|f| f.2) == Some(depth) {
                            let (name, start, _) =
                                fn_stack.pop().expect("last() was Some");
                            spans.push(FnSpan { name, start, end: idx + 1 });
                        }
                    }
                    ';' => {
                        if fn_pending.is_some() && fn_stack.last().map(|f| f.2) != Some(depth) {
                            fn_pending = None;
                        }
                    }
                    _ => {}
                }
            }
            if !test_regions.is_empty() || test_pending {
                self.lines[idx].is_test = true;
            }
        }
        spans.sort_by_key(|s| s.start);
        self.fns = spans;
    }

    fn collect_suppressions(&mut self) {
        let mut out = Vec::new();
        for (idx, line) in self.lines.iter().enumerate() {
            if let Some(s) = parse_suppression(&line.comment, idx + 1) {
                out.push(s);
            }
        }
        self.suppressions = out;
    }

    /// Is line `lineno` (1-based) suppressed for `rule`? Returns the
    /// matching suppression's index for usage inventory.
    pub fn suppression_for(&self, rule: &str, lineno: usize) -> Option<usize> {
        self.suppressions
            .iter()
            .position(|s| s.rule == rule && (s.line == lineno || s.line + 1 == lineno))
    }

    /// The innermost function span containing `lineno`, if any.
    pub fn fn_at(&self, lineno: usize) -> Option<&FnSpan> {
        self.fns
            .iter()
            .filter(|f| f.start <= lineno && lineno <= f.end)
            .min_by_key(|f| f.end - f.start)
    }

    /// Raw text of a 1-based line, trimmed, for finding excerpts.
    pub fn excerpt(&self, lineno: usize) -> String {
        self.raw
            .get(lineno - 1)
            .map(|l| l.trim().to_string())
            .unwrap_or_default()
    }
}

/// `// lint: allow(rule, reason...)` anywhere in a comment.
fn parse_suppression(comment: &str, lineno: usize) -> Option<Suppression> {
    let at = comment.find("lint: allow(")?;
    let body = &comment[at + "lint: allow(".len()..];
    let close = body.find(')')?;
    let body = &body[..close];
    let (rule, reason) = match body.split_once(',') {
        Some((r, why)) => (r.trim(), why.trim()),
        None => (body.trim(), ""),
    };
    if rule.is_empty() {
        return None;
    }
    Some(Suppression { rule: rule.to_string(), reason: reason.to_string(), line: lineno })
}

/// `fn name` on this code line (lexical; returns the identifier).
fn fn_decl_name(code: &str) -> Option<String> {
    let bytes = code.as_bytes();
    let mut search = 0usize;
    while let Some(rel) = code[search..].find("fn ") {
        let at = search + rel;
        // word boundary on the left ("fn" not a suffix of an identifier)
        let ok_left = at == 0 || !is_ident_char(bytes[at - 1] as char);
        if ok_left {
            let rest = code[at + 3..].trim_start();
            let name: String = rest.chars().take_while(|&c| is_ident_char(c)).collect();
            if !name.is_empty() {
                return Some(name);
            }
        }
        search = at + 3;
    }
    None
}

pub(crate) fn is_ident_char(c: char) -> bool {
    c.is_ascii_alphanumeric() || c == '_'
}

/// At `chars[i]` sitting on `r` or `b`: does a raw string literal start
/// here (`r"`, `r#"`, `br##"` …)? Returns (hash count, chars consumed up
/// to and including the opening quote). Only valid when `chars[i]` is not
/// part of a longer identifier (checked by the caller's position: we also
/// verify the char before is not an identifier char).
fn raw_string_hashes(chars: &[char], i: usize) -> Option<(u32, usize)> {
    if i > 0 && is_ident_char(chars[i - 1]) {
        return None;
    }
    let mut j = i;
    if chars[j] == 'b' {
        j += 1;
        if chars.get(j) != Some(&'r') {
            return None;
        }
    }
    if chars.get(j) != Some(&'r') {
        return None;
    }
    j += 1;
    let mut hashes = 0u32;
    while chars.get(j) == Some(&'#') {
        hashes += 1;
        j += 1;
    }
    if chars.get(j) == Some(&'"') {
        Some((hashes, j + 1 - i))
    } else {
        None
    }
}

/// Does `"` at `chars[i]` close a raw string with `hashes` delimiter
/// hashes (i.e. is it followed by that many `#`s)?
fn closes_raw(chars: &[char], i: usize, hashes: u32) -> bool {
    (1..=hashes as usize).all(|k| chars.get(i + k) == Some(&'#'))
}

/// Disambiguate `'` between a char literal and a lifetime: `'\...'` and
/// `'x'` are literals; `'a`, `'static`, `'_` are lifetimes.
fn char_literal_starts(chars: &[char], i: usize) -> bool {
    match chars.get(i + 1) {
        Some('\\') => true,
        Some(&c) if c != '\'' => chars.get(i + 2) == Some(&'\''),
        _ => false,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn strips_line_and_block_comments() {
        let f = SourceFile::parse(
            "t.rs",
            "let a = 1; // trailing note\n/* block\nstill block */ let b = 2;\n",
        );
        assert_eq!(f.lines[0].code.trim(), "let a = 1;");
        assert_eq!(f.lines[0].comment.trim(), "trailing note");
        assert_eq!(f.lines[1].code, "");
        assert_eq!(f.lines[1].comment.trim(), "block");
        assert_eq!(f.lines[2].code.trim(), "let b = 2;");
    }

    #[test]
    fn nested_block_comments() {
        let f = SourceFile::parse("t.rs", "/* a /* b */ c */ let x = 1;\n");
        assert_eq!(f.lines[0].code.trim(), "let x = 1;");
    }

    #[test]
    fn blanks_string_contents_and_collects_them() {
        let f = SourceFile::parse("t.rs", "let s = \"panic!(do not trip)\"; s.len();\n");
        assert!(!f.lines[0].code.contains("panic!"), "{}", f.lines[0].code);
        assert_eq!(f.lines[0].strings, vec!["panic!(do not trip)".to_string()]);
        assert!(f.lines[0].code.contains("\"\""));
    }

    #[test]
    fn string_escapes_and_embedded_quote() {
        let f = SourceFile::parse("t.rs", r#"let s = "a\"b // not a comment";"#);
        assert_eq!(f.lines[0].strings.len(), 1);
        assert!(f.lines[0].comment.is_empty());
        assert!(f.lines[0].code.ends_with(';'));
    }

    #[test]
    fn raw_strings_span_lines() {
        let f = SourceFile::parse("t.rs", "let s = r#\"one\ntwo \"quoted\" \"#; done();\n");
        assert_eq!(f.lines[0].strings.len(), 0, "raw string has not ended yet");
        assert_eq!(f.lines[1].strings.len(), 1);
        assert!(f.lines[1].strings[0].contains("quoted"));
        assert!(f.lines[1].code.contains("done()"));
    }

    #[test]
    fn char_literals_and_lifetimes() {
        let f = SourceFile::parse(
            "t.rs",
            "fn f<'a>(x: &'a str) -> char { let c = '\"'; let d = '\\n'; 'x' }\n",
        );
        // the quote inside the char literal must not open a string
        assert!(f.lines[0].strings.is_empty());
        assert!(f.lines[0].code.contains("&'a str"), "{}", f.lines[0].code);
    }

    #[test]
    fn comment_markers_inside_strings_are_inert() {
        let f = SourceFile::parse("t.rs", "let u = \"http://x\"; real();\n");
        assert!(f.lines[0].code.contains("real()"));
        assert!(f.lines[0].comment.is_empty());
    }

    #[test]
    fn cfg_test_region_is_marked() {
        let src = "fn prod() { body(); }\n#[cfg(test)]\nmod tests {\n    fn helper() {}\n}\nfn prod2() {}\n";
        let f = SourceFile::parse("t.rs", src);
        assert!(!f.lines[0].is_test);
        assert!(f.lines[1].is_test, "attribute line");
        assert!(f.lines[2].is_test);
        assert!(f.lines[3].is_test);
        assert!(f.lines[4].is_test, "closing brace line");
        assert!(!f.lines[5].is_test);
    }

    #[test]
    fn test_attr_fn_is_marked() {
        let src = "#[test]\nfn check() {\n    assert!(true);\n}\nfn prod() {}\n";
        let f = SourceFile::parse("t.rs", src);
        assert!(f.lines[2].is_test);
        assert!(!f.lines[4].is_test);
    }

    #[test]
    fn fn_spans_cover_bodies_and_nest() {
        let src = "impl X {\n    fn one(&self) {\n        a();\n    }\n    fn two() { b(); }\n}\n";
        let f = SourceFile::parse("t.rs", src);
        let names: Vec<&str> = f.fns.iter().map(|s| s.name.as_str()).collect();
        assert_eq!(names, vec!["one", "two"]);
        assert_eq!((f.fns[0].start, f.fns[0].end), (2, 4));
        assert_eq!((f.fns[1].start, f.fns[1].end), (5, 5));
        assert_eq!(f.fn_at(3).map(|s| s.name.as_str()), Some("one"));
        assert_eq!(f.fn_at(6), None);
    }

    #[test]
    fn trait_method_signatures_are_not_spans() {
        let src = "trait T {\n    fn decl(&self) -> usize;\n    fn with_body(&self) { x(); }\n}\n";
        let f = SourceFile::parse("t.rs", src);
        let names: Vec<&str> = f.fns.iter().map(|s| s.name.as_str()).collect();
        assert_eq!(names, vec!["with_body"]);
    }

    #[test]
    fn multiline_fn_signature() {
        let src = "fn long(\n    a: usize,\n) -> usize {\n    a\n}\n";
        let f = SourceFile::parse("t.rs", src);
        assert_eq!(f.fns.len(), 1);
        assert_eq!(f.fns[0].name, "long");
        assert_eq!((f.fns[0].start, f.fns[0].end), (3, 5));
    }

    #[test]
    fn suppressions_parse_and_match_next_line() {
        let src = "// lint: allow(panic-audit, documented API contract)\nfoo.unwrap();\nbar.unwrap();\n";
        let f = SourceFile::parse("t.rs", src);
        assert_eq!(f.suppressions.len(), 1);
        assert_eq!(f.suppressions[0].rule, "panic-audit");
        assert_eq!(f.suppressions[0].reason, "documented API contract");
        assert!(f.suppression_for("panic-audit", 1).is_some());
        assert!(f.suppression_for("panic-audit", 2).is_some());
        assert!(f.suppression_for("panic-audit", 3).is_none());
        assert!(f.suppression_for("lock-order", 2).is_none());
    }

    #[test]
    fn fn_keyword_inside_identifier_is_ignored() {
        let f = SourceFile::parse("t.rs", "let definitely_fn = 1;\nlet x = infn foo;\n");
        assert!(f.fns.is_empty());
    }
}
